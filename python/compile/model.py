"""L2 JAX model: the solver compute graph over the GSE-SEM ELL planes.

The paper's contribution lives at L1 (format/kernels) and the controller
at L3; the L2 graph composes the Pallas SpMV with the FP64 vector ops of
one CG iteration so XLA can fuse the whole step into a single HLO module
that the rust runtime executes AOT (no python at solve time).

Exported entry points (lowered by aot.py):
  * decode_{level}(heads, tail1, tail2, idx, scales) -> values
  * spmv_{level}(planes..., scales, x) -> y
  * cg_step_{level}(planes..., scales, x, r, p, rr) -> (x', r', p', rr')
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import gse_decode, spmv_ell


def decode_model(heads, tail1, tail2, idx, scales, *, level):
    return (gse_decode.gse_decode(heads, tail1, tail2, idx, scales, level=level),)


def spmv_model(heads, tail1, tail2, idx, cols, scales, x, *, level):
    return (spmv_ell.spmv_ell(heads, tail1, tail2, idx, cols, scales, x, level=level),)


@functools.partial(jax.jit, static_argnames=("level",))
def cg_step(heads, tail1, tail2, idx, cols, scales, x, r, p, rr, *, level):
    """One unpreconditioned CG iteration over the ELL operator.

    rr is carried as shape-(1,) so every port is a tensor (scalars in the
    PJRT boundary are awkward from rust). Returns (x', r', p', rr').
    """
    w = spmv_ell.spmv_ell(heads, tail1, tail2, idx, cols, scales, p, level=level)
    rr0 = rr[0]
    pw = jnp.dot(p, w)
    alpha = rr0 / pw
    x_new = x + alpha * p
    r_new = r - alpha * w
    rr_new = jnp.dot(r_new, r_new)
    beta = rr_new / rr0
    p_new = r_new + beta * p
    return x_new, r_new, p_new, jnp.reshape(rr_new, (1,))


def cg_step_model(*args, level):
    return tuple(cg_step(*args, level=level))


def cg_run_model(heads, tail1, tail2, idx, cols, scales, b, *, level, iters=50):
    """A fixed-iteration CG solve fused into one module (e2e demo): runs
    `iters` CG steps from x0 = 0 and returns (x, final rr)."""
    x = jnp.zeros_like(b)
    r = b
    p = b
    rr = jnp.reshape(jnp.dot(b, b), (1,))

    def body(_, carry):
        x, r, p, rr = carry
        return cg_step(heads, tail1, tail2, idx, cols, scales, x, r, p, rr, level=level)

    x, r, p, rr = jax.lax.fori_loop(0, iters, body, (x, r, p, rr))
    return x, rr
