"""AOT export: lower the L1/L2 graphs once to HLO *text* + manifest.json.

HLO text (NOT `.serialize()` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published `xla` rust
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/gen_hlo.py and DESIGN.md.

Run once via `make artifacts`; python is never on the solve path.
"""

import argparse
import functools
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Exported problem geometry (static shapes for the AOT path).
DECODE_N = 4096
ELL_ROWS = 256
ELL_WIDTH = 16
X_LEN = ELL_ROWS  # square demo systems
CG_ITERS = 50

U32 = jnp.uint32
F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries():
    """(name, fn, [(shape, dtype, label)], n_outputs) for every artifact."""
    plane = (ELL_ROWS, ELL_WIDTH)
    vec = (X_LEN,)
    entries = []
    for level in ("head", "t1", "full"):
        entries.append(
            (
                f"decode_{level}",
                functools.partial(model.decode_model, level=level),
                [((DECODE_N,), U32, "u32")] * 4 + [((64,), F64, "f64")],
                1,
            )
        )
        entries.append(
            (
                f"spmv_ell_{level}",
                functools.partial(model.spmv_model, level=level),
                [(plane, U32, "u32")] * 5 + [((64,), F64, "f64"), (vec, F64, "f64")],
                1,
            )
        )
        entries.append(
            (
                f"cg_step_{level}",
                functools.partial(model.cg_step_model, level=level),
                [(plane, U32, "u32")] * 5
                + [((64,), F64, "f64"), (vec, F64, "f64"), (vec, F64, "f64"),
                   (vec, F64, "f64"), ((1,), F64, "f64")],
                4,
            )
        )
    entries.append(
        (
            "cg_run_head",
            functools.partial(model.cg_run_model, level="head", iters=CG_ITERS),
            [(plane, U32, "u32")] * 5 + [((64,), F64, "f64"), (vec, F64, "f64")],
            2,
        )
    )
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"kernels": []}
    for name, fn, specs, n_out in build_entries():
        example = [_spec(shape, dtype) for shape, dtype, _ in specs]
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["kernels"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(shape) for shape, _, _ in specs],
                "dtypes": [label for _, _, label in specs],
                "outputs": n_out,
            }
        )
        print(f"  {name}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['kernels'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
