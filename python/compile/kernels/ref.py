"""Pure-numpy GSE-SEM reference (oracle) — bit-exact mirror of the rust
encoder/decoder (rust/src/formats/{gse,sem}.rs, External layout).

This file is the single normative python definition of the format; the
Pallas kernels are validated against it by pytest + hypothesis, and it is
itself validated against f64 semantics in test_ref.py.

Encoding spec (DESIGN.md §8, External/matrix layout):
  * table entries are IEEE-754 biased f64 exponents + 1, frequency order,
    with max_exp+1 guaranteed present;
  * index of a value = entry with the smallest diff = entry - exp >= 1
    (first match wins on ties);
  * D = ((1<<52) | mant52) >> minDiff  (explicit leading one,
    denormalized into a common 52-bit frame);
  * head  (u16) = sign<<15 | D>>37          (15 mantissa bits)
  * tail1 (u16) = (D>>21) & 0xFFFF
  * tail2 (u32) = D & (2^21 - 1)
  * decode(level) = sign * D_level * 2^(stored - 1075).
"""

import numpy as np

M_HEAD = 15
S_HEAD = 37
S_TAIL1 = 21
W_TAIL2 = 21
SCALE_EXP = 1075  # bias 1023 + mantissa 52

LEVELS = ("head", "t1", "full")


def split_f64(x):
    """(sign, biased_exp, mant52) of float64 array."""
    bits = np.asarray(x, dtype=np.float64).view(np.uint64)
    sign = (bits >> np.uint64(63)).astype(np.uint32)
    exp = ((bits >> np.uint64(52)) & np.uint64(0x7FF)).astype(np.uint32)
    mant = bits & np.uint64((1 << 52) - 1)
    return sign, exp, mant


def gse_extract(values, k):
    """Top-k shared exponents (biased+1), frequency-desc, max+1 present.

    Mirrors GseTable::from_histogram: ties break toward the smaller
    exponent; if max_exp+1 is absent it replaces the last entry.
    """
    _, exp, _ = split_f64(values)
    ok = (exp != 0) & (exp != 0x7FF)
    exp = exp[ok]
    if exp.size == 0:
        return np.array([1024], dtype=np.uint32)
    counts = np.bincount(exp, minlength=2048)
    nz = np.nonzero(counts)[0]
    # sort by count desc then exponent asc (match rust determinism)
    order = sorted(nz, key=lambda e: (-counts[e], e))
    entries = [int(e) + 1 for e in order[:k]]
    need = int(exp.max()) + 1
    if need not in entries:
        entries[-1] = need
    # dedup keeping first occurrence
    seen, out = set(), []
    for e in entries:
        if e not in seen:
            seen.add(e)
            out.append(e)
    return np.array(out, dtype=np.uint32)


def lookup(table, biased_exp):
    """(idx, minDiff) arrays for each exponent; idx = -1 if out of range."""
    biased_exp = np.asarray(biased_exp, dtype=np.int64)
    diffs = table.astype(np.int64)[None, :] - biased_exp[..., None]
    valid = diffs >= 1
    big = np.where(valid, diffs, np.int64(1 << 40))
    idx = np.argmin(big, axis=-1)  # first minimum wins ties, like rust
    mind = np.take_along_axis(big, idx[..., None], axis=-1)[..., 0]
    out_of_range = ~valid.any(axis=-1)
    idx = np.where(out_of_range, -1, idx)
    mind = np.where(out_of_range, 0, mind)
    return idx.astype(np.int64), mind.astype(np.uint64)


def sem_encode(values, table):
    """Encode float64 array -> (heads u16, tail1 u16, tail2 u32, idx u16).

    Zeros/subnormals encode to zero mantissa. Out-of-table exponents
    saturate to the largest shared binade (matching the rust fallback).
    """
    values = np.asarray(values, dtype=np.float64)
    sign, exp, mant = split_f64(values)
    idx, mind = lookup(table, exp)

    # saturation for out-of-range exponents
    oor = idx < 0
    if oor.any():
        bi = int(np.argmax(table))
        stored = int(table[bi])
        maxval = float(np.ldexp(float((1 << 52) - 1), stored - SCALE_EXP))
        vals2 = values.copy()
        vals2[oor] = np.where(np.isnan(values[oor]), 0.0, np.copysign(maxval, values[oor]))
        sign, exp, mant = split_f64(vals2)
        idx, mind = lookup(table, exp)

    normal = (exp != 0) & (exp != 0x7FF)
    d = (mant | np.uint64(1 << 52)) >> np.minimum(mind, np.uint64(63))
    d = np.where(normal, d, np.uint64(0))
    idx = np.where(normal, idx, 0)

    heads = ((sign.astype(np.uint64) << np.uint64(15)) | (d >> np.uint64(S_HEAD))).astype(
        np.uint16
    )
    tail1 = ((d >> np.uint64(S_TAIL1)) & np.uint64(0xFFFF)).astype(np.uint16)
    tail2 = (d & np.uint64((1 << W_TAIL2) - 1)).astype(np.uint32)
    return heads, tail1, tail2, idx.astype(np.uint16)


def frame(heads, tail1, tail2, level):
    """Reconstruct the D-frame prefix available at a level (uint64)."""
    d = (np.asarray(heads, dtype=np.uint64) & np.uint64(0x7FFF)) << np.uint64(S_HEAD)
    if level in ("t1", "full"):
        d = d | (np.asarray(tail1, dtype=np.uint64) << np.uint64(S_TAIL1))
    if level == "full":
        d = d | (np.asarray(tail2, dtype=np.uint64) & np.uint64((1 << W_TAIL2) - 1))
    return d


def decode(heads, tail1, tail2, idx, table, level):
    """Decode to float64 at a precision level (the rust ldexp path)."""
    d = frame(heads, tail1, tail2, level)
    stored = table.astype(np.int64)[np.asarray(idx, dtype=np.int64)]
    v = np.ldexp(d.astype(np.float64), (stored - SCALE_EXP).astype(np.int32))
    neg = (np.asarray(heads, dtype=np.uint16) & np.uint16(0x8000)) != 0
    return np.where(neg, -v, v)


def scales_from_table(table):
    """Per-index decode scale 2^(stored-1075), padded to 64 entries f64
    (what the Pallas kernels consume instead of integer exponent math)."""
    s = np.ldexp(1.0, table.astype(np.int64) - SCALE_EXP)
    out = np.zeros(64, dtype=np.float64)
    out[: len(s)] = s
    return out


def decode_float(heads, tail1, tail2, idx, scales, level):
    """Float-only decode used by the Pallas kernels (DESIGN.md §6): the
    frame is assembled in f64 arithmetic (exact, < 2^53) and scaled by a
    gathered power of two. Must agree bit-for-bit with `decode`."""
    h = np.asarray(heads, dtype=np.uint16)
    hm = (h & np.uint16(0x7FFF)).astype(np.float64)
    t1 = np.asarray(tail1, dtype=np.uint16).astype(np.float64)
    t2 = np.asarray(tail2, dtype=np.uint32).astype(np.float64)
    d = hm * float(1 << S_HEAD)
    if level in ("t1", "full"):
        d = d + t1 * float(1 << S_TAIL1)
    if level == "full":
        d = d + t2
    v = d * scales[np.asarray(idx, dtype=np.int64)]
    neg = (h & np.uint16(0x8000)) != 0
    return np.where(neg, -v, v)


def spmv_ell_ref(heads, tail1, tail2, idx, cols, scales, x, level):
    """Reference ELL SpMV: decode every slot, gather x, row-sum.

    All arrays are (R, W); padding slots must have zero heads/tails.
    """
    vals = decode_float(heads, tail1, tail2, idx, scales, level)
    gathered = x[np.asarray(cols, dtype=np.int64)]
    return (vals * gathered).sum(axis=1)
