"""L1 Pallas kernel: GSE-SEM padded-ELL SpMV.

The matrix travels as fixed-shape (R, W) planes — heads / tail1 / tail2 /
exp-idx / column-index — the static-shape view the rust side produces
with `spmv::ell::to_ell`. The grid tiles rows (`ROWS_PER_BLOCK` per
step); each step decodes its tile with the float-only SEM decode and
accumulates `sum_w vals * x[cols]`.

Hardware adaptation (DESIGN.md §6): the CUDA CSR-vector kernel assigns a
warp per row and staggers loads; here BlockSpec expresses the HBM->VMEM
tiling, the 64-entry scale table lives in VMEM with the tile, and the
gather of x is left to XLA (interpret mode) / Mosaic (real TPU).

VMEM estimate per tile at ROWS_PER_BLOCK=256, W=16 (f64 x resident):
5 planes * 256*16 * 4B = 80 KiB + x — far under the 16 MiB budget; see
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import gse_decode

ROWS_PER_BLOCK = 256


def _spmv_kernel(heads_ref, tail1_ref, tail2_ref, idx_ref, cols_ref, scales_ref, x_ref,
                 y_ref, *, level):
    vals = gse_decode._decode_block(
        heads_ref[...], tail1_ref[...], tail2_ref[...], idx_ref[...], scales_ref[...], level
    )
    x = x_ref[...]
    gathered = x[cols_ref[...]]  # (rows, W) gather
    y_ref[...] = (vals * gathered).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("level",))
def spmv_ell(heads, tail1, tail2, idx, cols, scales, x, *, level="head"):
    """y = decode(A_ell, level) @ x.

    heads/tail1/tail2/idx/cols: uint32[R, W]; scales: f64[64]; x: f64[N].
    R must be a multiple of ROWS_PER_BLOCK.
    """
    r, w = heads.shape
    assert r % ROWS_PER_BLOCK == 0, f"R={r} must be a multiple of {ROWS_PER_BLOCK}"
    n = x.shape[0]
    grid = (r // ROWS_PER_BLOCK,)
    plane = pl.BlockSpec((ROWS_PER_BLOCK, w), lambda i: (i, 0))
    table = pl.BlockSpec((64,), lambda i: (0,))
    xspec = pl.BlockSpec((n,), lambda i: (0,))
    yspec = pl.BlockSpec((ROWS_PER_BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_spmv_kernel, level=level),
        grid=grid,
        in_specs=[plane, plane, plane, plane, plane, table, xspec],
        out_specs=yspec,
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float64),
        interpret=True,
    )(heads, tail1, tail2, idx, cols, scales, x)


def spmv_ell_ref(heads, tail1, tail2, idx, cols, scales, x, *, level="head"):
    """Plain-jnp oracle."""
    vals = gse_decode._decode_block(
        jnp.asarray(heads), jnp.asarray(tail1), jnp.asarray(tail2), jnp.asarray(idx),
        jnp.asarray(scales), level,
    )
    return (vals * jnp.asarray(x)[jnp.asarray(cols)]).sum(axis=1)
