"""L1 Pallas kernel: GSE-SEM decode (head / head+tail1 / full -> f64).

The format-conversion hot-spot of the paper's Algorithm 2, rethought for
TPU (DESIGN.md §6 Hardware-Adaptation): no per-lane bit-scan — the frame
is assembled with *float* multiply-adds (each term is an integer below
2^52, so f64 arithmetic is exact) and rescaled by a power-of-two gathered
from the VMEM-resident shared-exponent scale table:

    value = sign * (head_mant * 2^37 + tail1 * 2^21 + tail2) * scale[idx]

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU efficiency is estimated in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

S_HEAD = 37
S_TAIL1 = 21

# block size for the 1-D decode grid (8*128 lanes = one VPU tile of f32,
# a safe multiple for f64 too)
BLOCK = 1024


def _decode_block(heads, tail1, tail2, idx, scales, level):
    """Decode a block of SEM words; all inputs are jnp arrays (u32/f64)."""
    hm = (heads & 0x7FFF).astype(jnp.float64)
    sign = jnp.where((heads & 0x8000) != 0, -1.0, 1.0).astype(jnp.float64)
    d = hm * float(1 << S_HEAD)
    if level in ("t1", "full"):
        d = d + tail1.astype(jnp.float64) * float(1 << S_TAIL1)
    if level == "full":
        d = d + tail2.astype(jnp.float64)
    scale = scales[idx]  # gather from the 64-entry VMEM table
    return sign * d * scale


def _decode_kernel(heads_ref, tail1_ref, tail2_ref, idx_ref, scales_ref, out_ref, *, level):
    heads = heads_ref[...]
    tail1 = tail1_ref[...]
    tail2 = tail2_ref[...]
    idx = idx_ref[...]
    scales = scales_ref[...]
    out_ref[...] = _decode_block(heads, tail1, tail2, idx, scales, level)


@functools.partial(jax.jit, static_argnames=("level",))
def gse_decode(heads, tail1, tail2, idx, scales, *, level="full"):
    """Decode `n` SEM words (u32 planes) to f64.

    heads/tail1/tail2/idx: uint32[n] (u16 planes widened at the boundary —
    the rust `xla` crate only constructs u32/u64 integer literals).
    scales: float64[64] per-index scale table.
    """
    n = heads.shape[0]
    assert n % BLOCK == 0, f"n={n} must be a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    block = pl.BlockSpec((BLOCK,), lambda i: (i,))
    # the scale table rides along whole in every grid step
    table_spec = pl.BlockSpec((64,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_decode_kernel, level=level),
        grid=grid,
        in_specs=[block, block, block, block, table_spec],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
        interpret=True,
    )(heads, tail1, tail2, idx, scales)


def gse_decode_ref(heads, tail1, tail2, idx, scales, *, level="full"):
    """Plain-jnp oracle of the same computation (no pallas)."""
    return _decode_block(
        jnp.asarray(heads), jnp.asarray(tail1), jnp.asarray(tail2), jnp.asarray(idx),
        jnp.asarray(scales), level,
    )
