"""L1 Pallas kernels + the pure-numpy reference oracle."""
