"""L2 model tests: the CG step graph behaves like CG, and the AOT export
lowers every entry point to valid HLO text."""

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def spd_ell_system(rng, rows=256, width=16):
    """A strictly diagonally dominant *symmetric* banded system in ELL
    planes (bandwidth (width-1)//2 each side) — genuinely SPD so CG
    converges."""
    n = rows
    half = (width - 1) // 2
    cols = np.zeros((rows, width), dtype=np.uint32)
    vals = np.zeros((rows, width))
    weight = {}
    for r in range(rows):
        cols[r, 0] = r
        slot = 1
        s = 0.0
        for d in range(1, half + 1):
            for c in (r - d, r + d):
                if 0 <= c < n:
                    key = (min(r, c), max(r, c))
                    if key not in weight:
                        weight[key] = rng.exponential() + 0.1
                    w = weight[key]
                    cols[r, slot] = c
                    vals[r, slot] = -w
                    s += w
                    slot += 1
        vals[r, 0] = s * 1.2 + 0.5
    table = ref.gse_extract(vals.ravel(), 8)
    h, t1, t2, idx = ref.sem_encode(vals.ravel(), table)
    shape = (rows, width)
    planes = tuple(
        np.ascontiguousarray(p.reshape(shape), dtype=np.uint32) for p in (h, t1, t2, idx)
    )
    return planes, cols, ref.scales_from_table(table), vals, table


class TestCgStep:
    def test_one_step_reduces_residual(self):
        rng = np.random.default_rng(11)
        planes, cols, scales, _, _ = spd_ell_system(rng)
        n = cols.shape[0]
        b = rng.normal(size=n)
        x = np.zeros(n)
        r = b.copy()
        p = b.copy()
        rr = np.array([b @ b])
        x1, r1, p1, rr1 = model.cg_step(
            *planes, cols, scales, x, r, p, rr, level="full"
        )
        assert float(rr1[0]) < float(rr[0])
        assert np.isfinite(np.asarray(x1)).all()

    def test_cg_run_converges_on_spd(self):
        rng = np.random.default_rng(13)
        planes, cols, scales, _, _ = spd_ell_system(rng)
        n = cols.shape[0]
        b = rng.normal(size=n)
        x, rr = model.cg_run_model(*planes, cols, scales, b, level="full", iters=100)
        rel = np.sqrt(float(rr[0])) / np.linalg.norm(b)
        assert rel < 1e-6, rel

    def test_head_level_stalls_above_full(self):
        """Low-precision A: CG residual floor is higher than full's —
        the phenomenon the stepped controller exploits."""
        rng = np.random.default_rng(17)
        planes, cols, scales, _, _ = spd_ell_system(rng)
        n = cols.shape[0]
        b = rng.normal(size=n)
        _, rr_head = model.cg_run_model(*planes, cols, scales, b, level="head", iters=100)
        _, rr_full = model.cg_run_model(*planes, cols, scales, b, level="full", iters=100)
        assert float(rr_full[0]) <= float(rr_head[0])


class TestAotExport:
    def test_all_entries_lower_to_hlo(self, tmp_path):
        for name, fn, specs, _ in aot.build_entries():
            example = [aot._spec(shape, dtype) for shape, dtype, _ in specs]
            import jax

            lowered = jax.jit(fn).lower(*example)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_main_writes_manifest(self, tmp_path, monkeypatch):
        out = tmp_path / "arts"
        monkeypatch.setattr(
            "sys.argv", ["aot.py", "--out", str(out)]
        )
        aot.main()
        import json

        man = json.loads((out / "manifest.json").read_text())
        names = {k["name"] for k in man["kernels"]}
        assert "spmv_ell_head" in names
        assert "cg_run_head" in names
        for k in man["kernels"]:
            assert (out / k["file"]).exists()
            assert len(k["inputs"]) == len(k["dtypes"])
