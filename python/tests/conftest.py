"""Test setup: f64 must be enabled before any jax tracing happens."""

import jax

jax.config.update("jax_enable_x64", True)
