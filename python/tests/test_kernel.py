"""Pallas kernels vs the numpy oracle — the core L1 correctness signal.

hypothesis sweeps shapes, k, magnitude spreads, and precision levels;
every comparison is exact (the decode is float-exact by construction) or
allclose for the SpMV reductions (summation-order drift only).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gse_decode, ref, spmv_ell


def make_planes(rng, n, k, sigma):
    vals = np.exp(rng.normal(0, sigma, size=n)) * rng.choice([-1.0, 1.0], size=n)
    table = ref.gse_extract(vals, k)
    h, t1, t2, idx = ref.sem_encode(vals, table)
    scales = ref.scales_from_table(table)
    return vals, table, h, t1, t2, idx, scales


def widen(a):
    return np.ascontiguousarray(a, dtype=np.uint32)


class TestDecodeKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        st.sampled_from([1024, 2048, 4096]),
        st.sampled_from([1, 2, 8, 64]),
        st.floats(0.1, 6.0),
        st.sampled_from(list(ref.LEVELS)),
        st.integers(0, 2**31),
    )
    def test_matches_oracle(self, n, k, sigma, level, seed):
        rng = np.random.default_rng(seed)
        _, table, h, t1, t2, idx, scales = make_planes(rng, n, k, sigma)
        got = np.asarray(
            gse_decode.gse_decode(
                widen(h), widen(t1), widen(t2), widen(idx), scales, level=level
            )
        )
        want = ref.decode_float(h, t1, t2, idx, scales, level)
        np.testing.assert_array_equal(got, want)

    def test_decode_equals_true_values_at_full(self):
        rng = np.random.default_rng(7)
        vals, table, h, t1, t2, idx, scales = make_planes(rng, 1024, 8, 2.0)
        got = np.asarray(
            gse_decode.gse_decode(widen(h), widen(t1), widen(t2), widen(idx), scales,
                                  level="full")
        )
        nz = vals != 0
        rel = np.abs(got[nz] - vals[nz]) / np.abs(vals[nz])
        assert rel.max() <= 2.0 ** -40

    def test_block_misalignment_rejected(self):
        rng = np.random.default_rng(1)
        _, _, h, t1, t2, idx, scales = make_planes(rng, 1024, 8, 1.0)
        with pytest.raises(AssertionError):
            gse_decode.gse_decode(
                widen(h[:1000]), widen(t1[:1000]), widen(t2[:1000]), widen(idx[:1000]),
                scales, level="head",
            )

    def test_kernel_vs_plain_jnp_path(self):
        rng = np.random.default_rng(5)
        _, _, h, t1, t2, idx, scales = make_planes(rng, 2048, 16, 3.0)
        a = np.asarray(
            gse_decode.gse_decode(widen(h), widen(t1), widen(t2), widen(idx), scales,
                                  level="t1")
        )
        b = np.asarray(
            gse_decode.gse_decode_ref(widen(h), widen(t1), widen(t2), widen(idx), scales,
                                      level="t1")
        )
        np.testing.assert_array_equal(a, b)


class TestSpmvKernel:
    @settings(max_examples=12, deadline=None)
    @given(
        st.sampled_from([256, 512]),
        st.sampled_from([4, 16]),
        st.sampled_from([2, 8]),
        st.sampled_from(list(ref.LEVELS)),
        st.integers(0, 2**31),
    )
    def test_matches_oracle(self, rows, width, k, level, seed):
        rng = np.random.default_rng(seed)
        n = rows
        _, table, h, t1, t2, idx, scales = make_planes(rng, rows * width, k, 2.0)
        shape = (rows, width)
        cols = rng.integers(0, n, size=shape).astype(np.uint32)
        x = rng.normal(size=n)
        got = np.asarray(
            spmv_ell.spmv_ell(
                widen(h.reshape(shape)), widen(t1.reshape(shape)),
                widen(t2.reshape(shape)), widen(idx.reshape(shape)),
                cols, scales, x, level=level,
            )
        )
        want = ref.spmv_ell_ref(
            h.reshape(shape), t1.reshape(shape), t2.reshape(shape),
            idx.reshape(shape), cols, scales, x, level,
        )
        # identical decode, summation order may differ inside the kernel
        scale = np.abs(want).max() if want.size else 1.0
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12 * max(scale, 1e-300))

    def test_zero_padding_contributes_nothing(self):
        rows, width, n = 256, 8, 256
        rng = np.random.default_rng(2)
        _, table, h, t1, t2, idx, scales = make_planes(rng, rows * width, 8, 1.0)
        shape = (rows, width)
        h = h.reshape(shape).copy()
        t1 = t1.reshape(shape).copy()
        t2 = t2.reshape(shape).copy()
        idx = idx.reshape(shape).copy()
        cols = rng.integers(0, n, size=shape).astype(np.uint32)
        # zero out the last two slots of every row (padding)
        for plane in (h, t1, t2, idx):
            plane[:, -2:] = 0
        x = rng.normal(size=n)
        full = np.asarray(
            spmv_ell.spmv_ell(widen(h), widen(t1), widen(t2), widen(idx), cols, scales,
                              x, level="full")
        )
        # same result when padding columns point anywhere else
        cols2 = cols.copy()
        cols2[:, -2:] = 0
        moved = np.asarray(
            spmv_ell.spmv_ell(widen(h), widen(t1), widen(t2), widen(idx), cols2, scales,
                              x, level="full")
        )
        np.testing.assert_allclose(full, moved, rtol=1e-13)
