"""Validate the numpy GSE-SEM oracle against float64 semantics.

These tests pin down the format spec itself (DESIGN.md §8); the Pallas
kernels are then validated against this oracle in test_kernel.py, and
the rust implementation pins the same golden values in its unit tests —
the three implementations meet at this spec.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def finite_values(min_mag=1e-300, max_mag=1e300):
    return st.floats(
        allow_nan=False,
        allow_infinity=False,
        allow_subnormal=False,
        min_value=-max_mag,
        max_value=max_mag,
    ).filter(lambda x: x == 0.0 or abs(x) >= min_mag)


class TestExtract:
    def test_single_binade(self):
        t = ref.gse_extract(np.array([1.0, 1.5, 1.9]), 8)
        assert list(t) == [1024]  # biased 1023 + 1

    def test_frequency_order_and_max_guarantee(self):
        vals = np.array([2.0] * 5 + [1.0] * 3 + [1e300])
        t = ref.gse_extract(vals, 2)
        assert t[0] == 1025  # most frequent first
        maxe = np.frexp(1e300)[1] - 1 + 1023  # biased exponent of 1e300
        assert (maxe + 1) in t  # max+1 present even at k=2

    def test_k_larger_than_distinct(self):
        t = ref.gse_extract(np.array([1.0, 2.0]), 64)
        assert len(t) == 2

    def test_empty_and_zero_input(self):
        t = ref.gse_extract(np.array([0.0, 0.0]), 4)
        assert list(t) == [1024]


class TestGolden:
    """Golden values shared with the rust tests (sem.rs)."""

    def test_encode_1p5_single_entry_table(self):
        table = np.array([1024], dtype=np.uint32)
        h, t1, t2, idx = ref.sem_encode(np.array([1.5]), table)
        # D = (0b11 << 51) >> 1 = 3 << 50; head mant = D >> 37 = 3 << 13
        assert h[0] == 0x6000
        assert t1[0] == 0 and t2[0] == 0 and idx[0] == 0
        assert ref.decode(h, t1, t2, idx, table, "head")[0] == 1.5

    def test_encode_negative_sign_bit(self):
        table = np.array([1024], dtype=np.uint32)
        h, *_ = ref.sem_encode(np.array([-1.5]), table)
        assert h[0] == 0xE000

    def test_zero_encodes_to_zero(self):
        table = np.array([1024], dtype=np.uint32)
        h, t1, t2, idx = ref.sem_encode(np.array([0.0, -0.0, 1e-310]), table)
        for level in ref.LEVELS:
            np.testing.assert_array_equal(
                ref.decode(h, t1, t2, idx, table, level), [0.0, 0.0, 0.0]
            )


class TestRoundtrip:
    @settings(max_examples=300, deadline=None)
    @given(
        st.lists(finite_values(1e-30, 1e30), min_size=1, max_size=100),
        st.sampled_from([1, 2, 4, 8, 16, 64]),
    )
    def test_full_precision_relative_error(self, vals, k):
        vals = np.array(vals, dtype=np.float64)
        table = ref.gse_extract(vals, k)
        h, t1, t2, idx = ref.sem_encode(vals, table)
        out = ref.decode(h, t1, t2, idx, table, "full")
        nz = vals != 0
        if nz.any():
            rel = np.abs(out[nz] - vals[nz]) / np.abs(vals[nz])
            # full level keeps >= 52 - (minDiff-1) frame bits; with the
            # guaranteed max+1 entry minDiff is small for top binades but
            # can be large for tiny values under small k — bound by the
            # k=1 worst case: every kept value within its own binade
            # loses at most minDiff bits.
            assert np.all(rel <= 1.0)
            # exact-hit values (minDiff == 1) lose only mantissa bit 0
            _, exp, _ = ref.split_f64(vals)
            rel_full = np.zeros_like(vals)
            rel_full[nz] = rel
            exact = np.isin(exp + 1, table) & nz
            assert np.all(rel_full[exact] <= 2.0 ** -51)

    @settings(max_examples=200, deadline=None)
    @given(
        st.lists(finite_values(1e-6, 1e6), min_size=1, max_size=60),
        st.sampled_from([2, 8, 32]),
    )
    def test_levels_monotone(self, vals, k):
        vals = np.array(vals, dtype=np.float64)
        table = ref.gse_extract(vals, k)
        h, t1, t2, idx = ref.sem_encode(vals, table)
        errs = [
            np.abs(ref.decode(h, t1, t2, idx, table, lvl) - vals).max()
            for lvl in ref.LEVELS
        ]
        assert errs[0] >= errs[1] >= errs[2]

    @settings(max_examples=200, deadline=None)
    @given(st.lists(finite_values(1e-10, 1e10), min_size=1, max_size=60))
    def test_decode_equals_decode_float(self, vals):
        """The integer (ldexp) and float-only (Pallas-style) decodes are
        the same function."""
        vals = np.array(vals, dtype=np.float64)
        table = ref.gse_extract(vals, 8)
        h, t1, t2, idx = ref.sem_encode(vals, table)
        scales = ref.scales_from_table(table)
        for lvl in ref.LEVELS:
            a = ref.decode(h, t1, t2, idx, table, lvl)
            b = ref.decode_float(h, t1, t2, idx, scales, lvl)
            np.testing.assert_array_equal(a, b)

    def test_saturation_out_of_table(self):
        table = np.array([1024], dtype=np.uint32)  # covers exp <= 1023
        h, t1, t2, idx = ref.sem_encode(np.array([1e300, -1e300]), table)
        out = ref.decode(h, t1, t2, idx, table, "full")
        assert np.isfinite(out).all()
        assert out[0] > 0 > out[1]
        assert out[0] < 2.0  # clamped into the largest shared binade


class TestSpmvRef:
    def test_matches_dense_matvec(self):
        rng = np.random.default_rng(3)
        R, W, N = 8, 4, 8
        dense = np.zeros((R, N))
        cols = rng.integers(0, N, size=(R, W))
        vals = rng.normal(size=(R, W)) * np.exp(rng.normal(size=(R, W)))
        # build ELL planes; allow duplicate cols (they sum)
        table = ref.gse_extract(vals.ravel(), 8)
        h, t1, t2, idx = ref.sem_encode(vals.ravel(), table)
        shape = (R, W)
        scales = ref.scales_from_table(table)
        x = rng.normal(size=N)
        y = ref.spmv_ell_ref(
            h.reshape(shape), t1.reshape(shape), t2.reshape(shape),
            idx.reshape(shape), cols, scales, x, "full",
        )
        decoded = ref.decode(h, t1, t2, idx, table, "full").reshape(shape)
        for r in range(R):
            for w in range(W):
                dense[r, cols[r, w]] += decoded[r, w]
        np.testing.assert_allclose(y, dense @ x, rtol=1e-12)
