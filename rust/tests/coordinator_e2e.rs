//! Coordinator end-to-end: batch suites through the worker pool, CLI
//! parsing, corpus IO round-trips — the L3 surface a downstream user
//! touches.

use gsem::coordinator::cli::Cli;
use gsem::coordinator::{
    FormatChoice, RhsSpec, ServiceConfig, SolveRequest, SolveSpec, SolverKind, SolverPool,
    SolverService,
};
use gsem::formats::ValueFormat;
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::corpus::{cg_set, gmres_set, CorpusSize};
use gsem::sparse::mm;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn mini_suite_runs_all_formats_on_first_cg_matrices() {
    let set = cg_set(CorpusSize::Small);
    let pool = SolverPool::new(2);
    let mut reqs = Vec::new();
    for m in set.iter().take(3) {
        let a = Arc::new(m.a.clone());
        for fmt in [
            FormatChoice::fixed(ValueFormat::Fp64),
            FormatChoice::fixed(ValueFormat::Bf16),
            FormatChoice::Stepped { k: 8, params: SteppedParams::cg_paper().scaled(0.01) },
        ] {
            reqs.push(SolveRequest::new(&m.name, Arc::clone(&a), SolverKind::Cg, fmt));
        }
    }
    let res: Vec<_> = pool.run_batch(reqs).into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(res.len(), 9);
    // every FP64 run on the small CG set must converge
    for r in res.iter().filter(|r| r.format_label == "FP64") {
        assert!(r.outcome.converged, "{} failed: {}", r.name, r.relres_fp64);
    }
    // no NaNs anywhere except flagged breakdowns
    for r in &res {
        if !r.outcome.broke_down {
            assert!(r.relres_fp64.is_finite(), "{} {}", r.name, r.format_label);
        }
    }
}

#[test]
fn pool_batches_same_matrix_cg_and_caches_encodes() {
    // 4 random-RHS CG requests on one matrix: the pool must merge them
    // into one multi-RHS block solve, and the GSE requests must share a
    // single encode through the operator cache
    let set = cg_set(CorpusSize::Small);
    let a = Arc::new(set[0].a.clone());
    let mut reqs = Vec::new();
    for seed in 0..4u64 {
        let mut r = SolveRequest::new(
            &format!("rhs{seed}"),
            Arc::clone(&a),
            SolverKind::Cg,
            FormatChoice::fixed(ValueFormat::Fp64),
        );
        r.rhs = gsem::coordinator::RhsSpec::Random(seed);
        reqs.push(r);
    }
    for level in [gsem::formats::Precision::Head, gsem::formats::Precision::Full] {
        reqs.push(SolveRequest::new(
            "gse",
            Arc::clone(&a),
            SolverKind::Cg,
            FormatChoice::fixed(ValueFormat::GseSem(level)),
        ));
    }
    let pool = SolverPool::new(2);
    let res: Vec<_> = pool.run_batch(reqs).into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(res.len(), 6);
    for r in &res {
        assert!(r.relres_fp64.is_finite(), "{} {}", r.name, r.format_label);
    }
    assert_eq!(pool.metrics().counter("pool.batched_groups"), 1);
    assert_eq!(pool.metrics().counter("pool.batched_rhs"), 4);
    // GSE head + full share one encode: at least one cache hit there,
    // plus FP64 residual-operator reuse across all six jobs
    // expected: 2 misses (FP64 op, GSE encode) and 4 hits (shared FP64
    // residual operator ×3, second GSE level ×1)
    let st = pool.cache().stats();
    assert!(st.hits >= 4, "hits={} misses={}", st.hits, st.misses);
    assert_eq!(st.misses, 2, "misses={}", st.misses);
}

#[test]
fn gmres_small_suite_first_entries() {
    let set = gmres_set(CorpusSize::Small);
    let pool = SolverPool::new(2);
    let reqs: Vec<SolveRequest> = set
        .iter()
        .take(2)
        .map(|m| {
            SolveRequest::new(
                &m.name,
                Arc::new(m.a.clone()),
                SolverKind::Gmres,
                FormatChoice::fixed(ValueFormat::Fp64),
            )
        })
        .collect();
    for r in pool.run_batch(reqs) {
        let r = r.unwrap();
        assert!(r.outcome.iters > 0);
        assert!(r.relres_fp64.is_finite());
    }
}

#[test]
fn service_merges_staggered_corpus_requests_across_arcs() {
    // the serve-path e2e: requests arrive staggered, each holding its
    // *own* clone of the corpus matrix (distinct Arc allocations). The
    // windowed intake plus digest keying must still batch them into
    // one multi-RHS CG solve over one cached operator.
    let set = cg_set(CorpusSize::Small);
    let svc = SolverService::new(
        ServiceConfig::new().workers(2).window(Duration::from_secs(30)).batch_width(4),
    );
    let tickets: Vec<_> = (0..4u64)
        .map(|seed| {
            let a = Arc::new(set[0].a.clone()); // fresh allocation per request
            let mut spec = SolveSpec::new(
                &format!("rr{seed}"),
                svc.register(&a),
                SolverKind::Cg,
                FormatChoice::fixed(ValueFormat::Fp64),
            );
            spec.rhs = RhsSpec::Random(seed);
            svc.submit(spec).unwrap()
        })
        .collect();
    for (seed, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_eq!(r.name, format!("rr{seed}"));
        assert!(r.outcome.converged, "rr{seed}: {}", r.relres_fp64);
    }
    assert_eq!(svc.metrics().counter("pool.batched_groups"), 1);
    assert_eq!(svc.metrics().counter("pool.batched_rhs"), 4);
    assert_eq!(svc.metrics().counter("intake.merged"), 4);
    // one fp64 operator miss; the residual lookup and every duplicate
    // registration hit the same digest-keyed entry
    let st = svc.registry().stats();
    assert_eq!(st.misses, 1, "stats: {st:?}");
}

#[test]
fn cli_surface_matches_docs() {
    let c = Cli::parse(
        "solve --matrix poisson2d_16x16 --solver cg --format stepped --k 8 --scale 0.05"
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    assert_eq!(c.command.as_deref(), Some("solve"));
    assert_eq!(c.get("format"), Some("stepped"));
    assert_eq!(c.get_usize("k", 0).unwrap(), 8);
    assert_eq!(c.get_f64("scale", 0.0).unwrap(), 0.05);
}

#[test]
fn corpus_matrix_roundtrips_through_matrixmarket() {
    let set = cg_set(CorpusSize::Small);
    let dir = std::env::temp_dir().join("gsem_e2e_mm");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("m.mtx");
    mm::write_path(&set[0].a, &p).unwrap();
    let back = mm::read_path(&p).unwrap();
    assert_eq!(back, set[0].a);
}
