//! Randomized property suite on the GSE-SEM format invariants —
//! the deeper contracts the unit tests don't pin down — plus the
//! batched-operator parity contract (`apply_multi` ≡ looped applies).

use gsem::formats::gse::GseTable;
use gsem::formats::sem::{self, SemGeometry, SemLayout};
use gsem::formats::{Precision, SemVector};
use gsem::spmv::{GseCsr, SpmvOp};
use gsem::util::quickcheck::check;
use gsem::util::Prng;

fn random_values(r: &mut Prng, n: usize, sigma: f64) -> Vec<f64> {
    (0..n)
        .map(|_| r.lognormal(0.0, sigma) * if r.chance(0.5) { -1.0 } else { 1.0 })
        .collect()
}

#[test]
fn sign_symmetry_only_flips_sign_bit() {
    check(
        11,
        2000,
        |r| (r.lognormal(0.0, 4.0), 1 + r.below(32)),
        |(x, k)| {
            let t = GseTable::from_values(&[*x, -*x], *k);
            let g = SemGeometry::new(SemLayout::External, t.ei_bit);
            let p = sem::encode(*x, &t, &g).map_err(|e| format!("{e:?}"))?;
            let n = sem::encode(-*x, &t, &g).map_err(|e| format!("{e:?}"))?;
            if p.head ^ n.head != 0x8000 || p.tail1 != n.tail1 || p.tail2 != n.tail2 {
                return Err(format!("sign asymmetry at x={x}"));
            }
            Ok(())
        },
    );
}

#[test]
fn decode_is_monotone_within_a_binade() {
    // truncation is order-preserving for values sharing one exponent
    // (same expIdx/minDiff): x <= y  =>  dec(x) <= dec(y). Across
    // binades the per-binade minDiff differs, so global order is NOT
    // preserved — that is inherent to denormalized storage, not a bug.
    check(
        13,
        300,
        |r| {
            let e = r.range_i64(-20, 20) as i32;
            let mut xs: Vec<f64> =
                (0..64).map(|_| gsem::formats::ieee::ldexp(1.0 + r.f64(), e)).collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (xs, 1 + r.below(16))
        },
        |(xs, k)| {
            let enc = SemVector::encode(xs, *k);
            for lvl in Precision::LADDER {
                let dec = enc.decode(lvl);
                for w in dec.windows(2) {
                    if w[0] > w[1] {
                        return Err(format!("order violated at {lvl:?}: {} > {}", w[0], w[1]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn decode_never_overshoots_magnitude() {
    // |decode(x)| <= |x| always (pure truncation, no rounding up)
    check(
        17,
        500,
        |r| (random_values(r, 50, 5.0), 1 + r.below(64)),
        |(xs, k)| {
            let enc = SemVector::encode(xs, *k);
            for lvl in Precision::LADDER {
                let dec = enc.decode(lvl);
                for (x, d) in xs.iter().zip(&dec) {
                    if d.abs() > x.abs() {
                        return Err(format!("overshoot {d} vs {x} at {lvl:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn inline_and_external_layouts_agree_at_full_precision_bits() {
    // the two layouts share tails geometry differences but decode the
    // same values when the mantissa fits both heads
    check(
        19,
        400,
        |r| {
            // small mantissas: values with <= 9 significant bits
            let xs: Vec<f64> = (0..20)
                .map(|_| (1 + r.below(511)) as f64 * 2f64.powi(r.range_i64(-8, 8) as i32))
                .collect();
            (xs, 2 + r.below(7))
        },
        |(xs, k)| {
            let t = GseTable::from_values(xs, *k);
            let gi = SemGeometry::new(SemLayout::Inline, t.ei_bit);
            let ge = SemGeometry::new(SemLayout::External, t.ei_bit);
            for &x in xs {
                let pi = sem::encode(x, &t, &gi).map_err(|e| format!("{e:?}"))?;
                let pe = sem::encode(x, &t, &ge).map_err(|e| format!("{e:?}"))?;
                let di = sem::decode_ldexp(&pi, &t, &gi, Precision::Full);
                let de = sem::decode_ldexp(&pe, &t, &ge, Precision::Full);
                if di.to_bits() != de.to_bits() {
                    return Err(format!("layouts disagree: {di} vs {de} for {x}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gse_csr_packed_and_unpacked_agree() {
    check(
        23,
        60,
        |r| {
            let n = 16 + r.below(48);
            let a = gsem::sparse::gen::randmat::exp_controlled(
                n,
                n,
                4,
                gsem::sparse::gen::randmat::ExpLaw::Gaussian { e0: 0, sigma: 4.0 },
                r.next_u64(),
            );
            let x: Vec<f64> = (0..n).map(|_| r.range_f64(-1.0, 1.0)).collect();
            (a, x)
        },
        |(a, x)| {
            let packed = GseCsr::from_csr(a, 8);
            if !packed.packed {
                return Err("expected packed".into());
            }
            // force the unpacked path by faking a huge column count
            let mut wide = a.clone();
            wide.ncols = (1usize << 31) + 1;
            let unpacked = GseCsr::from_csr(&wide, 8);
            if unpacked.packed {
                return Err("expected unpacked".into());
            }
            for lvl in Precision::LADDER {
                for j in 0..packed.nnz() {
                    let dp = packed.decode(j, lvl);
                    let du = unpacked.decode(j, lvl);
                    if dp.to_bits() != du.to_bits() {
                        return Err(format!("packed/unpacked mismatch nnz {j} {lvl:?}"));
                    }
                }
            }
            let mut y = vec![0.0; a.nrows];
            packed.spmv(x, &mut y, Precision::Head);
            Ok(())
        },
    );
}

#[test]
fn table_reuse_is_stable_across_perturbed_data() {
    // §III-B1: "the group exponent setting can be reused in subsequent
    // calculations" — a table from data D encodes data D' drawn from the
    // same distribution with bounded extra error.
    check(
        29,
        100,
        |r| {
            let seed = r.next_u64();
            (seed, 1.0 + r.f64() * 3.0)
        },
        |(seed, sigma)| {
            let mut r1 = Prng::new(*seed);
            let mut r2 = Prng::new(seed ^ 0xABCD);
            let train = random_values(&mut r1, 500, *sigma);
            let test = random_values(&mut r2, 500, *sigma);
            let t = GseTable::from_values(&train, 8);
            let enc = SemVector::encode_with_table(&test, t);
            let dec = enc.decode(Precision::Full);
            for (x, d) in test.iter().zip(&dec) {
                // either well represented, or clamped/zeroed only when the
                // test value's magnitude is outside the train range
                let rel = ((x - d) / x).abs();
                let train_max = train.iter().fold(0.0f64, |m, v| m.max(v.abs()));
                if *x != 0.0 && x.abs() <= train_max && rel > 1e-6 && d.abs() > 0.0 {
                    // values far below the table's smallest exponent lose
                    // bits proportional to the distance; accept if tiny
                    if x.abs() > train_max * 1e-12 {
                        return Err(format!("reuse error x={x} d={d} rel={rel}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn apply_multi_is_bit_identical_to_looped_single_applies() {
    // the batched-operator contract: for every storage format, fused
    // apply_multi at nrhs ∈ {1, 3, 8} equals nrhs single applies
    // bit-for-bit, below and above the parallel row threshold and for
    // every worker count
    check(
        37,
        8,
        |r| {
            // straddle the parallel fallback (PAR_MIN_ROWS = 1024 rows)
            let n = if r.chance(0.5) {
                40 + r.below(60)
            } else {
                1040 + r.below(80)
            };
            let a = gsem::sparse::gen::randmat::exp_controlled(
                n,
                n,
                4,
                gsem::sparse::gen::randmat::ExpLaw::Gaussian { e0: 0, sigma: 3.0 },
                r.next_u64(),
            );
            let threads = 1 + r.below(4);
            (a, threads)
        },
        |(a, threads)| {
            let ops: Vec<Box<dyn SpmvOp>> = gsem::spmv::build_operators_par(a, 8, *threads);
            let mut rx = Prng::new(77);
            for nrhs in [1usize, 3, 8] {
                let x: Vec<f64> = (0..a.ncols * nrhs).map(|_| rx.range_f64(-2.0, 2.0)).collect();
                for op in &ops {
                    let mut y_fused = vec![0.0; a.nrows * nrhs];
                    op.apply_multi(&x, &mut y_fused, nrhs);
                    let mut y_loop = vec![0.0; a.nrows * nrhs];
                    gsem::spmv::apply_multi_looped(op.as_ref(), &x, &mut y_loop, nrhs);
                    for (i, (f, l)) in y_fused.iter().zip(&y_loop).enumerate() {
                        if f.to_bits() != l.to_bits() {
                            return Err(format!(
                                "{} nrhs={nrhs} threads={threads}: slot {i} {f} != {l}",
                                op.format().label()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn spmv_linearity_in_x() {
    // A(a·x + y) = a·Ax + Ay holds exactly for the decoded operator up
    // to f64 rounding of the vector ops
    check(
        31,
        80,
        |r| {
            let n = 20 + r.below(40);
            let a = gsem::sparse::gen::fem::diffusion2d(
                (n as f64).sqrt().ceil() as usize + 2,
                (n as f64).sqrt().ceil() as usize + 2,
                6.0,
                r.next_u64(),
            );
            let nn = a.nrows;
            let x: Vec<f64> = (0..nn).map(|_| r.range_f64(-1.0, 1.0)).collect();
            let y: Vec<f64> = (0..nn).map(|_| r.range_f64(-1.0, 1.0)).collect();
            (a, x, y, r.range_f64(-2.0, 2.0))
        },
        |(a, x, y, alpha)| {
            let g = GseCsr::from_csr(a, 8);
            let n = a.nrows;
            let mut ax = vec![0.0; n];
            let mut ay = vec![0.0; n];
            let mut axy = vec![0.0; n];
            g.spmv(x, &mut ax, Precision::Head);
            g.spmv(y, &mut ay, Precision::Head);
            let comb: Vec<f64> = x.iter().zip(y).map(|(xi, yi)| alpha * xi + yi).collect();
            g.spmv(&comb, &mut axy, Precision::Head);
            for i in 0..n {
                let want = alpha * ax[i] + ay[i];
                let scale = want.abs().max(1.0);
                if (axy[i] - want).abs() > 1e-10 * scale {
                    return Err(format!("nonlinearity row {i}: {} vs {want}", axy[i]));
                }
            }
            Ok(())
        },
    );
}
