//! Failure-injection tests: malformed inputs and degenerate systems must
//! produce errors or flagged breakdowns, never panics or silent garbage.

use gsem::coordinator::{FormatChoice, ServiceError, SolveRequest, SolveResult, SolverKind};
use gsem::formats::ValueFormat;
use gsem::runtime::artifacts::Manifest;
use gsem::sparse::coo::Coo;
use gsem::sparse::csr::Csr;
use gsem::sparse::mm;
use std::io::Cursor;
use std::sync::Arc;

#[test]
fn matrixmarket_rejects_malformed_inputs() {
    let cases: &[&str] = &[
        "",                                                       // empty
        "%%MatrixMarket matrix coordinate real general\n",        // no size
        "%%MatrixMarket matrix coordinate real general\n2 2\n",   // short size
        "%%MatrixMarket matrix coordinate real general\n2 2 1\n", // missing entry
        "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n", // missing value
        "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n", // 0-based
        "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 abc\n", // bad number
        "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1 1\n", // complex
    ];
    for (i, c) in cases.iter().enumerate() {
        assert!(mm::read(Cursor::new(*c)).is_err(), "case {i} should fail");
    }
}

#[test]
fn manifest_rejects_malformed_json() {
    let dir = std::env::temp_dir().join("gsem_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    for (i, text) in [
        "not json at all",
        "{\"kernels\": \"nope\"}",
        "{\"kernels\": [{\"name\": \"x\"}]}", // missing file/inputs
        "{}",
    ]
    .iter()
    .enumerate()
    {
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        assert!(Manifest::load(&dir).is_err(), "case {i} should fail: {text}");
    }
    let _ = std::fs::remove_file(dir.join("manifest.json"));
}

/// Redeem a typed dispatch result for inspection: a clean result passes
/// through, a [`ServiceError::Breakdown`] yields its partial result
/// (that is the point of boxing it), anything else is a test failure.
fn redeem(res: Result<SolveResult, ServiceError>) -> SolveResult {
    match res {
        Ok(r) => r,
        Err(ServiceError::Breakdown(b)) => *b,
        Err(e) => panic!("unexpected service error: {e}"),
    }
}

#[test]
fn singular_matrix_solves_flag_not_panic() {
    // zero matrix: CG breaks down (pAp = 0), GMRES stalls — all flagged
    let a = Arc::new(Csr::empty(16, 16));
    for solver in [SolverKind::Cg, SolverKind::Gmres, SolverKind::Bicgstab] {
        let mut req = SolveRequest::new(
            "zero",
            Arc::clone(&a),
            solver,
            FormatChoice::fixed(ValueFormat::Fp64),
        );
        req.rhs = gsem::coordinator::RhsSpec::Ones;
        req.max_iters = 50;
        let res = redeem(gsem::coordinator::jobs::dispatch(&req));
        assert!(!res.outcome.converged, "{solver:?} cannot converge on A=0");
        assert!(res.outcome.x.iter().all(|v| v.is_finite()), "{solver:?} produced non-finite x");
    }
}

#[test]
fn indefinite_matrix_cg_does_not_panic() {
    // CG on an indefinite (saddle) matrix: may break down, must not panic
    let mut c = Coo::new(4, 4);
    c.push(0, 0, 1.0);
    c.push(1, 1, -1.0); // negative eigenvalue
    c.push(2, 2, 2.0);
    c.push(3, 3, -2.0);
    let a = Arc::new(c.to_csr());
    let mut req =
        SolveRequest::new("saddle", a, SolverKind::Cg, FormatChoice::fixed(ValueFormat::Fp64));
    req.rhs = gsem::coordinator::RhsSpec::Ones;
    req.max_iters = 100;
    let res = redeem(gsem::coordinator::jobs::dispatch(&req));
    // diagonal system: CG actually solves it; just require sanity
    assert!(res.relres_fp64.is_finite() || res.outcome.broke_down);
}

#[test]
fn nan_values_in_matrix_are_flagged_by_validate() {
    let mut a = gsem::sparse::gen::poisson::poisson2d(3, 3);
    a.vals[0] = f64::NAN;
    assert!(a.validate().is_err());
}

#[test]
fn gse_encode_handles_extreme_magnitudes() {
    // denormal-range and near-max values together; must not panic and
    // must keep every decode finite
    let xs = vec![5e-324, 1e-300, 1.0, 1e300, f64::MAX, -f64::MAX, 0.0];
    let enc = gsem::formats::SemVector::encode(&xs, 4);
    for lvl in gsem::formats::Precision::LADDER {
        for v in enc.decode(lvl) {
            assert!(v.is_finite());
        }
    }
}

#[test]
fn empty_and_single_row_matrices() {
    for a in [Csr::empty(0, 0), Csr::empty(1, 1), Csr::identity(1)] {
        a.validate().unwrap();
        if a.nrows > 0 {
            let g = gsem::spmv::GseCsr::from_csr(&a, 2);
            let x = vec![1.0; a.ncols];
            let mut y = vec![0.0; a.nrows];
            g.spmv(&x, &mut y, gsem::formats::Precision::Head);
        }
    }
}

#[test]
fn bicgstab_breakdown_in_one_column_fails_only_that_ticket() {
    // A = diag(1..5, 0): the last row/column is a null direction. A
    // right-hand side b = e5 makes BiCGSTAB break down immediately —
    // p = r̂₀ = e5 gives A·p = 0, so ⟨r̂₀, A·p⟩ = 0 and the ρ/α
    // recurrence is degenerate (the Lanczos-breakdown family) — while
    // b = A·1 lies in the range and converges. Merged into one block,
    // the breakdown must deflate only its own column.
    let mut c = Coo::new(6, 6);
    for i in 0..5 {
        c.push(i, i, 1.0 + i as f64);
    }
    let a = Arc::new(c.to_csr());
    use gsem::coordinator::{RhsSpec, ServiceConfig, SolverService};
    let svc = SolverService::manual(ServiceConfig::new().workers(2));
    let mk = |name: &str, rhs: RhsSpec| {
        let mut r = SolveRequest::new(
            name,
            Arc::clone(&a),
            SolverKind::Bicgstab,
            FormatChoice::fixed(ValueFormat::Fp64),
        );
        r.rhs = rhs;
        r.max_iters = 50;
        r
    };
    let good = mk("good", RhsSpec::AxOnes);
    let bad = mk("bad", RhsSpec::Unit(5));
    let tg = svc.submit_request(good.clone()).unwrap();
    let tb = svc.submit_request(bad.clone()).unwrap();
    assert_eq!(svc.flush(), 2);
    let rg = tg.wait().unwrap();
    // the exact-zero recurrence is flagged in-band (finite iterate, not
    // a non-finite Breakdown) — redeem() tolerates either surface
    let rb = redeem(tb.wait());
    // they really ran as one block...
    assert_eq!(svc.metrics().counter("intake.merged"), 2);
    assert_eq!(svc.metrics().counter("pool.batched_bicgstab"), 1);
    // ...the degenerate column failed alone, without poisoning the rest
    assert!(!rb.outcome.converged, "null-direction RHS cannot converge");
    assert_eq!(rb.outcome.iters, 0, "breakdown fires before the first update");
    assert!(rb.outcome.x.iter().all(|v| v.is_finite()));
    assert!(rg.outcome.converged, "in-range RHS must still converge: {}", rg.relres_fp64);
    // ...and both tickets match one-shot dispatch bitwise
    for (req, res) in [(&good, &rg), (&bad, &rb)] {
        let single = redeem(gsem::coordinator::jobs::dispatch(req));
        assert_eq!(res.outcome.converged, single.outcome.converged, "{}", req.name);
        assert_eq!(res.outcome.iters, single.outcome.iters, "{}", req.name);
        assert_eq!(res.outcome.x, single.outcome.x, "{}", req.name);
        assert_eq!(res.relres_fp64.to_bits(), single.relres_fp64.to_bits(), "{}", req.name);
    }
}

#[test]
fn cancelled_ticket_in_merged_group_fails_only_itself() {
    use gsem::coordinator::{RhsSpec, ServiceConfig, SolveSpec, SolverService};
    let a = Arc::new(gsem::sparse::gen::poisson::poisson2d(8, 8));
    let svc = SolverService::manual(ServiceConfig::new().workers(2));
    let h = svc.register(&a);
    let mk = |name: &str, seed: u64| {
        SolveSpec::new(name, h.clone(), SolverKind::Cg, FormatChoice::fixed(ValueFormat::Fp64))
            .rhs(RhsSpec::Random(seed))
    };
    let keep = svc.submit(mk("keep", 1)).unwrap();
    let gone = svc.submit(mk("gone", 2)).unwrap();
    gone.cancel();
    svc.flush();
    // the cancelled ticket resolves with its typed error...
    match gone.wait() {
        Err(ServiceError::Cancelled { name }) => assert_eq!(name, "gone"),
        other => panic!("expected Cancelled, got {:?}", other.map(|r| r.name)),
    }
    assert_eq!(svc.metrics().counter("intake.cancelled"), 1);
    // ...while its group sibling completes bitwise-identical to a
    // one-shot dispatch, untouched by the deflation
    let kept = keep.wait().expect("sibling must be unaffected");
    let mut req = SolveRequest::new(
        "keep",
        Arc::clone(&a),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::Fp64),
    );
    req.rhs = RhsSpec::Random(1);
    let single = gsem::coordinator::jobs::dispatch(&req).unwrap();
    assert_eq!(kept.outcome.iters, single.outcome.iters);
    assert_eq!(kept.outcome.x, single.outcome.x);
    assert_eq!(kept.relres_fp64.to_bits(), single.relres_fp64.to_bits());
}

#[test]
fn expired_deadline_in_merged_group_fails_only_itself() {
    use gsem::coordinator::{RhsSpec, ServiceConfig, SolveSpec, SolverService};
    use std::time::Instant;
    let a = Arc::new(gsem::sparse::gen::poisson::poisson2d(8, 8));
    let svc = SolverService::manual(ServiceConfig::new().workers(2));
    let h = svc.register(&a);
    let mk = |name: &str, seed: u64| {
        SolveSpec::new(name, h.clone(), SolverKind::Cg, FormatChoice::fixed(ValueFormat::Fp64))
            .rhs(RhsSpec::Random(seed))
    };
    let keep = svc.submit(mk("keep", 3)).unwrap();
    let late = svc.submit(mk("late", 4).deadline_at(Instant::now())).unwrap();
    svc.flush();
    match late.wait() {
        Err(ServiceError::DeadlineExceeded { name }) => assert_eq!(name, "late"),
        other => panic!("expected DeadlineExceeded, got {:?}", other.map(|r| r.name)),
    }
    assert_eq!(svc.metrics().counter("intake.deadline_expired"), 1);
    assert!(keep.wait().expect("sibling must be unaffected").outcome.converged);
}

#[test]
fn sainv_breakdown_fails_typed_without_poisoning_the_registry() {
    use gsem::coordinator::{
        Precond, RhsSpec, SainvParams, ServiceConfig, SolveSpec, SolverService,
    };
    // identity with one zeroed pivot: the SAINV biconjugation hits a
    // zero pivot at that column and the factor build fails — a typed
    // registry error per ticket, never a panic or a hang
    let mut sing = Csr::identity(8);
    sing.vals[3] = 0.0;
    let sing = Arc::new(sing);
    let params = SainvParams { drop_tol: 0.1, k: 8 };
    let svc = SolverService::manual(ServiceConfig::new().workers(2));
    let hb = svc.register(&sing);
    let mk = |name: &str, seed: u64| {
        SolveSpec::new(name, hb.clone(), SolverKind::Gmres, FormatChoice::Ir { k: 8 })
            .rhs(RhsSpec::Random(seed))
            .precond(Precond::Sainv(params))
    };
    // two tickets merge into one group; the build error fans out to both
    let t1 = svc.submit(mk("bad1", 1)).unwrap();
    let t2 = svc.submit(mk("bad2", 2)).unwrap();
    svc.flush();
    for t in [t1, t2] {
        match t.wait() {
            Err(ServiceError::Registry(e)) => {
                assert!(e.to_string().contains("sainv breakdown"), "unexpected error: {e}");
            }
            other => panic!("expected Registry error, got {:?}", other.map(|r| r.name)),
        }
    }
    assert_eq!(svc.metrics().counter("precond.builds"), 0, "failed builds must not count");
    // the same service (same registry shards) then serves a healthy
    // matrix with the same params — the failed build left no residue
    let good = Arc::new(gsem::sparse::gen::poisson::poisson2d(6, 6));
    let hg = svc.register(&good);
    let tg = svc
        .submit(
            SolveSpec::new("good", hg, SolverKind::Gmres, FormatChoice::Ir { k: 8 })
                .rhs(RhsSpec::Random(3))
                .precond(Precond::Sainv(params)),
        )
        .unwrap();
    svc.flush();
    let rg = tg.wait().expect("healthy matrix must solve after the failed build");
    assert!(rg.outcome.converged, "relres {}", rg.relres_fp64);
    assert_eq!(rg.format_label, "GSE-IR(sainv)");
    assert_eq!(svc.metrics().counter("precond.builds"), 1);
    // resubmitting the degenerate system fails typed again: the shard
    // retries the build instead of waiting on a poisoned latch
    let t3 = svc.submit(mk("bad3", 4)).unwrap();
    svc.flush();
    match t3.wait() {
        Err(ServiceError::Registry(e)) => {
            assert!(e.to_string().contains("sainv breakdown"), "unexpected error: {e}");
        }
        other => panic!("expected Registry error, got {:?}", other.map(|r| r.name)),
    }
    assert_eq!(svc.metrics().counter("precond.builds"), 1, "good-matrix build stays the only one");
}

#[test]
fn cli_rejects_bad_invocations() {
    use gsem::coordinator::cli::Cli;
    // bare double-dash
    assert!(Cli::parse(["--".to_string()]).is_err());
    // numeric parse failures surface as Err, not panic
    let c = Cli::parse(["x".to_string(), "--k".to_string(), "NaN-ish".to_string()]).unwrap();
    assert!(c.get_usize("k", 1).is_err());
}
