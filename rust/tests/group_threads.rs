//! Thread-reconfiguration acceptance: [`SpmvOp::set_threads`] makes an
//! operator's worker budget a post-build runtime property, and the
//! contract is that *any* budget — including budgets changed between
//! solves or mid-solve from a monitor callback — leaves every solver
//! result bitwise identical to threads = 1. Rows are never split
//! across workers (the `util::parallel` chunking invariant), so the
//! budget may only move wall time, never bits. This suite pins that
//! for registry-built operators across all seven storage formats,
//! CG / GMRES / BiCGSTAB blocks, the two stepped ladders, and nrhs
//! 1 / 5 / 8, which is what lets the intake flusher's core allocator
//! retune shared registry entries freely.

use gsem::coordinator::MatrixRegistry;
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::run_stepped_with;
use gsem::solvers::{
    bicgstab_solve_multi, cg_solve, cg_solve_multi, gmres_solve_multi, run_stepped_multi,
    BicgstabOpts, BlockSolver, CgOpts, CopyLadderOp, GmresOpts, MonitorCmd, SolveOutcome,
    SteppedParams, SwitchableOp,
};
use gsem::sparse::gen::fem::diffusion2d;
use gsem::spmv::SpmvOp;
use gsem::util::Prng;
use std::sync::Arc;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_bitwise(base: &SolveOutcome, other: &SolveOutcome, ctx: &str) {
    assert_eq!(base.converged, other.converged, "{ctx}: converged");
    assert_eq!(base.broke_down, other.broke_down, "{ctx}: broke_down");
    assert_eq!(base.iters, other.iters, "{ctx}: iters");
    assert_eq!(base.switches, other.switches, "{ctx}: switches");
    assert_eq!(bits(&base.x), bits(&other.x), "{ctx}: x");
    assert_eq!(bits(&base.history), bits(&other.history), "{ctx}: history");
    assert_eq!(base.relres.to_bits(), other.relres.to_bits(), "{ctx}: relres");
}

/// All seven registry formats: the four fixed widths plus the three
/// GSE-SEM levels (which share one encode — and one thread budget).
fn formats() -> [ValueFormat; 7] {
    [
        ValueFormat::Fp64,
        ValueFormat::Fp32,
        ValueFormat::Fp16,
        ValueFormat::Bf16,
        ValueFormat::GseSem(Precision::Head),
        ValueFormat::GseSem(Precision::HeadTail1),
        ValueFormat::GseSem(Precision::Full),
    ]
}

/// Column 0 easy (`b = A·1`), column 1 zero (trivially converged), the
/// rest random — exercises deflation under every budget.
fn rhs_block(op: &dyn SpmvOp, nrhs: usize, seed: u64) -> Vec<f64> {
    let n = op.nrows();
    let mut bs = vec![0.0; n * nrhs];
    let ones = vec![1.0; op.ncols()];
    op.apply(&ones, &mut bs[0..n]);
    let mut rng = Prng::new(seed);
    for j in 2..nrhs {
        for v in bs[j * n..(j + 1) * n].iter_mut() {
            *v = rng.range_f64(-1.0, 1.0);
        }
    }
    bs
}

fn solve_block(op: &dyn SpmvOp, solver: &BlockSolver, bs: &[f64], nrhs: usize) -> Vec<SolveOutcome> {
    match solver {
        BlockSolver::Cg(o) => cg_solve_multi(op, bs, nrhs, o),
        BlockSolver::Gmres(o) => gmres_solve_multi(op, bs, nrhs, o),
        BlockSolver::Bicgstab(o) => bicgstab_solve_multi(op, bs, nrhs, o),
    }
}

fn block_solvers() -> [BlockSolver; 3] {
    [
        BlockSolver::Cg(CgOpts { tol: 1e-6, max_iters: 120, inv_diag: None }),
        BlockSolver::Gmres(GmresOpts { tol: 1e-6, restart: 10, max_outer: 12 }),
        BlockSolver::Bicgstab(BicgstabOpts { tol: 1e-6, max_iters: 120 }),
    ]
}

/// Eager controller: escalates whenever a 4-residual window is not
/// improving 99% after the 6-iteration warm-up — the ladders climb.
fn eager_params() -> SteppedParams {
    SteppedParams {
        l: 6,
        t: 4,
        m: 2,
        rsd_limit: 0.5,
        ndec_limit: 2,
        reldec_limit: 0.99,
        divergence_factor: 100.0,
    }
}

#[test]
fn registry_operators_retune_bitwise_across_formats_and_solvers() {
    // 1296 rows: single applies clear the serial gate too, so budgets
    // of 2 / 3 / cores genuinely change the execution shape
    let a = Arc::new(diffusion2d(36, 36, 9.0, 4));
    let reg = MatrixRegistry::new();
    let h = reg.register(&a);
    let cores = gsem::util::parallel::default_workers();
    for format in formats() {
        let op = reg.operator(&h, format, 8, None);
        for solver in &block_solvers() {
            for nrhs in [1usize, 5, 8] {
                let bs = rhs_block(op.as_ref(), nrhs, 7);
                op.set_threads(1);
                assert_eq!(op.threads(), 1);
                let base = solve_block(op.as_ref(), solver, &bs, nrhs);
                for threads in [2usize, 3, cores] {
                    op.set_threads(threads);
                    assert_eq!(op.threads(), threads.max(1));
                    let outs = solve_block(op.as_ref(), solver, &bs, nrhs);
                    for (j, (b0, o)) in base.iter().zip(&outs).enumerate() {
                        let ctx = format!(
                            "{} {solver:?} nrhs={nrhs} threads={threads} col={j}",
                            format.label()
                        );
                        assert_bitwise(b0, o, &ctx);
                    }
                }
            }
        }
    }
}

#[test]
fn stepped_ladders_retune_bitwise() {
    let a = Arc::new(diffusion2d(10, 10, 9.0, 4));
    let reg = MatrixRegistry::new();
    let h = reg.register(&a);
    let params = eager_params();
    // shared cached pieces, exactly what the intake multi path fetches
    let g = reg.gse(&h, 8, None);
    let lo = reg.operator(&h, ValueFormat::Fp32, 0, None);
    let hi = reg.operator(&h, ValueFormat::Fp64, 0, None);
    let cores = gsem::util::parallel::default_workers();
    let solvers = [
        BlockSolver::Cg(CgOpts { tol: 1e-8, max_iters: 300, inv_diag: None }),
        BlockSolver::Gmres(GmresOpts { tol: 1e-8, restart: 10, max_outer: 30 }),
        BlockSolver::Bicgstab(BicgstabOpts { tol: 1e-8, max_iters: 300 }),
    ];
    let mut any_switched = false;
    for solver in &solvers {
        for nrhs in [1usize, 5, 8] {
            let bs = rhs_block(hi.as_ref(), nrhs, 3);
            // GSE tag ladder: fresh per run (tag resets), but the
            // budget lives on the shared encode and carries over
            let ladder = SwitchableOp::new(Arc::clone(&g));
            ladder.set_threads(1);
            let base = run_stepped_multi(&ladder, &bs, nrhs, params, solver);
            for threads in [2usize, 3, cores] {
                let ladder = SwitchableOp::new(Arc::clone(&g));
                ladder.set_threads(threads);
                assert_eq!(ladder.threads(), threads.max(1));
                let outs = run_stepped_multi(&ladder, &bs, nrhs, params, solver);
                for (j, (b0, o)) in base.iter().zip(&outs).enumerate() {
                    let ctx = format!("stepped-gse {solver:?} nrhs={nrhs} threads={threads} col={j}");
                    assert_bitwise(b0, o, &ctx);
                    any_switched |= !o.switches.is_empty();
                }
            }
            // copy ladder: budgets live on the shared fp32/fp64 rungs
            let ladder = CopyLadderOp::new(Arc::clone(&lo), Arc::clone(&hi));
            ladder.set_threads(1);
            let base = run_stepped_multi(&ladder, &bs, nrhs, params, solver);
            for threads in [2usize, 3, cores] {
                let ladder = CopyLadderOp::new(Arc::clone(&lo), Arc::clone(&hi));
                ladder.set_threads(threads);
                assert_eq!(ladder.threads(), threads.max(1));
                let outs = run_stepped_multi(&ladder, &bs, nrhs, params, solver);
                for (j, (b0, o)) in base.iter().zip(&outs).enumerate() {
                    let ctx =
                        format!("stepped-copy {solver:?} nrhs={nrhs} threads={threads} col={j}");
                    assert_bitwise(b0, o, &ctx);
                    any_switched |= !o.switches.is_empty();
                }
            }
        }
    }
    assert!(any_switched, "the eager controller must escalate at least one column");
}

#[test]
fn mid_solve_retune_is_bitwise_invisible() {
    let a = Arc::new(diffusion2d(36, 36, 9.0, 4));
    let reg = MatrixRegistry::new();
    let h = reg.register(&a);
    let n = a.nrows;
    let mut rng = Prng::new(19);
    let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();

    // fixed format: the monitor retunes the operator every iteration,
    // cycling budgets while CG is mid-recurrence
    let op = reg.operator(&h, ValueFormat::Fp64, 0, None);
    let o = CgOpts { tol: 1e-10, max_iters: 200, inv_diag: None };
    op.set_threads(1);
    let base = cg_solve(op.as_ref(), &b, &o, |_, _| MonitorCmd::Continue);
    let budgets = [2usize, 5, 1, 3];
    let retuned = cg_solve(op.as_ref(), &b, &o, |it, _| {
        op.set_threads(budgets[it % budgets.len()]);
        MonitorCmd::Continue
    });
    assert_bitwise(&base, &retuned, "mid-solve cg retune");

    // stepped ladder: retune *between rungs* — each time the
    // controller escalates (Restart), the budget changes with it
    let g = reg.gse(&h, 8, None);
    let params = eager_params();
    let so = CgOpts { tol: 1e-8, max_iters: 300, inv_diag: None };
    let ladder = SwitchableOp::new(Arc::clone(&g));
    ladder.set_threads(1);
    let (base, _, _) = run_stepped_with(&ladder, params, |op, mon| cg_solve(op, &b, &so, mon));
    let ladder = SwitchableOp::new(Arc::clone(&g));
    ladder.set_threads(1);
    let mut budget = 1usize;
    let (retuned, _, _) = run_stepped_with(&ladder, params, |op, mon| {
        cg_solve(op, &b, &so, |it, r| {
            let cmd = mon(it, r);
            if matches!(cmd, MonitorCmd::Restart) {
                budget = budget % 4 + 1;
                op.set_threads(budget);
            }
            cmd
        })
    });
    assert!(!retuned.switches.is_empty(), "the stepped run must escalate rungs");
    assert_bitwise(&base, &retuned, "stepped between-rung retune");
}
