//! Serving-hardening acceptance, all through the public service API:
//! operator spill/restore round-trips (GSE and the copy-ladder rungs)
//! must re-hit without re-encoding and stay bitwise identical, and the
//! hardening counters must surface in [`MetricsSnapshot`] / its JSON.
//!
//! [`MetricsSnapshot`]: gsem::coordinator::MetricsSnapshot

use gsem::coordinator::{
    FormatChoice, RhsSpec, ServiceConfig, SolveResult, SolveSpec, SolverKind, SolverService,
};
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::poisson::poisson2d;
use gsem::sparse::Csr;
use std::path::PathBuf;
use std::sync::Arc;

/// A per-test spill directory, wiped first: spill files are
/// content-addressed and persist, so leftovers from a previous run
/// would satisfy first-pass misses and skew the encode counts.
fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Two passes over several matrices through a spill-backed service
/// whose cache budget is far below the working set. Pass 1 encodes and
/// spills on eviction; pass 2 re-hits every digest and must be answered
/// by restores — zero re-encodes — with bitwise-identical results.
fn spill_roundtrip(dir_name: &str, format: FormatChoice) {
    let dir = fresh_dir(dir_name);
    let svc = SolverService::manual(
        ServiceConfig::new().workers(2).cache_bytes(12 * 1024).spill_dir(dir),
    );
    let mats: Vec<Arc<Csr>> =
        [10usize, 12, 14, 16].iter().map(|&n| Arc::new(poisson2d(n, n))).collect();
    let handles: Vec<_> = mats.iter().map(|a| svc.register(a)).collect();
    let solve = |j: usize| -> SolveResult {
        let spec =
            SolveSpec::new(&format!("m{j}"), handles[j].clone(), SolverKind::Cg, format.clone())
                .rhs(RhsSpec::Random(40 + j as u64));
        let t = svc.submit(spec).unwrap();
        svc.flush();
        t.wait().unwrap()
    };
    let first: Vec<SolveResult> = (0..mats.len()).map(|j| solve(j)).collect();
    let st1 = svc.registry().stats();
    assert!(st1.evictions > 0, "tiny budget must evict: {st1:?}");
    assert!(st1.spills > 0, "evictions must spill, not drop: {st1:?}");
    let encodes_after_pass1 = svc.metrics().timing("cache.encode").0;

    let second: Vec<SolveResult> = (0..mats.len()).map(|j| solve(j)).collect();
    let st2 = svc.registry().stats();
    assert!(st2.restores > 0, "second pass must restore from spill: {st2:?}");
    assert!(st2.restore_bytes > 0, "restores must account their file bytes: {st2:?}");
    assert_eq!(
        svc.metrics().timing("cache.encode").0,
        encodes_after_pass1,
        "a restored operator must not be re-encoded"
    );
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.outcome.iters, b.outcome.iters, "{}", a.name);
        assert!(bits_eq(&a.outcome.x, &b.outcome.x), "{}: restore changed the solve", a.name);
        assert_eq!(a.relres_fp64.to_bits(), b.relres_fp64.to_bits(), "{}", a.name);
    }
}

#[test]
fn spill_restore_roundtrip_gse() {
    spill_roundtrip(
        "gsem_spill_gse_test",
        FormatChoice::Fixed { format: ValueFormat::GseSem(Precision::Full), k: 8 },
    );
}

#[test]
fn spill_restore_roundtrip_copy_ladder() {
    spill_roundtrip(
        "gsem_spill_copy_test",
        FormatChoice::SteppedCopy { params: SteppedParams::cg_paper().scaled(0.01) },
    );
}

#[test]
fn metrics_snapshot_and_json_expose_hardening_counters() {
    let svc = SolverService::manual(ServiceConfig::new().workers(2).queue_depth(1));
    let a = Arc::new(poisson2d(8, 8));
    let h = svc.register(&a);
    let mk = |name: &str, seed: u64| {
        SolveSpec::new(name, h.clone(), SolverKind::Cg, FormatChoice::fixed(ValueFormat::Fp64))
            .rhs(RhsSpec::Random(seed))
    };
    let t = svc.submit(mk("ok", 1)).unwrap();
    assert!(svc.submit(mk("excess", 2)).is_err(), "depth-1 queue must shed the second submit");
    svc.flush();
    t.wait().unwrap();
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.counter("intake.submitted"), 1);
    assert_eq!(snap.counter("intake.shed"), 1);
    assert_eq!(snap.counter("intake.flushes"), 1);
    let json = snap.to_json();
    for key in ["\"counters\"", "\"gauges\"", "\"timings\"", "intake.submitted", "intake.shed"] {
        assert!(json.contains(key), "snapshot JSON missing {key}: {json}");
    }
}
