//! Block-solver acceptance: every multi-RHS entry point added for the
//! asymmetric suites — [`gmres_solve_multi`], [`bicgstab_solve_multi`]
//! and the stepped multi-RHS mode ([`run_stepped_multi`], one shared
//! precision ladder serving per-column controllers) — must be
//! **bitwise identical per column** to dispatching each right-hand
//! side through its single-RHS solver, across storage formats, block
//! widths and operator worker counts, including columns that deflate
//! out of the block early and columns that stagnate at the iteration
//! cap.

use gsem::solvers::bicgstab::{bicgstab_solve, bicgstab_solve_multi, BicgstabOpts};
use gsem::solvers::gmres::{gmres_solve, gmres_solve_multi, GmresOpts};
use gsem::solvers::precond::Jacobi;
use gsem::solvers::stepped::{run_stepped_multi, run_stepped_with, BlockSolver, SteppedParams};
use gsem::solvers::{
    cg_solve, ir_gmres_solve, ir_solve_multi, CgOpts, CopyLadderOp, IrGmresOpts, MonitorCmd,
    PrecisionSwitchable, PrecondOp, SainvFactors, SainvParams, SolveOutcome, SwitchableOp,
};
use gsem::sparse::gen::convdiff::convdiff2d;
use gsem::sparse::gen::fem::diffusion2d;
use gsem::spmv::{build_operators_par, GseCsr, LowpCsr, SpmvOp};
use gsem::util::Prng;
use std::sync::Arc;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Full bitwise comparison of one block column against its single
/// dispatch: flags, counts, iterates, histories, switch logs and the
/// closing residual must all agree to the bit.
fn assert_bitwise(single: &SolveOutcome, multi: &SolveOutcome, ctx: &str) {
    assert_eq!(single.converged, multi.converged, "{ctx}: converged");
    assert_eq!(single.broke_down, multi.broke_down, "{ctx}: broke_down");
    assert_eq!(single.iters, multi.iters, "{ctx}: iters");
    assert_eq!(single.switches, multi.switches, "{ctx}: switches");
    assert_eq!(bits(&single.x), bits(&multi.x), "{ctx}: x");
    assert_eq!(bits(&single.history), bits(&multi.history), "{ctx}: history");
    assert_eq!(single.relres.to_bits(), multi.relres.to_bits(), "{ctx}: relres");
}

/// A block of RHS columns exercising the deflation paths: an easy
/// `b = A·1` column (converges first), a zero column (trivially
/// converged, never enters the block), and random tails.
fn rhs_block(op: &dyn SpmvOp, nrhs: usize, seed: u64) -> Vec<f64> {
    let n = op.nrows();
    let mut bs = vec![0.0; n * nrhs];
    let ones = vec![1.0; op.ncols()];
    op.apply(&ones, &mut bs[0..n]);
    let mut rng = Prng::new(seed);
    // column 1 (when present) stays zero; the rest are random
    for j in 2..nrhs {
        for v in bs[j * n..(j + 1) * n].iter_mut() {
            *v = rng.range_f64(-1.0, 1.0);
        }
    }
    bs
}

#[test]
fn gmres_block_matches_single_dispatch_bitwise() {
    let a = convdiff2d(8, 8, 4.0, 2.0);
    let opts = GmresOpts { tol: 1e-6, restart: 10, max_outer: 60 };
    for threads in [1usize, 3] {
        for op in build_operators_par(&a, 8, threads) {
            for nrhs in [1usize, 3, 8] {
                let bs = rhs_block(op.as_ref(), nrhs, 7);
                let outs = gmres_solve_multi(op.as_ref(), &bs, nrhs, &opts);
                assert_eq!(outs.len(), nrhs);
                for (j, multi) in outs.iter().enumerate() {
                    let b = &bs[j * op.nrows()..(j + 1) * op.nrows()];
                    let single = gmres_solve(op.as_ref(), b, &opts, |_, _| MonitorCmd::Continue);
                    let ctx = format!(
                        "gmres {} threads={threads} nrhs={nrhs} col={j}",
                        op.format().label()
                    );
                    assert_bitwise(&single, multi, &ctx);
                }
            }
        }
    }
}

#[test]
fn bicgstab_block_matches_single_dispatch_bitwise() {
    let a = convdiff2d(8, 8, 6.0, 3.0);
    let opts = BicgstabOpts { tol: 1e-6, max_iters: 400 };
    for threads in [1usize, 3] {
        for op in build_operators_par(&a, 8, threads) {
            for nrhs in [1usize, 3, 8] {
                let bs = rhs_block(op.as_ref(), nrhs, 11);
                let outs = bicgstab_solve_multi(op.as_ref(), &bs, nrhs, &opts);
                assert_eq!(outs.len(), nrhs);
                for (j, multi) in outs.iter().enumerate() {
                    let b = &bs[j * op.nrows()..(j + 1) * op.nrows()];
                    let single =
                        bicgstab_solve(op.as_ref(), b, &opts, |_, _| MonitorCmd::Continue);
                    let ctx = format!(
                        "bicgstab {} threads={threads} nrhs={nrhs} col={j}",
                        op.format().label()
                    );
                    assert_bitwise(&single, multi, &ctx);
                }
            }
        }
    }
}

/// Aggressive controller tuning: after the `l` warm-up, any window that
/// is not improving by 99% per `t` residuals escalates — guarantees the
/// ladder actually climbs mid-block, at different iterations for
/// different columns (the rung peel-off path).
fn eager_params() -> SteppedParams {
    SteppedParams {
        l: 6,
        t: 4,
        m: 2,
        rsd_limit: 0.5,
        ndec_limit: 2,
        reldec_limit: 0.99,
        divergence_factor: 100.0,
    }
}

fn stepped_single(
    op: &impl PrecisionSwitchable,
    b: &[f64],
    params: SteppedParams,
    solver: &BlockSolver,
) -> SolveOutcome {
    let (out, _, _) = match solver {
        BlockSolver::Cg(o) => run_stepped_with(op, params, |op, mon| cg_solve(op, b, o, mon)),
        BlockSolver::Gmres(o) => {
            run_stepped_with(op, params, |op, mon| gmres_solve(op, b, o, mon))
        }
        BlockSolver::Bicgstab(o) => {
            run_stepped_with(op, params, |op, mon| bicgstab_solve(op, b, o, mon))
        }
    };
    out
}

#[test]
fn stepped_block_matches_single_dispatch_bitwise() {
    // wide-exponent values: the low rungs differ numerically from the
    // high ones, so escalation changes the arithmetic it re-anchors
    let a = diffusion2d(10, 10, 9.0, 4);
    let params = eager_params();
    let g = Arc::new(GseCsr::from_csr(&a, 8));
    let lo: Arc<dyn SpmvOp> = Arc::new(LowpCsr::<f32>::from_csr(&a));
    let hi: Arc<dyn SpmvOp> = Arc::new(gsem::spmv::fp64::Fp64Csr::new(a.clone()));
    let solvers = [
        BlockSolver::Cg(CgOpts { tol: 1e-8, max_iters: 300, inv_diag: None }),
        BlockSolver::Gmres(GmresOpts { tol: 1e-8, restart: 10, max_outer: 30 }),
        BlockSolver::Bicgstab(BicgstabOpts { tol: 1e-8, max_iters: 300 }),
    ];
    let mut any_switched = false;
    for solver in &solvers {
        for nrhs in [1usize, 3, 8] {
            let bs = rhs_block(hi.as_ref(), nrhs, 3);
            // GSE tag ladder: one shared SwitchableOp for the block,
            // a fresh one per single dispatch — same encode either way
            let ladder = SwitchableOp::new(Arc::clone(&g));
            let outs = run_stepped_multi(&ladder, &bs, nrhs, params, solver);
            for (j, multi) in outs.iter().enumerate() {
                let b = &bs[j * a.nrows..(j + 1) * a.nrows];
                let sop = SwitchableOp::new(Arc::clone(&g));
                let single = stepped_single(&sop, b, params, solver);
                assert_bitwise(&single, multi, &format!("stepped-gse nrhs={nrhs} col={j}"));
                any_switched |= !multi.switches.is_empty();
            }
            // copy ladder: shared fp32/fp64 rungs behind Arcs
            let ladder = CopyLadderOp::new(Arc::clone(&lo), Arc::clone(&hi));
            let outs = run_stepped_multi(&ladder, &bs, nrhs, params, solver);
            for (j, multi) in outs.iter().enumerate() {
                let b = &bs[j * a.nrows..(j + 1) * a.nrows];
                let sop = CopyLadderOp::new(Arc::clone(&lo), Arc::clone(&hi));
                let single = stepped_single(&sop, b, params, solver);
                assert_bitwise(&single, multi, &format!("stepped-copy nrhs={nrhs} col={j}"));
                any_switched |= !multi.switches.is_empty();
            }
        }
    }
    assert!(any_switched, "the eager controller must escalate at least one column");
}

#[test]
fn ir_gmres_block_matches_single_dispatch_bitwise() {
    // preconditioned GMRES-IR: the block driver groups active columns
    // by rung per outer round, so parity covers the regrouping path as
    // well as the fused inner solves — for every preconditioner and
    // operator worker count
    let a = convdiff2d(8, 8, 4.0, 2.0);
    let opts = IrGmresOpts { tol: 1e-8, ..IrGmresOpts::default() };
    let g = Arc::new(GseCsr::from_csr(&a, 8));
    let sainv = SainvFactors::build(&a, SainvParams { drop_tol: 0.05, k: 8 })
        .expect("convdiff is sainv-friendly");
    let preconds = [
        PrecondOp::None,
        PrecondOp::Jacobi(Arc::new(Jacobi::from_csr(a.clone()))),
        PrecondOp::Sainv(Arc::new(sainv)),
    ];
    let op = gsem::spmv::fp64::Fp64Csr::new(a.clone());
    for threads in [1usize, 3] {
        g.threads.set(threads);
        for m in &preconds {
            m.set_threads(threads);
            for nrhs in [1usize, 3, 8] {
                let bs = rhs_block(&op, nrhs, 17);
                let outs = ir_solve_multi(&g, m, &bs, nrhs, &opts);
                assert_eq!(outs.len(), nrhs);
                for (j, multi) in outs.iter().enumerate() {
                    let b = &bs[j * a.nrows..(j + 1) * a.nrows];
                    let single = ir_gmres_solve(&g, m, b, &opts);
                    let ctx =
                        format!("ir{} threads={threads} nrhs={nrhs} col={j}", m.label_suffix());
                    assert_bitwise(&single, multi, &ctx);
                }
                assert!(
                    outs.iter().all(|o| o.converged),
                    "ir{} nrhs={nrhs} must converge",
                    m.label_suffix()
                );
            }
        }
    }
}

#[test]
fn block_deflation_and_stagnation_columns() {
    let a = convdiff2d(10, 10, 8.0, 4.0);
    let op = gsem::spmv::fp64::Fp64Csr::new(a.clone());
    let n = a.nrows;

    // deflation: the zero column converges at iteration 0 and the easy
    // b = A·1 column well before the random ones; the survivors keep
    // batching and still match single dispatch exactly
    let opts = GmresOpts { tol: 1e-6, restart: 10, max_outer: 60 };
    let bs = rhs_block(&op, 4, 23);
    let outs = gmres_solve_multi(&op, &bs, 4, &opts);
    assert!(outs[1].converged && outs[1].iters == 0, "zero column is trivial");
    assert!(outs[0].converged, "easy column converges");
    for (j, multi) in outs.iter().enumerate() {
        let b = &bs[j * n..(j + 1) * n];
        let single = gmres_solve(&op, b, &opts, |_, _| MonitorCmd::Continue);
        assert_bitwise(&single, multi, &format!("deflation col={j}"));
    }

    // stagnation: an unreachable tolerance pins every column at the
    // iteration cap — parity must hold on the capped path too
    let tight = BicgstabOpts { tol: 1e-300, max_iters: 7 };
    let outs = bicgstab_solve_multi(&op, &bs, 4, &tight);
    for (j, multi) in outs.iter().enumerate() {
        let b = &bs[j * n..(j + 1) * n];
        let single = bicgstab_solve(&op, b, &tight, |_, _| MonitorCmd::Continue);
        assert_bitwise(&single, multi, &format!("stagnation col={j}"));
        if j != 1 {
            assert!(!multi.converged, "col {j} must stagnate");
        }
    }
    assert!(outs.iter().any(|o| o.iters == 7), "some column must run to the iteration cap");
}
