//! Cross-layer parity: the AOT-compiled Pallas kernels (python-lowered,
//! rust-executed via PJRT) must agree with the pure-rust decode/SpMV on
//! data encoded by the *rust* encoder. This is the proof that all three
//! implementations (numpy oracle, Pallas kernel, rust) meet at the same
//! format spec.
//!
//! Skips with a notice if `make artifacts` has not run.

use gsem::formats::{ieee, Precision};
use gsem::runtime::executor::{Arg, Engine};

use gsem::spmv::ell::to_ell;
use gsem::spmv::GseCsr;
use gsem::util::Prng;

fn engine() -> Option<Engine> {
    match Engine::load_default() {
        Ok(Some(e)) if !e.backend_available() => {
            eprintln!("SKIP: no PJRT backend linked in this build");
            None
        }
        Ok(e) => {
            if e.is_none() {
                eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            }
            e
        }
        Err(err) => panic!("engine load error: {err:#}"),
    }
}

#[test]
fn missing_artifacts_skip_cleanly() {
    // the graceful-degrade contract this suite relies on: an absent
    // artifacts dir must be Ok(None), never an error or a panic
    let missing = Engine::load(std::path::Path::new("/nonexistent/gsem_artifacts"));
    assert!(missing.unwrap().is_none());
    // and the default-dir helper used by every test below must not panic
    let _ = engine();
}

/// Pad the 64-entry scale table the kernels consume.
fn scale_table(g: &GseCsr) -> Vec<f64> {
    let mut s = vec![0.0f64; 64];
    for (i, &e) in g.table.entries.iter().enumerate() {
        s[i] = ieee::ldexp(1.0, e as i32 - 1075);
    }
    s
}

fn widen16(v: &[u16]) -> Vec<u32> {
    v.iter().map(|&x| x as u32).collect()
}

/// Build the exact (256, 16) ELL planes the exported artifacts expect.
/// SPD (variable-coefficient diffusion) so the CG artifacts are on-label.
fn demo_system() -> (GseCsr, gsem::sparse::Csr, gsem::spmv::ell::EllBlocks) {
    let a = gsem::sparse::gen::fem::diffusion2d(16, 16, 8.0, 21); // 256 rows, <=5 nnz/row
    assert_eq!(a.nrows, 256);
    let g = GseCsr::from_csr(&a, 8);
    let e = to_ell(&g, &a, 16);
    assert_eq!(e.slabs.len(), 1, "width 16 must hold every row");
    (g, a, e)
}

#[test]
fn decode_kernel_matches_rust_decoder() {
    let Some(mut engine) = engine() else { return };
    let mut rng = Prng::new(42);
    let xs: Vec<f64> = (0..4096)
        .map(|_| rng.lognormal(0.0, 3.0) * if rng.chance(0.5) { -1.0 } else { 1.0 })
        .collect();
    // encode with the rust encoder in External layout via a 1-row matrix
    let a = gsem::sparse::Csr {
        nrows: 1,
        ncols: 4096,
        rowptr: vec![0, 4096],
        colidx: (0..4096u32).collect(),
        vals: xs.clone(),
    };
    let g = GseCsr::from_csr(&a, 8);
    let scales = scale_table(&g);
    let idx: Vec<u32> = (0..g.nnz()).map(|j| g.col_and_idx(j).1 as u32).collect();
    let heads = widen16(&g.heads);
    let tail1 = widen16(&g.tail1);
    let tail2: Vec<u32> = g.tail2.clone();

    for (name, level) in [
        ("decode_head", Precision::Head),
        ("decode_t1", Precision::HeadTail1),
        ("decode_full", Precision::Full),
    ] {
        let k = engine.kernel(name).unwrap();
        let out = k
            .run_f64(&[
                Arg::U32(&heads),
                Arg::U32(&tail1),
                Arg::U32(&tail2),
                Arg::U32(&idx),
                Arg::F64(&scales),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 4096);
        for j in 0..4096 {
            let want = g.decode(j, level);
            let got = out[0][j];
            assert!(
                (want - got).abs() <= 1e-300 + 1e-12 * want.abs(),
                "{name} j={j}: rust={want} pallas={got}"
            );
        }
    }
}

#[test]
fn spmv_kernel_matches_rust_spmv() {
    let Some(mut engine) = engine() else { return };
    let (g, _a, e) = demo_system();
    let slab = &e.slabs[0];
    let scales = scale_table(&g);
    let mut rng = Prng::new(7);
    let x: Vec<f64> = (0..256).map(|_| rng.range_f64(-2.0, 2.0)).collect();

    let heads = widen16(&slab.heads);
    let tail1 = widen16(&slab.tail1);
    let tail2 = slab.tail2.clone();
    let idx = slab.exp_idx.clone();
    let cols = slab.cols.clone();

    for (name, level) in [
        ("spmv_ell_head", Precision::Head),
        ("spmv_ell_t1", Precision::HeadTail1),
        ("spmv_ell_full", Precision::Full),
    ] {
        let k = engine.kernel(name).unwrap();
        let out = k
            .run_f64(&[
                Arg::U32(&heads),
                Arg::U32(&tail1),
                Arg::U32(&tail2),
                Arg::U32(&idx),
                Arg::U32(&cols),
                Arg::F64(&scales),
                Arg::F64(&x),
            ])
            .unwrap();
        let mut want = vec![0.0; 256];
        g.spmv(&x, &mut want, level);
        let scale = want.iter().fold(1.0f64, |m, &v| m.max(v.abs()));
        for r in 0..256 {
            assert!(
                (want[r] - out[0][r]).abs() <= 1e-11 * scale,
                "{name} row {r}: rust={} pallas={}",
                want[r],
                out[0][r]
            );
        }
    }
}

#[test]
fn cg_step_kernel_reduces_residual_like_rust() {
    let Some(mut engine) = engine() else { return };
    let (g, a, e) = demo_system();
    let slab = &e.slabs[0];
    let scales = scale_table(&g);
    // b = A*1
    let ones = vec![1.0; 256];
    let mut b = vec![0.0; 256];
    gsem::spmv::fp64::spmv(&a, &ones, &mut b);
    let x = vec![0.0; 256];
    let r = b.clone();
    let p = b.clone();
    let rr = vec![b.iter().map(|v| v * v).sum::<f64>()];

    let heads = widen16(&slab.heads);
    let tail1 = widen16(&slab.tail1);
    let tail2 = slab.tail2.clone();
    let idx = slab.exp_idx.clone();
    let cols = slab.cols.clone();

    let k = engine.kernel("cg_step_full").unwrap();
    let out = k
        .run_f64(&[
            Arg::U32(&heads),
            Arg::U32(&tail1),
            Arg::U32(&tail2),
            Arg::U32(&idx),
            Arg::U32(&cols),
            Arg::F64(&scales),
            Arg::F64(&x),
            Arg::F64(&r),
            Arg::F64(&p),
            Arg::F64(&rr),
        ])
        .unwrap();
    assert_eq!(out.len(), 4);
    let rr_new = out[3][0];
    assert!(rr_new.is_finite());
    assert!(rr_new < rr[0], "one CG step must reduce ||r||^2: {rr_new} vs {}", rr[0]);
}

#[test]
fn cg_run_artifact_solves_the_demo_system() {
    let Some(mut engine) = engine() else { return };
    let (g, a, e) = demo_system();
    let slab = &e.slabs[0];
    let scales = scale_table(&g);
    let ones = vec![1.0; 256];
    let mut b = vec![0.0; 256];
    gsem::spmv::fp64::spmv(&a, &ones, &mut b);

    let heads = widen16(&slab.heads);
    let tail1 = widen16(&slab.tail1);
    let tail2 = slab.tail2.clone();
    let idx = slab.exp_idx.clone();
    let cols = slab.cols.clone();

    let k = engine.kernel("cg_run_head").unwrap();
    let out = k
        .run_f64(&[
            Arg::U32(&heads),
            Arg::U32(&tail1),
            Arg::U32(&tail2),
            Arg::U32(&idx),
            Arg::U32(&cols),
            Arg::F64(&scales),
            Arg::F64(&b),
        ])
        .unwrap();
    let x = &out[0];
    // CG on the convdiff demo system is not guaranteed (asymmetric), but
    // with mild wind the symmetric part dominates; require a meaningful
    // residual drop rather than full convergence.
    let head_op = g.clone().at_level(Precision::Head);
    let rel = gsem::solvers::true_relres(&head_op, x, &b);
    assert!(rel < 0.5, "50-step CG should reduce the residual, rel={rel}");
}
