//! Integration tests across formats × solvers × the stepped controller —
//! the qualitative claims of Tables III/IV at test scale:
//!
//! * FP16 storage overflows/fails on wide-range matrices where BF16 and
//!   GSE-SEM survive;
//! * GSE-SEM(full) reaches FP64-class residuals; head-only may stall;
//! * the stepped solver escalates precision when (and only when) the
//!   low-precision phase stalls, and then converges.

use gsem::coordinator::{FormatChoice, RhsSpec, SolveRequest, SolverKind};
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::convdiff::convdiff2d;
use gsem::sparse::gen::fem::diffusion2d;
use gsem::sparse::gen::randmat::{exp_controlled_spd, ExpLaw};
use gsem::spmv::GseCsr;
use std::sync::Arc;

fn run(
    a: Arc<gsem::sparse::Csr>,
    solver: SolverKind,
    fmt: FormatChoice,
) -> gsem::coordinator::jobs::SolveResult {
    let mut req = SolveRequest::new("t", a, solver, fmt);
    req.rhs = RhsSpec::AxOnes;
    gsem::coordinator::jobs::dispatch(&req)
}

#[test]
fn fp16_breaks_down_on_wide_range_cg_system() {
    // magnitudes straddle FP16's range -> conversion overflow, the "/"
    // rows of Table IV
    let a = Arc::new(exp_controlled_spd(
        200,
        5,
        ExpLaw::Bimodal { e0: 10, gap: 12, p: 0.5 }, // values up to ~2^23
        99,
    ));
    let r16 = run(Arc::clone(&a), SolverKind::Cg, FormatChoice::fixed(ValueFormat::Fp16));
    let rb = run(Arc::clone(&a), SolverKind::Cg, FormatChoice::fixed(ValueFormat::Bf16));
    let rg = run(
        Arc::clone(&a),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::GseSem(Precision::Full)),
    );
    // FP16 matrix is corrupted: either breakdown or wildly wrong result
    assert!(
        r16.outcome.broke_down || r16.relres_fp64 > 1e-3,
        "fp16 should fail here, relres={}",
        r16.relres_fp64
    );
    assert!(!rb.outcome.broke_down);
    assert!(rg.outcome.converged, "GSE-SEM full must converge, relres={}", rg.relres_fp64);
    assert!(rg.relres_fp64 < 1e-5);
}

#[test]
fn gse_full_matches_fp64_iterations_on_cg() {
    let a = Arc::new(diffusion2d(20, 20, 6.0, 5));
    let r64 = run(Arc::clone(&a), SolverKind::Cg, FormatChoice::fixed(ValueFormat::Fp64));
    let rg = run(
        Arc::clone(&a),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::GseSem(Precision::Full)),
    );
    assert!(r64.outcome.converged && rg.outcome.converged);
    let ratio = rg.outcome.iters as f64 / r64.outcome.iters as f64;
    assert!((0.5..2.0).contains(&ratio), "iters {} vs {}", rg.outcome.iters, r64.outcome.iters);
}

#[test]
fn head_only_stalls_where_full_converges() {
    // hard contrast -> head's ~15-bit mantissa floor blocks 1e-6
    let a = Arc::new(diffusion2d(24, 24, 16.0, 9));
    let rh = run(
        Arc::clone(&a),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::GseSem(Precision::Head)),
    );
    let rf = run(
        Arc::clone(&a),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::GseSem(Precision::Full)),
    );
    assert!(rf.outcome.converged);
    // head either fails to converge or needs (many) more iterations
    assert!(
        !rh.outcome.converged || rh.outcome.iters > rf.outcome.iters,
        "head iters {} vs full {}",
        rh.outcome.iters,
        rf.outcome.iters
    );
}

#[test]
fn stepped_cg_escalates_and_converges_on_hard_system() {
    let a = Arc::new(diffusion2d(24, 24, 16.0, 9));
    let params = SteppedParams {
        l: 30,
        t: 20,
        m: 10,
        rsd_limit: 0.5,
        ndec_limit: 10,
        reldec_limit: 0.45,
        divergence_factor: 100.0,
    };
    let res = run(
        Arc::clone(&a),
        SolverKind::Cg,
        FormatChoice::Stepped { k: 8, params },
    );
    assert!(res.outcome.converged, "stepped CG must converge, relres={}", res.relres_fp64);
    // the controller must actually have escalated on this hard system
    // if the head phase alone could not reach 1e-6
    let head_only = run(
        Arc::clone(&a),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::GseSem(Precision::Head)),
    );
    if !head_only.outcome.converged {
        assert!(
            !res.outcome.switches.is_empty(),
            "expected precision switches, got none (head alone failed though)"
        );
    }
}

#[test]
fn stepped_gmres_converges_on_asymmetric() {
    let a = Arc::new(convdiff2d(20, 20, 24.0, 8.0));
    let params = SteppedParams::gmres_paper().scaled(0.01);
    let res = run(Arc::clone(&a), SolverKind::Gmres, FormatChoice::Stepped { k: 8, params });
    assert!(res.outcome.converged, "relres={}", res.relres_fp64);
    assert!(res.relres_fp64 < 1e-4);
}

#[test]
fn stepped_copy_ladder_cg_converges_and_reaches_fp64_accuracy() {
    // the related-work fp32→fp64 copy ladder under the same controller:
    // must converge on the hard system and report its own label
    let a = Arc::new(diffusion2d(24, 24, 16.0, 9));
    let params = SteppedParams {
        l: 30,
        t: 20,
        m: 10,
        rsd_limit: 0.5,
        ndec_limit: 10,
        reldec_limit: 0.45,
        divergence_factor: 100.0,
    };
    let res = run(Arc::clone(&a), SolverKind::Cg, FormatChoice::SteppedCopy { params });
    assert_eq!(res.format_label, "FP32->FP64");
    assert!(res.outcome.converged, "copy-ladder CG must converge, relres={}", res.relres_fp64);
    // fp32-rung convergence bounds the FP64-matrix residual only by the
    // storage perturbation; escalation to the fp64 rung tightens it
    assert!(res.relres_fp64 < 1e-2, "relres={}", res.relres_fp64);
}

#[test]
fn stepped_copy_ladder_gmres_converges_on_asymmetric() {
    let a = Arc::new(convdiff2d(20, 20, 24.0, 8.0));
    let params = SteppedParams::gmres_paper().scaled(0.01);
    let res = run(Arc::clone(&a), SolverKind::Gmres, FormatChoice::SteppedCopy { params });
    assert!(res.outcome.converged, "relres={}", res.relres_fp64);
    assert!(res.relres_fp64 < 1e-3, "relres={}", res.relres_fp64);
}

#[test]
fn both_ladders_run_green_on_the_same_system() {
    // acceptance: the stepped controller drives the GSE tag ladder and
    // the copy ladder interchangeably on one system
    let a = Arc::new(diffusion2d(20, 20, 10.0, 5));
    let params = SteppedParams::cg_paper().scaled(0.02);
    let gse = run(Arc::clone(&a), SolverKind::Cg, FormatChoice::Stepped { k: 8, params });
    let copy = run(Arc::clone(&a), SolverKind::Cg, FormatChoice::SteppedCopy { params });
    assert!(gse.outcome.converged, "GSE ladder relres={}", gse.relres_fp64);
    assert!(copy.outcome.converged, "copy ladder relres={}", copy.relres_fp64);
    assert_eq!(gse.format_label, "GSE-SEM");
    assert_eq!(copy.format_label, "FP32->FP64");
}

#[test]
fn stepped_does_not_escalate_on_easy_system() {
    // easy Poisson: head precision suffices at 1e-6 with x=A·1 rhs
    let a = Arc::new(gsem::sparse::gen::poisson::poisson2d(16, 16));
    let params = SteppedParams::cg_paper().scaled(0.02);
    let res = run(Arc::clone(&a), SolverKind::Cg, FormatChoice::Stepped { k: 8, params });
    assert!(res.outcome.converged);
    assert!(
        res.outcome.switches.is_empty(),
        "no escalation expected on exact-representable Poisson: {:?}",
        res.outcome.switches
    );
}

#[test]
fn switchable_op_escalation_changes_numerics_in_flight() {
    // direct check of the Alg. 3 mechanism: same storage, levels differ
    let a = diffusion2d(12, 12, 12.0, 3);
    let g = GseCsr::from_csr(&a, 8);
    let op = gsem::solvers::stepped::SwitchableOp::new(g);
    let x = vec![1.0; a.ncols];
    let mut y_head = vec![0.0; a.nrows];
    let mut y_full = vec![0.0; a.nrows];
    use gsem::spmv::SpmvOp;
    op.apply(&x, &mut y_head);
    op.set_level(Precision::Full);
    op.apply(&x, &mut y_full);
    let mut y_ref = vec![0.0; a.nrows];
    gsem::spmv::fp64::spmv(&a, &x, &mut y_ref);
    let e_head = gsem::spmv::max_abs_diff(&y_head, &y_ref);
    let e_full = gsem::spmv::max_abs_diff(&y_full, &y_ref);
    assert!(e_full < e_head, "full {e_full} must beat head {e_head}");
}
