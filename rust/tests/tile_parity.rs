//! Tile-remainder parity acceptance for the register-tiled multi-RHS
//! kernels: every fused `apply_multi` (all 7 CSR operator formats plus
//! the fused ELL kernel) must stay **bitwise identical** to single-RHS
//! dispatch at batch widths that land on every lane-tile boundary —
//! below one tile, exactly one tile, one past it, and mid-remainder —
//! and at every worker count. The matrices are sized so a single apply
//! stays under the serial threshold while the wide blocks cross the
//! rows×nrhs parallel gate, exercising both sides of the split
//! decision.

use gsem::formats::Precision;
use gsem::sparse::gen::randmat::{exp_controlled, ExpLaw};
use gsem::spmv::ell::to_ell;
use gsem::spmv::{apply_multi_looped, build_operators_par, EllSpmv, GseCsr, SpmvOp, LANES};
use gsem::util::Prng;
use std::sync::Arc;

/// nrhs values straddling every tile boundary of the LANES-wide walk.
fn tile_widths() -> [usize; 5] {
    [1, LANES - 1, LANES, LANES + 1, 2 * LANES + 3]
}

fn rand_x(n: usize, seed: u64) -> Vec<f64> {
    let mut r = Prng::new(seed);
    (0..n).map(|_| r.range_f64(-2.0, 2.0)).collect()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn fused_tiles_match_looped_across_formats_widths_and_workers() {
    // 700 rows: a single apply stays below PAR_MIN_ROWS (serial), but
    // 700 × nrhs ≥ 2 crosses the rows×nrhs gate, so widths 1 and 3+
    // take different split paths — both must be bitwise identical.
    let a = exp_controlled(700, 700, 5, ExpLaw::Gaussian { e0: 0, sigma: 3.0 }, 33);
    for &workers in &[1usize, 3] {
        let ops = build_operators_par(&a, 8, workers);
        assert_eq!(ops.len(), 7);
        for op in &ops {
            for &nrhs in &tile_widths() {
                let x = rand_x(a.ncols * nrhs, 7 + nrhs as u64);
                let mut y_fused = vec![0.0; a.nrows * nrhs];
                op.apply_multi(&x, &mut y_fused, nrhs);
                let mut y_loop = vec![0.0; a.nrows * nrhs];
                apply_multi_looped(op.as_ref(), &x, &mut y_loop, nrhs);
                assert_eq!(
                    bits(&y_fused),
                    bits(&y_loop),
                    "{} nrhs={nrhs} workers={workers}",
                    op.format().label()
                );
            }
        }
    }
}

#[test]
fn ell_fused_multi_matches_per_column_single() {
    let a = exp_controlled(600, 600, 6, ExpLaw::Zipf { e0: -4, count: 8, s: 1.2 }, 9);
    let g = GseCsr::from_csr(&a, 8);
    let e = to_ell(&g, &a, 3);
    for &workers in &[1usize, 3] {
        for &nrhs in &tile_widths() {
            let x = rand_x(a.ncols * nrhs, 40 + nrhs as u64);
            for lvl in Precision::LADDER {
                let y = e.spmv_multi_decoded_par(&g, &x, nrhs, lvl, workers);
                for j in 0..nrhs {
                    let yj = e.spmv_decoded(&g, &x[j * a.ncols..(j + 1) * a.ncols], lvl);
                    assert_eq!(
                        bits(&y[j * a.nrows..(j + 1) * a.nrows]),
                        bits(&yj),
                        "col {j} nrhs={nrhs} workers={workers} {lvl:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn ell_operator_matches_looped_through_the_trait() {
    let a = exp_controlled(500, 500, 5, ExpLaw::Gaussian { e0: -1, sigma: 2.5 }, 5);
    let g = Arc::new(GseCsr::from_csr(&a, 8));
    for &workers in &[1usize, 3] {
        for lvl in Precision::LADDER {
            let op = EllSpmv::new(Arc::clone(&g), &a, 4, lvl).with_threads(workers);
            for &nrhs in &tile_widths() {
                let x = rand_x(a.ncols * nrhs, 60 + nrhs as u64);
                let mut y_fused = vec![0.0; a.nrows * nrhs];
                op.apply_multi(&x, &mut y_fused, nrhs);
                let mut y_loop = vec![0.0; a.nrows * nrhs];
                apply_multi_looped(&op, &x, &mut y_loop, nrhs);
                assert_eq!(
                    bits(&y_fused),
                    bits(&y_loop),
                    "nrhs={nrhs} workers={workers} {lvl:?}"
                );
            }
        }
    }
}
