//! Serving-path acceptance: per-request results from [`SolverService`]
//! (windowed intake, digest-keyed registry) must be bitwise-identical
//! to `SolverPool::run_batch` dispatch for the same request set, across
//! formats and solvers; eviction under a small byte budget must never
//! change results, only `cache.*` counters.

use gsem::coordinator::{
    FormatChoice, RhsSpec, ServiceConfig, ServiceError, SolveRequest, SolveResult, SolverKind,
    SolverPool, SolverService,
};
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::convdiff::convdiff2d;
use gsem::sparse::gen::poisson::poisson2d;
use std::sync::Arc;
use std::time::Duration;

/// The cross-format, cross-solver request set. Built fresh per call so
/// every run re-allocates its matrices (distinct `Arc`s — exactly what
/// digest keying must see through).
fn request_set() -> Vec<SolveRequest> {
    let p = Arc::new(poisson2d(10, 10));
    let c = Arc::new(convdiff2d(8, 8, 4.0, 2.0));
    let mut reqs = Vec::new();
    // three same-matrix CG/FP64 requests: the mergeable group
    for seed in 0..3u64 {
        let mut r = SolveRequest::new(
            &format!("cg-fp64-{seed}"),
            Arc::clone(&p),
            SolverKind::Cg,
            FormatChoice::fixed(ValueFormat::Fp64),
        );
        r.rhs = RhsSpec::Random(seed);
        reqs.push(r);
    }
    // fixed low-precision and GSE formats
    reqs.push(SolveRequest::new(
        "cg-bf16",
        Arc::clone(&p),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::Bf16),
    ));
    reqs.push(SolveRequest::new(
        "cg-gse-head",
        Arc::clone(&p),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::GseSem(Precision::Head)),
    ));
    reqs.push(SolveRequest::new(
        "cg-gse-full",
        Arc::clone(&p),
        SolverKind::Cg,
        FormatChoice::fixed(ValueFormat::GseSem(Precision::Full)),
    ));
    // other solvers
    reqs.push(SolveRequest::new(
        "gmres-fp64",
        Arc::clone(&c),
        SolverKind::Gmres,
        FormatChoice::fixed(ValueFormat::Fp64),
    ));
    reqs.push(SolveRequest::new(
        "bicgstab-fp32",
        Arc::clone(&p),
        SolverKind::Bicgstab,
        FormatChoice::fixed(ValueFormat::Fp32),
    ));
    // both stepped ladders
    reqs.push(SolveRequest::new(
        "cg-stepped",
        Arc::clone(&p),
        SolverKind::Cg,
        FormatChoice::Stepped { k: 8, params: SteppedParams::cg_paper().scaled(0.01) },
    ));
    reqs.push(SolveRequest::new(
        "cg-stepped-copy",
        Arc::clone(&p),
        SolverKind::Cg,
        FormatChoice::SteppedCopy { params: SteppedParams::cg_paper().scaled(0.01) },
    ));
    reqs
}

fn assert_bitwise_same(base: &[SolveResult], got: &[SolveResult]) {
    assert_eq!(base.len(), got.len());
    for (b, g) in base.iter().zip(got) {
        assert_eq!(b.name, g.name);
        assert_eq!(b.format_label, g.format_label, "{}", b.name);
        assert_eq!(b.outcome.iters, g.outcome.iters, "{}", b.name);
        assert_eq!(b.outcome.converged, g.outcome.converged, "{}", b.name);
        assert_eq!(b.outcome.x, g.outcome.x, "{}: solution diverged bitwise", b.name);
        assert_eq!(
            b.relres_fp64.to_bits(),
            g.relres_fp64.to_bits(),
            "{}: residual diverged bitwise",
            b.name
        );
    }
}

/// Drain a batch, unwrapping the typed-error layer: this request set
/// never breaks down, so every ticket must resolve `Ok`.
fn run_batch_ok(pool: &SolverPool, reqs: Vec<SolveRequest>) -> Vec<SolveResult> {
    pool.run_batch(reqs).into_iter().map(|r| r.expect("clean request set")).collect()
}

fn submit_all(svc: &SolverService, stagger: Option<Duration>) -> Vec<SolveResult> {
    let tickets: Vec<_> = request_set()
        .into_iter()
        .map(|r| {
            let t = svc.submit_request(r).expect("unbounded intake admits everything");
            if let Some(d) = stagger {
                std::thread::sleep(d);
            }
            t
        })
        .collect();
    tickets.into_iter().map(|t| t.wait().expect("clean request set")).collect()
}

#[test]
fn windowed_service_matches_pool_dispatch_bitwise() {
    let pool = SolverPool::new(3);
    let base = run_batch_ok(&pool, request_set());
    // sanity: the baseline itself converges where expected
    assert!(base.iter().filter(|r| r.format_label == "FP64").all(|r| r.outcome.converged));

    // one-shot arrival: everything lands in a single window
    let svc = SolverService::new(
        ServiceConfig::new().workers(3).window(Duration::from_millis(20)).batch_width(256),
    );
    let got = submit_all(&svc, None);
    assert_bitwise_same(&base, &got);

    // staggered arrival: flushes may split the set arbitrarily across
    // windows — per-request results must not change
    let svc2 = SolverService::new(
        ServiceConfig::new().workers(2).window(Duration::from_millis(2)).batch_width(4),
    );
    let got2 = submit_all(&svc2, Some(Duration::from_micros(500)));
    assert_bitwise_same(&base, &got2);
}

#[test]
fn manual_service_matches_pool_dispatch_bitwise() {
    let pool = SolverPool::new(2);
    let base = run_batch_ok(&pool, request_set());
    let svc = SolverService::manual(ServiceConfig::new().workers(2));
    let tickets: Vec<_> =
        request_set().into_iter().map(|r| svc.submit_request(r).unwrap()).collect();
    assert_eq!(svc.flush(), tickets.len());
    let got: Vec<SolveResult> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    assert_bitwise_same(&base, &got);
    // the mergeable trio actually merged
    assert_eq!(svc.metrics().counter("pool.batched_rhs"), 3);
    assert_eq!(svc.metrics().counter("intake.merged"), 3);
    assert_eq!(svc.metrics().counter("intake.flushes"), 1);
}

#[test]
fn eviction_changes_counters_not_results() {
    let pool = SolverPool::new(2);
    let base = run_batch_ok(&pool, request_set());
    // a budget far below the working set: operators are evicted and
    // rebuilt continuously while the batch runs
    let svc = SolverService::manual(ServiceConfig::new().workers(2).cache_bytes(8 * 1024));
    let tickets: Vec<_> =
        request_set().into_iter().map(|r| svc.submit_request(r).unwrap()).collect();
    svc.flush();
    let got: Vec<SolveResult> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    assert_bitwise_same(&base, &got);
    let st = svc.registry().stats();
    assert!(st.evictions > 0, "tiny budget must evict (stats: {st:?})");
    assert!(st.bytes <= 8 * 1024, "resident {} over budget", st.bytes);
    assert_eq!(svc.metrics().counter("cache.evictions"), st.evictions);
}

/// One named request for the asymmetric/stepped merge traces.
fn asym_request(
    a: &Arc<gsem::sparse::Csr>,
    name: &str,
    seed: u64,
    fmt: FormatChoice,
) -> SolveRequest {
    let solver = if name.starts_with("gmres") {
        SolverKind::Gmres
    } else {
        SolverKind::Cg
    };
    let mut r = SolveRequest::new(name, Arc::clone(a), solver, fmt);
    r.rhs = RhsSpec::Random(seed);
    r
}

#[test]
fn staggered_gmres_trace_merges_and_matches_dispatch() {
    let a = Arc::new(convdiff2d(8, 8, 4.0, 2.0));
    let svc = SolverService::new(
        ServiceConfig::new().workers(2).window(Duration::from_secs(30)).batch_width(4),
    );
    let reqs: Vec<SolveRequest> = (0..4)
        .map(|i| {
            asym_request(&a, &format!("gmres-{i}"), i, FormatChoice::fixed(ValueFormat::Fp64))
        })
        .collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|r| {
            let t = svc.submit_request(r.clone()).unwrap();
            std::thread::sleep(Duration::from_micros(300));
            t
        })
        .collect();
    for (r, t) in reqs.iter().zip(tickets) {
        let got = t.wait().unwrap();
        let single = gsem::coordinator::jobs::dispatch(r).unwrap();
        assert_eq!(got.outcome.iters, single.outcome.iters, "{}", r.name);
        assert_eq!(got.outcome.x, single.outcome.x, "{}", r.name);
        assert_eq!(got.relres_fp64.to_bits(), single.relres_fp64.to_bits(), "{}", r.name);
    }
    assert!(svc.metrics().counter("intake.merged") > 0, "staggered GMRES must merge");
    assert_eq!(svc.metrics().counter("pool.batched_gmres"), 1);
    assert_eq!(svc.metrics().counter("pool.batched_rhs"), 4);
}

#[test]
fn staggered_stepped_trace_merges_and_matches_dispatch() {
    let a = Arc::new(poisson2d(9, 9));
    let params = SteppedParams::cg_paper().scaled(0.01);
    let mk_all = || -> Vec<SolveRequest> {
        let mut reqs: Vec<SolveRequest> = (0..3)
            .map(|i| {
                asym_request(&a, &format!("cg-st-{i}"), i, FormatChoice::Stepped { k: 8, params })
            })
            .collect();
        // a differently tuned stepped request must NOT join the block
        reqs.push(asym_request(
            &a,
            "cg-st-other",
            9,
            FormatChoice::Stepped { k: 8, params: SteppedParams::cg_paper().scaled(0.02) },
        ));
        reqs
    };
    for cache_bytes in [None, Some(4 * 1024usize)] {
        let mut cfg =
            ServiceConfig::new().workers(2).window(Duration::from_secs(30)).batch_width(4);
        if let Some(b) = cache_bytes {
            cfg = cfg.cache_bytes(b);
        }
        let svc = SolverService::new(cfg);
        let reqs = mk_all();
        let tickets: Vec<_> = reqs
            .iter()
            .map(|r| {
                let t = svc.submit_request(r.clone()).unwrap();
                std::thread::sleep(Duration::from_micros(300));
                t
            })
            .collect();
        for (r, t) in reqs.iter().zip(tickets) {
            let got = t.wait().unwrap();
            let single = gsem::coordinator::jobs::dispatch(r).unwrap();
            assert_eq!(got.format_label, "GSE-SEM", "{}", r.name);
            assert_eq!(got.outcome.iters, single.outcome.iters, "{}", r.name);
            assert_eq!(got.outcome.switches, single.outcome.switches, "{}", r.name);
            assert_eq!(got.outcome.x, single.outcome.x, "{}", r.name);
            assert_eq!(got.relres_fp64.to_bits(), single.relres_fp64.to_bits(), "{}", r.name);
        }
        // the three equal-params requests merged; the odd one ran alone
        assert_eq!(svc.metrics().counter("intake.merged"), 3, "budget {cache_bytes:?}");
        assert_eq!(svc.metrics().counter("pool.batched_stepped"), 1);
        assert_eq!(svc.metrics().counter("pool.batched_rhs"), 3);
        if cache_bytes.is_some() {
            assert!(svc.registry().stats().evictions > 0, "tiny budget must evict");
        }
    }
}

#[test]
fn bounded_intake_sheds_excess_and_admitted_match_dispatch() {
    let a = Arc::new(poisson2d(10, 10));
    let svc = SolverService::manual(ServiceConfig::new().workers(2).queue_depth(3));
    let mut tickets = Vec::new();
    let mut shed = 0u64;
    for seed in 0..8u64 {
        let mut r = SolveRequest::new(
            &format!("burst-{seed}"),
            Arc::clone(&a),
            SolverKind::Cg,
            FormatChoice::fixed(ValueFormat::Fp64),
        );
        r.rhs = RhsSpec::Random(seed);
        match svc.submit_request(r.clone()) {
            Ok(t) => tickets.push((r, t)),
            Err(ServiceError::Overloaded { depth }) => {
                assert_eq!(depth, 3, "shed must report the saturated depth");
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert_eq!(tickets.len(), 3, "bound admits exactly queue_depth");
    assert_eq!(shed, 5);
    assert_eq!(svc.metrics().counter("intake.shed"), 5);
    assert_eq!(svc.metrics().counter("intake.submitted"), 3);
    svc.flush();
    // load-shedding must not perturb what was admitted: every survivor
    // is bitwise identical to its one-shot dispatch
    for (r, t) in tickets {
        let got = t.wait().unwrap();
        let single = gsem::coordinator::jobs::dispatch(&r).unwrap();
        assert_eq!(got.outcome.iters, single.outcome.iters, "{}", r.name);
        assert_eq!(got.outcome.x, single.outcome.x, "{}", r.name);
        assert_eq!(got.relres_fp64.to_bits(), single.relres_fp64.to_bits(), "{}", r.name);
    }
}

#[test]
fn auto_format_resolves_before_grouping_and_merges_with_hand_picked() {
    let a = Arc::new(poisson2d(12, 12));
    // what the policy will resolve Auto to for this digest at width 1
    let hand = gsem::coordinator::policy::decide(&a, SolverKind::Cg, 1).choice;
    assert!(
        matches!(hand, FormatChoice::Stepped { .. }),
        "narrow poisson population resolves to the stepped ladder, got {hand:?}"
    );
    let svc = SolverService::manual(ServiceConfig::new().workers(2));
    let auto_req = {
        let mut r = SolveRequest::new("auto", Arc::clone(&a), SolverKind::Cg, FormatChoice::Auto);
        r.rhs = RhsSpec::Random(7);
        r
    };
    let hand_req = {
        let mut r = SolveRequest::new("hand", Arc::clone(&a), SolverKind::Cg, hand.clone());
        r.rhs = RhsSpec::Random(8);
        r
    };
    let t_auto = svc.submit_request(auto_req.clone()).unwrap();
    let t_hand = svc.submit_request(hand_req.clone()).unwrap();
    assert_eq!(svc.flush(), 2);
    let r_auto = t_auto.wait().unwrap();
    let r_hand = t_hand.wait().unwrap();
    // Auto resolved to the hand-picked key BEFORE the grouping pass:
    // the two requests land in one merged multi-RHS block
    assert_eq!(svc.metrics().counter("intake.merged"), 2);
    assert_eq!(svc.metrics().counter("pool.batched_groups"), 1);
    assert_eq!(svc.metrics().counter("policy.decisions"), 1);
    // each column bitwise-matches one-shot dispatch at the resolved format
    let mut single_auto = auto_req;
    single_auto.format = hand.clone();
    let s_auto = gsem::coordinator::jobs::dispatch(&single_auto).unwrap();
    let s_hand = gsem::coordinator::jobs::dispatch(&hand_req).unwrap();
    assert_eq!(r_auto.format_label, "GSE-SEM");
    assert_eq!(r_auto.outcome.x, s_auto.outcome.x, "auto column diverged bitwise");
    assert_eq!(r_hand.outcome.x, s_hand.outcome.x, "hand column diverged bitwise");
    // a second Auto request: the digest's decision is served from cache
    // and resolves to the identical solve
    let mut again = SolveRequest::new("auto2", Arc::clone(&a), SolverKind::Cg, FormatChoice::Auto);
    again.rhs = RhsSpec::Random(7);
    let t2 = svc.submit_request(again).unwrap();
    svc.flush();
    let r2 = t2.wait().unwrap();
    assert_eq!(svc.metrics().counter("policy.cache_hits"), 1);
    assert_eq!(r2.outcome.x, s_auto.outcome.x, "cached decision changed the result");
}

#[test]
fn new_counters_appear_in_metrics_report() {
    let svc = SolverService::manual(ServiceConfig::new().workers(2).cache_bytes(8 * 1024));
    let tickets: Vec<_> =
        request_set().into_iter().map(|r| svc.submit_request(r).unwrap()).collect();
    svc.flush();
    for t in tickets {
        let _ = t.wait().unwrap();
    }
    let report = svc.metrics().report();
    for counter in ["cache.evictions", "cache.bytes", "intake.flushes", "intake.merged"] {
        assert!(report.contains(counter), "report missing {counter}:\n{report}");
    }
    assert!(svc.metrics().counter("intake.flushes") >= 1);
    assert!(svc.metrics().counter("intake.merged") >= 3);
    assert!(svc.metrics().counter("cache.evictions") >= 1);
    assert!(svc.metrics().gauge("cache.bytes") <= 8 * 1024);
}
