//! Coordinate-format sparse matrix builder.

use super::csr::Csr;

/// COO triplet accumulator; duplicates are summed on conversion.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        assert!(nrows < u32::MAX as usize && ncols < u32::MAX as usize);
        Self { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        let mut c = Self::new(nrows, ncols);
        c.rows.reserve(cap);
        c.cols.reserve(cap);
        c.vals.reserve(cap);
        c
    }

    /// Add one entry. Zero values are kept (callers may rely on explicit
    /// zeros); use [`Coo::prune_zeros`] to drop them.
    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.nrows && c < self.ncols, "entry out of bounds");
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    /// Add both (r,c,v) and (c,r,v) (symmetric off-diagonal expansion).
    #[inline]
    pub fn push_sym(&mut self, r: usize, c: usize, v: f64) {
        self.push(r, c, v);
        if r != c {
            self.push(c, r, v);
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn prune_zeros(&mut self) {
        let mut keep = 0usize;
        for i in 0..self.vals.len() {
            if self.vals[i] != 0.0 {
                self.rows[keep] = self.rows[i];
                self.cols[keep] = self.cols[i];
                self.vals[keep] = self.vals[i];
                keep += 1;
            }
        }
        self.rows.truncate(keep);
        self.cols.truncate(keep);
        self.vals.truncate(keep);
    }

    /// Convert to CSR, summing duplicate entries, columns sorted per row.
    pub fn to_csr(&self) -> Csr {
        let nnz = self.nnz();
        // Counting sort by row.
        let mut rowptr = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            rowptr[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            rowptr[i + 1] += rowptr[i];
        }
        let mut colidx = vec![0u32; nnz];
        let mut vals = vec![0f64; nnz];
        let mut next = rowptr.clone();
        for i in 0..nnz {
            let r = self.rows[i] as usize;
            let slot = next[r];
            next[r] += 1;
            colidx[slot] = self.cols[i];
            vals[slot] = self.vals[i];
        }
        // Sort each row by column and merge duplicates.
        let mut out_colidx = Vec::with_capacity(nnz);
        let mut out_vals = Vec::with_capacity(nnz);
        let mut out_rowptr = vec![0usize; self.nrows + 1];
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for r in 0..self.nrows {
            scratch.clear();
            scratch.extend(
                colidx[rowptr[r]..rowptr[r + 1]]
                    .iter()
                    .copied()
                    .zip(vals[rowptr[r]..rowptr[r + 1]].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < scratch.len() {
                let (c, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                out_colidx.push(c);
                out_vals.push(v);
                i = j;
            }
            out_rowptr[r + 1] = out_colidx.len();
        }
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            rowptr: out_rowptr,
            colidx: out_colidx,
            vals: out_vals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csr_sorts_and_sums_duplicates() {
        let mut c = Coo::new(2, 3);
        c.push(1, 2, 5.0);
        c.push(0, 1, 1.0);
        c.push(0, 0, 2.0);
        c.push(0, 1, 3.0); // duplicate -> summed
        let a = c.to_csr();
        assert_eq!(a.rowptr, vec![0, 2, 3]);
        assert_eq!(a.colidx, vec![0, 1, 2]);
        assert_eq!(a.vals, vec![2.0, 4.0, 5.0]);
    }

    #[test]
    fn prune_zeros_removes_only_zeros() {
        let mut c = Coo::new(1, 4);
        c.push(0, 0, 1.0);
        c.push(0, 1, 0.0);
        c.push(0, 2, -2.0);
        c.prune_zeros();
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.vals, vec![1.0, -2.0]);
    }

    #[test]
    fn push_sym_mirrors_offdiagonal() {
        let mut c = Coo::new(3, 3);
        c.push_sym(0, 1, 2.0);
        c.push_sym(2, 2, 1.0);
        assert_eq!(c.nnz(), 3);
        let a = c.to_csr();
        assert_eq!(a.get(0, 1), 2.0);
        assert_eq!(a.get(1, 0), 2.0);
        assert_eq!(a.get(2, 2), 1.0);
    }

    #[test]
    fn empty_rows_handled() {
        let mut c = Coo::new(4, 4);
        c.push(3, 0, 1.0);
        let a = c.to_csr();
        assert_eq!(a.rowptr, vec![0, 0, 0, 0, 1]);
    }
}
