//! Per-matrix numerical statistics backing the §II motivation study:
//! entropy of value/exponent/mantissa populations and the top-k shared-
//! exponent coverage of Eq. 2 (Fig. 1).

use super::csr::Csr;
use crate::formats::entropy::{analyze, EntropyReport};
use crate::formats::gse::ExpHistogram;

/// The k values reported in Fig. 1(b)-(h).
pub const TOPK_LEVELS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Full §II statistics for one matrix.
#[derive(Clone, Debug)]
pub struct MatrixStats {
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    pub entropy: EntropyReport,
    /// coverage at each of [`TOPK_LEVELS`]
    pub topk: [f64; 7],
    pub num_distinct_exponents: usize,
    /// fraction of nnz whose value is exactly representable in bf16
    /// (useful context for the baseline-error figures)
    pub avg_abs: f64,
    pub max_abs: f64,
    pub min_abs_nonzero: f64,
}

/// Compute [`MatrixStats`] for a matrix's non-zeros.
pub fn matrix_stats(m: &Csr) -> MatrixStats {
    let mut hist = ExpHistogram::new();
    hist.push_all(&m.vals);
    let mut topk = [0f64; 7];
    for (i, &k) in TOPK_LEVELS.iter().enumerate() {
        topk[i] = hist.topk_coverage(k);
    }
    let mut sum_abs = 0f64;
    let mut max_abs = 0f64;
    let mut min_abs = f64::INFINITY;
    for &v in &m.vals {
        let a = v.abs();
        sum_abs += a;
        max_abs = max_abs.max(a);
        if a > 0.0 {
            min_abs = min_abs.min(a);
        }
    }
    MatrixStats {
        nrows: m.nrows,
        ncols: m.ncols,
        nnz: m.nnz(),
        entropy: analyze(&m.vals),
        topk,
        num_distinct_exponents: hist.num_distinct(),
        avg_abs: if m.nnz() == 0 { 0.0 } else { sum_abs / m.nnz() as f64 },
        max_abs,
        min_abs_nonzero: if min_abs.is_finite() { min_abs } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn stats_on_single_binade_matrix() {
        let mut c = Coo::new(2, 2);
        c.push(0, 0, 1.0);
        c.push(0, 1, 1.5);
        c.push(1, 1, 1.25);
        let s = matrix_stats(&c.to_csr());
        assert_eq!(s.nnz, 3);
        assert_eq!(s.num_distinct_exponents, 1);
        assert_eq!(s.topk[0], 1.0); // top-1 covers everything
        assert_eq!(s.entropy.exponent_bits, 0.0);
        assert_eq!(s.max_abs, 1.5);
        assert_eq!(s.min_abs_nonzero, 1.0);
    }

    #[test]
    fn topk_monotone_nondecreasing() {
        let mut c = Coo::new(1, 64);
        for j in 0..64usize {
            c.push(0, j, 2f64.powi((j % 13) as i32));
        }
        let s = matrix_stats(&c.to_csr());
        for w in s.topk.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!((s.topk[6] - 1.0).abs() < 1e-12); // top-64 covers all
        assert_eq!(s.num_distinct_exponents, 13);
    }
}
