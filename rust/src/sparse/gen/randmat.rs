//! General random sparse matrices with *controlled exponent
//! distributions* — the knob the whole paper turns on. The Fig. 1 / Fig. 4
//! sweeps need matrices spanning the top-k coverage spectrum from "one
//! shared exponent covers 99%" to "exponents everywhere"; these
//! generators place each non-zero's exponent by an explicit discrete
//! distribution so the sweep covers that spectrum by construction.

use crate::formats::ieee;
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::Prng;

/// Exponent-placement law for [`exp_controlled`].
#[derive(Clone, Copy, Debug)]
pub enum ExpLaw {
    /// All non-zeros share one binade (`2^e .. 2^{e+1}`).
    Single { e: i32 },
    /// Zipf(s) over `count` consecutive binades starting at `e0` —
    /// s large = heavy clustering (high top-1 coverage), s→0 = uniform.
    Zipf { e0: i32, count: usize, s: f64 },
    /// Two clusters separated by `gap` binades with mixing ratio `p`.
    Bimodal { e0: i32, gap: i32, p: f64 },
    /// Normal over binades with stddev `sigma` centered at `e0`.
    Gaussian { e0: i32, sigma: f64 },
}

/// Draw a value whose exponent follows `law` (mantissa uniform in
/// [1, 2)) with a random sign unless `positive`.
pub fn draw_value(rng: &mut Prng, law: ExpLaw, positive: bool) -> f64 {
    let e = match law {
        ExpLaw::Single { e } => e,
        ExpLaw::Zipf { e0, count, s } => {
            let weights: Vec<f64> =
                (1..=count).map(|r| 1.0 / (r as f64).powf(s)).collect();
            e0 + rng.weighted(&weights) as i32
        }
        ExpLaw::Bimodal { e0, gap, p } => {
            if rng.chance(p) {
                e0
            } else {
                e0 + gap
            }
        }
        ExpLaw::Gaussian { e0, sigma } => e0 + (rng.normal() * sigma).round() as i32,
    };
    let mant = 1.0 + rng.f64();
    let sign = if positive || rng.chance(0.5) { 1.0 } else { -1.0 };
    sign * ieee::ldexp(mant, e.clamp(-1000, 1000))
}

/// Random sparse matrix: `nrows × ncols`, about `row_nnz` entries per row
/// (plus a guaranteed diagonal when square), values drawn by `law`.
/// Square matrices are made strictly diagonally dominant so both CG
/// (after symmetrization) and GMRES workloads built on top are solvable.
pub fn exp_controlled(
    nrows: usize,
    ncols: usize,
    row_nnz: usize,
    law: ExpLaw,
    seed: u64,
) -> Csr {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(nrows, ncols, nrows * (row_nnz + 1));
    for r in 0..nrows {
        let offdiag = rng.sample_indices(ncols, row_nnz.min(ncols));
        let mut rowsum = 0.0;
        for c in offdiag {
            if nrows == ncols && c == r {
                continue;
            }
            let v = draw_value(&mut rng, law, false);
            rowsum += v.abs();
            coo.push(r, c, v);
        }
        if nrows == ncols {
            // strict dominance; diagonal inherits the row's scale so the
            // exponent distribution is not distorted much
            coo.push(r, r, rowsum * 1.05 + draw_value(&mut rng, law, true).abs());
        }
    }
    coo.to_csr()
}

/// Symmetric positive-definite variant: symmetrize the off-diagonal part
/// then re-dominate the diagonal.
pub fn exp_controlled_spd(n: usize, row_nnz: usize, law: ExpLaw, seed: u64) -> Csr {
    let a = exp_controlled(n, n, row_nnz, law, seed);
    let t = a.transpose();
    // B = (A + A^T)/2 off-diagonal, then strict dominance on the diagonal
    let mut coo = Coo::with_capacity(n, n, a.nnz() * 2);
    let mut rowsum = vec![0f64; n];
    for r in 0..n {
        for (m, half) in [(&a, 0.5), (&t, 0.5)] {
            let (cols, vals) = m.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                if c as usize != r {
                    coo.push(r, c as usize, half * v);
                    rowsum[r] += (half * v).abs();
                }
            }
        }
    }
    for (r, &s) in rowsum.iter().enumerate() {
        coo.push(r, r, s * 1.1 + 1e-6);
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::gse::ExpHistogram;
    use crate::sparse::stats::matrix_stats;

    #[test]
    fn single_law_one_exponent() {
        let mut rng = Prng::new(1);
        let mut h = ExpHistogram::new();
        for _ in 0..1000 {
            h.push(draw_value(&mut rng, ExpLaw::Single { e: 3 }, false));
        }
        assert_eq!(h.num_distinct(), 1);
        assert_eq!(h.topk_coverage(1), 1.0);
    }

    #[test]
    fn zipf_concentration_follows_s() {
        let mk = |s: f64| {
            let mut rng = Prng::new(2);
            let mut h = ExpHistogram::new();
            for _ in 0..20_000 {
                h.push(draw_value(&mut rng, ExpLaw::Zipf { e0: -5, count: 32, s }, false));
            }
            h.topk_coverage(1)
        };
        let heavy = mk(2.5);
        let flat = mk(0.1);
        assert!(heavy > 0.7, "heavy={heavy}");
        assert!(flat < 0.15, "flat={flat}");
    }

    #[test]
    fn bimodal_two_exponents() {
        let mut rng = Prng::new(3);
        let mut h = ExpHistogram::new();
        for _ in 0..5000 {
            h.push(draw_value(&mut rng, ExpLaw::Bimodal { e0: 0, gap: 10, p: 0.8 }, false));
        }
        assert_eq!(h.num_distinct(), 2);
        let c1 = h.topk_coverage(1);
        assert!((c1 - 0.8).abs() < 0.03, "c1={c1}");
    }

    #[test]
    fn matrix_valid_dominant_and_law_respected() {
        let a = exp_controlled(300, 300, 6, ExpLaw::Zipf { e0: -2, count: 8, s: 1.5 }, 4);
        a.validate().unwrap();
        assert!(a.diag_dominance() > 1.0);
        let s = matrix_stats(&a);
        assert!(s.topk[3] > 0.95); // top-8 covers nearly everything
    }

    #[test]
    fn spd_variant_symmetric_dominant() {
        let a = exp_controlled_spd(150, 5, ExpLaw::Gaussian { e0: 0, sigma: 3.0 }, 5);
        a.validate().unwrap();
        assert!(a.is_symmetric(1e-12));
        assert!(a.diag_dominance() > 1.0);
    }

    #[test]
    fn rectangular_supported() {
        let a = exp_controlled(40, 80, 5, ExpLaw::Single { e: 0 }, 6);
        a.validate().unwrap();
        assert_eq!((a.nrows, a.ncols), (40, 80));
    }
}
