//! Finite-difference Poisson (Laplacian) matrices: the canonical SPD
//! iterative-solver workload. Values concentrate on two exponents
//! ({4,-1} / {6,-1}), the extreme-clustering end of the Fig. 1 spectrum.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;

/// 2D 5-point Laplacian on an `nx × ny` grid (Dirichlet boundaries).
/// SPD, `n = nx*ny`, ≤ 5 nnz/row.
pub fn poisson2d(nx: usize, ny: usize) -> Csr {
    let n = nx * ny;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    let idx = |i: usize, j: usize| i * ny + j;
    for i in 0..nx {
        for j in 0..ny {
            let r = idx(i, j);
            coo.push(r, r, 4.0);
            if i > 0 {
                coo.push(r, idx(i - 1, j), -1.0);
            }
            if i + 1 < nx {
                coo.push(r, idx(i + 1, j), -1.0);
            }
            if j > 0 {
                coo.push(r, idx(i, j - 1), -1.0);
            }
            if j + 1 < ny {
                coo.push(r, idx(i, j + 1), -1.0);
            }
        }
    }
    coo.to_csr()
}

/// 3D 7-point Laplacian on an `n³` grid. SPD.
pub fn poisson3d(n: usize) -> Csr {
    let total = n * n * n;
    let mut coo = Coo::with_capacity(total, total, 7 * total);
    let idx = |i: usize, j: usize, k: usize| (i * n + j) * n + k;
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let r = idx(i, j, k);
                coo.push(r, r, 6.0);
                if i > 0 {
                    coo.push(r, idx(i - 1, j, k), -1.0);
                }
                if i + 1 < n {
                    coo.push(r, idx(i + 1, j, k), -1.0);
                }
                if j > 0 {
                    coo.push(r, idx(i, j - 1, k), -1.0);
                }
                if j + 1 < n {
                    coo.push(r, idx(i, j + 1, k), -1.0);
                }
                if k > 0 {
                    coo.push(r, idx(i, j, k - 1), -1.0);
                }
                if k + 1 < n {
                    coo.push(r, idx(i, j, k + 1), -1.0);
                }
            }
        }
    }
    coo.to_csr()
}

/// Anisotropic 2D Laplacian: `-eps * u_xx - u_yy`, spreading the value
/// set over more exponents as `eps` departs from 1.
pub fn poisson2d_aniso(nx: usize, ny: usize, eps: f64) -> Csr {
    let n = nx * ny;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    let idx = |i: usize, j: usize| i * ny + j;
    for i in 0..nx {
        for j in 0..ny {
            let r = idx(i, j);
            coo.push(r, r, 2.0 * eps + 2.0);
            if i > 0 {
                coo.push(r, idx(i - 1, j), -eps);
            }
            if i + 1 < nx {
                coo.push(r, idx(i + 1, j), -eps);
            }
            if j > 0 {
                coo.push(r, idx(i, j - 1), -1.0);
            }
            if j + 1 < ny {
                coo.push(r, idx(i, j + 1), -1.0);
            }
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson2d_structure() {
        let a = poisson2d(4, 5);
        a.validate().unwrap();
        assert_eq!(a.nrows, 20);
        assert!(a.is_symmetric(0.0));
        assert_eq!(a.get(0, 0), 4.0);
        assert_eq!(a.max_row_nnz(), 5);
        // interior row has exactly 5 entries
        let (cols, _) = a.row(6);
        assert_eq!(cols.len(), 5);
    }

    #[test]
    fn poisson2d_is_positive_definite_via_dominance() {
        // weak diagonal dominance + irreducibility => PD; check dominance >= 4/4
        let a = poisson2d(6, 6);
        assert!(a.diag_dominance() >= 1.0);
    }

    #[test]
    fn poisson3d_structure() {
        let a = poisson3d(4);
        a.validate().unwrap();
        assert_eq!(a.nrows, 64);
        assert!(a.is_symmetric(0.0));
        assert_eq!(a.get(0, 0), 6.0);
        assert_eq!(a.max_row_nnz(), 7);
    }

    #[test]
    fn poisson_two_distinct_exponents() {
        let s = crate::sparse::stats::matrix_stats(&poisson2d(8, 8));
        assert_eq!(s.num_distinct_exponents, 2);
        assert_eq!(s.topk[1], 1.0); // top-2 covers everything
    }

    #[test]
    fn aniso_spreads_exponents() {
        let a = poisson2d_aniso(8, 8, 1e-3);
        a.validate().unwrap();
        assert!(a.is_symmetric(0.0));
        let s = crate::sparse::stats::matrix_stats(&a);
        assert!(s.num_distinct_exponents >= 3);
    }
}
