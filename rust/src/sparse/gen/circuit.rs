//! Circuit-simulation style matrices — stand-ins for the adder_dcop /
//! init_adder / add32 / Pd family in the GMRES test set. Modified nodal
//! analysis produces asymmetric, ill-scaled matrices whose conductances
//! span many binades (resistors in ohms..megaohms), i.e. the *wide*
//! end of the exponent-distribution spectrum.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::Prng;

/// Random conductance network with `n` nodes and ~`avg_deg` neighbors
/// per node. `sigma_ln` controls the conductance magnitude spread;
/// `asym` in [0,1] injects controlled-source asymmetry (0 = symmetric).
/// Diagonally dominant, hence nonsingular.
pub fn conductance_network(n: usize, avg_deg: usize, sigma_ln: f64, asym: f64, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * (avg_deg + 1));
    let mut diag = vec![0f64; n];
    // ring backbone guarantees irreducibility
    for i in 0..n {
        let j = (i + 1) % n;
        if n > 1 {
            let g = rng.lognormal(0.0, sigma_ln);
            let skew = 1.0 + asym * rng.range_f64(-0.5, 0.5);
            coo.push(i, j, -g * skew);
            coo.push(j, i, -g / skew);
            diag[i] += g * skew;
            diag[j] += g / skew;
        }
    }
    // random chords
    let extra = n * avg_deg.saturating_sub(2) / 2;
    for _ in 0..extra {
        let i = rng.below(n);
        let j = rng.below(n);
        if i == j {
            continue;
        }
        let g = rng.lognormal(0.0, sigma_ln);
        let skew = 1.0 + asym * rng.range_f64(-0.5, 0.5);
        coo.push(i, j, -g * skew);
        coo.push(j, i, -g / skew);
        diag[i] += g * skew;
        diag[j] += g / skew;
    }
    // grounded capacitor / source stamp on every node: strict dominance
    for (i, &d) in diag.iter().enumerate() {
        coo.push(i, i, d * 1.02 + 1e-3);
    }
    coo.to_csr()
}

/// DC operating-point style matrix (adder_dcop analog): a conductance
/// network plus a handful of dense-ish rows/cols from voltage sources,
/// giving the characteristic arrow pattern and wildly mixed scales.
pub fn dcop(n: usize, nsrc: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let base = conductance_network(n, 4, 4.0, 0.3, seed ^ 0xD15EA5E);
    let mut coo = Coo::with_capacity(n + nsrc, n + nsrc, base.nnz() + 4 * nsrc * 3);
    for r in 0..n {
        let (cols, vals) = base.row(r);
        for (&c, &v) in cols.iter().zip(vals) {
            coo.push(r, c as usize, v);
        }
    }
    // voltage-source rows: +-1 incidence entries and tiny regularization
    for s in 0..nsrc {
        let row = n + s;
        let a = rng.below(n);
        let mut b = rng.below(n);
        if b == a {
            b = (b + 1) % n;
        }
        coo.push(row, a, 1.0);
        coo.push(row, b, -1.0);
        coo.push(a, row, 1.0);
        coo.push(b, row, -1.0);
        coo.push(row, row, 1e-9); // near-zero pivot, the dcop nastiness
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::matrix_stats;

    #[test]
    fn network_valid_and_dominant() {
        let a = conductance_network(200, 4, 3.0, 0.0, 42);
        a.validate().unwrap();
        assert!(a.diag_dominance() > 1.0);
        assert!(a.is_symmetric(1e-12)); // asym = 0
    }

    #[test]
    fn asymmetry_knob() {
        let a = conductance_network(100, 4, 2.0, 0.5, 7);
        a.validate().unwrap();
        assert!(!a.is_symmetric(1e-9));
        assert!(a.diag_dominance() > 1.0); // still dominant
    }

    #[test]
    fn wide_exponent_spread() {
        let s = matrix_stats(&conductance_network(500, 6, 5.0, 0.2, 3));
        assert!(s.num_distinct_exponents > 10, "{}", s.num_distinct_exponents);
        // top-8 should NOT cover everything for sigma=5
        assert!(s.topk[3] < 0.999);
    }

    #[test]
    fn dcop_shape_and_sources() {
        let a = dcop(100, 5, 9);
        a.validate().unwrap();
        assert_eq!(a.nrows, 105);
        // source row has the incidence pair + pivot
        let (cols, _) = a.row(100);
        assert!(cols.len() >= 3);
        assert!(!a.is_symmetric(1e-9));
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            conductance_network(50, 4, 2.0, 0.1, 5),
            conductance_network(50, 4, 2.0, 0.1, 5)
        );
    }
}
