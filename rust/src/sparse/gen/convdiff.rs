//! Convection–diffusion matrices (upwind FD): the classic asymmetric
//! GMRES workload — stand-ins for wang3, epb2, atmosmodl, dw* in the
//! paper's GMRES set. The Péclet number controls the asymmetry strength
//! and (with it) GMRES difficulty.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::Prng;

/// 2D convection–diffusion with constant wind `(wx, wy)` and first-order
/// upwinding on an `nx × ny` grid. Asymmetric for nonzero wind.
pub fn convdiff2d(nx: usize, ny: usize, wx: f64, wy: f64) -> Csr {
    let n = nx * ny;
    let idx = |i: usize, j: usize| i * ny + j;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    // diffusion: 5-point Laplacian; convection: upwind differences
    let (axp, axm) = if wx >= 0.0 { (wx, 0.0) } else { (0.0, -wx) };
    let (ayp, aym) = if wy >= 0.0 { (wy, 0.0) } else { (0.0, -wy) };
    for i in 0..nx {
        for j in 0..ny {
            let r = idx(i, j);
            coo.push(r, r, 4.0 + axp + axm + ayp + aym);
            if i > 0 {
                coo.push(r, idx(i - 1, j), -1.0 - axp);
            }
            if i + 1 < nx {
                coo.push(r, idx(i + 1, j), -1.0 - axm);
            }
            if j > 0 {
                coo.push(r, idx(i, j - 1), -1.0 - ayp);
            }
            if j + 1 < ny {
                coo.push(r, idx(i, j + 1), -1.0 - aym);
            }
        }
    }
    coo.to_csr()
}

/// Recirculating-wind convection–diffusion: spatially varying wind field
/// `w = (sin πy·cos πx·pe, -sin πx·cos πy·pe)`; harder than constant
/// wind, values spread across more binades as `pe` grows.
pub fn convdiff2d_recirc(nx: usize, ny: usize, pe: f64) -> Csr {
    let n = nx * ny;
    let idx = |i: usize, j: usize| i * ny + j;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    let pi = std::f64::consts::PI;
    for i in 0..nx {
        for j in 0..ny {
            let x = (i as f64 + 0.5) / nx as f64;
            let y = (j as f64 + 0.5) / ny as f64;
            let wx = pe * (pi * y).sin() * (pi * x).cos();
            let wy = -pe * (pi * x).sin() * (pi * y).cos();
            let (axp, axm) = if wx >= 0.0 { (wx, 0.0) } else { (0.0, -wx) };
            let (ayp, aym) = if wy >= 0.0 { (wy, 0.0) } else { (0.0, -wy) };
            let r = idx(i, j);
            coo.push(r, r, 4.0 + axp + axm + ayp + aym);
            if i > 0 {
                coo.push(r, idx(i - 1, j), -1.0 - axp);
            }
            if i + 1 < nx {
                coo.push(r, idx(i + 1, j), -1.0 - axm);
            }
            if j > 0 {
                coo.push(r, idx(i, j - 1), -1.0 - ayp);
            }
            if j + 1 < ny {
                coo.push(r, idx(i, j + 1), -1.0 - aym);
            }
        }
    }
    coo.to_csr()
}

/// Tridiagonal "device simulation" style matrix (dw1024/dw2048 analog):
/// banded asymmetric with oscillatory coefficients.
pub fn device1d(n: usize, band: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * (2 * band + 1));
    for i in 0..n {
        let mut diag = 0.0;
        for d in 1..=band {
            let scale = 2f64.powi(-(d as i32));
            if i >= d {
                let v = scale * rng.range_f64(0.5, 1.5) * (1.0 + 0.3 * (i as f64 * 0.1).sin());
                coo.push(i, i - d, -v);
                diag += v;
            }
            if i + d < n {
                let v = scale * rng.range_f64(0.5, 1.5) * (1.0 - 0.3 * (i as f64 * 0.1).cos());
                coo.push(i, i + d, -v);
                diag += v;
            }
        }
        coo.push(i, i, diag * 1.1 + 0.1);
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convdiff_symmetric_iff_no_wind() {
        assert!(convdiff2d(8, 8, 0.0, 0.0).is_symmetric(0.0));
        assert!(!convdiff2d(8, 8, 4.0, 0.0).is_symmetric(1e-12));
    }

    #[test]
    fn convdiff_valid_and_dominant() {
        for pe in [0.0, 1.0, 32.0] {
            let a = convdiff2d(10, 12, pe, pe / 2.0);
            a.validate().unwrap();
            assert!(a.diag_dominance() >= 0.99, "pe={pe}");
        }
    }

    #[test]
    fn recirc_asymmetric_and_valid() {
        let a = convdiff2d_recirc(12, 12, 20.0);
        a.validate().unwrap();
        assert!(!a.is_symmetric(1e-12));
        assert_eq!(a.nrows, 144);
    }

    #[test]
    fn device1d_banded() {
        let a = device1d(64, 3, 2);
        a.validate().unwrap();
        assert_eq!(a.max_row_nnz(), 7);
        assert!(!a.is_symmetric(1e-12));
        assert!(a.diag_dominance() > 1.0);
    }
}
