//! Named matrix corpora — the experiment workloads standing in for the
//! paper's SuiteSparse selections (DESIGN.md §5):
//!
//! * [`spmv_corpus`] — ~300 matrices across classes, sizes, and exponent
//!   distributions (the ">300 sparse matrices" of Fig. 4/5/6).
//! * [`cg_set`] — 15 SPD systems matched in spirit to Table II's CG set.
//! * [`gmres_set`] — 15 asymmetric systems matched to Table II's GMRES
//!   set.
//!
//! Sizes are scaled down from the paper's (which go up to 3×10⁸ nnz on a
//! V100) to what a single CPU core exercises in reasonable time; the
//! `CorpusSize` knob (env `GSEM_CORPUS=small|medium|full`) restores
//! larger instances for the full benchmark runs.

use super::circuit::{conductance_network, dcop};
use super::convdiff::{convdiff2d, convdiff2d_recirc, device1d};
use super::fem::{diffusion2d, mass1d, shell2d, stiffness1d};
use super::poisson::{poisson2d, poisson2d_aniso, poisson3d};
use super::randmat::{exp_controlled, exp_controlled_spd, ExpLaw};
use crate::sparse::csr::Csr;

/// A corpus entry: generator-derived matrix plus identification.
#[derive(Clone, Debug)]
pub struct NamedMatrix {
    pub name: String,
    pub class: &'static str,
    pub a: Csr,
}

impl NamedMatrix {
    fn new(name: impl Into<String>, class: &'static str, a: Csr) -> Self {
        Self { name: name.into(), class, a }
    }
}

/// Corpus scale selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusSize {
    /// CI / `make test`: ~60 matrices, ≤ ~3e4 nnz.
    Small,
    /// default bench: ~300 matrices, ≤ ~2e5 nnz.
    Medium,
    /// full runs: ~300 matrices, ≤ ~2e6 nnz.
    Full,
}

impl CorpusSize {
    /// Resolve from the `GSEM_CORPUS` env var (default Medium).
    pub fn from_env() -> Self {
        match std::env::var("GSEM_CORPUS").as_deref() {
            Ok("small") => CorpusSize::Small,
            Ok("full") => CorpusSize::Full,
            _ => CorpusSize::Medium,
        }
    }

    fn grid_sizes(self) -> &'static [usize] {
        match self {
            CorpusSize::Small => &[8, 16, 32],
            CorpusSize::Medium => &[12, 24, 48, 96, 160],
            CorpusSize::Full => &[16, 32, 64, 128, 256, 512],
        }
    }

    fn n_sizes(self) -> &'static [usize] {
        match self {
            CorpusSize::Small => &[64, 256, 1024],
            CorpusSize::Medium => &[128, 512, 2048, 8192, 24000],
            CorpusSize::Full => &[256, 1024, 4096, 16384, 65536, 262144],
        }
    }
}

/// The SpMV evaluation corpus (Fig. 1 / 4 / 5 / 6 workload): matrices of
/// every class crossed with sizes and exponent-distribution laws.
pub fn spmv_corpus(size: CorpusSize) -> Vec<NamedMatrix> {
    let mut out = Vec::new();
    // -- structured PDE matrices (tight exponent clustering) --
    for &g in size.grid_sizes() {
        out.push(NamedMatrix::new(format!("poisson2d_{g}x{g}"), "pde", poisson2d(g, g)));
        out.push(NamedMatrix::new(
            format!("aniso2d_{g}x{g}"),
            "pde",
            poisson2d_aniso(g, g, 1e-2),
        ));
        let g3 = (g as f64).powf(2.0 / 3.0).round() as usize;
        out.push(NamedMatrix::new(format!("poisson3d_{g3}"), "pde", poisson3d(g3.max(3))));
        for pe in [4.0, 64.0] {
            out.push(NamedMatrix::new(
                format!("convdiff_{g}x{g}_pe{pe}"),
                "cfd",
                convdiff2d(g, g, pe, pe / 3.0),
            ));
        }
        out.push(NamedMatrix::new(
            format!("recirc_{g}x{g}"),
            "cfd",
            convdiff2d_recirc(g, g, 16.0),
        ));
    }
    // -- FEM with material contrast (medium spread) --
    for (i, &g) in size.grid_sizes().iter().enumerate() {
        for contrast in [2.0, 10.0] {
            out.push(NamedMatrix::new(
                format!("diffusion_{g}x{g}_c{contrast}"),
                "fem",
                diffusion2d(g, g, contrast, 100 + i as u64),
            ));
        }
        out.push(NamedMatrix::new(format!("shell_{g}x{g}"), "fem", shell2d(g, g, 200 + i as u64)));
    }
    for (i, &n) in size.n_sizes().iter().enumerate() {
        out.push(NamedMatrix::new(
            format!("stiffness1d_{n}"),
            "fem",
            stiffness1d(n, 2.0, 300 + i as u64),
        ));
        out.push(NamedMatrix::new(format!("mass1d_{n}"), "fem", mass1d(n, 350 + i as u64)));
    }
    // -- circuits (wide spread) --
    for (i, &n) in size.n_sizes().iter().enumerate() {
        out.push(NamedMatrix::new(
            format!("circuit_{n}"),
            "circuit",
            conductance_network(n, 5, 4.0, 0.25, 400 + i as u64),
        ));
        out.push(NamedMatrix::new(
            format!("dcop_{n}"),
            "circuit",
            dcop(n.saturating_sub(n / 20).max(8), (n / 20).max(2), 450 + i as u64),
        ));
        out.push(NamedMatrix::new(
            format!("device1d_{n}"),
            "circuit",
            device1d(n, 3, 500 + i as u64),
        ));
    }
    // -- exponent-law sweep (the Fig. 1(b-h) coverage spectrum) --
    let laws: [(&str, ExpLaw); 6] = [
        ("single", ExpLaw::Single { e: 0 }),
        ("zipf_s25", ExpLaw::Zipf { e0: -4, count: 16, s: 2.5 }),
        ("zipf_s10", ExpLaw::Zipf { e0: -8, count: 32, s: 1.0 }),
        ("zipf_s02", ExpLaw::Zipf { e0: -16, count: 64, s: 0.2 }),
        ("bimodal", ExpLaw::Bimodal { e0: -2, gap: 12, p: 0.7 }),
        ("gauss_s6", ExpLaw::Gaussian { e0: 0, sigma: 6.0 }),
    ];
    for (i, &n) in size.n_sizes().iter().enumerate() {
        for (lname, law) in laws {
            out.push(NamedMatrix::new(
                format!("rand_{lname}_{n}"),
                "random",
                exp_controlled(n, n, 8, law, 600 + i as u64),
            ));
        }
    }
    out
}

/// The 15-system CG test set (Table II left, scaled): SPD matrices
/// ordered by size like the paper's (bcsstk09 .. Queen_4147).
pub fn cg_set(size: CorpusSize) -> Vec<NamedMatrix> {
    let s = match size {
        CorpusSize::Small => 1usize,
        CorpusSize::Medium => 2,
        CorpusSize::Full => 4,
    };
    let mut v = Vec::new();
    // paper analog                         paper matrix (rows, nnz)
    v.push(NamedMatrix::new("cg01_stiff_small", "fem", stiffness1d(540 * s, 1.0, 9001))); // bcsstk09 1,083
    v.push(NamedMatrix::new("cg02_mass_diag", "fem", mass1d(1780 * s, 9002))); // bcsstm24 3,562
    v.push(NamedMatrix::new("cg03_shell_dense", "fem", shell2d(36 * s, 36 * s, 9003))); // bundle1 10,581
    v.push(NamedMatrix::new(
        "cg04_diffusion_mild",
        "fem",
        diffusion2d(51 * s, 51 * s, 4.0, 9004),
    )); // ted_B 10,605
    v.push(NamedMatrix::new(
        "cg05_spd_bimodal",
        "random",
        exp_controlled_spd(3500 * s, 6, ExpLaw::Bimodal { e0: -1, gap: 8, p: 0.75 }, 9005),
    )); // cvxbqp1 50,000
    v.push(NamedMatrix::new("cg06_shell_big", "fem", shell2d(64 * s, 64 * s, 9006))); // consph 83,334
    v.push(NamedMatrix::new("cg07_poisson3d", "pde", poisson3d(14 * s))); // m_t1 97,578
    v.push(NamedMatrix::new(
        "cg08_diffusion_contrast",
        "fem",
        diffusion2d(64 * s, 64 * s, 10.0, 9008),
    )); // Dubcova3 146,689
    v.push(NamedMatrix::new("cg09_poisson2d_a", "pde", poisson2d(96 * s, 96 * s))); // af_0_k101 503,625
    v.push(NamedMatrix::new("cg10_aniso", "pde", poisson2d_aniso(96 * s, 96 * s, 1e-2))); // af_1_k101
    v.push(NamedMatrix::new(
        "cg11_spd_zipf",
        "random",
        exp_controlled_spd(9000 * s, 7, ExpLaw::Zipf { e0: -6, count: 16, s: 1.5 }, 9011),
    )); // af_shell4 504,855
    v.push(NamedMatrix::new(
        "cg12_fault_contrast",
        "fem",
        diffusion2d(80 * s, 80 * s, 16.0, 9012),
    )); // Fault_639 638,802 (extreme contrast = hard)
    v.push(NamedMatrix::new("cg13_shell_fine", "fem", shell2d(90 * s, 90 * s, 9013))); // bone010 986,703
    v.push(NamedMatrix::new(
        "cg14_thermal",
        "fem",
        diffusion2d(110 * s, 110 * s, 6.0, 9014),
    )); // thermal2 1,228,045
    v.push(NamedMatrix::new("cg15_queen_big", "pde", poisson2d(140 * s, 140 * s))); // Queen_4147 4,147,110
    v
}

/// The 15-system GMRES test set (Table II right, scaled): asymmetric
/// matrices ordered by size like the paper's (iprob .. ML_Geer).
pub fn gmres_set(size: CorpusSize) -> Vec<NamedMatrix> {
    let s = match size {
        CorpusSize::Small => 1usize,
        CorpusSize::Medium => 2,
        CorpusSize::Full => 4,
    };
    let mut v = Vec::new();
    v.push(NamedMatrix::new(
        "gm01_iprob",
        "random",
        exp_controlled(1500 * s, 1500 * s, 3, ExpLaw::Single { e: 0 }, 8001),
    )); // iprob 3,001
    v.push(NamedMatrix::new("gm02_dw_a", "circuit", device1d(1024 * s, 2, 8002))); // dw1024
    v.push(NamedMatrix::new("gm03_dw_b", "circuit", device1d(1024 * s, 2, 8003))); // dw2048
    v.push(NamedMatrix::new("gm04_dcop_a", "circuit", dcop(880 * s, 25, 8004))); // adder_dcop_01
    v.push(NamedMatrix::new("gm05_dcop_b", "circuit", dcop(880 * s, 25, 8005))); // init_adder1
    v.push(NamedMatrix::new("gm06_dcop_c", "circuit", dcop(880 * s, 28, 8006))); // adder_dcop_39
    v.push(NamedMatrix::new(
        "gm07_pd",
        "random",
        exp_controlled(4000 * s, 4000 * s, 3, ExpLaw::Zipf { e0: -10, count: 24, s: 0.8 }, 8007),
    )); // Pd 8,081
    v.push(NamedMatrix::new(
        "gm08_add32",
        "circuit",
        conductance_network(2480 * s, 4, 3.0, 0.3, 8008),
    )); // add32 4,960
    v.push(NamedMatrix::new(
        "gm09_ts",
        "random",
        exp_controlled(1070 * s, 1070 * s, 21, ExpLaw::Gaussian { e0: 0, sigma: 8.0 }, 8009),
    )); // TS 2,142 (dense-ish rows)
    v.push(NamedMatrix::new("gm10_epb", "cfd", convdiff2d(112 * s, 112 * s, 8.0, 3.0))); // epb2 25,228
    v.push(NamedMatrix::new("gm11_wang", "cfd", convdiff2d_recirc(114 * s, 114 * s, 24.0))); // wang3 26,064
    v.push(NamedMatrix::new(
        "gm12_tetra",
        "cfd",
        convdiff2d(120 * s, 120 * s, 48.0, 16.0),
    )); // 3D_28984_Tetra
    v.push(NamedMatrix::new(
        "gm13_raefsky",
        "random",
        exp_controlled(1275 * s, 1275 * s, 90, ExpLaw::Zipf { e0: -3, count: 8, s: 2.0 }, 8013),
    )); // raefsky1 3,242 x 293,409 nnz (dense rows)
    v.push(NamedMatrix::new("gm14_atmos", "cfd", convdiff2d_recirc(170 * s, 170 * s, 6.0))); // atmosmodl
    v.push(NamedMatrix::new("gm15_geer", "cfd", convdiff2d(200 * s, 200 * s, 12.0, 12.0))); // ML_Geer
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_corpus_valid_and_named_uniquely() {
        let c = spmv_corpus(CorpusSize::Small);
        assert!(c.len() >= 50, "corpus size {}", c.len());
        let mut names: Vec<&str> = c.iter().map(|m| m.name.as_str()).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len(), "duplicate names");
        for m in &c {
            m.a.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
        }
    }

    #[test]
    fn corpus_spans_coverage_spectrum() {
        let c = spmv_corpus(CorpusSize::Small);
        let covers: Vec<f64> = c
            .iter()
            .map(|m| crate::sparse::stats::matrix_stats(&m.a).topk[3]) // top-8
            .collect();
        let min = covers.iter().cloned().fold(1.0, f64::min);
        let max = covers.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.999, "max top-8 coverage {max}");
        assert!(min < 0.7, "min top-8 coverage {min}");
    }

    #[test]
    fn cg_set_is_spd_shaped() {
        for m in cg_set(CorpusSize::Small) {
            m.a.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
            assert!(m.a.is_symmetric(1e-12), "{} not symmetric", m.name);
            assert!(m.a.diag().iter().all(|&d| d > 0.0), "{} diag", m.name);
        }
    }

    #[test]
    fn gmres_set_mostly_asymmetric() {
        let set = gmres_set(CorpusSize::Small);
        assert_eq!(set.len(), 15);
        let asym = set.iter().filter(|m| !m.a.is_symmetric(1e-12)).count();
        assert!(asym >= 12, "only {asym} asymmetric");
        for m in &set {
            m.a.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
        }
    }

    #[test]
    fn sizes_ordered_like_table2() {
        let set = cg_set(CorpusSize::Small);
        // first should be much smaller than last, mirroring Table II
        assert!(set[0].a.nnz() * 4 < set[14].a.nnz());
    }
}
