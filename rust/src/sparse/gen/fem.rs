//! FEM-style SPD matrices with heterogeneous material coefficients —
//! stand-ins for the structural matrices of the CG test set (bcsstk*,
//! consph, af_shell, bone010, ...). The lognormal coefficient field
//! spreads non-zero magnitudes over several binades, and the coefficient
//! contrast controls the condition number (large contrast = the hard,
//! slow-converging systems where low-precision storage stalls CG).

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::Prng;

/// 1D P1 stiffness matrix with elementwise coefficients `a_e`:
/// tridiagonal SPD, `A[i][i] = a_i + a_{i+1}`, `A[i][i+1] = -a_{i+1}`.
/// `sigma` is the lognormal spread of the coefficients (in natural log).
pub fn stiffness1d(n: usize, sigma: f64, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let coeff: Vec<f64> = (0..=n).map(|_| rng.lognormal(0.0, sigma)).collect();
    let mut coo = Coo::with_capacity(n, n, 3 * n);
    for i in 0..n {
        coo.push(i, i, coeff[i] + coeff[i + 1]);
        if i + 1 < n {
            coo.push(i, i + 1, -coeff[i + 1]);
            coo.push(i + 1, i, -coeff[i + 1]);
        }
    }
    coo.to_csr()
}

/// 2D 5-point variable-coefficient diffusion on `nx × ny`:
/// `-div(a(x) grad u)` with harmonic-mean face coefficients. SPD.
/// `contrast_log2` sets the coefficient field's spread in binades.
pub fn diffusion2d(nx: usize, ny: usize, contrast_log2: f64, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let sigma = contrast_log2 * std::f64::consts::LN_2 / 2.0;
    // cell coefficients
    let cell: Vec<f64> = (0..nx * ny).map(|_| rng.lognormal(0.0, sigma)).collect();
    let at = |i: usize, j: usize| cell[i * ny + j];
    let face = |a: f64, b: f64| 2.0 * a * b / (a + b); // harmonic mean
    let n = nx * ny;
    let idx = |i: usize, j: usize| i * ny + j;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    for i in 0..nx {
        for j in 0..ny {
            let r = idx(i, j);
            let mut diag = 0.0;
            let mut push_face = |coo: &mut Coo, c: usize, f: f64| {
                coo.push(r, c, -f);
                diag += f;
            };
            if i > 0 {
                let f = face(at(i, j), at(i - 1, j));
                push_face(&mut coo, idx(i - 1, j), f);
            }
            if i + 1 < nx {
                let f = face(at(i, j), at(i + 1, j));
                push_face(&mut coo, idx(i + 1, j), f);
            }
            if j > 0 {
                let f = face(at(i, j), at(i, j - 1));
                push_face(&mut coo, idx(i, j - 1), f);
            }
            if j + 1 < ny {
                let f = face(at(i, j), at(i, j + 1));
                push_face(&mut coo, idx(i, j + 1), f);
            }
            // Dirichlet boundary contribution keeps A nonsingular.
            let boundary_faces = [(i == 0), (i + 1 == nx), (j == 0), (j + 1 == ny)]
                .iter()
                .filter(|&&b| b)
                .count();
            diag += boundary_faces as f64 * at(i, j);
            coo.push(r, r, diag);
        }
    }
    coo.to_csr()
}

/// Shell/plate-like SPD matrix: 9-point (Moore neighborhood) stencil with
/// smoothly varying thickness — denser rows (≤ 9 nnz) akin to consph /
/// af_shell. SPD by diagonal dominance.
pub fn shell2d(nx: usize, ny: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let n = nx * ny;
    let idx = |i: usize, j: usize| i * ny + j;
    // smooth thickness field: random low-frequency cosine mix
    let (a1, a2) = (rng.range_f64(0.5, 2.0), rng.range_f64(0.5, 2.0));
    let tau = std::f64::consts::TAU;
    let (p1, p2) = (rng.range_f64(0.0, tau), rng.range_f64(0.0, tau));
    let thick = |i: usize, j: usize| {
        let x = i as f64 / nx as f64;
        let y = j as f64 / ny as f64;
        (2.0 + (a1 * (3.0 * x * std::f64::consts::TAU + p1).cos())
            + (a2 * (2.0 * y * std::f64::consts::TAU + p2).sin()))
        .exp()
    };
    let mut coo = Coo::with_capacity(n, n, 9 * n);
    for i in 0..nx {
        for j in 0..ny {
            let r = idx(i, j);
            let t0 = thick(i, j);
            let mut diag = 0.0;
            for di in -1i64..=1 {
                for dj in -1i64..=1 {
                    if di == 0 && dj == 0 {
                        continue;
                    }
                    let (ii, jj) = (i as i64 + di, j as i64 + dj);
                    if ii < 0 || jj < 0 || ii >= nx as i64 || jj >= ny as i64 {
                        continue;
                    }
                    let w = (t0 * thick(ii as usize, jj as usize)).sqrt()
                        / ((di * di + dj * dj) as f64);
                    coo.push(r, idx(ii as usize, jj as usize), -w);
                    diag += w;
                }
            }
            coo.push(r, r, diag * 1.05 + t0); // strictly dominant
        }
    }
    let a = coo.to_csr();
    // Symmetrize exactly (floating-point thick() is symmetric already,
    // but keep the guarantee under future edits).
    let t = a.transpose();
    let vals: Vec<f64> = a.vals.iter().zip(&t.vals).map(|(&x, &y)| 0.5 * (x + y)).collect();
    a.with_values(vals)
}

/// Mass-like matrix: well-conditioned SPD companion (bcsstm24-style,
/// diagonal-heavy).
pub fn mass1d(n: usize, seed: u64) -> Csr {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n);
    for i in 0..n {
        coo.push(i, i, rng.lognormal(0.0, 2.0));
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stiffness1d_spd_shape() {
        let a = stiffness1d(50, 1.0, 3);
        a.validate().unwrap();
        assert!(a.is_symmetric(1e-15));
        assert!(a.diag().iter().all(|&d| d > 0.0));
        assert_eq!(a.nnz(), 50 + 2 * 49);
    }

    #[test]
    fn diffusion2d_spd_and_dominant() {
        let a = diffusion2d(10, 10, 8.0, 7);
        a.validate().unwrap();
        assert!(a.is_symmetric(1e-12));
        // interior rows are weakly dominant (ratio exactly 1 up to
        // summation-order rounding); boundary rows strictly dominant
        assert!(a.diag_dominance() >= 1.0 - 1e-9, "dominance {}", a.diag_dominance());
    }

    #[test]
    fn diffusion_contrast_spreads_exponents() {
        let lo = crate::sparse::stats::matrix_stats(&diffusion2d(16, 16, 1.0, 5));
        let hi = crate::sparse::stats::matrix_stats(&diffusion2d(16, 16, 16.0, 5));
        assert!(hi.num_distinct_exponents > lo.num_distinct_exponents);
    }

    #[test]
    fn shell2d_symmetric_dominant() {
        let a = shell2d(12, 12, 11);
        a.validate().unwrap();
        assert!(a.is_symmetric(1e-12));
        assert!(a.diag_dominance() > 1.0);
        assert_eq!(a.max_row_nnz(), 9);
    }

    #[test]
    fn mass1d_diagonal() {
        let a = mass1d(20, 1);
        assert_eq!(a.nnz(), 20);
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(stiffness1d(30, 1.0, 9), stiffness1d(30, 1.0, 9));
        assert_ne!(stiffness1d(30, 1.0, 9), stiffness1d(30, 1.0, 10));
    }
}
