//! Synthetic matrix generators — the SuiteSparse Matrix Collection
//! stand-in (DESIGN.md §5). Each generator targets one of the matrix
//! classes the paper's test sets draw from (circuit simulation, CFD /
//! convection–diffusion, structural FEM, linear programming-ish general
//! matrices), with explicit control over the properties the GSE-SEM
//! format is sensitive to: exponent clustering (top-k coverage), value
//! magnitude spread, symmetry/definiteness, and sparsity pattern.

pub mod poisson;
pub mod fem;
pub mod circuit;
pub mod convdiff;
pub mod randmat;
pub mod corpus;

pub use corpus::{cg_set, gmres_set, spmv_corpus, CorpusSize, NamedMatrix};
