//! Compressed Sparse Row matrices — the storage format of every SpMV in
//! the paper (§III-C1): `rowptr`, `colidx` (u32, whose spare top bits the
//! GSE-SEM format borrows for exponent indexes), and `vals`.

/// 128-bit structural content digest of a [`Csr`] — the
/// content-addressed key of the coordinator's matrix registry. Computed
/// from shape, sparsity pattern, and value bits only, so two
/// structurally identical matrices digest equally no matter which `Arc`
/// or allocation holds them (unlike pointer-identity keys, which miss
/// on every fresh allocation).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct MatrixDigest([u64; 2]);

impl MatrixDigest {
    /// Stable hex rendering (no address-dependent state).
    pub fn to_hex(self) -> String {
        format!("{:016x}{:016x}", self.0[0], self.0[1])
    }
}

/// One absorb step of the digest's splitmix-style mixer: xor the word
/// in, then scramble with multiply/shift rounds. `mul` differentiates
/// the two independent streams.
#[inline]
fn mix(h: u64, w: u64, mul: u64) -> u64 {
    let mut h = h ^ w;
    h = h.wrapping_mul(mul);
    h ^= h >> 29;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^ (h >> 32)
}

/// CSR sparse matrix over f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// `rowptr[i]..rowptr[i+1]` indexes row i's entries.
    pub rowptr: Vec<usize>,
    /// Column of each non-zero (u32, like CUSP / the paper).
    pub colidx: Vec<u32>,
    /// Value of each non-zero.
    pub vals: Vec<f64>,
}

impl Csr {
    /// An empty matrix with no entries.
    pub fn empty(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, rowptr: vec![0; nrows + 1], colidx: Vec::new(), vals: Vec::new() }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        Self {
            nrows: n,
            ncols: n,
            rowptr: (0..=n).collect(),
            colidx: (0..n as u32).collect(),
            vals: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Column indexes and values of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.rowptr[i], self.rowptr[i + 1]);
        (&self.colidx[a..b], &self.vals[a..b])
    }

    /// Entry (r, c), 0 if not stored. O(log nnz(row)).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&(c as u32)) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Structural + numerical validation; used by generators and IO.
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.nrows + 1 {
            return Err("rowptr length".into());
        }
        if *self.rowptr.first().unwrap_or(&0) != 0 || *self.rowptr.last().unwrap() != self.nnz() {
            return Err("rowptr endpoints".into());
        }
        if self.colidx.len() != self.vals.len() {
            return Err("colidx/vals length mismatch".into());
        }
        for i in 0..self.nrows {
            if self.rowptr[i] > self.rowptr[i + 1] {
                return Err(format!("rowptr not monotone at {i}"));
            }
            let (cols, _) = self.row(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {i} columns not strictly sorted"));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.ncols {
                    return Err(format!("row {i} column out of range"));
                }
            }
        }
        if self.vals.iter().any(|v| !v.is_finite()) {
            return Err("non-finite value".into());
        }
        Ok(())
    }

    /// Transpose (also converts CSR<->CSC views).
    pub fn transpose(&self) -> Csr {
        let nnz = self.nnz();
        let mut rowptr = vec![0usize; self.ncols + 1];
        for &c in &self.colidx {
            rowptr[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            rowptr[i + 1] += rowptr[i];
        }
        let mut colidx = vec![0u32; nnz];
        let mut vals = vec![0f64; nnz];
        let mut next = rowptr.clone();
        for r in 0..self.nrows {
            let (cols, vs) = self.row(r);
            for (&c, &v) in cols.iter().zip(vs) {
                let slot = next[c as usize];
                next[c as usize] += 1;
                colidx[slot] = r as u32;
                vals[slot] = v;
            }
        }
        Csr { nrows: self.ncols, ncols: self.nrows, rowptr, colidx, vals }
    }

    /// Is the matrix numerically symmetric (within `tol` relative)?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let t = self.transpose();
        if t.rowptr != self.rowptr || t.colidx != self.colidx {
            return false;
        }
        self.vals
            .iter()
            .zip(&t.vals)
            .all(|(&a, &b)| (a - b).abs() <= tol * a.abs().max(b.abs()).max(1e-300))
    }

    /// Main diagonal as a dense vector.
    pub fn diag(&self) -> Vec<f64> {
        (0..self.nrows.min(self.ncols)).map(|i| self.get(i, i)).collect()
    }

    /// Strict diagonal dominance factor: min_i |a_ii| / sum_{j!=i}|a_ij|
    /// (+inf for rows with empty off-diagonal). > 1 implies dominance.
    pub fn diag_dominance(&self) -> f64 {
        let mut worst = f64::INFINITY;
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let mut diag = 0.0;
            let mut off = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                if c as usize == i {
                    diag = v.abs();
                } else {
                    off += v.abs();
                }
            }
            let f = if off == 0.0 { f64::INFINITY } else { diag / off };
            worst = worst.min(f);
        }
        worst
    }

    /// Scale rows and columns symmetrically by `d^-1/2` (Jacobi scaling).
    pub fn sym_diag_scale(&self) -> (Csr, Vec<f64>) {
        let d: Vec<f64> =
            self.diag().iter().map(|&x| if x > 0.0 { x.sqrt().recip() } else { 1.0 }).collect();
        let mut out = self.clone();
        for r in 0..self.nrows {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            for k in a..b {
                let c = self.colidx[k] as usize;
                out.vals[k] = self.vals[k] * d[r] * d[c];
            }
        }
        (out, d)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.vals.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Dense representation (tests only; guards against large n).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        assert!(self.nrows * self.ncols <= 1 << 20, "to_dense is for small matrices");
        let mut m = vec![vec![0.0; self.ncols]; self.nrows];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                m[r][c as usize] = v;
            }
        }
        m
    }

    /// Replace values (same sparsity) — used to build perturbed variants.
    pub fn with_values(&self, vals: Vec<f64>) -> Csr {
        assert_eq!(vals.len(), self.nnz());
        Csr { vals, ..self.clone() }
    }

    /// Average non-zeros per row.
    pub fn avg_row_nnz(&self) -> f64 {
        if self.nrows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.nrows as f64
        }
    }

    /// Maximum non-zeros in any row (ELL width).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.nrows).map(|i| self.rowptr[i + 1] - self.rowptr[i]).max().unwrap_or(0)
    }

    /// Content digest over shape, sparsity pattern, and value **bits**
    /// (bit-exact: NaN payloads and `-0.0` vs `+0.0` distinguish). Two
    /// independent 64-bit mixing streams give a 128-bit digest —
    /// collision-safe for non-adversarial workloads. Cost is one pass
    /// over the matrix (O(nnz)), far below any encode or solve.
    pub fn digest(&self) -> MatrixDigest {
        const M0: u64 = 0x9E37_79B9_7F4A_7C15;
        const M1: u64 = 0xD6E8_FEB8_6659_FD93;
        // arbitrary fixed seeds (pi fraction bits)
        let mut h0: u64 = 0x243F_6A88_85A3_08D3;
        let mut h1: u64 = 0x1319_8A2E_0370_7344;
        let mut feed = |w: u64| {
            h0 = mix(h0, w, M0);
            h1 = mix(h1, w.rotate_left(32), M1);
        };
        feed(self.nrows as u64);
        feed(self.ncols as u64);
        feed(self.nnz() as u64);
        for &p in &self.rowptr {
            feed(p as u64);
        }
        for &c in &self.colidx {
            feed(c as u64);
        }
        for &v in &self.vals {
            feed(v.to_bits());
        }
        MatrixDigest([h0, h1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn sample() -> Csr {
        // [ 2 1 0 ]
        // [ 0 3 0 ]
        // [ 4 0 5 ]
        let mut c = Coo::new(3, 3);
        for (r, cc, v) in [(0, 0, 2.0), (0, 1, 1.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)] {
            c.push(r, cc, v);
        }
        c.to_csr()
    }

    #[test]
    fn validate_ok_and_get() {
        let a = sample();
        a.validate().unwrap();
        assert_eq!(a.get(0, 1), 1.0);
        assert_eq!(a.get(1, 0), 0.0);
        assert_eq!(a.nnz(), 5);
    }

    #[test]
    fn validate_catches_bad_columns() {
        let mut a = sample();
        a.colidx[0] = 7; // out of range + unsorted
        assert!(a.validate().is_err());
    }

    #[test]
    fn transpose_involution() {
        let a = sample();
        let t = a.transpose();
        assert_eq!(t.get(1, 0), 1.0);
        assert_eq!(t.get(0, 2), 4.0);
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn symmetry_checks() {
        let mut c = Coo::new(2, 2);
        c.push_sym(0, 1, 3.0);
        c.push(0, 0, 1.0);
        c.push(1, 1, 1.0);
        let a = c.to_csr();
        assert!(a.is_symmetric(0.0));
        assert!(!sample().is_symmetric(1e-12));
    }

    #[test]
    fn diag_and_dominance() {
        let a = sample();
        assert_eq!(a.diag(), vec![2.0, 3.0, 5.0]);
        // row0: 2/1=2, row1: inf, row2: 5/4
        assert_eq!(a.diag_dominance(), 1.25);
    }

    #[test]
    fn identity_properties() {
        let i = Csr::identity(4);
        i.validate().unwrap();
        assert_eq!(i.nnz(), 4);
        assert!(i.is_symmetric(0.0));
        assert_eq!(i.diag(), vec![1.0; 4]);
    }

    #[test]
    fn sym_diag_scale_unitizes_diagonal() {
        let mut c = Coo::new(2, 2);
        c.push(0, 0, 4.0);
        c.push(1, 1, 9.0);
        c.push_sym(0, 1, 1.0);
        let (s, _) = c.to_csr().sym_diag_scale();
        assert!((s.get(0, 0) - 1.0).abs() < 1e-15);
        assert!((s.get(1, 1) - 1.0).abs() < 1e-15);
        assert!((s.get(0, 1) - 1.0 / 6.0).abs() < 1e-15);
    }

    #[test]
    fn ell_width_and_avg() {
        let a = sample();
        assert_eq!(a.max_row_nnz(), 2);
        assert!((a.avg_row_nnz() - 5.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn digest_is_content_addressed() {
        let a = sample();
        // clones (fresh allocations) digest identically
        assert_eq!(a.digest(), a.clone().digest());
        // any value-bit change changes the digest (even sign of zero)
        let mut vals = a.vals.clone();
        vals[0] = -vals[0];
        assert_ne!(a.digest(), a.with_values(vals).digest());
        let zeroed = a.with_values(vec![0.0; a.nnz()]);
        let negzeroed = a.with_values(vec![-0.0; a.nnz()]);
        assert_ne!(zeroed.digest(), negzeroed.digest());
        // structural changes (pattern, shape) change the digest
        let mut b = a.clone();
        b.colidx[0] = 1;
        b.colidx[1] = 2;
        assert_ne!(a.digest(), b.digest());
        assert_ne!(Csr::identity(4).digest(), Csr::identity(5).digest());
        // stable hex rendering: repeated digests render identically
        let (h1, h2) = (a.digest().to_hex(), a.clone().digest().to_hex());
        assert_eq!(h1.len(), 32);
        assert_eq!(h1, h2);
    }
}
