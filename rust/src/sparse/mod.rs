//! Sparse-matrix substrate: COO/CSR storage, MatrixMarket IO, numerical
//! statistics (Fig. 1 analyses), and the synthetic matrix generators that
//! stand in for the SuiteSparse collection (DESIGN.md §5).

pub mod coo;
pub mod csr;
pub mod mm;
pub mod stats;
pub mod gen;

pub use coo::Coo;
pub use csr::Csr;
pub use stats::MatrixStats;
