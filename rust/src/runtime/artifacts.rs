//! Artifact manifest: `artifacts/manifest.json` describes every HLO
//! module the python compile path exported — name, file, input shapes
//! and dtypes — so the rust side can validate calls before dispatching
//! to PJRT.
//!
//! The manifest is written by `python/compile/aot.py`; the parser here
//! is deliberately small (flat JSON, no external crates in this offline
//! build environment).

use crate::util::error::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One exported computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    /// HLO text file, relative to the artifacts dir
    pub file: String,
    /// input shapes, row-major
    pub inputs: Vec<Vec<usize>>,
    /// input dtypes ("f32"/"f64"/"u16"/"u32"/"i32")
    pub dtypes: Vec<String>,
    /// number of outputs in the result tuple
    pub outputs: usize,
}

/// The whole artifacts directory.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`. Returns Ok(None) if the directory or
    /// manifest is missing (artifacts not built — callers degrade
    /// gracefully, e.g. parity tests skip).
    pub fn load(dir: &Path) -> Result<Option<Manifest>> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let entries = parse_manifest(&text)?;
        Ok(Some(Manifest { dir: dir.to_path_buf(), entries }))
    }

    /// Default artifacts dir: `$GSEM_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("GSEM_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            PathBuf::from("artifacts")
        })
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

/// Minimal JSON parsing for the fixed manifest schema:
/// `{"kernels": [{"name": .., "file": .., "inputs": [[..],..],
///   "dtypes": [..], "outputs": n}, ...]}`
fn parse_manifest(text: &str) -> Result<BTreeMap<String, ManifestEntry>> {
    let mut out = BTreeMap::new();
    let v = json::parse(text)?;
    let kernels = v.get("kernels").context("manifest missing 'kernels'")?;
    let arr = kernels.as_array().context("'kernels' must be an array")?;
    for k in arr {
        let name = k
            .get("name")
            .and_then(|x| x.as_str())
            .context("kernel missing name")?
            .to_string();
        let file = k
            .get("file")
            .and_then(|x| x.as_str())
            .context("kernel missing file")?
            .to_string();
        let inputs: Vec<Vec<usize>> = k
            .get("inputs")
            .and_then(|x| x.as_array())
            .context("kernel missing inputs")?
            .iter()
            .map(|shape| {
                shape
                    .as_array()
                    .context("shape must be array")
                    .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
            })
            .collect::<Result<_>>()?;
        let dtypes: Vec<String> = k
            .get("dtypes")
            .and_then(|x| x.as_array())
            .context("kernel missing dtypes")?
            .iter()
            .filter_map(|d| d.as_str().map(|s| s.to_string()))
            .collect();
        let outputs = k.get("outputs").and_then(|x| x.as_usize()).unwrap_or(1);
        if dtypes.len() != inputs.len() {
            bail!("kernel {name}: dtypes/inputs arity mismatch");
        }
        out.insert(name.clone(), ManifestEntry { name, file, inputs, dtypes, outputs });
    }
    Ok(out)
}

/// A tiny recursive-descent JSON parser (objects, arrays, strings,
/// numbers, bools, null) — enough for the manifest, no external crates.
pub mod json {
    use crate::util::error::{bail, Result};
    use std::collections::BTreeMap;

    #[derive(Clone, Debug, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(BTreeMap<String, Value>),
    }

    impl Value {
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(m) => m.get(key),
                _ => None,
            }
        }

        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }

        pub fn as_array(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(a) => Some(a),
                _ => None,
            }
        }

        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }

        pub fn as_usize(&self) -> Option<usize> {
            self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
        }
    }

    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at {}", p.i);
        }
        Ok(v)
    }

    struct Parser<'a> {
        b: &'a [u8],
        i: usize,
    }

    impl<'a> Parser<'a> {
        fn ws(&mut self) {
            while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
                self.i += 1;
            }
        }

        fn peek(&self) -> Option<u8> {
            self.b.get(self.i).copied()
        }

        fn eat(&mut self, c: u8) -> Result<()> {
            if self.peek() == Some(c) {
                self.i += 1;
                Ok(())
            } else {
                bail!("expected '{}' at {}", c as char, self.i)
            }
        }

        fn value(&mut self) -> Result<Value> {
            self.ws();
            match self.peek() {
                Some(b'{') => self.object(),
                Some(b'[') => self.array(),
                Some(b'"') => Ok(Value::Str(self.string()?)),
                Some(b't') => self.lit("true", Value::Bool(true)),
                Some(b'f') => self.lit("false", Value::Bool(false)),
                Some(b'n') => self.lit("null", Value::Null),
                Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
                other => bail!("unexpected {:?} at {}", other.map(|c| c as char), self.i),
            }
        }

        fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
            if self.b[self.i..].starts_with(s.as_bytes()) {
                self.i += s.len();
                Ok(v)
            } else {
                bail!("bad literal at {}", self.i)
            }
        }

        fn object(&mut self) -> Result<Value> {
            self.eat(b'{')?;
            let mut m = BTreeMap::new();
            self.ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                return Ok(Value::Obj(m));
            }
            loop {
                self.ws();
                let k = self.string()?;
                self.ws();
                self.eat(b':')?;
                let v = self.value()?;
                m.insert(k, v);
                self.ws();
                match self.peek() {
                    Some(b',') => {
                        self.i += 1;
                    }
                    Some(b'}') => {
                        self.i += 1;
                        break;
                    }
                    _ => bail!("expected ',' or '}}' at {}", self.i),
                }
            }
            Ok(Value::Obj(m))
        }

        fn array(&mut self) -> Result<Value> {
            self.eat(b'[')?;
            let mut a = Vec::new();
            self.ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                return Ok(Value::Arr(a));
            }
            loop {
                a.push(self.value()?);
                self.ws();
                match self.peek() {
                    Some(b',') => {
                        self.i += 1;
                    }
                    Some(b']') => {
                        self.i += 1;
                        break;
                    }
                    _ => bail!("expected ',' or ']' at {}", self.i),
                }
            }
            Ok(Value::Arr(a))
        }

        fn string(&mut self) -> Result<String> {
            self.eat(b'"')?;
            let mut s = String::new();
            loop {
                match self.peek() {
                    Some(b'"') => {
                        self.i += 1;
                        break;
                    }
                    Some(b'\\') => {
                        self.i += 1;
                        match self.peek() {
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'u') => {
                                // \uXXXX
                                let hex = std::str::from_utf8(
                                    &self.b[self.i + 1..self.i + 5],
                                )?;
                                let cp = u32::from_str_radix(hex, 16)?;
                                s.push(char::from_u32(cp).unwrap_or('?'));
                                self.i += 4;
                            }
                            other => bail!("bad escape {other:?}"),
                        }
                        self.i += 1;
                    }
                    Some(c) => {
                        // copy raw utf8 bytes
                        let start = self.i;
                        let len = utf8_len(c);
                        s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                        self.i += len;
                    }
                    None => bail!("unterminated string"),
                }
            }
            Ok(s)
        }

        fn number(&mut self) -> Result<Value> {
            let start = self.i;
            if self.peek() == Some(b'-') {
                self.i += 1;
            }
            while let Some(c) = self.peek() {
                if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                    self.i += 1;
                } else {
                    break;
                }
            }
            let s = std::str::from_utf8(&self.b[start..self.i])?;
            Ok(Value::Num(s.parse()?))
        }
    }

    fn utf8_len(first: u8) -> usize {
        match first {
            0x00..=0x7F => 1,
            0xC0..=0xDF => 2,
            0xE0..=0xEF => 3,
            _ => 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "kernels": [
        {"name": "decode_head", "file": "decode_head.hlo.txt",
         "inputs": [[1024], [64]], "dtypes": ["u16", "f64"], "outputs": 1},
        {"name": "spmv_ell", "file": "spmv_ell.hlo.txt",
         "inputs": [[256, 16], [256, 16], [64], [256]],
         "dtypes": ["u16", "u32", "f64", "f64"], "outputs": 2}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = parse_manifest(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let d = &m["decode_head"];
        assert_eq!(d.inputs, vec![vec![1024], vec![64]]);
        assert_eq!(d.dtypes, vec!["u16", "f64"]);
        assert_eq!(d.outputs, 1);
        assert_eq!(m["spmv_ell"].outputs, 2);
    }

    #[test]
    fn missing_manifest_is_none() {
        let m = Manifest::load(Path::new("/nonexistent/dir")).unwrap();
        assert!(m.is_none());
    }

    #[test]
    fn load_from_disk_roundtrip() {
        let dir = std::env::temp_dir().join("gsem_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap().unwrap();
        assert!(m.get("decode_head").is_some());
        assert!(m.get("nope").is_none());
        assert!(m.hlo_path(m.get("spmv_ell").unwrap()).ends_with("spmv_ell.hlo.txt"));
    }

    #[test]
    fn json_parser_handles_nesting_and_escapes() {
        let v = json::parse(r#"{"a": [1, 2.5, "x\ny", true, null], "b": {"c": -3}}"#).unwrap();
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_f64(), Some(-3.0));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[1].as_usize(), None); // 2.5 is not usize
    }

    #[test]
    fn json_parser_rejects_garbage() {
        assert!(json::parse("{").is_err());
        assert!(json::parse("[1,]").is_err());
        assert!(json::parse("hello").is_err());
        assert!(json::parse("{} extra").is_err());
    }

    #[test]
    fn arity_mismatch_rejected() {
        let bad = r#"{"kernels": [{"name":"x","file":"f","inputs":[[1]],"dtypes":["f32","f64"],"outputs":1}]}"#;
        assert!(parse_manifest(bad).is_err());
    }
}
