//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and runs
//! them on the XLA CPU client from the rust hot path. Python is never on
//! the request path: after `make artifacts` the binary is self-contained.
//!
//! Interchange is HLO *text*, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod executor;

pub use artifacts::{Manifest, ManifestEntry};
pub use executor::{Engine, LoadedKernel};
