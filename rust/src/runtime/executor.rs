//! PJRT executor front-end: manifest-validated kernel dispatch for the
//! AOT-compiled JAX/Pallas artifacts.
//!
//! This build carries **no PJRT backend**: the offline environment has no
//! `xla` crate to link against, so the executor validates manifests,
//! argument arity, shapes and dtypes exactly like the real path, and
//! reports [`Engine::backend_available`]` == false` instead of executing.
//! Callers (the `kernels` CLI subcommand, the AOT parity tests, the e2e
//! example) check that flag and skip cleanly — the same graceful
//! degradation as unbuilt artifacts. Dropping a PJRT-backed
//! implementation in later only has to replace [`LoadedKernel::run_f64`]
//! and [`Engine::compile_entry`].

use super::artifacts::{Manifest, ManifestEntry};
use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A host-side argument for a kernel call.
pub enum Arg<'a> {
    F64(&'a [f64]),
    F32(&'a [f32]),
    U32(&'a [u32]),
    I32(&'a [i32]),
}

impl Arg<'_> {
    fn dtype(&self) -> &'static str {
        match self {
            Arg::F64(_) => "f64",
            Arg::F32(_) => "f32",
            Arg::U32(_) => "u32",
            Arg::I32(_) => "i32",
        }
    }

    fn len(&self) -> usize {
        match self {
            Arg::F64(x) => x.len(),
            Arg::F32(x) => x.len(),
            Arg::U32(x) => x.len(),
            Arg::I32(x) => x.len(),
        }
    }
}

/// A manifest-validated artifact, ready to dispatch (once a backend is
/// linked in).
pub struct LoadedKernel {
    pub entry: ManifestEntry,
}

impl LoadedKernel {
    /// Validate arguments against the manifest entry, then execute.
    /// Without a PJRT backend the validation still runs (so arity/shape
    /// bugs surface in tests) and execution reports an error.
    pub fn run_f64(&self, args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        self.validate_args(args)?;
        bail!(
            "kernel {}: no PJRT backend linked in this build (see runtime::executor docs)",
            self.entry.name
        )
    }

    /// The argument checks shared by every backend.
    pub fn validate_args(&self, args: &[Arg]) -> Result<()> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "kernel {}: expected {} args, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            );
        }
        for (i, a) in args.iter().enumerate() {
            let want: usize = self.entry.inputs[i].iter().product();
            if a.len() != want {
                bail!(
                    "kernel {} arg {i}: expected {} elements ({:?}), got {}",
                    self.entry.name,
                    want,
                    self.entry.inputs[i],
                    a.len()
                );
            }
            if a.dtype() != self.entry.dtypes[i] {
                bail!(
                    "kernel {} arg {i}: expected dtype {}, got {}",
                    self.entry.name,
                    self.entry.dtypes[i],
                    a.dtype()
                );
            }
        }
        Ok(())
    }
}

/// The engine: manifest + (when a backend exists) compiled-kernel cache.
pub struct Engine {
    pub manifest: Manifest,
    cache: HashMap<String, LoadedKernel>,
}

impl Engine {
    /// Load from an artifacts dir. `Ok(None)` when artifacts are absent
    /// (not built yet) so callers can skip gracefully.
    pub fn load(dir: &Path) -> Result<Option<Engine>> {
        let Some(manifest) = Manifest::load(dir)? else {
            return Ok(None);
        };
        Ok(Some(Engine { manifest, cache: HashMap::new() }))
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<Option<Engine>> {
        Self::load(&Manifest::default_dir())
    }

    /// Whether kernels can actually execute in this build.
    pub fn backend_available(&self) -> bool {
        false
    }

    pub fn platform(&self) -> String {
        "stub (no PJRT backend)".to_string()
    }

    /// Validate (once) and return a kernel by manifest name. Checks the
    /// manifest entry and that its HLO file exists on disk — the part of
    /// `compile` that does not need XLA.
    pub fn kernel(&mut self, name: &str) -> Result<&LoadedKernel> {
        if !self.cache.contains_key(name) {
            let k = self.compile_entry(name)?;
            self.cache.insert(name.to_string(), k);
        }
        Ok(self.cache.get(name).unwrap())
    }

    fn compile_entry(&self, name: &str) -> Result<LoadedKernel> {
        let entry = self
            .manifest
            .get(name)
            .with_context(|| format!("kernel '{name}' not in manifest"))?
            .clone();
        let path = self.manifest.hlo_path(&entry);
        if !path.exists() {
            bail!("kernel '{name}': HLO file {} missing", path.display());
        }
        Ok(LoadedKernel { entry })
    }

    /// Names of every artifact available.
    pub fn kernel_names(&self) -> Vec<String> {
        self.manifest.entries.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "kernels": [
        {"name": "decode_head", "file": "decode_head.hlo.txt",
         "inputs": [[4], [2]], "dtypes": ["u32", "f64"], "outputs": 1}
      ]
    }"#;

    /// Per-test directory: tests run in parallel and fs::write is not
    /// atomic, so sharing one manifest path would be flaky.
    fn stub_dir(test: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("gsem_executor_{test}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        std::fs::write(dir.join("decode_head.hlo.txt"), "HloModule decode_head\n").unwrap();
        dir
    }

    #[test]
    fn arg_metadata() {
        let xs = [1.0f64, 2.0];
        let a = Arg::F64(&xs);
        assert_eq!(a.dtype(), "f64");
        assert_eq!(a.len(), 2);
        let u = [1u32];
        assert_eq!(Arg::U32(&u).dtype(), "u32");
    }

    #[test]
    fn missing_artifacts_load_as_none_not_panic() {
        // the graceful-degrade contract: kernels/AOT-parity paths skip
        let empty = std::env::temp_dir().join("gsem_executor_empty");
        std::fs::create_dir_all(&empty).unwrap();
        let _ = std::fs::remove_file(empty.join("manifest.json"));
        assert!(Engine::load(&empty).unwrap().is_none());
        assert!(Engine::load(Path::new("/nonexistent/gsem")).unwrap().is_none());
    }

    #[test]
    fn stub_engine_validates_and_reports_no_backend() {
        let mut e = Engine::load(&stub_dir("validate")).unwrap().unwrap();
        assert!(!e.backend_available());
        assert_eq!(e.kernel_names(), vec!["decode_head".to_string()]);
        let k = e.kernel("decode_head").unwrap();
        // arity mismatch caught before backend dispatch
        assert!(k.run_f64(&[]).is_err());
        // correct args still cannot execute without a backend
        let u = [1u32, 2, 3, 4];
        let s = [1.0f64, 2.0];
        let err = k.run_f64(&[Arg::U32(&u), Arg::F64(&s)]).unwrap_err();
        assert!(format!("{err}").contains("no PJRT backend"), "{err}");
        // shape/dtype mismatches reported as such
        let bad = k.validate_args(&[Arg::F64(&s), Arg::F64(&s)]).unwrap_err();
        assert!(format!("{bad}").contains("expected"), "{bad}");
    }

    #[test]
    fn unknown_kernel_and_missing_hlo_are_errors() {
        let mut e = Engine::load(&stub_dir("unknown")).unwrap().unwrap();
        assert!(e.kernel("nope").is_err());
    }
}
