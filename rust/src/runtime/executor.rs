//! PJRT executor: compile HLO-text artifacts once, cache the loaded
//! executables, execute with concrete buffers from the solver hot path.
//!
//! The published `xla` crate exposes Literal constructors for
//! i32/i64/u32/u64/f32/f64 — u16 head planes are widened to u32 on the
//! boundary (the kernels mask back to 16 bits). This path exists for
//! cross-layer parity and the end-to-end demo, not for peak traffic.

use super::artifacts::{Manifest, ManifestEntry};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A host-side argument for a kernel call.
pub enum Arg<'a> {
    F64(&'a [f64]),
    F32(&'a [f32]),
    U32(&'a [u32]),
    I32(&'a [i32]),
}

impl<'a> Arg<'a> {
    fn dtype(&self) -> &'static str {
        match self {
            Arg::F64(_) => "f64",
            Arg::F32(_) => "f32",
            Arg::U32(_) => "u32",
            Arg::I32(_) => "i32",
        }
    }

    fn len(&self) -> usize {
        match self {
            Arg::F64(x) => x.len(),
            Arg::F32(x) => x.len(),
            Arg::U32(x) => x.len(),
            Arg::I32(x) => x.len(),
        }
    }

    fn to_literal(&self, dims: &[usize]) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Arg::F64(x) => xla::Literal::vec1(x),
            Arg::F32(x) => xla::Literal::vec1(x),
            Arg::U32(x) => xla::Literal::vec1(x),
            Arg::I32(x) => xla::Literal::vec1(x),
        };
        Ok(lit.reshape(&dims_i64)?)
    }
}

/// A compiled, ready-to-run artifact.
pub struct LoadedKernel {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedKernel {
    /// Execute with validated arguments; returns the output tuple as
    /// f64 vectors (all exported kernels produce f64 outputs).
    pub fn run_f64(&self, args: &[Arg]) -> Result<Vec<Vec<f64>>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "kernel {}: expected {} args, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            let want: usize = self.entry.inputs[i].iter().product();
            if a.len() != want {
                bail!(
                    "kernel {} arg {i}: expected {} elements ({:?}), got {}",
                    self.entry.name,
                    want,
                    self.entry.inputs[i],
                    a.len()
                );
            }
            if a.dtype() != self.entry.dtypes[i] {
                bail!(
                    "kernel {} arg {i}: expected dtype {}, got {}",
                    self.entry.name,
                    self.entry.dtypes[i],
                    a.dtype()
                );
            }
            literals.push(a.to_literal(&self.entry.inputs[i])?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // jax lowers with return_tuple=True: unwrap the tuple.
        let outs = result.to_tuple()?;
        let mut vecs = Vec::with_capacity(outs.len());
        for o in outs {
            vecs.push(o.to_vec::<f64>()?);
        }
        Ok(vecs)
    }
}

/// The PJRT engine: one CPU client + compiled-kernel cache.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, LoadedKernel>,
}

impl Engine {
    /// Load from an artifacts dir. `Ok(None)` when artifacts are absent
    /// (not built yet) so callers can skip gracefully.
    pub fn load(dir: &Path) -> Result<Option<Engine>> {
        let Some(manifest) = Manifest::load(dir)? else {
            return Ok(None);
        };
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Some(Engine { manifest, client, cache: HashMap::new() }))
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<Option<Engine>> {
        Self::load(&Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return a kernel by manifest name.
    pub fn kernel(&mut self, name: &str) -> Result<&LoadedKernel> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .get(name)
                .with_context(|| format!("kernel '{name}' not in manifest"))?
                .clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("XLA compile of '{name}'"))?;
            self.cache.insert(name.to_string(), LoadedKernel { entry, exe });
        }
        Ok(self.cache.get(name).unwrap())
    }

    /// Names of every artifact available.
    pub fn kernel_names(&self) -> Vec<String> {
        self.manifest.entries.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts` to have run; they skip (and
    /// say so) otherwise, so `cargo test` stays green pre-build.
    fn engine() -> Option<Engine> {
        match Engine::load(&Manifest::default_dir()) {
            Ok(e) => e,
            Err(err) => panic!("artifact load failed: {err:#}"),
        }
    }

    #[test]
    fn arg_metadata() {
        let xs = [1.0f64, 2.0];
        let a = Arg::F64(&xs);
        assert_eq!(a.dtype(), "f64");
        assert_eq!(a.len(), 2);
        let u = [1u32];
        assert_eq!(Arg::U32(&u).dtype(), "u32");
    }

    #[test]
    fn engine_loads_and_lists_kernels() {
        let Some(mut e) = engine() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        assert_eq!(e.platform(), "cpu");
        let names = e.kernel_names();
        assert!(!names.is_empty());
        // every manifest entry must compile
        for n in names {
            e.kernel(&n).unwrap_or_else(|err| panic!("{n}: {err:#}"));
        }
    }

    #[test]
    fn run_rejects_bad_arity_and_shapes() {
        let Some(mut e) = engine() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let names = e.kernel_names();
        let k = e.kernel(&names[0]).unwrap();
        assert!(k.run_f64(&[]).is_err());
    }
}
