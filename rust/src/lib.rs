//! # gsem — Group-Shared-Exponent mixed-precision iterative solvers
//!
//! Reproduction of *"Precision-Aware Iterative Algorithms Based on
//! Group-Shared Exponents of Floating-Point Numbers"* (Gao, Shen, Zhang,
//! Ji, Huang — 2024).
//!
//! The library is organised bottom-up:
//!
//! * [`util`] — PRNG, statistics, timing, bit manipulation, a tiny
//!   property-testing harness and a bench harness (offline substitutes for
//!   `rand`/`proptest`/`criterion`, which are not available in this build
//!   environment).
//! * [`formats`] — IEEE-754 bit-level tools, software-simulated FP16 /
//!   BF16 / FP8 / TF32 minifloats, and the paper's contribution: the
//!   **GSE-SEM** format (group-shared exponents + sign/exponent-index/
//!   mantissa with segmented head/tail1/tail2 storage).
//! * [`sparse`] — COO/CSR matrices, MatrixMarket IO, and synthetic matrix
//!   generators standing in for the SuiteSparse collection.
//! * [`spmv`] — SpMV operators for every storage format, including the
//!   three-precision GSE-SEM SpMV, plus a memory-traffic roofline model
//!   used to translate CPU measurements into the paper's V100 setting.
//! * [`solvers`] — CG, restarted GMRES and BiCGSTAB, each single- and
//!   multi-RHS (lockstep block solves, bitwise identical per column to
//!   single dispatch), iterative refinement, and the paper's **stepped
//!   mixed-precision controller** (RSD / nDec / relDec switching
//!   conditions), generic over precision ladders (zero-copy GSE-SEM
//!   tags or the copy-based fp32→fp64 baseline) — including a batched
//!   stepped mode sharing one ladder across per-column controllers.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`).
//! * [`coordinator`] — the L3 serving layer: a long-lived
//!   `SolverService` (bounded windowed intake that merges staggered
//!   same-matrix requests — CG, GMRES, BiCGSTAB, fixed-format or
//!   stepped — into multi-RHS block solves, with admission-control
//!   load-shedding, per-ticket deadlines/priorities and cancellation
//!   behind the typed `ServiceError` surface), a sharded
//!   content-addressed operator registry with per-key build latches,
//!   LRU byte-budget eviction and disk spill/restore, the `SolverPool`
//!   batch wrapper, metrics with machine-readable snapshots, and the
//!   experiment-suite / trace-replay / soak CLI.

pub mod util;
pub mod formats;
pub mod sparse;
pub mod spmv;
pub mod solvers;
pub mod runtime;
pub mod coordinator;

pub use formats::gse::GseTable;
pub use formats::Precision;
pub use formats::SemVector;
pub use sparse::csr::Csr;
pub use spmv::GseCsr;
