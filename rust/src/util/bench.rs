//! Minimal benchmark harness (criterion substitute — see DESIGN.md §5).
//!
//! Each `[[bench]]` target with `harness = false` builds a `BenchSuite`,
//! registers closures, and calls `run()`. The harness does warmup, picks
//! an iteration count targeting a fixed measurement window, and reports
//! median / p5 / p95 wall time. Results can also be dumped as CSV into
//! `results/` so EXPERIMENTS.md can reference them.

use super::stats::{median, percentile};
use super::timer::Timer;

/// One measured sample set for a named benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration: median, p5, p95.
    pub median_s: f64,
    pub p5_s: f64,
    pub p95_s: f64,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median_s
    }
}

/// Options controlling the measurement loop.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Warmup time budget in seconds.
    pub warmup_s: f64,
    /// Measurement time budget in seconds.
    pub measure_s: f64,
    /// Number of samples to split the measurement budget into.
    pub samples: usize,
    /// Hard cap on iterations per sample (for very fast bodies).
    pub max_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup_s: 0.3, measure_s: 1.0, samples: 12, max_iters: 1 << 22 }
    }
}

/// Fast-mode override used by CI / `make test`: honors GSEM_BENCH_FAST to
/// shrink budgets so every bench binary still exercises its full code
/// path quickly.
pub fn default_opts() -> BenchOpts {
    if std::env::var("GSEM_BENCH_FAST").is_ok() {
        BenchOpts { warmup_s: 0.02, measure_s: 0.08, samples: 4, max_iters: 1 << 18 }
    } else {
        BenchOpts::default()
    }
}

/// Measure a closure under the given options. The closure should return
/// some value dependent on its work; it is passed through `black_box` to
/// keep the optimizer honest.
pub fn measure<T>(opts: &BenchOpts, mut body: impl FnMut() -> T) -> (f64, f64, f64, usize, usize) {
    // Warmup + calibration: figure out iterations per sample.
    let t = Timer::start();
    let mut calib_iters = 0usize;
    while t.elapsed_s() < opts.warmup_s {
        std::hint::black_box(body());
        calib_iters += 1;
        if calib_iters >= opts.max_iters {
            break;
        }
    }
    let per_iter = (t.elapsed_s() / calib_iters.max(1) as f64).max(1e-9);
    let budget_per_sample = opts.measure_s / opts.samples as f64;
    let iters = ((budget_per_sample / per_iter).ceil() as usize).clamp(1, opts.max_iters);

    let mut samples = Vec::with_capacity(opts.samples);
    for _ in 0..opts.samples {
        let st = Timer::start();
        for _ in 0..iters {
            std::hint::black_box(body());
        }
        samples.push(st.elapsed_s() / iters as f64);
    }
    (
        median(&samples),
        percentile(&samples, 5.0),
        percentile(&samples, 95.0),
        opts.samples,
        iters,
    )
}

/// Named collection of benchmarks with shared options.
pub struct BenchSuite {
    pub title: String,
    pub opts: BenchOpts,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), opts: default_opts(), results: Vec::new() }
    }

    /// Run one benchmark and record + print its result.
    pub fn bench<T>(&mut self, name: &str, body: impl FnMut() -> T) -> BenchResult {
        let (med, p5, p95, samples, iters) = measure(&self.opts, body);
        let r = BenchResult {
            name: name.to_string(),
            median_s: med,
            p5_s: p5,
            p95_s: p95,
            samples,
            iters_per_sample: iters,
        };
        eprintln!(
            "  {:<44} {:>12} [{} .. {}]  ({} samples x {} iters)",
            r.name,
            fmt_time(r.median_s),
            fmt_time(r.p5_s),
            fmt_time(r.p95_s),
            samples,
            iters
        );
        self.results.push(r.clone());
        r
    }

    /// Look up a previous result by name.
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }
}

/// Human-readable time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_positive_time() {
        let opts = BenchOpts { warmup_s: 0.01, measure_s: 0.02, samples: 3, max_iters: 1000 };
        let mut acc = 0u64;
        let (med, p5, p95, samples, iters) = measure(&opts, || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert!(med > 0.0 && p5 > 0.0 && p95 >= p5);
        assert_eq!(samples, 3);
        assert!(iters >= 1);
    }

    #[test]
    fn suite_records_results() {
        let mut s = BenchSuite::new("t");
        s.opts = BenchOpts { warmup_s: 0.005, measure_s: 0.01, samples: 2, max_iters: 100 };
        s.bench("a", || 1 + 1);
        assert!(s.get("a").is_some());
        assert!(s.get("b").is_none());
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
