//! Tiny CSV writer for bench outputs (`results/*.csv`), so EXPERIMENTS.md
//! numbers are regenerable and diffable.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// A CSV file under the repo-level `results/` directory.
pub struct CsvWriter {
    path: PathBuf,
    buf: String,
    cols: usize,
}

/// Resolve the results directory (env override GSEM_RESULTS_DIR, default
/// `results/` under the current directory) and create it.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("GSEM_RESULTS_DIR").unwrap_or_else(|_| "results".to_string());
    let p = PathBuf::from(dir);
    let _ = fs::create_dir_all(&p);
    p
}

impl CsvWriter {
    /// Create a writer for `results/<name>.csv` with the given header.
    pub fn create(name: &str, header: &[&str]) -> std::io::Result<Self> {
        let path = results_dir().join(format!("{name}.csv"));
        let mut w = Self { path, buf: String::new(), cols: header.len() };
        w.raw_row(header);
        Ok(w)
    }

    fn raw_row<S: AsRef<str>>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.cols, "csv arity mismatch");
        let line: Vec<String> = cells.iter().map(|c| escape(c.as_ref())).collect();
        self.buf.push_str(&line.join(","));
        self.buf.push('\n');
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) {
        self.raw_row(cells);
    }

    /// Flush to disk; returns the written path.
    pub fn finish(mut self) -> std::io::Result<PathBuf> {
        let mut f = fs::File::create(&self.path)?;
        f.write_all(self.buf.as_bytes())?;
        self.buf.clear(); // Drop must not rewrite the file
        Ok(self.path.clone())
    }
}

fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// One-shot helper: write a full table at once.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<PathBuf> {
    let mut w = CsvWriter::create(name, header)?;
    for r in rows {
        w.row(r);
    }
    w.finish()
}

/// Check path helper for tests.
pub fn csv_path(name: &str) -> PathBuf {
    results_dir().join(format!("{name}.csv"))
}

impl Drop for CsvWriter {
    fn drop(&mut self) {
        // Best-effort flush if finish() was not called.
        if !self.buf.is_empty() {
            if let Ok(mut f) = fs::File::create(&self.path) {
                let _ = f.write_all(self.buf.as_bytes());
            }
        }
    }
}

/// Allow inspecting the path before finish (used in tests).
impl CsvWriter {
    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        std::env::set_var("GSEM_RESULTS_DIR", "/tmp/gsem_test_results");
        let mut w = CsvWriter::create("unit_csv", &["a", "b"]).unwrap();
        w.row(&["x,y", "plain"]);
        let p = w.finish().unwrap();
        let content = fs::read_to_string(p).unwrap();
        assert!(content.starts_with("a,b\n"));
        assert!(content.contains("\"x,y\",plain"));
        std::env::remove_var("GSEM_RESULTS_DIR");
    }

    #[test]
    fn one_shot_write() {
        std::env::set_var("GSEM_RESULTS_DIR", "/tmp/gsem_test_results");
        let p =
            write_csv("unit_csv2", &["h"], &[vec!["1".to_string()], vec!["2".to_string()]])
                .unwrap();
        let content = fs::read_to_string(p).unwrap();
        assert_eq!(content, "h\n1\n2\n");
        std::env::remove_var("GSEM_RESULTS_DIR");
    }
}
