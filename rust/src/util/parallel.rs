//! Shared chunk-parallel execution helpers.
//!
//! Every parallel hot path in the crate used to hand-roll the same
//! `std::thread::scope` pattern (the FP64 SpMV, the coordinator's worker
//! pool, the metrics stress test). This module is the single home for
//! that machinery:
//!
//! * [`default_workers`] — the configurable worker count
//!   (`GSEM_WORKERS` env override, else the machine's parallelism);
//! * [`balance_by_weight`] — partition `0..n` into contiguous ranges of
//!   roughly equal total weight (nnz-balanced row chunks for SpMV);
//! * [`for_each_disjoint`] — run per-chunk work over disjoint mutable
//!   slices of one output buffer on scoped threads;
//! * [`for_each_disjoint_cols`] — the multi-RHS variant: per-chunk work
//!   over the matching row range of every column of a column-major
//!   buffer (the batched SpMV output layout);
//! * [`run_queue`] — a fixed-size worker pool draining a job queue,
//!   results returned in submission order;
//! * [`broadcast`] — run a closure once per worker (stress tests).
//!
//! Determinism contract: chunk workers compute each output element with
//! exactly the serial per-element code, so results are **bit-for-bit
//! identical** to the serial path for every worker count (each row's
//! dot product is accumulated by a single thread in the serial order).

use std::ops::Range;
use std::sync::mpsc;
use std::sync::Mutex;

/// Worker count: `GSEM_WORKERS` if set (>= 1), else
/// `std::thread::available_parallelism()`, else 1.
pub fn default_workers() -> usize {
    let env = std::env::var("GSEM_WORKERS").ok().and_then(|v| v.parse::<usize>().ok());
    if let Some(n) = env {
        return n.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Partition `0..n` into at most `parts` contiguous ranges whose total
/// `weight(i)` is roughly balanced. Every index is covered exactly once;
/// ranges are returned in ascending order. `parts` is clamped to
/// `[1, max(n, 1)]`.
pub fn balance_by_weight(
    n: usize,
    parts: usize,
    weight: impl Fn(usize) -> usize,
) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(n.max(1));
    let total: usize = (0..n).map(&weight).sum();
    let target = total.div_ceil(parts).max(1);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    for i in 0..n {
        acc += weight(i);
        if acc >= target && out.len() + 1 < parts {
            out.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    out.push(start..n);
    out
}

/// Split `out` along `chunks` (contiguous, ascending, starting at 0 and
/// covering `out.len()`) and run `work(chunk, sub_slice)` for each chunk
/// on scoped threads. With a single chunk the work runs on the calling
/// thread — the serial fast path.
pub fn for_each_disjoint<T, F>(out: &mut [T], chunks: &[Range<usize>], work: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    debug_assert!(chunks.first().map(|c| c.start == 0).unwrap_or(true));
    debug_assert!(chunks.windows(2).all(|w| w[0].end == w[1].start));
    if chunks.len() <= 1 {
        if let Some(ch) = chunks.first() {
            work(ch.clone(), out);
        }
        return;
    }
    let mut slices: Vec<(Range<usize>, &mut [T])> = Vec::with_capacity(chunks.len());
    let mut rest = out;
    let mut cursor = 0usize;
    for ch in chunks {
        // mem::take sidesteps E0506: the loan on `*rest` must outlive
        // the pushed sub-slice, which would forbid reassigning `rest`.
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(ch.end - cursor);
        cursor = ch.end;
        slices.push((ch.clone(), head));
        rest = tail;
    }
    let work = &work;
    std::thread::scope(|s| {
        for (ch, ys) in slices {
            s.spawn(move || work(ch, ys));
        }
    });
}

/// Split a column-major `out` (columns of `col_len` elements each)
/// along row `chunks` and run `work(chunk, cols)` per chunk on scoped
/// threads, where `cols[j]` is column `j` restricted to the chunk's
/// rows. This is the multi-RHS sibling of [`for_each_disjoint`]: the
/// batched SpMV kernels partition rows exactly like the single-vector
/// path but must write one output element per (row, column) pair.
/// With a single chunk the work runs on the calling thread.
pub fn for_each_disjoint_cols<T, F>(
    out: &mut [T],
    col_len: usize,
    chunks: &[Range<usize>],
    work: F,
) where
    T: Send,
    F: Fn(Range<usize>, &mut [&mut [T]]) + Sync,
{
    debug_assert!(col_len == 0 || out.len() % col_len == 0);
    debug_assert!(chunks.first().map(|c| c.start == 0).unwrap_or(true));
    debug_assert!(chunks.windows(2).all(|w| w[0].end == w[1].start));
    debug_assert!(chunks.last().map(|c| c.end == col_len).unwrap_or(true));
    let ncols = if col_len == 0 {
        0
    } else {
        out.len() / col_len
    };
    if chunks.len() <= 1 {
        if let Some(ch) = chunks.first() {
            let mut cols: Vec<&mut [T]> = Vec::with_capacity(ncols);
            let mut rest = out;
            for _ in 0..ncols {
                let (col, tail) = std::mem::take(&mut rest).split_at_mut(col_len);
                rest = tail;
                let (_, upper) = col.split_at_mut(ch.start);
                let (sub, _) = upper.split_at_mut(ch.end - ch.start);
                cols.push(sub);
            }
            work(ch.clone(), &mut cols);
        }
        return;
    }
    let mut per_chunk: Vec<Vec<&mut [T]>> =
        chunks.iter().map(|_| Vec::with_capacity(ncols)).collect();
    let mut rest = out;
    for _ in 0..ncols {
        // same mem::take borrow-split as for_each_disjoint, applied per
        // column: carve each column into its per-chunk sub-slices
        let (mut col, tail) = std::mem::take(&mut rest).split_at_mut(col_len);
        rest = tail;
        let mut cursor = 0usize;
        for (w, ch) in chunks.iter().enumerate() {
            let (head, t) = std::mem::take(&mut col).split_at_mut(ch.end - cursor);
            cursor = ch.end;
            per_chunk[w].push(head);
            col = t;
        }
    }
    let work = &work;
    std::thread::scope(|s| {
        for (ch, mut cols) in chunks.iter().cloned().zip(per_chunk) {
            s.spawn(move || work(ch, &mut cols));
        }
    });
}

/// Drain `jobs` through `workers` scoped threads, returning `f(job)`
/// results in submission order. `workers` is clamped to the job count;
/// 0/1 workers degrade to an in-thread loop.
pub fn run_queue<J, R, F>(workers: usize, jobs: Vec<J>, f: F) -> Vec<R>
where
    J: Send,
    R: Send,
    F: Fn(J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return jobs.into_iter().map(f).collect();
    }
    let queue = Mutex::new(jobs.into_iter().enumerate().collect::<Vec<(usize, J)>>());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let queue = &queue;
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            s.spawn(move || loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((idx, j)) => {
                        if tx.send((idx, f(j))).is_err() {
                            break;
                        }
                    }
                    None => break,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (idx, res) in rx {
            out[idx] = Some(res);
        }
        out.into_iter().map(|r| r.expect("worker died with job")).collect()
    })
}

/// Run `f(worker_index)` once on each of `n` scoped threads.
pub fn broadcast<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let f = &f;
    std::thread::scope(|s| {
        for i in 0..n.max(1) {
            s.spawn(move || f(i));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn default_workers_at_least_one_and_env_override() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn balance_covers_everything_contiguously() {
        for (n, parts) in [(10usize, 3usize), (1, 4), (100, 7), (5, 5), (0, 2)] {
            let ch = balance_by_weight(n, parts, |_| 1);
            assert_eq!(ch.first().map(|c| c.start), Some(0));
            assert_eq!(ch.last().unwrap().end, n);
            for w in ch.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            assert!(ch.len() <= parts.max(1));
        }
    }

    #[test]
    fn balance_weights_skewed() {
        // one heavy item at the front: it gets its own chunk
        let ch = balance_by_weight(10, 3, |i| if i == 0 { 100 } else { 1 });
        assert_eq!(ch[0], 0..1);
        assert_eq!(ch.last().unwrap().end, 10);
    }

    #[test]
    fn disjoint_chunks_write_every_slot() {
        let mut out = vec![0usize; 57];
        let chunks = balance_by_weight(out.len(), 4, |_| 1);
        for_each_disjoint(&mut out, &chunks, |ch, ys| {
            for (k, slot) in ys.iter_mut().enumerate() {
                *slot = ch.start + k + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i + 1);
        }
    }

    #[test]
    fn single_chunk_runs_inline() {
        let mut out = vec![0u8; 8];
        for_each_disjoint(&mut out, &[0..8], |_, ys| ys.fill(7));
        assert_eq!(out, vec![7; 8]);
        // empty chunk list is a no-op
        let mut empty: Vec<u8> = Vec::new();
        for_each_disjoint(&mut empty, &[], |_, _| unreachable!());
    }

    #[test]
    fn disjoint_cols_write_every_slot() {
        // 3 columns of 57 rows, split 4 ways: slot = col*1000 + row + 1
        let col_len = 57usize;
        let ncols = 3usize;
        let mut out = vec![0usize; col_len * ncols];
        let chunks = balance_by_weight(col_len, 4, |_| 1);
        for_each_disjoint_cols(&mut out, col_len, &chunks, |ch, cols| {
            for (j, col) in cols.iter_mut().enumerate() {
                for (k, slot) in col.iter_mut().enumerate() {
                    *slot = j * 1000 + ch.start + k + 1;
                }
            }
        });
        for j in 0..ncols {
            for r in 0..col_len {
                assert_eq!(out[j * col_len + r], j * 1000 + r + 1);
            }
        }
    }

    #[test]
    fn disjoint_cols_single_chunk_inline() {
        let mut out = vec![0u8; 12]; // 2 columns of 6
        for_each_disjoint_cols(&mut out, 6, &[0..6], |_, cols| {
            assert_eq!(cols.len(), 2);
            for col in cols.iter_mut() {
                col.fill(9);
            }
        });
        assert_eq!(out, vec![9; 12]);
        // empty chunk list is a no-op
        let mut empty: Vec<u8> = Vec::new();
        for_each_disjoint_cols(&mut empty, 0, &[], |_, _| unreachable!());
    }

    #[test]
    fn queue_preserves_order_for_any_worker_count() {
        for workers in [1usize, 2, 3, 8] {
            let jobs: Vec<usize> = (0..17).collect();
            let out = run_queue(workers, jobs, |j| j * 2);
            assert_eq!(out, (0..17).map(|j| j * 2).collect::<Vec<_>>(), "workers={workers}");
        }
        assert!(run_queue(4, Vec::<u32>::new(), |j| j).is_empty());
    }

    #[test]
    fn broadcast_runs_once_per_worker() {
        let hits = AtomicUsize::new(0);
        broadcast(6, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 6);
    }
}
