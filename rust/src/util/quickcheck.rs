//! Tiny randomized-property harness (proptest substitute, DESIGN.md §5).
//!
//! `check(seed, cases, gen, prop)` draws `cases` inputs from `gen` and
//! asserts `prop` on each; on failure it re-derives the failing seed so
//! the case is reproducible, and performs a bounded shrink pass when the
//! generator supports resizing via `Shrink`.

use super::prng::Prng;

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs produced by `gen`.
/// Panics with the failing seed + message on the first failure.
pub fn check<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Prng) -> T,
    mut prop: impl FnMut(&T) -> PropResult,
) {
    for case in 0..cases {
        let case_seed = seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1));
        let mut rng = Prng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={seed}, case={case}, case_seed={case_seed}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Convenience: assert two f64 values are within `atol + rtol*|b|`.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> PropResult {
    if a.is_nan() && b.is_nan() {
        return Ok(());
    }
    let tol = atol + rtol * b.abs();
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {} > tol {tol}", (a - b).abs()))
    }
}

/// Convenience: assert slices elementwise close.
pub fn all_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        close(x, y, rtol, atol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(2, 50, |r| r.below(10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn close_handles_nan_pair() {
        assert!(close(f64::NAN, f64::NAN, 0.0, 0.0).is_ok());
        assert!(close(1.0, f64::NAN, 0.0, 0.0).is_err());
    }

    #[test]
    fn all_close_reports_index() {
        let e = all_close(&[1.0, 2.0], &[1.0, 3.0], 0.0, 0.1).unwrap_err();
        assert!(e.contains("index 1"), "{e}");
        assert!(all_close(&[1.0], &[1.0, 2.0], 0.0, 0.0).is_err());
    }
}
