//! Infrastructure substrate: PRNG, statistics, timing, bit helpers,
//! shared chunk-parallelism ([`parallel`]), an error-context type
//! ([`error`], the `anyhow` substitute), and the in-repo substitutes for
//! `criterion` (bench harness) and `proptest` (randomized property
//! harness) — none of those crates are available in this offline build
//! environment (see DESIGN.md §5).

pub mod prng;
pub mod codec;
pub mod stats;
pub mod timer;
pub mod bits;
pub mod bench;
pub mod quickcheck;
pub mod table;
pub mod csv;
pub mod error;
pub mod parallel;

pub use prng::Prng;
pub use stats::{geomean, mean, median, percentile, stddev};
pub use timer::Timer;
