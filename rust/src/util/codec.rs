//! Minimal little-endian binary codec — the serialization substrate for
//! the coordinator's operator spill files (no `serde`/`bincode` in this
//! offline build, see DESIGN.md §5).
//!
//! [`ByteWriter`] appends fixed-width scalars and length-prefixed
//! arrays; [`ByteReader`] reads them back with fallible, bounds-checked
//! accessors so a truncated or corrupt spill file surfaces as an
//! [`Error`](crate::util::error::Error) instead of a panic — the
//! registry then falls back to re-encoding.

use crate::util::error::{Error, Result};

/// Append-only little-endian byte buffer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        // bit pattern, not value: round-trips NaN payloads and -0.0
        self.put_u64(v.to_bits());
    }

    /// `u64` length prefix + raw bytes.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_u16s(&mut self, v: &[u16]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u16(x);
        }
    }

    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u32(x);
        }
    }

    /// `usize` values stored as u64 (rowptr arrays).
    pub fn put_usizes(&mut self, v: &[usize]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x as u64);
        }
    }

    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_f64(x);
        }
    }
}

/// Bounds-checked reader over a byte slice; every accessor fails with a
/// context message on truncation.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::msg(format!(
                "truncated buffer: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Read a `u64` length prefix, validated against the remaining bytes
    /// so a corrupt length cannot trigger a huge allocation.
    fn get_len(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.get_u64()? as usize;
        if n.checked_mul(elem_bytes).is_none_or(|total| total > self.remaining()) {
            return Err(Error::msg(format!(
                "corrupt length prefix {n} at offset {} (remaining {})",
                self.pos,
                self.remaining()
            )));
        }
        Ok(n)
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_len(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn get_u16s(&mut self) -> Result<Vec<u16>> {
        let n = self.get_len(2)?;
        (0..n).map(|_| self.get_u16()).collect()
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.get_len(4)?;
        (0..n).map(|_| self.get_u32()).collect()
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.get_len(8)?;
        (0..n).map(|_| Ok(self.get_u64()? as usize)).collect()
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.get_len(8)?;
        (0..n).map(|_| self.get_f64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u16(0xBEEF);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u16().unwrap(), 0xBEEF);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.get_f64().unwrap().is_nan());
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn arrays_round_trip() {
        let mut w = ByteWriter::new();
        w.put_bytes(&[1, 2, 3]);
        w.put_u16s(&[10, 20]);
        w.put_u32s(&[]);
        w.put_usizes(&[0, usize::MAX]);
        w.put_f64s(&[1.5, -2.25]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_u16s().unwrap(), vec![10, 20]);
        assert_eq!(r.get_u32s().unwrap(), Vec::<u32>::new());
        assert_eq!(r.get_usizes().unwrap(), vec![0, usize::MAX]);
        assert_eq!(r.get_f64s().unwrap(), vec![1.5, -2.25]);
    }

    #[test]
    fn truncation_errors_not_panics() {
        let mut w = ByteWriter::new();
        w.put_u32s(&[1, 2, 3]);
        let bytes = w.into_bytes();
        // cut mid-array: the reader must fail cleanly
        for cut in 0..bytes.len() {
            let mut r = ByteReader::new(&bytes[..cut]);
            assert!(r.get_u32s().is_err(), "cut at {cut} should fail");
        }
    }

    #[test]
    fn corrupt_length_rejected_without_allocation() {
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX); // absurd length prefix, no payload
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).get_f64s().is_err());
        assert!(ByteReader::new(&bytes).get_bytes().is_err());
    }
}
