//! Small statistics helpers shared by the analysis and bench code.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0.0 for slices shorter than 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean of positive values; zero/negative entries are skipped.
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Percentile in [0, 100], linear interpolation between order statistics.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Shannon entropy (bits) of a discrete distribution given by counts.
pub fn entropy_from_counts(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let n = total as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Relative standard deviation (Eq. 3 of the paper): stddev / mean.
/// Returns +inf when the mean is zero but the data is not.
pub fn rsd(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        if xs.iter().all(|&x| x == 0.0) {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        stddev(xs) / m.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        // zeros skipped
        assert!((geomean(&[0.0, 1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn entropy_uniform_vs_point() {
        assert!((entropy_from_counts(&[1, 1, 1, 1]) - 2.0).abs() < 1e-12);
        assert_eq!(entropy_from_counts(&[8, 0, 0]), 0.0);
        assert_eq!(entropy_from_counts(&[]), 0.0);
    }

    #[test]
    fn rsd_matches_definition() {
        let xs = [1.0, 2.0, 3.0];
        assert!((rsd(&xs) - stddev(&xs) / 2.0).abs() < 1e-12);
        assert_eq!(rsd(&[0.0, 0.0]), 0.0);
    }
}
