//! Deterministic pseudo-random number generation.
//!
//! xoshiro256++ seeded via splitmix64 — the standard construction used by
//! the `rand_xoshiro` crate, re-implemented here because the offline
//! registry does not carry `rand`. All experiment workloads are seeded so
//! every bench/test run is reproducible bit-for-bit.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // A state of all zeros is invalid for xoshiro; splitmix64 cannot
        // produce four consecutive zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double mantissa resolution.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n) (n > 0). Uses Lemire-style rejection-free
    /// multiply-shift; bias is < 2^-32 for all n that occur here.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform i64 in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// adequate for workload generation).
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample: exp(mu + sigma * N(0,1)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Random boolean with probability `p` of being true.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (m <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        // Floyd's algorithm for sparse sampling, full shuffle when dense.
        if m * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(m);
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(m);
            let mut out = Vec::with_capacity(m);
            for j in (n - m)..n {
                let t = self.below(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }

    /// Draw from a discrete distribution given (unnormalized) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Prng::new(3);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Prng::new(11);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Prng::new(13);
        for (n, m) in [(100, 5), (100, 90), (10, 10), (1000, 3)] {
            let s = r.sample_indices(n, m);
            assert_eq!(s.len(), m);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), m);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Prng::new(21);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }
}
