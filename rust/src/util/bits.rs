//! Bit-manipulation helpers used by the floating-point format code.
//!
//! All shifts here are *safe* for shift amounts >= the bit width (they
//! saturate to 0), which the SEM encoder relies on when the exponent
//! difference exceeds the mantissa width (very small values round to 0).

/// `x >> n`, returning 0 when `n >= 64` instead of UB.
#[inline(always)]
pub fn shr64(x: u64, n: u32) -> u64 {
    if n >= 64 {
        0
    } else {
        x >> n
    }
}

/// `x << n`, returning 0 when `n >= 64` instead of UB.
#[inline(always)]
pub fn shl64(x: u64, n: u32) -> u64 {
    if n >= 64 {
        0
    } else {
        x << n
    }
}

/// Mask with the least-significant `n` bits set (`n <= 64`).
#[inline(always)]
pub fn mask64(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Position (0-based from LSB) of the most significant set bit, or `None`
/// for zero. `msb(1) == Some(0)`, `msb(0b100) == Some(2)`.
#[inline(always)]
pub fn msb(x: u64) -> Option<u32> {
    if x == 0 {
        None
    } else {
        Some(63 - x.leading_zeros())
    }
}

/// CUDA `__fns(mask, base, -1)` analog restricted to how Algorithm 2 of
/// the paper uses it: scan from bit `base` *downward* and return the bit
/// position of the first set bit, or `None` if no set bit at or below
/// `base`. (The paper scans the 15 value bits of the 16-bit head from
/// MSB-1 downward.)
#[inline(always)]
pub fn fns_down(x: u64, base: u32) -> Option<u32> {
    let masked = x & mask64(base + 1);
    msb(masked)
}

/// Round-to-nearest-even truncation of a `w`-bit unsigned integer to its
/// top `keep` bits; returns the rounded value **and** a carry flag set
/// when rounding overflowed out of the `keep`-bit field.
#[inline]
pub fn round_ties_even(x: u64, w: u32, keep: u32) -> (u64, bool) {
    debug_assert!(keep <= w && w <= 64);
    if keep >= w {
        return (x, false);
    }
    let drop = w - keep;
    let head = shr64(x, drop);
    let rem = x & mask64(drop);
    let half = shl64(1, drop - 1);
    let round_up = rem > half || (rem == half && head & 1 == 1);
    if round_up {
        let r = head + 1;
        if r >> keep != 0 {
            (r >> 1, true) // carried into a new leading bit
        } else {
            (r, false)
        }
    } else {
        (head, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shr_saturates() {
        assert_eq!(shr64(u64::MAX, 64), 0);
        assert_eq!(shr64(u64::MAX, 100), 0);
        assert_eq!(shr64(0b100, 2), 1);
    }

    #[test]
    fn shl_saturates() {
        assert_eq!(shl64(1, 64), 0);
        assert_eq!(shl64(1, 63), 1 << 63);
    }

    #[test]
    fn mask_widths() {
        assert_eq!(mask64(0), 0);
        assert_eq!(mask64(1), 1);
        assert_eq!(mask64(52), (1u64 << 52) - 1);
        assert_eq!(mask64(64), u64::MAX);
    }

    #[test]
    fn msb_positions() {
        assert_eq!(msb(0), None);
        assert_eq!(msb(1), Some(0));
        assert_eq!(msb(0b1010), Some(3));
        assert_eq!(msb(u64::MAX), Some(63));
    }

    #[test]
    fn fns_down_matches_paper_usage() {
        // head value bits: scan from bit 14 downward.
        assert_eq!(fns_down(0b0100_0000_0000_0000, 14), Some(14));
        assert_eq!(fns_down(0b0000_0000_0000_0001, 14), Some(0));
        assert_eq!(fns_down(0, 14), None);
        // A sign bit above `base` must not be found.
        assert_eq!(fns_down(0b1000_0000_0000_0000, 14), None);
    }

    #[test]
    fn round_ties_even_basics() {
        // 0b1011 (11) keep 2 of 4 bits: head=0b10, rem=0b11>0b10 -> up -> 0b11
        assert_eq!(round_ties_even(0b1011, 4, 2), (0b11, false));
        // tie rounds to even: 0b1010 keep 2: head=0b10 even, rem==half -> stay
        assert_eq!(round_ties_even(0b1010, 4, 2), (0b10, false));
        // tie with odd head rounds up: 0b1110 keep 2: head=0b11, rem==half -> 0b100 carries
        assert_eq!(round_ties_even(0b1110, 4, 2), (0b10, true));
        // keep >= w is identity
        assert_eq!(round_ties_even(0b1011, 4, 4), (0b1011, false));
    }
}
