//! Minimal error-context type (anyhow substitute — the offline build
//! environment carries no external crates, see DESIGN.md §5).
//!
//! Provides the slice of `anyhow` this crate actually uses:
//! * [`Error`] — a message chain; `{e}` prints the outermost context,
//!   `{e:#}` prints the whole chain joined with `": "`.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result` and `Option`.
//! * [`bail!`](crate::bail) — early-return with a formatted message.

use std::fmt;

/// A chain of context messages, outermost first.
#[derive(Debug, Clone)]
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { chain: vec![m.to_string()] }
    }

    /// Prepend a context message (what `.context(..)` does).
    pub fn wrap(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl std::error::Error for Error {}

/// `Result` with [`Error`] as the default error type (anyhow-style).
pub type Result<T, E = Error> = std::result::Result<T, E>;

macro_rules! impl_from {
    ($($t:ty),* $(,)?) => {
        $(impl From<$t> for Error {
            fn from(e: $t) -> Self {
                Error::msg(e)
            }
        })*
    };
}

impl_from!(
    std::io::Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::str::Utf8Error,
    String,
    &str,
);

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($t)*)))
    };
}

pub use crate::bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(Error::msg("root cause"))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(1).context("missing").unwrap(), 1);
    }

    #[test]
    fn with_context_lazy() {
        let mut called = false;
        let r: Result<u32> = Ok(7);
        let out = r.with_context(|| {
            called = true;
            "never"
        });
        assert_eq!(out.unwrap(), 7);
        assert!(!called);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn bail_formats() {
        fn f(x: i32) -> Result<()> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
    }
}
