//! Wall-clock timing helper.

use std::time::Instant;

/// A simple stopwatch around `std::time::Instant`.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Elapsed microseconds since start.
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }

    /// Restart the timer, returning the elapsed seconds of the lap.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn time_it_returns_result() {
        let (v, s) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
