//! Aligned text-table printer used by the bench harness and CLI to emit
//! the paper's tables/figure series in a readable form.

/// A simple column-aligned table builder.
pub struct TextTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with padded columns, a header underline, and 2-space gutters.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers matching the paper's notation.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if !x.is_finite() {
        "/".to_string() // the paper prints "/" for overflowed runs
    } else {
        format!("{x:.1E}")
    }
}

pub fn fx(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(&["a", "bbbb"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["yyy".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a    bbbb"));
        assert!(lines[2].starts_with("x"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn sci_formats() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(f64::INFINITY), "/");
        assert!(sci(1.23e-6).contains("E-6"));
    }
}
