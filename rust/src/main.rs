//! `gsem` — leader binary: CLI driver over the coordinator.
//!
//! Subcommands:
//! * `analyze`   — §II motivation stats for a matrix (entropy, top-k).
//! * `spmv`      — run/compare SpMV formats on a matrix.
//! * `solve`     — run CG/GMRES/BiCGSTAB in any storage format
//!                 (including stepped GSE-SEM) and print the outcome.
//! * `serve`     — replay a staggered request trace through the
//!                 windowed `SolverService` (intake/cache metrics).
//! * `suite`     — run the paper's CG + GMRES test sets end-to-end.
//! * `kernels`   — list/compile the AOT artifacts (PJRT check).
//! * `gen`       — write a corpus matrix to a MatrixMarket file.

use gsem::coordinator::cli::Cli;
use gsem::coordinator::{
    FormatChoice, RhsSpec, ServiceConfig, SolveRequest, SolveSpec, SolverKind, SolverPool,
    SolverService,
};
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::corpus::{cg_set, gmres_set, spmv_corpus, CorpusSize, NamedMatrix};
use gsem::sparse::{mm, stats::matrix_stats, Csr};
use gsem::spmv::{fp64, max_abs_diff, traffic};
use gsem::util::table::TextTable;
use gsem::util::Timer;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match cli.command.as_deref() {
        Some("analyze") => cmd_analyze(&cli),
        Some("spmv") => cmd_spmv(&cli),
        Some("solve") => cmd_solve(&cli),
        Some("serve") => cmd_serve(&cli),
        Some("suite") => cmd_suite(&cli),
        Some("kernels") => cmd_kernels(&cli),
        Some("gen") => cmd_gen(&cli),
        _ => {
            print_usage();
            0
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "gsem — GSE-SEM mixed-precision iterative solvers (paper reproduction)\n\n\
         USAGE: gsem <command> [--options]\n\n\
         COMMANDS:\n\
           analyze  --matrix <name|path.mtx>            exponent/entropy stats (Fig. 1)\n\
           spmv     --matrix <name|path.mtx> [--k 8] [--threads N]\n\
                    compare SpMV formats (Fig. 6)\n\
           solve    --matrix <name|path.mtx> --solver cg|gmres|bicgstab\n\
                    --format fp64|fp32|fp16|bf16|gse-head|gse-t1|gse-full|stepped|stepped-copy\n\
                    [--k 8] [--nrhs N] [--workers N]  (N > 1 pools N random RHS over\n\
                    --workers threads, 0 = auto; every solver/format combination —\n\
                    CG/GMRES/BiCGSTAB, fixed or stepped — merges them into one\n\
                    multi-RHS block solve)\n\
           serve    [--requests 24] [--window-ms 5] [--batch-width 8] [--stagger-us 300]\n\
                    [--workers 0] [--cache-mb 0] [--matrix <...>] [--solver cg] [--format fp64]\n\
                    replay a staggered request trace through the windowed SolverService\n\
                    and report intake/cache metrics (0 = auto workers / unbounded cache)\n\
           suite    [--solver cg|gmres|both] [--size small|medium|full] [--workers N] (0 = auto)\n\
           kernels                                      PJRT artifact check\n\
           gen      --matrix <name> --out <path.mtx> | --list\n\n\
         Matrix names: any corpus entry (see `gen --list`), e.g. poisson2d_48x48."
    );
}

/// Resolve a matrix by corpus name or .mtx path.
fn load_matrix(spec: &str) -> Result<Csr, String> {
    if spec.ends_with(".mtx") {
        return mm::read_path(Path::new(spec)).map_err(|e| format!("{e:#}"));
    }
    let size = CorpusSize::from_env();
    let all: Vec<NamedMatrix> = spmv_corpus(size)
        .into_iter()
        .chain(cg_set(size))
        .chain(gmres_set(size))
        .collect();
    all.into_iter()
        .find(|m| m.name == spec)
        .map(|m| m.a)
        .ok_or_else(|| format!("unknown matrix '{spec}' (try e.g. poisson2d_48x48 or a .mtx path)"))
}

fn cmd_analyze(cli: &Cli) -> i32 {
    let Some(spec) = cli.get("matrix") else {
        eprintln!("--matrix required");
        return 2;
    };
    let a = match load_matrix(spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let s = matrix_stats(&a);
    println!("matrix {spec}: {} x {}, nnz {}", s.nrows, s.ncols, s.nnz);
    println!(
        "entropy (bits): values {:.3}  exponents {:.3}  mantissas {:.3}",
        s.entropy.value_bits, s.entropy.exponent_bits, s.entropy.mantissa_bits
    );
    println!("distinct exponents: {}", s.num_distinct_exponents);
    let mut t = TextTable::new(&["top-k", "coverage"]);
    for (i, &k) in gsem::sparse::stats::TOPK_LEVELS.iter().enumerate() {
        t.row(&[format!("top-{k}"), format!("{:.4}", s.topk[i])]);
    }
    t.print();
    0
}

fn cmd_spmv(cli: &Cli) -> i32 {
    let Some(spec) = cli.get("matrix") else {
        eprintln!("--matrix required");
        return 2;
    };
    let k = cli.get_usize("k", 8).unwrap_or(8);
    let reps = cli.get_usize("reps", 100).unwrap_or(100);
    // --threads 0 = auto (machine parallelism / GSEM_WORKERS)
    let threads = match cli.get_usize("threads", 1).unwrap_or(1) {
        0 => gsem::util::parallel::default_workers(),
        n => n,
    };
    let a = match load_matrix(spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let x = vec![1.0; a.ncols]; // paper: x = 1 to observe representation error
    let mut y64 = vec![0.0; a.nrows];
    fp64::spmv(&a, &x, &mut y64);

    let ops = gsem::spmv::build_operators_par(&a, k, threads);
    let mut t = TextTable::new(&[
        "format",
        "cpu time/op",
        "cpu speedup",
        "V100 model speedup",
        "maxAbsErr",
    ]);
    let mut t64 = 0.0;
    for op in &ops {
        let mut y = vec![0.0; a.nrows];
        let timer = Timer::start();
        for _ in 0..reps {
            op.apply(&x, &mut y);
        }
        let dt = timer.elapsed_s() / reps as f64;
        if op.format() == ValueFormat::Fp64 {
            t64 = dt;
        }
        let err = max_abs_diff(&y64, &y);
        t.row(&[
            op.format().label().to_string(),
            format!("{:.3} us", dt * 1e6),
            if t64 > 0.0 { format!("{:.2}x", t64 / dt) } else { "-".into() },
            format!("{:.2}x", traffic::V100.speedup_vs_fp64(&a, op.format())),
            format!("{err:.3E}"),
        ]);
    }
    t.print();
    0
}

fn parse_format(s: &str, k: usize) -> Option<FormatChoice> {
    let format = match s {
        "fp64" => ValueFormat::Fp64,
        "fp32" => ValueFormat::Fp32,
        "fp16" => ValueFormat::Fp16,
        "bf16" => ValueFormat::Bf16,
        "gse-head" => ValueFormat::GseSem(Precision::Head),
        "gse-t1" => ValueFormat::GseSem(Precision::HeadTail1),
        "gse-full" => ValueFormat::GseSem(Precision::Full),
        _ => return None,
    };
    Some(FormatChoice::Fixed { format, k })
}

fn parse_solver(s: &str) -> Option<SolverKind> {
    match s {
        "cg" => Some(SolverKind::Cg),
        "gmres" => Some(SolverKind::Gmres),
        "bicgstab" => Some(SolverKind::Bicgstab),
        _ => None,
    }
}

/// Full format axis shared by `solve` and `serve`: fixed formats plus
/// the two stepped ladders (whose controller thresholds depend on the
/// solver family).
fn parse_format_choice(s: &str, solver: SolverKind, k: usize, scale: f64) -> Option<FormatChoice> {
    let stepped_base = match solver {
        SolverKind::Cg | SolverKind::Bicgstab => SteppedParams::cg_paper(),
        SolverKind::Gmres => SteppedParams::gmres_paper(),
    };
    match s {
        "stepped" => Some(FormatChoice::Stepped { k, params: stepped_base.scaled(scale) }),
        "stepped-copy" => Some(FormatChoice::SteppedCopy { params: stepped_base.scaled(scale) }),
        other => parse_format(other, k),
    }
}

fn cmd_solve(cli: &Cli) -> i32 {
    let Some(spec) = cli.get("matrix") else {
        eprintln!("--matrix required");
        return 2;
    };
    let Some(solver) = parse_solver(cli.get_or("solver", "cg")) else {
        eprintln!("unknown solver {}", cli.get_or("solver", "cg"));
        return 2;
    };
    let k = cli.get_usize("k", 8).unwrap_or(8);
    let fmt_str = cli.get_or("format", "stepped");
    let scale = cli.get_f64("scale", 0.02).unwrap_or(0.02);
    let Some(format) = parse_format_choice(fmt_str, solver, k, scale) else {
        eprintln!("unknown format {fmt_str}");
        return 2;
    };
    let a = match load_matrix(spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let nrhs = cli.get_usize("nrhs", 1).unwrap_or(1).max(1);
    let mut req = SolveRequest::new(spec, Arc::new(a), solver, format);
    req.tol = cli.get_f64("tol", 1e-6).unwrap_or(1e-6);
    if nrhs > 1 {
        // --workers 0 = auto, matching serve/suite
        let workers = match cli.get_usize("workers", 1).unwrap_or(1) {
            0 => gsem::util::parallel::default_workers(),
            n => n,
        };
        return solve_multi_rhs(req, nrhs, solver, workers);
    }
    let res = gsem::coordinator::jobs::dispatch(&req);
    println!(
        "{} [{}] {}: iters={} converged={} relres(solver)={} relres(FP64)={:.3E} time={:.3}s",
        res.name,
        res.format_label,
        solver_name(solver),
        res.outcome.iters,
        res.outcome.converged,
        res.outcome.relres_label(),
        res.relres_fp64,
        res.outcome.seconds
    );
    if !res.outcome.switches.is_empty() {
        println!("precision switches: {:?}", res.outcome.switches);
    }
    if res.outcome.converged {
        0
    } else {
        1
    }
}

fn solver_name(solver: SolverKind) -> &'static str {
    match solver {
        SolverKind::Cg => "CG",
        SolverKind::Gmres => "GMRES",
        SolverKind::Bicgstab => "BiCGSTAB",
    }
}

/// `solve --nrhs N`: N independent random right-hand sides on one
/// matrix, run through the pool (`--workers` sizes it). Every
/// solver/format combination — CG, GMRES and BiCGSTAB over fixed
/// formats, plus both stepped ladders — merges into a single multi-RHS
/// block solve over the cached operator (stepped blocks share one
/// precision ladder across per-column controllers; see the
/// `pool.batched_*` and `cache.*` counters printed at the end).
fn solve_multi_rhs(req: SolveRequest, nrhs: usize, solver: SolverKind, workers: usize) -> i32 {
    let reqs: Vec<SolveRequest> = (0..nrhs)
        .map(|j| {
            let mut r = req.clone();
            r.name = format!("{}#{j}", req.name);
            r.rhs = RhsSpec::Random(1000 + j as u64);
            r
        })
        .collect();
    let pool = SolverPool::new(workers);
    let results = pool.run_batch(reqs);
    let mut t = TextTable::new(&["rhs", "format", "iters", "relres(FP64)", "time(s)"]);
    let mut all_ok = true;
    for r in &results {
        all_ok &= r.outcome.converged;
        t.row(&[
            r.name.clone(),
            r.format_label.clone(),
            r.outcome.iters.to_string(),
            format!("{:.3E}", r.relres_fp64),
            format!("{:.3}", r.outcome.seconds),
        ]);
    }
    println!("{} x{nrhs} RHS (pool-batched where possible)", solver_name(solver));
    t.print();
    print!("{}", pool.metrics().report());
    if all_ok {
        0
    } else {
        1
    }
}

/// `serve`: replay a request trace with staggered arrivals through the
/// windowed [`SolverService`]. Requests round-robin over the trace
/// matrices (one `--matrix`, or the first three CG-set entries), each
/// with a distinct random RHS; the intake merges whatever lands in the
/// same window into multi-RHS block solves. Prints the per-request
/// table, throughput, and the full metrics report (`intake.*`,
/// `cache.*`, `pool.batched_*`).
fn cmd_serve(cli: &Cli) -> i32 {
    let (requests, window_ms, batch_width, stagger_us, cache_mb) = match (
        cli.get_usize("requests", 24),
        cli.get_u64("window-ms", 5),
        cli.get_usize("batch-width", 8),
        cli.get_u64("stagger-us", 300),
        cli.get_usize("cache-mb", 0),
    ) {
        (Ok(r), Ok(w), Ok(b), Ok(s), Ok(c)) => (r.max(1), w, b, s, c),
        _ => {
            eprintln!("serve: numeric option failed to parse");
            return 2;
        }
    };
    let (workers_opt, k, scale, tol) = match (
        cli.get_usize("workers", 0),
        cli.get_usize("k", 8),
        cli.get_f64("scale", 0.02),
        cli.get_f64("tol", 1e-6),
    ) {
        (Ok(w), Ok(k), Ok(s), Ok(t)) => (w, k, s, t),
        _ => {
            eprintln!("serve: numeric option failed to parse");
            return 2;
        }
    };
    // --workers 0 = auto (machine parallelism / GSEM_WORKERS)
    let workers = match workers_opt {
        0 => gsem::util::parallel::default_workers(),
        n => n,
    };
    let Some(solver) = parse_solver(cli.get_or("solver", "cg")) else {
        eprintln!("unknown solver {}", cli.get_or("solver", "cg"));
        return 2;
    };
    let fmt_str = cli.get_or("format", "fp64");
    let Some(format) = parse_format_choice(fmt_str, solver, k, scale) else {
        eprintln!("unknown format {fmt_str}");
        return 2;
    };
    let mats: Vec<(String, Arc<Csr>)> = match cli.get("matrix") {
        Some(spec) => match load_matrix(spec) {
            Ok(a) => vec![(spec.to_string(), Arc::new(a))],
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        None => cg_set(CorpusSize::Small)
            .into_iter()
            .take(3)
            .map(|m| (m.name, Arc::new(m.a)))
            .collect(),
    };
    let mut cfg = ServiceConfig::new()
        .workers(workers)
        .window_ms(window_ms)
        .batch_width(batch_width);
    if cache_mb > 0 {
        cfg = cfg.cache_bytes(cache_mb << 20);
    }
    let svc = SolverService::new(cfg);
    // register each trace matrix once; handles are cheap to clone and
    // carry the digest, so the submit loop never re-hashes
    let handles: Vec<(String, gsem::coordinator::MatrixHandle)> =
        mats.iter().map(|(name, a)| (name.clone(), svc.register(a))).collect();
    println!(
        "serving {requests} staggered requests over {} matrices \
         (window {window_ms}ms, batch width {batch_width}, workers {workers}, \
         stagger {stagger_us}us)",
        mats.len()
    );
    let timer = Timer::start();
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            let (name, handle) = &handles[i % handles.len()];
            let mut spec = SolveSpec::new(
                &format!("{name}#{i}"),
                handle.clone(),
                solver,
                format.clone(),
            );
            spec.rhs = RhsSpec::Random(1000 + i as u64);
            spec.tol = tol;
            let ticket = svc.submit(spec);
            if stagger_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(stagger_us));
            }
            ticket
        })
        .collect();
    let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
    let wall = timer.elapsed_s();
    let mut t = TextTable::new(&["request", "format", "iters", "relres(FP64)", "time(s)"]);
    let mut all_ok = true;
    for r in &results {
        all_ok &= r.outcome.converged;
        t.row(&[
            r.name.clone(),
            r.format_label.clone(),
            r.outcome.iters.to_string(),
            format!("{:.3E}", r.relres_fp64),
            format!("{:.3}", r.outcome.seconds),
        ]);
    }
    t.print();
    println!("wall {:.3}s  ({:.1} req/s)", wall, requests as f64 / wall);
    print!("{}", svc.metrics().report());
    if all_ok {
        0
    } else {
        1
    }
}

fn cmd_suite(cli: &Cli) -> i32 {
    let size = match cli.get_or("size", "small") {
        "small" => CorpusSize::Small,
        "full" => CorpusSize::Full,
        _ => CorpusSize::Medium,
    };
    let which = cli.get_or("solver", "both");
    let scale = cli.get_f64("scale", 0.02).unwrap_or(0.02);
    // --workers 0 = auto (machine parallelism / GSEM_WORKERS)
    let pool = match cli.get_usize("workers", 1).unwrap_or(1) {
        0 => SolverPool::with_default_workers(),
        n => SolverPool::new(n),
    };
    let formats: [(&str, FormatChoice); 3] = [
        ("FP64", FormatChoice::fixed(ValueFormat::Fp64)),
        ("FP16", FormatChoice::fixed(ValueFormat::Fp16)),
        ("BF16", FormatChoice::fixed(ValueFormat::Bf16)),
    ];
    for (solver, set) in
        [(SolverKind::Cg, cg_set(size)), (SolverKind::Gmres, gmres_set(size))]
    {
        if which != "both"
            && !(which == "cg" && solver == SolverKind::Cg)
            && !(which == "gmres" && solver == SolverKind::Gmres)
        {
            continue;
        }
        let stepped_base = match solver {
            SolverKind::Gmres => SteppedParams::gmres_paper(),
            _ => SteppedParams::cg_paper(),
        };
        println!(
            "== {} suite ({} matrices) ==",
            if solver == SolverKind::Cg { "CG" } else { "GMRES" },
            set.len()
        );
        let mut t = TextTable::new(&["matrix", "format", "iters", "relres", "time(s)"]);
        for m in &set {
            let a = Arc::new(m.a.clone());
            let mut reqs: Vec<SolveRequest> = formats
                .iter()
                .map(|(_, f)| SolveRequest::new(&m.name, Arc::clone(&a), solver, f.clone()))
                .collect();
            reqs.push(SolveRequest::new(
                &m.name,
                Arc::clone(&a),
                solver,
                FormatChoice::Stepped { k: 8, params: stepped_base.scaled(scale) },
            ));
            for r in pool.run_batch(reqs) {
                t.row(&[
                    r.name.clone(),
                    r.format_label.clone(),
                    r.outcome.iters.to_string(),
                    r.outcome.relres_label(),
                    format!("{:.3}", r.outcome.seconds),
                ]);
            }
        }
        t.print();
    }
    // operator-cache + batching counters accumulated across the suite
    print!("{}", pool.metrics().report());
    0
}

fn cmd_kernels(_cli: &Cli) -> i32 {
    match gsem::runtime::Engine::load_default() {
        Ok(None) => {
            eprintln!("artifacts/ not built — run `make artifacts` first");
            1
        }
        Err(e) => {
            eprintln!("engine load failed: {e:#}");
            1
        }
        Ok(Some(mut engine)) => {
            println!("PJRT platform: {}", engine.platform());
            let names = engine.kernel_names();
            for n in &names {
                match engine.kernel(n) {
                    Ok(k) => println!(
                        "  {n}: inputs {:?} dtypes {:?} outputs {}",
                        k.entry.inputs, k.entry.dtypes, k.entry.outputs
                    ),
                    Err(e) => {
                        eprintln!("  {n}: COMPILE FAILED: {e:#}");
                        return 1;
                    }
                }
            }
            0
        }
    }
}

fn cmd_gen(cli: &Cli) -> i32 {
    if cli.flag("list") {
        let size = CorpusSize::from_env();
        for m in spmv_corpus(size).iter().chain(&cg_set(size)).chain(&gmres_set(size)) {
            println!(
                "{:<28} {:>9} x {:<9} nnz {:<10} [{}]",
                m.name,
                m.a.nrows,
                m.a.ncols,
                m.a.nnz(),
                m.class
            );
        }
        return 0;
    }
    let (Some(spec), Some(out)) = (cli.get("matrix"), cli.get("out")) else {
        eprintln!("--matrix and --out required (or --list)");
        return 2;
    };
    match load_matrix(spec)
        .and_then(|a| mm::write_path(&a, Path::new(out)).map_err(|e| format!("{e:#}")))
    {
        Ok(()) => {
            println!("wrote {out}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
