//! `gsem` — leader binary: CLI driver over the coordinator.
//!
//! Subcommands:
//! * `analyze`   — §II motivation stats for a matrix (entropy, top-k).
//! * `spmv`      — run/compare SpMV formats on a matrix.
//! * `solve`     — run CG/GMRES/BiCGSTAB in any storage format
//!                 (including stepped GSE-SEM) and print the outcome.
//! * `serve`     — replay a staggered request trace through the
//!                 windowed `SolverService` (intake/cache metrics);
//!                 `--soak` runs the serving-hardening soak harness
//!                 (overload, deadlines/cancellation, spill/restore).
//! * `suite`     — run the paper's CG + GMRES test sets end-to-end.
//! * `kernels`   — list/compile the AOT artifacts (PJRT check).
//! * `gen`       — write a corpus matrix to a MatrixMarket file.

use gsem::coordinator::cli::Cli;
use gsem::coordinator::{
    FormatChoice, Precond, RhsSpec, SainvParams, ServiceConfig, ServiceError, SolveRequest,
    SolveResult, SolveSpec, SolverKind, SolverPool, SolverService,
};
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::corpus::{cg_set, gmres_set, spmv_corpus, CorpusSize, NamedMatrix};
use gsem::sparse::{mm, stats::matrix_stats, Csr};
use gsem::spmv::{fp64, max_abs_diff, traffic};
use gsem::util::table::TextTable;
use gsem::util::Timer;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match cli.command.as_deref() {
        Some("analyze") => cmd_analyze(&cli),
        Some("spmv") => cmd_spmv(&cli),
        Some("solve") => cmd_solve(&cli),
        Some("serve") => cmd_serve(&cli),
        Some("suite") => cmd_suite(&cli),
        Some("kernels") => cmd_kernels(&cli),
        Some("gen") => cmd_gen(&cli),
        _ => {
            print_usage();
            0
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "gsem — GSE-SEM mixed-precision iterative solvers (paper reproduction)\n\n\
         USAGE: gsem <command> [--options]\n\n\
         COMMANDS:\n\
           analyze  --matrix <name|path.mtx>            exponent/entropy stats (Fig. 1)\n\
           spmv     --matrix <name|path.mtx> [--k 8] [--threads N]\n\
                    compare SpMV formats (Fig. 6)\n\
           solve    --matrix <name|path.mtx> --solver cg|gmres|bicgstab\n\
                    --format auto|fp64|fp32|fp16|bf16|gse-head|gse-t1|gse-full\n\
                             |stepped|stepped-copy|ir\n\
                    (auto = entropy + byte-model policy picks per matrix digest,\n\
                    cached in the registry — see the policy.* metrics)\n\
                    [--precond none|jacobi|sainv] [--drop-tol 0.1]\n\
                    [--k 8] [--nrhs N] [--workers N]  (N > 1 pools N random RHS over\n\
                    --workers threads, 0 = auto; every solver/format combination —\n\
                    CG/GMRES/BiCGSTAB, fixed or stepped — merges them into one\n\
                    multi-RHS block solve; `ir` runs preconditioned GMRES-IR over\n\
                    the GSE ladder — sainv requires it, and its factors are\n\
                    registry-cached per digest x params)\n\
           serve    [--requests 24] [--window-ms 5] [--batch-width 8] [--stagger-us 300]\n\
                    [--workers 0] [--op-threads 0] [--cache-mb 0] [--queue-depth 0]\n\
                    [--deadline-ms 0] [--spill-dir <dir>] [--metrics-json <path>]\n\
                    [--matrix <...>] [--solver cg] [--format auto]\n\
                    [--precond none|jacobi|sainv] [--drop-tol 0.1]\n\
                    replay a staggered request trace through the windowed SolverService\n\
                    and report intake/cache metrics (0 = auto workers / unbounded\n\
                    cache / unbounded queue / no deadline); sheds past --queue-depth\n\
                    surface as typed Overloaded errors; --op-threads pins every\n\
                    group's intra-group worker budget (0 = the flusher's core\n\
                    allocator divides --workers across concurrent groups by weight)\n\
           serve --soak  [--queue-depth 8] [--soak-cache-kb 24] [--spill-dir <dir>]\n\
                    [--metrics-json <path>] [--workers 0] [--stagger-us 200]\n\
                    serving-hardening soak: overload/load-shed with an\n\
                    admitted-vs-one-shot parity audit, a deadline+cancellation\n\
                    mix, spill/restore churn under a tiny cache budget, and\n\
                    repeated SAINV GMRES-IR traffic (factors built once per\n\
                    digest, per-ticket parity)\n\
           suite    [--solver cg|gmres|both] [--size small|medium|full] [--workers N] (0 = auto)\n\
           kernels                                      PJRT artifact check\n\
           gen      --matrix <name> --out <path.mtx> | --list\n\n\
         Matrix names: any corpus entry (see `gen --list`), e.g. poisson2d_48x48."
    );
}

/// Resolve a matrix by corpus name or .mtx path.
fn load_matrix(spec: &str) -> Result<Csr, String> {
    if spec.ends_with(".mtx") {
        return mm::read_path(Path::new(spec)).map_err(|e| format!("{e:#}"));
    }
    let size = CorpusSize::from_env();
    let all: Vec<NamedMatrix> = spmv_corpus(size)
        .into_iter()
        .chain(cg_set(size))
        .chain(gmres_set(size))
        .collect();
    all.into_iter()
        .find(|m| m.name == spec)
        .map(|m| m.a)
        .ok_or_else(|| format!("unknown matrix '{spec}' (try e.g. poisson2d_48x48 or a .mtx path)"))
}

fn cmd_analyze(cli: &Cli) -> i32 {
    let Some(spec) = cli.get("matrix") else {
        eprintln!("--matrix required");
        return 2;
    };
    let a = match load_matrix(spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let s = matrix_stats(&a);
    println!("matrix {spec}: {} x {}, nnz {}", s.nrows, s.ncols, s.nnz);
    println!(
        "entropy (bits): values {:.3}  exponents {:.3}  mantissas {:.3}",
        s.entropy.value_bits, s.entropy.exponent_bits, s.entropy.mantissa_bits
    );
    println!("distinct exponents: {}", s.num_distinct_exponents);
    let mut t = TextTable::new(&["top-k", "coverage"]);
    for (i, &k) in gsem::sparse::stats::TOPK_LEVELS.iter().enumerate() {
        t.row(&[format!("top-{k}"), format!("{:.4}", s.topk[i])]);
    }
    t.print();
    0
}

fn cmd_spmv(cli: &Cli) -> i32 {
    let Some(spec) = cli.get("matrix") else {
        eprintln!("--matrix required");
        return 2;
    };
    let k = cli.get_usize("k", 8).unwrap_or(8);
    let reps = cli.get_usize("reps", 100).unwrap_or(100);
    // --threads 0 = auto (machine parallelism / GSEM_WORKERS)
    let threads = match cli.get_usize("threads", 1).unwrap_or(1) {
        0 => gsem::util::parallel::default_workers(),
        n => n,
    };
    let a = match load_matrix(spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let x = vec![1.0; a.ncols]; // paper: x = 1 to observe representation error
    let mut y64 = vec![0.0; a.nrows];
    fp64::spmv(&a, &x, &mut y64);

    let ops = gsem::spmv::build_operators_par(&a, k, threads);
    let mut t = TextTable::new(&[
        "format",
        "cpu time/op",
        "cpu speedup",
        "V100 model speedup",
        "maxAbsErr",
    ]);
    let mut t64 = 0.0;
    for op in &ops {
        let mut y = vec![0.0; a.nrows];
        let timer = Timer::start();
        for _ in 0..reps {
            op.apply(&x, &mut y);
        }
        let dt = timer.elapsed_s() / reps as f64;
        if op.format() == ValueFormat::Fp64 {
            t64 = dt;
        }
        let err = max_abs_diff(&y64, &y);
        t.row(&[
            op.format().label().to_string(),
            format!("{:.3} us", dt * 1e6),
            if t64 > 0.0 { format!("{:.2}x", t64 / dt) } else { "-".into() },
            format!("{:.2}x", traffic::V100.speedup_vs_fp64(&a, op.format())),
            format!("{err:.3E}"),
        ]);
    }
    t.print();
    0
}

fn parse_format(s: &str, k: usize) -> Option<FormatChoice> {
    let format = match s {
        "fp64" => ValueFormat::Fp64,
        "fp32" => ValueFormat::Fp32,
        "fp16" => ValueFormat::Fp16,
        "bf16" => ValueFormat::Bf16,
        "gse-head" => ValueFormat::GseSem(Precision::Head),
        "gse-t1" => ValueFormat::GseSem(Precision::HeadTail1),
        "gse-full" => ValueFormat::GseSem(Precision::Full),
        _ => return None,
    };
    Some(FormatChoice::Fixed { format, k })
}

fn parse_solver(s: &str) -> Option<SolverKind> {
    match s {
        "cg" => Some(SolverKind::Cg),
        "gmres" => Some(SolverKind::Gmres),
        "bicgstab" => Some(SolverKind::Bicgstab),
        _ => None,
    }
}

/// Full format axis shared by `solve` and `serve`: fixed formats, the
/// two stepped ladders (whose controller thresholds depend on the
/// solver family), GMRES-based iterative refinement (`ir`, which
/// drives its own inner GMRES and accepts every `--precond`), and
/// `auto` — the entropy/byte-model-driven policy
/// ([`gsem::coordinator::policy`]) that picks per matrix digest and
/// caches the decision in the registry.
fn parse_format_choice(s: &str, solver: SolverKind, k: usize, scale: f64) -> Option<FormatChoice> {
    let stepped_base = match solver {
        SolverKind::Cg | SolverKind::Bicgstab => SteppedParams::cg_paper(),
        SolverKind::Gmres => SteppedParams::gmres_paper(),
    };
    match s {
        "auto" => Some(FormatChoice::Auto),
        "stepped" => Some(FormatChoice::Stepped { k, params: stepped_base.scaled(scale) }),
        "stepped-copy" => Some(FormatChoice::SteppedCopy { params: stepped_base.scaled(scale) }),
        "ir" => Some(FormatChoice::Ir { k }),
        other => parse_format(other, k),
    }
}

/// The `--precond` axis shared by `solve` and `serve`: `none`
/// (default), `jacobi`, or `sainv` (drop tolerance from `--drop-tol`,
/// exponent-group width shared with `--k`). SAINV requires
/// `--format ir`; the dispatch layer enforces that with a typed error.
fn parse_precond(cli: &Cli, k: usize) -> Result<Precond, String> {
    match cli.get_or("precond", "none") {
        "none" => Ok(Precond::None),
        "jacobi" => Ok(Precond::Jacobi),
        "sainv" => {
            let Ok(drop_tol) = cli.get_f64("drop-tol", 0.1) else {
                return Err("--drop-tol failed to parse".into());
            };
            Ok(Precond::Sainv(SainvParams { drop_tol, k }))
        }
        other => Err(format!("unknown preconditioner {other} (none|jacobi|sainv)")),
    }
}

fn cmd_solve(cli: &Cli) -> i32 {
    let Some(spec) = cli.get("matrix") else {
        eprintln!("--matrix required");
        return 2;
    };
    let Some(solver) = parse_solver(cli.get_or("solver", "cg")) else {
        eprintln!("unknown solver {}", cli.get_or("solver", "cg"));
        return 2;
    };
    let k = cli.get_usize("k", 8).unwrap_or(8);
    let fmt_str = cli.get_or("format", "stepped");
    let scale = cli.get_f64("scale", 0.02).unwrap_or(0.02);
    let Some(format) = parse_format_choice(fmt_str, solver, k, scale) else {
        eprintln!("unknown format {fmt_str}");
        return 2;
    };
    let precond = match parse_precond(cli, k) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let a = match load_matrix(spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let nrhs = cli.get_usize("nrhs", 1).unwrap_or(1).max(1);
    let mut req = SolveRequest::new(spec, Arc::new(a), solver, format);
    req.precond = precond;
    req.tol = cli.get_f64("tol", 1e-6).unwrap_or(1e-6);
    if nrhs > 1 {
        // --workers 0 = auto, matching serve/suite
        let workers = match cli.get_usize("workers", 1).unwrap_or(1) {
            0 => gsem::util::parallel::default_workers(),
            n => n,
        };
        return solve_multi_rhs(req, nrhs, solver, workers);
    }
    // redeem breakdowns so the outcome line still prints (the paper's
    // "/" rows); other typed errors have no partial result to show
    let res = match gsem::coordinator::jobs::dispatch(&req) {
        Ok(r) => r,
        Err(ServiceError::Breakdown(b)) => *b,
        Err(e) => {
            eprintln!("solve failed: {e}");
            return 1;
        }
    };
    println!(
        "{} [{}] {}: iters={} converged={} relres(solver)={} relres(FP64)={:.3E} time={:.3}s",
        res.name,
        res.format_label,
        solver_name(solver),
        res.outcome.iters,
        res.outcome.converged,
        res.outcome.relres_label(),
        res.relres_fp64,
        res.outcome.seconds
    );
    if !res.outcome.switches.is_empty() {
        println!("precision switches: {:?}", res.outcome.switches);
    }
    if res.outcome.converged {
        0
    } else {
        1
    }
}

fn solver_name(solver: SolverKind) -> &'static str {
    match solver {
        SolverKind::Cg => "CG",
        SolverKind::Gmres => "GMRES",
        SolverKind::Bicgstab => "BiCGSTAB",
    }
}

/// `solve --nrhs N`: N independent random right-hand sides on one
/// matrix, run through the pool (`--workers` sizes it). Every
/// solver/format combination — CG, GMRES and BiCGSTAB over fixed
/// formats, plus both stepped ladders — merges into a single multi-RHS
/// block solve over the cached operator (stepped blocks share one
/// precision ladder across per-column controllers; see the
/// `pool.batched_*` and `cache.*` counters printed at the end).
fn solve_multi_rhs(req: SolveRequest, nrhs: usize, solver: SolverKind, workers: usize) -> i32 {
    let reqs: Vec<SolveRequest> = (0..nrhs)
        .map(|j| {
            let mut r = req.clone();
            r.name = format!("{}#{j}", req.name);
            r.rhs = RhsSpec::Random(1000 + j as u64);
            r
        })
        .collect();
    let pool = SolverPool::new(workers);
    let mut t = TextTable::new(&["rhs", "format", "iters", "relres(FP64)", "time(s)"]);
    let mut all_ok = true;
    for r in pool.run_batch(reqs) {
        let r = match r {
            Ok(r) => r,
            Err(ServiceError::Breakdown(b)) => *b,
            Err(e) => {
                eprintln!("solve failed: {e}");
                all_ok = false;
                continue;
            }
        };
        all_ok &= r.outcome.converged;
        t.row(&[
            r.name.clone(),
            r.format_label.clone(),
            r.outcome.iters.to_string(),
            format!("{:.3E}", r.relres_fp64),
            format!("{:.3}", r.outcome.seconds),
        ]);
    }
    println!("{} x{nrhs} RHS (pool-batched where possible)", solver_name(solver));
    t.print();
    print!("{}", pool.metrics().report());
    if all_ok {
        0
    } else {
        1
    }
}

/// `serve`: replay a request trace with staggered arrivals through the
/// windowed [`SolverService`]. Requests round-robin over the trace
/// matrices (one `--matrix`, or the first three CG-set entries), each
/// with a distinct random RHS; the intake merges whatever lands in the
/// same window into multi-RHS block solves. Prints the per-request
/// table, throughput, and the full metrics report (`intake.*`,
/// `cache.*`, `pool.batched_*`).
fn cmd_serve(cli: &Cli) -> i32 {
    if cli.flag("soak") {
        return cmd_serve_soak(cli);
    }
    let (requests, window_ms, batch_width, stagger_us, cache_mb) = match (
        cli.get_usize("requests", 24),
        cli.get_u64("window-ms", 5),
        cli.get_usize("batch-width", 8),
        cli.get_u64("stagger-us", 300),
        cli.get_usize("cache-mb", 0),
    ) {
        (Ok(r), Ok(w), Ok(b), Ok(s), Ok(c)) => (r.max(1), w, b, s, c),
        _ => {
            eprintln!("serve: numeric option failed to parse");
            return 2;
        }
    };
    let (workers_opt, k, scale, tol) = match (
        cli.get_usize("workers", 0),
        cli.get_usize("k", 8),
        cli.get_f64("scale", 0.02),
        cli.get_f64("tol", 1e-6),
    ) {
        (Ok(w), Ok(k), Ok(s), Ok(t)) => (w, k, s, t),
        _ => {
            eprintln!("serve: numeric option failed to parse");
            return 2;
        }
    };
    let (queue_depth, deadline_ms, op_threads) = match (
        cli.get_usize("queue-depth", 0),
        cli.get_u64("deadline-ms", 0),
        cli.get_usize("op-threads", 0),
    ) {
        (Ok(q), Ok(d), Ok(t)) => (q, d, t),
        _ => {
            eprintln!("serve: numeric option failed to parse");
            return 2;
        }
    };
    // --workers 0 = auto (machine parallelism / GSEM_WORKERS)
    let workers = match workers_opt {
        0 => gsem::util::parallel::default_workers(),
        n => n,
    };
    let Some(solver) = parse_solver(cli.get_or("solver", "cg")) else {
        eprintln!("unknown solver {}", cli.get_or("solver", "cg"));
        return 2;
    };
    // serving default: let the policy pick per digest — hand-picked
    // formats remain available via --format
    let fmt_str = cli.get_or("format", "auto");
    let Some(format) = parse_format_choice(fmt_str, solver, k, scale) else {
        eprintln!("unknown format {fmt_str}");
        return 2;
    };
    let precond = match parse_precond(cli, k) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mats: Vec<(String, Arc<Csr>)> = match cli.get("matrix") {
        Some(spec) => match load_matrix(spec) {
            Ok(a) => vec![(spec.to_string(), Arc::new(a))],
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        None => cg_set(CorpusSize::Small)
            .into_iter()
            .take(3)
            .map(|m| (m.name, Arc::new(m.a)))
            .collect(),
    };
    let mut cfg = ServiceConfig::new()
        .workers(workers)
        .window_ms(window_ms)
        .batch_width(batch_width)
        // 0 = allocator-managed intra-group budgets (the default)
        .op_threads(op_threads);
    if cache_mb > 0 {
        cfg = cfg.cache_bytes(cache_mb << 20);
    }
    if queue_depth > 0 {
        cfg = cfg.queue_depth(queue_depth);
    }
    if let Some(dir) = cli.get("spill-dir") {
        cfg = cfg.spill_dir(dir);
    }
    let svc = SolverService::new(cfg);
    // register each trace matrix once; handles are cheap to clone and
    // carry the digest, so the submit loop never re-hashes
    let handles: Vec<(String, gsem::coordinator::MatrixHandle)> =
        mats.iter().map(|(name, a)| (name.clone(), svc.register(a))).collect();
    println!(
        "serving {requests} staggered requests over {} matrices \
         (window {window_ms}ms, batch width {batch_width}, workers {workers}, \
         stagger {stagger_us}us)",
        mats.len()
    );
    let timer = Timer::start();
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for i in 0..requests {
        let (mname, handle) = &handles[i % handles.len()];
        let name = format!("{mname}#{i}");
        let mut spec = SolveSpec::new(&name, handle.clone(), solver, format.clone())
            .rhs(RhsSpec::Random(1000 + i as u64))
            .precond(precond.clone())
            .tol(tol);
        if deadline_ms > 0 {
            spec = spec.deadline_in(std::time::Duration::from_millis(deadline_ms));
        }
        match svc.submit(spec) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                shed += 1;
                eprintln!("  request {i}: {e}");
            }
        }
        if stagger_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(stagger_us));
        }
    }
    let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
    let wall = timer.elapsed_s();
    let mut t = TextTable::new(&["request", "format", "iters", "relres(FP64)", "time(s)"]);
    let mut all_ok = true;
    let (mut expired, mut errors) = (0usize, 0usize);
    for r in results {
        let r = match r {
            Ok(r) => r,
            Err(ServiceError::Breakdown(b)) => *b,
            Err(e @ ServiceError::DeadlineExceeded { .. }) => {
                expired += 1;
                println!("  {e}");
                continue;
            }
            Err(e) => {
                errors += 1;
                eprintln!("  {e}");
                continue;
            }
        };
        all_ok &= r.outcome.converged;
        t.row(&[
            r.name.clone(),
            r.format_label.clone(),
            r.outcome.iters.to_string(),
            format!("{:.3E}", r.relres_fp64),
            format!("{:.3}", r.outcome.seconds),
        ]);
    }
    t.print();
    if shed + expired > 0 {
        println!("shed {shed}  deadline-expired {expired}");
    }
    println!("wall {:.3}s  ({:.1} req/s)", wall, requests as f64 / wall);
    print!("{}", svc.metrics().report());
    if let Some(path) = cli.get("metrics-json") {
        match std::fs::write(path, svc.metrics().snapshot().to_json()) {
            Ok(()) => println!("wrote metrics to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                return 1;
            }
        }
    }
    if all_ok && errors == 0 {
        0
    } else {
        1
    }
}

/// Bitwise equality of two solution vectors — the block-solve parity
/// contract is *identical to single dispatch*, not merely close.
fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One-shot reference dispatch for the soak parity audits: same
/// name/matrix/solver/format/seed as the serviced ticket.
fn one_shot(
    name: &str,
    a: &Arc<Csr>,
    solver: SolverKind,
    format: &FormatChoice,
    seed: u64,
) -> Option<SolveResult> {
    let mut req = SolveRequest::new(name, Arc::clone(a), solver, format.clone());
    req.rhs = RhsSpec::Random(seed);
    gsem::coordinator::jobs::dispatch(&req).ok()
}

/// `serve --soak`: the serving-hardening soak harness, three phases.
///
/// * **A — overload.** Burst-submit past a small bounded queue on a
///   manual-flush service. The overflow must shed with typed
///   `Overloaded` errors, and every *admitted* ticket must match its
///   one-shot dispatch bitwise.
/// * **B — deadlines + cancellation.** A staggered trace flushed in
///   windows, with already-expired deadlines and post-submit cancels
///   mixed in. Expired/cancelled tickets must resolve with the matching
///   typed error; survivors are parity-audited against one-shot runs.
/// * **C — spill/restore.** GSE-encoded solves over several matrices
///   under a tiny cache byte budget with a spill directory, then the
///   same digests re-touched: the second pass must be answered by spill
///   restores (restore counter > 0) with zero re-encodes, bitwise equal
///   to the first pass.
/// * **D — preconditioner residency.** Repeated SAINV GMRES-IR traffic
///   over two digests: the registry must build each digest's factors
///   exactly once (`precond.builds` == digest count) while every
///   ticket converges and matches its one-shot dispatch bitwise.
/// * **E — auto-format policy residency.** Two passes of
///   `--format auto` traffic over the same digests: the first pass
///   computes one policy decision per digest (`policy.decisions`), the
///   second is answered entirely from the registry cache
///   (`policy.cache_hits`), and every serviced result matches its
///   one-shot Auto dispatch bitwise.
///
/// Prints one summary line per phase, optionally writes a combined
/// `--metrics-json` snapshot (`overload` / `deadline_cancel` /
/// `spill_restore` / `precond` / `policy` keys), and exits non-zero if
/// any check fails. `GSEM_BENCH_FAST=1` shrinks the trace for CI smoke
/// runs.
fn cmd_serve_soak(cli: &Cli) -> i32 {
    let fast = std::env::var("GSEM_BENCH_FAST").is_ok();
    let (queue_depth, cache_kb, stagger_us) = match (
        cli.get_usize("queue-depth", 8),
        cli.get_usize("soak-cache-kb", 24),
        cli.get_u64("stagger-us", 200),
    ) {
        (Ok(q), Ok(c), Ok(s)) => (q.max(1), c.max(1), s),
        _ => {
            eprintln!("serve --soak: numeric option failed to parse");
            return 2;
        }
    };
    let workers = match cli.get_usize("workers", 0).unwrap_or(0) {
        0 => gsem::util::parallel::default_workers(),
        n => n,
    };
    let spill_dir = match cli.get("spill-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join("gsem-soak-spill"),
    };
    let mats: Vec<(String, Arc<Csr>)> = cg_set(CorpusSize::Small)
        .into_iter()
        .take(4)
        .map(|m| (m.name, Arc::new(m.a)))
        .collect();
    let fp64 = FormatChoice::fixed(ValueFormat::Fp64);
    let mut failures: Vec<String> = Vec::new();

    // -- phase A: burst past the bounded queue; audit the admitted side
    let svc = SolverService::manual(ServiceConfig::new().workers(workers).queue_depth(queue_depth));
    let (name0, a0) = &mats[0];
    let handle0 = svc.register(a0);
    let burst = if fast { queue_depth + 4 } else { queue_depth * 3 };
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for i in 0..burst {
        let name = format!("{name0}/soak-a#{i}");
        let spec = SolveSpec::new(&name, handle0.clone(), SolverKind::Cg, fp64.clone())
            .rhs(RhsSpec::Random(7000 + i as u64));
        match svc.submit(spec) {
            Ok(t) => admitted.push((i, t)),
            Err(ServiceError::Overloaded { .. }) => shed += 1,
            Err(e) => failures.push(format!("phase A: unexpected submit error: {e}")),
        }
    }
    let n_admitted = admitted.len();
    svc.flush();
    let mut parity_a = true;
    for (i, t) in admitted {
        match t.wait() {
            Ok(r) => match one_shot(&r.name, a0, SolverKind::Cg, &fp64, 7000 + i as u64) {
                Some(s)
                    if bits_eq(&r.outcome.x, &s.outcome.x)
                        && r.outcome.iters == s.outcome.iters => {}
                _ => parity_a = false,
            },
            Err(e) => failures.push(format!("phase A: admitted ticket failed: {e}")),
        }
    }
    if shed == 0 || svc.metrics().counter("intake.shed") == 0 {
        failures.push("phase A: burst was not shed (expected typed Overloaded)".into());
    }
    if !parity_a {
        failures.push("phase A: admitted results diverge from one-shot dispatch".into());
    }
    println!(
        "soak A (overload): burst={burst} admitted={n_admitted} shed={shed} parity={}",
        if parity_a { "ok" } else { "MISMATCH" }
    );
    let snap_a = svc.metrics().snapshot();
    drop(svc);

    // -- phase B: staggered trace with expired deadlines and cancels
    let svc = SolverService::manual(
        ServiceConfig::new().workers(workers).queue_depth(4 * queue_depth.max(8)),
    );
    let handles: Vec<_> = mats.iter().map(|(_, a)| svc.register(a)).collect();
    let n_req = if fast { 16 } else { 56 };
    let mut tickets = Vec::new();
    for i in 0..n_req {
        let (mname, _) = &mats[i % mats.len()];
        let name = format!("{mname}/soak-b#{i}");
        let handle = handles[i % handles.len()].clone();
        let mut spec = SolveSpec::new(&name, handle, SolverKind::Cg, fp64.clone())
            .rhs(RhsSpec::Random(8000 + i as u64))
            .priority((i % 3) as i32 - 1);
        let expect = if i % 5 == 0 {
            spec = spec.deadline_in(std::time::Duration::ZERO);
            "deadline"
        } else if i % 7 == 0 {
            "cancel"
        } else {
            spec = spec.deadline_in(std::time::Duration::from_secs(600));
            "ok"
        };
        match svc.submit(spec) {
            Ok(t) => {
                if expect == "cancel" {
                    t.cancel();
                }
                tickets.push((i, t, expect));
            }
            Err(e) => failures.push(format!("phase B: submit {i}: {e}")),
        }
        if (i + 1) % 8 == 0 {
            svc.flush();
        }
        if stagger_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(stagger_us));
        }
    }
    svc.flush();
    let (mut n_ok, mut n_dead, mut n_cancel) = (0usize, 0usize, 0usize);
    let mut parity_b = true;
    for (i, t, expect) in tickets {
        match (expect, t.wait()) {
            ("deadline", Err(ServiceError::DeadlineExceeded { .. })) => n_dead += 1,
            ("cancel", Err(ServiceError::Cancelled { .. })) => n_cancel += 1,
            ("ok", Ok(r)) => {
                n_ok += 1;
                let a = &mats[i % mats.len()].1;
                match one_shot(&r.name, a, SolverKind::Cg, &fp64, 8000 + i as u64) {
                    Some(s) if bits_eq(&r.outcome.x, &s.outcome.x) => {}
                    _ => parity_b = false,
                }
            }
            (exp, got) => {
                let got = match got {
                    Ok(r) => format!("ok ({})", r.name),
                    Err(e) => e.to_string(),
                };
                failures.push(format!("phase B: request {i} expected {exp}, got {got}"));
            }
        }
    }
    if n_dead == 0 {
        failures.push("phase B: no deadline expiries observed".into());
    }
    if n_cancel == 0 {
        failures.push("phase B: no cancellations observed".into());
    }
    if !parity_b {
        failures.push("phase B: surviving results diverge from one-shot dispatch".into());
    }
    println!(
        "soak B (deadline/cancel): ok={n_ok} deadline={n_dead} cancelled={n_cancel} parity={}",
        if parity_b { "ok" } else { "MISMATCH" }
    );
    let snap_b = svc.metrics().snapshot();
    drop(svc);

    // -- phase C: churn a tiny cache over GSE encodes, then re-touch
    if let Err(e) = std::fs::create_dir_all(&spill_dir) {
        eprintln!("serve --soak: cannot create spill dir {}: {e}", spill_dir.display());
        return 1;
    }
    let svc = SolverService::manual(
        ServiceConfig::new()
            .workers(workers)
            .cache_bytes(cache_kb << 10)
            .spill_dir(spill_dir.clone()),
    );
    let gse = FormatChoice::Fixed { format: ValueFormat::GseSem(Precision::Full), k: 8 };
    let handles: Vec<_> = mats.iter().map(|(_, a)| svc.register(a)).collect();
    let mut firsts = Vec::new();
    for (j, (mname, _)) in mats.iter().enumerate() {
        let name = format!("{mname}/soak-c");
        let spec = SolveSpec::new(&name, handles[j].clone(), SolverKind::Cg, gse.clone())
            .rhs(RhsSpec::Random(9000 + j as u64));
        match svc.submit(spec) {
            Ok(t) => {
                svc.flush();
                firsts.push(t.wait());
            }
            Err(e) => failures.push(format!("phase C: submit {mname}: {e}")),
        }
    }
    let encode_before = svc.metrics().timing("cache.encode").0;
    let mut parity_c = true;
    for (j, (mname, _)) in mats.iter().enumerate() {
        let name = format!("{mname}/soak-c");
        let spec = SolveSpec::new(&name, handles[j].clone(), SolverKind::Cg, gse.clone())
            .rhs(RhsSpec::Random(9000 + j as u64));
        match svc.submit(spec) {
            Ok(t) => {
                svc.flush();
                match (t.wait(), firsts.get(j)) {
                    (Ok(r2), Some(Ok(r1))) if bits_eq(&r1.outcome.x, &r2.outcome.x) => {}
                    _ => parity_c = false,
                }
            }
            Err(e) => failures.push(format!("phase C: resubmit {mname}: {e}")),
        }
    }
    let encode_after = svc.metrics().timing("cache.encode").0;
    let stats = svc.registry().stats();
    if stats.spills == 0 {
        failures.push("phase C: eviction never spilled (cache budget too large?)".into());
    }
    if stats.restores == 0 {
        failures.push("phase C: digest re-hit was not answered from spill".into());
    }
    if encode_after != encode_before {
        failures.push(format!(
            "phase C: {} re-encode(s) on the restore pass",
            encode_after - encode_before
        ));
    }
    if !parity_c {
        failures.push("phase C: restored operator changed the solve bitwise".into());
    }
    println!(
        "soak C (spill/restore): spills={} restores={} restore_bytes={} re-encodes={} parity={}",
        stats.spills,
        stats.restores,
        stats.restore_bytes,
        encode_after - encode_before,
        if parity_c { "ok" } else { "MISMATCH" }
    );
    let snap_c = svc.metrics().snapshot();
    drop(svc);

    // -- phase D: SAINV factor residency under repeated GMRES-IR traffic
    let svc = SolverService::manual(ServiceConfig::new().workers(workers));
    let ir = FormatChoice::Ir { k: 8 };
    let sainv = Precond::Sainv(SainvParams { drop_tol: 0.1, k: 8 });
    let dmats = &mats[..2];
    let dhandles: Vec<_> = dmats.iter().map(|(_, a)| svc.register(a)).collect();
    let reps = if fast { 3 } else { 6 };
    let mut tickets = Vec::new();
    for (j, (mname, _)) in dmats.iter().enumerate() {
        for i in 0..reps {
            let name = format!("{mname}/soak-d#{i}");
            let spec = SolveSpec::new(&name, dhandles[j].clone(), SolverKind::Gmres, ir.clone())
                .rhs(RhsSpec::Random(9500 + (j * reps + i) as u64))
                .precond(sainv.clone());
            match svc.submit(spec) {
                Ok(t) => tickets.push((j, i, t)),
                Err(e) => failures.push(format!("phase D: submit {name}: {e}")),
            }
        }
    }
    let n_d = tickets.len();
    svc.flush();
    let mut parity_d = true;
    let mut d_ok = 0usize;
    for (j, i, t) in tickets {
        match t.wait() {
            Ok(r) => {
                if !r.outcome.converged || r.format_label != "GSE-IR(sainv)" {
                    failures.push(format!(
                        "phase D: {} did not converge as GSE-IR(sainv) (label {}, relres {:.3E})",
                        r.name, r.format_label, r.relres_fp64
                    ));
                    continue;
                }
                d_ok += 1;
                // per-ticket parity against one-shot IR dispatch (its
                // own factor build through the global registry)
                let a = &dmats[j].1;
                let mut req =
                    SolveRequest::new(&r.name, Arc::clone(a), SolverKind::Gmres, ir.clone());
                req.rhs = RhsSpec::Random(9500 + (j * reps + i) as u64);
                req.precond = sainv.clone();
                match gsem::coordinator::jobs::dispatch(&req) {
                    Ok(s) if bits_eq(&r.outcome.x, &s.outcome.x) => {}
                    _ => parity_d = false,
                }
            }
            Err(e) => failures.push(format!("phase D: ticket failed: {e}")),
        }
    }
    let builds = svc.metrics().counter("precond.builds");
    if builds != dmats.len() as u64 {
        failures.push(format!(
            "phase D: expected {} sainv builds (one per digest), got {builds}",
            dmats.len()
        ));
    }
    if !parity_d {
        failures.push("phase D: serviced IR results diverge from one-shot dispatch".into());
    }
    println!(
        "soak D (precond): requests={n_d} ok={d_ok} sainv_builds={builds} parity={}",
        if parity_d { "ok" } else { "MISMATCH" }
    );
    let snap_d = svc.metrics().snapshot();
    drop(svc);

    // -- phase E: auto-format policy residency + one-shot parity
    let svc = SolverService::manual(ServiceConfig::new().workers(workers));
    let ehandles: Vec<_> = mats.iter().map(|(_, a)| svc.register(a)).collect();
    let auto = FormatChoice::Auto;
    let mut parity_e = true;
    let mut e_firsts: Vec<Option<SolveResult>> = vec![None; mats.len()];
    for pass in 0..2usize {
        for (j, (mname, a)) in mats.iter().enumerate() {
            let name = format!("{mname}/soak-e");
            let spec = SolveSpec::new(&name, ehandles[j].clone(), SolverKind::Cg, auto.clone())
                .rhs(RhsSpec::Random(9900 + j as u64));
            match svc.submit(spec) {
                Ok(t) => {
                    svc.flush();
                    match t.wait() {
                        Ok(r) => {
                            if pass == 0 {
                                // one-shot Auto dispatch resolves the
                                // same digest-deterministic decision
                                match one_shot(&r.name, a, SolverKind::Cg, &auto, 9900 + j as u64)
                                {
                                    Some(s) if bits_eq(&r.outcome.x, &s.outcome.x) => {}
                                    _ => parity_e = false,
                                }
                                e_firsts[j] = Some(r);
                            } else {
                                match &e_firsts[j] {
                                    Some(r1) if bits_eq(&r1.outcome.x, &r.outcome.x) => {}
                                    _ => parity_e = false,
                                }
                            }
                        }
                        Err(e) => failures.push(format!("phase E: ticket {mname}: {e}")),
                    }
                }
                Err(e) => failures.push(format!("phase E: submit {mname}: {e}")),
            }
        }
    }
    let decisions = svc.metrics().counter("policy.decisions");
    let cache_hits = svc.metrics().counter("policy.cache_hits");
    if decisions != mats.len() as u64 {
        failures.push(format!(
            "phase E: expected {} policy decisions (one per digest), got {decisions}",
            mats.len()
        ));
    }
    if cache_hits != mats.len() as u64 {
        failures.push(format!(
            "phase E: expected {} policy cache hits on the second pass, got {cache_hits}",
            mats.len()
        ));
    }
    if !parity_e {
        failures.push("phase E: auto-format results diverge across passes/one-shot".into());
    }
    println!(
        "soak E (auto): decisions={decisions} cache_hits={cache_hits} fallbacks={} parity={}",
        svc.metrics().counter("policy.fallbacks"),
        if parity_e { "ok" } else { "MISMATCH" }
    );
    let snap_e = svc.metrics().snapshot();

    if let Some(path) = cli.get("metrics-json") {
        let json = format!(
            "{{\"overload\":{},\"deadline_cancel\":{},\"spill_restore\":{},\"precond\":{},\
             \"policy\":{}}}\n",
            snap_a.to_json(),
            snap_b.to_json(),
            snap_c.to_json(),
            snap_d.to_json(),
            snap_e.to_json()
        );
        if let Err(e) = std::fs::write(path, json) {
            eprintln!("serve --soak: cannot write {path}: {e}");
            return 1;
        }
        println!("wrote soak metrics to {path}");
    }
    if failures.is_empty() {
        println!("soak: all checks passed");
        0
    } else {
        for f in &failures {
            eprintln!("soak FAIL: {f}");
        }
        1
    }
}

fn cmd_suite(cli: &Cli) -> i32 {
    let size = match cli.get_or("size", "small") {
        "small" => CorpusSize::Small,
        "full" => CorpusSize::Full,
        _ => CorpusSize::Medium,
    };
    let which = cli.get_or("solver", "both");
    let scale = cli.get_f64("scale", 0.02).unwrap_or(0.02);
    // --workers 0 = auto (machine parallelism / GSEM_WORKERS)
    let pool = match cli.get_usize("workers", 1).unwrap_or(1) {
        0 => SolverPool::with_default_workers(),
        n => SolverPool::new(n),
    };
    let formats: [(&str, FormatChoice); 3] = [
        ("FP64", FormatChoice::fixed(ValueFormat::Fp64)),
        ("FP16", FormatChoice::fixed(ValueFormat::Fp16)),
        ("BF16", FormatChoice::fixed(ValueFormat::Bf16)),
    ];
    for (solver, set) in
        [(SolverKind::Cg, cg_set(size)), (SolverKind::Gmres, gmres_set(size))]
    {
        if which != "both"
            && !(which == "cg" && solver == SolverKind::Cg)
            && !(which == "gmres" && solver == SolverKind::Gmres)
        {
            continue;
        }
        let stepped_base = match solver {
            SolverKind::Gmres => SteppedParams::gmres_paper(),
            _ => SteppedParams::cg_paper(),
        };
        println!(
            "== {} suite ({} matrices) ==",
            if solver == SolverKind::Cg { "CG" } else { "GMRES" },
            set.len()
        );
        let mut t = TextTable::new(&["matrix", "format", "iters", "relres", "time(s)"]);
        for m in &set {
            let a = Arc::new(m.a.clone());
            let mut reqs: Vec<SolveRequest> = formats
                .iter()
                .map(|(_, f)| SolveRequest::new(&m.name, Arc::clone(&a), solver, f.clone()))
                .collect();
            reqs.push(SolveRequest::new(
                &m.name,
                Arc::clone(&a),
                solver,
                FormatChoice::Stepped { k: 8, params: stepped_base.scaled(scale) },
            ));
            for r in pool.run_batch(reqs) {
                let r = match r {
                    Ok(r) => r,
                    Err(ServiceError::Breakdown(b)) => *b,
                    Err(e) => {
                        eprintln!("{}: {e}", m.name);
                        continue;
                    }
                };
                t.row(&[
                    r.name.clone(),
                    r.format_label.clone(),
                    r.outcome.iters.to_string(),
                    r.outcome.relres_label(),
                    format!("{:.3}", r.outcome.seconds),
                ]);
            }
        }
        t.print();
    }
    // operator-cache + batching counters accumulated across the suite
    print!("{}", pool.metrics().report());
    0
}

fn cmd_kernels(_cli: &Cli) -> i32 {
    match gsem::runtime::Engine::load_default() {
        Ok(None) => {
            eprintln!("artifacts/ not built — run `make artifacts` first");
            1
        }
        Err(e) => {
            eprintln!("engine load failed: {e:#}");
            1
        }
        Ok(Some(mut engine)) => {
            println!("PJRT platform: {}", engine.platform());
            let names = engine.kernel_names();
            for n in &names {
                match engine.kernel(n) {
                    Ok(k) => println!(
                        "  {n}: inputs {:?} dtypes {:?} outputs {}",
                        k.entry.inputs, k.entry.dtypes, k.entry.outputs
                    ),
                    Err(e) => {
                        eprintln!("  {n}: COMPILE FAILED: {e:#}");
                        return 1;
                    }
                }
            }
            0
        }
    }
}

fn cmd_gen(cli: &Cli) -> i32 {
    if cli.flag("list") {
        let size = CorpusSize::from_env();
        for m in spmv_corpus(size).iter().chain(&cg_set(size)).chain(&gmres_set(size)) {
            println!(
                "{:<28} {:>9} x {:<9} nnz {:<10} [{}]",
                m.name,
                m.a.nrows,
                m.a.ncols,
                m.a.nnz(),
                m.class
            );
        }
        return 0;
    }
    let (Some(spec), Some(out)) = (cli.get("matrix"), cli.get("out")) else {
        eprintln!("--matrix and --out required (or --list)");
        return 2;
    };
    match load_matrix(spec)
        .and_then(|a| mm::write_path(&a, Path::new(out)).map_err(|e| format!("{e:#}")))
    {
        Ok(()) => {
            println!("wrote {out}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
