//! Typed serving-path errors — the coordinator's failure taxonomy.
//!
//! Every way a submitted solve can fail to produce a clean result maps
//! onto one [`ServiceError`] variant, so callers match on *what
//! happened* (shed vs. expired vs. solver breakdown) instead of parsing
//! strings:
//!
//! * [`ServiceError::Overloaded`] — the bounded intake queue was full;
//!   the request was shed at submit time (admission control).
//! * [`ServiceError::DeadlineExceeded`] — the ticket's deadline passed
//!   before its group flushed, or mid-solve (the column deflated out of
//!   its block).
//! * [`ServiceError::Cancelled`] — [`SolveTicket::cancel`] fired, either
//!   before the flush or mid-solve (column deflation).
//! * [`ServiceError::Breakdown`] — the solver hit non-finite values
//!   (the paper's "/" rows); carries the partial [`SolveResult`] so the
//!   iteration count / history stay inspectable.
//! * [`ServiceError::Registry`] — an operator registry / spill-layer
//!   failure, wrapping the [`crate::util::error::Error`] chain.
//! * [`ServiceError::Shutdown`] — the service dropped before answering.
//!
//! [`SolveTicket::cancel`]: super::intake::SolveTicket::cancel

use super::jobs::SolveResult;
use std::fmt;

/// Typed failure of a serving-path solve. See the module docs for the
/// taxonomy.
#[derive(Clone, Debug)]
pub enum ServiceError {
    /// Admission control shed the request: the bounded intake queue
    /// held `depth` pending solves and accepted no more.
    Overloaded {
        /// Queue depth at the moment of rejection.
        depth: usize,
    },
    /// The ticket's deadline expired before a result was produced.
    DeadlineExceeded {
        /// Request name, for attribution in logs.
        name: String,
    },
    /// The ticket was cancelled via `SolveTicket::cancel`.
    Cancelled {
        /// Request name, for attribution in logs.
        name: String,
    },
    /// The solver broke down (non-finite values — FP16 overflow, a
    /// degenerate recurrence). The boxed result carries the partial
    /// outcome: iterations completed, residual history, last iterate.
    Breakdown(Box<SolveResult>),
    /// Operator registry / spill failure.
    Registry(crate::util::error::Error),
    /// The service shut down before this ticket was answered.
    Shutdown,
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Overloaded { depth } => {
                write!(f, "overloaded: intake queue full at depth {depth}")
            }
            Self::DeadlineExceeded { name } => write!(f, "deadline exceeded: {name}"),
            Self::Cancelled { name } => write!(f, "cancelled: {name}"),
            Self::Breakdown(r) => write!(
                f,
                "solver breakdown: {} [{}] after {} iters",
                r.name, r.format_label, r.outcome.iters
            ),
            Self::Registry(e) => write!(f, "registry: {e:#}"),
            Self::Shutdown => write!(f, "service shut down before answering"),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<crate::util::error::Error> for ServiceError {
    fn from(e: crate::util::error::Error) -> Self {
        Self::Registry(e)
    }
}

/// Map a raw solver result onto the typed surface: breakdowns (the only
/// in-band failure a solve itself produces) become
/// [`ServiceError::Breakdown`], everything else passes through — a
/// non-*converged* run is still an `Ok` result (the caller reads
/// `outcome.converged`), exactly as the paper's tables report stalled
/// runs alongside converged ones.
pub(crate) fn classify(res: SolveResult) -> Result<SolveResult, ServiceError> {
    if res.outcome.broke_down {
        Err(ServiceError::Breakdown(Box::new(res)))
    } else {
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SolveOutcome;

    fn result(broke_down: bool) -> SolveResult {
        SolveResult {
            name: "t".into(),
            solver: super::super::jobs::SolverKind::Cg,
            format_label: "FP64".into(),
            outcome: SolveOutcome {
                converged: false,
                iters: 3,
                relres: 0.5,
                history: vec![],
                switches: vec![],
                seconds: 0.0,
                x: vec![],
                broke_down,
            },
            relres_fp64: 0.5,
        }
    }

    #[test]
    fn classify_splits_breakdown_from_stall() {
        assert!(classify(result(false)).is_ok());
        match classify(result(true)) {
            Err(ServiceError::Breakdown(b)) => assert_eq!(b.outcome.iters, 3),
            other => panic!("expected Breakdown, got {other:?}"),
        }
    }

    #[test]
    fn display_is_informative() {
        let e = ServiceError::Overloaded { depth: 7 };
        assert!(e.to_string().contains("depth 7"));
        let e = ServiceError::DeadlineExceeded { name: "req".into() };
        assert!(e.to_string().contains("req"));
        let e: ServiceError = crate::util::error::Error::msg("disk full").into();
        assert!(e.to_string().contains("disk full"));
        // the std::error::Error impl is object-safe
        let _: &dyn std::error::Error = &e;
    }
}
