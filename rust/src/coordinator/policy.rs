//! Entropy-driven automatic format selection — the paper's "exponent
//! entropy is far below the 11 bits IEEE spends on it" observation
//! (§II, Fig. 1a) turned into a serving-path policy.
//!
//! Every caller used to pick [`FormatChoice`] by hand. With
//! [`FormatChoice::Auto`] the coordinator decides instead, per
//! registered matrix, from three inputs:
//!
//! 1. **Exponent entropy and dynamic range** of the matrix non-zeros
//!    *and* the reference right-hand side (`b = A·1`, so the analysis
//!    is a pure function of the matrix content) via
//!    [`crate::formats::entropy::analyze`]. They decide the GSE group
//!    count `k` (smallest table covering [`COVERAGE_TARGET`] of the
//!    exponent population, [`GseTable::auto_k`]) and whether a
//!    lowp/head rung is safe at all: populations wider than the safe
//!    thresholds refuse a head start (the stepped ladder escalates
//!    from the first residual check instead), and populations beyond
//!    the hard thresholds get plain fp64.
//! 2. **The [`crate::spmv::traffic`] byte model** at the request's
//!    batch width, ranking fp64 against the GSE head rung with the
//!    k-exact table bytes, per-nnz decode cost, k staging overhead and
//!    table-miss scan penalty. Wide batches legitimately flip the
//!    decision to fp64: RHS traffic dominates and the format stops
//!    mattering (modeled speedup below [`MIN_MODELED_SPEEDUP`]).
//! 3. **Observed stepped switch logs** ([`record_switches`], fed by
//!    every registry-backed stepped solve): when a digest's solves
//!    mostly escalate off the head rung in their first quarter, the
//!    ladder is not paying for its low-precision start and the policy
//!    collapses it to fp64 for that digest × solver.
//!
//! Decisions are **cached in the [`MatrixRegistry`]** per digest ×
//! solver × nrhs-bucket (power-of-two widths) through the same
//! latch/LRU/spill machinery as operators: computed exactly once under
//! concurrency, byte-charged, evictable and restorable from disk.
//! Resolution happens *before* intake grouping keys are formed, so an
//! Auto request merges with hand-picked requests for the same
//! configuration. Outcomes surface as `policy.decisions` /
//! `policy.cache_hits` / `policy.fallbacks` metrics.

use crate::coordinator::jobs::{FormatChoice, RhsSpec, SolverKind, DEFAULT_K};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::{MatrixHandle, MatrixRegistry};
use crate::formats::entropy;
use crate::formats::gse::ExpHistogram;
use crate::formats::{GseTable, Precision, ValueFormat};
use crate::solvers::sainv::Precond;
use crate::solvers::stepped::SteppedParams;
use crate::sparse::csr::{Csr, MatrixDigest};
use crate::sparse::stats::matrix_stats;
use crate::spmv::traffic::{k_overhead_time, V100};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Above this exponent range (bits between the largest and smallest
/// non-zero magnitude), the head/lowp rung is refused as a starting
/// point and the ladder escalates from the first check.
pub const SAFE_EXP_RANGE_BITS: f64 = 24.0;

/// Above this exponent-field entropy (bits), same refusal.
pub const SAFE_EXP_ENTROPY_BITS: f64 = 4.5;

/// Beyond this range the population is treated as fp64-only — no GSE
/// rung (subnormal-heavy or wildly ill-scaled instances land here).
pub const HARD_EXP_RANGE_BITS: f64 = 48.0;

/// Beyond this exponent entropy, same fp64-only fallback.
pub const HARD_EXP_ENTROPY_BITS: f64 = 6.0;

/// Exponent-population coverage the auto-sized GSE table must reach
/// ([`GseTable::auto_k`] picks the smallest k achieving it).
pub const COVERAGE_TARGET: f64 = 0.99;

/// Minimum modeled head-rung speedup over fp64 for a GSE choice to be
/// worth the table + decode overhead at the request's batch width.
pub const MIN_MODELED_SPEEDUP: f64 = 1.02;

/// Row count at which the stepped controller runs the paper's full
/// iteration schedule; smaller systems shrink it proportionally
/// ([`SteppedParams::scaled`], floored at [`MIN_PARAM_SCALE`]).
const PARAM_SCALE_ROWS: f64 = 150_000.0;
const MIN_PARAM_SCALE: f64 = 0.005;

/// Observed solves required before switch-log feedback may override
/// the entropy/byte-model decision (keeps decisions deterministic
/// until the evidence is real).
const FEEDBACK_MIN_SOLVES: u32 = 3;

/// Per-nnz bit-scan cost (seconds) for values whose exponent misses
/// the shared table — mirrors [`crate::spmv::traffic::gse_head_time_at_k`].
const MISS_SCAN_S: f64 = 0.004e-9;

/// One resolved auto-format decision (see module docs). `rationale` is
/// a human-readable account of which tier fired and why — it rides
/// spill round-trips so a restored decision still explains itself.
#[derive(Clone, Debug)]
pub struct PolicyDecision {
    /// The concrete choice (never [`FormatChoice::Auto`]).
    pub choice: FormatChoice,
    /// Why: the decision inputs and the tier that fired.
    pub rationale: String,
    /// True when a safety tier fired (hard/safe threshold exceeded) —
    /// exported as the `policy.fallbacks` counter.
    pub fallback: bool,
}

impl PolicyDecision {
    /// Resident size charged against the registry byte budget.
    pub fn encoded_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.rationale.len()
    }
}

/// Batch widths are bucketed to powers of two so nearby widths share
/// one cached decision (the byte model varies slowly in nrhs).
pub fn nrhs_bucket(nrhs: usize) -> usize {
    nrhs.max(1).next_power_of_two()
}

#[derive(Clone, Copy, Default)]
struct LadderFeedback {
    solves: u32,
    early_full: u32,
}

/// Process-wide switch-log accumulator. Keyed by digest × solver, like
/// the cached decisions it refines; a plain mutex is fine — recording
/// is a few loads per completed stepped solve.
fn feedback() -> &'static Mutex<HashMap<(MatrixDigest, SolverKind), LadderFeedback>> {
    static FEEDBACK: OnceLock<Mutex<HashMap<(MatrixDigest, SolverKind), LadderFeedback>>> =
        OnceLock::new();
    FEEDBACK.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Feed one completed stepped solve's escalation trace into the online
/// ladder-depth refinement: a switch to the full rung (tag ≥ 3) within
/// the first quarter of the solve means the low-precision start bought
/// almost nothing. Called by the dispatch and intake stepped paths.
pub fn record_switches(
    digest: MatrixDigest,
    solver: SolverKind,
    iters: usize,
    switches: &[(usize, u8)],
) {
    let early = switches.iter().any(|&(it, tag)| tag >= 3 && it.saturating_mul(4) <= iters);
    let mut map = feedback().lock().unwrap();
    let e = map.entry((digest, solver)).or_default();
    e.solves = e.solves.saturating_add(1);
    if early {
        e.early_full = e.early_full.saturating_add(1);
    }
}

/// Whether observed solves say the ladder's low start pays for this
/// digest × solver. Optimistic until [`FEEDBACK_MIN_SOLVES`] solves
/// are on record; after that, a majority of early full-escalations
/// collapses the ladder.
fn ladder_pays(digest: MatrixDigest, solver: SolverKind) -> bool {
    let map = feedback().lock().unwrap();
    match map.get(&(digest, solver)) {
        Some(f) if f.solves >= FEEDBACK_MIN_SOLVES => f.early_full * 2 < f.solves,
        _ => true,
    }
}

/// Modeled per-SpMV time of a format choice at a batch width — the
/// ranking function behind the policy's traffic tier, public so the
/// `ablation_autoformat` bench can report the same numbers it acted
/// on. Stepped/IR choices are modeled at their head rung (the rung
/// the ladder is meant to spend its bandwidth-bound iterations on);
/// an unresolved `Auto` models as fp64.
pub fn modeled_time(a: &Csr, choice: &FormatChoice, nrhs: usize) -> f64 {
    let nnz = a.nnz();
    let nrows = a.nrows;
    let nrhs = nrhs.max(1);
    let gse = |level: Precision, k: usize| {
        let mut hist = ExpHistogram::new();
        hist.push_all(&a.vals);
        let hit = hist.topk_coverage(k);
        V100.spmv_multi_time_at_k(nnz, nrows, ValueFormat::GseSem(level), nrhs, k)
            + k_overhead_time(&V100, k, nnz)
            + nnz as f64 * (1.0 - hit).max(0.0) * MISS_SCAN_S
    };
    match choice {
        FormatChoice::Fixed { format: ValueFormat::GseSem(level), k } => gse(*level, (*k).max(1)),
        FormatChoice::Fixed { format, .. } => {
            V100.spmv_multi_time_at_k(nnz, nrows, *format, nrhs, 0)
        }
        FormatChoice::Stepped { k, .. } | FormatChoice::Ir { k } => {
            gse(Precision::Head, (*k).max(1))
        }
        FormatChoice::SteppedCopy { .. } => {
            V100.spmv_multi_time_at_k(nnz, nrows, ValueFormat::Fp32, nrhs, 0)
        }
        FormatChoice::Auto => V100.spmv_multi_time_at_k(nnz, nrows, ValueFormat::Fp64, nrhs, 0),
    }
}

/// Stepped controller parameters for a solver, with the iteration
/// schedule scaled to the system size (deterministic per matrix shape,
/// so auto and repeated requests agree bit-for-bit).
fn stepped_params(solver: SolverKind, nrows: usize) -> SteppedParams {
    let base = match solver {
        SolverKind::Gmres => SteppedParams::gmres_paper(),
        SolverKind::Cg | SolverKind::Bicgstab => SteppedParams::cg_paper(),
    };
    base.scaled((nrows as f64 / PARAM_SCALE_ROWS).clamp(MIN_PARAM_SCALE, 1.0))
}

/// Compute a decision without any cache — the pure function the cached
/// path memoizes. Public for benches and tests that want the policy's
/// answer outside a registry; `nrhs` is bucketed exactly like the
/// cached path, so the two always agree.
pub fn decide(a: &Csr, solver: SolverKind, nrhs: usize) -> PolicyDecision {
    compute(a, a.digest(), solver, nrhs_bucket(nrhs))
}

/// The registry-cached decision for `(handle, solver, nrhs bucket)`:
/// computed once per key under concurrency (latch path), LRU-charged
/// and spill-safe. Counters: a fresh compute is `policy.decisions`
/// (+`policy.fallbacks` when a safety tier fired); anything served
/// from the cache — including a spill restore — is `policy.cache_hits`.
pub fn decide_cached(
    reg: &MatrixRegistry,
    h: &MatrixHandle,
    solver: SolverKind,
    nrhs: usize,
    metrics: Option<&Metrics>,
) -> Arc<PolicyDecision> {
    let bucket = nrhs_bucket(nrhs);
    let (d, built) =
        reg.policy(h, solver, bucket, metrics, || compute(h.matrix(), h.digest(), solver, bucket));
    if let Some(m) = metrics {
        if built {
            m.incr("policy.decisions");
            if d.fallback {
                m.incr("policy.fallbacks");
            }
        } else {
            m.incr("policy.cache_hits");
        }
    }
    d
}

/// Resolve an [`FormatChoice::Auto`] request to its concrete choice —
/// the single entry point shared by one-shot dispatch and the intake
/// flusher. SAINV preconditioning only rides the IR format, so that
/// pairing resolves directly (forced by the precond spec, not the
/// value population); everything else goes through the cached policy
/// when a registry is present, or a fresh [`decide`] when not.
pub(crate) fn resolve_dispatch(
    cached: Option<(&MatrixRegistry, &MatrixHandle)>,
    a: &Arc<Csr>,
    solver: SolverKind,
    precond: &Precond,
    nrhs: usize,
    metrics: Option<&Metrics>,
) -> FormatChoice {
    if matches!(precond, Precond::Sainv(_)) {
        if let Some(m) = metrics {
            m.incr("policy.decisions");
        }
        return FormatChoice::Ir { k: DEFAULT_K };
    }
    match cached {
        Some((reg, h)) => decide_cached(reg, h, solver, nrhs, metrics).choice.clone(),
        None => {
            let d = decide(a, solver, nrhs);
            if let Some(m) = metrics {
                m.incr("policy.decisions");
                if d.fallback {
                    m.incr("policy.fallbacks");
                }
            }
            d.choice
        }
    }
}

/// The decision function itself (see module docs for the three tiers).
fn compute(a: &Csr, digest: MatrixDigest, solver: SolverKind, bucket: usize) -> PolicyDecision {
    let stats = matrix_stats(a);
    if stats.nnz == 0 || stats.min_abs_nonzero == 0.0 {
        return PolicyDecision {
            choice: FormatChoice::fixed(ValueFormat::Fp64),
            rationale: "degenerate value population (no finite non-zeros): fp64".into(),
            fallback: true,
        };
    }
    // the reference RHS is b = A·1 — a pure function of the matrix
    // content, so folding its dynamic range into the decision keeps
    // the result cacheable per digest
    let b = RhsSpec::AxOnes.build(a);
    let rhs = entropy::analyze(&b);
    let (mut rhs_min, mut rhs_max) = (f64::INFINITY, 0f64);
    for &v in &b {
        let x = v.abs();
        if x > 0.0 && x.is_finite() {
            rhs_min = rhs_min.min(x);
            rhs_max = rhs_max.max(x);
        }
    }
    let mat_range = (stats.max_abs / stats.min_abs_nonzero).log2();
    let rhs_range =
        if rhs_min.is_finite() && rhs_min > 0.0 { (rhs_max / rhs_min).log2() } else { 0.0 };
    let range = mat_range.max(rhs_range);
    let exp_entropy = stats.entropy.exponent_bits.max(rhs.exponent_bits);
    let mut hist = ExpHistogram::new();
    hist.push_all(&a.vals);
    let k = GseTable::auto_k(&hist, COVERAGE_TARGET);
    let coverage = hist.topk_coverage(k);
    if range > HARD_EXP_RANGE_BITS || exp_entropy > HARD_EXP_ENTROPY_BITS {
        return PolicyDecision {
            choice: FormatChoice::fixed(ValueFormat::Fp64),
            rationale: format!(
                "exponent range {range:.1} bits / entropy {exp_entropy:.2} bits beyond the hard \
                 thresholds ({HARD_EXP_RANGE_BITS}/{HARD_EXP_ENTROPY_BITS}): every reduced rung \
                 is unsafe, fp64"
            ),
            fallback: true,
        };
    }
    let params = stepped_params(solver, a.nrows);
    if range > SAFE_EXP_RANGE_BITS || exp_entropy > SAFE_EXP_ENTROPY_BITS {
        return PolicyDecision {
            choice: FormatChoice::Stepped { k, params },
            rationale: format!(
                "exponent range {range:.1} bits / entropy {exp_entropy:.2} bits above the safe \
                 thresholds ({SAFE_EXP_RANGE_BITS}/{SAFE_EXP_ENTROPY_BITS}): head start refused, \
                 escalating GSE ladder at k={k}"
            ),
            fallback: true,
        };
    }
    let t64 = modeled_time(a, &FormatChoice::fixed(ValueFormat::Fp64), bucket);
    let ladder = FormatChoice::Stepped { k, params };
    let t_head = modeled_time(a, &ladder, bucket);
    let speedup = t64 / t_head;
    if speedup < MIN_MODELED_SPEEDUP {
        return PolicyDecision {
            choice: FormatChoice::fixed(ValueFormat::Fp64),
            rationale: format!(
                "modeled head speedup {speedup:.3}x at nrhs {bucket} below \
                 {MIN_MODELED_SPEEDUP}x (table + decode overhead not amortized): fp64"
            ),
            fallback: false,
        };
    }
    if !ladder_pays(digest, solver) {
        return PolicyDecision {
            choice: FormatChoice::fixed(ValueFormat::Fp64),
            rationale: "observed stepped switch logs escalate off the head rung early for this \
                        digest: ladder depth collapsed to fp64"
                .into(),
            fallback: false,
        };
    }
    PolicyDecision {
        choice: ladder,
        rationale: format!(
            "exponent entropy {exp_entropy:.2} bits over {} binades, top-{k} coverage \
             {coverage:.3}, modeled head speedup {speedup:.2}x at nrhs {bucket}: stepped GSE \
             ladder",
            stats.num_distinct_exponents
        ),
        fallback: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::jobs::{dispatch_cached, SolveRequest};
    use crate::solvers::SainvParams;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen::corpus::{cg_set, gmres_set, CorpusSize};
    use crate::sparse::gen::poisson::poisson2d;
    use crate::sparse::gen::randmat::{exp_controlled, ExpLaw};

    #[test]
    fn nrhs_buckets_round_up_to_powers_of_two() {
        assert_eq!(nrhs_bucket(0), 1);
        assert_eq!(nrhs_bucket(1), 1);
        assert_eq!(nrhs_bucket(3), 4);
        assert_eq!(nrhs_bucket(8), 8);
        assert_eq!(nrhs_bucket(9), 16);
    }

    #[test]
    fn narrow_population_picks_the_stepped_gse_ladder() {
        // poisson has two distinct exponents: tiny table, safe head rung
        let a = poisson2d(16, 16);
        let d = decide(&a, SolverKind::Cg, 1);
        assert!(!d.fallback, "{}", d.rationale);
        match &d.choice {
            FormatChoice::Stepped { k, .. } => {
                assert!(*k <= 8, "two-exponent population, got k={k}")
            }
            other => panic!("expected the stepped ladder, got {other:?}"),
        }
        assert!(d.rationale.contains("stepped"), "{}", d.rationale);
    }

    #[test]
    fn wide_exponent_population_refuses_low_rungs() {
        // sigma-30 binade spread: range and entropy far beyond the
        // hard thresholds — the policy must never start low here
        let a = exp_controlled(40, 40, 4, ExpLaw::Gaussian { e0: 0, sigma: 30.0 }, 7);
        let d = decide(&a, SolverKind::Gmres, 1);
        assert!(d.fallback, "{}", d.rationale);
        match &d.choice {
            FormatChoice::Fixed { format, .. } => {
                assert_eq!(*format, ValueFormat::Fp64, "only fp64 is safe this wide")
            }
            FormatChoice::Stepped { .. } => {} // safe-tier refusal: ladder from the bottom
            other => panic!("wide population must not pick {other:?}"),
        }
    }

    #[test]
    fn subnormal_entries_force_the_hard_fp64_fallback() {
        // subnormal off-diagonals put ~1000 bits between the largest
        // and smallest magnitude; before the entropy/stats subnormal
        // fix these values were invisible to the analysis
        let sub = f64::MIN_POSITIVE / 8.0;
        let mut c = Coo::new(3, 3);
        for i in 0..3 {
            c.push(i, i, 1.0);
        }
        c.push(0, 1, sub);
        c.push(1, 0, sub);
        let a = c.to_csr();
        let d = decide(&a, SolverKind::Cg, 1);
        assert!(d.fallback, "{}", d.rationale);
        assert!(
            matches!(d.choice, FormatChoice::Fixed { format: ValueFormat::Fp64, .. }),
            "{:?}",
            d.choice
        );
    }

    #[test]
    fn wide_batches_amortize_away_the_gse_advantage() {
        // at huge nrhs the RHS traffic dominates and the modeled head
        // speedup collapses toward 1: auto legitimately picks fp64
        let a = poisson2d(16, 16);
        let d = decide(&a, SolverKind::Cg, 4096);
        assert!(
            matches!(d.choice, FormatChoice::Fixed { format: ValueFormat::Fp64, .. }),
            "{:?}",
            d.choice
        );
        assert!(!d.fallback, "a modeled ranking is not a safety fallback");
    }

    #[test]
    fn early_full_escalations_collapse_the_ladder() {
        let a = poisson2d(14, 14); // digest unique to this test
        let digest = a.digest();
        assert!(matches!(decide(&a, SolverKind::Cg, 1).choice, FormatChoice::Stepped { .. }));
        // three observed solves, each at the full rung within the
        // first quarter: the low start is not paying
        for _ in 0..3 {
            record_switches(digest, SolverKind::Cg, 400, &[(30, 2), (60, 3)]);
        }
        let d = decide(&a, SolverKind::Cg, 1);
        assert!(
            matches!(d.choice, FormatChoice::Fixed { format: ValueFormat::Fp64, .. }),
            "{:?}",
            d.choice
        );
        assert!(d.rationale.contains("switch logs"), "{}", d.rationale);
        // feedback is keyed per solver: the GMRES ladder is untouched
        assert!(matches!(decide(&a, SolverKind::Gmres, 1).choice, FormatChoice::Stepped { .. }));
        // late escalations do not count against the ladder
        let late = poisson2d(15, 15);
        for _ in 0..4 {
            record_switches(late.digest(), SolverKind::Cg, 400, &[(350, 3)]);
        }
        assert!(matches!(decide(&late, SolverKind::Cg, 1).choice, FormatChoice::Stepped { .. }));
    }

    #[test]
    fn sainv_precond_resolves_auto_to_ir() {
        let a = Arc::new(poisson2d(6, 6));
        let m = Metrics::new();
        let choice = resolve_dispatch(
            None,
            &a,
            SolverKind::Gmres,
            &Precond::Sainv(SainvParams::default()),
            1,
            Some(&m),
        );
        assert!(matches!(choice, FormatChoice::Ir { k: DEFAULT_K }), "{choice:?}");
        assert_eq!(m.counter("policy.decisions"), 1);
    }

    #[test]
    fn corpus_decisions_are_deterministic_and_cache_on_second_request() {
        let size = CorpusSize::Small;
        let reg = MatrixRegistry::new();
        let m = Metrics::new();
        let mut total = 0u64;
        for (set, solver) in
            [(cg_set(size), SolverKind::Cg), (gmres_set(size), SolverKind::Gmres)]
        {
            for nm in &set {
                let a = Arc::new(nm.a.clone());
                let h = reg.register(&a);
                let d1 = decide_cached(&reg, &h, solver, 1, Some(&m));
                let d2 = decide_cached(&reg, &h, solver, 1, Some(&m));
                assert!(
                    Arc::ptr_eq(&d1, &d2),
                    "{}: second request must serve the cached decision",
                    nm.name
                );
                assert!(
                    !matches!(d1.choice, FormatChoice::Auto),
                    "{}: every corpus matrix resolves concretely",
                    nm.name
                );
                // a fresh uncached compute agrees exactly
                let fresh = decide(&nm.a, solver, 1);
                assert_eq!(fresh.choice.group_key(), d1.choice.group_key(), "{}", nm.name);
                assert_eq!(fresh.rationale, d1.rationale, "{}", nm.name);
                assert_eq!(fresh.fallback, d1.fallback, "{}", nm.name);
                total += 1;
            }
        }
        assert_eq!(m.counter("policy.decisions"), total);
        assert_eq!(m.counter("policy.cache_hits"), total);
    }

    #[test]
    fn auto_dispatch_resolves_and_caches() {
        let a = Arc::new(poisson2d(12, 12));
        let reg = MatrixRegistry::new();
        let m = Metrics::new();
        let req = SolveRequest::new("auto", Arc::clone(&a), SolverKind::Cg, FormatChoice::Auto);
        let r1 = dispatch_cached(&req, Some(&reg), Some(&m)).unwrap();
        assert!(r1.outcome.converged);
        assert_eq!(r1.format_label, "GSE-SEM", "narrow population resolves to the ladder");
        assert_eq!(m.counter("policy.decisions"), 1);
        assert_eq!(m.counter("policy.fallbacks"), 0);
        let r2 = dispatch_cached(&req, Some(&reg), Some(&m)).unwrap();
        assert_eq!(m.counter("policy.cache_hits"), 1);
        assert_eq!(r1.outcome.x, r2.outcome.x);
        // registry-less dispatch resolves the same choice bitwise
        let r3 = dispatch_cached(&req, None, None).unwrap();
        assert_eq!(r3.outcome.x, r1.outcome.x);
    }

    #[test]
    fn modeled_time_ranks_formats_sanely() {
        let a = poisson2d(24, 24);
        let t64 = modeled_time(&a, &FormatChoice::fixed(ValueFormat::Fp64), 1);
        let stepped = FormatChoice::Stepped {
            k: 2,
            params: SteppedParams::cg_paper().scaled(0.01),
        };
        let t_head = modeled_time(&a, &stepped, 1);
        assert!(t_head < t64, "head rung must model faster at nrhs 1");
        // and the gap closes as the batch widens
        let r1 = modeled_time(&a, &stepped, 1) / t64;
        let r64 = modeled_time(&a, &stepped, 64)
            / modeled_time(&a, &FormatChoice::fixed(ValueFormat::Fp64), 64);
        assert!(r64 > r1, "wider batches amortize the format difference");
    }
}
