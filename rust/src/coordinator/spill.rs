//! Operator spill codec — serializes evicted registry entries to a
//! spill directory so the next miss for the same key restores the
//! encoded operator instead of re-paying the encode (the whole point of
//! the paper's one-encode-serves-every-rung storage at serving scale).
//!
//! Files are **content-addressed**: named by the matrix digest plus the
//! format (or GSE table size), so a spill file is never stale and both
//! sides of the codec can be fully best-effort — any I/O failure,
//! truncation, or version mismatch simply falls back to re-encoding.
//! Layout (little-endian, via [`crate::util::codec`]):
//!
//! ```text
//! u64 magic · u32 version · f64 build_seconds · bytes payload
//! ```
//!
//! The payload starts with a [`spill_tag`] byte and then the plane
//! arrays of the concrete operator: for GSE entries the shared-exponent
//! table plus head/tail planes exactly as encoded (every derived decode
//! table is recomputed on restore, see `GseCsr::from_parts`), for
//! fixed-format operators the CSR arrays with values widened losslessly
//! to f64. A restored operator is bitwise indistinguishable from the
//! original encode.

use super::jobs::{FormatChoice, SolverKind};
use super::policy::PolicyDecision;
use super::registry::{CachedVal, Key};
use crate::formats::{GseTable, Precision, ValueFormat};
use crate::solvers::sainv::{SainvFactors, SainvParamsKey};
use crate::solvers::stepped::SteppedParams;
use crate::sparse::csr::Csr;
use crate::spmv::fp64::Fp64Csr;
use crate::spmv::lowp::{LowpCsr, StoredValue};
use crate::spmv::{spill_tag, GseCsr, SpmvOp, ThreadBudget};
use crate::util::error::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: u64 = 0x4753_454D_5350_4C31; // "GSEMSPL1"
const VERSION: u32 = 1;

/// Spill-file name for a registry key: `<digest-hex>-<format>.spill`.
fn file_path(dir: &Path, key: &Key) -> PathBuf {
    let name = match key {
        Key::Op { digest, format } => {
            let tag = match format {
                ValueFormat::Fp64 => "fp64",
                ValueFormat::Fp32 => "fp32",
                ValueFormat::Fp16 => "fp16",
                ValueFormat::Bf16 => "bf16",
                // Op keys never carry GseSem (the registry routes GSE
                // levels through the shared Gse entry), but name them
                // distinctly anyway rather than panic in a best-effort
                // path
                ValueFormat::GseSem(Precision::Head) => "gsehead",
                ValueFormat::GseSem(Precision::HeadTail1) => "gset1",
                ValueFormat::GseSem(Precision::Full) => "gsefull",
            };
            format!("{}-{}.spill", digest.to_hex(), tag)
        }
        Key::Gse { digest, k } => format!("{}-gse{}.spill", digest.to_hex(), k),
        Key::Sainv { digest, params } => {
            format!("{}-sainv{}d{:016x}.spill", digest.to_hex(), params.k, params.drop_bits)
        }
        Key::Policy { digest, solver, bucket } => {
            format!("{}-policy{}n{}.spill", digest.to_hex(), solver_tag(*solver), bucket)
        }
    };
    dir.join(name)
}

fn solver_tag(s: SolverKind) -> &'static str {
    match s {
        SolverKind::Cg => "cg",
        SolverKind::Gmres => "gm",
        SolverKind::Bicgstab => "bi",
    }
}

/// Serialize an evicted entry. Best-effort: returns `false` (and writes
/// nothing lasting) on opt-out operators or any I/O failure. An already
/// present file is left alone — content addressing makes it identical
/// to what would be rewritten.
pub(crate) fn write(dir: &Path, key: &Key, v: &CachedVal, build_s: f64) -> bool {
    let path = file_path(dir, key);
    if path.exists() {
        return true;
    }
    try_write(dir, &path, v, build_s).is_ok()
}

fn try_write(dir: &Path, path: &Path, v: &CachedVal, build_s: f64) -> Result<()> {
    let payload = match v {
        CachedVal::Op(op) => op.spill_bytes().context("operator opts out of spill")?,
        CachedVal::Gse(g) => encode_gse(g),
        CachedVal::Sainv(f) => encode_sainv(f),
        CachedVal::Policy(d) => encode_policy(d)?,
    };
    let mut w = crate::util::codec::ByteWriter::new();
    w.put_u64(MAGIC);
    w.put_u32(VERSION);
    w.put_f64(build_s);
    w.put_bytes(&payload);
    std::fs::create_dir_all(dir)?;
    // write-then-rename so a concurrent restore never sees a torn file;
    // the temp name is keyed so concurrent evictors of *different*
    // entries never collide (same-key racers write identical bytes)
    let tmp = path.with_extension("spill.tmp");
    std::fs::write(&tmp, w.into_bytes())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// A successfully restored spill entry: the decoded value, its original
/// encode seconds, the file size, and how long the file read itself
/// took (surfaced as the `cache.restore_read_ns` counter).
pub(crate) struct Restored {
    pub v: CachedVal,
    pub build_s: f64,
    pub file_bytes: u64,
    pub read_ns: u64,
}

/// Deserialize the spilled entry for `key`, if present and intact.
/// `None` covers both "never spilled" and "unreadable" (the caller
/// falls back to a fresh encode either way).
pub(crate) fn read(dir: &Path, key: &Key) -> Option<Restored> {
    let t = crate::util::Timer::start();
    let bytes = read_exact_all(&file_path(dir, key)).ok()?;
    let read_ns = (t.elapsed_s() * 1e9) as u64;
    let file_bytes = bytes.len() as u64;
    let (v, build_s) = try_decode(key, &bytes).ok()?;
    Some(Restored { v, build_s, file_bytes, read_ns })
}

/// One pre-sized `read_exact` into the decode buffer: spill files are
/// content-addressed and renamed into place whole, so the size from
/// `metadata` is authoritative and a single sized read replaces the
/// generic probe-and-grow `read_to_end` loop. A file that shrinks
/// between stat and read (it never should) errors out into the normal
/// fall-back-to-encode path.
fn read_exact_all(path: &Path) -> std::io::Result<Vec<u8>> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let len = f.metadata()?.len() as usize;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    Ok(buf)
}

fn try_decode(key: &Key, bytes: &[u8]) -> Result<(CachedVal, f64)> {
    let mut r = crate::util::codec::ByteReader::new(bytes);
    if r.get_u64()? != MAGIC {
        bail!("not a spill file");
    }
    let version = r.get_u32()?;
    if version != VERSION {
        bail!("unsupported spill version {version}");
    }
    let build_s = r.get_f64()?;
    let payload = r.get_bytes()?;
    let v = match key {
        Key::Gse { .. } => CachedVal::Gse(Arc::new(decode_gse(&payload)?)),
        Key::Op { format, .. } => CachedVal::Op(decode_op(*format, &payload)?),
        Key::Sainv { params, .. } => CachedVal::Sainv(Arc::new(decode_sainv(&payload, *params)?)),
        Key::Policy { .. } => CachedVal::Policy(Arc::new(decode_policy(&payload)?)),
    };
    Ok((v, build_s))
}

/// GSE payload: the exact plane arrays of the encode (`GseTable`
/// entries, rowptr/cols, head/tail planes, out-of-band exponent
/// indexes). `packed` and `ei_bit` ride along so the restored decode
/// geometry matches bit for bit.
fn encode_gse(g: &GseCsr) -> Vec<u8> {
    let mut w = crate::util::codec::ByteWriter::new();
    w.put_u8(spill_tag::GSE);
    w.put_u64(g.nrows as u64);
    w.put_u64(g.ncols as u64);
    w.put_usizes(&g.rowptr);
    w.put_u32s(&g.cols);
    w.put_u16s(&g.heads);
    w.put_u16s(&g.tail1);
    w.put_u32s(&g.tail2);
    match &g.ext_idx {
        Some(idx) => {
            w.put_u8(1);
            w.put_bytes(idx);
        }
        None => w.put_u8(0),
    }
    w.put_u32s(&g.table.entries);
    w.put_u8(g.packed as u8);
    w.into_bytes()
}

fn decode_gse(payload: &[u8]) -> Result<GseCsr> {
    let mut r = crate::util::codec::ByteReader::new(payload);
    if r.get_u8()? != spill_tag::GSE {
        bail!("spill payload is not a GSE encode");
    }
    let nrows = r.get_u64()? as usize;
    let ncols = r.get_u64()? as usize;
    let rowptr = r.get_usizes()?;
    let cols = r.get_u32s()?;
    let heads = r.get_u16s()?;
    let tail1 = r.get_u16s()?;
    let tail2 = r.get_u32s()?;
    let ext_idx = match r.get_u8()? {
        0 => None,
        _ => Some(r.get_bytes()?),
    };
    let entries = r.get_u32s()?;
    let packed = r.get_u8()? != 0;
    if rowptr.len() != nrows + 1 || *rowptr.last().unwrap_or(&0) != cols.len() {
        bail!("inconsistent GSE spill structure");
    }
    let table = GseTable::from_entries(entries);
    Ok(GseCsr::from_parts(nrows, ncols, rowptr, cols, heads, tail1, tail2, ext_idx, table, packed))
}

/// SAINV payload: the construction params (revalidated against the key
/// on decode), the pivot reciprocals, and the two GSE factor encodes —
/// each nested through [`encode_gse`] so a restored factor pair shares
/// every bitwise guarantee of the plain GSE round trip.
fn encode_sainv(f: &SainvFactors) -> Vec<u8> {
    let mut w = crate::util::codec::ByteWriter::new();
    w.put_u8(spill_tag::SAINV);
    let key: SainvParamsKey = f.params().into();
    w.put_u64(key.k as u64);
    w.put_u64(key.drop_bits);
    w.put_f64s(f.inv_d());
    w.put_bytes(&encode_gse(f.z()));
    w.put_bytes(&encode_gse(f.wt()));
    w.into_bytes()
}

fn decode_sainv(payload: &[u8], key_params: SainvParamsKey) -> Result<SainvFactors> {
    let mut r = crate::util::codec::ByteReader::new(payload);
    if r.get_u8()? != spill_tag::SAINV {
        bail!("spill payload is not a SAINV factor pair");
    }
    let k = r.get_u64()? as usize;
    let drop_bits = r.get_u64()?;
    if k != key_params.k || drop_bits != key_params.drop_bits {
        bail!("sainv spill params do not match the key");
    }
    let inv_d = r.get_f64s()?;
    let z = decode_gse(&r.get_bytes()?)?;
    let wt = decode_gse(&r.get_bytes()?)?;
    if z.nrows != inv_d.len() || wt.nrows != inv_d.len() {
        bail!("inconsistent sainv spill structure");
    }
    Ok(SainvFactors::from_parts(z, wt, inv_d, key_params.params()))
}

/// Policy payload: tag, fallback flag, the concrete [`FormatChoice`]
/// (format/k/stepped params bit-for-bit), then the rationale text. A
/// restored decision must group-key identically to the original so a
/// post-restore Auto request still merges with hand-picked ones.
fn encode_policy(d: &PolicyDecision) -> Result<Vec<u8>> {
    let mut w = crate::util::codec::ByteWriter::new();
    w.put_u8(spill_tag::POLICY);
    w.put_u8(d.fallback as u8);
    match &d.choice {
        FormatChoice::Fixed { format, k } => {
            w.put_u8(0);
            w.put_u8(format_tag(*format));
            w.put_u64(*k as u64);
        }
        FormatChoice::Stepped { k, params } => {
            w.put_u8(1);
            w.put_u64(*k as u64);
            encode_params(&mut w, params);
        }
        FormatChoice::SteppedCopy { params } => {
            w.put_u8(2);
            encode_params(&mut w, params);
        }
        FormatChoice::Ir { k } => {
            w.put_u8(3);
            w.put_u64(*k as u64);
        }
        FormatChoice::Auto => bail!("Auto is never a concrete policy decision"),
    }
    w.put_bytes(d.rationale.as_bytes());
    Ok(w.into_bytes())
}

fn decode_policy(payload: &[u8]) -> Result<PolicyDecision> {
    let mut r = crate::util::codec::ByteReader::new(payload);
    if r.get_u8()? != spill_tag::POLICY {
        bail!("spill payload is not a policy decision");
    }
    let fallback = r.get_u8()? != 0;
    let choice = match r.get_u8()? {
        0 => {
            let format = format_from_tag(r.get_u8()?)?;
            FormatChoice::Fixed { format, k: r.get_u64()? as usize }
        }
        1 => {
            let k = r.get_u64()? as usize;
            FormatChoice::Stepped { k, params: decode_params(&mut r)? }
        }
        2 => FormatChoice::SteppedCopy { params: decode_params(&mut r)? },
        3 => FormatChoice::Ir { k: r.get_u64()? as usize },
        t => bail!("unknown policy choice tag {t}"),
    };
    let rationale = String::from_utf8(r.get_bytes()?)
        .map_err(|_| crate::util::error::Error::msg("policy rationale is not utf-8"))?;
    Ok(PolicyDecision { choice, rationale, fallback })
}

fn format_tag(f: ValueFormat) -> u8 {
    match f {
        ValueFormat::Fp64 => 0,
        ValueFormat::Fp32 => 1,
        ValueFormat::Fp16 => 2,
        ValueFormat::Bf16 => 3,
        ValueFormat::GseSem(Precision::Head) => 4,
        ValueFormat::GseSem(Precision::HeadTail1) => 5,
        ValueFormat::GseSem(Precision::Full) => 6,
    }
}

fn format_from_tag(t: u8) -> Result<ValueFormat> {
    Ok(match t {
        0 => ValueFormat::Fp64,
        1 => ValueFormat::Fp32,
        2 => ValueFormat::Fp16,
        3 => ValueFormat::Bf16,
        4 => ValueFormat::GseSem(Precision::Head),
        5 => ValueFormat::GseSem(Precision::HeadTail1),
        6 => ValueFormat::GseSem(Precision::Full),
        _ => bail!("unknown value-format tag {t}"),
    })
}

fn encode_params(w: &mut crate::util::codec::ByteWriter, p: &SteppedParams) {
    w.put_u64(p.l as u64);
    w.put_u64(p.t as u64);
    w.put_u64(p.m as u64);
    w.put_u64(p.ndec_limit as u64);
    w.put_f64(p.rsd_limit);
    w.put_f64(p.reldec_limit);
    w.put_f64(p.divergence_factor);
}

fn decode_params(r: &mut crate::util::codec::ByteReader) -> Result<SteppedParams> {
    Ok(SteppedParams {
        l: r.get_u64()? as usize,
        t: r.get_u64()? as usize,
        m: r.get_u64()? as usize,
        ndec_limit: r.get_u64()? as usize,
        rsd_limit: r.get_f64()?,
        reldec_limit: r.get_f64()?,
        divergence_factor: r.get_f64()?,
    })
}

fn decode_op(format: ValueFormat, payload: &[u8]) -> Result<Arc<dyn SpmvOp>> {
    let mut r = crate::util::codec::ByteReader::new(payload);
    match format {
        ValueFormat::Fp64 => {
            let a = decode_csr(&mut r, spill_tag::FP64)?;
            Ok(Arc::new(Fp64Csr::new(a)))
        }
        ValueFormat::Fp32 => decode_lowp::<f32>(&mut r, spill_tag::FP32),
        ValueFormat::Fp16 => decode_lowp::<crate::formats::Fp16>(&mut r, spill_tag::FP16),
        ValueFormat::Bf16 => decode_lowp::<crate::formats::Bf16>(&mut r, spill_tag::BF16),
        ValueFormat::GseSem(_) => bail!("GSE operators restore via their shared encode key"),
    }
}

/// The common CSR body shared by the fp64 and low-precision layouts
/// (tag, dims, rowptr, colidx, f64-widened values).
fn decode_csr(r: &mut crate::util::codec::ByteReader, want_tag: u8) -> Result<Csr> {
    let tag = r.get_u8()?;
    if tag != want_tag {
        bail!("spill payload tag {tag} does not match key format (want {want_tag})");
    }
    let nrows = r.get_u64()? as usize;
    let ncols = r.get_u64()? as usize;
    let rowptr = r.get_usizes()?;
    let colidx = r.get_u32s()?;
    let vals = r.get_f64s()?;
    if rowptr.len() != nrows + 1
        || *rowptr.last().unwrap_or(&0) != colidx.len()
        || colidx.len() != vals.len()
    {
        bail!("inconsistent CSR spill structure");
    }
    Ok(Csr { nrows, ncols, rowptr, colidx, vals })
}

fn decode_lowp<T: StoredValue>(
    r: &mut crate::util::codec::ByteReader,
    want_tag: u8,
) -> Result<Arc<dyn SpmvOp>> {
    let a = decode_csr(r, want_tag)?;
    let overflowed = r.get_u8()? != 0;
    let vals: Vec<T> = a.vals.iter().map(|&v| T::from_f64(v)).collect();
    Ok(Arc::new(LowpCsr {
        nrows: a.nrows,
        ncols: a.ncols,
        rowptr: a.rowptr,
        colidx: a.colidx,
        vals,
        overflowed,
        threads: ThreadBudget::new(1),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::spmv::max_abs_diff;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("gsem-spill-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn gse_round_trip_is_bitwise() {
        let a = Arc::new(poisson2d(9, 9));
        let g = GseCsr::from_csr(&a, 8);
        let dir = tmp_dir("gse");
        let key = Key::Gse { digest: a.digest(), k: 8 };
        assert!(write(&dir, &key, &CachedVal::Gse(Arc::new(GseCsr::from_csr(&a, 8))), 0.25));
        let r = read(&dir, &key).expect("restore");
        assert_eq!(r.build_s, 0.25);
        assert!(r.file_bytes > 0);
        let CachedVal::Gse(restored) = r.v else { panic!("gse key restores a gse encode") };
        // every plane and the decoded SpMV must match the original
        assert_eq!(restored.rowptr, g.rowptr);
        assert_eq!(restored.cols, g.cols);
        assert_eq!(restored.heads, g.heads);
        assert_eq!(restored.tail1, g.tail1);
        assert_eq!(restored.tail2, g.tail2);
        assert_eq!(restored.ext_idx, g.ext_idx);
        assert_eq!(restored.table.entries, g.table.entries);
        let x: Vec<f64> = (0..a.ncols).map(|i| (i % 5) as f64 - 2.0).collect();
        for level in [Precision::Head, Precision::HeadTail1, Precision::Full] {
            let mut y0 = vec![0.0; a.nrows];
            g.spmv(&x, &mut y0, level);
            let mut y1 = vec![0.0; a.nrows];
            restored.spmv(&x, &mut y1, level);
            assert_eq!(y0, y1, "restored GSE SpMV must be bitwise identical at {level:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fixed_format_round_trips() {
        let a = Arc::new(poisson2d(7, 7));
        let dir = tmp_dir("op");
        for format in [
            ValueFormat::Fp64,
            ValueFormat::Fp32,
            ValueFormat::Fp16,
            ValueFormat::Bf16,
        ] {
            let op = super::super::registry::build_fixed_operator(&a, format, 0);
            let key = Key::Op { digest: a.digest(), format };
            assert!(write(&dir, &key, &CachedVal::Op(Arc::clone(&op)), 0.0), "{format:?}");
            let restored = read(&dir, &key).expect("restore");
            let CachedVal::Op(restored) = restored.v else {
                panic!("op key restores an operator")
            };
            assert_eq!(restored.format(), format);
            assert_eq!(restored.encoded_bytes(), op.encoded_bytes());
            let x: Vec<f64> = (0..a.ncols).map(|i| (i % 3) as f64).collect();
            let mut y0 = vec![0.0; a.nrows];
            op.apply(&x, &mut y0);
            let mut y1 = vec![0.0; a.nrows];
            restored.apply(&x, &mut y1);
            assert_eq!(max_abs_diff(&y0, &y1), 0.0, "{format:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sainv_round_trip_is_bitwise() {
        use crate::solvers::sainv::SainvParams;
        let a = Arc::new(poisson2d(9, 9));
        let params = SainvParams { drop_tol: 0.05, k: 8 };
        let f = SainvFactors::build(&a, params).expect("spd build");
        let dir = tmp_dir("sainv");
        let key = Key::Sainv { digest: a.digest(), params: params.into() };
        assert!(write(&dir, &key, &CachedVal::Sainv(Arc::new(f.clone())), 0.25));
        let r = read(&dir, &key).expect("restore");
        assert_eq!(r.build_s, 0.25);
        assert!(r.file_bytes > 0);
        let CachedVal::Sainv(restored) = r.v else { panic!("sainv key restores factors") };
        // plane-for-plane equality on both factors and the pivots
        assert_eq!(restored.inv_d(), f.inv_d());
        assert_eq!(restored.z().heads, f.z().heads);
        assert_eq!(restored.z().tail2, f.z().tail2);
        assert_eq!(restored.wt().heads, f.wt().heads);
        assert_eq!(restored.wt().tail2, f.wt().tail2);
        assert_eq!(restored.params(), f.params());
        // and the applied preconditioner is bitwise identical per rung
        let r0: Vec<f64> = (0..f.nrows()).map(|i| (i % 7) as f64 - 3.0).collect();
        for level in [Precision::Head, Precision::HeadTail1, Precision::Full] {
            let mut y0 = vec![0.0; f.nrows()];
            f.apply(&r0, &mut y0, level);
            let mut y1 = vec![0.0; f.nrows()];
            restored.apply(&r0, &mut y1, level);
            assert_eq!(y0, y1, "restored SAINV apply must be bitwise identical at {level:?}");
        }
        // a mismatched-params key refuses the file instead of mis-decoding
        let wrong = SainvParams { drop_tol: 0.25, k: 8 };
        let wrong_key = Key::Sainv { digest: a.digest(), params: wrong.into() };
        assert!(read(&dir, &wrong_key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn policy_round_trip_is_exact() {
        let a = Arc::new(poisson2d(5, 5));
        let dir = tmp_dir("policy");
        let choices = [
            FormatChoice::Fixed { format: ValueFormat::GseSem(Precision::Full), k: 16 },
            FormatChoice::Stepped { k: 4, params: SteppedParams::cg_paper().scaled(0.25) },
            FormatChoice::Ir { k: 8 },
        ];
        for (i, choice) in choices.iter().enumerate() {
            let d = PolicyDecision {
                choice: choice.clone(),
                rationale: format!("test rationale {i}"),
                fallback: i == 0,
            };
            let key =
                Key::Policy { digest: a.digest(), solver: SolverKind::Cg, bucket: 1 << i };
            assert!(write(&dir, &key, &CachedVal::Policy(Arc::new(d.clone())), 0.01));
            let r = read(&dir, &key).expect("restore");
            let CachedVal::Policy(restored) = r.v else {
                panic!("policy key restores a decision")
            };
            // group-key equality = the restored choice still merges
            // with the original's groups (params bit-for-bit)
            assert_eq!(restored.choice.group_key(), choice.group_key());
            assert_eq!(restored.rationale, d.rationale);
            assert_eq!(restored.fallback, d.fallback);
        }
        // distinct solver/bucket keys name distinct files
        let k1 = Key::Policy { digest: a.digest(), solver: SolverKind::Cg, bucket: 1 };
        let k2 = Key::Policy { digest: a.digest(), solver: SolverKind::Gmres, bucket: 1 };
        assert_ne!(file_path(&dir, &k1), file_path(&dir, &k2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_or_missing_files_fall_back() {
        let a = Arc::new(poisson2d(5, 5));
        let dir = tmp_dir("corrupt");
        let key = Key::Op { digest: a.digest(), format: ValueFormat::Fp64 };
        // missing: a clean None
        assert!(read(&dir, &key).is_none());
        // corrupt: truncate a valid file at every prefix length
        let op = super::super::registry::build_fixed_operator(&a, ValueFormat::Fp64, 0);
        assert!(write(&dir, &key, &CachedVal::Op(op), 0.0));
        let path = file_path(&dir, &key);
        let full = std::fs::read(&path).unwrap();
        for cut in [0, 4, 11, 13, 21, full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(read(&dir, &key).is_none(), "cut at {cut} must not restore");
        }
        // and restored after rewriting the intact bytes
        std::fs::write(&path, &full).unwrap();
        assert!(read(&dir, &key).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
