//! Content-addressed operator registry — the serving-path replacement
//! for the old `Arc`-pointer `OperatorCache`.
//!
//! Three problems with pointer keys at serving scale, and how this
//! module solves each:
//!
//! * **Identity misses.** Structurally identical matrices behind
//!   distinct `Arc`s (fresh parses of the same file, per-request
//!   clones) missed on every lookup and each pinned a private encode.
//!   Entries are now keyed by [`MatrixDigest`] — a structural digest of
//!   the CSR — through a typed [`MatrixHandle`], so equal content
//!   shares one entry and nothing needs to pin the matrix `Arc` to
//!   keep its key valid.
//! * **Serialized encodes.** Builds used to run under the one global
//!   cache lock: no duplicate encodes, but every worker queued behind
//!   every encode. The map is now **sharded**, and a miss installs a
//!   per-key **build latch** before releasing the shard lock — distinct
//!   matrices encode in parallel while duplicate requests wait on the
//!   latch and still encode exactly once.
//! * **Unbounded growth.** Entries used to live for the pool's
//!   lifetime. Each entry now carries its resident size
//!   ([`crate::spmv::SpmvOp::encoded_bytes`]) and the registry evicts
//!   least-recently-used entries above a configurable byte budget.
//!
//! Outcomes surface in [`Metrics`] as `cache.hits` / `cache.misses` /
//! `cache.evictions` counters, the `cache.bytes` gauge, and the
//! `cache.encode_saved` timing series; the same numbers are available
//! without a metrics sink via [`MatrixRegistry::stats`]. The pool's
//! accessor is still called `cache()` for familiarity.
//!
//! Cached operators are **thread-reconfigurable in place**: a worker
//! budget set through [`crate::spmv::SpmvOp::set_threads`] is an
//! atomic store on the operator's shared [`crate::spmv::ThreadBudget`]
//! — zero re-encode, no change to digest keys or `encoded_bytes`, so
//! one entry serves any parallelism level and the intake flusher's
//! core allocator retunes entries freely between (and during) solves.
//! Budgets are sticky on the shared entry and results are bitwise
//! independent of them, so concurrent holders racing on a budget is
//! benign; spill round-trips restore operators at budget 1.

use crate::coordinator::jobs::SolverKind;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::PolicyDecision;
use crate::formats::ValueFormat;
use crate::solvers::sainv::{SainvFactors, SainvParams, SainvParamsKey};
use crate::sparse::csr::{Csr, MatrixDigest};
use crate::spmv::fp64::Fp64Csr;
use crate::spmv::gse::GseSpmv;
use crate::spmv::lowp::LowpCsr;
use crate::spmv::{GseCsr, SpmvOp};
use crate::util::Timer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};

/// Shard count (power of two; keyed by digest hash). Sixteen shards
/// keep lock contention negligible at any plausible worker count.
const SHARD_COUNT: usize = 16;

/// Byte budget of the process-wide registry when `GSEM_CACHE_BYTES`
/// is not set.
pub const DEFAULT_GLOBAL_BUDGET: usize = 1 << 30;

/// Typed handle to a registered matrix: the structural digest plus the
/// data `Arc`. Handles are cheap to clone and are the only way to ask
/// the registry for operators — computing the digest once at
/// registration keeps the per-request cost off the lookup path.
#[derive(Clone, Debug)]
pub struct MatrixHandle {
    digest: MatrixDigest,
    a: Arc<Csr>,
}

impl MatrixHandle {
    /// Digest `a` and wrap it. Equal-content matrices produce equal
    /// handles regardless of which `Arc` holds them.
    pub fn of(a: &Arc<Csr>) -> Self {
        Self { digest: a.digest(), a: Arc::clone(a) }
    }

    /// The content-addressed registry key.
    pub fn digest(&self) -> MatrixDigest {
        self.digest
    }

    /// The matrix data.
    pub fn matrix(&self) -> &Arc<Csr> {
        &self.a
    }
}

/// Build a fixed-format operator from scratch (no memoization) — the
/// single construction point shared by the registry miss path and
/// uncached one-shot dispatch. `k` is the GSE shared-exponent count
/// (ignored by the non-GSE formats).
pub(crate) fn build_fixed_operator(a: &Csr, format: ValueFormat, k: usize) -> Arc<dyn SpmvOp> {
    match format {
        ValueFormat::Fp64 => Arc::new(Fp64Csr::new(a.clone())),
        ValueFormat::Fp32 => Arc::new(LowpCsr::<f32>::from_csr(a)),
        ValueFormat::Fp16 => Arc::new(LowpCsr::<crate::formats::Fp16>::from_csr(a)),
        ValueFormat::Bf16 => Arc::new(LowpCsr::<crate::formats::Bf16>::from_csr(a)),
        ValueFormat::GseSem(level) => Arc::new(GseCsr::from_csr(a, k).at_level(level)),
    }
}

/// Registry key: content digest + what was built from it. GSE encodes
/// are cached once per (digest, k) and every precision level views the
/// same entry through a cheap wrapper; non-GSE operators ignore `k`
/// entirely, so their key carries none. `pub(crate)` so the
/// [`super::spill`] codec can name spill files after it.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum Key {
    Op { digest: MatrixDigest, format: ValueFormat },
    Gse { digest: MatrixDigest, k: usize },
    /// SAINV factors: one entry per (matrix content, sainv params).
    Sainv { digest: MatrixDigest, params: SainvParamsKey },
    /// Auto-format policy decision: one entry per (matrix content,
    /// solver, nrhs bucket) — see [`crate::coordinator::policy`].
    Policy { digest: MatrixDigest, solver: SolverKind, bucket: usize },
}

/// What a cache entry holds (`pub(crate)` for the [`super::spill`]
/// encoder/decoder).
#[derive(Clone)]
pub(crate) enum CachedVal {
    Op(Arc<dyn SpmvOp>),
    Gse(Arc<GseCsr>),
    Sainv(Arc<SainvFactors>),
    Policy(Arc<PolicyDecision>),
}

impl CachedVal {
    fn bytes(&self) -> usize {
        match self {
            CachedVal::Op(op) => op.encoded_bytes(),
            CachedVal::Gse(m) => m.encoded_bytes(),
            CachedVal::Sainv(f) => f.encoded_bytes(),
            CachedVal::Policy(d) => d.encoded_bytes(),
        }
    }

    fn into_op(self) -> Arc<dyn SpmvOp> {
        match self {
            CachedVal::Op(op) => op,
            _ => unreachable!("op keys hold operators"),
        }
    }

    fn into_gse(self) -> Arc<GseCsr> {
        match self {
            CachedVal::Gse(m) => m,
            _ => unreachable!("gse keys hold encodes"),
        }
    }

    fn into_sainv(self) -> Arc<SainvFactors> {
        match self {
            CachedVal::Sainv(f) => f,
            _ => unreachable!("sainv keys hold factors"),
        }
    }

    fn into_policy(self) -> Arc<PolicyDecision> {
        match self {
            CachedVal::Policy(d) => d,
            _ => unreachable!("policy keys hold decisions"),
        }
    }
}

/// One filled cache slot.
struct CacheEntry {
    v: CachedVal,
    /// resident size charged against the byte budget
    bytes: usize,
    /// seconds the build took — credited as "saved" on every hit
    build_s: f64,
    /// LRU clock tick of the last access
    last_used: u64,
}

/// Per-key build latch: a miss installs one before releasing the shard
/// lock, so duplicate requests block here (not on the shard) while the
/// builder encodes, and distinct keys encode in parallel.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

enum LatchState {
    Pending,
    Done(CachedVal, f64),
    Failed,
}

impl Latch {
    fn new() -> Self {
        Self { state: Mutex::new(LatchState::Pending), cv: Condvar::new() }
    }

    /// Block until the builder publishes; `None` means the builder
    /// withdrew (panicked) and the caller should race to rebuild.
    fn wait(&self) -> Option<(CachedVal, f64)> {
        let mut st = self.state.lock().unwrap();
        loop {
            match &*st {
                LatchState::Pending => st = self.cv.wait(st).unwrap(),
                LatchState::Done(v, build_s) => return Some((v.clone(), *build_s)),
                LatchState::Failed => return None,
            }
        }
    }

    fn fill(&self, v: CachedVal, build_s: f64) {
        *self.state.lock().unwrap() = LatchState::Done(v, build_s);
        self.cv.notify_all();
    }

    fn fail(&self) {
        *self.state.lock().unwrap() = LatchState::Failed;
        self.cv.notify_all();
    }
}

enum Slot {
    Ready(CacheEntry),
    Building(Arc<Latch>),
}

/// What the shard lookup decided to do (computed under the shard lock,
/// acted on outside it).
enum Plan {
    Hit(CachedVal, f64),
    Wait(Arc<Latch>),
    Build,
}

#[derive(Clone, Copy, Default)]
struct Counters {
    hits: u64,
    misses: u64,
    encode_saved_s: f64,
    evictions: u64,
    spills: u64,
    restores: u64,
    restore_bytes: u64,
    restore_read_ns: u64,
}

/// Aggregate registry outcomes (also exported to [`Metrics`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct RegistryStats {
    pub hits: u64,
    pub misses: u64,
    /// total encode/build seconds that hits avoided re-spending
    pub encode_saved_s: f64,
    /// entries dropped by the LRU byte-budget policy
    pub evictions: u64,
    /// evicted entries serialized to the spill directory
    pub spills: u64,
    /// misses answered from the spill directory instead of re-encoding
    pub restores: u64,
    /// total spill-file bytes read back by restores
    pub restore_bytes: u64,
    /// nanoseconds spent in spill-file reads (the restore IO cost,
    /// one pre-sized `read_exact` per restored entry)
    pub restore_read_ns: u64,
    /// resident encoded bytes currently cached
    pub bytes: usize,
    /// cached builds currently resident (operators + GSE encodes)
    pub entries: usize,
}

/// Sharded, content-addressed, byte-budgeted operator registry (see
/// module docs).
pub struct MatrixRegistry {
    shards: Vec<Mutex<HashMap<Key, Slot>>>,
    /// byte budget; `usize::MAX` = unbounded (no eviction)
    budget: usize,
    /// spill directory: evicted entries are serialized here and
    /// restored on the next miss for their key (`None` = drop on evict)
    spill: Option<std::path::PathBuf>,
    /// resident bytes across all shards (Ready entries only)
    bytes: AtomicUsize,
    /// LRU clock: monotonically increasing access ticks
    clock: AtomicU64,
    counters: Mutex<Counters>,
    /// `Arc`-pointer → digest memo so re-registering the same
    /// allocation (every request of a big batch) skips the O(nnz)
    /// re-hash; `Weak` guards against address reuse after drop.
    digests: Mutex<HashMap<usize, (Weak<Csr>, MatrixDigest)>>,
}

impl Default for MatrixRegistry {
    fn default() -> Self {
        Self::with_budget(usize::MAX)
    }
}

impl MatrixRegistry {
    /// Unbounded registry (no eviction) — the per-pool default.
    pub fn new() -> Self {
        Self::default()
    }

    /// Registry that evicts least-recently-used entries once resident
    /// encoded storage exceeds `budget_bytes`.
    pub fn with_budget(budget_bytes: usize) -> Self {
        Self::with_options(budget_bytes, None)
    }

    /// Registry with a byte budget **and** an optional spill directory.
    /// With a spill dir set, LRU eviction serializes the entry to disk
    /// (see the `coordinator::spill` codec) and the next miss for that
    /// key restores it instead of re-paying the encode — surfaced as
    /// `cache.spills` / `cache.restores` / `cache.restore_bytes`.
    /// Spill files are content-addressed (named by digest + format), so
    /// they are never stale and persist across [`MatrixRegistry::clear`].
    pub fn with_options(budget_bytes: usize, spill_dir: Option<std::path::PathBuf>) -> Self {
        Self {
            shards: (0..SHARD_COUNT).map(|_| Mutex::new(HashMap::new())).collect(),
            budget: budget_bytes,
            spill: spill_dir,
            bytes: AtomicUsize::new(0),
            clock: AtomicU64::new(0),
            counters: Mutex::new(Counters::default()),
            digests: Mutex::new(HashMap::new()),
        }
    }

    /// The process-wide registry used by one-shot
    /// [`crate::coordinator::jobs::dispatch`] — single CLI solves and
    /// the bench suites share encodes with each other instead of
    /// rebuilding per call. Budget: `GSEM_CACHE_BYTES` env override,
    /// else [`DEFAULT_GLOBAL_BUDGET`].
    pub fn global() -> &'static MatrixRegistry {
        static GLOBAL: OnceLock<MatrixRegistry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let budget = std::env::var("GSEM_CACHE_BYTES")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(DEFAULT_GLOBAL_BUDGET);
            MatrixRegistry::with_budget(budget)
        })
    }

    /// Register a matrix: digest its content and hand back the typed
    /// key. Registration never encodes anything — operators build
    /// lazily on first request. Re-registering the same `Arc` (every
    /// request of a batch on one matrix) is a pointer lookup, not a
    /// re-hash.
    pub fn register(&self, a: &Arc<Csr>) -> MatrixHandle {
        let ptr = Arc::as_ptr(a) as usize;
        {
            let memo = self.digests.lock().unwrap();
            if let Some((weak, digest)) = memo.get(&ptr) {
                // the allocation must still be this exact Arc — an
                // upgrade failure means the address was recycled
                if weak.upgrade().is_some_and(|live| Arc::ptr_eq(&live, a)) {
                    return MatrixHandle { digest: *digest, a: Arc::clone(a) };
                }
            }
        }
        let handle = MatrixHandle::of(a);
        let mut memo = self.digests.lock().unwrap();
        // opportunistically drop dead entries so the memo stays small
        memo.retain(|_, (weak, _)| weak.strong_count() > 0);
        memo.insert(ptr, (Arc::downgrade(a), handle.digest));
        handle
    }

    /// The encoded GSE-SEM matrix for `(handle, k)`, building it on a
    /// miss. Shared by the fixed-level operators (all three levels view
    /// one encode) and the stepped ladder.
    pub fn gse(&self, h: &MatrixHandle, k: usize, metrics: Option<&Metrics>) -> Arc<GseCsr> {
        let a = Arc::clone(h.matrix());
        self.get_or_build(Key::Gse { digest: h.digest(), k }, metrics, move || {
            CachedVal::Gse(Arc::new(GseCsr::from_csr(&a, k)))
        })
        .into_gse()
    }

    /// A type-erased fixed-format operator for `(handle, format, k)`,
    /// building it on a miss. GSE levels wrap the shared
    /// [`MatrixRegistry::gse`] encode (the wrapper itself is a cheap
    /// `Arc` view, so only the encode is memoized and budgeted).
    pub fn operator(
        &self,
        h: &MatrixHandle,
        format: ValueFormat,
        k: usize,
        metrics: Option<&Metrics>,
    ) -> Arc<dyn SpmvOp> {
        if let ValueFormat::GseSem(level) = format {
            let g = self.gse(h, k, metrics);
            return Arc::new(GseSpmv::new(g, level));
        }
        let a = Arc::clone(h.matrix());
        self.get_or_build(Key::Op { digest: h.digest(), format }, metrics, move || {
            CachedVal::Op(build_fixed_operator(&a, format, 0))
        })
        .into_op()
    }

    /// The SAINV factors for `(handle, params)`, building them on a
    /// miss. The build is **fallible** (SAINV pivots can collapse on
    /// singular or wildly indefinite matrices): an `Err` propagates to
    /// every caller that raced on this key, the slot is withdrawn, and
    /// the shard stays fully usable — a later request retries the
    /// build from scratch. Successful factors are charged against the
    /// byte budget, LRU-evicted, and spill/restore like every other
    /// entry. Build outcomes surface as `precond.builds` /
    /// `precond.build_ns` / `precond.bytes`.
    pub fn sainv(
        &self,
        h: &MatrixHandle,
        params: SainvParams,
        metrics: Option<&Metrics>,
    ) -> crate::util::error::Result<Arc<SainvFactors>> {
        let a = Arc::clone(h.matrix());
        let key = Key::Sainv { digest: h.digest(), params: params.into() };
        self.try_get_or_build(key, metrics, move || {
            let t = Timer::start();
            let f = SainvFactors::build(&a, params)?;
            if let Some(m) = metrics {
                m.incr("precond.builds");
                m.add("precond.build_ns", (t.elapsed_s() * 1e9) as u64);
                m.add("precond.bytes", f.encoded_bytes() as u64);
            }
            Ok(CachedVal::Sainv(Arc::new(f)))
        })
        .map(CachedVal::into_sainv)
    }

    /// The auto-format [`PolicyDecision`] for `(handle, solver, nrhs
    /// bucket)`, computing it on a miss. Decisions ride the same
    /// latch/LRU/spill machinery as operators: one compute under
    /// concurrency, byte-charged (they are tiny), evictable and
    /// restorable. Returns `(decision, freshly_built)` so the caller
    /// can split `policy.decisions` from `policy.cache_hits` — a
    /// spill restore counts as a hit (the compute was skipped).
    pub(crate) fn policy(
        &self,
        h: &MatrixHandle,
        solver: SolverKind,
        bucket: usize,
        metrics: Option<&Metrics>,
        build: impl FnOnce() -> PolicyDecision,
    ) -> (Arc<PolicyDecision>, bool) {
        let built = std::cell::Cell::new(false);
        let d = self
            .get_or_build(Key::Policy { digest: h.digest(), solver, bucket }, metrics, || {
                built.set(true);
                CachedVal::Policy(Arc::new(build()))
            })
            .into_policy();
        (d, built.get())
    }

    /// Aggregate hit/miss/eviction/byte counters.
    pub fn stats(&self) -> RegistryStats {
        let c = *self.counters.lock().unwrap();
        RegistryStats {
            hits: c.hits,
            misses: c.misses,
            encode_saved_s: c.encode_saved_s,
            evictions: c.evictions,
            spills: c.spills,
            restores: c.restores,
            restore_bytes: c.restore_bytes,
            restore_read_ns: c.restore_read_ns,
            bytes: self.bytes.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }

    /// Drop every resident entry, returning how many were dropped.
    /// Builds currently in flight are unaffected (they republish when
    /// they finish); outstanding `Arc`s handed to callers stay valid.
    /// This is the escape hatch for embedders of the process-wide
    /// [`MatrixRegistry::global`] cache, whose entries otherwise live
    /// until the byte budget pushes them out.
    pub fn clear(&self) -> usize {
        let mut dropped = 0usize;
        for shard in &self.shards {
            let mut map = shard.lock().unwrap();
            map.retain(|_, slot| match slot {
                Slot::Ready(e) => {
                    self.bytes.fetch_sub(e.bytes, Ordering::Relaxed);
                    dropped += 1;
                    false
                }
                Slot::Building(_) => true,
            });
        }
        // the digest memo only holds weak refs; reclaim dead slots too
        self.digests.lock().unwrap().retain(|_, (weak, _)| weak.strong_count() > 0);
        dropped
    }

    /// Resident encoded bytes currently cached.
    pub fn bytes(&self) -> usize {
        self.bytes.load(Ordering::Relaxed)
    }

    /// The configured byte budget (`usize::MAX` = unbounded).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Number of resident cached builds (operators + GSE encodes).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.lock().unwrap().values().filter(|v| matches!(v, Slot::Ready(_))).count()
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn shard_of(&self, key: &Key) -> usize {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// The registry's core path: hit, wait on a concurrent build, or
    /// become the builder. `build` runs **outside** the shard lock.
    fn get_or_build(
        &self,
        key: Key,
        metrics: Option<&Metrics>,
        build: impl FnOnce() -> CachedVal,
    ) -> CachedVal {
        let si = self.shard_of(&key);
        let mut build = Some(build);
        loop {
            let plan = {
                let mut map = self.shards[si].lock().unwrap();
                match map.get_mut(&key) {
                    Some(Slot::Ready(e)) => {
                        e.last_used = self.clock.fetch_add(1, Ordering::Relaxed);
                        Plan::Hit(e.v.clone(), e.build_s)
                    }
                    Some(Slot::Building(latch)) => Plan::Wait(Arc::clone(latch)),
                    None => {
                        map.insert(key, Slot::Building(Arc::new(Latch::new())));
                        Plan::Build
                    }
                }
            };
            match plan {
                Plan::Hit(v, saved_s) => {
                    self.credit_hit(saved_s, metrics);
                    return v;
                }
                Plan::Wait(latch) => match latch.wait() {
                    // the builder finished while we slept: a hit that
                    // cost no duplicate encode (exactly-once build)
                    Some((v, build_s)) => {
                        self.credit_hit(build_s, metrics);
                        return v;
                    }
                    // the builder withdrew (panicked); race to rebuild
                    None => continue,
                },
                Plan::Build => {
                    let mut guard = BuildGuard { reg: self, shard: si, key, armed: true };
                    // a previously evicted entry may be waiting in the
                    // spill dir: restoring skips the encode entirely,
                    // so neither `misses` nor `cache.encode` move
                    if let Some(r) = self.try_restore(&key) {
                        self.publish(si, &key, r.v.clone(), r.build_s);
                        guard.armed = false;
                        self.credit_restore(r.file_bytes, r.read_ns, metrics);
                        self.enforce_budget(metrics);
                        return r.v;
                    }
                    let t = Timer::start();
                    let run = build.take().expect("a get_or_build call builds at most once");
                    let v = run();
                    let build_s = t.elapsed_s();
                    self.publish(si, &key, v.clone(), build_s);
                    guard.armed = false;
                    self.credit_miss(build_s, metrics);
                    self.enforce_budget(metrics);
                    return v;
                }
            }
        }
    }

    /// Fallible sibling of [`MatrixRegistry::get_or_build`] for entries
    /// whose construction can legitimately fail (SAINV pivot
    /// breakdown). The hit / latch-wait / restore machinery is
    /// identical; the difference is the error path: the builder leaves
    /// its [`BuildGuard`] armed, so the guard's `Drop` withdraws the
    /// `Building` slot and fails the latch — waiters wake, see the
    /// withdrawal, loop, and retry the build themselves (each getting
    /// its own typed error if the matrix really is broken). Nothing is
    /// published, the shard is never poisoned, and a later request for
    /// the same key starts clean.
    fn try_get_or_build(
        &self,
        key: Key,
        metrics: Option<&Metrics>,
        build: impl FnOnce() -> crate::util::error::Result<CachedVal>,
    ) -> crate::util::error::Result<CachedVal> {
        let si = self.shard_of(&key);
        let mut build = Some(build);
        loop {
            let plan = {
                let mut map = self.shards[si].lock().unwrap();
                match map.get_mut(&key) {
                    Some(Slot::Ready(e)) => {
                        e.last_used = self.clock.fetch_add(1, Ordering::Relaxed);
                        Plan::Hit(e.v.clone(), e.build_s)
                    }
                    Some(Slot::Building(latch)) => Plan::Wait(Arc::clone(latch)),
                    None => {
                        map.insert(key, Slot::Building(Arc::new(Latch::new())));
                        Plan::Build
                    }
                }
            };
            match plan {
                Plan::Hit(v, saved_s) => {
                    self.credit_hit(saved_s, metrics);
                    return Ok(v);
                }
                Plan::Wait(latch) => match latch.wait() {
                    Some((v, build_s)) => {
                        self.credit_hit(build_s, metrics);
                        return Ok(v);
                    }
                    // the builder withdrew (failed or panicked); retry
                    // so this caller gets its own build outcome
                    None => continue,
                },
                Plan::Build => {
                    let mut guard = BuildGuard { reg: self, shard: si, key, armed: true };
                    if let Some(r) = self.try_restore(&key) {
                        self.publish(si, &key, r.v.clone(), r.build_s);
                        guard.armed = false;
                        self.credit_restore(r.file_bytes, r.read_ns, metrics);
                        self.enforce_budget(metrics);
                        return Ok(r.v);
                    }
                    let t = Timer::start();
                    let run = build.take().expect("a try_get_or_build call builds at most once");
                    // on Err the guard stays armed: its Drop withdraws
                    // the slot and fails the latch, releasing waiters
                    let v = run()?;
                    let build_s = t.elapsed_s();
                    self.publish(si, &key, v.clone(), build_s);
                    guard.armed = false;
                    self.credit_miss(build_s, metrics);
                    self.enforce_budget(metrics);
                    return Ok(v);
                }
            }
        }
    }

    /// Flip the builder's `Building` slot to `Ready` and release latch
    /// waiters — shared by the build and spill-restore paths.
    fn publish(&self, si: usize, key: &Key, v: CachedVal, build_s: f64) {
        let bytes = v.bytes();
        // charge the budget *before* publishing: a concurrent evictor
        // may uncharge the entry the moment it becomes visible, and the
        // counter must never go below the sum of resident entries
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        let mut map = self.shards[si].lock().unwrap();
        let slot = map.get_mut(key).expect("builder's slot is present");
        let latch = match slot {
            Slot::Building(l) => Arc::clone(l),
            Slot::Ready(_) => unreachable!("only the builder fills its slot"),
        };
        let last_used = self.clock.fetch_add(1, Ordering::Relaxed);
        *slot = Slot::Ready(CacheEntry { v: v.clone(), bytes, build_s, last_used });
        latch.fill(v, build_s);
    }

    /// Evict least-recently-used Ready entries until resident bytes fit
    /// the budget. Shards are scanned one lock at a time and victims
    /// revalidated before removal, so this never holds two locks.
    fn enforce_budget(&self, metrics: Option<&Metrics>) {
        while self.bytes.load(Ordering::Relaxed) > self.budget {
            let mut victim: Option<(usize, Key, u64)> = None;
            for (si, shard) in self.shards.iter().enumerate() {
                let map = shard.lock().unwrap();
                for (k, slot) in map.iter() {
                    if let Slot::Ready(e) = slot {
                        if victim.as_ref().map(|v| e.last_used < v.2).unwrap_or(true) {
                            victim = Some((si, *k, e.last_used));
                        }
                    }
                }
            }
            let Some((si, key, last_used)) = victim else { break };
            let mut map = self.shards[si].lock().unwrap();
            let still_lru =
                matches!(map.get(&key), Some(Slot::Ready(e)) if e.last_used == last_used);
            if still_lru {
                if let Some(Slot::Ready(e)) = map.remove(&key) {
                    drop(map);
                    self.bytes.fetch_sub(e.bytes, Ordering::Relaxed);
                    // best-effort spill before the planes drop: an I/O
                    // failure or opt-out operator just falls back to
                    // re-encoding on the next miss
                    let spilled = self
                        .spill
                        .as_deref()
                        .is_some_and(|dir| super::spill::write(dir, &key, &e.v, e.build_s));
                    {
                        let mut c = self.counters.lock().unwrap();
                        c.evictions += 1;
                        if spilled {
                            c.spills += 1;
                        }
                    }
                    if let Some(m) = metrics {
                        m.incr("cache.evictions");
                        if spilled {
                            m.incr("cache.spills");
                        }
                    }
                }
            }
            // touched since the scan: loop and pick a fresh victim
        }
        if let Some(m) = metrics {
            m.gauge_set("cache.bytes", self.bytes.load(Ordering::Relaxed) as u64);
        }
    }

    fn credit_hit(&self, saved_s: f64, metrics: Option<&Metrics>) {
        {
            let mut c = self.counters.lock().unwrap();
            c.hits += 1;
            c.encode_saved_s += saved_s;
        }
        if let Some(m) = metrics {
            m.incr("cache.hits");
            m.time("cache.encode_saved", saved_s);
        }
    }

    fn credit_miss(&self, build_s: f64, metrics: Option<&Metrics>) {
        self.counters.lock().unwrap().misses += 1;
        if let Some(m) = metrics {
            m.incr("cache.misses");
            m.time("cache.encode", build_s);
        }
    }

    /// Deserialize a spilled entry for `key`, if one exists. The
    /// restored value carries its original build seconds (so later hits
    /// credit the true saved encode time), the spill-file size, and the
    /// file-read nanoseconds. The file stays on disk: content-addressed
    /// names are never stale, so a future eviction of the restored
    /// entry can skip re-serializing.
    fn try_restore(&self, key: &Key) -> Option<super::spill::Restored> {
        super::spill::read(self.spill.as_deref()?, key)
    }

    fn credit_restore(&self, file_bytes: u64, read_ns: u64, metrics: Option<&Metrics>) {
        {
            let mut c = self.counters.lock().unwrap();
            c.restores += 1;
            c.restore_bytes += file_bytes;
            c.restore_read_ns += read_ns;
        }
        if let Some(m) = metrics {
            m.incr("cache.restores");
            m.add("cache.restore_bytes", file_bytes);
            m.add("cache.restore_read_ns", read_ns);
        }
    }

    /// Test hook: run the full hit/latch/build machinery with an
    /// injected builder, so concurrency tests can observe exactly when
    /// and how often builds run.
    #[cfg(test)]
    fn operator_with(
        &self,
        h: &MatrixHandle,
        format: ValueFormat,
        build: impl FnOnce() -> Arc<dyn SpmvOp>,
    ) -> Arc<dyn SpmvOp> {
        self.get_or_build(Key::Op { digest: h.digest(), format }, None, move || {
            CachedVal::Op(build())
        })
        .into_op()
    }
}

/// Withdraws a `Building` slot if the builder unwinds, releasing latch
/// waiters to retry instead of hanging forever.
struct BuildGuard<'a> {
    reg: &'a MatrixRegistry,
    shard: usize,
    key: Key,
    armed: bool,
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut map = self.reg.shards[self.shard].lock().unwrap();
        match map.remove(&self.key) {
            Some(Slot::Building(latch)) => latch.fail(),
            Some(ready @ Slot::Ready(_)) => {
                // defensive: never drop a published entry
                map.insert(self.key, ready);
            }
            None => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Precision;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::sparse::gen::randmat::{exp_controlled, ExpLaw};
    use crate::util::parallel;
    use crate::util::quickcheck;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn equal_content_distinct_arcs_share_one_entry() {
        // property: under pointer keying this was a guaranteed miss;
        // under content addressing it must always hit
        quickcheck::check(
            11,
            12,
            |rng| {
                let n = 4 + rng.below(24);
                let row = 1 + rng.below(5);
                let seed = rng.below(1000) as u64;
                exp_controlled(n, n, row, ExpLaw::Gaussian { e0: 0, sigma: 2.0 }, seed)
            },
            |m| {
                let reg = MatrixRegistry::new();
                let a = Arc::new(m.clone());
                let b = Arc::new(m.clone());
                assert!(!Arc::ptr_eq(&a, &b));
                let ha = reg.register(&a);
                let hb = reg.register(&b);
                if ha.digest() != hb.digest() {
                    return Err("equal content must digest equally".into());
                }
                let op1 = reg.operator(&ha, ValueFormat::Fp64, 0, None);
                let op2 = reg.operator(&hb, ValueFormat::Fp64, 0, None);
                if !Arc::ptr_eq(&op1, &op2) {
                    return Err("distinct arcs must share one cached operator".into());
                }
                let st = reg.stats();
                if (st.hits, st.misses, st.entries) != (1, 1, 1) {
                    return Err(format!(
                        "expected 1 hit / 1 miss / 1 entry, got {} / {} / {}",
                        st.hits, st.misses, st.entries
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn retuning_cached_operators_changes_no_bytes_or_keys() {
        let reg = MatrixRegistry::new();
        let a = Arc::new(poisson2d(8, 8));
        let h = reg.register(&a);
        // fixed format: the budget is shared through the cached Arc
        let op = reg.operator(&h, ValueFormat::Fp64, 0, None);
        let bytes = op.encoded_bytes();
        op.set_threads(6);
        assert_eq!(op.encoded_bytes(), bytes, "retune must not change residency");
        let again = reg.operator(&h, ValueFormat::Fp64, 0, None);
        assert!(Arc::ptr_eq(&op, &again), "retune must not change the cache key");
        assert_eq!(again.threads(), 6, "budget is shared through the entry");
        // GSE levels: fresh wrapper views, one shared encode — and one
        // shared budget, so retuning any level retunes its siblings
        let head = reg.operator(&h, ValueFormat::GseSem(Precision::Head), 8, None);
        let full = reg.operator(&h, ValueFormat::GseSem(Precision::Full), 8, None);
        let head_bytes = head.encoded_bytes();
        head.set_threads(4);
        assert_eq!(full.threads(), 4, "levels share the encode's budget");
        assert_eq!(head.encoded_bytes(), head_bytes);
        let st = reg.stats();
        assert_eq!(st.misses, 2, "one fp64 encode + one gse encode, retunes add none");
    }

    #[test]
    fn duplicate_requests_encode_exactly_once() {
        let reg = MatrixRegistry::new();
        let a = Arc::new(poisson2d(8, 8));
        let h = reg.register(&a);
        let encodes = AtomicUsize::new(0);
        let ops: Mutex<Vec<Arc<dyn SpmvOp>>> = Mutex::new(Vec::new());
        parallel::broadcast(8, |_| {
            let op = reg.operator_with(&h, ValueFormat::Fp64, || {
                // slow build: every other worker must arrive while this
                // runs and wait on the latch rather than re-encode
                std::thread::sleep(Duration::from_millis(30));
                encodes.fetch_add(1, Ordering::Relaxed);
                build_fixed_operator(&a, ValueFormat::Fp64, 0)
            });
            ops.lock().unwrap().push(op);
        });
        assert_eq!(encodes.load(Ordering::Relaxed), 1, "latch must dedupe builds");
        let ops = ops.into_inner().unwrap();
        assert!(ops.windows(2).all(|w| Arc::ptr_eq(&w[0], &w[1])));
        let st = reg.stats();
        assert_eq!((st.hits, st.misses), (7, 1));
    }

    #[test]
    fn distinct_matrices_encode_in_parallel() {
        // two slow builds on distinct keys rendezvous *inside* their
        // builders — possible only if encodes run off the global lock
        let reg = MatrixRegistry::new();
        let mats = [Arc::new(poisson2d(6, 6)), Arc::new(poisson2d(7, 7))];
        let gate = Arc::new((Mutex::new(0usize), Condvar::new()));
        parallel::broadcast(2, |w| {
            let a = &mats[w];
            let h = reg.register(a);
            reg.operator_with(&h, ValueFormat::Fp64, || {
                let (count, cv) = &*gate;
                let mut inside = count.lock().unwrap();
                *inside += 1;
                cv.notify_all();
                while *inside < 2 {
                    let (g, timeout) = cv
                        .wait_timeout(inside, Duration::from_secs(10))
                        .unwrap();
                    inside = g;
                    assert!(!timeout.timed_out(), "builds serialized behind one lock");
                }
                build_fixed_operator(a, ValueFormat::Fp64, 0)
            });
        });
        let st = reg.stats();
        assert_eq!((st.hits, st.misses), (0, 2));
    }

    #[test]
    fn gse_levels_share_one_encode() {
        let reg = MatrixRegistry::new();
        let a = Arc::new(poisson2d(8, 8));
        let h = reg.register(&a);
        let head = reg.operator(&h, ValueFormat::GseSem(Precision::Head), 8, None);
        let full = reg.operator(&h, ValueFormat::GseSem(Precision::Full), 8, None);
        assert_eq!(head.format(), ValueFormat::GseSem(Precision::Head));
        assert_eq!(full.format(), ValueFormat::GseSem(Precision::Full));
        // one encode miss, one hit; a different k encodes again
        let st = reg.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        let _k2 = reg.gse(&h, 2, None);
        assert_eq!(reg.stats().misses, 2);
        // cached operators compute the same product as fresh ones
        let x = vec![1.0; a.ncols];
        let mut y1 = vec![0.0; a.nrows];
        head.apply(&x, &mut y1);
        let mut y2 = vec![0.0; a.nrows];
        GseCsr::from_csr(&a, 8).at_level(Precision::Head).apply(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let mats: Vec<Arc<Csr>> =
            (0..3).map(|i| Arc::new(poisson2d(10 + i, 10 + i))).collect();
        let one = Fp64Csr::new(mats[0].as_ref().clone()).encoded_bytes();
        // room for about two fp64 operators of this size
        let reg = MatrixRegistry::with_budget(one * 5 / 2);
        let m = Metrics::new();
        let h0 = reg.register(&mats[0]);
        let h1 = reg.register(&mats[1]);
        let h2 = reg.register(&mats[2]);
        let _ = reg.operator(&h0, ValueFormat::Fp64, 0, Some(&m));
        let _ = reg.operator(&h1, ValueFormat::Fp64, 0, Some(&m));
        assert_eq!(reg.stats().evictions, 0);
        // touch h0 so h1 is the LRU victim when h2 arrives
        let _ = reg.operator(&h0, ValueFormat::Fp64, 0, Some(&m));
        let _ = reg.operator(&h2, ValueFormat::Fp64, 0, Some(&m));
        let st = reg.stats();
        assert_eq!(st.evictions, 1);
        assert!(st.bytes <= reg.budget());
        assert_eq!(st.entries, 2);
        assert_eq!(m.counter("cache.evictions"), 1);
        assert_eq!(m.gauge("cache.bytes"), st.bytes as u64);
        // h0 survived (recently used), h1 was evicted: re-request misses
        let before = reg.stats().misses;
        let _ = reg.operator(&h0, ValueFormat::Fp64, 0, Some(&m));
        assert_eq!(reg.stats().misses, before);
        let _ = reg.operator(&h1, ValueFormat::Fp64, 0, Some(&m));
        assert_eq!(reg.stats().misses, before + 1);
    }

    #[test]
    fn clear_drops_entries_and_uncharges_bytes() {
        let reg = MatrixRegistry::new();
        let a = Arc::new(poisson2d(8, 8));
        let h = reg.register(&a);
        let op = reg.operator(&h, ValueFormat::Fp64, 0, None);
        let _ = reg.gse(&h, 8, None);
        assert_eq!(reg.len(), 2);
        assert!(reg.bytes() > 0);
        assert_eq!(reg.clear(), 2);
        assert!(reg.is_empty());
        assert_eq!(reg.bytes(), 0);
        // handed-out operators stay usable; re-requesting re-encodes
        let x = vec![1.0; a.ncols];
        let mut y = vec![0.0; a.nrows];
        op.apply(&x, &mut y);
        let before = reg.stats().misses;
        let _ = reg.operator(&h, ValueFormat::Fp64, 0, None);
        assert_eq!(reg.stats().misses, before + 1);
    }

    #[test]
    fn register_memoizes_digest_by_pointer() {
        let reg = MatrixRegistry::new();
        let a = Arc::new(poisson2d(8, 8));
        let h1 = reg.register(&a);
        let h2 = reg.register(&a); // memo path: pointer lookup, no re-hash
        assert_eq!(h1.digest(), h2.digest());
        assert_eq!(reg.digests.lock().unwrap().len(), 1);
        // a distinct allocation gets its own memo slot but the same
        // content digest
        let b = Arc::new(poisson2d(8, 8));
        let h3 = reg.register(&b);
        assert_eq!(h1.digest(), h3.digest());
        assert_eq!(reg.digests.lock().unwrap().len(), 2);
        // dropping an Arc lets its memo entry be reclaimed on the next
        // registration, and the memoized digest stays correct
        drop(b);
        let c = Arc::new(poisson2d(9, 9));
        let hc = reg.register(&c);
        assert_eq!(hc.digest(), c.digest());
        assert!(reg.digests.lock().unwrap().len() <= 2);
    }

    #[test]
    fn sainv_factors_build_exactly_once_under_concurrent_submits() {
        let reg = MatrixRegistry::new();
        let m = Metrics::new();
        let a = Arc::new(poisson2d(8, 8));
        let h = reg.register(&a);
        let params = SainvParams { drop_tol: 0.1, k: 8 };
        let factors: Mutex<Vec<Arc<SainvFactors>>> = Mutex::new(Vec::new());
        parallel::broadcast(8, |_| {
            let f = reg.sainv(&h, params, Some(&m)).expect("spd matrix factors cleanly");
            factors.lock().unwrap().push(f);
        });
        let factors = factors.into_inner().unwrap();
        assert_eq!(factors.len(), 8);
        assert!(factors.windows(2).all(|w| Arc::ptr_eq(&w[0], &w[1])));
        assert_eq!(m.counter("precond.builds"), 1, "latch must dedupe sainv builds");
        let st = reg.stats();
        assert_eq!((st.hits, st.misses), (7, 1));
        assert!(st.bytes >= factors[0].encoded_bytes(), "factors count in cache.bytes");
        // distinct params are a distinct entry
        let other = SainvParams { drop_tol: 0.05, k: 8 };
        let f2 = reg.sainv(&h, other, Some(&m)).unwrap();
        assert!(!Arc::ptr_eq(&factors[0], &f2));
        assert_eq!(m.counter("precond.builds"), 2);
    }

    #[test]
    fn failed_sainv_build_does_not_poison_the_shard() {
        let reg = MatrixRegistry::new();
        let m = Metrics::new();
        // zero a diagonal entry: the sainv pivot collapses and the
        // build must fail with a typed error, twice in a row, without
        // hanging a latch or leaving a dead slot behind
        let mut bad = Csr::identity(4);
        bad.vals[2] = 0.0;
        let bad = Arc::new(bad);
        let hb = reg.register(&bad);
        let params = SainvParams::default();
        assert!(reg.sainv(&hb, params, Some(&m)).is_err());
        assert!(reg.sainv(&hb, params, Some(&m)).is_err(), "retry fails cleanly, no hang");
        assert_eq!(m.counter("precond.builds"), 0, "failed builds are not counted");
        assert_eq!(reg.len(), 0, "nothing published for a failed build");
        // the same registry still serves good matrices
        let good = Arc::new(poisson2d(6, 6));
        let hg = reg.register(&good);
        let f = reg.sainv(&hg, params, Some(&m)).expect("good matrix after failures");
        assert_eq!(f.nrows(), 36);
        assert_eq!(m.counter("precond.builds"), 1);
    }

    #[test]
    fn sainv_entries_are_lru_evictable() {
        let a = Arc::new(poisson2d(10, 10));
        let params = SainvParams { drop_tol: 0.1, k: 8 };
        let probe = MatrixRegistry::new();
        let hp = probe.register(&a);
        let one = probe.sainv(&hp, params, None).unwrap().encoded_bytes();
        // room for the factors but not for them plus two fp64 operators
        let reg = MatrixRegistry::with_budget(one + 1);
        let m = Metrics::new();
        let h = reg.register(&a);
        let f = reg.sainv(&h, params, Some(&m)).unwrap();
        assert!(reg.bytes() >= one);
        // a newer entry pushes the factors out (they are now LRU)
        let b = Arc::new(poisson2d(11, 11));
        let hb = reg.register(&b);
        let _ = reg.operator(&hb, ValueFormat::Fp64, 0, Some(&m));
        assert!(reg.stats().evictions >= 1, "sainv entry must be evictable");
        // the handed-out Arc stays valid; re-requesting rebuilds
        assert_eq!(f.nrows(), 100);
        let before = m.counter("precond.builds");
        let f2 = reg.sainv(&h, params, Some(&m)).unwrap();
        assert_eq!(m.counter("precond.builds"), before + 1);
        assert_eq!(f2.nrows(), 100);
    }

    #[test]
    fn metrics_surface_hits_and_saved_seconds() {
        let reg = MatrixRegistry::new();
        let m = Metrics::new();
        let a = Arc::new(poisson2d(10, 10));
        let h = reg.register(&a);
        let _ = reg.gse(&h, 8, Some(&m));
        let _ = reg.gse(&h, 8, Some(&m));
        assert_eq!(m.counter("cache.misses"), 1);
        assert_eq!(m.counter("cache.hits"), 1);
        let (n, total, _) = m.timing("cache.encode_saved");
        assert_eq!(n, 1);
        assert!(total >= 0.0);
        assert!(reg.stats().encode_saved_s >= 0.0);
        assert!(!reg.is_empty());
        assert!(reg.bytes() > 0);
        // the gauge tracks resident bytes after every build
        assert_eq!(m.gauge("cache.bytes"), reg.bytes() as u64);
    }
}
