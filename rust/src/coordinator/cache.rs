//! Coordinator-level operator cache.
//!
//! Every solve job used to re-encode its matrix from scratch —
//! `GseCsr::from_csr` per stepped request, a throwaway `Fp64Csr` clone
//! per residual check. Under the batch/suite workloads the same handful
//! of matrices are requested over and over, so the encode work is pure
//! waste. [`OperatorCache`] memoizes built operators keyed by **matrix
//! identity** (the `Arc<Csr>` pointer — entries keep the `Arc` alive so
//! a key can never be recycled while cached), storage format, and the
//! GSE shared-exponent count `k`.
//!
//! Cache outcomes surface in [`Metrics`] as `cache.hits` /
//! `cache.misses` counters and the `cache.encode_saved` timing series
//! (seconds of encode work a hit avoided); the same numbers are
//! available without a metrics sink via [`OperatorCache::stats`].
//!
//! Operators are built serially (the build runs under the cache lock so
//! concurrent pool workers never duplicate an encode) and with one SpMV
//! worker thread, matching the per-job dispatch behavior.

use crate::coordinator::metrics::Metrics;
use crate::formats::ValueFormat;
use crate::sparse::csr::Csr;
use crate::spmv::fp64::Fp64Csr;
use crate::spmv::gse::GseSpmv;
use crate::spmv::lowp::LowpCsr;
use crate::spmv::{GseCsr, SpmvOp};
use crate::util::Timer;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cache key: matrix identity + format (+ GSE `k`, 0 for non-GSE).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct Key {
    matrix: usize,
    format: ValueFormat,
    k: usize,
}

struct OpEntry {
    op: Arc<dyn SpmvOp>,
    /// seconds the build took — credited as "saved" on every hit
    build_s: f64,
    /// keeps the matrix alive so the pointer key stays unique
    _matrix: Arc<Csr>,
}

struct GseEntry {
    m: Arc<GseCsr>,
    build_s: f64,
    _matrix: Arc<Csr>,
}

/// Build a fixed-format operator from scratch (no memoization) — the
/// single construction point shared by the cache miss path and
/// uncached one-shot dispatch. `k` is the GSE shared-exponent count
/// (ignored by the non-GSE formats).
pub(crate) fn build_fixed_operator(a: &Csr, format: ValueFormat, k: usize) -> Arc<dyn SpmvOp> {
    match format {
        ValueFormat::Fp64 => Arc::new(Fp64Csr::new(a.clone())),
        ValueFormat::Fp32 => Arc::new(LowpCsr::<f32>::from_csr(a)),
        ValueFormat::Fp16 => Arc::new(LowpCsr::<crate::formats::Fp16>::from_csr(a)),
        ValueFormat::Bf16 => Arc::new(LowpCsr::<crate::formats::Bf16>::from_csr(a)),
        ValueFormat::GseSem(level) => Arc::new(GseCsr::from_csr(a, k).at_level(level)),
    }
}

/// Aggregate cache outcomes (also exported to [`Metrics`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// total encode/build seconds that hits avoided re-spending
    pub encode_saved_s: f64,
}

/// Memoized operator builds for the coordinator (see module docs).
#[derive(Default)]
pub struct OperatorCache {
    ops: Mutex<HashMap<Key, OpEntry>>,
    gse: Mutex<HashMap<(usize, usize), GseEntry>>,
    stats: Mutex<CacheStats>,
}

impl OperatorCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The encoded GSE-SEM matrix for `(a, k)`, building it on a miss.
    /// Shared by the fixed-level operators (all three levels view one
    /// encode) and the stepped ladder.
    pub fn gse(&self, a: &Arc<Csr>, k: usize, metrics: Option<&Metrics>) -> Arc<GseCsr> {
        let key = (Arc::as_ptr(a) as usize, k);
        let mut map = self.gse.lock().unwrap();
        if let Some(e) = map.get(&key) {
            self.credit_hit(e.build_s, metrics);
            return Arc::clone(&e.m);
        }
        let t = Timer::start();
        let m = Arc::new(GseCsr::from_csr(a, k));
        let build_s = t.elapsed_s();
        self.credit_miss(build_s, metrics);
        map.insert(key, GseEntry { m: Arc::clone(&m), build_s, _matrix: Arc::clone(a) });
        m
    }

    /// A type-erased fixed-format operator for `(a, format, k)`,
    /// building it on a miss. GSE levels wrap the shared
    /// [`OperatorCache::gse`] encode (the wrapper itself is a cheap
    /// `Arc` view, so only the encode is memoized).
    pub fn operator(
        &self,
        a: &Arc<Csr>,
        format: ValueFormat,
        k: usize,
        metrics: Option<&Metrics>,
    ) -> Arc<dyn SpmvOp> {
        if let ValueFormat::GseSem(level) = format {
            let g = self.gse(a, k, metrics);
            return Arc::new(GseSpmv::new(g, level));
        }
        let key = Key { matrix: Arc::as_ptr(a) as usize, format, k: 0 };
        let mut map = self.ops.lock().unwrap();
        if let Some(e) = map.get(&key) {
            self.credit_hit(e.build_s, metrics);
            return Arc::clone(&e.op);
        }
        let t = Timer::start();
        let op = build_fixed_operator(a, format, k);
        let build_s = t.elapsed_s();
        self.credit_miss(build_s, metrics);
        map.insert(key, OpEntry { op: Arc::clone(&op), build_s, _matrix: Arc::clone(a) });
        op
    }

    /// Aggregate hit/miss/saved-seconds counters.
    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }

    /// Number of cached builds (operators + GSE encodes).
    pub fn len(&self) -> usize {
        self.ops.lock().unwrap().len() + self.gse.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn credit_hit(&self, saved_s: f64, metrics: Option<&Metrics>) {
        {
            let mut st = self.stats.lock().unwrap();
            st.hits += 1;
            st.encode_saved_s += saved_s;
        }
        if let Some(m) = metrics {
            m.incr("cache.hits");
            m.time("cache.encode_saved", saved_s);
        }
    }

    fn credit_miss(&self, build_s: f64, metrics: Option<&Metrics>) {
        self.stats.lock().unwrap().misses += 1;
        if let Some(m) = metrics {
            m.incr("cache.misses");
            m.time("cache.encode", build_s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Precision;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn same_matrix_hits_distinct_matrices_miss() {
        let cache = OperatorCache::new();
        let a = Arc::new(poisson2d(8, 8));
        let b = Arc::new(poisson2d(8, 8)); // equal content, distinct identity
        let op1 = cache.operator(&a, ValueFormat::Fp64, 0, None);
        let op2 = cache.operator(&a, ValueFormat::Fp64, 0, None);
        assert!(Arc::ptr_eq(&op1, &op2));
        let _op3 = cache.operator(&b, ValueFormat::Fp64, 0, None);
        let st = cache.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn gse_levels_share_one_encode() {
        let cache = OperatorCache::new();
        let a = Arc::new(poisson2d(8, 8));
        let head = cache.operator(&a, ValueFormat::GseSem(Precision::Head), 8, None);
        let full = cache.operator(&a, ValueFormat::GseSem(Precision::Full), 8, None);
        assert_eq!(head.format(), ValueFormat::GseSem(Precision::Head));
        assert_eq!(full.format(), ValueFormat::GseSem(Precision::Full));
        // one encode miss, one hit; a different k encodes again
        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        let _k2 = cache.gse(&a, 2, None);
        assert_eq!(cache.stats().misses, 2);
        // cached operators compute the same product as fresh ones
        let x = vec![1.0; a.ncols];
        let mut y1 = vec![0.0; a.nrows];
        head.apply(&x, &mut y1);
        let mut y2 = vec![0.0; a.nrows];
        GseCsr::from_csr(&a, 8).at_level(Precision::Head).apply(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn metrics_surface_hits_and_saved_seconds() {
        let cache = OperatorCache::new();
        let m = Metrics::new();
        let a = Arc::new(poisson2d(10, 10));
        let _ = cache.gse(&a, 8, Some(&m));
        let _ = cache.gse(&a, 8, Some(&m));
        assert_eq!(m.counter("cache.misses"), 1);
        assert_eq!(m.counter("cache.hits"), 1);
        let (n, total, _) = m.timing("cache.encode_saved");
        assert_eq!(n, 1);
        assert!(total >= 0.0);
        assert!(cache.stats().encode_saved_s >= 0.0);
        assert!(!cache.is_empty());
    }
}
