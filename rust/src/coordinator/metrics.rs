//! Process-wide metrics registry: counters, gauges and timing
//! histograms for the coordinator (solve counts, SpMV calls per format,
//! precision switches, intake flushes, cache residency).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, u64>>,
    timings: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, v: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += v;
    }

    /// Set a point-in-time gauge (e.g. `cache.bytes`): last write wins,
    /// unlike the monotonic counters.
    pub fn gauge_set(&self, name: &str, v: u64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    /// Current gauge value (0 if never set).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn time(&self, name: &str, seconds: f64) {
        self.timings.lock().unwrap().entry(name.to_string()).or_default().push(seconds);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// (count, total_s, mean_s) of a timing series.
    pub fn timing(&self, name: &str) -> (usize, f64, f64) {
        let t = self.timings.lock().unwrap();
        match t.get(name) {
            Some(v) if !v.is_empty() => {
                let total: f64 = v.iter().sum();
                (v.len(), total, total / v.len() as f64)
            }
            _ => (0, 0.0, 0.0),
        }
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::from("== metrics ==\n");
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<40} {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<40} {v} (gauge)\n"));
        }
        for (k, v) in self.timings.lock().unwrap().iter() {
            let total: f64 = v.iter().sum();
            out.push_str(&format!(
                "  {k:<40} n={} total={:.3}s mean={:.3}ms\n",
                v.len(),
                total,
                1e3 * total / v.len() as f64
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("solves");
        m.add("solves", 4);
        assert_eq!(m.counter("solves"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timings_aggregate() {
        let m = Metrics::new();
        m.time("spmv", 0.5);
        m.time("spmv", 1.5);
        let (n, total, mean) = m.timing("spmv");
        assert_eq!(n, 2);
        assert!((total - 2.0).abs() < 1e-12);
        assert!((mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_updates() {
        let m = Metrics::new();
        crate::util::parallel::broadcast(4, |_| {
            for _ in 0..1000 {
                m.incr("x");
            }
        });
        assert_eq!(m.counter("x"), 4000);
    }

    #[test]
    fn report_contains_everything() {
        let m = Metrics::new();
        m.incr("a");
        m.time("b", 0.1);
        m.gauge_set("g", 7);
        let r = m.report();
        assert!(r.contains("a") && r.contains("b"));
        assert!(r.contains("g") && r.contains("7 (gauge)"));
    }

    #[test]
    fn gauges_last_write_wins() {
        let m = Metrics::new();
        assert_eq!(m.gauge("cache.bytes"), 0);
        m.gauge_set("cache.bytes", 100);
        m.gauge_set("cache.bytes", 42);
        assert_eq!(m.gauge("cache.bytes"), 42);
        assert!(m.report().contains("cache.bytes"));
    }
}
