//! Process-wide metrics registry: counters, gauges and timing
//! histograms for the coordinator (solve counts, SpMV calls per format,
//! precision switches, intake flushes / sheds, cache residency and
//! spill traffic). Besides the human-readable [`Metrics::report`],
//! [`Metrics::snapshot`] exports everything as a plain
//! [`MetricsSnapshot`] struct with a JSON renderer, so harnesses query
//! counters instead of parsing the report string.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Aggregate of one timing series in a [`MetricsSnapshot`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimingSummary {
    pub count: usize,
    pub total_s: f64,
    pub mean_s: f64,
}

/// Point-in-time copy of every counter, gauge and timing series — a
/// plain data struct, safe to hold across solver runs and to serialize
/// with [`MetricsSnapshot::to_json`].
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub timings: BTreeMap<String, TimingSummary>,
}

impl MetricsSnapshot {
    /// Counter value (0 if absent) — mirrors [`Metrics::counter`].
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Render as a JSON object (hand-rolled: no serde in this offline
    /// build). Keys are metric names; timings become
    /// `{"count": n, "total_s": x, "mean_s": y}` objects.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut out = String::from("{\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{}", esc(k), v));
        }
        out.push_str("},\"gauges\":{");
        for (i, (k, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{}", esc(k), v));
        }
        out.push_str("},\"timings\":{");
        for (i, (k, t)) in self.timings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{}\":{{\"count\":{},\"total_s\":{:.9},\"mean_s\":{:.9}}}",
                esc(k),
                t.count,
                t.total_s,
                t.mean_s
            ));
        }
        out.push_str("}}");
        out
    }
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, u64>>,
    timings: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, v: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += v;
    }

    /// Set a point-in-time gauge (e.g. `cache.bytes`): last write wins,
    /// unlike the monotonic counters.
    pub fn gauge_set(&self, name: &str, v: u64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    /// Current gauge value (0 if never set).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn time(&self, name: &str, seconds: f64) {
        self.timings.lock().unwrap().entry(name.to_string()).or_default().push(seconds);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// (count, total_s, mean_s) of a timing series.
    pub fn timing(&self, name: &str) -> (usize, f64, f64) {
        let t = self.timings.lock().unwrap();
        match t.get(name) {
            Some(v) if !v.is_empty() => {
                let total: f64 = v.iter().sum();
                (v.len(), total, total / v.len() as f64)
            }
            _ => (0, 0.0, 0.0),
        }
    }

    /// Copy every counter, gauge and timing aggregate into a plain
    /// [`MetricsSnapshot`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self.counters.lock().unwrap().clone();
        let gauges = self.gauges.lock().unwrap().clone();
        let timings = self
            .timings
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                let total: f64 = v.iter().sum();
                let mean = if v.is_empty() { 0.0 } else { total / v.len() as f64 };
                (k.clone(), TimingSummary { count: v.len(), total_s: total, mean_s: mean })
            })
            .collect();
        MetricsSnapshot { counters, gauges, timings }
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::from("== metrics ==\n");
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<40} {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<40} {v} (gauge)\n"));
        }
        for (k, v) in self.timings.lock().unwrap().iter() {
            let total: f64 = v.iter().sum();
            out.push_str(&format!(
                "  {k:<40} n={} total={:.3}s mean={:.3}ms\n",
                v.len(),
                total,
                1e3 * total / v.len() as f64
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("solves");
        m.add("solves", 4);
        assert_eq!(m.counter("solves"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timings_aggregate() {
        let m = Metrics::new();
        m.time("spmv", 0.5);
        m.time("spmv", 1.5);
        let (n, total, mean) = m.timing("spmv");
        assert_eq!(n, 2);
        assert!((total - 2.0).abs() < 1e-12);
        assert!((mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_updates() {
        let m = Metrics::new();
        crate::util::parallel::broadcast(4, |_| {
            for _ in 0..1000 {
                m.incr("x");
            }
        });
        assert_eq!(m.counter("x"), 4000);
    }

    #[test]
    fn report_contains_everything() {
        let m = Metrics::new();
        m.incr("a");
        m.time("b", 0.1);
        m.gauge_set("g", 7);
        let r = m.report();
        assert!(r.contains("a") && r.contains("b"));
        assert!(r.contains("g") && r.contains("7 (gauge)"));
    }

    #[test]
    fn snapshot_mirrors_live_state() {
        let m = Metrics::new();
        m.add("solves", 3);
        m.gauge_set("cache.bytes", 99);
        m.time("encode", 0.5);
        m.time("encode", 1.5);
        let s = m.snapshot();
        assert_eq!(s.counter("solves"), 3);
        assert_eq!(s.counter("missing"), 0);
        assert_eq!(s.gauges["cache.bytes"], 99);
        let t = s.timings["encode"];
        assert_eq!(t.count, 2);
        assert!((t.total_s - 2.0).abs() < 1e-12);
        assert!((t.mean_s - 1.0).abs() < 1e-12);
        // snapshots are detached copies
        m.incr("solves");
        assert_eq!(s.counter("solves"), 3);
    }

    #[test]
    fn snapshot_json_is_well_formed() {
        let m = Metrics::new();
        m.incr("a.b");
        m.gauge_set("g", 7);
        m.time("t", 0.25);
        let j = m.snapshot().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"a.b\":1"));
        assert!(j.contains("\"g\":7"));
        assert!(j.contains("\"count\":1"));
        // braces balance (cheap structural sanity without a parser)
        let open = j.matches('{').count();
        let close = j.matches('}').count();
        assert_eq!(open, close);
        // empty snapshot still renders all three sections
        let empty = Metrics::new().snapshot().to_json();
        assert_eq!(empty, "{\"counters\":{},\"gauges\":{},\"timings\":{}}");
    }

    #[test]
    fn gauges_last_write_wins() {
        let m = Metrics::new();
        assert_eq!(m.gauge("cache.bytes"), 0);
        m.gauge_set("cache.bytes", 100);
        m.gauge_set("cache.bytes", 42);
        assert_eq!(m.gauge("cache.bytes"), 42);
        assert!(m.report().contains("cache.bytes"));
    }
}
