//! Solve-job model and the batch front door.
//!
//! A [`SolveRequest`] names a matrix, a right-hand side, a solver and a
//! storage format (including both stepped ladders); [`dispatch`] runs
//! it through the process-wide content-addressed
//! [`MatrixRegistry`], so repeated one-shot solves share encodes with
//! everything else in the process. [`SolverPool`] is now a thin
//! submit-all-then-flush wrapper over
//! [`crate::coordinator::intake::SolverService`]: every batch rides the
//! same intake/grouping path the serving API uses, merging same-matrix
//! same-configuration requests — CG, GMRES, BiCGSTAB, fixed-format or
//! stepped — into multi-RHS block solves
//! ([`crate::solvers::cg::cg_solve_multi`] and its
//! [`crate::solvers::gmres::gmres_solve_multi`] /
//! [`crate::solvers::bicgstab::bicgstab_solve_multi`] /
//! [`crate::solvers::stepped::run_stepped_multi`] siblings). Riding
//! the intake path also buys pooled batches its **core allocator**:
//! each flushed group's operators are retuned in place
//! ([`crate::spmv::SpmvOp::set_threads`]) to a share of the service's
//! workers — a lone dominant merged block gets the full budget — with
//! results bitwise independent of the granted budget (see the intake
//! module docs).
//!
//! Since the serving hardening, [`dispatch`] / [`dispatch_cached`] and
//! [`SolverPool::run_batch`] return results typed by [`ServiceError`]:
//! solver breakdowns surface as [`ServiceError::Breakdown`] (carrying
//! the partial result) instead of an `Ok` the caller must inspect for
//! `broke_down`, and pooled batches can also report shed, cancelled or
//! expired tickets.

use crate::coordinator::error::{classify, ServiceError};
use crate::coordinator::intake::{ServiceConfig, SolverService};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::{build_fixed_operator, MatrixHandle, MatrixRegistry};
use crate::formats::ValueFormat;
use crate::solvers::bicgstab::{bicgstab_solve, BicgstabOpts};
use crate::solvers::ir::IrGmresOpts;
use crate::solvers::ladder::CopyLadderOp;
use crate::solvers::sainv::{Precond, PrecondOp};
use crate::solvers::stepped::{run_stepped, run_stepped_with, BlockSolver, SteppedParams};
use crate::solvers::{
    cg_solve, gmres_solve, ir_gmres_solve, CgOpts, GmresOpts, MonitorCmd, SolveOutcome,
};
use crate::sparse::csr::Csr;
use crate::spmv::{GseCsr, SpmvOp};
use crate::util::parallel;
use crate::util::Prng;
use std::sync::Arc;

/// Default GSE shared-exponent count (the paper's headline k).
pub const DEFAULT_K: usize = 8;

/// Which solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    Cg,
    Gmres,
    Bicgstab,
}

/// Right-hand-side specification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RhsSpec {
    /// b = A·1 (exact solution = ones; the suite default)
    AxOnes,
    /// b = 1
    Ones,
    /// b = e_i (canonical basis vector; degenerate-direction probes)
    Unit(usize),
    /// uniform random in [-1, 1]
    Random(u64),
}

impl RhsSpec {
    pub fn build(&self, a: &Csr) -> Vec<f64> {
        match self {
            RhsSpec::AxOnes => {
                let ones = vec![1.0; a.ncols];
                let mut b = vec![0.0; a.nrows];
                crate::spmv::fp64::spmv(a, &ones, &mut b);
                b
            }
            RhsSpec::Ones => vec![1.0; a.nrows],
            RhsSpec::Unit(i) => {
                let mut b = vec![0.0; a.nrows];
                if *i < b.len() {
                    b[*i] = 1.0;
                }
                b
            }
            RhsSpec::Random(seed) => {
                let mut rng = Prng::new(*seed);
                (0..a.nrows).map(|_| rng.range_f64(-1.0, 1.0)).collect()
            }
        }
    }
}

/// Storage format under test — the paper's comparison axis plus the two
/// stepped ladders (Algorithm 3 over GSE-SEM, and the copy-based
/// related-work baseline). The GSE shared-exponent count `k` lives
/// here, and only here: `FormatChoice` is the single source of truth
/// (`SolveRequest` no longer carries a duplicate).
#[derive(Clone, Debug)]
pub enum FormatChoice {
    /// Fixed storage format; `k` is the GSE-SEM shared-exponent count
    /// (ignored by non-GSE formats).
    Fixed { format: ValueFormat, k: usize },
    /// GSE-SEM with the stepped controller; k shared exponents.
    Stepped { k: usize, params: SteppedParams },
    /// Copy-based fp32→fp64 stepped ladder (related-work baseline).
    SteppedCopy { params: SteppedParams },
    /// GMRES-based iterative refinement over the GSE ladder
    /// ([`crate::solvers::ir::ir_gmres_solve`]); the request's
    /// [`Precond`] picks the preconditioner and the request's
    /// [`SolverKind`] is ignored — IR drives its own inner GMRES.
    Ir { k: usize },
    /// Entropy/byte-model-driven automatic selection
    /// ([`crate::coordinator::policy`]). Resolved to one of the
    /// concrete choices above — per matrix digest × solver ×
    /// nrhs-bucket, digest-cached in the registry — before grouping or
    /// the format dispatch ever sees it, so an `Auto` request merges
    /// with hand-picked requests for the same configuration.
    Auto,
}

/// Hashable fingerprint of a [`SteppedParams`]: the f64 thresholds are
/// keyed by bit pattern, so "same params" means the exactly-equal
/// controller configuration and nothing looser.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) struct SteppedParamsKey {
    l: usize,
    t: usize,
    m: usize,
    rsd_bits: u64,
    ndec: usize,
    reldec_bits: u64,
    div_bits: u64,
}

impl From<&SteppedParams> for SteppedParamsKey {
    fn from(p: &SteppedParams) -> Self {
        Self {
            l: p.l,
            t: p.t,
            m: p.m,
            rsd_bits: p.rsd_limit.to_bits(),
            ndec: p.ndec_limit,
            reldec_bits: p.reldec_limit.to_bits(),
            div_bits: p.divergence_factor.to_bits(),
        }
    }
}

/// The format component of the intake grouping key — what must match
/// (beyond matrix digest, solver kind and solve caps) for two requests
/// to merge into one multi-RHS block solve.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum FormatKey {
    Fixed { format: ValueFormat, k: usize },
    Stepped { k: usize, params: SteppedParamsKey },
    SteppedCopy { params: SteppedParamsKey },
    Ir { k: usize },
}

impl FormatChoice {
    /// Fixed format with the default `k` = [`DEFAULT_K`].
    pub fn fixed(format: ValueFormat) -> Self {
        FormatChoice::Fixed { format, k: DEFAULT_K }
    }

    /// The GSE shared-exponent count, if this choice encodes one.
    pub fn k(&self) -> Option<usize> {
        match self {
            FormatChoice::Fixed { format: ValueFormat::GseSem(_), k } => Some(*k),
            FormatChoice::Stepped { k, .. } => Some(*k),
            FormatChoice::Ir { k } => Some(*k),
            FormatChoice::Fixed { .. } | FormatChoice::SteppedCopy { .. } | FormatChoice::Auto => {
                None
            }
        }
    }

    /// Grouping fingerprint for the intake's batch merge. `k` is
    /// normalized away for non-GSE fixed formats (it only affects GSE
    /// storage, so numerically identical requests still batch), and
    /// [`SteppedParams`] participates bit-for-bit — two stepped
    /// requests with different controller tunings never merge, because
    /// their escalation schedules (and thus their results) differ.
    pub(crate) fn group_key(&self) -> FormatKey {
        match self {
            FormatChoice::Fixed { format, k } => {
                let k = match format {
                    ValueFormat::GseSem(_) => *k,
                    _ => 0,
                };
                FormatKey::Fixed { format: *format, k }
            }
            FormatChoice::Stepped { k, params } => {
                FormatKey::Stepped { k: *k, params: params.into() }
            }
            FormatChoice::SteppedCopy { params } => {
                FormatKey::SteppedCopy { params: params.into() }
            }
            FormatChoice::Ir { k } => FormatKey::Ir { k: *k },
            FormatChoice::Auto => {
                unreachable!("Auto resolves to a concrete choice before grouping")
            }
        }
    }
}

/// Default `(tol, max_iters)` caps for one solver kind — the single
/// source shared by [`SolveRequest::new`] and the serving path's
/// [`crate::coordinator::intake::SolveSpec::new`], so the two request
/// types can never drift apart.
pub(crate) fn default_caps(solver: SolverKind) -> (f64, usize) {
    let max_iters = match solver {
        SolverKind::Cg | SolverKind::Bicgstab => 5000,
        SolverKind::Gmres => 15000,
    };
    (1e-6, max_iters)
}

/// One solve job, addressed by `Arc` — the thin legacy shim kept for
/// one-shot [`dispatch`] and `SolverPool::run_batch` callers.
/// Migration note: the serving path's
/// [`crate::coordinator::intake::SolveSpec`] is the single owner of a
/// request's name / RHS / tolerance / caps (plus deadline and
/// priority); prefer it when talking to a
/// [`crate::coordinator::intake::SolverService`] — this type survives
/// as the `Arc`-addressed front for registry-less dispatch.
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub name: String,
    pub a: Arc<Csr>,
    pub rhs: RhsSpec,
    pub solver: SolverKind,
    pub format: FormatChoice,
    /// Preconditioner spec: `Jacobi` scales CG's residual
    /// (other fixed solvers ignore it), `Sainv(..)` requires the
    /// [`FormatChoice::Ir`] format, where it is applied inside the
    /// inner GMRES at the ladder's active rung.
    pub precond: Precond,
    pub tol: f64,
    pub max_iters: usize,
}

impl SolveRequest {
    pub fn new(name: &str, a: Arc<Csr>, solver: SolverKind, format: FormatChoice) -> Self {
        let (tol, max_iters) = default_caps(solver);
        Self {
            name: name.to_string(),
            a,
            rhs: RhsSpec::AxOnes,
            solver,
            format,
            precond: Precond::None,
            tol,
            max_iters,
        }
    }
}

/// Job result: outcome + labels.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub name: String,
    pub solver: SolverKind,
    pub format_label: String,
    pub outcome: SolveOutcome,
    /// relative residual measured against the FP64 matrix (the paper's
    /// reported "Relative Residual")
    pub relres_fp64: f64,
}

/// Run one request synchronously through the process-wide
/// [`MatrixRegistry::global`] — single CLI solves and the bench suites
/// share encodes with pooled solves in the same process instead of
/// rebuilding operators from scratch per call. Results are identical
/// to an uncached build (the registry returns exactly the operator it
/// would construct); a solver breakdown comes back as
/// [`ServiceError::Breakdown`] carrying the partial result.
pub fn dispatch(req: &SolveRequest) -> Result<SolveResult, ServiceError> {
    dispatch_cached(req, Some(MatrixRegistry::global()), None)
}

/// Run one request, reusing encoded operators from `registry` (when
/// given) and reporting cache/solve counters into `metrics` (when
/// given). Breakdowns surface as [`ServiceError::Breakdown`].
pub fn dispatch_cached(
    req: &SolveRequest,
    registry: Option<&MatrixRegistry>,
    metrics: Option<&Metrics>,
) -> Result<SolveResult, ServiceError> {
    match registry {
        Some(reg) => dispatch_with_handle(req, &reg.register(&req.a), reg, metrics),
        None => dispatch_inner(req, None, metrics),
    }
    .and_then(classify)
}

/// Registry-backed dispatch for a caller that already digested the
/// matrix (the intake queue's path — no per-request re-hash). An `Err`
/// here is a *construction* failure (an invalid precond/format pairing
/// or a SAINV pivot breakdown); solver breakdowns are an `Ok` result
/// the caller runs through [`classify`].
pub(crate) fn dispatch_with_handle(
    req: &SolveRequest,
    handle: &MatrixHandle,
    registry: &MatrixRegistry,
    metrics: Option<&Metrics>,
) -> Result<SolveResult, ServiceError> {
    dispatch_inner(req, Some((registry, handle)), metrics)
}

fn dispatch_inner(
    req: &SolveRequest,
    cached: Option<(&MatrixRegistry, &MatrixHandle)>,
    metrics: Option<&Metrics>,
) -> Result<SolveResult, ServiceError> {
    // an Auto choice resolves here on the one-shot path (the serving
    // path resolves in the intake flusher, before grouping) at batch
    // width 1 — digest-cached when a registry is present
    let resolved;
    let req = match req.format {
        FormatChoice::Auto => {
            let choice = crate::coordinator::policy::resolve_dispatch(
                cached,
                &req.a,
                req.solver,
                &req.precond,
                1,
                metrics,
            );
            resolved = SolveRequest { format: choice, ..req.clone() };
            &resolved
        }
        _ => req,
    };
    if matches!(req.precond, Precond::Sainv(_)) && !matches!(req.format, FormatChoice::Ir { .. })
    {
        return Err(ServiceError::Registry(crate::util::error::Error::msg(
            "sainv preconditioning requires the ir format",
        )));
    }
    let a = req.a.as_ref();
    let b = req.rhs.build(a);
    // single lookup point: registry when available, fresh build when not
    let op_for = |format: ValueFormat, k: usize| -> Arc<dyn SpmvOp> {
        match cached {
            Some((reg, h)) => reg.operator(h, format, k, metrics),
            None => build_fixed_operator(a, format, k),
        }
    };
    let (outcome, label) = match &req.format {
        FormatChoice::Fixed { format, k } => {
            let op = op_for(*format, *k);
            let mut noop = |_: usize, _: f64| MonitorCmd::Continue;
            let out = run_solver_monitored(req, op.as_ref(), &b, &mut noop);
            (out, format.label().to_string())
        }
        FormatChoice::Stepped { k, params } => {
            let g: Arc<GseCsr> = match cached {
                Some((reg, h)) => reg.gse(h, *k, metrics),
                None => Arc::new(GseCsr::from_csr(a, *k)),
            };
            let (out, _, _) = run_stepped(g, *params, |op, monitor| {
                run_solver_monitored(req, op, &b, monitor)
            });
            // feed the policy's online ladder-depth refinement
            if let Some((_, h)) = cached {
                crate::coordinator::policy::record_switches(
                    h.digest(),
                    req.solver,
                    out.iters,
                    &out.switches,
                );
            }
            (out, "GSE-SEM".to_string())
        }
        FormatChoice::SteppedCopy { params } => {
            // both rungs come from the registry (when present) so
            // repeated jobs share the fp32/fp64 copies; only the tag
            // state is per-solve
            let op =
                CopyLadderOp::new(op_for(ValueFormat::Fp32, 0), op_for(ValueFormat::Fp64, 0));
            let (out, _, _) = run_stepped_with(&op, *params, |op, monitor| {
                run_solver_monitored(req, op, &b, monitor)
            });
            (out, "FP32->FP64".to_string())
        }
        FormatChoice::Ir { k } => {
            let g: Arc<GseCsr> = match cached {
                Some((reg, h)) => reg.gse(h, *k, metrics),
                None => Arc::new(GseCsr::from_csr(a, *k)),
            };
            // SAINV factors come from the registry when one is present
            // (built exactly once per digest × params, LRU-budgeted);
            // a pivot breakdown surfaces as a typed construction error
            let m = match (&req.precond, cached) {
                (Precond::Sainv(p), Some((reg, h))) => {
                    PrecondOp::Sainv(reg.sainv(h, *p, metrics)?)
                }
                _ => PrecondOp::for_spec(&req.precond, a)?,
            };
            let opts = IrGmresOpts::for_caps(req.tol, req.max_iters);
            let out = ir_gmres_solve(&g, &m, &b, &opts);
            (out, ir_label(&req.precond).to_string())
        }
        FormatChoice::Auto => {
            unreachable!("Auto resolved to a concrete choice at the top of dispatch_inner")
        }
    };
    // the paper's reported residual: against the FP64 matrix
    let fp64_op = op_for(ValueFormat::Fp64, 0);
    let relres_fp64 = crate::solvers::true_relres(fp64_op.as_ref(), &outcome.x, &b);
    Ok(SolveResult {
        name: req.name.clone(),
        solver: req.solver,
        format_label: label,
        outcome,
        relres_fp64,
    })
}

/// Result label for the IR format, suffixed by the preconditioner.
pub(crate) fn ir_label(p: &Precond) -> &'static str {
    match p {
        Precond::None => "GSE-IR",
        Precond::Jacobi => "GSE-IR(jacobi)",
        Precond::Sainv(_) => "GSE-IR(sainv)",
    }
}

/// The per-solver caps for one request — the single source of the
/// `SolverKind` → options mapping (GMRES turns the iteration cap into
/// restart-30 outer cycles), shared by single dispatch
/// ([`run_solver_monitored`]) and the intake's block path, so the two
/// can never drift apart and break block/single bitwise parity.
pub(crate) fn solver_opts(
    solver: SolverKind,
    tol: f64,
    max_iters: usize,
    inv_diag: Option<Vec<f64>>,
) -> BlockSolver {
    match solver {
        SolverKind::Cg => BlockSolver::Cg(CgOpts { tol, max_iters, inv_diag }),
        SolverKind::Gmres => {
            BlockSolver::Gmres(GmresOpts { tol, restart: 30, max_outer: max_iters.div_ceil(30) })
        }
        SolverKind::Bicgstab => BlockSolver::Bicgstab(BicgstabOpts { tol, max_iters }),
    }
}

/// The inverse-diagonal vector a [`Precond::Jacobi`] request feeds into
/// [`CgOpts::inv_diag`] — shared by single dispatch and the intake's
/// block path so preconditioned parity holds bitwise. `None` / `Sainv`
/// contribute nothing here (SAINV lives inside the IR format).
pub(crate) fn precond_inv_diag(p: &Precond, a: &Csr) -> Option<Vec<f64>> {
    match p {
        Precond::Jacobi => Some(crate::solvers::precond::Jacobi::from_csr(a).inv_diag),
        Precond::None | Precond::Sainv(_) => None,
    }
}

/// One solver invocation with an installed monitor — the plumbing every
/// format path (fixed, GSE stepped, copy stepped) shares. The monitor
/// is what the stepped controllers hook; plain solves pass a no-op.
fn run_solver_monitored(
    req: &SolveRequest,
    op: &dyn SpmvOp,
    b: &[f64],
    monitor: &mut dyn FnMut(usize, f64) -> MonitorCmd,
) -> SolveOutcome {
    let inv_diag = precond_inv_diag(&req.precond, &req.a);
    match solver_opts(req.solver, req.tol, req.max_iters, inv_diag) {
        BlockSolver::Cg(o) => cg_solve(op, b, &o, monitor),
        BlockSolver::Gmres(o) => gmres_solve(op, b, &o, monitor),
        BlockSolver::Bicgstab(o) => bicgstab_solve(op, b, &o, monitor),
    }
}

/// Fixed-size worker pool — since the serving redesign, a thin
/// submit-all-then-flush wrapper over a manual-mode
/// [`SolverService`]: every request goes through the same
/// digest-keyed intake/grouping path the windowed service uses, so
/// same-matrix requests with equal solver/format/caps (even behind
/// distinct `Arc`s) — CG, GMRES, BiCGSTAB, fixed-format or stepped —
/// are solved as one multi-RHS block and every job shares the pool's
/// content-addressed [`MatrixRegistry`] (one encode per digest ×
/// format × k). Per-column results are bit-for-bit what individual
/// dispatch would produce; results come back in submission order.
pub struct SolverPool {
    svc: SolverService,
}

impl SolverPool {
    pub fn new(workers: usize) -> Self {
        Self { svc: SolverService::manual(ServiceConfig::new().workers(workers)) }
    }

    /// Worker pool sized from `GSEM_WORKERS` / the machine's parallelism.
    pub fn with_default_workers() -> Self {
        Self::new(parallel::default_workers())
    }

    /// Pool-lifetime counters: cache hits/misses/evictions, encode
    /// seconds saved, intake flushes, multi-RHS groups formed.
    pub fn metrics(&self) -> &Metrics {
        self.svc.metrics()
    }

    /// The pool's operator registry (shared across batches).
    pub fn cache(&self) -> &MatrixRegistry {
        self.svc.registry()
    }

    /// Run a batch, preserving input order: submit everything into the
    /// service's intake, flush once, wait the tickets. Each slot is the
    /// job's result or the typed [`ServiceError`] that kept it from
    /// producing one (a breakdown, or — under a bounded queue — a shed).
    pub fn run_batch(&self, reqs: Vec<SolveRequest>) -> Vec<Result<SolveResult, ServiceError>> {
        let tickets: Vec<_> = reqs.into_iter().map(|r| self.svc.submit_request(r)).collect();
        self.svc.flush();
        tickets.into_iter().map(|t| t.and_then(|t| t.wait())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Precision;
    use crate::sparse::gen::convdiff::convdiff2d;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn dispatch_cg_fp64() {
        let a = Arc::new(poisson2d(10, 10));
        let fmt = FormatChoice::fixed(ValueFormat::Fp64);
        let req = SolveRequest::new("p", a, SolverKind::Cg, fmt);
        let res = dispatch(&req).unwrap();
        assert!(res.outcome.converged);
        assert!(res.relres_fp64 < 1e-6);
        assert_eq!(res.format_label, "FP64");
    }

    #[test]
    fn dispatch_gmres_gse_head() {
        let a = Arc::new(convdiff2d(10, 10, 4.0, 2.0));
        let req = SolveRequest::new(
            "c",
            a,
            SolverKind::Gmres,
            FormatChoice::fixed(ValueFormat::GseSem(Precision::Head)),
        );
        let res = dispatch(&req).unwrap();
        // head-only decode still converges on this well-conditioned system
        assert!(res.outcome.converged);
    }

    #[test]
    fn dispatch_stepped_records_label() {
        let a = Arc::new(poisson2d(8, 8));
        let req = SolveRequest::new(
            "s",
            a,
            SolverKind::Cg,
            FormatChoice::Stepped { k: 8, params: SteppedParams::cg_paper().scaled(0.01) },
        );
        let res = dispatch(&req).unwrap();
        assert_eq!(res.format_label, "GSE-SEM");
        assert!(res.outcome.converged);
    }

    #[test]
    fn dispatch_stepped_copy_ladder() {
        let a = Arc::new(poisson2d(8, 8));
        let req = SolveRequest::new(
            "sc",
            a,
            SolverKind::Cg,
            FormatChoice::SteppedCopy { params: SteppedParams::cg_paper().scaled(0.01) },
        );
        let res = dispatch(&req).unwrap();
        assert_eq!(res.format_label, "FP32->FP64");
        assert!(res.outcome.converged, "relres={}", res.relres_fp64);
    }

    #[test]
    fn dispatch_ir_with_sainv_reaches_tight_tolerance() {
        use crate::solvers::SainvParams;
        let a = Arc::new(poisson2d(10, 10));
        let mut req = SolveRequest::new("ir", a, SolverKind::Gmres, FormatChoice::Ir { k: 8 });
        req.precond = Precond::Sainv(SainvParams { drop_tol: 0.05, k: 8 });
        req.tol = 1e-10;
        let reg = MatrixRegistry::new();
        let m = Metrics::new();
        let res = dispatch_cached(&req, Some(&reg), Some(&m)).unwrap();
        assert!(res.outcome.converged);
        assert_eq!(res.format_label, "GSE-IR(sainv)");
        assert!(res.relres_fp64 < 1e-8, "relres={}", res.relres_fp64);
        assert_eq!(m.counter("precond.builds"), 1);
        // a second dispatch reuses the cached factors
        let _ = dispatch_cached(&req, Some(&reg), Some(&m)).unwrap();
        assert_eq!(m.counter("precond.builds"), 1);
    }

    #[test]
    fn dispatch_ir_unpreconditioned_and_jacobi_labels() {
        let a = Arc::new(poisson2d(8, 8));
        let mut req =
            SolveRequest::new("ir0", Arc::clone(&a), SolverKind::Gmres, FormatChoice::Ir { k: 8 });
        let res = dispatch_cached(&req, None, None).unwrap();
        assert_eq!(res.format_label, "GSE-IR");
        assert!(res.outcome.converged);
        req.precond = Precond::Jacobi;
        let res = dispatch_cached(&req, None, None).unwrap();
        assert_eq!(res.format_label, "GSE-IR(jacobi)");
        assert!(res.outcome.converged);
    }

    #[test]
    fn sainv_precond_requires_ir_format() {
        use crate::solvers::SainvParams;
        let a = Arc::new(poisson2d(6, 6));
        let mut req =
            SolveRequest::new("bad", a, SolverKind::Cg, FormatChoice::fixed(ValueFormat::Fp64));
        req.precond = Precond::Sainv(SainvParams::default());
        let err = dispatch_cached(&req, None, None).unwrap_err();
        assert!(matches!(err, ServiceError::Registry(_)), "got {err:?}");
    }

    #[test]
    fn jacobi_precond_speeds_up_ill_scaled_cg() {
        // scale the Poisson system's rows/cols wildly: plain CG slows
        // down, Jacobi restores the iteration count
        let base = poisson2d(12, 12);
        let scales: Vec<f64> = (0..base.nrows).map(|i| 10f64.powi((i % 7) as i32 - 3)).collect();
        let mut scaled = base.clone();
        for i in 0..scaled.nrows {
            let (start, end) = (scaled.rowptr[i], scaled.rowptr[i + 1]);
            for idx in start..end {
                let j = scaled.colidx[idx] as usize;
                scaled.vals[idx] *= scales[i] * scales[j];
            }
        }
        let a = Arc::new(scaled);
        let mut plain = SolveRequest::new(
            "plain",
            Arc::clone(&a),
            SolverKind::Cg,
            FormatChoice::fixed(ValueFormat::Fp64),
        );
        plain.max_iters = 20000;
        let mut pre = plain.clone();
        pre.name = "jacobi".into();
        pre.precond = Precond::Jacobi;
        let plain = dispatch_cached(&plain, None, None).unwrap();
        let pre = dispatch_cached(&pre, None, None).unwrap();
        assert!(pre.outcome.converged);
        assert!(
            pre.outcome.iters < plain.outcome.iters,
            "jacobi {} vs plain {}",
            pre.outcome.iters,
            plain.outcome.iters
        );
    }

    #[test]
    fn dispatch_uncached_matches_registry_dispatch() {
        // the registry returns exactly the operator it would build:
        // cached and uncached dispatch agree bitwise
        let a = Arc::new(poisson2d(9, 9));
        let mut req = SolveRequest::new(
            "u",
            a,
            SolverKind::Cg,
            FormatChoice::fixed(ValueFormat::GseSem(Precision::Full)),
        );
        req.rhs = RhsSpec::Random(5);
        let uncached = dispatch_cached(&req, None, None).unwrap();
        let reg = MatrixRegistry::new();
        let cached = dispatch_cached(&req, Some(&reg), None).unwrap();
        assert_eq!(uncached.outcome.iters, cached.outcome.iters);
        assert_eq!(uncached.outcome.x, cached.outcome.x);
        assert_eq!(uncached.relres_fp64.to_bits(), cached.relres_fp64.to_bits());
        assert_eq!(reg.stats().misses, 2);
    }

    #[test]
    fn stepped_copy_jobs_share_cached_rungs() {
        let a = Arc::new(poisson2d(8, 8));
        let params = SteppedParams::cg_paper().scaled(0.01);
        let reqs: Vec<SolveRequest> = (0..2)
            .map(|i| {
                let mut r = SolveRequest::new(
                    &format!("c{i}"),
                    Arc::clone(&a),
                    SolverKind::Cg,
                    FormatChoice::SteppedCopy { params },
                );
                r.rhs = RhsSpec::Random(i as u64);
                r
            })
            .collect();
        let pool = SolverPool::new(2);
        let res = pool.run_batch(reqs);
        assert!(res.iter().all(|r| r.as_ref().unwrap().outcome.converged));
        // equal-params stepped-copy jobs now merge into one block over
        // a single shared fp32/fp64 ladder: two rung encodes, and the
        // fp64 residual lookup hits the cached high rung
        let st = pool.cache().stats();
        assert_eq!(st.misses, 2);
        assert!(st.hits >= 1, "hits={}", st.hits);
        assert_eq!(pool.metrics().counter("pool.batched_groups"), 1);
        assert_eq!(pool.metrics().counter("pool.batched_stepped"), 1);
    }

    #[test]
    fn group_key_separates_stepped_params_and_normalizes_fixed_k() {
        // SteppedParams participates in the key: differently tuned
        // stepped requests must never merge
        let a = SteppedParams::cg_paper();
        let b = SteppedParams::cg_paper().scaled(0.5);
        let mut c = a;
        c.rsd_limit += 1e-9;
        let key = |f: &FormatChoice| f.group_key();
        assert_eq!(
            key(&FormatChoice::Stepped { k: 8, params: a }),
            key(&FormatChoice::Stepped { k: 8, params: a })
        );
        assert_ne!(
            key(&FormatChoice::Stepped { k: 8, params: a }),
            key(&FormatChoice::Stepped { k: 8, params: b })
        );
        assert_ne!(
            key(&FormatChoice::Stepped { k: 8, params: a }),
            key(&FormatChoice::Stepped { k: 8, params: c }),
            "an epsilon threshold change must change the key"
        );
        assert_ne!(
            key(&FormatChoice::Stepped { k: 8, params: a }),
            key(&FormatChoice::Stepped { k: 4, params: a }),
            "k participates for the GSE stepped ladder"
        );
        assert_eq!(
            key(&FormatChoice::SteppedCopy { params: a }),
            key(&FormatChoice::SteppedCopy { params: a })
        );
        assert_ne!(
            key(&FormatChoice::SteppedCopy { params: a }),
            key(&FormatChoice::SteppedCopy { params: b })
        );
        // the stepped and copy ladders never merge with each other
        assert_ne!(
            key(&FormatChoice::Stepped { k: 8, params: a }),
            key(&FormatChoice::SteppedCopy { params: a })
        );
        // k is normalized away for non-GSE fixed formats...
        assert_eq!(
            key(&FormatChoice::Fixed { format: ValueFormat::Fp64, k: 8 }),
            key(&FormatChoice::Fixed { format: ValueFormat::Fp64, k: 3 })
        );
        // ...but kept for GSE storage, where it changes the encode
        assert_ne!(
            key(&FormatChoice::Fixed { format: ValueFormat::GseSem(Precision::Head), k: 8 }),
            key(&FormatChoice::Fixed { format: ValueFormat::GseSem(Precision::Head), k: 3 })
        );
    }

    #[test]
    fn format_choice_owns_k() {
        assert_eq!(FormatChoice::fixed(ValueFormat::Fp64).k(), None);
        let g = FormatChoice::Fixed { format: ValueFormat::GseSem(Precision::Head), k: 16 };
        assert_eq!(g.k(), Some(16));
        let s = FormatChoice::Stepped { k: 4, params: SteppedParams::cg_paper() };
        assert_eq!(s.k(), Some(4));
        let c = FormatChoice::SteppedCopy { params: SteppedParams::cg_paper() };
        assert_eq!(c.k(), None);
    }

    #[test]
    fn pool_preserves_order_and_completes() {
        let a = Arc::new(poisson2d(8, 8));
        let reqs: Vec<SolveRequest> = (0..6)
            .map(|i| {
                let mut r = SolveRequest::new(
                    &format!("job{i}"),
                    Arc::clone(&a),
                    SolverKind::Cg,
                    FormatChoice::fixed(ValueFormat::Fp64),
                );
                r.rhs = RhsSpec::Random(i as u64);
                r
            })
            .collect();
        let pool = SolverPool::new(3);
        let res = pool.run_batch(reqs);
        assert_eq!(res.len(), 6);
        for (i, r) in res.iter().enumerate() {
            let r = r.as_ref().unwrap();
            assert_eq!(r.name, format!("job{i}"));
            assert!(r.outcome.converged);
        }
        // all six shared one matrix+format: one multi-RHS group
        assert_eq!(pool.metrics().counter("pool.batched_groups"), 1);
        assert_eq!(pool.metrics().counter("pool.batched_rhs"), 6);
        assert_eq!(pool.metrics().counter("intake.flushes"), 1);
    }

    #[test]
    fn batched_group_matches_individual_dispatch_bitwise() {
        let a = Arc::new(poisson2d(9, 9));
        let mk = |seed: u64| {
            let mut r = SolveRequest::new(
                "b",
                Arc::clone(&a),
                SolverKind::Cg,
                FormatChoice::fixed(ValueFormat::Fp64),
            );
            r.rhs = RhsSpec::Random(seed);
            r
        };
        let pool = SolverPool::new(2);
        let batched: Vec<SolveResult> =
            pool.run_batch(vec![mk(1), mk(2), mk(3)]).into_iter().map(|r| r.unwrap()).collect();
        for (seed, br) in (1u64..=3).zip(&batched) {
            let single = dispatch(&mk(seed)).unwrap();
            assert_eq!(br.outcome.iters, single.outcome.iters, "seed {seed}");
            assert_eq!(br.outcome.x, single.outcome.x, "seed {seed}");
            assert_eq!(br.relres_fp64.to_bits(), single.relres_fp64.to_bits(), "seed {seed}");
        }
    }

    #[test]
    fn pool_groups_equal_content_behind_distinct_arcs() {
        // digest keying: three separately-allocated copies of one
        // matrix still merge into a single multi-RHS group (pointer
        // keys made each of these a singleton)
        let reqs: Vec<SolveRequest> = (0..3)
            .map(|i| {
                let mut r = SolveRequest::new(
                    &format!("copy{i}"),
                    Arc::new(poisson2d(8, 8)),
                    SolverKind::Cg,
                    FormatChoice::fixed(ValueFormat::Fp64),
                );
                r.rhs = RhsSpec::Random(i as u64);
                r
            })
            .collect();
        let pool = SolverPool::new(2);
        let res = pool.run_batch(reqs);
        assert!(res.iter().all(|r| r.as_ref().unwrap().outcome.converged));
        assert_eq!(pool.metrics().counter("pool.batched_groups"), 1);
        assert_eq!(pool.metrics().counter("pool.batched_rhs"), 3);
        // and one fp64 operator served all three (plus the residual)
        assert_eq!(pool.cache().stats().misses, 1);
    }

    #[test]
    fn pool_cache_reuses_encodes_across_formats() {
        let a = Arc::new(poisson2d(8, 8));
        let mut reqs = Vec::new();
        for level in Precision::LADDER {
            reqs.push(SolveRequest::new(
                "g",
                Arc::clone(&a),
                SolverKind::Cg,
                FormatChoice::fixed(ValueFormat::GseSem(level)),
            ));
        }
        let pool = SolverPool::new(1);
        let res = pool.run_batch(reqs);
        assert_eq!(res.len(), 3);
        // one GSE encode + one FP64 residual operator; everything else hits
        let st = pool.cache().stats();
        assert_eq!(st.misses, 2, "hits={} misses={}", st.hits, st.misses);
        assert!(st.hits >= 3);
        assert!(pool.metrics().counter("cache.hits") >= 3);
    }

    #[test]
    fn rhs_specs() {
        let a = poisson2d(4, 4);
        assert_eq!(RhsSpec::Ones.build(&a), vec![1.0; 16]);
        let b = RhsSpec::AxOnes.build(&a);
        // row sums of the Laplacian: interior 0, boundary positive
        assert!(b.iter().all(|&v| v >= 0.0));
        let r1 = RhsSpec::Random(1).build(&a);
        let r2 = RhsSpec::Random(1).build(&a);
        assert_eq!(r1, r2);
        assert_ne!(r1, RhsSpec::Random(2).build(&a));
        let e3 = RhsSpec::Unit(3).build(&a);
        assert_eq!(e3.iter().sum::<f64>(), 1.0);
        assert_eq!(e3[3], 1.0);
        // out-of-range index degrades to the zero vector, not a panic
        assert!(RhsSpec::Unit(99).build(&a).iter().all(|&v| v == 0.0));
    }
}
