//! Solve-job model and worker pool.
//!
//! A [`SolveRequest`] names a matrix, a right-hand side, a solver and a
//! storage format (including the stepped GSE-SEM mode); [`dispatch`]
//! runs it; [`SolverPool`] fans a batch out over OS threads with an
//! mpsc-based queue (the offline substitute for a tokio runtime —
//! DESIGN.md §5).

use crate::formats::ValueFormat;
use crate::solvers::bicgstab::{bicgstab_solve, BicgstabOpts};
use crate::solvers::stepped::{run_stepped, SteppedParams};
use crate::solvers::{cg_solve, gmres_solve, CgOpts, GmresOpts, SolveOutcome};
use crate::sparse::csr::Csr;
use crate::spmv::fp64::Fp64Csr;
use crate::spmv::lowp::LowpCsr;
use crate::spmv::{GseCsr, SpmvOp};
use crate::util::parallel;
use crate::util::Prng;
use std::sync::Arc;

/// Which solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Cg,
    Gmres,
    Bicgstab,
}

/// Right-hand-side specification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RhsSpec {
    /// b = A·1 (exact solution = ones; the suite default)
    AxOnes,
    /// b = 1
    Ones,
    /// uniform random in [-1, 1]
    Random(u64),
}

impl RhsSpec {
    pub fn build(&self, a: &Csr) -> Vec<f64> {
        match self {
            RhsSpec::AxOnes => {
                let ones = vec![1.0; a.ncols];
                let mut b = vec![0.0; a.nrows];
                crate::spmv::fp64::spmv(a, &ones, &mut b);
                b
            }
            RhsSpec::Ones => vec![1.0; a.nrows],
            RhsSpec::Random(seed) => {
                let mut rng = Prng::new(*seed);
                (0..a.nrows).map(|_| rng.range_f64(-1.0, 1.0)).collect()
            }
        }
    }
}

/// Storage format under test — the paper's comparison axis, plus the
/// stepped mode (Algorithm 3).
#[derive(Clone, Debug)]
pub enum FormatChoice {
    Fixed(ValueFormat),
    /// GSE-SEM with the stepped controller; k shared exponents.
    Stepped { k: usize, params: SteppedParams },
}

/// One solve job.
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub name: String,
    pub a: Arc<Csr>,
    pub rhs: RhsSpec,
    pub solver: SolverKind,
    pub format: FormatChoice,
    pub tol: f64,
    pub max_iters: usize,
    /// GSE-SEM shared exponent count for Fixed(GseSem) formats
    pub k: usize,
}

impl SolveRequest {
    pub fn new(name: &str, a: Arc<Csr>, solver: SolverKind, format: FormatChoice) -> Self {
        Self {
            name: name.to_string(),
            a,
            rhs: RhsSpec::AxOnes,
            solver,
            format,
            tol: 1e-6,
            max_iters: match solver {
                SolverKind::Cg | SolverKind::Bicgstab => 5000,
                SolverKind::Gmres => 15000,
            },
            k: 8,
        }
    }
}

/// Job result: outcome + labels.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub name: String,
    pub solver: SolverKind,
    pub format_label: String,
    pub outcome: SolveOutcome,
    /// relative residual measured against the FP64 matrix (the paper's
    /// reported "Relative Residual")
    pub relres_fp64: f64,
}

/// Run one request synchronously.
pub fn dispatch(req: &SolveRequest) -> SolveResult {
    let a = req.a.as_ref();
    let b = req.rhs.build(a);
    let (outcome, label) = match &req.format {
        FormatChoice::Fixed(fmt) => {
            let op: Box<dyn SpmvOp> = match fmt {
                ValueFormat::Fp64 => Box::new(Fp64Csr::new(a.clone())),
                ValueFormat::Fp32 => Box::new(LowpCsr::<f32>::from_csr(a)),
                ValueFormat::Fp16 => Box::new(LowpCsr::<crate::formats::Fp16>::from_csr(a)),
                ValueFormat::Bf16 => Box::new(LowpCsr::<crate::formats::Bf16>::from_csr(a)),
                ValueFormat::GseSem(level) => {
                    Box::new(GseCsr::from_csr(a, req.k).at_level(*level))
                }
            };
            (run_solver(req, op.as_ref(), &b), fmt.label().to_string())
        }
        FormatChoice::Stepped { k, params } => {
            let g = GseCsr::from_csr(a, *k);
            let (out, _, _) = run_stepped(g, *params, |op, monitor| match req.solver {
                SolverKind::Cg => cg_solve(
                    op,
                    &b,
                    &CgOpts { tol: req.tol, max_iters: req.max_iters, inv_diag: None },
                    monitor,
                ),
                SolverKind::Gmres => gmres_solve(
                    op,
                    &b,
                    &GmresOpts {
                        tol: req.tol,
                        restart: 30,
                        max_outer: req.max_iters.div_ceil(30),
                    },
                    monitor,
                ),
                SolverKind::Bicgstab => bicgstab_solve(
                    op,
                    &b,
                    &BicgstabOpts { tol: req.tol, max_iters: req.max_iters },
                    monitor,
                ),
            });
            (out, "GSE-SEM".to_string())
        }
    };
    // the paper's reported residual: against the FP64 matrix
    let fp64_op = Fp64Csr::new(a.clone());
    let relres_fp64 = crate::solvers::true_relres(&fp64_op, &outcome.x, &b);
    SolveResult {
        name: req.name.clone(),
        solver: req.solver,
        format_label: label,
        outcome,
        relres_fp64,
    }
}

fn run_solver(req: &SolveRequest, op: &dyn SpmvOp, b: &[f64]) -> SolveOutcome {
    match req.solver {
        SolverKind::Cg => cg_solve(
            op,
            b,
            &CgOpts { tol: req.tol, max_iters: req.max_iters, inv_diag: None },
            |_, _| crate::solvers::MonitorCmd::Continue,
        ),
        SolverKind::Gmres => gmres_solve(
            op,
            b,
            &GmresOpts { tol: req.tol, restart: 30, max_outer: req.max_iters.div_ceil(30) },
            |_, _| crate::solvers::MonitorCmd::Continue,
        ),
        SolverKind::Bicgstab => bicgstab_solve(
            op,
            b,
            &BicgstabOpts { tol: req.tol, max_iters: req.max_iters },
            |_, _| crate::solvers::MonitorCmd::Continue,
        ),
    }
}

/// Fixed-size worker pool over the shared [`parallel::run_queue`]
/// machinery; results come back in submission order.
pub struct SolverPool {
    workers: usize,
}

impl SolverPool {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Worker pool sized from `GSEM_WORKERS` / the machine's parallelism.
    pub fn with_default_workers() -> Self {
        Self::new(parallel::default_workers())
    }

    /// Run a batch, preserving input order.
    pub fn run_batch(&self, reqs: Vec<SolveRequest>) -> Vec<SolveResult> {
        parallel::run_queue(self.workers, reqs, |req| dispatch(&req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Precision;
    use crate::sparse::gen::convdiff::convdiff2d;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn dispatch_cg_fp64() {
        let a = Arc::new(poisson2d(10, 10));
        let req = SolveRequest::new("p", a, SolverKind::Cg, FormatChoice::Fixed(ValueFormat::Fp64));
        let res = dispatch(&req);
        assert!(res.outcome.converged);
        assert!(res.relres_fp64 < 1e-6);
        assert_eq!(res.format_label, "FP64");
    }

    #[test]
    fn dispatch_gmres_gse_head() {
        let a = Arc::new(convdiff2d(10, 10, 4.0, 2.0));
        let req = SolveRequest::new(
            "c",
            a,
            SolverKind::Gmres,
            FormatChoice::Fixed(ValueFormat::GseSem(Precision::Head)),
        );
        let res = dispatch(&req);
        // head-only decode still converges on this well-conditioned system
        assert!(res.outcome.converged);
    }

    #[test]
    fn dispatch_stepped_records_label() {
        let a = Arc::new(poisson2d(8, 8));
        let req = SolveRequest::new(
            "s",
            a,
            SolverKind::Cg,
            FormatChoice::Stepped { k: 8, params: SteppedParams::cg_paper().scaled(0.01) },
        );
        let res = dispatch(&req);
        assert_eq!(res.format_label, "GSE-SEM");
        assert!(res.outcome.converged);
    }

    #[test]
    fn pool_preserves_order_and_completes() {
        let a = Arc::new(poisson2d(8, 8));
        let reqs: Vec<SolveRequest> = (0..6)
            .map(|i| {
                SolveRequest::new(
                    &format!("job{i}"),
                    Arc::clone(&a),
                    SolverKind::Cg,
                    FormatChoice::Fixed(ValueFormat::Fp64),
                )
            })
            .collect();
        let pool = SolverPool::new(3);
        let res = pool.run_batch(reqs);
        assert_eq!(res.len(), 6);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.name, format!("job{i}"));
            assert!(r.outcome.converged);
        }
    }

    #[test]
    fn rhs_specs() {
        let a = poisson2d(4, 4);
        assert_eq!(RhsSpec::Ones.build(&a), vec![1.0; 16]);
        let b = RhsSpec::AxOnes.build(&a);
        // row sums of the Laplacian: interior 0, boundary positive
        assert!(b.iter().all(|&v| v >= 0.0));
        let r1 = RhsSpec::Random(1).build(&a);
        let r2 = RhsSpec::Random(1).build(&a);
        assert_eq!(r1, r2);
        assert_ne!(r1, RhsSpec::Random(2).build(&a));
    }
}
