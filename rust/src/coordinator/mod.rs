//! L3 coordinator (DESIGN.md §2): the paper's contribution is the
//! numeric format + solver policy (L1/L2), so L3 is the serving layer —
//! a solve-job model, a long-lived [`SolverService`] with bounded,
//! windowed intake ([`intake`]: admission control, deadlines,
//! priorities, cancellation), a typed failure taxonomy ([`error`]), a
//! sharded content-addressed operator registry ([`registry`]) with disk
//! spill of evicted encodes (the `spill` codec) — holding fixed-format
//! operators, shared GSE encodes, SAINV preconditioner factors
//! (built fallibly, exactly once per digest × params), **and**
//! auto-format policy decisions ([`policy`]: entropy + byte-model
//! driven [`FormatChoice::Auto`] resolution, cached per digest ×
//! solver × nrhs bucket) — the [`SolverPool`] batch wrapper with
//! same-matrix multi-RHS merging, a metrics registry with serializable
//! snapshots ([`metrics`]), and the CLI plumbing that runs the
//! experiment suite and the `serve` trace replay / soak harness. No
//! request-path python anywhere.

pub mod registry;
pub mod intake;
pub mod jobs;
pub mod error;
pub mod metrics;
pub mod cli;
pub mod policy;
pub(crate) mod spill;

pub use crate::solvers::{Precond, SainvParams};
pub use error::ServiceError;
pub use intake::{ServiceConfig, SolveSpec, SolveTicket, SolverService};
pub use jobs::{FormatChoice, RhsSpec, SolveRequest, SolveResult, SolverKind, SolverPool};
pub use metrics::{Metrics, MetricsSnapshot};
pub use policy::PolicyDecision;
pub use registry::{MatrixHandle, MatrixRegistry, RegistryStats};
