//! Thin L3 coordinator (DESIGN.md §2): the paper's contribution is the
//! numeric format + solver policy (L1/L2), so L3 is a driver — a solve-
//! job model, a worker pool, a metrics registry, and the CLI plumbing
//! that runs the experiment suite. No request-path python anywhere.

pub mod jobs;
pub mod metrics;
pub mod cli;

pub use jobs::{FormatChoice, RhsSpec, SolveRequest, SolveResult, SolverKind, SolverPool};
pub use metrics::Metrics;
