//! Thin L3 coordinator (DESIGN.md §2): the paper's contribution is the
//! numeric format + solver policy (L1/L2), so L3 is a driver — a solve-
//! job model, a worker pool with same-matrix multi-RHS batching, an
//! operator cache, a metrics registry, and the CLI plumbing that runs
//! the experiment suite. No request-path python anywhere.

pub mod cache;
pub mod jobs;
pub mod metrics;
pub mod cli;

pub use cache::{CacheStats, OperatorCache};
pub use jobs::{FormatChoice, RhsSpec, SolveRequest, SolveResult, SolverKind, SolverPool};
pub use metrics::Metrics;
