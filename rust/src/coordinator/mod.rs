//! L3 coordinator (DESIGN.md §2): the paper's contribution is the
//! numeric format + solver policy (L1/L2), so L3 is the serving layer —
//! a solve-job model, a long-lived [`SolverService`] with windowed
//! intake ([`intake`]), a sharded content-addressed operator registry
//! ([`registry`]), the [`SolverPool`] batch wrapper with same-matrix
//! multi-RHS merging, a metrics registry, and the CLI plumbing that
//! runs the experiment suite and the `serve` trace replay. No
//! request-path python anywhere.

pub mod registry;
pub mod intake;
pub mod jobs;
pub mod metrics;
pub mod cli;

pub use intake::{ServiceConfig, SolveSpec, SolveTicket, SolverService};
pub use jobs::{FormatChoice, RhsSpec, SolveRequest, SolveResult, SolverKind, SolverPool};
pub use metrics::Metrics;
pub use registry::{MatrixHandle, MatrixRegistry, RegistryStats};
