//! Windowed intake and the long-lived [`SolverService`] front door.
//!
//! `SolverPool::run_batch` was a one-shot API: it could only merge
//! same-matrix CG requests that happened to arrive in the *same call*.
//! A serving system sees the opposite arrival pattern — requests
//! trickle in staggered — so the service puts an `IntakeQueue` in
//! front of the grouping logic: [`SolverService::submit`] enqueues a
//! [`SolveSpec`] and returns a [`SolveTicket`] immediately, and a
//! background flusher holds the batch open until either a time
//! **window** elapses (measured from the batch's first arrival) or a
//! **batch-width** target is reached, then flushes everything pending
//! through the same digest-keyed grouping — staggered same-matrix
//! requests with equal solver/format/caps merge into one block solve:
//! [`crate::solvers::cg::cg_solve_multi`],
//! [`crate::solvers::gmres::gmres_solve_multi`],
//! [`crate::solvers::bicgstab::bicgstab_solve_multi`], or a
//! [`crate::solvers::stepped::run_stepped_multi`] block sharing one
//! precision ladder across per-column controllers.
//!
//! The serving path is hardened end to end, with every failure typed
//! as a [`ServiceError`]:
//!
//! * **Admission control** — [`ServiceConfig::queue_depth`] bounds the
//!   intake; a full queue sheds the submit with
//!   [`ServiceError::Overloaded`] (counted in `intake.shed`) instead
//!   of queuing forever.
//! * **Deadlines & priorities** — [`SolveSpec::deadline_in`] /
//!   [`SolveSpec::priority`] ride the spec. The flusher orders groups
//!   highest-priority first (ties: oldest arrival), expired tickets
//!   resolve with [`ServiceError::DeadlineExceeded`], and a deadline
//!   passing *mid-solve* deflates just that column out of its running
//!   block.
//! * **Cancellation** — [`SolveTicket::cancel`] resolves the ticket
//!   with [`ServiceError::Cancelled`]; an in-flight block deflates the
//!   cancelled column while its siblings stay bitwise identical to
//!   one-shot dispatch (`solvers::block`'s ctl contract).
//! * **Operator spill** — [`ServiceConfig::spill_dir`] hands the
//!   registry a directory where LRU-evicted operators are serialized;
//!   a digest re-hit restores from disk instead of re-paying the
//!   encode (`cache.spills` / `cache.restores` / `cache.restore_bytes`).
//!
//! Grouping is keyed on the [`MatrixHandle`]'s content digest (not
//! `Arc` identity) plus the solver kind, the format fingerprint
//! (`FormatChoice::group_key` — stepped controller params
//! participate bit-for-bit) and the solve caps, so equal matrices
//! submitted by unrelated callers batch together; per-request results
//! stay bitwise-identical to one-shot dispatch because the multi-RHS
//! kernels are bit-for-bit per column (PR 2's contract, re-verified in
//! `tests/service_parity.rs` and `tests/block_parity.rs`).
//!
//! [`ServiceConfig`] (builder) sets workers, window, batch width,
//! queue depth, the registry's cache byte budget and its spill
//! directory. Two driving modes share all the flush machinery:
//!
//! * [`SolverService::new`] — spawns the background flusher thread
//!   (the serving mode; `gsem serve` and the intake ablation use it);
//! * [`SolverService::manual`] — no thread; the caller decides when to
//!   [`SolverService::flush`]. `SolverPool::run_batch` is now exactly
//!   submit-all-then-flush over a manual service.
//!
//! Intake activity surfaces in [`Metrics`] as `intake.submitted` /
//! `intake.flushes` / `intake.merged` / `intake.shed` /
//! `intake.cancelled` / `intake.deadline_expired` counters and the
//! `intake.depth` gauge, next to the registry's `cache.*` family.
//!
//! # Core allocation: two-level parallelism
//!
//! A flush schedules its groups on the worker queue (groups run
//! concurrently, one slot each) **and** hands every group an
//! intra-group worker budget via the operators' runtime-reconfigurable
//! [`crate::spmv::SpmvOp::set_threads`] surface — retuning a registry
//! operator is an atomic store on its shared
//! [`crate::spmv::ThreadBudget`], zero re-encode, no change to digest
//! keys or `encoded_bytes`. The allocator divides
//! [`ServiceConfig::workers`] cores across the flushed groups by
//! weight (`max(nnz, rows) × nrhs` — nnz-informed like the ELL
//! chunker's row weights):
//!
//! * a group whose row-work (`rows × nrhs`) stays under
//!   [`crate::spmv::par_min_rows`] is granted one core — its kernels
//!   would take the serial fallback anyway, exactly the one-per-core
//!   behavior small groups always had;
//! * the rest split the budget proportionally (floor-rounded, minimum
//!   one core each), and rounding leftovers go to the heaviest group —
//!   so a dominant merged block alone in a flush gets the **full**
//!   budget, converting the merge from a bytes win into a wall-clock
//!   win;
//! * [`ServiceConfig::op_threads`] (nonzero) overrides the policy with
//!   a fixed per-group budget (`serve --op-threads` in the CLI).
//!
//! Any budget is bit-for-bit identical to serial (rows never split
//! across workers — the [`crate::util::parallel`] invariant), so
//! allocation only moves wall time, never results; concurrent groups
//! that share a registry operator may race on its budget, and that too
//! is benign for the same reason. Allocation surfaces as the
//! `pool.group_threads` gauge, the `pool.group_ns` counter (plus the
//! `pool.group` timing series), and the `intake.group_split` counter
//! (flushes whose core budget was divided across ≥ 2 groups).

use crate::coordinator::error::{classify, ServiceError};
use crate::coordinator::jobs::{
    default_caps, dispatch_with_handle, ir_label, precond_inv_diag, solver_opts, FormatChoice,
    FormatKey, RhsSpec, SolveRequest, SolveResult, SolverKind,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::{MatrixHandle, MatrixRegistry};
use crate::formats::ValueFormat;
use crate::solvers::bicgstab::bicgstab_solve_multi_ctl;
use crate::solvers::block::{BlockCtl, ColumnExit};
use crate::solvers::cg::cg_solve_multi_ctl;
use crate::solvers::gmres::gmres_solve_multi_ctl;
use crate::solvers::ir::{ir_solve_multi_ctl, IrGmresOpts};
use crate::solvers::ladder::{CopyLadderOp, SwitchableOp};
use crate::solvers::sainv::{Precond, PrecondKey, PrecondOp};
use crate::solvers::stepped::{run_stepped_multi_ctl, BlockSolver};
use crate::solvers::SolveOutcome;
use crate::sparse::csr::{Csr, MatrixDigest};
use crate::util::parallel;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Builder-style configuration for a [`SolverService`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads draining flushed groups.
    pub workers: usize,
    /// How long the intake holds a batch open after its first request
    /// arrives (zero = flush on every submit).
    pub window: Duration,
    /// Flush early once this many requests are pending.
    pub batch_width: usize,
    /// Registry byte budget (`None` = unbounded, the pool default).
    pub cache_bytes: Option<usize>,
    /// Bound on pending intake requests (`None` = unbounded). A full
    /// queue sheds further submits with [`ServiceError::Overloaded`].
    pub queue_depth: Option<usize>,
    /// Directory for the registry's operator spill: LRU-evicted
    /// encodes are serialized here and restored on the next digest hit
    /// (`None` = evictions just drop and rebuild).
    pub spill_dir: Option<PathBuf>,
    /// Fixed intra-group worker budget applied to every flushed group
    /// (0 = allocator-managed: the flusher divides [`Self::workers`]
    /// across concurrent groups by weight — see the module docs).
    pub op_threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: parallel::default_workers(),
            window: Duration::from_millis(5),
            batch_width: 32,
            cache_bytes: None,
            queue_depth: None,
            spill_dir: None,
            op_threads: 0,
        }
    }
}

impl ServiceConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    pub fn window(mut self, w: Duration) -> Self {
        self.window = w;
        self
    }

    pub fn window_ms(self, ms: u64) -> Self {
        self.window(Duration::from_millis(ms))
    }

    pub fn batch_width(mut self, n: usize) -> Self {
        self.batch_width = n.max(1);
        self
    }

    pub fn cache_bytes(mut self, bytes: usize) -> Self {
        self.cache_bytes = Some(bytes);
        self
    }

    /// Bound the intake queue: at most `n` requests pending at once,
    /// further submits shed with [`ServiceError::Overloaded`].
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = Some(n.max(1));
        self
    }

    /// Spill LRU-evicted operators into `dir` (created on first use)
    /// and restore them on the next digest hit.
    pub fn spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spill_dir = Some(dir.into());
        self
    }

    /// Pin every group's intra-group worker budget to `n` instead of
    /// letting the flusher's core allocator divide [`Self::workers`]
    /// by group weight (0 restores allocator management).
    pub fn op_threads(mut self, n: usize) -> Self {
        self.op_threads = n;
        self
    }
}

/// One solve request addressed by registry handle — since the serving
/// redesign the **single owner** of a request's name / RHS / tolerance
/// / iteration caps plus the serving-only `deadline` and `priority`
/// fields ([`SolveRequest`] is the thin `Arc`-addressed shim kept for
/// one-shot dispatch).
#[derive(Clone, Debug)]
pub struct SolveSpec {
    pub name: String,
    pub matrix: MatrixHandle,
    pub rhs: RhsSpec,
    pub solver: SolverKind,
    pub format: FormatChoice,
    /// Preconditioner spec ([`Precond::None`] by default). A batching
    /// axis: only same-preconditioner requests merge.
    pub precond: Precond,
    pub tol: f64,
    pub max_iters: usize,
    /// Absolute wall-clock deadline: past it the ticket resolves with
    /// [`ServiceError::DeadlineExceeded`] — before the flush, or
    /// mid-solve by deflating the column out of its block.
    pub deadline: Option<Instant>,
    /// Flush-order priority (higher runs first; default 0). Ties break
    /// by arrival age, oldest first.
    pub priority: i32,
}

impl SolveSpec {
    /// Spec with the dispatch defaults (`AxOnes` RHS, 1e-6 tolerance,
    /// solver-dependent iteration caps, no deadline, priority 0).
    pub fn new(name: &str, matrix: MatrixHandle, solver: SolverKind, format: FormatChoice) -> Self {
        let (tol, max_iters) = default_caps(solver);
        Self {
            name: name.to_string(),
            matrix,
            rhs: RhsSpec::AxOnes,
            solver,
            format,
            precond: Precond::None,
            tol,
            max_iters,
            deadline: None,
            priority: 0,
        }
    }

    /// Replace the right-hand side.
    pub fn rhs(mut self, rhs: RhsSpec) -> Self {
        self.rhs = rhs;
        self
    }

    /// Replace the preconditioner spec.
    pub fn precond(mut self, p: Precond) -> Self {
        self.precond = p;
        self
    }

    /// Replace the convergence tolerance.
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Replace the iteration cap.
    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// Absolute deadline for this solve.
    pub fn deadline_at(mut self, d: Instant) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Deadline `d` from now.
    pub fn deadline_in(self, d: Duration) -> Self {
        self.deadline_at(Instant::now() + d)
    }

    /// Flush-order priority (higher runs first).
    pub fn priority(mut self, p: i32) -> Self {
        self.priority = p;
        self
    }

    /// The equivalent `Arc`-addressed request (dispatch plumbing;
    /// deadline/priority are serving-path concerns and do not ride).
    pub(crate) fn to_request(&self) -> SolveRequest {
        SolveRequest {
            name: self.name.clone(),
            a: Arc::clone(self.matrix.matrix()),
            rhs: self.rhs,
            solver: self.solver,
            format: self.format.clone(),
            precond: self.precond.clone(),
            tol: self.tol,
            max_iters: self.max_iters,
        }
    }
}

/// Receipt for a submitted solve; redeem with [`SolveTicket::wait`].
pub struct SolveTicket {
    rx: mpsc::Receiver<Result<SolveResult, ServiceError>>,
    cancel: Arc<AtomicBool>,
    /// the one-shot result was already handed out via `try_wait`
    answered: bool,
}

impl SolveTicket {
    fn new(rx: mpsc::Receiver<Result<SolveResult, ServiceError>>, cancel: Arc<AtomicBool>) -> Self {
        Self { rx, cancel, answered: false }
    }

    /// Block until the service answers this request: the solve result,
    /// or the typed reason it never produced one (cancelled, expired,
    /// broke down, service shut down). Panics if the one-shot result
    /// was already redeemed via [`SolveTicket::try_wait`] (caller bug,
    /// not a service failure).
    pub fn wait(self) -> Result<SolveResult, ServiceError> {
        assert!(!self.answered, "ticket already redeemed via try_wait");
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(ServiceError::Shutdown),
        }
    }

    /// The result, if its flush already completed; `None` while the
    /// request is still pending, and also after the one result was
    /// already handed out (the channel is one-shot). A service that
    /// died *without ever answering* yields [`ServiceError::Shutdown`]
    /// instead of letting pollers spin forever.
    pub fn try_wait(&mut self) -> Option<Result<SolveResult, ServiceError>> {
        match self.rx.try_recv() {
            Ok(res) => {
                self.answered = true;
                Some(res)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) if self.answered => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.answered = true;
                Some(Err(ServiceError::Shutdown))
            }
        }
    }

    /// Ask the service to abandon this solve. Before the flush the
    /// ticket resolves with [`ServiceError::Cancelled`] without running
    /// at all; mid-solve the column deflates out of its running block
    /// (siblings stay bitwise identical to one-shot dispatch). A solve
    /// that already finished keeps its result — cancel is best-effort,
    /// never an error.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// A queued request plus the channel its result travels back on.
struct PendingSolve {
    spec: SolveSpec,
    tx: mpsc::Sender<Result<SolveResult, ServiceError>>,
    cancel: Arc<AtomicBool>,
    /// submit time: the flusher's age tiebreak, oldest first.
    arrived: Instant,
}

/// Accumulates staggered submissions until the flusher takes them.
struct IntakeQueue {
    state: Mutex<IntakeState>,
    arrivals: Condvar,
    /// admission bound (`None` = unbounded).
    depth: Option<usize>,
}

struct IntakeState {
    pending: Vec<PendingSolve>,
    /// when the oldest pending request arrived (window anchor)
    first_arrival: Option<Instant>,
    shutdown: bool,
}

impl IntakeQueue {
    fn new(depth: Option<usize>) -> Self {
        Self {
            state: Mutex::new(IntakeState {
                pending: Vec::new(),
                first_arrival: None,
                shutdown: false,
            }),
            arrivals: Condvar::new(),
            depth,
        }
    }

    /// Admit one request, or shed it: `Err(depth)` when the queue is
    /// already holding `depth >= bound` pending solves.
    fn push(&self, p: PendingSolve) -> Result<(), usize> {
        let mut st = self.state.lock().unwrap();
        if let Some(bound) = self.depth {
            if st.pending.len() >= bound {
                return Err(st.pending.len());
            }
        }
        if st.pending.is_empty() {
            st.first_arrival = Some(Instant::now());
        }
        st.pending.push(p);
        self.arrivals.notify_all();
        Ok(())
    }

    /// Drain everything pending right now (manual flush).
    fn take(&self) -> Vec<PendingSolve> {
        let mut st = self.state.lock().unwrap();
        st.first_arrival = None;
        std::mem::take(&mut st.pending)
    }

    fn len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.arrivals.notify_all();
    }

    /// Block until a batch is ready — the oldest pending request aged
    /// past `window`, `width` requests accumulated, or shutdown — and
    /// drain it. `None` means shutdown with nothing left to flush.
    fn wait_batch(&self, window: Duration, width: usize) -> Option<Vec<PendingSolve>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.pending.is_empty() {
                if st.shutdown {
                    return None;
                }
                st = self.arrivals.wait(st).unwrap();
                continue;
            }
            if st.shutdown || st.pending.len() >= width {
                break;
            }
            let Some(first) = st.first_arrival else { break };
            let deadline = first + window;
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self.arrivals.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        st.first_arrival = None;
        Some(std::mem::take(&mut st.pending))
    }
}

/// Batch-grouping key: requests on content-equal matrices with the
/// same solver, format fingerprint ([`FormatChoice::group_key`] — the
/// stepped controller params participate bit-for-bit) and solve caps
/// merge into one multi-RHS block solve. Digest-keyed, so structurally
/// equal matrices behind distinct `Arc`s batch together (pointer keys
/// could not). Every solver/format combination is groupable: CG,
/// GMRES and BiCGSTAB over fixed formats, plus both stepped ladders.
/// Deadline and priority do **not** participate — they shape when a
/// group runs and when a column leaves it, not the arithmetic.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct GroupKey {
    digest: MatrixDigest,
    solver: SolverKind,
    format: FormatKey,
    precond: PrecondKey,
    tol_bits: u64,
    max_iters: usize,
}

fn group_key(spec: &SolveSpec) -> GroupKey {
    GroupKey {
        digest: spec.matrix.digest(),
        solver: spec.solver,
        format: spec.format.group_key(),
        precond: (&spec.precond).into(),
        tol_bits: spec.tol.to_bits(),
        max_iters: spec.max_iters,
    }
}

/// Flush-order policy: highest max-priority group first, ties broken
/// by earliest arrival — urgent traffic runs first, starved groups
/// still drain in age order behind it.
fn order_groups(groups: &mut [Vec<PendingSolve>]) {
    fn pri(g: &[PendingSolve]) -> i32 {
        g.iter().map(|p| p.spec.priority).max().unwrap_or(0)
    }
    fn age(g: &[PendingSolve]) -> Option<Instant> {
        g.iter().map(|p| p.arrived).min()
    }
    groups.sort_by(|ga, gb| pri(gb).cmp(&pri(ga)).then_with(|| age(ga).cmp(&age(gb))));
}

struct ServiceInner {
    workers: usize,
    window: Duration,
    batch_width: usize,
    op_threads: usize,
    registry: Arc<MatrixRegistry>,
    metrics: Metrics,
    intake: IntakeQueue,
}

/// Core allocator for one flush: divide `workers` cores across the
/// flushed groups by weight, where a group's weight is
/// `max(nnz, rows) × nrhs` — the same nnz-informed work estimate the
/// ELL chunker applies per row, lifted to whole groups. Policy (see
/// the module docs):
///
/// * a group whose row-work (`rows × nrhs`) stays under
///   [`crate::spmv::par_min_rows`] gets one core — its kernels take
///   the serial fallback anyway;
/// * the rest split the budget proportionally (floored, minimum one),
///   with rounding leftovers granted to the heaviest group, so a lone
///   dominant merged block receives the full budget;
/// * a nonzero `op_threads` override pins every group to that count.
///
/// Returns one intra-group budget per group, each in
/// `[1, max(workers, op_threads)]`.
fn allocate_threads(workers: usize, op_threads: usize, groups: &[Vec<PendingSolve>]) -> Vec<usize> {
    if op_threads > 0 {
        return vec![op_threads; groups.len()];
    }
    let workers = workers.max(1);
    let min_rows = crate::spmv::par_min_rows();
    // weight 0 marks a group too small to split profitably
    let weights: Vec<u128> = groups
        .iter()
        .map(|g| {
            let a = g[0].spec.matrix.matrix();
            if a.nrows.saturating_mul(g.len()) < min_rows {
                0
            } else {
                (a.nnz().max(a.nrows) as u128) * (g.len() as u128)
            }
        })
        .collect();
    let total: u128 = weights.iter().sum();
    let mut budgets: Vec<usize> = weights
        .iter()
        .map(|&w| {
            if w == 0 || total == 0 {
                1
            } else {
                (((workers as u128) * w / total) as usize).clamp(1, workers)
            }
        })
        .collect();
    // floor rounding can strand cores; hand them to the heaviest
    // splittable group (ties break to the first, i.e. highest priority)
    if let Some(hi) = (0..groups.len()).filter(|&i| weights[i] > 0).max_by_key(|&i| weights[i]) {
        let used: usize = budgets.iter().sum();
        if used < workers {
            budgets[hi] = (budgets[hi] + (workers - used)).min(workers);
        }
    }
    budgets
}

impl ServiceInner {
    fn flusher_loop(&self) {
        while let Some(batch) = self.intake.wait_batch(self.window, self.batch_width) {
            self.run_flush(batch);
        }
    }

    /// Group one drained batch, order the groups by priority/age, and
    /// solve them on the worker queue, answering every ticket. Results
    /// are routed by per-ticket channels, so callers see submission
    /// order regardless of how groups interleave.
    fn run_flush(&self, mut batch: Vec<PendingSolve>) {
        self.metrics.gauge_set("intake.depth", self.intake.len() as u64);
        if batch.is_empty() {
            return;
        }
        self.metrics.incr("intake.flushes");
        self.resolve_auto_formats(&mut batch);
        let mut groups: Vec<Vec<PendingSolve>> = Vec::new();
        let mut by_key: HashMap<GroupKey, usize> = HashMap::new();
        for p in batch {
            match by_key.entry(group_key(&p.spec)) {
                Entry::Occupied(e) => groups[*e.get()].push(p),
                Entry::Vacant(v) => {
                    v.insert(groups.len());
                    groups.push(vec![p]);
                }
            }
        }
        let merged: u64 = groups.iter().filter(|g| g.len() > 1).map(|g| g.len() as u64).sum();
        if merged > 0 {
            self.metrics.add("intake.merged", merged);
        }
        order_groups(&mut groups);
        let budgets = allocate_threads(self.workers, self.op_threads, &groups);
        if groups.len() > 1 && budgets.iter().any(|&b| b > 1) {
            // the flush's core budget was actually divided across groups
            self.metrics.incr("intake.group_split");
        }
        let jobs: Vec<(Vec<PendingSolve>, usize)> = groups.into_iter().zip(budgets).collect();
        parallel::run_queue(self.workers, jobs, |(g, threads)| self.run_group(g, threads));
    }

    /// Resolve every [`FormatChoice::Auto`] spec in a drained batch to
    /// its concrete choice *before* grouping keys are formed, so auto
    /// requests merge with hand-picked requests for the same resolved
    /// configuration. The policy's batch width is the number of
    /// same-digest × same-solver Auto specs in this flush — the width
    /// those columns will solve at if they all merge (hand-picked
    /// siblings only widen the block, which favors the same choice).
    /// Decisions are digest-cached in the registry, so repeat flushes
    /// pay one lookup per Auto spec (`policy.cache_hits`).
    fn resolve_auto_formats(&self, batch: &mut [PendingSolve]) {
        let mut widths: HashMap<(MatrixDigest, SolverKind), usize> = HashMap::new();
        for p in batch.iter() {
            if matches!(p.spec.format, FormatChoice::Auto) {
                *widths.entry((p.spec.matrix.digest(), p.spec.solver)).or_insert(0) += 1;
            }
        }
        if widths.is_empty() {
            return;
        }
        for p in batch.iter_mut() {
            if !matches!(p.spec.format, FormatChoice::Auto) {
                continue;
            }
            let nrhs = widths[&(p.spec.matrix.digest(), p.spec.solver)];
            let choice = crate::coordinator::policy::resolve_dispatch(
                Some((self.registry.as_ref(), &p.spec.matrix)),
                p.spec.matrix.matrix(),
                p.spec.solver,
                &p.spec.precond,
                nrhs,
                Some(&self.metrics),
            );
            p.spec.format = choice;
        }
    }

    /// Answer a ticket that never ran (triage or mid-block deflation).
    fn resolve_dead(&self, p: PendingSolve, exit: ColumnExit) {
        let name = p.spec.name;
        let err = match exit {
            ColumnExit::Cancelled => {
                self.metrics.incr("intake.cancelled");
                ServiceError::Cancelled { name }
            }
            ColumnExit::DeadlineExceeded => {
                self.metrics.incr("intake.deadline_expired");
                ServiceError::DeadlineExceeded { name }
            }
            ColumnExit::Completed => unreachable!("completed columns carry results"),
        };
        let _ = p.tx.send(Err(err));
    }

    /// Point a spec's operator(s) at the granted worker budget before a
    /// singleton dispatch. Registry entries are shared and budgets are
    /// sticky, so this must run on every dispatch — a previous flush
    /// may have left a different budget behind. The fetch is the same
    /// cached lookup the dispatch itself performs a moment later, so
    /// misses are not doubled.
    fn tune_singleton(&self, spec: &SolveSpec, threads: usize) {
        let handle = &spec.matrix;
        let m = Some(&self.metrics);
        match &spec.format {
            FormatChoice::Fixed { format, k } => {
                self.registry.operator(handle, *format, *k, m).set_threads(threads);
            }
            FormatChoice::Stepped { k, .. } => {
                // the budget lives on the shared encode: every ladder
                // rung over this GseCsr retunes at once
                self.registry.gse(handle, *k, m).threads.set(threads);
            }
            FormatChoice::SteppedCopy { .. } => {
                self.registry.operator(handle, ValueFormat::Fp32, 0, m).set_threads(threads);
                self.registry.operator(handle, ValueFormat::Fp64, 0, m).set_threads(threads);
            }
            FormatChoice::Ir { k } => {
                // the sainv factors are NOT prefetched here — their
                // build is fallible and the dispatch a moment later
                // owns the typed error; budgets are bitwise-neutral,
                // so the factors keep their sticky budget
                self.registry.gse(handle, *k, m).threads.set(threads);
            }
            FormatChoice::Auto => {
                unreachable!("Auto resolves before grouping (resolve_auto_formats)")
            }
        }
    }

    /// Solve one group under `threads` intra-group workers (granted by
    /// [`allocate_threads`]), recording the budget and the group's wall
    /// time in the `pool.*` metrics family.
    fn run_group(&self, group: Vec<PendingSolve>, threads: usize) {
        self.metrics.gauge_set("pool.group_threads", threads as u64);
        let timer = crate::util::Timer::start();
        self.run_group_inner(group, threads);
        let s = timer.elapsed_s();
        self.metrics.add("pool.group_ns", (s * 1e9) as u64);
        self.metrics.time("pool.group", s);
    }

    /// Solve one group: singletons dispatch normally; larger groups run
    /// as one multi-RHS block — CG / GMRES / BiCGSTAB over the registry
    /// operator for fixed formats, or a stepped block over one shared
    /// ladder ([`crate::solvers::stepped::run_stepped_multi`]) for the
    /// two stepped modes. Cancelled or already-expired tickets are
    /// triaged out first; the survivors' per-column results are
    /// bit-for-bit what individual dispatch would produce, even when a
    /// sibling column deflates mid-solve — and, by the row-chunking
    /// invariant, regardless of the granted `threads` budget.
    fn run_group_inner(&self, group: Vec<PendingSolve>, threads: usize) {
        // pre-solve triage: answer dead tickets without solver time
        let now = Instant::now();
        let mut live: Vec<PendingSolve> = Vec::with_capacity(group.len());
        for p in group {
            if p.cancel.load(Ordering::Relaxed) {
                self.resolve_dead(p, ColumnExit::Cancelled);
            } else if p.spec.deadline.is_some_and(|d| now >= d) {
                self.resolve_dead(p, ColumnExit::DeadlineExceeded);
            } else {
                live.push(p);
            }
        }
        if live.is_empty() {
            return;
        }
        if live.len() == 1 {
            let p = live.into_iter().next().unwrap();
            self.tune_singleton(&p.spec, threads);
            let req = p.spec.to_request();
            let res =
                dispatch_with_handle(&req, &p.spec.matrix, &self.registry, Some(&self.metrics))
                    .and_then(classify);
            let _ = p.tx.send(res);
            return;
        }
        let (solver, tol, max_iters) =
            (live[0].spec.solver, live[0].spec.tol, live[0].spec.max_iters);
        let handle = live[0].spec.matrix.clone();
        // cloned out so the match below can move `live` (error fan-out)
        let format = live[0].spec.format.clone();
        let precond = live[0].spec.precond.clone();
        let nrhs = live.len();
        let n = handle.matrix().nrows;
        let mut bs = vec![0.0; n * nrhs];
        for (j, p) in live.iter().enumerate() {
            bs[j * n..(j + 1) * n].copy_from_slice(&p.spec.rhs.build(handle.matrix()));
        }
        self.metrics.incr("pool.batched_groups");
        self.metrics.add("pool.batched_rhs", nrhs as u64);
        self.metrics.incr(match &format {
            FormatChoice::Ir { .. } => "pool.batched_ir",
            _ => match solver {
                SolverKind::Cg => "pool.batched_cg",
                SolverKind::Gmres => "pool.batched_gmres",
                SolverKind::Bicgstab => "pool.batched_bicgstab",
            },
        });
        // per-column cancel flags and deadlines, polled between apply
        // rounds so a triggered column deflates out of the block
        let ctl = BlockCtl::new(
            live.iter().map(|p| Some(Arc::clone(&p.cancel))).collect(),
            live.iter().map(|p| p.spec.deadline).collect(),
        );
        // the exact caps single dispatch would hand the solver (shared
        // mapping — see jobs::solver_opts; a Jacobi spec rides into
        // CgOpts::inv_diag exactly as single dispatch computes it)
        let block_solver =
            solver_opts(solver, tol, max_iters, precond_inv_diag(&precond, handle.matrix()));
        let (outs, exits, label): (Vec<SolveOutcome>, Vec<ColumnExit>, String) =
            match &format {
                FormatChoice::Fixed { format, k } => {
                    let op = self.registry.operator(&handle, *format, *k, Some(&self.metrics));
                    op.set_threads(threads);
                    let (outs, exits) = match &block_solver {
                        BlockSolver::Cg(o) => cg_solve_multi_ctl(op.as_ref(), &bs, nrhs, o, &ctl),
                        BlockSolver::Gmres(o) => {
                            gmres_solve_multi_ctl(op.as_ref(), &bs, nrhs, o, &ctl)
                        }
                        BlockSolver::Bicgstab(o) => {
                            bicgstab_solve_multi_ctl(op.as_ref(), &bs, nrhs, o, &ctl)
                        }
                    };
                    (outs, exits, format.label().to_string())
                }
                FormatChoice::Stepped { k, params } => {
                    self.metrics.incr("pool.batched_stepped");
                    let g = self.registry.gse(&handle, *k, Some(&self.metrics));
                    let ladder = SwitchableOp::new(g);
                    ladder.set_threads(threads);
                    let (outs, exits) =
                        run_stepped_multi_ctl(&ladder, &bs, nrhs, *params, &block_solver, &ctl);
                    // feed the policy's online ladder-depth refinement
                    // (completed columns only — deflated traces are
                    // truncated and would miscount early escalations)
                    for (out, exit) in outs.iter().zip(&exits) {
                        if *exit == ColumnExit::Completed {
                            crate::coordinator::policy::record_switches(
                                handle.digest(),
                                solver,
                                out.iters,
                                &out.switches,
                            );
                        }
                    }
                    (outs, exits, "GSE-SEM".to_string())
                }
                FormatChoice::SteppedCopy { params } => {
                    self.metrics.incr("pool.batched_stepped");
                    let lo =
                        self.registry.operator(&handle, ValueFormat::Fp32, 0, Some(&self.metrics));
                    let hi =
                        self.registry.operator(&handle, ValueFormat::Fp64, 0, Some(&self.metrics));
                    let ladder = CopyLadderOp::new(lo, hi);
                    ladder.set_threads(threads);
                    let (outs, exits) =
                        run_stepped_multi_ctl(&ladder, &bs, nrhs, *params, &block_solver, &ctl);
                    (outs, exits, "FP32->FP64".to_string())
                }
                FormatChoice::Ir { k } => {
                    let g = self.registry.gse(&handle, *k, Some(&self.metrics));
                    g.threads.set(threads);
                    // the preconditioner build is the one fallible step:
                    // a SAINV pivot breakdown (or any registry failure)
                    // answers every ticket in the group with the same
                    // typed error — nothing hangs, nothing is poisoned
                    let built = match &precond {
                        Precond::Sainv(p) => self
                            .registry
                            .sainv(&handle, *p, Some(&self.metrics))
                            .map(PrecondOp::Sainv),
                        other => PrecondOp::for_spec(other, handle.matrix()),
                    };
                    let m = match built {
                        Ok(m) => m,
                        Err(e) => {
                            for t in live {
                                let _ = t.tx.send(Err(ServiceError::Registry(e.clone())));
                            }
                            return;
                        }
                    };
                    m.set_threads(threads);
                    let opts = IrGmresOpts::for_caps(tol, max_iters);
                    let (outs, exits) = ir_solve_multi_ctl(&g, &m, &bs, nrhs, &opts, &ctl);
                    (outs, exits, ir_label(&precond).to_string())
                }
                FormatChoice::Auto => {
                    unreachable!("Auto resolves before grouping (resolve_auto_formats)")
                }
            };
        let fp64 = self.registry.operator(&handle, ValueFormat::Fp64, 0, Some(&self.metrics));
        for (j, ((p, outcome), exit)) in live.into_iter().zip(outs).zip(exits).enumerate() {
            if exit != ColumnExit::Completed {
                self.resolve_dead(p, exit);
                continue;
            }
            let b = &bs[j * n..(j + 1) * n];
            let relres_fp64 = crate::solvers::true_relres(fp64.as_ref(), &outcome.x, b);
            let _ = p.tx.send(classify(SolveResult {
                name: p.spec.name,
                solver: p.spec.solver,
                format_label: label.clone(),
                outcome,
                relres_fp64,
            }));
        }
    }
}

/// Long-lived serving front door: a content-addressed
/// [`MatrixRegistry`] (optionally spill-backed), a bounded windowed
/// intake queue, grouping, and a worker queue behind one
/// `submit -> ticket` API with a typed error surface (see module docs).
pub struct SolverService {
    inner: Arc<ServiceInner>,
    flusher: Option<thread::JoinHandle<()>>,
}

impl SolverService {
    /// Serving mode: spawns the background flusher thread that applies
    /// the window / batch-width policy to staggered arrivals.
    pub fn new(cfg: ServiceConfig) -> Self {
        Self::build(cfg, true)
    }

    /// Manual mode: no background thread; batches flush only on
    /// [`SolverService::flush`] (what `SolverPool::run_batch` drives).
    pub fn manual(cfg: ServiceConfig) -> Self {
        Self::build(cfg, false)
    }

    fn build(cfg: ServiceConfig, windowed: bool) -> Self {
        let registry = Arc::new(MatrixRegistry::with_options(
            cfg.cache_bytes.unwrap_or(usize::MAX),
            cfg.spill_dir.clone(),
        ));
        let inner = Arc::new(ServiceInner {
            workers: cfg.workers.max(1),
            window: cfg.window,
            batch_width: cfg.batch_width.max(1),
            op_threads: cfg.op_threads,
            registry,
            metrics: Metrics::new(),
            intake: IntakeQueue::new(cfg.queue_depth),
        });
        let flusher = if windowed {
            let thread_inner = Arc::clone(&inner);
            Some(
                thread::Builder::new()
                    .name("gsem-intake".into())
                    .spawn(move || thread_inner.flusher_loop())
                    .expect("spawn intake flusher"),
            )
        } else {
            None
        };
        Self { inner, flusher }
    }

    /// Register a matrix once; the returned handle addresses it in
    /// [`SolveSpec`]s and shares encodes with every equal-content
    /// registration.
    pub fn register(&self, a: &Arc<Csr>) -> MatrixHandle {
        self.inner.registry.register(a)
    }

    /// Enqueue a request; returns immediately with its ticket, or
    /// sheds it with [`ServiceError::Overloaded`] when the bounded
    /// queue is full (counted in `intake.shed`).
    pub fn submit(&self, spec: SolveSpec) -> Result<SolveTicket, ServiceError> {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let pending =
            PendingSolve { spec, tx, cancel: Arc::clone(&cancel), arrived: Instant::now() };
        match self.inner.intake.push(pending) {
            Ok(()) => {
                self.inner.metrics.incr("intake.submitted");
                self.inner.metrics.gauge_set("intake.depth", self.inner.intake.len() as u64);
                Ok(SolveTicket::new(rx, cancel))
            }
            Err(depth) => {
                self.inner.metrics.incr("intake.shed");
                Err(ServiceError::Overloaded { depth })
            }
        }
    }

    /// Convenience: register the request's matrix and submit.
    pub fn submit_request(&self, req: SolveRequest) -> Result<SolveTicket, ServiceError> {
        let matrix = self.inner.registry.register(&req.a);
        self.submit(SolveSpec {
            name: req.name,
            matrix,
            rhs: req.rhs,
            solver: req.solver,
            format: req.format,
            precond: req.precond,
            tol: req.tol,
            max_iters: req.max_iters,
            deadline: None,
            priority: 0,
        })
    }

    /// Flush everything pending right now, in the calling thread.
    /// Returns how many requests were flushed.
    pub fn flush(&self) -> usize {
        let batch = self.inner.intake.take();
        let n = batch.len();
        self.inner.run_flush(batch);
        n
    }

    /// Requests currently waiting for a flush.
    pub fn pending(&self) -> usize {
        self.inner.intake.len()
    }

    /// Service-lifetime counters: intake flushes/merges/sheds, cache
    /// hits/misses/evictions/spills/restores/bytes, multi-RHS groups
    /// formed.
    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    /// The service's content-addressed operator registry.
    pub fn registry(&self) -> &MatrixRegistry {
        &self.inner.registry
    }
}

impl Drop for SolverService {
    fn drop(&mut self) {
        self.inner.intake.shutdown();
        match self.flusher.take() {
            // the flusher drains whatever is still pending, then exits
            Some(handle) => {
                let _ = handle.join();
            }
            // manual mode: answer any never-flushed stragglers so
            // their tickets resolve instead of hanging
            None => {
                let batch = self.inner.intake.take();
                self.inner.run_flush(batch);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;

    fn cg_spec(svc: &SolverService, a: &Arc<Csr>, name: &str, seed: u64) -> SolveSpec {
        let fmt = FormatChoice::fixed(ValueFormat::Fp64);
        SolveSpec::new(name, svc.register(a), SolverKind::Cg, fmt).rhs(RhsSpec::Random(seed))
    }

    #[test]
    fn manual_service_answers_every_ticket() {
        let svc = SolverService::manual(ServiceConfig::new().workers(2));
        let a = Arc::new(poisson2d(8, 8));
        let tickets: Vec<SolveTicket> =
            (0..5).map(|i| svc.submit(cg_spec(&svc, &a, &format!("t{i}"), i)).unwrap()).collect();
        assert_eq!(svc.pending(), 5);
        assert_eq!(svc.flush(), 5);
        assert_eq!(svc.pending(), 0);
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.name, format!("t{i}"));
            assert!(r.outcome.converged);
        }
        // all five rode one digest-keyed multi-RHS group
        assert_eq!(svc.metrics().counter("intake.flushes"), 1);
        assert_eq!(svc.metrics().counter("intake.merged"), 5);
        assert_eq!(svc.metrics().counter("pool.batched_rhs"), 5);
    }

    #[test]
    fn windowed_service_flushes_on_batch_width() {
        // width 4 with a long window: the 4th submit triggers the flush
        let svc = SolverService::new(
            ServiceConfig::new().workers(2).window(Duration::from_secs(30)).batch_width(4),
        );
        let a = Arc::new(poisson2d(8, 8));
        let tickets: Vec<SolveTicket> =
            (0..4).map(|i| svc.submit(cg_spec(&svc, &a, &format!("w{i}"), i)).unwrap()).collect();
        for t in tickets {
            assert!(t.wait().unwrap().outcome.converged);
        }
        assert_eq!(svc.metrics().counter("intake.submitted"), 4);
        assert!(svc.metrics().counter("intake.flushes") >= 1);
        // every request merged with at least one other
        assert_eq!(svc.metrics().counter("intake.merged"), 4);
        assert_eq!(svc.metrics().counter("pool.batched_rhs"), 4);
    }

    #[test]
    fn windowed_service_flushes_on_window_expiry() {
        let svc = SolverService::new(
            ServiceConfig::new().workers(1).window(Duration::from_millis(10)).batch_width(64),
        );
        let a = Arc::new(poisson2d(6, 6));
        let t = svc.submit(cg_spec(&svc, &a, "lone", 3)).unwrap();
        // width is far away: only the window can release this one
        let r = t.wait().unwrap();
        assert!(r.outcome.converged);
        assert_eq!(svc.metrics().counter("intake.flushes"), 1);
        assert_eq!(svc.metrics().counter("intake.merged"), 0);
    }

    #[test]
    fn ir_sainv_requests_merge_and_build_factors_once() {
        use crate::solvers::SainvParams;
        let svc = SolverService::manual(ServiceConfig::new().workers(2));
        let a = Arc::new(poisson2d(9, 9));
        let params = SainvParams { drop_tol: 0.05, k: 8 };
        let tickets: Vec<SolveTicket> = (0..3)
            .map(|i| {
                let spec =
                    SolveSpec::new(&format!("ir{i}"), svc.register(&a), SolverKind::Gmres,
                        FormatChoice::Ir { k: 8 })
                    .precond(Precond::Sainv(params))
                    .rhs(RhsSpec::Random(i))
                    .tol(1e-10);
                svc.submit(spec).unwrap()
            })
            .collect();
        svc.flush();
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.outcome.converged);
            assert_eq!(r.format_label, "GSE-IR(sainv)");
            assert!(r.relres_fp64 < 1e-8, "relres={}", r.relres_fp64);
        }
        assert_eq!(svc.metrics().counter("pool.batched_groups"), 1);
        assert_eq!(svc.metrics().counter("pool.batched_ir"), 1);
        assert_eq!(svc.metrics().counter("precond.builds"), 1, "one build serves the block");
    }

    #[test]
    fn precond_is_a_batching_axis() {
        // same matrix/solver/format/caps but different preconditioners:
        // the two requests must NOT merge (their iterates differ)
        let svc = SolverService::manual(ServiceConfig::new().workers(2));
        let a = Arc::new(poisson2d(8, 8));
        let spec = |name: &str, p: Precond| {
            SolveSpec::new(name, svc.register(&a), SolverKind::Gmres, FormatChoice::Ir { k: 8 })
                .precond(p)
                .rhs(RhsSpec::Random(1))
        };
        let t0 = svc.submit(spec("plain", Precond::None)).unwrap();
        let t1 = svc.submit(spec("jacobi", Precond::Jacobi)).unwrap();
        svc.flush();
        let r0 = t0.wait().unwrap();
        let r1 = t1.wait().unwrap();
        assert_eq!(r0.format_label, "GSE-IR");
        assert_eq!(r1.format_label, "GSE-IR(jacobi)");
        assert_eq!(svc.metrics().counter("intake.merged"), 0);
        assert_eq!(svc.metrics().counter("pool.batched_groups"), 0);
    }

    #[test]
    fn distinct_content_does_not_group() {
        let svc = SolverService::manual(ServiceConfig::new().workers(2));
        let a = Arc::new(poisson2d(8, 8));
        let b = Arc::new(poisson2d(9, 9));
        let ta = svc.submit(cg_spec(&svc, &a, "a", 1)).unwrap();
        let tb = svc.submit(cg_spec(&svc, &b, "b", 2)).unwrap();
        svc.flush();
        assert!(ta.wait().unwrap().outcome.converged);
        assert!(tb.wait().unwrap().outcome.converged);
        assert_eq!(svc.metrics().counter("intake.merged"), 0);
        assert_eq!(svc.metrics().counter("pool.batched_groups"), 0);
    }

    #[test]
    fn try_wait_tracks_pending_answered_and_redeemed() {
        let svc = SolverService::manual(ServiceConfig::new().workers(1));
        let a = Arc::new(poisson2d(6, 6));
        let mut ticket = svc.submit(cg_spec(&svc, &a, "poll", 4)).unwrap();
        // pending: not answered yet
        assert!(ticket.try_wait().is_none());
        svc.flush();
        let res = ticket.try_wait().expect("flushed result is available").unwrap();
        assert!(res.outcome.converged);
        // the one-shot result was redeemed: further polls are None, not
        // an error, even though the sender side is long gone
        assert!(ticket.try_wait().is_none());
        assert!(ticket.try_wait().is_none());
    }

    #[test]
    fn dropping_service_resolves_unflushed_tickets() {
        let a = Arc::new(poisson2d(6, 6));
        let ticket = {
            let svc = SolverService::manual(ServiceConfig::new().workers(1));
            svc.submit(cg_spec(&svc, &a, "straggler", 7)).unwrap()
            // dropped with the request still pending
        };
        assert!(ticket.wait().unwrap().outcome.converged);
    }

    #[test]
    fn bounded_intake_sheds_with_typed_overload() {
        let svc = SolverService::manual(ServiceConfig::new().workers(1).queue_depth(2));
        let a = Arc::new(poisson2d(6, 6));
        let t1 = svc.submit(cg_spec(&svc, &a, "a", 1)).unwrap();
        let t2 = svc.submit(cg_spec(&svc, &a, "b", 2)).unwrap();
        match svc.submit(cg_spec(&svc, &a, "c", 3)) {
            Err(ServiceError::Overloaded { depth }) => assert_eq!(depth, 2),
            other => panic!("expected Overloaded, got {:?}", other.map(|_| "ticket")),
        }
        assert_eq!(svc.metrics().counter("intake.shed"), 1);
        assert_eq!(svc.metrics().counter("intake.submitted"), 2);
        // admitted work is unaffected by the shed
        svc.flush();
        assert!(t1.wait().unwrap().outcome.converged);
        assert!(t2.wait().unwrap().outcome.converged);
        // the freed queue admits again
        let t4 = svc.submit(cg_spec(&svc, &a, "d", 4)).unwrap();
        svc.flush();
        assert!(t4.wait().unwrap().outcome.converged);
    }

    #[test]
    fn cancelled_before_flush_resolves_with_typed_error() {
        let svc = SolverService::manual(ServiceConfig::new().workers(1));
        let a = Arc::new(poisson2d(6, 6));
        let t = svc.submit(cg_spec(&svc, &a, "gone", 1)).unwrap();
        t.cancel();
        svc.flush();
        match t.wait() {
            Err(ServiceError::Cancelled { name }) => assert_eq!(name, "gone"),
            other => panic!("expected Cancelled, got {other:?}"),
        }
        assert_eq!(svc.metrics().counter("intake.cancelled"), 1);
    }

    #[test]
    fn expired_deadline_resolves_with_typed_error() {
        let svc = SolverService::manual(ServiceConfig::new().workers(1));
        let a = Arc::new(poisson2d(6, 6));
        let spec = cg_spec(&svc, &a, "late", 1).deadline_at(Instant::now());
        let t = svc.submit(spec).unwrap();
        svc.flush();
        match t.wait() {
            Err(ServiceError::DeadlineExceeded { name }) => assert_eq!(name, "late"),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(svc.metrics().counter("intake.deadline_expired"), 1);
    }

    #[test]
    fn allocator_splits_cores_by_group_weight() {
        let svc = SolverService::manual(ServiceConfig::new().workers(4));
        let big = Arc::new(poisson2d(64, 64)); // 4096 rows >> par_min_rows
        let tiny = Arc::new(poisson2d(6, 6)); // serial-gated at any nrhs here
        let group = |a: &Arc<Csr>, n: usize| -> Vec<PendingSolve> {
            (0..n)
                .map(|i| {
                    let (tx, _rx) = mpsc::channel();
                    PendingSolve {
                        spec: cg_spec(&svc, a, &format!("g{i}"), i as u64),
                        tx,
                        cancel: Arc::new(AtomicBool::new(false)),
                        arrived: Instant::now(),
                    }
                })
                .collect()
        };

        // a lone group gets the full budget
        let lone = vec![group(&big, 8)];
        assert_eq!(allocate_threads(4, 0, &lone), [4]);

        // groups below the serial gate stay at one core
        let small = vec![group(&tiny, 1), group(&tiny, 2)];
        assert_eq!(allocate_threads(4, 0, &small), [1, 1]);

        // proportional split: 8-wide vs 2-wide on the same matrix is a
        // 4:1 weight ratio, leftovers land on the heavier group
        let mixed = vec![group(&big, 8), group(&big, 2)];
        assert_eq!(allocate_threads(5, 0, &mixed), [4, 1]);

        // serial groups don't dilute the heavy group's share
        let skewed = vec![group(&big, 8), group(&tiny, 1)];
        assert_eq!(allocate_threads(4, 0, &skewed), [4, 1]);

        // a nonzero op_threads override pins every group
        assert_eq!(allocate_threads(4, 3, &mixed), [3, 3]);
    }

    #[test]
    fn op_threads_override_flows_to_group_runs() {
        let svc = SolverService::manual(ServiceConfig::new().workers(2).op_threads(2));
        let a = Arc::new(poisson2d(8, 8));
        let tickets: Vec<SolveTicket> =
            (0..3).map(|i| svc.submit(cg_spec(&svc, &a, &format!("o{i}"), i)).unwrap()).collect();
        svc.flush();
        for t in tickets {
            assert!(t.wait().unwrap().outcome.converged);
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.gauges.get("pool.group_threads"), Some(&2));
        assert!(snap.counters.get("pool.group_ns").is_some_and(|&ns| ns > 0));
    }

    #[test]
    fn groups_order_by_priority_then_age() {
        let svc = SolverService::manual(ServiceConfig::new().workers(1));
        let a = Arc::new(poisson2d(6, 6));
        let t0 = Instant::now();
        let pend = |name: &str, pri: i32, arrived: Instant| {
            let (tx, _rx) = mpsc::channel();
            PendingSolve {
                spec: cg_spec(&svc, &a, name, 0).priority(pri),
                tx,
                cancel: Arc::new(AtomicBool::new(false)),
                arrived,
            }
        };
        let mut groups = vec![
            vec![pend("old-low", 0, t0)],
            vec![pend("new-high", 5, t0 + Duration::from_millis(2))],
            vec![pend("mid-low", 0, t0 + Duration::from_millis(1))],
        ];
        order_groups(&mut groups);
        let names: Vec<&str> = groups.iter().map(|g| g[0].spec.name.as_str()).collect();
        assert_eq!(names, ["new-high", "old-low", "mid-low"]);
    }
}
