//! Hand-rolled CLI argument parsing (offline substitute for `clap`,
//! DESIGN.md §5): `--key value` / `--key=value` / `--flag` options after
//! a positional subcommand.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from an args iterator (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli, String> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare '--' not supported".to_string());
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    cli.options.insert(stripped.to_string(), v);
                } else {
                    cli.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if cli.command.is_none() {
                cli.command = Some(arg);
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let c = parse("solve --matrix poisson --k 8 --tol=1e-8 --verbose");
        assert_eq!(c.command.as_deref(), Some("solve"));
        assert_eq!(c.get("matrix"), Some("poisson"));
        assert_eq!(c.get_usize("k", 4).unwrap(), 8);
        assert_eq!(c.get_f64("tol", 1e-6).unwrap(), 1e-8);
        assert!(c.flag("verbose"));
        assert!(!c.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let c = parse("spmv");
        assert_eq!(c.get_usize("k", 8).unwrap(), 8);
        assert_eq!(c.get_or("format", "gse"), "gse");
    }

    #[test]
    fn positional_args() {
        let c = parse("analyze a.mtx b.mtx --top 4");
        assert_eq!(c.positional, vec!["a.mtx", "b.mtx"]);
        assert_eq!(c.get_usize("top", 0).unwrap(), 4);
    }

    #[test]
    fn bad_numbers_error() {
        let c = parse("x --k eight");
        assert!(c.get_usize("k", 1).is_err());
        assert!(c.get_u64("k", 1).is_err());
    }

    #[test]
    fn u64_options() {
        let c = parse("serve --window-ms 5 --stagger-us=250");
        assert_eq!(c.get_u64("window-ms", 0).unwrap(), 5);
        assert_eq!(c.get_u64("stagger-us", 0).unwrap(), 250);
        assert_eq!(c.get_u64("missing", 9).unwrap(), 9);
    }
}
