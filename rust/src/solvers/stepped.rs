//! The stepped mixed-precision controller (§III-D, Algorithm 3).
//!
//! The solver starts with the head-only SpMV (tag 1), monitors the
//! residual history, and escalates to head+tail1 (tag 2) then full
//! (tag 3) when progress stalls. Every `m` iterations — after an initial
//! window of `l` low-precision iterations — three metrics over the last
//! `t` residuals decide:
//!
//! * `RSD`   — relative standard deviation (Eq. 3)
//! * `nDec`  — number of decreases (Eqs. 4–5)
//! * `relDec`— relative decrease over the window (Eq. 6)
//!
//! **Condition 1**: `RSD > RSD_limit && nDec < nDec_limit` — residuals
//!   fluctuate without progress.
//! **Condition 2**: `nDec ≥ nDec_limit && relDec < relDec_limit` —
//!   steady but slow decrease.
//! **Condition 3**: `nDec == 0` — no decrease at all.
//!
//! Any of the three triggers one escalation step. The controller is
//! agnostic to *what* escalates: any [`PrecisionSwitchable`] ladder
//! (see [`crate::solvers::ladder`]) plugs into [`run_stepped_with`] —
//! the paper's zero-copy GSE tag ladder and the copy-based fp32→fp64
//! baseline both run under the identical switching policy.

use crate::formats::Precision;
use crate::solvers::ladder::PrecisionSwitchable;
use crate::spmv::gse::GseCsr;
use crate::util::stats;
use std::sync::Arc;

// Historical home of the GSE tag ladder — re-exported so existing
// `stepped::SwitchableOp` paths keep working after the extraction into
// the format-agnostic [`crate::solvers::ladder`] module.
pub use crate::solvers::ladder::SwitchableOp;

/// Controller parameters (paper §IV-D1 values via [`SteppedParams::gmres_paper`]
/// / [`SteppedParams::cg_paper`]; [`SteppedParams::scaled`] shrinks the
/// schedule proportionally for the scaled-down test sets).
#[derive(Clone, Copy, Debug)]
pub struct SteppedParams {
    /// initial low-precision iterations before any check
    pub l: usize,
    /// residual-history window length
    pub t: usize,
    /// check period after the first `l` iterations
    pub m: usize,
    pub rsd_limit: f64,
    /// threshold on nDec (the paper's conditions use t/2; its §IV-D1
    /// calibration sets an explicit value — both are supported)
    pub ndec_limit: usize,
    pub reldec_limit: f64,
    /// safety valve beyond the paper's three conditions: escalate
    /// immediately when the residual exceeds `divergence_factor ×` the
    /// best residual seen — catches the indefinite-head case (zeroed
    /// diagonals) where CG blows up long before a window fills.
    pub divergence_factor: f64,
}

impl SteppedParams {
    /// Paper values for GMRES: l=9000, t=300, m=1500,
    /// RSD_limit=0.03, nDec_limit=80, relDec_limit=0.08.
    pub fn gmres_paper() -> Self {
        Self {
            l: 9000,
            t: 300,
            m: 1500,
            rsd_limit: 0.03,
            ndec_limit: 80,
            reldec_limit: 0.08,
            divergence_factor: 100.0,
        }
    }

    /// Paper values for CG: l=3000, t=250, m=500,
    /// RSD_limit=0.50, nDec_limit=130, relDec_limit=0.45.
    pub fn cg_paper() -> Self {
        Self {
            l: 3000,
            t: 250,
            m: 500,
            rsd_limit: 0.50,
            ndec_limit: 130,
            reldec_limit: 0.45,
            divergence_factor: 100.0,
        }
    }

    /// Shrink the iteration schedule by `factor` (thresholds unchanged,
    /// window floors keep the statistics meaningful). Used because the
    /// scaled-down matrices converge in far fewer iterations than the
    /// paper's 5000/15000 budgets.
    pub fn scaled(self, factor: f64) -> Self {
        let sc = |v: usize, lo: usize| (((v as f64) * factor).round() as usize).max(lo);
        Self {
            l: sc(self.l, 10),
            t: sc(self.t, 8),
            m: sc(self.m, 5),
            ndec_limit: sc(self.ndec_limit, 2),
            ..self
        }
    }
}

/// Which of the paper's three conditions fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchReason {
    Fluctuating,  // Condition 1
    SlowDecrease, // Condition 2
    NoDecrease,   // Condition 3
    /// Safety valve (ours): residual exploded past divergence_factor ×
    /// the best seen — the low-precision operator is unusable (e.g.
    /// indefinite because small diagonals truncated to zero).
    Diverged,
}

/// Metrics of Eqs. 3–6 over a residual window.
#[derive(Clone, Copy, Debug)]
pub struct WindowMetrics {
    pub rsd: f64,
    pub ndec: usize,
    pub reldec: f64,
}

/// Compute RSD / nDec / relDec over the last `t` residuals
/// (`window.len() == t`, oldest first).
pub fn window_metrics(window: &[f64]) -> WindowMetrics {
    let rsd = stats::rsd(window);
    let mut ndec = 0usize;
    for w in window.windows(2) {
        if w[0] > w[1] {
            ndec += 1;
        }
    }
    let first = window.first().copied().unwrap_or(0.0);
    let last = window.last().copied().unwrap_or(0.0);
    let reldec = if first != 0.0 { (first - last) / first } else { 0.0 };
    WindowMetrics { rsd, ndec, reldec }
}

/// The residual-monitoring precision controller. Ladder-agnostic: it
/// tracks a 1-based rung `tag` up to a configured depth, and the caller
/// (see [`run_stepped_with`]) mirrors escalations onto whatever
/// [`PrecisionSwitchable`] operator is in play.
#[derive(Clone, Debug)]
pub struct PrecisionController {
    pub params: SteppedParams,
    /// current 1-based rung (Alg. 3's `tag`)
    pub tag: u8,
    /// ladder depth — no checks once `tag` reaches it
    max_tag: u8,
    window: Vec<f64>,
    last_check: usize,
    best_resid: f64,
    /// (iteration, new tag) escalation log
    pub switches: Vec<(usize, u8)>,
    /// reasons matching `switches`
    pub reasons: Vec<SwitchReason>,
}

impl PrecisionController {
    /// Controller for the paper's three-rung GSE ladder.
    pub fn new(params: SteppedParams) -> Self {
        Self::with_ladder_depth(params, Precision::LADDER.len() as u8)
    }

    /// Controller for a ladder with `max_tag` rungs (tags `1..=max_tag`,
    /// e.g. 2 for the copy-based fp32→fp64 ladder).
    pub fn with_ladder_depth(params: SteppedParams, max_tag: u8) -> Self {
        Self {
            params,
            tag: 1,
            max_tag: max_tag.max(1),
            window: Vec::with_capacity(params.t),
            last_check: 0,
            best_resid: f64::INFINITY,
            switches: Vec::new(),
            reasons: Vec::new(),
        }
    }

    /// Evaluate conditions 1–3 on a full window.
    pub fn check_conditions(&self, m: &WindowMetrics) -> Option<SwitchReason> {
        let p = &self.params;
        if m.ndec == 0 {
            return Some(SwitchReason::NoDecrease); // Condition 3
        }
        if m.rsd > p.rsd_limit && m.ndec < p.ndec_limit {
            return Some(SwitchReason::Fluctuating); // Condition 1
        }
        if m.ndec >= p.ndec_limit && m.reldec < p.reldec_limit {
            return Some(SwitchReason::SlowDecrease); // Condition 2
        }
        None
    }

    /// Feed one residual observation; returns the new rung tag if the
    /// controller escalated at this iteration.
    pub fn observe(&mut self, iter: usize, resid: f64) -> Option<u8> {
        if self.window.len() == self.params.t {
            self.window.remove(0);
        }
        self.window.push(resid);
        if self.tag >= self.max_tag {
            return None;
        }
        // divergence safety valve fires regardless of the l/m schedule
        if resid.is_finite()
            && self.best_resid.is_finite()
            && resid > self.params.divergence_factor * self.best_resid
        {
            self.best_resid = self.best_resid.min(resid);
            self.tag += 1;
            self.switches.push((iter, self.tag));
            self.reasons.push(SwitchReason::Diverged);
            self.window.clear();
            self.last_check = iter;
            return Some(self.tag);
        }
        self.best_resid = self.best_resid.min(resid);
        if iter < self.params.l.max(self.params.t) {
            return None;
        }
        if iter - self.last_check < self.params.m {
            return None;
        }
        if self.window.len() < self.params.t {
            return None;
        }
        self.last_check = iter;
        let metrics = window_metrics(&self.window);
        if let Some(reason) = self.check_conditions(&metrics) {
            self.tag += 1;
            self.switches.push((iter, self.tag));
            self.reasons.push(reason);
            // restart the window so the next decision sees post-switch data
            self.window.clear();
            return Some(self.tag);
        }
        None
    }
}

/// Run a solver with the stepped controller attached to **any**
/// precision ladder (Algorithm 3's outer wiring, generalized): the
/// `solve` closure receives the ladder operator and the monitor callback
/// to install; every escalation the controller decides is mirrored onto
/// `op` via [`PrecisionSwitchable::set_tag`] and answered with
/// [`crate::solvers::MonitorCmd::Restart`] (the Krylov recurrence was
/// built with the old operator). Returns the outcome, the switch
/// reasons, and the sequence of tags seen.
pub fn run_stepped_with<L, F>(
    op: &L,
    params: SteppedParams,
    solve: F,
) -> (crate::solvers::SolveOutcome, Vec<SwitchReason>, Vec<u8>)
where
    L: PrecisionSwitchable,
    F: FnOnce(
        &L,
        &mut dyn FnMut(usize, f64) -> crate::solvers::MonitorCmd,
    ) -> crate::solvers::SolveOutcome,
{
    let mut ctrl = PrecisionController::with_ladder_depth(params, op.num_tags());
    ctrl.tag = op.tag().max(1);
    let mut tags_seen = vec![ctrl.tag];
    let mut out = {
        let ctrlref = &mut ctrl;
        let tags = &mut tags_seen;
        let mut monitor = move |iter: usize, resid: f64| {
            if let Some(new_tag) = ctrlref.observe(iter, resid) {
                op.set_tag(new_tag);
                tags.push(new_tag);
                crate::solvers::MonitorCmd::Restart
            } else {
                crate::solvers::MonitorCmd::Continue
            }
        };
        solve(op, &mut monitor)
    };
    out.switches = ctrl.switches.clone();
    (out, ctrl.reasons, tags_seen)
}

/// Which solver drives a stepped block solve — the monitored sibling
/// of the fixed-format `*_solve_multi` entry points, carrying the
/// per-solver caps exactly as single dispatch would pass them.
#[derive(Clone, Debug)]
pub enum BlockSolver {
    Cg(crate::solvers::CgOpts),
    Gmres(crate::solvers::GmresOpts),
    Bicgstab(crate::solvers::bicgstab::BicgstabOpts),
}

/// Stepped multi-RHS mode: solve `nrhs` column-major packed right-hand
/// sides over **one shared** [`PrecisionSwitchable`] ladder, giving
/// every column its own [`PrecisionController`] (same
/// RSD / nDec / relDec policy as [`run_stepped_with`] installs around
/// a single solve). Each round trip performs one fused
/// [`crate::spmv::SpmvOp::apply_multi`] per precision rung still in
/// play — the block applies at the coarsest rung first, and columns
/// whose controller demanded a finer rung peel off into their own
/// residual sub-block. Per-column outcomes (iterates, histories,
/// switch logs, residuals) are bitwise identical to dispatching each
/// RHS through [`run_stepped_with`] with a fresh ladder.
pub fn run_stepped_multi<L: PrecisionSwitchable>(
    op: &L,
    bs: &[f64],
    nrhs: usize,
    params: SteppedParams,
    solver: &BlockSolver,
) -> Vec<crate::solvers::SolveOutcome> {
    let ctl = crate::solvers::block::BlockCtl::none(nrhs);
    run_stepped_multi_ctl(op, bs, nrhs, params, solver, &ctl).0
}

/// [`run_stepped_multi`] with per-column cancel/deadline controls:
/// triggered columns deflate mid-block (partial outcome, matching exit
/// reason) while survivors stay bitwise identical to single dispatch.
pub(crate) fn run_stepped_multi_ctl<L: PrecisionSwitchable>(
    op: &L,
    bs: &[f64],
    nrhs: usize,
    params: SteppedParams,
    solver: &BlockSolver,
    ctl: &crate::solvers::block::BlockCtl,
) -> (Vec<crate::solvers::SolveOutcome>, Vec<crate::solvers::block::ColumnExit>) {
    use crate::solvers::bicgstab::BicgstabColumn;
    use crate::solvers::block::{run_tagged_block_ctl, ColumnMonitor};
    use crate::solvers::cg::CgColumn;
    use crate::solvers::gmres::GmresColumn;

    let n = op.nrows();
    assert_eq!(op.ncols(), n, "stepped multi-RHS requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    if nrhs == 0 {
        return (Vec::new(), Vec::new());
    }
    // every column starts on the coarsest rung, as a fresh per-request
    // ladder would
    op.set_tag(1);
    let depth = op.num_tags();
    let ctrl = || ColumnMonitor::Stepped(PrecisionController::with_ladder_depth(params, depth));
    match solver {
        BlockSolver::Cg(o) => {
            let cols: Vec<CgColumn> =
                (0..nrhs).map(|j| CgColumn::new(&bs[j * n..(j + 1) * n], o, ctrl())).collect();
            run_tagged_block_ctl(op, cols, ctl)
        }
        BlockSolver::Gmres(o) => {
            let cols: Vec<GmresColumn> = (0..nrhs)
                .map(|j| GmresColumn::new(&bs[j * n..(j + 1) * n], o, ctrl()))
                .collect();
            run_tagged_block_ctl(op, cols, ctl)
        }
        BlockSolver::Bicgstab(o) => {
            let cols: Vec<BicgstabColumn> = (0..nrhs)
                .map(|j| BicgstabColumn::new(&bs[j * n..(j + 1) * n], o, ctrl()))
                .collect();
            run_tagged_block_ctl(op, cols, ctl)
        }
    }
}

/// The historical GSE-SEM entry point: wrap `m` in a [`SwitchableOp`]
/// and run [`run_stepped_with`], reporting the levels as [`Precision`]
/// values. Shared by the CG and GMRES stepped paths.
pub fn run_stepped<F>(
    m: impl Into<Arc<GseCsr>>,
    params: SteppedParams,
    solve: F,
) -> (crate::solvers::SolveOutcome, Vec<SwitchReason>, Vec<Precision>)
where
    F: FnOnce(
        &SwitchableOp,
        &mut dyn FnMut(usize, f64) -> crate::solvers::MonitorCmd,
    ) -> crate::solvers::SolveOutcome,
{
    let op = SwitchableOp::new(m);
    let (out, reasons, tags) = run_stepped_with(&op, params, solve);
    let levels = tags.into_iter().map(Precision::from_tag).collect();
    (out, reasons, levels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_match_paper_equations() {
        // strictly decreasing window: nDec = t-1, relDec = (r0-rN)/r0
        let w: Vec<f64> = (0..10).map(|i| 10.0 - i as f64).collect();
        let m = window_metrics(&w);
        assert_eq!(m.ndec, 9);
        assert!((m.reldec - 0.9).abs() < 1e-12);
        // constant window: nDec = 0, RSD = 0
        let m = window_metrics(&[5.0; 10]);
        assert_eq!(m.ndec, 0);
        assert_eq!(m.rsd, 0.0);
        assert_eq!(m.reldec, 0.0);
    }

    #[test]
    fn condition3_fires_on_stagnation() {
        let p = SteppedParams {
            l: 5,
            t: 4,
            m: 2,
            rsd_limit: 0.5,
            ndec_limit: 2,
            reldec_limit: 0.1,
            divergence_factor: 100.0,
        };
        let mut c = PrecisionController::new(p);
        let mut switched_at = None;
        for i in 1..50 {
            if let Some(lvl) = c.observe(i, 1.0) {
                switched_at = Some((i, lvl));
                break;
            }
        }
        let (i, tag) = switched_at.expect("must escalate on constant residuals");
        assert_eq!(tag, 2);
        assert!(i >= 5);
        assert_eq!(c.reasons[0], SwitchReason::NoDecrease);
    }

    #[test]
    fn no_switch_while_converging_fast() {
        let p = SteppedParams {
            l: 5,
            t: 4,
            m: 2,
            rsd_limit: 10.0,
            ndec_limit: 2,
            reldec_limit: 0.01,
            divergence_factor: 100.0,
        };
        let mut c = PrecisionController::new(p);
        for i in 1..100 {
            // residual halves every iteration: healthy convergence
            assert!(c.observe(i, 2f64.powi(-(i as i32))).is_none(), "switched at {i}");
        }
        assert_eq!(c.tag, 1);
    }

    #[test]
    fn escalates_through_full_ladder_and_stops() {
        let p = SteppedParams {
            l: 2,
            t: 3,
            m: 1,
            rsd_limit: 0.5,
            ndec_limit: 2,
            reldec_limit: 0.1,
            divergence_factor: 100.0,
        };
        let mut c = PrecisionController::new(p);
        let mut seen = Vec::new();
        for i in 1..200 {
            if let Some(tag) = c.observe(i, 1.0) {
                seen.push(tag);
            }
        }
        assert_eq!(seen, vec![2, 3]);
        assert_eq!(c.switches.len(), 2);
        assert_eq!(c.switches[0].1, 2);
        assert_eq!(c.switches[1].1, 3);
    }

    #[test]
    fn ladder_depth_caps_escalation() {
        // two-rung ladder (the copy fp32→fp64 baseline): one escalation
        let p = SteppedParams {
            l: 2,
            t: 3,
            m: 1,
            rsd_limit: 0.5,
            ndec_limit: 2,
            reldec_limit: 0.1,
            divergence_factor: 100.0,
        };
        let mut c = PrecisionController::with_ladder_depth(p, 2);
        let mut seen = Vec::new();
        for i in 1..200 {
            if let Some(tag) = c.observe(i, 1.0) {
                seen.push(tag);
            }
        }
        assert_eq!(seen, vec![2]);
        assert_eq!(c.tag, 2);
    }

    #[test]
    fn respects_initial_l_window() {
        let p = SteppedParams {
            l: 50,
            t: 4,
            m: 1,
            rsd_limit: 0.5,
            ndec_limit: 2,
            reldec_limit: 0.1,
            divergence_factor: 100.0,
        };
        let mut c = PrecisionController::new(p);
        for i in 1..50 {
            assert!(c.observe(i, 1.0).is_none());
        }
    }

    #[test]
    fn condition1_fluctuation() {
        let p = SteppedParams {
            l: 4,
            t: 8,
            m: 1,
            rsd_limit: 0.05,
            ndec_limit: 6,
            reldec_limit: 1e-9,
            divergence_factor: 100.0,
        };
        let mut c = PrecisionController::new(p);
        // oscillating residuals: half the steps decrease -> ndec ~ t/2 < 6,
        // rsd large
        let mut fired = None;
        for i in 1..100 {
            let r = if i % 2 == 0 { 1.0 } else { 2.0 };
            if c.observe(i, r).is_some() {
                fired = Some(i);
                break;
            }
        }
        assert!(fired.is_some());
        assert_eq!(c.reasons[0], SwitchReason::Fluctuating);
    }

    #[test]
    fn condition2_slow_decrease() {
        let p = SteppedParams {
            l: 4,
            t: 8,
            m: 1,
            rsd_limit: 1e9, // condition 1 can't fire
            ndec_limit: 4,
            reldec_limit: 0.5, // require 50% decrease per window
            divergence_factor: 100.0,
        };
        let mut c = PrecisionController::new(p);
        let mut fired = None;
        for i in 1..100 {
            // strictly decreasing but only 0.1% per step
            let r = 1.0 * (1.0 - 0.001f64).powi(i as i32);
            if c.observe(i, r).is_some() {
                fired = Some(i);
                break;
            }
        }
        assert!(fired.is_some());
        assert_eq!(c.reasons[0], SwitchReason::SlowDecrease);
    }

    #[test]
    fn scaled_params_preserve_floors() {
        let p = SteppedParams::cg_paper().scaled(0.001);
        assert!(p.l >= 10 && p.t >= 8 && p.m >= 5 && p.ndec_limit >= 2);
        let q = SteppedParams::gmres_paper().scaled(0.1);
        assert_eq!(q.l, 900);
        assert_eq!(q.t, 30);
        assert_eq!(q.m, 150);
    }
}
