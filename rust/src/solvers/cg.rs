//! Conjugate gradients with residual-history instrumentation — the
//! Table IV / Fig. 9 solver. Matches the paper's setup (§IV-A): relative
//! residual threshold 1e-6, max 5000 iterations, vector ops in FP64, the
//! SpMV operator supplies whichever storage precision is under test.

use super::blas1::{axpy, dot, has_nonfinite, nrm2, xpby};
use super::block::{run_fixed_block_ctl, BlockColumn, BlockCtl, ColumnExit, ColumnMonitor};
use super::{MonitorCmd, SolveOutcome};
use crate::spmv::SpmvOp;
use crate::util::Timer;

/// CG options.
#[derive(Clone, Debug)]
pub struct CgOpts {
    /// stop when ‖r‖/‖b‖ ≤ tol
    pub tol: f64,
    pub max_iters: usize,
    /// optional Jacobi preconditioner (inverse diagonal)
    pub inv_diag: Option<Vec<f64>>,
}

impl Default for CgOpts {
    fn default() -> Self {
        Self { tol: 1e-6, max_iters: 5000, inv_diag: None }
    }
}

/// Solve `A x = b` by (preconditioned) CG. `monitor(iter, relres)` is
/// invoked once per iteration — the stepped controller hooks in here and
/// returns [`MonitorCmd::Restart`] after switching the operator's
/// precision, which re-anchors the recurrence (r = b − A x, p = z).
pub fn cg_solve(
    op: &dyn SpmvOp,
    b: &[f64],
    opts: &CgOpts,
    mut monitor: impl FnMut(usize, f64) -> MonitorCmd,
) -> SolveOutcome {
    let n = op.nrows();
    assert_eq!(b.len(), n);
    let timer = Timer::start();
    let bnorm = nrm2(b);
    if bnorm == 0.0 {
        return SolveOutcome {
            converged: true,
            iters: 0,
            relres: 0.0,
            history: vec![],
            switches: vec![],
            seconds: timer.elapsed_s(),
            x: vec![0.0; n],
            broke_down: false,
        };
    }

    let mut x = vec![0.0; n];
    let mut r = b.to_vec(); // r = b - A*0
    let mut z = r.clone();
    let apply_pre = |r: &[f64], z: &mut [f64], opts: &CgOpts| {
        if let Some(d) = &opts.inv_diag {
            for i in 0..r.len() {
                z[i] = r[i] * d[i];
            }
        } else {
            z.copy_from_slice(r);
        }
    };
    apply_pre(&r, &mut z, opts);
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let mut rz = dot(&r, &z);
    let mut history = Vec::with_capacity(opts.max_iters.min(8192));
    let mut broke_down = false;
    let mut converged = false;
    let mut iters = 0;
    // best-iterate checkpoint: restarts (precision switches) and the
    // final answer revert to the lowest-residual x seen, so a divergent
    // low-precision phase cannot poison the solve
    let mut best_x = x.clone();
    let mut best_rel = f64::INFINITY;

    for k in 0..opts.max_iters {
        op.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap == 0.0 || !pap.is_finite() {
            broke_down = !pap.is_finite();
            break;
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rel = nrm2(&r) / bnorm;
        history.push(rel);
        iters = k + 1;
        let cmd = monitor(iters, rel);
        if !rel.is_finite() || has_nonfinite(&x) {
            broke_down = true;
            break;
        }
        if rel < best_rel {
            best_rel = rel;
            best_x.copy_from_slice(&x);
        }
        if rel <= opts.tol {
            converged = true;
            break;
        }
        if cmd == MonitorCmd::Restart {
            // operator changed: resume from the best iterate, recompute
            // the true residual with the new operator, and restart the
            // direction sequence
            x.copy_from_slice(&best_x);
            op.apply(&x, &mut ap);
            for i in 0..n {
                r[i] = b[i] - ap[i];
            }
            apply_pre(&r, &mut z, opts);
            p.copy_from_slice(&z);
            rz = dot(&r, &z);
            continue;
        }
        apply_pre(&r, &mut z, opts);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        xpby(&z, beta, &mut p);
    }

    // a diverged tail must not beat the checkpoint
    if !broke_down && best_rel.is_finite() {
        let final_rel = super::true_relres(op, &x, b);
        if best_rel < final_rel {
            x.copy_from_slice(&best_x);
        }
    }
    let relres = super::true_relres(op, &x, b);
    SolveOutcome {
        converged,
        iters,
        relres,
        history,
        switches: vec![],
        seconds: timer.elapsed_s(),
        x,
        broke_down,
    }
}

/// Solve `A X = B` for `nrhs` right-hand sides packed column-major in
/// `bs` (`bs[j*n..(j+1)*n]` is RHS `j`), running `nrhs` independent CG
/// recurrences in lockstep so each iteration makes exactly **one** pass
/// over the matrix ([`SpmvOp::apply_multi`]) — the multi-RHS batching
/// lever of the coordinator's [`crate::coordinator::SolverPool`].
///
/// Each column follows the identical arithmetic sequence as a standalone
/// [`cg_solve`] on that RHS (bit-for-bit, since every in-tree
/// `apply_multi` is bit-identical to looped single applies), so the
/// per-column outcomes — iterates, iteration counts, residuals — match
/// the unbatched solver. Columns that converge or break down are frozen
/// (their search direction is zeroed) while the rest continue.
/// `seconds` in each outcome is the shared wall time of the block solve.
pub fn cg_solve_multi(
    op: &dyn SpmvOp,
    bs: &[f64],
    nrhs: usize,
    opts: &CgOpts,
) -> Vec<SolveOutcome> {
    let n = op.nrows();
    assert_eq!(op.ncols(), n, "multi-RHS CG requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    if nrhs == 0 {
        return Vec::new();
    }
    let timer = Timer::start();
    let apply_pre = |r: &[f64], z: &mut [f64]| {
        if let Some(d) = &opts.inv_diag {
            for i in 0..r.len() {
                z[i] = r[i] * d[i];
            }
        } else {
            z.copy_from_slice(r);
        }
    };

    // column-major packed per-RHS state: xs[j*n..(j+1)*n] is column j
    let mut xs = vec![0.0; n * nrhs];
    let mut rs = bs.to_vec();
    let mut zs = vec![0.0; n * nrhs];
    let mut ps = vec![0.0; n * nrhs];
    let mut aps = vec![0.0; n * nrhs];
    let mut best_xs = vec![0.0; n * nrhs];
    let mut bnorm = vec![0.0; nrhs];
    let mut rz = vec![0.0; nrhs];
    let mut best_rel = vec![f64::INFINITY; nrhs];
    let mut history: Vec<Vec<f64>> = vec![Vec::new(); nrhs];
    let mut iters = vec![0usize; nrhs];
    let mut converged = vec![false; nrhs];
    let mut broke_down = vec![false; nrhs];
    let mut active = vec![true; nrhs];

    for j in 0..nrhs {
        let c = j * n..(j + 1) * n;
        bnorm[j] = nrm2(&bs[c.clone()]);
        if bnorm[j] == 0.0 {
            converged[j] = true;
            active[j] = false;
            continue;
        }
        apply_pre(&rs[c.clone()], &mut zs[c.clone()]);
        ps[c.clone()].copy_from_slice(&zs[c.clone()]);
        rz[j] = dot(&rs[c.clone()], &zs[c]);
    }

    for k in 0..opts.max_iters {
        if !active.iter().any(|&a| a) {
            break;
        }
        // one pass over the matrix for every still-active column
        op.apply_multi(&ps, &mut aps, nrhs);
        for j in 0..nrhs {
            if !active[j] {
                continue;
            }
            let c = j * n..(j + 1) * n;
            let pap = dot(&ps[c.clone()], &aps[c.clone()]);
            if pap == 0.0 || !pap.is_finite() {
                broke_down[j] = !pap.is_finite();
                active[j] = false;
                ps[c].fill(0.0);
                continue;
            }
            let alpha = rz[j] / pap;
            axpy(alpha, &ps[c.clone()], &mut xs[c.clone()]);
            axpy(-alpha, &aps[c.clone()], &mut rs[c.clone()]);
            let rel = nrm2(&rs[c.clone()]) / bnorm[j];
            history[j].push(rel);
            iters[j] = k + 1;
            if !rel.is_finite() || has_nonfinite(&xs[c.clone()]) {
                broke_down[j] = true;
                active[j] = false;
                ps[c].fill(0.0);
                continue;
            }
            if rel < best_rel[j] {
                best_rel[j] = rel;
                best_xs[c.clone()].copy_from_slice(&xs[c.clone()]);
            }
            if rel <= opts.tol {
                converged[j] = true;
                active[j] = false;
                ps[c].fill(0.0);
                continue;
            }
            apply_pre(&rs[c.clone()], &mut zs[c.clone()]);
            let rz_new = dot(&rs[c.clone()], &zs[c.clone()]);
            let beta = rz_new / rz[j];
            rz[j] = rz_new;
            xpby(&zs[c.clone()], beta, &mut ps[c]);
        }
    }

    let seconds = timer.elapsed_s();
    let mut out = Vec::with_capacity(nrhs);
    for j in 0..nrhs {
        let c = j * n..(j + 1) * n;
        let b = &bs[c.clone()];
        // a diverged tail must not beat the checkpoint (as in cg_solve)
        if !broke_down[j] && best_rel[j].is_finite() {
            let final_rel = super::true_relres(op, &xs[c.clone()], b);
            if best_rel[j] < final_rel {
                xs[c.clone()].copy_from_slice(&best_xs[c.clone()]);
            }
        }
        let x = xs[c].to_vec();
        let relres = super::true_relres(op, &x, b);
        out.push(SolveOutcome {
            converged: converged[j],
            iters: iters[j],
            relres,
            history: std::mem::take(&mut history[j]),
            switches: vec![],
            seconds,
            x,
            broke_down: broke_down[j],
        });
    }
    out
}

/// [`cg_solve_multi`] with per-column cancel/deadline controls: columns
/// whose [`BlockCtl`] entry triggers deflate out of the block with a
/// [`ColumnExit`] recording why (their outcome carries the partial
/// iterate), while every surviving column stays bitwise identical to a
/// standalone [`cg_solve`] — the serving path's cancellation hook.
pub(crate) fn cg_solve_multi_ctl(
    op: &dyn SpmvOp,
    bs: &[f64],
    nrhs: usize,
    opts: &CgOpts,
    ctl: &BlockCtl,
) -> (Vec<SolveOutcome>, Vec<ColumnExit>) {
    let n = op.nrows();
    assert_eq!(op.ncols(), n, "multi-RHS CG requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    let cols: Vec<CgColumn> = (0..nrhs)
        .map(|j| CgColumn::new(&bs[j * n..(j + 1) * n], opts, ColumnMonitor::Fixed))
        .collect();
    run_fixed_block_ctl(op, cols, ctl)
}

/// One CG right-hand side as a [`BlockColumn`] state machine — the
/// monitored sibling of a [`cg_solve_multi`] column, used by the
/// stepped multi-RHS mode ([`crate::solvers::stepped::run_stepped_multi`]).
/// Between applies it runs exactly the arithmetic of [`cg_solve`] with
/// its monitor installed, so the outcome is bitwise identical to a
/// standalone monitored solve on this RHS.
pub(crate) struct CgColumn<'a> {
    b: &'a [f64],
    opts: &'a CgOpts,
    monitor: ColumnMonitor,
    bnorm: f64,
    x: Vec<f64>,
    r: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    best_x: Vec<f64>,
    best_rel: f64,
    rz: f64,
    history: Vec<f64>,
    iters: usize,
    converged: bool,
    broke_down: bool,
    state: CgState,
}

enum CgState {
    /// Next apply: `A · p` (the regular iteration).
    NeedAp,
    /// Next apply: `A · x` (re-anchoring after a precision switch).
    NeedRestart,
    Done,
}

impl<'a> CgColumn<'a> {
    pub(crate) fn new(b: &'a [f64], opts: &'a CgOpts, monitor: ColumnMonitor) -> Self {
        let n = b.len();
        let bnorm = nrm2(b);
        let mut col = Self {
            b,
            opts,
            monitor,
            bnorm,
            x: vec![0.0; n],
            r: b.to_vec(),
            z: vec![0.0; n],
            p: vec![0.0; n],
            best_x: vec![0.0; n],
            best_rel: f64::INFINITY,
            rz: 0.0,
            history: Vec::new(),
            iters: 0,
            converged: false,
            broke_down: false,
            state: CgState::NeedAp,
        };
        if bnorm == 0.0 {
            col.converged = true;
            col.state = CgState::Done;
            return col;
        }
        if opts.max_iters == 0 {
            col.state = CgState::Done;
            return col;
        }
        col.apply_pre();
        col.p.copy_from_slice(&col.z);
        col.rz = dot(&col.r, &col.z);
        col
    }

    /// z ← M⁻¹ r (Jacobi or identity), as in [`cg_solve`].
    fn apply_pre(&mut self) {
        let opts = self.opts;
        if let Some(d) = &opts.inv_diag {
            for i in 0..self.r.len() {
                self.z[i] = self.r[i] * d[i];
            }
        } else {
            self.z.copy_from_slice(&self.r);
        }
    }

    fn absorb_ap(&mut self, ap: &[f64]) {
        let pap = dot(&self.p, ap);
        if pap == 0.0 || !pap.is_finite() {
            self.broke_down = !pap.is_finite();
            self.state = CgState::Done;
            return;
        }
        let alpha = self.rz / pap;
        axpy(alpha, &self.p, &mut self.x);
        axpy(-alpha, ap, &mut self.r);
        let rel = nrm2(&self.r) / self.bnorm;
        self.history.push(rel);
        self.iters += 1;
        let cmd = self.monitor.observe(self.iters, rel);
        if !rel.is_finite() || has_nonfinite(&self.x) {
            self.broke_down = true;
            self.state = CgState::Done;
            return;
        }
        if rel < self.best_rel {
            self.best_rel = rel;
            self.best_x.copy_from_slice(&self.x);
        }
        if rel <= self.opts.tol {
            self.converged = true;
            self.state = CgState::Done;
            return;
        }
        if cmd == MonitorCmd::Restart {
            // operator escalated: resume from the best iterate; the
            // next apply recomputes the true residual at the new rung
            self.x.copy_from_slice(&self.best_x);
            self.state = CgState::NeedRestart;
            return;
        }
        self.apply_pre();
        let rz_new = dot(&self.r, &self.z);
        let beta = rz_new / self.rz;
        self.rz = rz_new;
        xpby(&self.z, beta, &mut self.p);
        if self.iters >= self.opts.max_iters {
            self.state = CgState::Done;
        }
    }

    fn absorb_restart(&mut self, ax: &[f64]) {
        let b = self.b;
        for i in 0..b.len() {
            self.r[i] = b[i] - ax[i];
        }
        self.apply_pre();
        self.p.copy_from_slice(&self.z);
        self.rz = dot(&self.r, &self.z);
        self.state = if self.iters >= self.opts.max_iters {
            CgState::Done
        } else {
            CgState::NeedAp
        };
    }
}

impl BlockColumn for CgColumn<'_> {
    fn active(&self) -> bool {
        !matches!(self.state, CgState::Done)
    }

    fn tag(&self) -> u8 {
        self.monitor.tag()
    }

    fn input(&self) -> &[f64] {
        match self.state {
            CgState::NeedAp => &self.p,
            CgState::NeedRestart => &self.x,
            CgState::Done => unreachable!("inactive column asked for input"),
        }
    }

    fn absorb(&mut self, y: &[f64]) {
        match self.state {
            CgState::NeedAp => self.absorb_ap(y),
            CgState::NeedRestart => self.absorb_restart(y),
            CgState::Done => unreachable!("inactive column fed a result"),
        }
    }

    fn deflate(&mut self) {
        self.state = CgState::Done;
    }

    fn finish(mut self, op: &dyn SpmvOp, seconds: f64) -> SolveOutcome {
        // a diverged tail must not beat the checkpoint (as in cg_solve)
        if !self.broke_down && self.best_rel.is_finite() {
            let final_rel = super::true_relres(op, &self.x, self.b);
            if self.best_rel < final_rel {
                self.x.copy_from_slice(&self.best_x);
            }
        }
        let relres = super::true_relres(op, &self.x, self.b);
        SolveOutcome {
            converged: self.converged,
            iters: self.iters,
            relres,
            history: self.history,
            switches: self.monitor.take_switches(),
            seconds,
            x: self.x,
            broke_down: self.broke_down,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::fem::diffusion2d;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::spmv::fp64::Fp64Csr;
    use crate::util::Prng;

    fn rhs_for_ones(op: &dyn SpmvOp) -> Vec<f64> {
        // b = A * 1  => exact solution is the ones vector
        let ones = vec![1.0; op.ncols()];
        let mut b = vec![0.0; op.nrows()];
        op.apply(&ones, &mut b);
        b
    }

    #[test]
    fn converges_on_poisson() {
        let op = Fp64Csr::new(poisson2d(20, 20));
        let b = rhs_for_ones(&op);
        let out = cg_solve(&op, &b, &CgOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-6);
        assert!(out.iters < 200);
        // solution close to ones
        for &xi in &out.x {
            assert!((xi - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn history_is_recorded_and_monitor_called() {
        let op = Fp64Csr::new(poisson2d(10, 10));
        let b = rhs_for_ones(&op);
        let mut calls = 0;
        let out = cg_solve(&op, &b, &CgOpts::default(), |_, _| {
            calls += 1;
            MonitorCmd::Continue
        });
        assert_eq!(out.history.len(), out.iters);
        assert_eq!(calls, out.iters);
        // residual decreases overall
        assert!(out.history.last().unwrap() < &out.history[0]);
    }

    #[test]
    fn jacobi_preconditioner_helps_on_scaled_problem() {
        let a = diffusion2d(24, 24, 14.0, 77);
        let inv: Vec<f64> = a.diag().iter().map(|&d| 1.0 / d).collect();
        let op = Fp64Csr::new(a);
        let b = rhs_for_ones(&op);
        let plain = cg_solve(
            &op,
            &b,
            &CgOpts { max_iters: 20000, ..Default::default() },
            |_, _| MonitorCmd::Continue,
        );
        let pre = cg_solve(
            &op,
            &b,
            &CgOpts { max_iters: 20000, inv_diag: Some(inv), ..Default::default() },
            |_, _| MonitorCmd::Continue,
        );
        assert!(pre.converged);
        assert!(
            pre.iters < plain.iters,
            "precond {} vs plain {}",
            pre.iters,
            plain.iters
        );
    }

    #[test]
    fn zero_rhs_trivial() {
        let op = Fp64Csr::new(poisson2d(5, 5));
        let out = cg_solve(&op, &[0.0; 25], &CgOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged);
        assert_eq!(out.iters, 0);
        assert!(out.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn respects_max_iters() {
        let op = Fp64Csr::new(poisson2d(30, 30));
        let b = rhs_for_ones(&op);
        let out = cg_solve(&op, &b, &CgOpts { max_iters: 3, ..Default::default() }, |_, _| {
            MonitorCmd::Continue
        });
        assert!(!out.converged);
        assert_eq!(out.iters, 3);
    }

    #[test]
    fn multi_rhs_matches_single_solves_bitwise() {
        let op = Fp64Csr::new(poisson2d(14, 14));
        let n = op.nrows();
        let nrhs = 3usize;
        let mut rng = Prng::new(8);
        let mut bs = vec![0.0; n * nrhs];
        // mix of shapes: b = A·1, random, zero
        bs[0..n].copy_from_slice(&rhs_for_ones(&op));
        for v in bs[n..2 * n].iter_mut() {
            *v = rng.range_f64(-1.0, 1.0);
        }
        let outs = cg_solve_multi(&op, &bs, nrhs, &CgOpts::default());
        assert_eq!(outs.len(), nrhs);
        for (j, multi) in outs.iter().enumerate() {
            let b = &bs[j * n..(j + 1) * n];
            let single = cg_solve(&op, b, &CgOpts::default(), |_, _| MonitorCmd::Continue);
            assert_eq!(multi.converged, single.converged, "rhs {j}");
            assert_eq!(multi.iters, single.iters, "rhs {j}");
            assert_eq!(multi.x, single.x, "rhs {j}");
            assert_eq!(multi.history, single.history, "rhs {j}");
            assert_eq!(multi.relres.to_bits(), single.relres.to_bits(), "rhs {j}");
        }
        // the zero column is the trivial solve
        assert!(outs[2].converged);
        assert_eq!(outs[2].iters, 0);
        assert!(outs[2].x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn multi_rhs_respects_max_iters_per_column() {
        let op = Fp64Csr::new(poisson2d(24, 24));
        let n = op.nrows();
        let b = rhs_for_ones(&op);
        let mut bs = vec![0.0; n * 2];
        bs[0..n].copy_from_slice(&b);
        for (i, v) in bs[n..2 * n].iter_mut().enumerate() {
            *v = (i % 5) as f64 - 2.0;
        }
        let opts = CgOpts { max_iters: 4, ..Default::default() };
        let outs = cg_solve_multi(&op, &bs, 2, &opts);
        for out in &outs {
            assert!(!out.converged);
            assert_eq!(out.iters, 4);
        }
    }

    #[test]
    fn ctl_deflates_only_triggered_columns() {
        use crate::formats::ValueFormat;
        use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
        use std::sync::Arc;
        use std::time::Instant;

        /// Flips `flag` after its `after`-th block apply — a
        /// deterministic stand-in for a cancel arriving mid-solve.
        struct FlipAfter<'a> {
            inner: &'a dyn SpmvOp,
            calls: AtomicUsize,
            after: usize,
            flag: Arc<AtomicBool>,
        }
        impl SpmvOp for FlipAfter<'_> {
            fn apply(&self, x: &[f64], y: &mut [f64]) {
                self.inner.apply(x, y);
            }
            fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
                self.inner.apply_multi(x, y, nrhs);
                if self.calls.fetch_add(1, Ordering::Relaxed) + 1 == self.after {
                    self.flag.store(true, Ordering::Relaxed);
                }
            }
            fn nrows(&self) -> usize {
                self.inner.nrows()
            }
            fn ncols(&self) -> usize {
                self.inner.ncols()
            }
            fn format(&self) -> ValueFormat {
                self.inner.format()
            }
            fn matrix_bytes(&self) -> usize {
                self.inner.matrix_bytes()
            }
        }

        let op = Fp64Csr::new(poisson2d(14, 14));
        let n = op.nrows();
        let mut rng = Prng::new(3);
        let mut bs = vec![0.0; n * 3];
        bs[0..n].copy_from_slice(&rhs_for_ones(&op));
        for v in bs[n..].iter_mut() {
            *v = rng.range_f64(-1.0, 1.0);
        }
        let flag = Arc::new(AtomicBool::new(false));
        let wrapped =
            FlipAfter { inner: &op, calls: AtomicUsize::new(0), after: 3, flag: Arc::clone(&flag) };
        // column 1 cancels after the third apply round; column 2's
        // deadline is already in the past (deflates before any apply)
        let ctl = crate::solvers::block::BlockCtl::new(
            vec![None, Some(flag), None],
            vec![None, None, Some(Instant::now())],
        );
        let (outs, exits) = cg_solve_multi_ctl(&wrapped, &bs, 3, &CgOpts::default(), &ctl);
        assert_eq!(exits[0], crate::solvers::block::ColumnExit::Completed);
        assert_eq!(exits[1], crate::solvers::block::ColumnExit::Cancelled);
        assert_eq!(exits[2], crate::solvers::block::ColumnExit::DeadlineExceeded);
        // the cancelled column carries exactly the 3 iterations it ran
        assert_eq!(outs[1].iters, 3);
        assert!(!outs[1].converged);
        assert_eq!(outs[2].iters, 0);
        // the surviving column is bitwise identical to a standalone solve
        let single = cg_solve(&op, &bs[0..n], &CgOpts::default(), |_, _| MonitorCmd::Continue);
        assert_eq!(outs[0].converged, single.converged);
        assert_eq!(outs[0].iters, single.iters);
        assert_eq!(outs[0].x, single.x);
        assert_eq!(outs[0].relres.to_bits(), single.relres.to_bits());
        // and the ctl-free block is untouched by the machinery
        let plain = cg_solve_multi(&op, &bs, 3, &CgOpts::default());
        assert_eq!(plain[0].x, outs[0].x);
    }

    #[test]
    fn random_spd_random_rhs() {
        let mut rng = Prng::new(5);
        let a = crate::sparse::gen::randmat::exp_controlled_spd(
            120,
            5,
            crate::sparse::gen::randmat::ExpLaw::Gaussian { e0: 0, sigma: 2.0 },
            11,
        );
        let op = Fp64Csr::new(a);
        let b: Vec<f64> = (0..120).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let out = cg_solve(&op, &b, &CgOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged, "relres={}", out.relres);
        assert!(out.relres < 1e-5);
    }
}
