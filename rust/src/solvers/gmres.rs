//! Restarted GMRES(m) with modified-Gram-Schmidt Arnoldi and Givens
//! rotations — the Table III / Fig. 8 solver. Paper setup (§IV-A):
//! restart 30, max outer 500 (15 000 inner iterations), tol 1e-6.
//!
//! The Givens recurrence yields the residual-norm estimate at every
//! *inner* iteration for free; that estimate is what the stepped
//! controller monitors (the paper records residuals per iteration).

use super::blas1::{axpy, dot, has_nonfinite, nrm2, scal};
use super::block::{
    run_fixed_block, run_fixed_block_ctl, BlockColumn, BlockCtl, ColumnExit, ColumnMonitor,
};
use super::{MonitorCmd, SolveOutcome};
use crate::spmv::SpmvOp;
use crate::util::Timer;

/// GMRES options.
#[derive(Clone, Debug)]
pub struct GmresOpts {
    /// stop when the residual estimate / ‖b‖ ≤ tol
    pub tol: f64,
    /// restart length m
    pub restart: usize,
    /// maximum outer cycles (total inner iterations = restart × this)
    pub max_outer: usize,
}

impl Default for GmresOpts {
    fn default() -> Self {
        Self { tol: 1e-6, restart: 30, max_outer: 500 }
    }
}

/// Solve `A x = b` by restarted GMRES. `monitor(total_inner_iter,
/// relres_estimate)` fires on every inner iteration.
pub fn gmres_solve(
    op: &dyn SpmvOp,
    b: &[f64],
    opts: &GmresOpts,
    mut monitor: impl FnMut(usize, f64) -> MonitorCmd,
) -> SolveOutcome {
    let n = op.nrows();
    assert_eq!(b.len(), n);
    let timer = Timer::start();
    let bnorm = nrm2(b);
    if bnorm == 0.0 {
        return SolveOutcome {
            converged: true,
            iters: 0,
            relres: 0.0,
            history: vec![],
            switches: vec![],
            seconds: timer.elapsed_s(),
            x: vec![0.0; n],
            broke_down: false,
        };
    }
    let m = opts.restart.max(1);
    let mut x = vec![0.0; n];
    let mut history: Vec<f64> = Vec::new();
    let mut total_iters = 0usize;
    let mut converged = false;
    let mut broke_down = false;

    // Krylov basis (m+1 vectors) and Hessenberg in column-major strips.
    let mut v: Vec<Vec<f64>> = (0..=m).map(|_| vec![0.0; n]).collect();
    let mut h = vec![0.0f64; (m + 1) * m]; // h[i + j*(m+1)]
    let mut cs = vec![0.0f64; m];
    let mut sn = vec![0.0f64; m];
    let mut g = vec![0.0f64; m + 1];
    let mut r = vec![0.0; n];

    'outer: for _cycle in 0..opts.max_outer {
        // r = b - A x
        op.apply(&x, &mut r);
        for i in 0..n {
            r[i] = b[i] - r[i];
        }
        let beta = nrm2(&r);
        if !beta.is_finite() {
            broke_down = true;
            break;
        }
        if beta / bnorm <= opts.tol {
            converged = true;
            break;
        }
        v[0].copy_from_slice(&r);
        scal(1.0 / beta, &mut v[0]);
        g.iter_mut().for_each(|gi| *gi = 0.0);
        g[0] = beta;

        let mut j_used = 0usize;
        for j in 0..m {
            // w = A v_j
            let (vj, w) = {
                // split borrow: v[j] read, v[j+1] written
                let (a, bseg) = v.split_at_mut(j + 1);
                (&a[j], &mut bseg[0])
            };
            op.apply(vj, w);
            // MGS orthogonalization (split_at_mut: v[i] read, v[j+1] written)
            for i in 0..=j {
                let (head, tail) = v.split_at_mut(j + 1);
                let hij = dot(&head[i], &tail[0]);
                h[i + j * (m + 1)] = hij;
                axpy(-hij, &head[i], &mut tail[0]);
            }
            let hj1 = nrm2(&v[j + 1]);
            h[(j + 1) + j * (m + 1)] = hj1;
            if !hj1.is_finite() {
                broke_down = true;
                break 'outer;
            }
            if hj1 > 0.0 {
                scal(1.0 / hj1, &mut v[j + 1]);
            }
            // apply existing rotations to the new column
            for i in 0..j {
                let t = cs[i] * h[i + j * (m + 1)] + sn[i] * h[(i + 1) + j * (m + 1)];
                h[(i + 1) + j * (m + 1)] =
                    -sn[i] * h[i + j * (m + 1)] + cs[i] * h[(i + 1) + j * (m + 1)];
                h[i + j * (m + 1)] = t;
            }
            // new rotation annihilating h[j+1, j]
            let (hjj, hj1j) = (h[j + j * (m + 1)], h[(j + 1) + j * (m + 1)]);
            let denom = (hjj * hjj + hj1j * hj1j).sqrt();
            if denom == 0.0 {
                // zero Hessenberg column: A annihilated v_j — the
                // operator is singular on the Krylov space (not a happy
                // breakdown, which requires nonsingular H)
                broke_down = true;
                break 'outer;
            }
            let (c, s) = (hjj / denom, hj1j / denom);
            cs[j] = c;
            sn[j] = s;
            h[j + j * (m + 1)] = c * hjj + s * hj1j;
            h[(j + 1) + j * (m + 1)] = 0.0;
            let gj = g[j];
            g[j] = c * gj;
            g[j + 1] = -s * gj;

            j_used = j + 1;
            total_iters += 1;
            let rel = g[j + 1].abs() / bnorm;
            history.push(rel);
            let cmd = monitor(total_iters, rel);
            if !rel.is_finite() {
                broke_down = true;
                break 'outer;
            }
            if rel <= opts.tol {
                converged = true;
                break;
            }
            if cmd == MonitorCmd::Restart {
                // operator changed: the Krylov basis was built with the
                // old A — finish this cycle now; the next outer iteration
                // recomputes r = b − A x with the new operator.
                break;
            }
        }

        // back-substitute y from H y = g and update x += V y
        if j_used > 0 {
            let mut y = vec![0.0f64; j_used];
            for i in (0..j_used).rev() {
                let mut s = g[i];
                for kk in (i + 1)..j_used {
                    s -= h[i + kk * (m + 1)] * y[kk];
                }
                let d = h[i + i * (m + 1)];
                y[i] = if d != 0.0 { s / d } else { 0.0 };
            }
            for (kk, &yk) in y.iter().enumerate() {
                axpy(yk, &v[kk], &mut x);
            }
            if super::blas1::has_nonfinite(&x) {
                broke_down = true;
                break;
            }
        }
        if converged {
            break;
        }
    }

    let relres = super::true_relres(op, &x, b);
    SolveOutcome {
        converged,
        iters: total_iters,
        relres,
        history,
        switches: vec![],
        seconds: timer.elapsed_s(),
        x,
        broke_down,
    }
}

/// Solve `A X = B` for `nrhs` right-hand sides packed column-major in
/// `bs`, running `nrhs` independent restarted-GMRES recurrences in
/// lockstep: every round trip over the matrix is **one**
/// [`SpmvOp::apply_multi`] across all still-active columns (cycle-start
/// residuals and Arnoldi products batch together — columns need not be
/// in the same phase). Each column follows the identical arithmetic
/// sequence as a standalone [`gmres_solve`] on that RHS, so per-column
/// outcomes are bitwise identical to single dispatch; columns deflate
/// out of the block as they converge or break down. `seconds` in each
/// outcome is the shared wall time of the block solve.
pub fn gmres_solve_multi(
    op: &dyn SpmvOp,
    bs: &[f64],
    nrhs: usize,
    opts: &GmresOpts,
) -> Vec<SolveOutcome> {
    let n = op.nrows();
    assert_eq!(op.ncols(), n, "multi-RHS GMRES requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    if nrhs == 0 {
        return Vec::new();
    }
    let cols: Vec<GmresColumn> = (0..nrhs)
        .map(|j| GmresColumn::new(&bs[j * n..(j + 1) * n], opts, ColumnMonitor::Fixed))
        .collect();
    run_fixed_block(op, cols)
}

/// [`gmres_solve_multi`] with per-column cancel/deadline controls:
/// triggered columns deflate mid-block (partial outcome, matching
/// [`ColumnExit`] reason) while survivors stay bitwise identical to
/// single dispatch.
pub(crate) fn gmres_solve_multi_ctl(
    op: &dyn SpmvOp,
    bs: &[f64],
    nrhs: usize,
    opts: &GmresOpts,
    ctl: &BlockCtl,
) -> (Vec<SolveOutcome>, Vec<ColumnExit>) {
    let n = op.nrows();
    assert_eq!(op.ncols(), n, "multi-RHS GMRES requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    if nrhs == 0 {
        return (Vec::new(), Vec::new());
    }
    let cols: Vec<GmresColumn> = (0..nrhs)
        .map(|j| GmresColumn::new(&bs[j * n..(j + 1) * n], opts, ColumnMonitor::Fixed))
        .collect();
    run_fixed_block_ctl(op, cols, ctl)
}

/// One GMRES right-hand side as a [`BlockColumn`] state machine.
/// Between applies it runs exactly the arithmetic of [`gmres_solve`]
/// with its monitor installed (Arnoldi/MGS, Givens update,
/// back-substitution at cycle end), so the outcome is bitwise
/// identical to a standalone monitored solve on this RHS.
pub(crate) struct GmresColumn<'a> {
    b: &'a [f64],
    opts: &'a GmresOpts,
    monitor: ColumnMonitor,
    m: usize,
    bnorm: f64,
    x: Vec<f64>,
    v: Vec<Vec<f64>>,
    h: Vec<f64>,
    cs: Vec<f64>,
    sn: Vec<f64>,
    g: Vec<f64>,
    cycle: usize,
    j: usize,
    j_used: usize,
    iters: usize,
    history: Vec<f64>,
    converged: bool,
    broke_down: bool,
    state: GmresState,
}

enum GmresState {
    /// Next apply: `A · x` (cycle-start residual).
    NeedResidual,
    /// Next apply: `A · v_j` (the Arnoldi step).
    NeedArnoldi,
    Done,
}

impl<'a> GmresColumn<'a> {
    pub(crate) fn new(b: &'a [f64], opts: &'a GmresOpts, monitor: ColumnMonitor) -> Self {
        let n = b.len();
        let bnorm = nrm2(b);
        let m = opts.restart.max(1);
        let mut col = Self {
            b,
            opts,
            monitor,
            m,
            bnorm,
            x: vec![0.0; n],
            v: (0..=m).map(|_| vec![0.0; n]).collect(),
            h: vec![0.0; (m + 1) * m],
            cs: vec![0.0; m],
            sn: vec![0.0; m],
            g: vec![0.0; m + 1],
            cycle: 0,
            j: 0,
            j_used: 0,
            iters: 0,
            history: Vec::new(),
            converged: false,
            broke_down: false,
            state: GmresState::NeedResidual,
        };
        if bnorm == 0.0 {
            col.converged = true;
            col.state = GmresState::Done;
        } else if opts.max_outer == 0 {
            col.state = GmresState::Done;
        }
        col
    }

    fn absorb_residual(&mut self, ax: &[f64]) {
        let b = self.b;
        let mut r = vec![0.0; b.len()];
        for i in 0..b.len() {
            r[i] = b[i] - ax[i];
        }
        let beta = nrm2(&r);
        if !beta.is_finite() {
            self.broke_down = true;
            self.state = GmresState::Done;
            return;
        }
        if beta / self.bnorm <= self.opts.tol {
            self.converged = true;
            self.state = GmresState::Done;
            return;
        }
        self.v[0].copy_from_slice(&r);
        scal(1.0 / beta, &mut self.v[0]);
        self.g.iter_mut().for_each(|gi| *gi = 0.0);
        self.g[0] = beta;
        self.j = 0;
        self.j_used = 0;
        self.state = GmresState::NeedArnoldi;
    }

    fn absorb_arnoldi(&mut self, w: &[f64]) {
        let (m, j) = (self.m, self.j);
        self.v[j + 1].copy_from_slice(w);
        // MGS orthogonalization (split_at_mut: v[i] read, v[j+1] written)
        for i in 0..=j {
            let (head, tail) = self.v.split_at_mut(j + 1);
            let hij = dot(&head[i], &tail[0]);
            self.h[i + j * (m + 1)] = hij;
            axpy(-hij, &head[i], &mut tail[0]);
        }
        let hj1 = nrm2(&self.v[j + 1]);
        self.h[(j + 1) + j * (m + 1)] = hj1;
        if !hj1.is_finite() {
            self.broke_down = true;
            self.state = GmresState::Done;
            return;
        }
        if hj1 > 0.0 {
            scal(1.0 / hj1, &mut self.v[j + 1]);
        }
        // apply existing rotations to the new column
        for i in 0..j {
            let t = self.cs[i] * self.h[i + j * (m + 1)]
                + self.sn[i] * self.h[(i + 1) + j * (m + 1)];
            self.h[(i + 1) + j * (m + 1)] =
                -self.sn[i] * self.h[i + j * (m + 1)] + self.cs[i] * self.h[(i + 1) + j * (m + 1)];
            self.h[i + j * (m + 1)] = t;
        }
        // new rotation annihilating h[j+1, j]
        let (hjj, hj1j) = (self.h[j + j * (m + 1)], self.h[(j + 1) + j * (m + 1)]);
        let denom = (hjj * hjj + hj1j * hj1j).sqrt();
        if denom == 0.0 {
            // zero Hessenberg column: singular on the Krylov space
            self.broke_down = true;
            self.state = GmresState::Done;
            return;
        }
        let (c, s) = (hjj / denom, hj1j / denom);
        self.cs[j] = c;
        self.sn[j] = s;
        self.h[j + j * (m + 1)] = c * hjj + s * hj1j;
        self.h[(j + 1) + j * (m + 1)] = 0.0;
        let gj = self.g[j];
        self.g[j] = c * gj;
        self.g[j + 1] = -s * gj;

        self.j_used = j + 1;
        self.iters += 1;
        let rel = self.g[j + 1].abs() / self.bnorm;
        self.history.push(rel);
        let cmd = self.monitor.observe(self.iters, rel);
        if !rel.is_finite() {
            self.broke_down = true;
            self.state = GmresState::Done;
            return;
        }
        if rel <= self.opts.tol {
            self.converged = true;
            self.end_cycle();
            return;
        }
        if cmd == MonitorCmd::Restart {
            // operator escalated: finish this cycle now; the next
            // cycle-start residual uses the new rung
            self.end_cycle();
            return;
        }
        self.j += 1;
        if self.j == m {
            self.end_cycle();
        }
    }

    /// Back-substitute `y` from `H y = g`, update `x += V y`, and move
    /// to the next cycle (or finish) — [`gmres_solve`]'s cycle tail.
    fn end_cycle(&mut self) {
        let m = self.m;
        if self.j_used > 0 {
            let ju = self.j_used;
            let mut y = vec![0.0f64; ju];
            for i in (0..ju).rev() {
                let mut s = self.g[i];
                for kk in (i + 1)..ju {
                    s -= self.h[i + kk * (m + 1)] * y[kk];
                }
                let d = self.h[i + i * (m + 1)];
                y[i] = if d != 0.0 { s / d } else { 0.0 };
            }
            for (kk, &yk) in y.iter().enumerate() {
                axpy(yk, &self.v[kk], &mut self.x);
            }
            if has_nonfinite(&self.x) {
                self.broke_down = true;
                self.state = GmresState::Done;
                return;
            }
        }
        if self.converged {
            self.state = GmresState::Done;
            return;
        }
        self.cycle += 1;
        self.state = if self.cycle >= self.opts.max_outer {
            GmresState::Done
        } else {
            GmresState::NeedResidual
        };
    }
}

impl BlockColumn for GmresColumn<'_> {
    fn active(&self) -> bool {
        !matches!(self.state, GmresState::Done)
    }

    fn tag(&self) -> u8 {
        self.monitor.tag()
    }

    fn input(&self) -> &[f64] {
        match self.state {
            GmresState::NeedResidual => &self.x,
            GmresState::NeedArnoldi => &self.v[self.j],
            GmresState::Done => unreachable!("inactive column asked for input"),
        }
    }

    fn absorb(&mut self, y: &[f64]) {
        match self.state {
            GmresState::NeedResidual => self.absorb_residual(y),
            GmresState::NeedArnoldi => self.absorb_arnoldi(y),
            GmresState::Done => unreachable!("inactive column fed a result"),
        }
    }

    fn deflate(&mut self) {
        self.state = GmresState::Done;
    }

    fn finish(mut self, op: &dyn SpmvOp, seconds: f64) -> SolveOutcome {
        let relres = super::true_relres(op, &self.x, self.b);
        SolveOutcome {
            converged: self.converged,
            iters: self.iters,
            relres,
            history: self.history,
            switches: self.monitor.take_switches(),
            seconds,
            x: self.x,
            broke_down: self.broke_down,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::circuit::conductance_network;
    use crate::sparse::gen::convdiff::{convdiff2d, device1d};
    use crate::sparse::gen::poisson::poisson2d;
    use crate::spmv::fp64::Fp64Csr;

    fn rhs_for_ones(op: &dyn SpmvOp) -> Vec<f64> {
        let ones = vec![1.0; op.ncols()];
        let mut b = vec![0.0; op.nrows()];
        op.apply(&ones, &mut b);
        b
    }

    #[test]
    fn converges_on_asymmetric_convdiff() {
        let op = Fp64Csr::new(convdiff2d(16, 16, 8.0, 4.0));
        let b = rhs_for_ones(&op);
        let out = gmres_solve(&op, &b, &GmresOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-5);
        for &xi in &out.x {
            assert!((xi - 1.0).abs() < 1e-3, "{xi}");
        }
    }

    #[test]
    fn converges_on_circuit_and_device() {
        for a in [conductance_network(300, 4, 3.0, 0.3, 1), device1d(256, 3, 2)] {
            let op = Fp64Csr::new(a);
            let b = rhs_for_ones(&op);
            let out = gmres_solve(&op, &b, &GmresOpts::default(), |_, _| MonitorCmd::Continue);
            assert!(out.converged, "relres {}", out.relres);
        }
    }

    #[test]
    fn residual_estimate_tracks_true_residual() {
        // at convergence the Givens estimate and the true residual agree
        let op = Fp64Csr::new(convdiff2d(12, 12, 4.0, 0.0));
        let b = rhs_for_ones(&op);
        let out = gmres_solve(&op, &b, &GmresOpts::default(), |_, _| MonitorCmd::Continue);
        let est = *out.history.last().unwrap();
        assert!(
            (est - out.relres).abs() <= 1e-6 + 0.5 * out.relres.max(est),
            "est={est} true={}",
            out.relres
        );
    }

    #[test]
    fn history_length_matches_inner_iterations() {
        let op = Fp64Csr::new(poisson2d(12, 12));
        let b = rhs_for_ones(&op);
        let mut calls = 0usize;
        let out = gmres_solve(&op, &b, &GmresOpts::default(), |_, _| {
            calls += 1;
            MonitorCmd::Continue
        });
        assert_eq!(out.history.len(), out.iters);
        assert_eq!(calls, out.iters);
    }

    #[test]
    fn restart_cycles_work() {
        // tiny restart forces multiple outer cycles
        let op = Fp64Csr::new(convdiff2d(14, 14, 16.0, 2.0));
        let b = rhs_for_ones(&op);
        let out = gmres_solve(
            &op,
            &b,
            &GmresOpts { restart: 5, max_outer: 500, tol: 1e-8 },
            |_, _| MonitorCmd::Continue,
        );
        assert!(out.converged, "relres={}", out.relres);
        assert!(out.iters > 5, "should need more than one cycle");
    }

    #[test]
    fn multi_rhs_matches_single_solves_bitwise() {
        let op = Fp64Csr::new(convdiff2d(10, 10, 6.0, 3.0));
        let n = op.nrows();
        let nrhs = 3usize;
        let mut bs = vec![0.0; n * nrhs];
        bs[0..n].copy_from_slice(&rhs_for_ones(&op));
        // column 1 stays zero (trivial); column 2 is a rough ramp
        for (i, v) in bs[2 * n..3 * n].iter_mut().enumerate() {
            *v = (i % 3) as f64 - 1.0;
        }
        let opts = GmresOpts::default();
        let outs = gmres_solve_multi(&op, &bs, nrhs, &opts);
        assert_eq!(outs.len(), nrhs);
        for (j, multi) in outs.iter().enumerate() {
            let b = &bs[j * n..(j + 1) * n];
            let single = gmres_solve(&op, b, &opts, |_, _| MonitorCmd::Continue);
            assert_eq!(multi.converged, single.converged, "rhs {j}");
            assert_eq!(multi.iters, single.iters, "rhs {j}");
            assert_eq!(multi.x, single.x, "rhs {j}");
            assert_eq!(multi.history, single.history, "rhs {j}");
            assert_eq!(multi.relres.to_bits(), single.relres.to_bits(), "rhs {j}");
        }
        // the zero column deflates immediately
        assert!(outs[1].converged);
        assert_eq!(outs[1].iters, 0);
    }

    #[test]
    fn max_outer_respected() {
        let op = Fp64Csr::new(convdiff2d(20, 20, 64.0, 32.0));
        let b = rhs_for_ones(&op);
        let out = gmres_solve(
            &op,
            &b,
            &GmresOpts { restart: 3, max_outer: 2, tol: 1e-14 },
            |_, _| MonitorCmd::Continue,
        );
        assert!(out.iters <= 6);
        assert!(!out.converged);
    }
}
