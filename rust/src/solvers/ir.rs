//! Mixed-precision iterative refinement (Carson & Higham [11] style) —
//! the related-work baseline the paper positions itself against. The
//! inner solver runs entirely on the *low-precision* GSE-SEM head
//! operator; the outer loop computes residuals with the full-precision
//! operator and accumulates the correction in FP64.

use super::blas1::nrm2;
use super::cg::{cg_solve, CgOpts};
use super::SolveOutcome;
use crate::formats::Precision;
use crate::spmv::gse::GseCsr;
use crate::spmv::SpmvOp;
use crate::util::Timer;

/// Iterative-refinement options.
#[derive(Clone, Debug)]
pub struct IrOpts {
    /// outer tolerance on ‖b − Ax‖/‖b‖ (full-precision residual)
    pub tol: f64,
    pub max_outer: usize,
    /// inner CG tolerance (relative, on the low-precision system)
    pub inner_tol: f64,
    pub inner_iters: usize,
}

impl Default for IrOpts {
    fn default() -> Self {
        Self { tol: 1e-6, max_outer: 40, inner_tol: 1e-2, inner_iters: 300 }
    }
}

/// Solve SPD `A x = b`: inner CG on the head-precision operator, outer
/// FP64 residual correction on the full-precision operator.
pub fn ir_solve(m: &GseCsr, b: &[f64], opts: &IrOpts) -> SolveOutcome {
    let n = m.nrows;
    let timer = Timer::start();
    let low = m.clone().at_level(Precision::Head);
    let full = m.clone().at_level(Precision::Full);
    let bnorm = nrm2(b);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut history = Vec::new();
    let mut total_inner = 0usize;
    let mut converged = false;
    let mut broke_down = false;

    for _outer in 0..opts.max_outer {
        // inner solve A_low d = r
        let inner = cg_solve(
            &low,
            &r,
            &CgOpts { tol: opts.inner_tol, max_iters: opts.inner_iters, inv_diag: None },
            |_, _| crate::solvers::MonitorCmd::Continue,
        );
        total_inner += inner.iters;
        if inner.broke_down {
            broke_down = true;
            break;
        }
        for i in 0..n {
            x[i] += inner.x[i];
        }
        // full-precision residual r = b - A x
        let mut ax = vec![0.0; n];
        full.apply(&x, &mut ax);
        for i in 0..n {
            r[i] = b[i] - ax[i];
        }
        let rel = nrm2(&r) / bnorm.max(f64::MIN_POSITIVE);
        history.push(rel);
        if !rel.is_finite() {
            broke_down = true;
            break;
        }
        if rel <= opts.tol {
            converged = true;
            break;
        }
    }

    let relres = super::true_relres(&full, &x, b);
    SolveOutcome {
        converged,
        iters: total_inner,
        relres,
        history,
        switches: vec![],
        seconds: timer.elapsed_s(),
        x,
        broke_down,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::fem::diffusion2d;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn refines_to_full_tolerance_on_poisson() {
        let a = poisson2d(12, 12);
        let g = GseCsr::from_csr(&a, 8);
        let ones = vec![1.0; a.ncols];
        let mut b = vec![0.0; a.nrows];
        crate::spmv::fp64::spmv(&a, &ones, &mut b);
        let out = ir_solve(&g, &b, &IrOpts::default());
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-6);
    }

    #[test]
    fn outer_history_monotonic_overall() {
        let a = diffusion2d(10, 10, 4.0, 3);
        let g = GseCsr::from_csr(&a, 8);
        let full = g.clone().at_level(Precision::Full);
        let ones = vec![1.0; a.ncols];
        let mut b = vec![0.0; a.nrows];
        full.apply(&ones, &mut b);
        let out = ir_solve(&g, &b, &IrOpts::default());
        assert!(out.converged);
        assert!(out.history.last().unwrap() < &out.history[0]);
    }

    #[test]
    fn respects_outer_cap() {
        let a = poisson2d(16, 16);
        let g = GseCsr::from_csr(&a, 8);
        let b = vec![1.0; a.nrows];
        let out = ir_solve(
            &g,
            &b,
            &IrOpts { tol: 1e-14, max_outer: 2, inner_tol: 0.5, inner_iters: 3 },
        );
        assert!(!out.converged);
        assert_eq!(out.history.len(), 2);
    }
}
