//! Mixed-precision iterative refinement.
//!
//! Two drivers live here:
//!
//! * [`ir_solve`] — the Carson & Higham-style CG baseline the paper
//!   positions itself against: inner CG on a low GSE-SEM rung, outer
//!   FP64 residual correction on the full-precision operator, with the
//!   inner rung escalated when the outer contraction stalls (escalations
//!   land in [`SolveOutcome::switches`]).
//! * [`ir_gmres_solve`] / [`ir_solve_multi`] — GMRES-based iterative
//!   refinement in the style of Loe et al. (arXiv:2109.01232): the
//!   inner solver is restarted GMRES on the **left-preconditioned
//!   ladder operator** `M⁻¹A` ([`PrecondLadderOp`]), with the
//!   preconditioner (`None`/`Jacobi`/SAINV, see
//!   [`crate::solvers::sainv`]) applied at a rung chosen per outer
//!   iteration from the residual trajectory — the adaptive-precision
//!   preconditioning of Carson & Khan (arXiv:2307.03914). The
//!   multi-RHS variant batches same-rung columns into fused
//!   `apply_multi` rounds over [`crate::solvers::block`], each column
//!   bitwise identical to single dispatch, and honours the intake's
//!   per-ticket cancel/deadline controls mid-solve.
//!
//! Rung-selection policy: every column starts on rung 1 (head). After
//! each outer correction the contraction ratio `relₖ/relₖ₋₁` is
//! compared against `escalate_ratio`; a slower-than-expected outer step
//! means the inner rung's precision is the bottleneck, so the column's
//! next inner solve (matrix **and** preconditioner) runs one rung
//! finer. Escalations are logged as `(total_inner_iters, new_tag)` in
//! [`SolveOutcome::switches`], exactly like the stepped controller's.

use super::blas1::nrm2;
use super::block::{BlockCtl, ColumnExit};
use super::cg::{cg_solve, CgOpts};
use super::gmres::{gmres_solve_multi_ctl, GmresOpts};
use super::ladder::PrecisionSwitchable;
use super::sainv::{PrecondLadderOp, PrecondOp};
use super::SolveOutcome;
use crate::formats::Precision;
use crate::spmv::gse::{GseCsr, GseSpmv};
use crate::spmv::SpmvOp;
use crate::util::Timer;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Contraction ratio above which [`ir_solve`] escalates its inner CG
/// rung (the GMRES driver takes the ratio from [`IrGmresOpts`]).
const ESCALATE_RATIO: f64 = 0.5;

/// Iterative-refinement options (CG baseline, [`ir_solve`]).
#[derive(Clone, Debug)]
pub struct IrOpts {
    /// outer tolerance on ‖b − Ax‖/‖b‖ (full-precision residual)
    pub tol: f64,
    pub max_outer: usize,
    /// inner CG tolerance (relative, on the low-precision system)
    pub inner_tol: f64,
    pub inner_iters: usize,
}

impl Default for IrOpts {
    fn default() -> Self {
        Self { tol: 1e-6, max_outer: 40, inner_tol: 1e-2, inner_iters: 300 }
    }
}

/// Solve SPD `A x = b`: inner CG on a low-precision GSE rung, outer
/// FP64 residual correction on the full-precision operator. The inner
/// rung starts at head and escalates (head → head+tail1 → full) when
/// an outer step contracts by less than [`ESCALATE_RATIO`]; switch
/// events are reported in [`SolveOutcome::switches`].
pub fn ir_solve(m: &GseCsr, b: &[f64], opts: &IrOpts) -> SolveOutcome {
    let n = m.nrows;
    let timer = Timer::start();
    // one encode, shared by every rung view (no per-level clones)
    let enc = Arc::new(m.clone());
    let full = GseSpmv::new(Arc::clone(&enc), Precision::Full);
    let bnorm = nrm2(b);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut ax = vec![0.0; n];
    let mut history = Vec::new();
    let mut switches = Vec::new();
    let mut total_inner = 0usize;
    let mut converged = false;
    let mut broke_down = false;
    let mut tag = 1u8;
    let mut prev_rel = f64::INFINITY;

    for _outer in 0..opts.max_outer {
        // inner solve A_tag d = r
        let low = GseSpmv::new(Arc::clone(&enc), Precision::from_tag(tag));
        let inner = cg_solve(
            &low,
            &r,
            &CgOpts { tol: opts.inner_tol, max_iters: opts.inner_iters, inv_diag: None },
            |_, _| crate::solvers::MonitorCmd::Continue,
        );
        total_inner += inner.iters;
        if inner.broke_down {
            broke_down = true;
            break;
        }
        for i in 0..n {
            x[i] += inner.x[i];
        }
        // full-precision residual r = b - A x
        full.apply(&x, &mut ax);
        for i in 0..n {
            r[i] = b[i] - ax[i];
        }
        let rel = nrm2(&r) / bnorm.max(f64::MIN_POSITIVE);
        history.push(rel);
        if !rel.is_finite() {
            broke_down = true;
            break;
        }
        if rel <= opts.tol {
            converged = true;
            break;
        }
        // stalled outer contraction: the inner rung is the bottleneck
        if rel / prev_rel > ESCALATE_RATIO && tag < Precision::LADDER.len() as u8 {
            tag += 1;
            switches.push((total_inner, tag));
        }
        prev_rel = rel;
    }

    let relres = super::true_relres(&full, &x, b);
    SolveOutcome {
        converged,
        iters: total_inner,
        relres,
        history,
        switches,
        seconds: timer.elapsed_s(),
        x,
        broke_down,
    }
}

/// Options of the GMRES-based iterative-refinement driver.
#[derive(Clone, Debug)]
pub struct IrGmresOpts {
    /// outer tolerance on ‖b − Ax‖/‖b‖ (full-precision residual)
    pub tol: f64,
    /// outer correction cap
    pub max_outer: usize,
    /// inner GMRES run per outer step (loose tolerance, few cycles)
    pub inner: GmresOpts,
    /// escalate the column's rung when `relₖ/relₖ₋₁` exceeds this
    pub escalate_ratio: f64,
}

impl Default for IrGmresOpts {
    fn default() -> Self {
        Self {
            tol: 1e-6,
            max_outer: 40,
            inner: GmresOpts { tol: 1e-2, restart: 30, max_outer: 4 },
            escalate_ratio: 0.5,
        }
    }
}

impl IrGmresOpts {
    /// Derive outer/inner budgets from a request's `(tol, max_iters)`
    /// caps: each outer step spends at most `restart × inner.max_outer`
    /// = 120 inner iterations, so the outer cap is the iteration cap in
    /// units of 120 (clamped to something useful).
    pub fn for_caps(tol: f64, max_iters: usize) -> Self {
        Self { tol, max_outer: max_iters.div_ceil(120).clamp(4, 200), ..Self::default() }
    }
}

/// Solve `A x = b` by preconditioned GMRES-IR on one GSE encode: inner
/// restarted GMRES on `M⁻¹A` at the column's current rung, outer FP64
/// residual correction at full precision. Single-RHS wrapper over
/// [`ir_solve_multi`] — bitwise identical to a width-1 block.
pub fn ir_gmres_solve(
    a: &Arc<GseCsr>,
    m: &PrecondOp,
    b: &[f64],
    opts: &IrGmresOpts,
) -> SolveOutcome {
    ir_solve_multi(a, m, b, 1, opts).pop().expect("one column in, one outcome out")
}

/// Multi-RHS GMRES-IR over `nrhs` column-major packed right-hand
/// sides: per outer round, active columns group by rung (coarsest
/// first) and each group runs one fused inner GMRES block on the
/// shared [`PrecondLadderOp`], followed by one fused full-precision
/// residual pass — every column bitwise identical to
/// [`ir_gmres_solve`] on its RHS alone.
pub fn ir_solve_multi(
    a: &Arc<GseCsr>,
    m: &PrecondOp,
    bs: &[f64],
    nrhs: usize,
    opts: &IrGmresOpts,
) -> Vec<SolveOutcome> {
    ir_solve_multi_ctl(a, m, bs, nrhs, opts, &BlockCtl::none(nrhs)).0
}

/// Per-column outer-loop state of the block GMRES-IR driver.
struct IrColumn {
    x: Vec<f64>,
    r: Vec<f64>,
    bnorm: f64,
    history: Vec<f64>,
    switches: Vec<(usize, u8)>,
    iters: usize,
    outer: usize,
    tag: u8,
    prev_rel: f64,
    active: bool,
    converged: bool,
    broke_down: bool,
}

/// [`ir_solve_multi`] plus the intake's per-ticket cancel/deadline
/// controls: triggered columns deflate out of the block between (and,
/// via a forwarded sub-ctl, during) inner solves, like every other
/// `_ctl` block runner.
pub(crate) fn ir_solve_multi_ctl(
    a: &Arc<GseCsr>,
    m: &PrecondOp,
    bs: &[f64],
    nrhs: usize,
    opts: &IrGmresOpts,
    ctl: &BlockCtl,
) -> (Vec<SolveOutcome>, Vec<ColumnExit>) {
    let n = a.nrows;
    assert_eq!(a.ncols, n, "GMRES-IR requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    if nrhs == 0 {
        return (Vec::new(), Vec::new());
    }
    let timer = Timer::start();
    let op = PrecondLadderOp::new(Arc::clone(a), m.clone());
    let full = GseSpmv::new(Arc::clone(a), Precision::Full);
    let mut exits = vec![ColumnExit::Completed; nrhs];
    let mut cols: Vec<IrColumn> = (0..nrhs)
        .map(|j| {
            let b = &bs[j * n..(j + 1) * n];
            let bnorm = nrm2(b);
            IrColumn {
                x: vec![0.0; n],
                r: b.to_vec(),
                bnorm,
                history: Vec::new(),
                switches: Vec::new(),
                iters: 0,
                outer: 0,
                tag: 1,
                prev_rel: f64::INFINITY,
                // a zero RHS is solved by x = 0 before any work
                active: bnorm != 0.0,
                converged: bnorm == 0.0,
                broke_down: false,
            }
        })
        .collect();

    let mut xs: Vec<f64> = Vec::new();
    let mut axs: Vec<f64> = Vec::new();
    loop {
        if ctl.has_controls() {
            for (j, col) in cols.iter_mut().enumerate() {
                if col.active {
                    if let Some(exit) = ctl.poll(j) {
                        col.active = false;
                        exits[j] = exit;
                    }
                }
            }
        }
        // group live columns by rung; BTreeMap iterates coarsest first
        let mut by_tag: BTreeMap<u8, Vec<usize>> = BTreeMap::new();
        for (j, col) in cols.iter().enumerate() {
            if col.active {
                by_tag.entry(col.tag).or_default().push(j);
            }
        }
        if by_tag.is_empty() {
            break;
        }
        for (tag, idxs) in by_tag {
            op.set_tag(tag);
            let level = Precision::from_tag(tag);
            let width = idxs.len();
            // fused M⁻¹r across the group: the inner right-hand sides
            xs.clear();
            xs.resize(n * width, 0.0);
            for (slot, &j) in idxs.iter().enumerate() {
                xs[slot * n..(slot + 1) * n].copy_from_slice(&cols[j].r);
            }
            let mut zs = vec![0.0f64; n * width];
            m.apply_multi_level(&xs, &mut zs, width, level);
            // inner block solve (M⁻¹A) d = M⁻¹r at this rung, with the
            // group's slice of the ticket controls forwarded
            let sub = ctl.subset(&idxs);
            let (inner_outs, inner_exits) =
                gmres_solve_multi_ctl(&op, &zs, width, &opts.inner, &sub);
            for (slot, &j) in idxs.iter().enumerate() {
                let col = &mut cols[j];
                if inner_exits[slot] != ColumnExit::Completed {
                    col.active = false;
                    exits[j] = inner_exits[slot];
                    continue;
                }
                let inner = &inner_outs[slot];
                col.iters += inner.iters;
                if inner.broke_down {
                    col.broke_down = true;
                    col.active = false;
                    continue;
                }
                for (xi, di) in col.x.iter_mut().zip(&inner.x) {
                    *xi += di;
                }
            }
        }
        // one fused full-precision residual pass over the survivors
        let live: Vec<usize> = (0..nrhs).filter(|&j| cols[j].active).collect();
        if live.is_empty() {
            continue; // loop top will observe no active columns
        }
        let width = live.len();
        xs.clear();
        xs.resize(n * width, 0.0);
        axs.clear();
        axs.resize(n * width, 0.0);
        for (slot, &j) in live.iter().enumerate() {
            xs[slot * n..(slot + 1) * n].copy_from_slice(&cols[j].x);
        }
        full.apply_multi(&xs, &mut axs, width);
        for (slot, &j) in live.iter().enumerate() {
            let col = &mut cols[j];
            let b = &bs[j * n..(j + 1) * n];
            let ax = &axs[slot * n..(slot + 1) * n];
            for i in 0..n {
                col.r[i] = b[i] - ax[i];
            }
            let rel = nrm2(&col.r) / col.bnorm.max(f64::MIN_POSITIVE);
            col.history.push(rel);
            col.outer += 1;
            if !rel.is_finite() {
                col.broke_down = true;
                col.active = false;
                continue;
            }
            if rel <= opts.tol {
                col.converged = true;
                col.active = false;
                continue;
            }
            if col.outer >= opts.max_outer {
                col.active = false;
                continue;
            }
            // residual-trajectory rung selection (arXiv:2307.03914)
            if rel / col.prev_rel > opts.escalate_ratio && col.tag < Precision::LADDER.len() as u8 {
                col.tag += 1;
                col.switches.push((col.iters, col.tag));
            }
            col.prev_rel = rel;
        }
    }
    let seconds = timer.elapsed_s();
    let outcomes = cols
        .into_iter()
        .enumerate()
        .map(|(j, col)| {
            let b = &bs[j * n..(j + 1) * n];
            let relres = super::true_relres(&full, &col.x, b);
            SolveOutcome {
                converged: col.converged,
                iters: col.iters,
                relres,
                history: col.history,
                switches: col.switches,
                seconds,
                x: col.x,
                broke_down: col.broke_down,
            }
        })
        .collect();
    (outcomes, exits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::sainv::{SainvFactors, SainvParams};
    use crate::sparse::gen::circuit::conductance_network;
    use crate::sparse::gen::fem::diffusion2d;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::util::Prng;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn refines_to_full_tolerance_on_poisson() {
        let a = poisson2d(12, 12);
        let g = GseCsr::from_csr(&a, 8);
        let ones = vec![1.0; a.ncols];
        let mut b = vec![0.0; a.nrows];
        crate::spmv::fp64::spmv(&a, &ones, &mut b);
        let out = ir_solve(&g, &b, &IrOpts::default());
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-6);
    }

    #[test]
    fn outer_history_monotonic_overall() {
        let a = diffusion2d(10, 10, 4.0, 3);
        let g = GseCsr::from_csr(&a, 8);
        let full = g.clone().at_level(Precision::Full);
        let ones = vec![1.0; a.ncols];
        let mut b = vec![0.0; a.nrows];
        full.apply(&ones, &mut b);
        let out = ir_solve(&g, &b, &IrOpts::default());
        assert!(out.converged);
        assert!(out.history.last().unwrap() < &out.history[0]);
    }

    #[test]
    fn respects_outer_cap() {
        let a = poisson2d(16, 16);
        let g = GseCsr::from_csr(&a, 8);
        let b = vec![1.0; a.nrows];
        let out = ir_solve(
            &g,
            &b,
            &IrOpts { tol: 1e-14, max_outer: 2, inner_tol: 0.5, inner_iters: 3 },
        );
        assert!(!out.converged);
        assert_eq!(out.history.len(), 2);
    }

    #[test]
    fn cg_ir_reports_switches_when_stalling() {
        // a weak inner solve stalls the outer contraction, forcing the
        // rung up the ladder — the satellite fix: switches are no
        // longer silently dropped
        let a = poisson2d(16, 16);
        let g = GseCsr::from_csr(&a, 8);
        let b = vec![1.0; a.nrows];
        let out = ir_solve(
            &g,
            &b,
            &IrOpts { tol: 1e-14, max_outer: 8, inner_tol: 0.9, inner_iters: 1 },
        );
        assert!(!out.switches.is_empty(), "stalled IR must escalate");
        for w in out.switches.windows(2) {
            assert!(w[0].1 < w[1].1, "tags escalate monotonically");
        }
        assert!(out.switches.iter().all(|&(_, t)| (2..=3).contains(&t)));
    }

    #[test]
    fn gmres_ir_converges_unpreconditioned() {
        let a = poisson2d(10, 10);
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let ones = vec![1.0; a.ncols];
        let mut b = vec![0.0; a.nrows];
        crate::spmv::fp64::spmv(&a, &ones, &mut b);
        let out = ir_gmres_solve(&g, &PrecondOp::None, &b, &IrGmresOpts::default());
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-6);
        assert!(out.iters > 0);
    }

    #[test]
    fn sainv_ir_reaches_tight_tolerance_on_circuit() {
        // the ill-conditioned corpus instance: exponent-skewed
        // conductances; SAINV-preconditioned GMRES-IR drives the true
        // residual far below where low-rung inner solves alone stall
        let a = conductance_network(300, 6, 3.0, 0.0, 42);
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let f = SainvFactors::build(&a, SainvParams { drop_tol: 0.05, k: 8 }).unwrap();
        let mut rng = Prng::new(9);
        let b: Vec<f64> = (0..a.nrows).map(|_| rng.f64() - 0.5).collect();
        let opts = IrGmresOpts { tol: 1e-10, max_outer: 60, ..Default::default() };
        let out = ir_gmres_solve(&g, &PrecondOp::Sainv(Arc::new(f)), &b, &opts);
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-8, "relres {}", out.relres);
    }

    #[test]
    fn block_columns_match_single_dispatch_bitwise() {
        let a = poisson2d(9, 9);
        let n = a.nrows;
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let f = Arc::new(SainvFactors::build(&a, SainvParams::default()).unwrap());
        let m = PrecondOp::Sainv(f);
        let nrhs = 3usize;
        let mut rng = Prng::new(4);
        let mut bs = vec![0.0; n * nrhs];
        let ones = vec![1.0; n];
        crate::spmv::fp64::spmv(&a, &ones, &mut bs[0..n]);
        for v in bs[n..].iter_mut() {
            *v = rng.f64() - 0.5;
        }
        let opts = IrGmresOpts::default();
        let block = ir_solve_multi(&g, &m, &bs, nrhs, &opts);
        for (j, got) in block.iter().enumerate() {
            let single = ir_gmres_solve(&g, &m, &bs[j * n..(j + 1) * n], &opts);
            assert_eq!(got.x, single.x, "column {j} x");
            assert_eq!(got.history, single.history, "column {j} history");
            assert_eq!(got.iters, single.iters, "column {j} iters");
            assert_eq!(got.switches, single.switches, "column {j} switches");
            assert_eq!(got.converged, single.converged, "column {j}");
        }
    }

    #[test]
    fn gmres_ir_escalates_rungs_from_residual_trajectory() {
        let a = poisson2d(12, 12);
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let b = vec![1.0; a.nrows];
        // an escalate_ratio of 0 forces a switch after every outer step
        // past the first — rungs must walk 1 → 2 → 3 and stop
        let opts = IrGmresOpts {
            tol: 1e-30,
            max_outer: 4,
            escalate_ratio: 0.0,
            ..Default::default()
        };
        let out = ir_gmres_solve(&g, &PrecondOp::None, &b, &opts);
        assert_eq!(out.history.len(), 4);
        let tags: Vec<u8> = out.switches.iter().map(|&(_, t)| t).collect();
        assert_eq!(tags, vec![2, 3], "ladder walk is capped at full");
    }

    #[test]
    fn cancelled_column_deflates_out_of_the_block() {
        let a = poisson2d(10, 10);
        let n = a.nrows;
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let nrhs = 2usize;
        let bs = vec![1.0; n * nrhs];
        let flag = Arc::new(AtomicBool::new(true));
        let ctl = BlockCtl::new(vec![None, Some(Arc::clone(&flag))], vec![None, None]);
        let (outs, exits) = ir_solve_multi_ctl(
            &g,
            &PrecondOp::None,
            &bs,
            nrhs,
            &IrGmresOpts::default(),
            &ctl,
        );
        assert_eq!(exits[0], ColumnExit::Completed);
        assert_eq!(exits[1], ColumnExit::Cancelled);
        assert!(outs[0].converged);
        assert!(!outs[1].converged);
        // the survivor matches a solo run bitwise
        let solo = ir_gmres_solve(&g, &PrecondOp::None, &bs[0..n], &IrGmresOpts::default());
        assert_eq!(outs[0].x, solo.x);
    }

    #[test]
    fn zero_rhs_column_converges_instantly() {
        let a = poisson2d(6, 6);
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let b = vec![0.0; a.nrows];
        let out = ir_gmres_solve(&g, &PrecondOp::None, &b, &IrGmresOpts::default());
        assert!(out.converged);
        assert_eq!(out.iters, 0);
        assert!(out.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn for_caps_scales_outer_budget() {
        let o = IrGmresOpts::for_caps(1e-9, 15000);
        assert_eq!(o.tol, 1e-9);
        assert_eq!(o.max_outer, 125);
        assert_eq!(IrGmresOpts::for_caps(1e-6, 10).max_outer, 4);
        assert_eq!(IrGmresOpts::for_caps(1e-6, 1_000_000).max_outer, 200);
    }
}
