//! Dense vector kernels (the cuBLAS calls of the paper, §IV-A: "all
//! vector operations in the iterative algorithms are performed by
//! calling APIs in the NVIDIA cuBLAS library" — always FP64).

/// dot(x, y)
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for (a, b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

/// ‖x‖₂
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y ← a·x + y
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// x ← a·x
#[inline]
pub fn scal(a: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// y ← x
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// y ← x + b·y  (the CG "p = r + beta p" update)
#[inline]
pub fn xpby(x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + b * *yi;
    }
}

/// Any non-finite component?
#[inline]
pub fn has_nonfinite(x: &[f64]) -> bool {
    x.iter().any(|v| !v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(nrm2(&[3.0, 4.0]), 5.0);
        assert_eq!(nrm2(&[]), 0.0);
    }

    #[test]
    fn axpy_scal_xpby() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![3.5, 4.5]);
        xpby(&[1.0, 1.0], 2.0, &mut y);
        assert_eq!(y, vec![8.0, 10.0]);
    }

    #[test]
    fn nonfinite_detection() {
        assert!(!has_nonfinite(&[1.0, -2.0]));
        assert!(has_nonfinite(&[1.0, f64::NAN]));
        assert!(has_nonfinite(&[f64::INFINITY]));
    }
}
