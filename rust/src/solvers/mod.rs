//! Iterative solvers and the paper's stepped mixed-precision controller
//! (§III-D).
//!
//! * [`blas1`] — the dense vector kernels (dot/axpy/norm); the paper
//!   calls cuBLAS for these, always in FP64 — so do we.
//! * [`cg`] — conjugate gradients (Table IV / Fig. 9 solver), single-
//!   and multi-RHS ([`cg::cg_solve_multi`]).
//! * [`gmres`] — restarted GMRES with MGS-Arnoldi + Givens rotations
//!   (Table III / Fig. 8 solver), single- and multi-RHS
//!   ([`gmres::gmres_solve_multi`]).
//! * [`bicgstab`] — BiCGSTAB (related-work extension [21]), single-
//!   and multi-RHS ([`bicgstab::bicgstab_solve_multi`]).
//! * `block` (crate-internal) — the lockstep block-solve frame behind
//!   every multi-RHS entry point: per-column solver state machines
//!   batched into one fused `apply_multi` per round trip, bitwise
//!   identical per column to single dispatch.
//! * [`stepped`] — the residual-monitoring precision controller
//!   (RSD / nDec / relDec, Conditions 1–3) and the Algorithm-3 wiring,
//!   generic over any precision ladder; [`stepped::run_stepped_multi`]
//!   is the batched mode (one shared ladder, per-column controllers).
//! * [`ladder`] — the [`ladder::PrecisionSwitchable`] ladder trait with
//!   the zero-copy GSE-SEM tag ladder ([`SwitchableOp`]) and the
//!   copy-based fp32→fp64 baseline ([`ladder::CopyLadderOp`]).
//! * [`precond`] — Jacobi / symmetric Gauss–Seidel preconditioner
//!   data (extension).
//! * [`sainv`] — drop-tolerance SAINV factored approximate inverse
//!   with GSE-resident factors ([`sainv::SainvFactors`]), the
//!   [`sainv::Precond`] spec axis, and the left-preconditioned ladder
//!   operator [`sainv::PrecondLadderOp`].
//! * [`ir`] — mixed-precision iterative refinement: the CG baseline
//!   ([`ir::ir_solve`], related work [11]) and preconditioned GMRES-IR
//!   over the ladder ([`ir::ir_gmres_solve`] /
//!   [`ir::ir_solve_multi`]).

pub mod blas1;
pub mod cg;
pub mod gmres;
pub mod bicgstab;
pub(crate) mod block;
pub mod ladder;
pub mod stepped;
pub mod precond;
pub mod sainv;
pub mod ir;

pub use bicgstab::{bicgstab_solve, bicgstab_solve_multi, BicgstabOpts};
pub use cg::{cg_solve, cg_solve_multi, CgOpts};
pub use gmres::{gmres_solve, gmres_solve_multi, GmresOpts};
pub use ir::{ir_gmres_solve, ir_solve, ir_solve_multi, IrGmresOpts, IrOpts};
pub use ladder::{CopyLadderOp, PrecisionSwitchable, SwitchableOp};
pub use sainv::{Precond, PrecondLadderOp, PrecondOp, SainvFactors, SainvParams};
pub use stepped::{run_stepped_multi, BlockSolver, PrecisionController, SteppedParams};

use crate::spmv::SpmvOp;

/// What the per-iteration monitor tells the solver. The stepped
/// controller returns [`MonitorCmd::Restart`] when it escalates the
/// operator's precision: the Krylov recurrences were built with the old
/// operator and must be re-anchored (CG recomputes r/p; GMRES ends the
/// inner cycle; BiCGSTAB re-initializes its shadow residual) — Alg. 3's
/// tag switch applied soundly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MonitorCmd {
    #[default]
    Continue,
    /// The operator changed: restart the solver's recurrence at the
    /// current iterate.
    Restart,
}

/// Shared outcome record for every solver run — exactly the data the
/// paper's Tables III/IV and Figs. 7/8/9 report.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    /// converged under the solver's internal criterion
    pub converged: bool,
    /// total iterations executed (inner iterations for GMRES)
    pub iters: usize,
    /// final *true* relative residual ‖b − Ax‖/‖b‖, computed with the
    /// operator handed to the solver
    pub relres: f64,
    /// per-iteration (estimated) residual norms
    pub history: Vec<f64>,
    /// iterations at which the stepped controller escalated precision,
    /// with the new tag (Alg. 3's `tag`)
    pub switches: Vec<(usize, u8)>,
    /// wall time of the solve
    pub seconds: f64,
    /// solution vector
    pub x: Vec<f64>,
    /// a non-finite value appeared (the paper's "/" rows: FP16 overflow)
    pub broke_down: bool,
}

impl SolveOutcome {
    /// The paper prints "/" when the run overflowed.
    pub fn relres_label(&self) -> String {
        if self.broke_down {
            "/".to_string()
        } else {
            format!("{:.1E}", self.relres)
        }
    }
}

/// True relative residual ‖b − A·x‖₂ / ‖b‖₂ using the given operator.
/// Built on the [`blas1`] kernels so every residual in the codebase
/// goes through the one dot/norm implementation.
pub fn true_relres(op: &dyn SpmvOp, x: &[f64], b: &[f64]) -> f64 {
    let mut r = vec![0.0; op.nrows()];
    op.apply(x, &mut r);
    // r = b − A·x
    blas1::scal(-1.0, &mut r);
    blas1::axpy(1.0, b, &mut r);
    let num = blas1::nrm2(&r);
    let den = blas1::nrm2(b);
    if den == 0.0 {
        num
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::Csr;
    use crate::spmv::fp64::Fp64Csr;

    #[test]
    fn true_relres_zero_for_exact_solution() {
        let op = Fp64Csr::new(Csr::identity(4));
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(true_relres(&op, &b, &b), 0.0);
        let x0 = vec![0.0; 4];
        assert!((true_relres(&op, &x0, &b) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn relres_label_overflow() {
        let o = SolveOutcome {
            converged: false,
            iters: 1,
            relres: f64::NAN,
            history: vec![],
            switches: vec![],
            seconds: 0.0,
            x: vec![],
            broke_down: true,
        };
        assert_eq!(o.relres_label(), "/");
    }
}
