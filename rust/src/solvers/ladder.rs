//! Format-agnostic precision ladders for the stepped controller.
//!
//! The paper's Algorithm 3 escalates through the GSE-SEM segment levels
//! of a *single* storage, but the stepped controller itself only needs
//! "an operator with numbered precision rungs" — the framing Loe et al.
//! (arXiv:2109.01232) and Carson–Khan (arXiv:2307.03914) use for
//! copy-based mixed-precision ladders. [`PrecisionSwitchable`] captures
//! that contract so `run_stepped_with` (and everything above it: the
//! CG/GMRES/BiCGSTAB monitor plumbing, the job model, the benches) is
//! generic over the ladder:
//!
//! * [`SwitchableOp`] — the paper's zero-copy GSE-SEM tag ladder
//!   (tags 1/2/3 read more segments of one encoded matrix);
//! * [`CopyLadderOp`] — the related-work baseline: two full copies of
//!   the matrix, fp32 (tag 1) → fp64 (tag 2), switching by re-pointing
//!   rather than re-reading.

use crate::formats::{Precision, ValueFormat};
use crate::sparse::csr::Csr;
use crate::spmv::fp64::Fp64Csr;
use crate::spmv::gse::GseCsr;
use crate::spmv::lowp::LowpCsr;
use crate::spmv::SpmvOp;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

/// An [`SpmvOp`] whose storage precision forms a ladder of 1-based
/// "rungs" (the paper's `tag`), raisable mid-solve through a shared
/// reference (interior mutability) so the controller can escalate from
/// inside a solver's monitor callback.
pub trait PrecisionSwitchable: SpmvOp {
    /// Number of rungs (3 for the GSE ladder, 2 for fp32→fp64).
    fn num_tags(&self) -> u8;
    /// Current rung.
    fn tag(&self) -> u8;
    /// Jump to `tag`, clamped to `[1, num_tags]`.
    fn set_tag(&self, tag: u8);
    /// Human-readable label of rung `tag` (reports / metrics).
    fn tag_label(&self, tag: u8) -> String;
}

/// An [`SpmvOp`] whose precision level can be raised mid-solve — the
/// `A_1/A_2/A_3` of Algorithm 3 over a *single* GSE-SEM storage.
pub struct SwitchableOp {
    pub m: Arc<GseCsr>,
    level: AtomicU8,
}

impl SwitchableOp {
    pub fn new(m: impl Into<Arc<GseCsr>>) -> Self {
        Self { m: m.into(), level: AtomicU8::new(1) }
    }

    pub fn level(&self) -> Precision {
        Precision::from_tag(self.level.load(Ordering::Relaxed))
    }

    pub fn set_level(&self, p: Precision) {
        self.level.store(p.tag(), Ordering::Relaxed);
    }
}

impl SpmvOp for SwitchableOp {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.m.spmv(x, y, self.level());
    }

    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        self.m.spmv_multi(x, y, nrhs, self.level());
    }

    fn nrows(&self) -> usize {
        self.m.nrows
    }

    fn ncols(&self) -> usize {
        self.m.ncols
    }

    fn format(&self) -> ValueFormat {
        ValueFormat::GseSem(self.level())
    }

    fn matrix_bytes(&self) -> usize {
        self.m.bytes_at(self.level())
    }

    fn encoded_bytes(&self) -> usize {
        // one shared encode serves every rung — the paper's storage win
        self.m.encoded_bytes()
    }

    fn set_threads(&self, threads: usize) {
        // the budget lives on the shared encode, so a retune reaches
        // every rung (and any sibling level view) at once
        self.m.threads.set(threads);
    }

    fn threads(&self) -> usize {
        self.m.threads.get()
    }
}

impl PrecisionSwitchable for SwitchableOp {
    fn num_tags(&self) -> u8 {
        Precision::LADDER.len() as u8
    }

    fn tag(&self) -> u8 {
        self.level.load(Ordering::Relaxed)
    }

    fn set_tag(&self, tag: u8) {
        self.set_level(Precision::from_tag(tag));
    }

    fn tag_label(&self, tag: u8) -> String {
        ValueFormat::GseSem(Precision::from_tag(tag)).label().to_string()
    }
}

/// Copy-based fp32→fp64 ladder — the related-work mixed-precision
/// baseline. Keeps **two full copies** of the matrix (the storage cost
/// GSE-SEM avoids): tag 1 applies the FP32 copy, tag 2 the FP64 copy.
/// The rungs are `Arc`-shared operators, so the coordinator cache can
/// hand the same copies to many stepped-copy jobs; only the tag is
/// per-solve. Both copies run the shared chunk-parallel SpMV paths, so
/// stepped solves over this ladder are an apples-to-apples contrast
/// with [`SwitchableOp`].
pub struct CopyLadderOp {
    pub lo: Arc<dyn SpmvOp>,
    pub hi: Arc<dyn SpmvOp>,
    tag: AtomicU8,
}

impl CopyLadderOp {
    /// Wrap two prebuilt rungs (e.g. cache-shared operators): `lo` is
    /// tag 1, `hi` tag 2. Dimensions must agree.
    pub fn new(lo: Arc<dyn SpmvOp>, hi: Arc<dyn SpmvOp>) -> Self {
        assert_eq!((lo.nrows(), lo.ncols()), (hi.nrows(), hi.ncols()));
        Self { lo, hi, tag: AtomicU8::new(1) }
    }

    /// Build both copies from scratch (the uncached one-shot path).
    pub fn from_csr(a: &Csr) -> Self {
        Self::with_threads(a, 1)
    }

    pub fn with_threads(a: &Csr, threads: usize) -> Self {
        Self::new(
            Arc::new(LowpCsr::<f32>::from_csr(a).with_threads(threads)),
            Arc::new(Fp64Csr::with_threads(a.clone(), threads)),
        )
    }

    fn active(&self) -> &dyn SpmvOp {
        if self.tag.load(Ordering::Relaxed) <= 1 {
            self.lo.as_ref()
        } else {
            self.hi.as_ref()
        }
    }
}

impl SpmvOp for CopyLadderOp {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.active().apply(x, y);
    }

    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        self.active().apply_multi(x, y, nrhs);
    }

    fn nrows(&self) -> usize {
        self.hi.nrows()
    }

    fn ncols(&self) -> usize {
        self.hi.ncols()
    }

    fn format(&self) -> ValueFormat {
        self.active().format()
    }

    fn matrix_bytes(&self) -> usize {
        self.active().matrix_bytes()
    }

    fn encoded_bytes(&self) -> usize {
        // the copy ladder's storage cost: both rungs stay resident
        self.lo.encoded_bytes() + self.hi.encoded_bytes()
    }

    fn set_threads(&self, threads: usize) {
        // both rungs retune so an escalation keeps the same budget
        self.lo.set_threads(threads);
        self.hi.set_threads(threads);
    }

    fn threads(&self) -> usize {
        self.active().threads()
    }
}

impl PrecisionSwitchable for CopyLadderOp {
    fn num_tags(&self) -> u8 {
        2
    }

    fn tag(&self) -> u8 {
        self.tag.load(Ordering::Relaxed)
    }

    fn set_tag(&self, tag: u8) {
        self.tag.store(tag.clamp(1, 2), Ordering::Relaxed);
    }

    fn tag_label(&self, tag: u8) -> String {
        let rung = if tag <= 1 { &self.lo } else { &self.hi };
        rung.format().label().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::fem::diffusion2d;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::spmv::max_abs_diff;

    #[test]
    fn switchable_op_levels() {
        let a = poisson2d(6, 6);
        let g = GseCsr::from_csr(&a, 8);
        let op = SwitchableOp::new(g);
        assert_eq!(op.level(), Precision::Head);
        assert_eq!(op.format(), ValueFormat::GseSem(Precision::Head));
        assert_eq!(op.num_tags(), 3);
        let b_head = op.matrix_bytes();
        let resident = op.encoded_bytes();
        op.set_level(Precision::Full);
        assert_eq!(op.level(), Precision::Full);
        assert_eq!(op.tag(), 3);
        assert!(op.matrix_bytes() > b_head);
        // zero-copy ladder: switching rungs never changes residency
        assert_eq!(op.encoded_bytes(), resident);
        assert_eq!(op.tag_label(1), "GSE-SEM(head)");
    }

    #[test]
    fn copy_ladder_switches_matrices() {
        // values that truncate in fp32 so the rungs differ numerically
        let a = diffusion2d(10, 10, 9.0, 4);
        let op = CopyLadderOp::from_csr(&a);
        assert_eq!(op.tag(), 1);
        assert_eq!(op.format(), ValueFormat::Fp32);
        assert_eq!(op.num_tags(), 2);
        let x = vec![1.0; a.ncols];
        let mut y32 = vec![0.0; a.nrows];
        op.apply(&x, &mut y32);
        let b32 = op.matrix_bytes();
        op.set_tag(2);
        assert_eq!(op.format(), ValueFormat::Fp64);
        assert!(op.matrix_bytes() > b32);
        // both copies stay resident — the storage cost GSE-SEM avoids
        assert_eq!(op.encoded_bytes(), op.lo.encoded_bytes() + op.hi.encoded_bytes());
        assert!(op.encoded_bytes() > op.hi.encoded_bytes());
        let mut y64 = vec![0.0; a.nrows];
        op.apply(&x, &mut y64);
        let mut y_ref = vec![0.0; a.nrows];
        crate::spmv::fp64::spmv(&a, &x, &mut y_ref);
        assert_eq!(y64, y_ref);
        assert!(max_abs_diff(&y32, &y_ref) > 0.0, "fp32 rung must differ");
        // clamped on both ends
        op.set_tag(9);
        assert_eq!(op.tag(), 2);
        op.set_tag(0);
        assert_eq!(op.tag(), 1);
        assert_eq!(op.tag_label(1), "FP32");
        assert_eq!(op.tag_label(2), "FP64");
    }

    #[test]
    fn copy_ladder_multi_matches_looped() {
        let a = diffusion2d(12, 12, 8.0, 7);
        let op = CopyLadderOp::from_csr(&a);
        let nrhs = 3usize;
        let x: Vec<f64> = (0..a.ncols * nrhs).map(|i| ((i % 11) as f64) - 5.0).collect();
        for tag in [1u8, 2] {
            op.set_tag(tag);
            let mut y = vec![0.0; a.nrows * nrhs];
            op.apply_multi(&x, &mut y, nrhs);
            let mut y_loop = vec![0.0; a.nrows * nrhs];
            crate::spmv::apply_multi_looped(&op, &x, &mut y_loop, nrhs);
            assert_eq!(y, y_loop, "tag={tag}");
        }
    }
}
