//! BiCGSTAB — the related-work extension solver (Zhao et al. [21] use it
//! as the inner solver of mixed-precision iterative refinement). Works on
//! asymmetric systems without GMRES's restart memory, so it is also the
//! extra ablation point for the stepped controller.

use super::blas1::{axpy, dot, nrm2};
use super::block::{
    run_fixed_block, run_fixed_block_ctl, BlockColumn, BlockCtl, ColumnExit, ColumnMonitor,
};
use super::{MonitorCmd, SolveOutcome};
use crate::spmv::SpmvOp;
use crate::util::Timer;

/// BiCGSTAB options.
#[derive(Clone, Debug)]
pub struct BicgstabOpts {
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for BicgstabOpts {
    fn default() -> Self {
        Self { tol: 1e-6, max_iters: 5000 }
    }
}

/// Solve `A x = b` with BiCGSTAB. `monitor(iter, relres)` fires per
/// iteration like the CG/GMRES hooks.
pub fn bicgstab_solve(
    op: &dyn SpmvOp,
    b: &[f64],
    opts: &BicgstabOpts,
    mut monitor: impl FnMut(usize, f64) -> MonitorCmd,
) -> SolveOutcome {
    let n = op.nrows();
    let timer = Timer::start();
    let bnorm = nrm2(b);
    if bnorm == 0.0 {
        return SolveOutcome {
            converged: true,
            iters: 0,
            relres: 0.0,
            history: vec![],
            switches: vec![],
            seconds: timer.elapsed_s(),
            x: vec![0.0; n],
            broke_down: false,
        };
    }
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut r0 = r.clone();
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut t = vec![0.0; n];
    let mut history = Vec::new();
    let mut converged = false;
    let mut broke_down = false;
    let mut iters = 0usize;

    for k in 0..opts.max_iters {
        let rho_new = dot(&r0, &r);
        if rho_new == 0.0 || !rho_new.is_finite() {
            broke_down = !rho_new.is_finite();
            break;
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        // p = r + beta (p - omega v)
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        op.apply(&p, &mut v);
        let r0v = dot(&r0, &v);
        if r0v == 0.0 || !r0v.is_finite() {
            broke_down = !r0v.is_finite();
            break;
        }
        alpha = rho / r0v;
        for i in 0..n {
            s[i] = r[i] - alpha * v[i];
        }
        let snorm = nrm2(&s);
        iters = k + 1;
        if snorm / bnorm <= opts.tol {
            axpy(alpha, &p, &mut x);
            history.push(snorm / bnorm);
            let _ = monitor(iters, snorm / bnorm);
            converged = true;
            break;
        }
        op.apply(&s, &mut t);
        let tt = dot(&t, &t);
        if tt == 0.0 || !tt.is_finite() {
            broke_down = !tt.is_finite();
            break;
        }
        omega = dot(&t, &s) / tt;
        if omega == 0.0 || !omega.is_finite() {
            broke_down = !omega.is_finite();
            break;
        }
        for i in 0..n {
            x[i] += alpha * p[i] + omega * s[i];
            r[i] = s[i] - omega * t[i];
        }
        let rel = nrm2(&r) / bnorm;
        history.push(rel);
        let cmd = monitor(iters, rel);
        if !rel.is_finite() {
            broke_down = true;
            break;
        }
        if rel <= opts.tol {
            converged = true;
            break;
        }
        if cmd == MonitorCmd::Restart {
            // operator changed: recompute the residual and restart the
            // shadow-residual recurrence at the current iterate
            op.apply(&x, &mut t);
            for i in 0..n {
                r[i] = b[i] - t[i];
            }
            // re-anchor the shadow residual and direction state
            r0.copy_from_slice(&r);
            for i in 0..n {
                p[i] = 0.0;
                v[i] = 0.0;
            }
            rho = 1.0;
            alpha = 1.0;
            omega = 1.0;
        }
    }

    let relres = super::true_relres(op, &x, b);
    SolveOutcome {
        converged,
        iters,
        relres,
        history,
        switches: vec![],
        seconds: timer.elapsed_s(),
        x,
        broke_down,
    }
}

/// Solve `A X = B` for `nrhs` right-hand sides packed column-major in
/// `bs`, running `nrhs` independent BiCGSTAB recurrences in lockstep:
/// the `A·p` and `A·s` products of all still-active columns batch into
/// **one** [`SpmvOp::apply_multi`] per round trip (columns need not be
/// on the same half-step). Each column follows the identical
/// arithmetic sequence as a standalone [`bicgstab_solve`] on that RHS,
/// so per-column outcomes are bitwise identical to single dispatch —
/// a breakdown (ρ ≈ 0, ⟨r̂₀, Ap⟩ ≈ 0, ω ≈ 0) deflates only its own
/// column while the rest of the block continues. `seconds` in each
/// outcome is the shared wall time of the block solve.
pub fn bicgstab_solve_multi(
    op: &dyn SpmvOp,
    bs: &[f64],
    nrhs: usize,
    opts: &BicgstabOpts,
) -> Vec<SolveOutcome> {
    let n = op.nrows();
    assert_eq!(op.ncols(), n, "multi-RHS BiCGSTAB requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    if nrhs == 0 {
        return Vec::new();
    }
    let cols: Vec<BicgstabColumn> = (0..nrhs)
        .map(|j| BicgstabColumn::new(&bs[j * n..(j + 1) * n], opts, ColumnMonitor::Fixed))
        .collect();
    run_fixed_block(op, cols)
}

/// [`bicgstab_solve_multi`] with per-column cancel/deadline controls:
/// triggered columns deflate mid-block (partial outcome, matching
/// [`ColumnExit`] reason) while survivors stay bitwise identical to
/// single dispatch.
pub(crate) fn bicgstab_solve_multi_ctl(
    op: &dyn SpmvOp,
    bs: &[f64],
    nrhs: usize,
    opts: &BicgstabOpts,
    ctl: &BlockCtl,
) -> (Vec<SolveOutcome>, Vec<ColumnExit>) {
    let n = op.nrows();
    assert_eq!(op.ncols(), n, "multi-RHS BiCGSTAB requires a square operator");
    assert_eq!(bs.len(), n * nrhs);
    if nrhs == 0 {
        return (Vec::new(), Vec::new());
    }
    let cols: Vec<BicgstabColumn> = (0..nrhs)
        .map(|j| BicgstabColumn::new(&bs[j * n..(j + 1) * n], opts, ColumnMonitor::Fixed))
        .collect();
    run_fixed_block_ctl(op, cols, ctl)
}

/// One BiCGSTAB right-hand side as a [`BlockColumn`] state machine.
/// Between applies it runs exactly the arithmetic of
/// [`bicgstab_solve`] with its monitor installed, so the outcome is
/// bitwise identical to a standalone monitored solve on this RHS.
pub(crate) struct BicgstabColumn<'a> {
    b: &'a [f64],
    opts: &'a BicgstabOpts,
    monitor: ColumnMonitor,
    bnorm: f64,
    x: Vec<f64>,
    r: Vec<f64>,
    r0: Vec<f64>,
    v: Vec<f64>,
    p: Vec<f64>,
    s: Vec<f64>,
    t: Vec<f64>,
    rho: f64,
    alpha: f64,
    omega: f64,
    iters: usize,
    history: Vec<f64>,
    converged: bool,
    broke_down: bool,
    state: BicgstabState,
}

enum BicgstabState {
    /// Next apply: `A · p` (first half-step).
    NeedAp,
    /// Next apply: `A · s` (stabilization half-step).
    NeedAs,
    /// Next apply: `A · x` (re-anchoring after a precision switch).
    NeedRestart,
    Done,
}

impl<'a> BicgstabColumn<'a> {
    pub(crate) fn new(b: &'a [f64], opts: &'a BicgstabOpts, monitor: ColumnMonitor) -> Self {
        let n = b.len();
        let bnorm = nrm2(b);
        let mut col = Self {
            b,
            opts,
            monitor,
            bnorm,
            x: vec![0.0; n],
            r: b.to_vec(),
            r0: b.to_vec(),
            v: vec![0.0; n],
            p: vec![0.0; n],
            s: vec![0.0; n],
            t: vec![0.0; n],
            rho: 1.0,
            alpha: 1.0,
            omega: 1.0,
            iters: 0,
            history: Vec::new(),
            converged: false,
            broke_down: false,
            state: BicgstabState::Done,
        };
        if bnorm == 0.0 {
            col.converged = true;
            return col;
        }
        if opts.max_iters == 0 {
            return col;
        }
        col.begin_iteration();
        col
    }

    /// The head of one [`bicgstab_solve`] loop pass: the ρ update and
    /// the new search direction, up to the `A·p` product.
    fn begin_iteration(&mut self) {
        let rho_new = dot(&self.r0, &self.r);
        if rho_new == 0.0 || !rho_new.is_finite() {
            self.broke_down = !rho_new.is_finite();
            self.state = BicgstabState::Done;
            return;
        }
        let beta = (rho_new / self.rho) * (self.alpha / self.omega);
        self.rho = rho_new;
        for i in 0..self.p.len() {
            self.p[i] = self.r[i] + beta * (self.p[i] - self.omega * self.v[i]);
        }
        self.state = BicgstabState::NeedAp;
    }

    fn absorb_ap(&mut self, y: &[f64]) {
        self.v.copy_from_slice(y);
        let r0v = dot(&self.r0, &self.v);
        if r0v == 0.0 || !r0v.is_finite() {
            self.broke_down = !r0v.is_finite();
            self.state = BicgstabState::Done;
            return;
        }
        self.alpha = self.rho / r0v;
        for i in 0..self.s.len() {
            self.s[i] = self.r[i] - self.alpha * self.v[i];
        }
        let snorm = nrm2(&self.s);
        self.iters += 1;
        if snorm / self.bnorm <= self.opts.tol {
            axpy(self.alpha, &self.p, &mut self.x);
            self.history.push(snorm / self.bnorm);
            let _ = self.monitor.observe(self.iters, snorm / self.bnorm);
            self.converged = true;
            self.state = BicgstabState::Done;
            return;
        }
        self.state = BicgstabState::NeedAs;
    }

    fn absorb_as(&mut self, y: &[f64]) {
        self.t.copy_from_slice(y);
        let tt = dot(&self.t, &self.t);
        if tt == 0.0 || !tt.is_finite() {
            self.broke_down = !tt.is_finite();
            self.state = BicgstabState::Done;
            return;
        }
        self.omega = dot(&self.t, &self.s) / tt;
        if self.omega == 0.0 || !self.omega.is_finite() {
            self.broke_down = !self.omega.is_finite();
            self.state = BicgstabState::Done;
            return;
        }
        for i in 0..self.x.len() {
            self.x[i] += self.alpha * self.p[i] + self.omega * self.s[i];
            self.r[i] = self.s[i] - self.omega * self.t[i];
        }
        let rel = nrm2(&self.r) / self.bnorm;
        self.history.push(rel);
        let cmd = self.monitor.observe(self.iters, rel);
        if !rel.is_finite() {
            self.broke_down = true;
            self.state = BicgstabState::Done;
            return;
        }
        if rel <= self.opts.tol {
            self.converged = true;
            self.state = BicgstabState::Done;
            return;
        }
        if cmd == MonitorCmd::Restart {
            self.state = BicgstabState::NeedRestart;
            return;
        }
        self.next_iteration();
    }

    fn absorb_restart(&mut self, ax: &[f64]) {
        // re-anchor the shadow residual and direction state at the
        // current iterate, as bicgstab_solve's Restart branch does
        let b = self.b;
        for i in 0..b.len() {
            self.r[i] = b[i] - ax[i];
        }
        self.r0.copy_from_slice(&self.r);
        for i in 0..self.p.len() {
            self.p[i] = 0.0;
            self.v[i] = 0.0;
        }
        self.rho = 1.0;
        self.alpha = 1.0;
        self.omega = 1.0;
        self.next_iteration();
    }

    fn next_iteration(&mut self) {
        if self.iters >= self.opts.max_iters {
            self.state = BicgstabState::Done;
        } else {
            self.begin_iteration();
        }
    }
}

impl BlockColumn for BicgstabColumn<'_> {
    fn active(&self) -> bool {
        !matches!(self.state, BicgstabState::Done)
    }

    fn tag(&self) -> u8 {
        self.monitor.tag()
    }

    fn input(&self) -> &[f64] {
        match self.state {
            BicgstabState::NeedAp => &self.p,
            BicgstabState::NeedAs => &self.s,
            BicgstabState::NeedRestart => &self.x,
            BicgstabState::Done => unreachable!("inactive column asked for input"),
        }
    }

    fn absorb(&mut self, y: &[f64]) {
        match self.state {
            BicgstabState::NeedAp => self.absorb_ap(y),
            BicgstabState::NeedAs => self.absorb_as(y),
            BicgstabState::NeedRestart => self.absorb_restart(y),
            BicgstabState::Done => unreachable!("inactive column fed a result"),
        }
    }

    fn deflate(&mut self) {
        self.state = BicgstabState::Done;
    }

    fn finish(mut self, op: &dyn SpmvOp, seconds: f64) -> SolveOutcome {
        let relres = super::true_relres(op, &self.x, self.b);
        SolveOutcome {
            converged: self.converged,
            iters: self.iters,
            relres,
            history: self.history,
            switches: self.monitor.take_switches(),
            seconds,
            x: self.x,
            broke_down: self.broke_down,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::convdiff::convdiff2d;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::spmv::fp64::Fp64Csr;

    fn rhs_for_ones(op: &dyn SpmvOp) -> Vec<f64> {
        let ones = vec![1.0; op.ncols()];
        let mut b = vec![0.0; op.nrows()];
        op.apply(&ones, &mut b);
        b
    }

    #[test]
    fn converges_on_spd() {
        let op = Fp64Csr::new(poisson2d(14, 14));
        let b = rhs_for_ones(&op);
        let out = bicgstab_solve(&op, &b, &BicgstabOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-5);
    }

    #[test]
    fn converges_on_asymmetric() {
        let op = Fp64Csr::new(convdiff2d(14, 14, 12.0, 4.0));
        let b = rhs_for_ones(&op);
        let out = bicgstab_solve(&op, &b, &BicgstabOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged, "relres {}", out.relres);
        for &xi in &out.x {
            assert!((xi - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn multi_rhs_matches_single_solves_bitwise() {
        let op = Fp64Csr::new(convdiff2d(10, 10, 8.0, 2.0));
        let n = op.nrows();
        let nrhs = 3usize;
        let mut bs = vec![0.0; n * nrhs];
        bs[0..n].copy_from_slice(&rhs_for_ones(&op));
        // column 1 stays zero (trivial); column 2 is a rough ramp
        for (i, v) in bs[2 * n..3 * n].iter_mut().enumerate() {
            *v = (i % 5) as f64 - 2.0;
        }
        let opts = BicgstabOpts::default();
        let outs = bicgstab_solve_multi(&op, &bs, nrhs, &opts);
        assert_eq!(outs.len(), nrhs);
        for (j, multi) in outs.iter().enumerate() {
            let b = &bs[j * n..(j + 1) * n];
            let single = bicgstab_solve(&op, b, &opts, |_, _| MonitorCmd::Continue);
            assert_eq!(multi.converged, single.converged, "rhs {j}");
            assert_eq!(multi.iters, single.iters, "rhs {j}");
            assert_eq!(multi.x, single.x, "rhs {j}");
            assert_eq!(multi.history, single.history, "rhs {j}");
            assert_eq!(multi.relres.to_bits(), single.relres.to_bits(), "rhs {j}");
        }
        assert!(outs[1].converged);
        assert_eq!(outs[1].iters, 0);
    }

    #[test]
    fn max_iters_respected() {
        let op = Fp64Csr::new(convdiff2d(20, 20, 40.0, 20.0));
        let b = rhs_for_ones(&op);
        let out = bicgstab_solve(
            &op,
            &b,
            &BicgstabOpts { tol: 1e-15, max_iters: 2 },
            |_, _| MonitorCmd::Continue,
        );
        assert!(out.iters <= 2);
    }
}
