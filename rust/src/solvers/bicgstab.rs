//! BiCGSTAB — the related-work extension solver (Zhao et al. [21] use it
//! as the inner solver of mixed-precision iterative refinement). Works on
//! asymmetric systems without GMRES's restart memory, so it is also the
//! extra ablation point for the stepped controller.

use super::blas1::{axpy, dot, nrm2};
use super::{MonitorCmd, SolveOutcome};
use crate::spmv::SpmvOp;
use crate::util::Timer;

/// BiCGSTAB options.
#[derive(Clone, Debug)]
pub struct BicgstabOpts {
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for BicgstabOpts {
    fn default() -> Self {
        Self { tol: 1e-6, max_iters: 5000 }
    }
}

/// Solve `A x = b` with BiCGSTAB. `monitor(iter, relres)` fires per
/// iteration like the CG/GMRES hooks.
pub fn bicgstab_solve(
    op: &dyn SpmvOp,
    b: &[f64],
    opts: &BicgstabOpts,
    mut monitor: impl FnMut(usize, f64) -> MonitorCmd,
) -> SolveOutcome {
    let n = op.nrows();
    let timer = Timer::start();
    let bnorm = nrm2(b);
    if bnorm == 0.0 {
        return SolveOutcome {
            converged: true,
            iters: 0,
            relres: 0.0,
            history: vec![],
            switches: vec![],
            seconds: timer.elapsed_s(),
            x: vec![0.0; n],
            broke_down: false,
        };
    }
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut r0 = r.clone();
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut t = vec![0.0; n];
    let mut history = Vec::new();
    let mut converged = false;
    let mut broke_down = false;
    let mut iters = 0usize;

    for k in 0..opts.max_iters {
        let rho_new = dot(&r0, &r);
        if rho_new == 0.0 || !rho_new.is_finite() {
            broke_down = !rho_new.is_finite();
            break;
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        // p = r + beta (p - omega v)
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        op.apply(&p, &mut v);
        let r0v = dot(&r0, &v);
        if r0v == 0.0 || !r0v.is_finite() {
            broke_down = !r0v.is_finite();
            break;
        }
        alpha = rho / r0v;
        for i in 0..n {
            s[i] = r[i] - alpha * v[i];
        }
        let snorm = nrm2(&s);
        iters = k + 1;
        if snorm / bnorm <= opts.tol {
            axpy(alpha, &p, &mut x);
            history.push(snorm / bnorm);
            let _ = monitor(iters, snorm / bnorm);
            converged = true;
            break;
        }
        op.apply(&s, &mut t);
        let tt = dot(&t, &t);
        if tt == 0.0 || !tt.is_finite() {
            broke_down = !tt.is_finite();
            break;
        }
        omega = dot(&t, &s) / tt;
        if omega == 0.0 || !omega.is_finite() {
            broke_down = !omega.is_finite();
            break;
        }
        for i in 0..n {
            x[i] += alpha * p[i] + omega * s[i];
            r[i] = s[i] - omega * t[i];
        }
        let rel = nrm2(&r) / bnorm;
        history.push(rel);
        let cmd = monitor(iters, rel);
        if !rel.is_finite() {
            broke_down = true;
            break;
        }
        if rel <= opts.tol {
            converged = true;
            break;
        }
        if cmd == MonitorCmd::Restart {
            // operator changed: recompute the residual and restart the
            // shadow-residual recurrence at the current iterate
            op.apply(&x, &mut t);
            for i in 0..n {
                r[i] = b[i] - t[i];
            }
            // re-anchor the shadow residual and direction state
            r0.copy_from_slice(&r);
            for i in 0..n {
                p[i] = 0.0;
                v[i] = 0.0;
            }
            rho = 1.0;
            alpha = 1.0;
            omega = 1.0;
        }
    }

    let relres = super::true_relres(op, &x, b);
    SolveOutcome {
        converged,
        iters,
        relres,
        history,
        switches: vec![],
        seconds: timer.elapsed_s(),
        x,
        broke_down,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::convdiff::convdiff2d;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::spmv::fp64::Fp64Csr;

    fn rhs_for_ones(op: &dyn SpmvOp) -> Vec<f64> {
        let ones = vec![1.0; op.ncols()];
        let mut b = vec![0.0; op.nrows()];
        op.apply(&ones, &mut b);
        b
    }

    #[test]
    fn converges_on_spd() {
        let op = Fp64Csr::new(poisson2d(14, 14));
        let b = rhs_for_ones(&op);
        let out = bicgstab_solve(&op, &b, &BicgstabOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged, "relres {}", out.relres);
        assert!(out.relres < 1e-5);
    }

    #[test]
    fn converges_on_asymmetric() {
        let op = Fp64Csr::new(convdiff2d(14, 14, 12.0, 4.0));
        let b = rhs_for_ones(&op);
        let out = bicgstab_solve(&op, &b, &BicgstabOpts::default(), |_, _| MonitorCmd::Continue);
        assert!(out.converged, "relres {}", out.relres);
        for &xi in &out.x {
            assert!((xi - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn max_iters_respected() {
        let op = Fp64Csr::new(convdiff2d(20, 20, 40.0, 20.0));
        let b = rhs_for_ones(&op);
        let out = bicgstab_solve(
            &op,
            &b,
            &BicgstabOpts { tol: 1e-15, max_iters: 2 },
            |_, _| MonitorCmd::Continue,
        );
        assert!(out.iters <= 2);
    }
}
