//! Factored sparse approximate inverse (SAINV) preconditioning with the
//! factors resident in GSE-SEM storage (Carson & Khan, arXiv:2202.10204
//! and the adaptive-precision follow-up arXiv:2307.03914).
//!
//! A right-looking biconjugation of `A` produces `Z`, `W` and a diagonal
//! `D` with `Wᵀ A Z ≈ D`, so `A⁻¹ ≈ Z·D⁻¹·Wᵀ`. Off-diagonal factor
//! entries below `drop_tol × max|column|` are dropped during the
//! biconjugation (the SAINV sparsification), which keeps the factors as
//! sparse as the matrix itself on the generator corpus. Both factors are
//! encoded as [`GseCsr`], so **applying `M⁻¹` is two fused multi-RHS
//! SpMVs** plus a diagonal scale — it runs through the same register
//! tiles, [`crate::spmv::ThreadBudget`] and byte accounting as any other
//! operator, and can be applied at any rung of the precision ladder
//! ([`Precision::Head`] / [`Precision::HeadTail1`] / [`Precision::Full`])
//! of one shared encode.
//!
//! Three layers live here:
//!
//! * [`SainvFactors`] — the encoded factors, built fallibly (a zero or
//!   non-finite pivot means the matrix is singular/indefinite beyond
//!   what the drop tolerance can absorb and construction fails typed);
//! * [`PrecondOp`] — the runtime preconditioner chosen by a
//!   [`Precond`] spec: identity, Jacobi, or SAINV;
//! * [`PrecondLadderOp`] — the left-preconditioned operator
//!   `x ↦ M⁻¹(A·x)` as a [`PrecisionSwitchable`] ladder rung, which is
//!   what the GMRES-IR inner solver iterates on
//!   (see [`crate::solvers::ir`]).

use crate::formats::{Precision, ValueFormat};
use crate::solvers::ladder::PrecisionSwitchable;
use crate::solvers::precond::Jacobi;
use crate::sparse::csr::Csr;
use crate::spmv::gse::GseCsr;
use crate::spmv::SpmvOp;
use crate::util::error::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

/// Pivots smaller than this (in magnitude) abort the factorization —
/// the column is numerically dependent on its predecessors, so the
/// approximate inverse would be garbage.
const PIVOT_FLOOR: f64 = 1e-300;

/// SAINV construction parameters — together with the matrix digest they
/// key the factors in the coordinator registry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SainvParams {
    /// Relative drop tolerance: factor entries below
    /// `drop_tol × max|column entry|` are discarded (diagonals are
    /// always kept). `0.0` keeps everything (the exact factorization up
    /// to rounding).
    pub drop_tol: f64,
    /// Shared-exponent group count of the GSE encode of both factors.
    pub k: usize,
}

impl Default for SainvParams {
    fn default() -> Self {
        Self { drop_tol: 0.1, k: 8 }
    }
}

/// Hashable fingerprint of [`SainvParams`] (`drop_tol` via its bit
/// pattern), used in registry/grouping keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SainvParamsKey {
    /// `drop_tol.to_bits()`.
    pub(crate) drop_bits: u64,
    /// group count, verbatim.
    pub(crate) k: usize,
}

impl SainvParamsKey {
    /// Reconstruct the parameters this key fingerprints (spill decode).
    pub(crate) fn params(self) -> SainvParams {
        SainvParams { drop_tol: f64::from_bits(self.drop_bits), k: self.k }
    }
}

impl From<SainvParams> for SainvParamsKey {
    fn from(p: SainvParams) -> Self {
        Self { drop_bits: p.drop_tol.to_bits(), k: p.k }
    }
}

/// Sparse accumulator: a dense value array with an epoch-stamped mark
/// array and a touched list, so clearing between columns is O(touched).
struct Accum {
    val: Vec<f64>,
    mark: Vec<u32>,
    touched: Vec<u32>,
    epoch: u32,
}

impl Accum {
    fn new(n: usize) -> Self {
        Self { val: vec![0.0; n], mark: vec![0; n], touched: Vec::new(), epoch: 0 }
    }

    fn begin(&mut self) {
        self.epoch += 1;
        self.touched.clear();
    }

    fn add(&mut self, i: usize, v: f64) {
        if self.mark[i] != self.epoch {
            self.mark[i] = self.epoch;
            self.val[i] = 0.0;
            self.touched.push(i as u32);
        }
        self.val[i] += v;
    }

    fn get(&self, i: usize) -> f64 {
        if self.mark[i] == self.epoch {
            self.val[i]
        } else {
            0.0
        }
    }

    /// All touched non-zero entries, index-sorted.
    fn gather(&mut self) -> SparseVec {
        self.touched.sort_unstable();
        let mut out = SparseVec::default();
        for &i in &self.touched {
            let v = self.val[i as usize];
            if v != 0.0 {
                out.idx.push(i);
                out.val.push(v);
            }
        }
        out
    }

    /// Touched entries surviving the relative drop tolerance
    /// (`keep` — the diagonal — always survives), index-sorted.
    fn gather_dropped(&mut self, keep: usize, drop_tol: f64) -> SparseVec {
        self.touched.sort_unstable();
        let mut amax = 0.0f64;
        for &i in &self.touched {
            amax = amax.max(self.val[i as usize].abs());
        }
        let floor = drop_tol * amax;
        let mut out = SparseVec::default();
        for &i in &self.touched {
            let v = self.val[i as usize];
            if i as usize == keep || (v != 0.0 && v.abs() >= floor) {
                out.idx.push(i);
                out.val.push(v);
            }
        }
        out
    }
}

/// One factor column/row in index-sorted sparse form.
#[derive(Default)]
struct SparseVec {
    idx: Vec<u32>,
    val: Vec<f64>,
}

impl SparseVec {
    fn iter(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.idx.iter().zip(&self.val).map(|(&i, &v)| (i as usize, v))
    }
}

/// Assemble a CSR whose row `j` is `rows[j]` (entries already sorted).
fn csr_from_rows(n: usize, rows: &[SparseVec]) -> Csr {
    let mut rowptr = Vec::with_capacity(n + 1);
    rowptr.push(0usize);
    let mut colidx = Vec::new();
    let mut vals = Vec::new();
    for r in rows {
        colidx.extend_from_slice(&r.idx);
        vals.extend_from_slice(&r.val);
        rowptr.push(colidx.len());
    }
    Csr { nrows: n, ncols: n, rowptr, colidx, vals }
}

/// Assemble a CSR whose **column** `j` is `cols[j]`: counting sort by
/// row; iterating `j` ascending keeps each row's columns sorted.
fn csr_from_cols(n: usize, cols: &[SparseVec]) -> Csr {
    let mut counts = vec![0usize; n + 1];
    for c in cols {
        for &i in &c.idx {
            counts[i as usize + 1] += 1;
        }
    }
    for i in 0..n {
        counts[i + 1] += counts[i];
    }
    let rowptr = counts.clone();
    let nnz = rowptr[n];
    let mut colidx = vec![0u32; nnz];
    let mut vals = vec![0.0f64; nnz];
    let mut next = rowptr.clone();
    for (j, c) in cols.iter().enumerate() {
        for (i, v) in c.iter() {
            let slot = next[i];
            next[i] += 1;
            colidx[slot] = j as u32;
            vals[slot] = v;
        }
    }
    Csr { nrows: n, ncols: n, rowptr, colidx, vals }
}

/// The factored sparse approximate inverse `A⁻¹ ≈ Z·D⁻¹·Wᵀ`, with `Z`
/// and `Wᵀ` resident as GSE-SEM encodes so `M⁻¹` applies at any ladder
/// rung. Registry-cacheable (keyed by matrix digest ×
/// [`SainvParamsKey`]), LRU-evictable and spillable like any operator.
#[derive(Clone)]
pub struct SainvFactors {
    z: Arc<GseCsr>,
    wt: Arc<GseCsr>,
    inv_d: Vec<f64>,
    params: SainvParams,
}

impl SainvFactors {
    /// Run the drop-tolerance biconjugation and encode the factors.
    ///
    /// Fails typed when the matrix is not square or a pivot
    /// `d_j = ⟨w_j, A z_j⟩` is (near-)zero or non-finite — a singular
    /// or too-indefinite matrix for this drop tolerance.
    pub fn build(a: &Csr, params: SainvParams) -> Result<Self> {
        let n = a.nrows;
        if n != a.ncols {
            bail!("sainv requires a square matrix, got {}x{}", a.nrows, a.ncols);
        }
        if !params.drop_tol.is_finite() || params.drop_tol < 0.0 {
            bail!("sainv drop_tol must be finite and >= 0, got {}", params.drop_tol);
        }
        let at = a.transpose();
        let mut zs: Vec<SparseVec> = Vec::with_capacity(n);
        let mut ws: Vec<SparseVec> = Vec::with_capacity(n);
        // u_i = A·z_i and v_i = Aᵀ·w_i, kept so later columns
        // biconjugate against finalized ones with sparse dots only
        let mut us: Vec<SparseVec> = Vec::with_capacity(n);
        let mut vs: Vec<SparseVec> = Vec::with_capacity(n);
        let mut inv_d = vec![0.0f64; n];
        let mut z_acc = Accum::new(n);
        let mut w_acc = Accum::new(n);
        let mut u_acc = Accum::new(n);
        let mut v_acc = Accum::new(n);
        for j in 0..n {
            z_acc.begin();
            w_acc.begin();
            z_acc.add(j, 1.0);
            w_acc.add(j, 1.0);
            for i in 0..j {
                // z_j ← z_j − (⟨v_i, z_j⟩/d_i)·z_i
                let mut dot = 0.0;
                for (idx, v) in vs[i].iter() {
                    dot += v * z_acc.get(idx);
                }
                if dot != 0.0 {
                    let alpha = dot * inv_d[i];
                    for (idx, v) in zs[i].iter() {
                        z_acc.add(idx, -alpha * v);
                    }
                }
                // w_j ← w_j − (⟨u_i, w_j⟩/d_i)·w_i
                let mut dot = 0.0;
                for (idx, v) in us[i].iter() {
                    dot += v * w_acc.get(idx);
                }
                if dot != 0.0 {
                    let beta = dot * inv_d[i];
                    for (idx, v) in ws[i].iter() {
                        w_acc.add(idx, -beta * v);
                    }
                }
            }
            let zj = z_acc.gather_dropped(j, params.drop_tol);
            let wj = w_acc.gather_dropped(j, params.drop_tol);
            // u_j = A·z_j: column c of A is row c of Aᵀ
            u_acc.begin();
            for (c, x) in zj.iter() {
                let (rows, avals) = at.row(c);
                for (&r, &av) in rows.iter().zip(avals) {
                    u_acc.add(r as usize, x * av);
                }
            }
            // v_j = Aᵀ·w_j: scatter row c of A
            v_acc.begin();
            for (c, x) in wj.iter() {
                let (cols, avals) = a.row(c);
                for (&cc, &av) in cols.iter().zip(avals) {
                    v_acc.add(cc as usize, x * av);
                }
            }
            let mut d = 0.0;
            for (idx, x) in wj.iter() {
                d += x * u_acc.get(idx);
            }
            if !d.is_finite() || d.abs() < PIVOT_FLOOR {
                bail!(
                    "sainv breakdown at column {j}: pivot {d:e} \
                     (singular or indefinite beyond drop_tol {})",
                    params.drop_tol
                );
            }
            inv_d[j] = 1.0 / d;
            us.push(u_acc.gather());
            vs.push(v_acc.gather());
            zs.push(zj);
            ws.push(wj);
        }
        let z_csr = csr_from_cols(n, &zs);
        let wt_csr = csr_from_rows(n, &ws);
        Ok(Self::from_parts(
            GseCsr::from_csr(&z_csr, params.k),
            GseCsr::from_csr(&wt_csr, params.k),
            inv_d,
            params,
        ))
    }

    /// Reassemble factors from already-encoded parts (spill restore).
    pub(crate) fn from_parts(z: GseCsr, wt: GseCsr, inv_d: Vec<f64>, params: SainvParams) -> Self {
        assert_eq!(z.nrows, inv_d.len());
        assert_eq!(wt.nrows, inv_d.len());
        Self { z: Arc::new(z), wt: Arc::new(wt), inv_d, params }
    }

    /// Problem size `n` (the factors are square `n × n`).
    pub fn nrows(&self) -> usize {
        self.inv_d.len()
    }

    /// The encoded `Z` factor.
    pub fn z(&self) -> &Arc<GseCsr> {
        &self.z
    }

    /// The encoded `Wᵀ` factor.
    pub fn wt(&self) -> &Arc<GseCsr> {
        &self.wt
    }

    /// `1/d_j` pivot reciprocals.
    pub fn inv_d(&self) -> &[f64] {
        &self.inv_d
    }

    /// Construction parameters (cache-key half).
    pub fn params(&self) -> SainvParams {
        self.params
    }

    /// `y ← M⁻¹·r = Z·(D⁻¹·(Wᵀ·r))` at one GSE precision rung.
    pub fn apply(&self, r: &[f64], y: &mut [f64], level: Precision) {
        let n = self.inv_d.len();
        assert_eq!(r.len(), n);
        assert_eq!(y.len(), n);
        let mut t = vec![0.0f64; n];
        self.wt.spmv(r, &mut t, level);
        for (ti, di) in t.iter_mut().zip(&self.inv_d) {
            *ti *= di;
        }
        self.z.spmv(&t, y, level);
    }

    /// Fused multi-RHS `M⁻¹` over `nrhs` column-major packed vectors —
    /// two fused SpMVs plus a per-column diagonal scale, bit-for-bit
    /// identical per column to looped [`SainvFactors::apply`].
    pub fn apply_multi(&self, rs: &[f64], ys: &mut [f64], nrhs: usize, level: Precision) {
        let n = self.inv_d.len();
        assert_eq!(rs.len(), n * nrhs);
        assert_eq!(ys.len(), n * nrhs);
        let mut t = vec![0.0f64; n * nrhs];
        self.wt.spmv_multi(rs, &mut t, nrhs, level);
        for col in t.chunks_exact_mut(n) {
            for (ti, di) in col.iter_mut().zip(&self.inv_d) {
                *ti *= di;
            }
        }
        self.z.spmv_multi(&t, ys, nrhs, level);
    }

    /// Resident bytes of both encodes plus the pivot vector — what the
    /// registry budget ledger charges for cached factors.
    pub fn encoded_bytes(&self) -> usize {
        self.z.encoded_bytes() + self.wt.encoded_bytes() + self.inv_d.len() * 8
    }

    /// Per-apply matrix traffic at one rung (roofline input).
    pub fn bytes_at(&self, level: Precision) -> usize {
        self.z.bytes_at(level) + self.wt.bytes_at(level) + self.inv_d.len() * 8
    }

    /// Retune both factor encodes' worker counts (see
    /// [`crate::spmv::ThreadBudget`]); bitwise-neutral like any retune.
    pub fn set_threads(&self, threads: usize) {
        self.z.threads.set(threads);
        self.wt.threads.set(threads);
    }

    /// Current worker count of the factor applies.
    pub fn threads(&self) -> usize {
        self.z.threads.get()
    }
}

/// Which preconditioner a solve request asks for — the spec half,
/// carried by `SolveRequest` / `SolveSpec` and fingerprinted into the
/// intake group key (preconditioning is a batching axis: only
/// same-preconditioner requests merge).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Precond {
    /// Unpreconditioned (the default — every pre-existing path).
    #[default]
    None,
    /// Inverse-diagonal scaling ([`Jacobi`]); for CG it fills
    /// `CgOpts::inv_diag`, for IR it is applied between the SpMVs.
    Jacobi,
    /// Drop-tolerance SAINV factors, registry-cached per digest ×
    /// params. Requires the IR format (`FormatChoice::Ir`).
    Sainv(SainvParams),
}

/// Hashable fingerprint of a [`Precond`] for grouping/registry keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrecondKey {
    /// no preconditioner
    None,
    /// Jacobi scaling
    Jacobi,
    /// SAINV with these parameters
    Sainv(SainvParamsKey),
}

impl From<&Precond> for PrecondKey {
    fn from(p: &Precond) -> Self {
        match p {
            Precond::None => PrecondKey::None,
            Precond::Jacobi => PrecondKey::Jacobi,
            Precond::Sainv(sp) => PrecondKey::Sainv((*sp).into()),
        }
    }
}

/// A built, applicable preconditioner — what the solvers consume.
/// Cloning shares the underlying factors.
#[derive(Clone, Default)]
pub enum PrecondOp {
    /// identity (no preconditioning)
    #[default]
    None,
    /// inverse-diagonal scale
    Jacobi(Arc<Jacobi>),
    /// SAINV factors (two fused SpMVs + diagonal scale per apply)
    Sainv(Arc<SainvFactors>),
}

impl PrecondOp {
    /// Build the operator for a spec against a matrix — the uncached
    /// one-shot path (the registry-backed path lives in
    /// `coordinator::registry::MatrixRegistry::sainv`).
    pub fn for_spec(spec: &Precond, a: &Csr) -> Result<Self> {
        Ok(match spec {
            Precond::None => PrecondOp::None,
            Precond::Jacobi => PrecondOp::Jacobi(Arc::new(Jacobi::from_csr(a))),
            Precond::Sainv(p) => PrecondOp::Sainv(Arc::new(SainvFactors::build(a, *p)?)),
        })
    }

    /// `y ← M⁻¹·r` at a ladder rung (`None` copies, `Jacobi` scales —
    /// both rung-independent; SAINV reads its encodes at `level`).
    pub fn apply_level(&self, r: &[f64], y: &mut [f64], level: Precision) {
        match self {
            PrecondOp::None => y.copy_from_slice(r),
            PrecondOp::Jacobi(j) => j.apply(r, y),
            PrecondOp::Sainv(f) => f.apply(r, y, level),
        }
    }

    /// Fused multi-RHS `M⁻¹` over column-major packed vectors,
    /// bit-for-bit identical per column to looped
    /// [`PrecondOp::apply_level`].
    pub fn apply_multi_level(&self, rs: &[f64], ys: &mut [f64], nrhs: usize, level: Precision) {
        match self {
            PrecondOp::None => ys.copy_from_slice(rs),
            PrecondOp::Jacobi(j) => {
                let n = j.inv_diag.len();
                for (rcol, ycol) in rs.chunks_exact(n).zip(ys.chunks_exact_mut(n)).take(nrhs) {
                    j.apply(rcol, ycol);
                }
            }
            PrecondOp::Sainv(f) => f.apply_multi(rs, ys, nrhs, level),
        }
    }

    /// Resident bytes of the preconditioner (0 for `None`).
    pub fn encoded_bytes(&self) -> usize {
        match self {
            PrecondOp::None => 0,
            PrecondOp::Jacobi(j) => j.inv_diag.len() * 8,
            PrecondOp::Sainv(f) => f.encoded_bytes(),
        }
    }

    /// Per-apply traffic at a rung (roofline input; 0 for `None`).
    pub fn bytes_at(&self, level: Precision) -> usize {
        match self {
            PrecondOp::None => 0,
            PrecondOp::Jacobi(j) => j.inv_diag.len() * 8,
            PrecondOp::Sainv(f) => f.bytes_at(level),
        }
    }

    /// Retune any parallel applies the preconditioner owns.
    pub fn set_threads(&self, threads: usize) {
        if let PrecondOp::Sainv(f) = self {
            f.set_threads(threads);
        }
    }

    /// Label suffix for result/metrics reporting: `""`, `"(jacobi)"`
    /// or `"(sainv)"`.
    pub fn label_suffix(&self) -> &'static str {
        match self {
            PrecondOp::None => "",
            PrecondOp::Jacobi(_) => "(jacobi)",
            PrecondOp::Sainv(_) => "(sainv)",
        }
    }
}

/// The left-preconditioned ladder operator `x ↦ M⁻¹(A·x)` over one
/// shared GSE encode of `A` — the system GMRES-IR's inner solver
/// iterates on. Both the matrix apply and (for SAINV) the
/// preconditioner apply read their encodes at the current rung, so one
/// `set_tag` moves the whole preconditioned product down or up the
/// ladder (arXiv:2307.03914's adaptive-precision preconditioning).
pub struct PrecondLadderOp {
    a: Arc<GseCsr>,
    m: PrecondOp,
    level: AtomicU8,
}

impl PrecondLadderOp {
    /// Wrap a shared encode and a built preconditioner; dimensions must
    /// agree. Starts at rung 1 (head) like [`super::SwitchableOp`].
    pub fn new(a: Arc<GseCsr>, m: PrecondOp) -> Self {
        match &m {
            PrecondOp::None => {}
            PrecondOp::Jacobi(j) => assert_eq!(j.inv_diag.len(), a.nrows),
            PrecondOp::Sainv(f) => assert_eq!(f.nrows(), a.nrows),
        }
        Self { a, m, level: AtomicU8::new(1) }
    }

    /// Current precision rung of both the matrix and `M⁻¹` applies.
    pub fn level(&self) -> Precision {
        Precision::from_tag(self.level.load(Ordering::Relaxed))
    }

    /// Move both applies to `p`'s rung.
    pub fn set_level(&self, p: Precision) {
        self.level.store(p.tag(), Ordering::Relaxed);
    }

    /// The wrapped preconditioner.
    pub fn precond(&self) -> &PrecondOp {
        &self.m
    }
}

impl SpmvOp for PrecondLadderOp {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let level = self.level();
        let mut t = vec![0.0f64; self.a.nrows];
        self.a.spmv(x, &mut t, level);
        self.m.apply_level(&t, y, level);
    }

    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        let level = self.level();
        let mut t = vec![0.0f64; self.a.nrows * nrhs];
        self.a.spmv_multi(x, &mut t, nrhs, level);
        self.m.apply_multi_level(&t, y, nrhs, level);
    }

    fn nrows(&self) -> usize {
        self.a.nrows
    }

    fn ncols(&self) -> usize {
        self.a.ncols
    }

    fn format(&self) -> ValueFormat {
        ValueFormat::GseSem(self.level())
    }

    fn matrix_bytes(&self) -> usize {
        self.a.bytes_at(self.level()) + self.m.bytes_at(self.level())
    }

    fn encoded_bytes(&self) -> usize {
        // one shared encode of A serves every rung; the preconditioner
        // adds its own resident factors
        self.a.encoded_bytes() + self.m.encoded_bytes()
    }

    fn set_threads(&self, threads: usize) {
        self.a.threads.set(threads);
        self.m.set_threads(threads);
    }

    fn threads(&self) -> usize {
        self.a.threads.get()
    }
}

impl PrecisionSwitchable for PrecondLadderOp {
    fn num_tags(&self) -> u8 {
        Precision::LADDER.len() as u8
    }

    fn tag(&self) -> u8 {
        self.level.load(Ordering::Relaxed)
    }

    fn set_tag(&self, tag: u8) {
        self.set_level(Precision::from_tag(tag));
    }

    fn tag_label(&self, tag: u8) -> String {
        format!(
            "{}{}",
            ValueFormat::GseSem(Precision::from_tag(tag)).label(),
            self.m.label_suffix()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::util::Prng;

    fn scaled_identity(n: usize) -> Csr {
        let mut a = Csr::identity(n);
        for (i, v) in a.vals.iter_mut().enumerate() {
            // powers of two: exact in every GSE rung
            *v = f64::powi(2.0, (i % 3) as i32 + 1);
        }
        a
    }

    #[test]
    fn exact_inverse_on_diagonal_matrix() {
        let a = scaled_identity(6);
        let f = SainvFactors::build(&a, SainvParams::default()).unwrap();
        let r: Vec<f64> = (0..6).map(|i| (i as f64) - 2.5).collect();
        for level in Precision::LADDER {
            let mut y = vec![0.0; 6];
            f.apply(&r, &mut y, level);
            for i in 0..6 {
                assert_eq!(y[i], r[i] / a.vals[i], "level {level:?} i {i}");
            }
        }
    }

    #[test]
    fn no_drop_factorization_inverts_poisson() {
        let a = poisson2d(6, 6);
        let n = a.nrows;
        let f = SainvFactors::build(&a, SainvParams { drop_tol: 0.0, k: 8 }).unwrap();
        let mut rng = Prng::new(7);
        let x: Vec<f64> = (0..n).map(|_| rng.f64() - 0.5).collect();
        let mut ax = vec![0.0; n];
        crate::spmv::fp64::spmv(&a, &x, &mut ax);
        let mut y = vec![0.0; n];
        f.apply(&ax, &mut y, Precision::Full);
        let err = crate::spmv::max_abs_diff(&x, &y);
        assert!(err < 1e-8, "M⁻¹(Ax) should recover x, err {err:e}");
    }

    #[test]
    fn drop_tolerance_sparsifies_factors() {
        let a = poisson2d(8, 8);
        let dense = SainvFactors::build(&a, SainvParams { drop_tol: 0.0, k: 8 }).unwrap();
        let sparse = SainvFactors::build(&a, SainvParams { drop_tol: 0.3, k: 8 }).unwrap();
        let nnz = |g: &GseCsr| *g.rowptr.last().unwrap();
        assert!(nnz(sparse.z()) < nnz(dense.z()), "dropping must sparsify Z");
        assert!(nnz(sparse.wt()) < nnz(dense.wt()), "dropping must sparsify Wᵀ");
        // diagonals always survive: M⁻¹ stays full-rank-ish
        assert!(nnz(sparse.z()) >= a.nrows);
    }

    #[test]
    fn fails_typed_on_singular_matrix() {
        let mut a = Csr::identity(5);
        a.vals[2] = 0.0; // zero pivot row
        let err = SainvFactors::build(&a, SainvParams::default()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sainv breakdown"), "{msg}");
        assert!(msg.contains("column 2"), "{msg}");
    }

    #[test]
    fn fails_typed_on_rectangular_matrix() {
        let a = Csr { nrows: 3, ncols: 4, rowptr: vec![0, 0, 0, 0], colidx: vec![], vals: vec![] };
        assert!(SainvFactors::build(&a, SainvParams::default()).is_err());
    }

    #[test]
    fn apply_multi_matches_looped_applies() {
        let a = poisson2d(7, 5);
        let n = a.nrows;
        let f = SainvFactors::build(&a, SainvParams { drop_tol: 0.05, k: 8 }).unwrap();
        let nrhs = 3usize;
        let mut rng = Prng::new(11);
        let rs: Vec<f64> = (0..n * nrhs).map(|_| rng.f64() - 0.5).collect();
        for level in Precision::LADDER {
            let mut fused = vec![0.0; n * nrhs];
            f.apply_multi(&rs, &mut fused, nrhs, level);
            let mut looped = vec![0.0; n * nrhs];
            for j in 0..nrhs {
                f.apply(&rs[j * n..(j + 1) * n], &mut looped[j * n..(j + 1) * n], level);
            }
            assert_eq!(fused, looped, "level {level:?}");
        }
    }

    #[test]
    fn precond_ladder_op_is_preconditioned_product() {
        let a = poisson2d(6, 6);
        let n = a.nrows;
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let f = Arc::new(SainvFactors::build(&a, SainvParams::default()).unwrap());
        let op = PrecondLadderOp::new(Arc::clone(&g), PrecondOp::Sainv(Arc::clone(&f)));
        assert_eq!(op.num_tags(), 3);
        assert_eq!(op.tag(), 1);
        assert_eq!(op.tag_label(3), "GSE-SEM(full)(sainv)");
        let mut rng = Prng::new(3);
        let x: Vec<f64> = (0..n).map(|_| rng.f64() - 0.5).collect();
        for level in Precision::LADDER {
            op.set_level(level);
            let mut got = vec![0.0; n];
            op.apply(&x, &mut got);
            let mut ax = vec![0.0; n];
            g.spmv(&x, &mut ax, level);
            let mut want = vec![0.0; n];
            f.apply(&ax, &mut want, level);
            assert_eq!(got, want, "level {level:?}");
        }
        // resident accounting covers A plus both factors
        assert_eq!(op.encoded_bytes(), g.encoded_bytes() + f.encoded_bytes());
        op.set_threads(3);
        assert_eq!(op.threads(), 3);
        assert_eq!(f.threads(), 3);
    }

    #[test]
    fn precond_op_none_and_jacobi() {
        let a = scaled_identity(4);
        let none = PrecondOp::for_spec(&Precond::None, &a).unwrap();
        let jac = PrecondOp::for_spec(&Precond::Jacobi, &a).unwrap();
        let r = vec![4.0, 4.0, 4.0, 4.0];
        let mut y = vec![0.0; 4];
        none.apply_level(&r, &mut y, Precision::Head);
        assert_eq!(y, r);
        jac.apply_level(&r, &mut y, Precision::Head);
        for i in 0..4 {
            assert_eq!(y[i], r[i] / a.vals[i]);
        }
        assert_eq!(none.label_suffix(), "");
        assert_eq!(jac.label_suffix(), "(jacobi)");
        assert_eq!(none.encoded_bytes(), 0);
        assert_eq!(jac.encoded_bytes(), 32);
        // multi matches looped for the cheap variants too
        let nrhs = 2usize;
        let rs = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let mut fused = vec![0.0; 4 * nrhs];
        jac.apply_multi_level(&rs, &mut fused, nrhs, Precision::Head);
        let mut looped = vec![0.0; 4 * nrhs];
        for j in 0..nrhs {
            let (r, y) = (&rs[j * 4..(j + 1) * 4], &mut looped[j * 4..(j + 1) * 4]);
            jac.apply_level(r, y, Precision::Head);
        }
        assert_eq!(fused, looped);
    }

    #[test]
    fn params_key_round_trips() {
        let p = SainvParams { drop_tol: 0.125, k: 16 };
        let key: SainvParamsKey = p.into();
        assert_eq!(key.params(), p);
        let q: PrecondKey = (&Precond::Sainv(p)).into();
        assert_eq!(q, PrecondKey::Sainv(key));
        assert_eq!(PrecondKey::from(&Precond::None), PrecondKey::None);
        assert_eq!(PrecondKey::from(&Precond::Jacobi), PrecondKey::Jacobi);
    }
}
