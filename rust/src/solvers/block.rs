//! Lockstep block-solve machinery shared by the multi-RHS solvers.
//!
//! `cg_solve_multi` showed the shape of the paper's batching win: run
//! `nrhs` independent Krylov recurrences in lockstep so every
//! iteration makes **one** pass over the matrix
//! ([`crate::spmv::SpmvOp::apply_multi`]). Extending that to GMRES,
//! BiCGSTAB and the stepped controller needs a slightly more general
//! frame, because those columns are not always in the same *phase*:
//! a GMRES column may be recomputing its cycle-start residual while a
//! neighbour is mid-Arnoldi, and a stepped column may sit on a finer
//! precision rung than the rest of the block.
//!
//! The frame here models each right-hand side as a [`BlockColumn`]
//! state machine that, between matrix applies, runs exactly the
//! arithmetic of its single-RHS solver. [`drive_columns`] repeatedly
//! gathers every live column's next SpMV input into a column-major
//! packed block, performs one fused `apply_multi` per precision rung
//! (coarsest first — columns whose controller demanded a finer rung
//! peel off into their own residual sub-block), and feeds each result
//! back into its column. Because every in-tree `apply_multi` is
//! bit-for-bit identical to looped single applies, each column's
//! outcome is **bitwise identical** to a standalone solve on that RHS
//! — the contract `tests/block_parity.rs` pins across formats, nrhs
//! and worker counts. Columns deflate out of the block as they
//! converge (or break down); the rest keep batching.
//!
//! Intra-block parallelism rides *inside* the operator: the intake
//! flusher's core allocator retunes the operator's
//! [`crate::spmv::ThreadBudget`] (via
//! [`crate::spmv::SpmvOp::set_threads`]) before — or even during — a
//! block solve, and nothing here needs to know. Every fused apply
//! reads the budget at call time, and any budget is bitwise identical
//! to serial, so thread counts never join iterates, histories or
//! switch logs in the solver state (`tests/group_threads.rs` pins
//! that, including mid-solve retunes between stepped rungs).

use super::stepped::PrecisionController;
use super::{MonitorCmd, SolveOutcome};
use crate::solvers::ladder::PrecisionSwitchable;
use crate::spmv::SpmvOp;
use crate::util::Timer;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Per-column monitor: the multi-RHS analogue of the `monitor`
/// callback the single-RHS solvers take. Fixed-format blocks observe
/// nothing; stepped blocks give every column its own
/// [`PrecisionController`] (same escalation policy, same switch log as
/// `run_stepped_with` installs around a single solve).
pub(crate) enum ColumnMonitor {
    /// No controller: always [`MonitorCmd::Continue`] (rung 1).
    Fixed,
    /// A private stepped controller deciding this column's rung.
    Stepped(PrecisionController),
}

impl ColumnMonitor {
    /// Feed one residual observation; [`MonitorCmd::Restart`] iff the
    /// controller escalated at this iteration.
    pub(crate) fn observe(&mut self, iter: usize, resid: f64) -> MonitorCmd {
        match self {
            ColumnMonitor::Fixed => MonitorCmd::Continue,
            ColumnMonitor::Stepped(ctrl) => {
                if ctrl.observe(iter, resid).is_some() {
                    MonitorCmd::Restart
                } else {
                    MonitorCmd::Continue
                }
            }
        }
    }

    /// The precision rung this column's applies must run at.
    pub(crate) fn tag(&self) -> u8 {
        match self {
            ColumnMonitor::Fixed => 1,
            ColumnMonitor::Stepped(ctrl) => ctrl.tag,
        }
    }

    /// The controller's escalation log (what `run_stepped_with` copies
    /// into [`SolveOutcome::switches`]).
    pub(crate) fn take_switches(&mut self) -> Vec<(usize, u8)> {
        match self {
            ColumnMonitor::Fixed => Vec::new(),
            ColumnMonitor::Stepped(ctrl) => std::mem::take(&mut ctrl.switches),
        }
    }
}

/// One right-hand side of a block solve, advanced one matrix apply at
/// a time. Implementations replicate their single-RHS solver's
/// arithmetic exactly between applies.
pub(crate) trait BlockColumn {
    /// Still needs matrix applies (not converged / broken / done)?
    fn active(&self) -> bool;
    /// Precision rung the next apply must run at (1 for fixed blocks).
    fn tag(&self) -> u8;
    /// The vector to multiply next (valid only while [`Self::active`]).
    fn input(&self) -> &[f64];
    /// Consume `y = A · input()` and advance to the next state.
    fn absorb(&mut self, y: &[f64]);
    /// Final outcome; `op` must be at this column's rung (the driver
    /// guarantees it) so the closing `true_relres` matches single
    /// dispatch. `seconds` is the shared wall time of the block.
    fn finish(self, op: &dyn SpmvOp, seconds: f64) -> SolveOutcome;
    /// Force the column out of the block mid-flight (cancellation /
    /// deadline): after this, [`Self::active`] is false and
    /// [`Self::finish`] reports the partial state reached so far.
    /// Siblings are untouched — their recurrences never read a
    /// neighbour's values, so the block stays bitwise identical to
    /// running them alone.
    fn deflate(&mut self);
}

/// Why a column left the block (parallel to the outcome vector of the
/// `_ctl` runners).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ColumnExit {
    /// Ran to its solver's own stopping rule (converged, stalled, or
    /// broke down) — the outcome is authoritative.
    Completed,
    /// Deflated mid-block: its ticket's cancel flag flipped.
    Cancelled,
    /// Deflated mid-block: its deadline passed.
    DeadlineExceeded,
}

/// Per-column cancellation flags and deadlines for a block solve,
/// polled between apply rounds by [`drive_columns_ctl`]. A column with
/// neither control is never polled, and a ctl built by
/// [`BlockCtl::none`] adds zero work to the drive loop.
pub(crate) struct BlockCtl {
    cancels: Vec<Option<Arc<AtomicBool>>>,
    deadlines: Vec<Option<Instant>>,
    any: bool,
}

impl BlockCtl {
    /// No controls: every column runs to its own stopping rule.
    pub(crate) fn none(n: usize) -> Self {
        Self { cancels: vec![None; n], deadlines: vec![None; n], any: false }
    }

    /// Per-column controls; both vectors must match the column count.
    pub(crate) fn new(
        cancels: Vec<Option<Arc<AtomicBool>>>,
        deadlines: Vec<Option<Instant>>,
    ) -> Self {
        assert_eq!(cancels.len(), deadlines.len());
        let any = cancels.iter().any(Option::is_some) || deadlines.iter().any(Option::is_some);
        Self { cancels, deadlines, any }
    }

    /// Any column carrying a cancel flag or deadline at all? (A
    /// control-free ctl lets drivers skip polling entirely.)
    pub(crate) fn has_controls(&self) -> bool {
        self.any
    }

    /// A ctl over a sub-block: column `s` of the subset maps to column
    /// `idxs[s]` here, sharing the same cancel flags and deadlines.
    /// The GMRES-IR outer loop uses this to forward per-ticket controls
    /// into each rung group's inner solve.
    pub(crate) fn subset(&self, idxs: &[usize]) -> BlockCtl {
        BlockCtl::new(
            idxs.iter().map(|&i| self.cancels[i].clone()).collect(),
            idxs.iter().map(|&i| self.deadlines[i]).collect(),
        )
    }

    /// Should column `j` deflate now? Cancel wins over deadline when
    /// both have triggered.
    pub(crate) fn poll(&self, j: usize) -> Option<ColumnExit> {
        if let Some(c) = &self.cancels[j] {
            if c.load(Ordering::Relaxed) {
                return Some(ColumnExit::Cancelled);
            }
        }
        if let Some(d) = self.deadlines[j] {
            if Instant::now() >= d {
                return Some(ColumnExit::DeadlineExceeded);
            }
        }
        None
    }
}

/// Drive a set of columns to completion over a square operator:
/// gather live columns' inputs per rung (coarsest first), one fused
/// `apply_multi` per rung, scatter results. `apply(tag, xs, ys, width)`
/// performs the block product — fixed-format callers ignore `tag`,
/// ladder callers switch the shared operator to that rung first.
pub(crate) fn drive_columns<C: BlockColumn>(
    cols: &mut [C],
    n: usize,
    apply: impl FnMut(u8, &[f64], &mut [f64], usize),
) {
    let ctl = BlockCtl::none(cols.len());
    let mut exits = vec![ColumnExit::Completed; cols.len()];
    drive_columns_ctl(cols, n, &ctl, &mut exits, apply);
}

/// [`drive_columns`] plus mid-flight deflation: before every apply
/// round, each live column's [`BlockCtl`] is polled and triggered
/// columns deflate out of the block, recording why in `exits`
/// (columns that run to completion keep [`ColumnExit::Completed`]).
/// Survivors see exactly the apply sequence a ctl-free block would
/// have given them — the bitwise-parity contract is unchanged.
pub(crate) fn drive_columns_ctl<C: BlockColumn>(
    cols: &mut [C],
    n: usize,
    ctl: &BlockCtl,
    exits: &mut [ColumnExit],
    mut apply: impl FnMut(u8, &[f64], &mut [f64], usize),
) {
    assert_eq!(cols.len(), exits.len());
    let mut xs: Vec<f64> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    loop {
        if ctl.any {
            for (j, c) in cols.iter_mut().enumerate() {
                if c.active() {
                    if let Some(exit) = ctl.poll(j) {
                        c.deflate();
                        exits[j] = exit;
                    }
                }
            }
        }
        // group the live columns by rung; BTreeMap iterates coarsest
        // (lowest tag) first
        let mut by_tag: BTreeMap<u8, Vec<usize>> = BTreeMap::new();
        for (i, c) in cols.iter().enumerate() {
            if c.active() {
                by_tag.entry(c.tag()).or_default().push(i);
            }
        }
        if by_tag.is_empty() {
            break;
        }
        for (tag, idxs) in by_tag {
            let width = idxs.len();
            xs.clear();
            xs.resize(n * width, 0.0);
            ys.clear();
            ys.resize(n * width, 0.0);
            for (slot, &i) in idxs.iter().enumerate() {
                xs[slot * n..(slot + 1) * n].copy_from_slice(cols[i].input());
            }
            apply(tag, &xs, &mut ys, width);
            for (slot, &i) in idxs.iter().enumerate() {
                cols[i].absorb(&ys[slot * n..(slot + 1) * n]);
            }
        }
    }
}

/// Run a fully-built column set over a fixed operator and collect the
/// per-column outcomes (shared wall clock, like `cg_solve_multi`).
pub(crate) fn run_fixed_block<C: BlockColumn>(
    op: &dyn SpmvOp,
    cols: Vec<C>,
) -> Vec<SolveOutcome> {
    let ctl = BlockCtl::none(cols.len());
    run_fixed_block_ctl(op, cols, &ctl).0
}

/// [`run_fixed_block`] with per-column cancel/deadline controls;
/// returns each column's outcome plus why it exited.
pub(crate) fn run_fixed_block_ctl<C: BlockColumn>(
    op: &dyn SpmvOp,
    mut cols: Vec<C>,
    ctl: &BlockCtl,
) -> (Vec<SolveOutcome>, Vec<ColumnExit>) {
    let n = op.nrows();
    let mut exits = vec![ColumnExit::Completed; cols.len()];
    let timer = Timer::start();
    drive_columns_ctl(&mut cols, n, ctl, &mut exits, |_tag, xs, ys, width| {
        op.apply_multi(xs, ys, width)
    });
    let seconds = timer.elapsed_s();
    (cols.into_iter().map(|c| c.finish(op, seconds)).collect(), exits)
}

/// Run a column set over a shared precision ladder: each rung's
/// sub-block applies with the ladder switched to that rung, and every
/// column's closing residual is computed at its final rung — exactly
/// what a fresh per-request ladder would have seen.
pub(crate) fn run_tagged_block<L: PrecisionSwitchable, C: BlockColumn>(
    op: &L,
    cols: Vec<C>,
) -> Vec<SolveOutcome> {
    let ctl = BlockCtl::none(cols.len());
    run_tagged_block_ctl(op, cols, &ctl).0
}

/// [`run_tagged_block`] with per-column cancel/deadline controls.
pub(crate) fn run_tagged_block_ctl<L: PrecisionSwitchable, C: BlockColumn>(
    op: &L,
    mut cols: Vec<C>,
    ctl: &BlockCtl,
) -> (Vec<SolveOutcome>, Vec<ColumnExit>) {
    let n = op.nrows();
    let mut exits = vec![ColumnExit::Completed; cols.len()];
    let timer = Timer::start();
    drive_columns_ctl(&mut cols, n, ctl, &mut exits, |tag, xs, ys, width| {
        op.set_tag(tag);
        op.apply_multi(xs, ys, width);
    });
    let seconds = timer.elapsed_s();
    let outcomes = cols
        .into_iter()
        .map(|c| {
            op.set_tag(c.tag());
            c.finish(op, seconds)
        })
        .collect();
    (outcomes, exits)
}
