//! Lockstep block-solve machinery shared by the multi-RHS solvers.
//!
//! `cg_solve_multi` showed the shape of the paper's batching win: run
//! `nrhs` independent Krylov recurrences in lockstep so every
//! iteration makes **one** pass over the matrix
//! ([`crate::spmv::SpmvOp::apply_multi`]). Extending that to GMRES,
//! BiCGSTAB and the stepped controller needs a slightly more general
//! frame, because those columns are not always in the same *phase*:
//! a GMRES column may be recomputing its cycle-start residual while a
//! neighbour is mid-Arnoldi, and a stepped column may sit on a finer
//! precision rung than the rest of the block.
//!
//! The frame here models each right-hand side as a [`BlockColumn`]
//! state machine that, between matrix applies, runs exactly the
//! arithmetic of its single-RHS solver. [`drive_columns`] repeatedly
//! gathers every live column's next SpMV input into a column-major
//! packed block, performs one fused `apply_multi` per precision rung
//! (coarsest first — columns whose controller demanded a finer rung
//! peel off into their own residual sub-block), and feeds each result
//! back into its column. Because every in-tree `apply_multi` is
//! bit-for-bit identical to looped single applies, each column's
//! outcome is **bitwise identical** to a standalone solve on that RHS
//! — the contract `tests/block_parity.rs` pins across formats, nrhs
//! and worker counts. Columns deflate out of the block as they
//! converge (or break down); the rest keep batching.

use super::stepped::PrecisionController;
use super::{MonitorCmd, SolveOutcome};
use crate::solvers::ladder::PrecisionSwitchable;
use crate::spmv::SpmvOp;
use crate::util::Timer;
use std::collections::BTreeMap;

/// Per-column monitor: the multi-RHS analogue of the `monitor`
/// callback the single-RHS solvers take. Fixed-format blocks observe
/// nothing; stepped blocks give every column its own
/// [`PrecisionController`] (same escalation policy, same switch log as
/// `run_stepped_with` installs around a single solve).
pub(crate) enum ColumnMonitor {
    /// No controller: always [`MonitorCmd::Continue`] (rung 1).
    Fixed,
    /// A private stepped controller deciding this column's rung.
    Stepped(PrecisionController),
}

impl ColumnMonitor {
    /// Feed one residual observation; [`MonitorCmd::Restart`] iff the
    /// controller escalated at this iteration.
    pub(crate) fn observe(&mut self, iter: usize, resid: f64) -> MonitorCmd {
        match self {
            ColumnMonitor::Fixed => MonitorCmd::Continue,
            ColumnMonitor::Stepped(ctrl) => {
                if ctrl.observe(iter, resid).is_some() {
                    MonitorCmd::Restart
                } else {
                    MonitorCmd::Continue
                }
            }
        }
    }

    /// The precision rung this column's applies must run at.
    pub(crate) fn tag(&self) -> u8 {
        match self {
            ColumnMonitor::Fixed => 1,
            ColumnMonitor::Stepped(ctrl) => ctrl.tag,
        }
    }

    /// The controller's escalation log (what `run_stepped_with` copies
    /// into [`SolveOutcome::switches`]).
    pub(crate) fn take_switches(&mut self) -> Vec<(usize, u8)> {
        match self {
            ColumnMonitor::Fixed => Vec::new(),
            ColumnMonitor::Stepped(ctrl) => std::mem::take(&mut ctrl.switches),
        }
    }
}

/// One right-hand side of a block solve, advanced one matrix apply at
/// a time. Implementations replicate their single-RHS solver's
/// arithmetic exactly between applies.
pub(crate) trait BlockColumn {
    /// Still needs matrix applies (not converged / broken / done)?
    fn active(&self) -> bool;
    /// Precision rung the next apply must run at (1 for fixed blocks).
    fn tag(&self) -> u8;
    /// The vector to multiply next (valid only while [`Self::active`]).
    fn input(&self) -> &[f64];
    /// Consume `y = A · input()` and advance to the next state.
    fn absorb(&mut self, y: &[f64]);
    /// Final outcome; `op` must be at this column's rung (the driver
    /// guarantees it) so the closing `true_relres` matches single
    /// dispatch. `seconds` is the shared wall time of the block.
    fn finish(self, op: &dyn SpmvOp, seconds: f64) -> SolveOutcome;
}

/// Drive a set of columns to completion over a square operator:
/// gather live columns' inputs per rung (coarsest first), one fused
/// `apply_multi` per rung, scatter results. `apply(tag, xs, ys, width)`
/// performs the block product — fixed-format callers ignore `tag`,
/// ladder callers switch the shared operator to that rung first.
pub(crate) fn drive_columns<C: BlockColumn>(
    cols: &mut [C],
    n: usize,
    mut apply: impl FnMut(u8, &[f64], &mut [f64], usize),
) {
    let mut xs: Vec<f64> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    loop {
        // group the live columns by rung; BTreeMap iterates coarsest
        // (lowest tag) first
        let mut by_tag: BTreeMap<u8, Vec<usize>> = BTreeMap::new();
        for (i, c) in cols.iter().enumerate() {
            if c.active() {
                by_tag.entry(c.tag()).or_default().push(i);
            }
        }
        if by_tag.is_empty() {
            break;
        }
        for (tag, idxs) in by_tag {
            let width = idxs.len();
            xs.clear();
            xs.resize(n * width, 0.0);
            ys.clear();
            ys.resize(n * width, 0.0);
            for (slot, &i) in idxs.iter().enumerate() {
                xs[slot * n..(slot + 1) * n].copy_from_slice(cols[i].input());
            }
            apply(tag, &xs, &mut ys, width);
            for (slot, &i) in idxs.iter().enumerate() {
                cols[i].absorb(&ys[slot * n..(slot + 1) * n]);
            }
        }
    }
}

/// Run a fully-built column set over a fixed operator and collect the
/// per-column outcomes (shared wall clock, like `cg_solve_multi`).
pub(crate) fn run_fixed_block<C: BlockColumn>(
    op: &dyn SpmvOp,
    mut cols: Vec<C>,
) -> Vec<SolveOutcome> {
    let n = op.nrows();
    let timer = Timer::start();
    drive_columns(&mut cols, n, |_tag, xs, ys, width| op.apply_multi(xs, ys, width));
    let seconds = timer.elapsed_s();
    cols.into_iter().map(|c| c.finish(op, seconds)).collect()
}

/// Run a column set over a shared precision ladder: each rung's
/// sub-block applies with the ladder switched to that rung, and every
/// column's closing residual is computed at its final rung — exactly
/// what a fresh per-request ladder would have seen.
pub(crate) fn run_tagged_block<L: PrecisionSwitchable, C: BlockColumn>(
    op: &L,
    mut cols: Vec<C>,
) -> Vec<SolveOutcome> {
    let n = op.nrows();
    let timer = Timer::start();
    drive_columns(&mut cols, n, |tag, xs, ys, width| {
        op.set_tag(tag);
        op.apply_multi(xs, ys, width);
    });
    let seconds = timer.elapsed_s();
    cols.into_iter()
        .map(|c| {
            op.set_tag(c.tag());
            c.finish(op, seconds)
        })
        .collect()
}
