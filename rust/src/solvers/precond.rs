//! Preconditioners (extension beyond the paper, which runs unpreconditioned
//! solvers; Jacobi gives the corpus' ill-scaled FEM systems a fair shot
//! and exercises the stepped controller in a second regime).

use crate::sparse::csr::Csr;
use std::sync::Arc;

/// Inverse-diagonal (Jacobi) preconditioner data.
#[derive(Clone, Debug)]
pub struct Jacobi {
    pub inv_diag: Vec<f64>,
}

impl Jacobi {
    /// Build from a matrix; zero diagonals fall back to 1 (identity).
    pub fn from_csr(a: &Csr) -> Self {
        let inv_diag = a
            .diag()
            .iter()
            .map(|&d| if d != 0.0 && d.is_finite() { 1.0 / d } else { 1.0 })
            .collect();
        Self { inv_diag }
    }

    /// z ← M⁻¹ r
    pub fn apply(&self, r: &[f64], z: &mut [f64]) {
        for i in 0..r.len() {
            z[i] = r[i] * self.inv_diag[i];
        }
    }
}

/// Symmetric Gauss–Seidel sweep preconditioner (one forward + one
/// backward sweep), a stronger option for the hardest FEM instances.
#[derive(Clone, Debug)]
pub struct SymGaussSeidel {
    a: Arc<Csr>,
    diag: Vec<f64>,
}

impl SymGaussSeidel {
    /// Build from a matrix, sharing (not copying) an `Arc`-held one;
    /// zero or non-finite diagonals fall back to 1 like [`Jacobi`].
    pub fn from_csr(a: impl Into<Arc<Csr>>) -> Self {
        let a = a.into();
        let diag =
            a.diag().iter().map(|&d| if d != 0.0 && d.is_finite() { d } else { 1.0 }).collect();
        Self { a, diag }
    }

    /// z ≈ M⁻¹ r via (D+L) D⁻¹ (D+U) splitting.
    pub fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = self.a.nrows;
        // forward solve (D+L) w = r
        for i in 0..n {
            let (cols, vals) = self.a.row(i);
            let mut s = r[i];
            for (&c, &v) in cols.iter().zip(vals) {
                if (c as usize) < i {
                    s -= v * z[c as usize];
                }
            }
            z[i] = s / self.diag[i];
        }
        // w ← D w
        for i in 0..n {
            z[i] *= self.diag[i];
        }
        // backward solve (D+U) z = w
        for i in (0..n).rev() {
            let (cols, vals) = self.a.row(i);
            let mut s = z[i];
            for (&c, &v) in cols.iter().zip(vals) {
                if (c as usize) > i {
                    s -= v * z[c as usize];
                }
            }
            z[i] = s / self.diag[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn jacobi_inverts_diagonal() {
        let a = poisson2d(4, 4);
        let j = Jacobi::from_csr(&a);
        assert!(j.inv_diag.iter().all(|&d| (d - 0.25).abs() < 1e-15));
        let r = vec![2.0; 16];
        let mut z = vec![0.0; 16];
        j.apply(&r, &mut z);
        assert!(z.iter().all(|&v| (v - 0.5).abs() < 1e-15));
    }

    #[test]
    fn sgs_is_identity_on_diagonal_matrix() {
        let a = crate::sparse::csr::Csr::identity(5);
        let m = SymGaussSeidel::from_csr(a);
        let r = vec![3.0, -1.0, 0.5, 2.0, 7.0];
        let mut z = vec![0.0; 5];
        m.apply(&r, &mut z);
        for (a, b) in r.iter().zip(&z) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn sgs_guards_nonfinite_diagonals() {
        let mut a = crate::sparse::csr::Csr::identity(3);
        a.vals[1] = f64::NAN;
        let m = SymGaussSeidel::from_csr(a);
        let r = vec![1.0, 1.0, 1.0];
        let mut z = vec![0.0; 3];
        m.apply(&r, &mut z);
        assert!(z.iter().all(|v| v.is_finite()), "NaN diagonal must not poison the sweep");
    }

    #[test]
    fn sgs_reduces_residual_as_smoother() {
        let a = Arc::new(poisson2d(8, 8));
        let m = SymGaussSeidel::from_csr(Arc::clone(&a));
        let b = vec![1.0; 64];
        let mut z = vec![0.0; 64];
        m.apply(&b, &mut z); // one SGS application = one smoothing step
        // residual after one application should be smaller than ||b||
        let mut az = vec![0.0; 64];
        crate::spmv::fp64::spmv(&a, &z, &mut az);
        let res: f64 = b.iter().zip(&az).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
        let bn: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(res < bn, "res {res} vs {bn}");
    }
}
