//! Segmented SoA storage of SEM-encoded vectors (§III-B3, Fig. 3).
//!
//! All heads are contiguous, followed by all tail1 segments, then all
//! tail2 segments — the memory layout that gives coalesced loads on the
//! GPU and streaming loads on the CPU. A *single* stored copy serves all
//! three precisions: decoding at `Head` touches only the head array,
//! `HeadTail1` adds the tail1 array, `Full` adds tail2 (the paper's
//! storage/computation decoupling).

use super::gse::GseTable;
use super::sem::{self, SemGeometry, SemLayout};
use super::{ieee, Precision};

/// A dense f64 vector encoded in GSE-SEM with inline exponent indexes.
#[derive(Clone, Debug)]
pub struct SemVector {
    pub table: GseTable,
    pub geom: SemGeometry,
    pub heads: Vec<u16>,
    pub tail1: Vec<u16>,
    pub tail2: Vec<u32>,
}

impl SemVector {
    /// Encode a vector, extracting a fresh k-entry shared-exponent table
    /// from the data (Algorithm 1 end-to-end).
    pub fn encode(xs: &[f64], k: usize) -> Self {
        let table = GseTable::from_values(xs, k);
        Self::encode_with_table(xs, table)
    }

    /// Encode with a pre-extracted table (§III-B1: the group exponent
    /// setting is reused across calculations without reanalysis).
    pub fn encode_with_table(xs: &[f64], table: GseTable) -> Self {
        let geom = SemGeometry::new(SemLayout::Inline, table.ei_bit);
        let mut heads = Vec::with_capacity(xs.len());
        let mut tail1 = Vec::with_capacity(xs.len());
        let mut tail2 = Vec::with_capacity(xs.len());
        for &x in xs {
            // By construction the table covers the data's exponent range;
            // anything unrepresentable (Inf/NaN or data outside the build
            // set) saturates to the largest shared binade, mirroring how
            // the GPU kernel would clamp rather than fault.
            let p = sem::encode(x, &table, &geom).unwrap_or_else(|_| {
                saturated_parts(x, &table, &geom)
            });
            heads.push(p.head);
            tail1.push(p.tail1);
            tail2.push(p.tail2);
        }
        Self { table, geom, heads, tail1, tail2 }
    }

    pub fn len(&self) -> usize {
        self.heads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heads.is_empty()
    }

    /// Decode one element at a precision level.
    #[inline]
    pub fn get(&self, i: usize, level: Precision) -> f64 {
        let parts = sem::SemParts {
            head: self.heads[i],
            tail1: if level >= Precision::HeadTail1 { self.tail1[i] } else { 0 },
            tail2: if level == Precision::Full { self.tail2[i] } else { 0 },
            exp_idx: sem::inline_exp_idx(self.heads[i], &self.geom),
        };
        sem::decode_ldexp(&parts, &self.table, &self.geom, level)
    }

    /// Decode the whole vector into `out`.
    pub fn decode_into(&self, level: Precision, out: &mut [f64]) {
        assert_eq!(out.len(), self.len());
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.get(i, level);
        }
    }

    /// Decode to a new Vec.
    pub fn decode(&self, level: Precision) -> Vec<f64> {
        let mut out = vec![0.0; self.len()];
        self.decode_into(level, &mut out);
        out
    }

    /// Total bytes resident for this encoding (GSE table + all segments);
    /// compare with `8 * len` for FP64.
    pub fn stored_bytes(&self) -> usize {
        self.table.len() * 4 + self.heads.len() * 2 + self.tail1.len() * 2 + self.tail2.len() * 4
    }

    /// Bytes *read* when decoding at a level (the traffic that matters
    /// for the memory-bound SpMV).
    pub fn read_bytes(&self, level: Precision) -> usize {
        self.table.len() * 4 + self.len() * level.bytes_per_value()
    }

    /// Maximum absolute decode error vs the original data at a level.
    pub fn max_abs_error(&self, original: &[f64], level: Precision) -> f64 {
        assert_eq!(original.len(), self.len());
        original
            .iter()
            .enumerate()
            .map(|(i, &x)| (x - self.get(i, level)).abs())
            .fold(0.0, f64::max)
    }
}

/// Clamp an unrepresentable value to the largest shared binade, keeping
/// the sign — the vector-level fallback for out-of-table exponents.
fn saturated_parts(
    x: f64,
    table: &GseTable,
    geom: &SemGeometry,
) -> sem::SemParts {
    let biggest = table
        .entries
        .iter()
        .enumerate()
        .max_by_key(|(_, &e)| e)
        .map(|(i, _)| i)
        .unwrap_or(0);
    // All-ones mantissa in the largest binade.
    let stored = table.stored_exp(biggest);
    let max_val = ieee::ldexp(
        ((1u64 << 52) - 1) as f64,
        stored as i32 - 1075,
    );
    let v = if x.is_nan() { 0.0 } else { max_val.copysign(x) };
    sem::encode(v, table, geom).expect("saturated value must encode")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn roundtrip_full_precision_close() {
        let mut r = Prng::new(1);
        let xs: Vec<f64> = (0..500).map(|_| r.range_f64(-1000.0, 1000.0)).collect();
        let v = SemVector::encode(&xs, 8);
        let back = v.decode(Precision::Full);
        for (&x, &y) in xs.iter().zip(&back) {
            if x != 0.0 {
                assert!(((x - y) / x).abs() < 2f64.powi(-40), "x={x} y={y}");
            }
        }
    }

    #[test]
    fn storage_sizes() {
        // 4 distinct binades so the table keeps k = 4 entries
        let xs: Vec<f64> = (0..100).map(|i| 2f64.powi((i % 4) as i32) * 1.3).collect();
        let v = SemVector::encode(&xs, 4);
        assert_eq!(v.table.len(), 4);
        assert_eq!(v.stored_bytes(), 4 * 4 + 100 * (2 + 2 + 4));
        assert_eq!(v.read_bytes(Precision::Head), 16 + 200);
        assert_eq!(v.read_bytes(Precision::HeadTail1), 16 + 400);
        assert_eq!(v.read_bytes(Precision::Full), 16 + 800);
    }

    #[test]
    fn error_decreases_with_level() {
        let mut r = Prng::new(2);
        let xs: Vec<f64> = (0..1000).map(|_| r.lognormal(0.0, 3.0)).collect();
        let v = SemVector::encode(&xs, 8);
        let e1 = v.max_abs_error(&xs, Precision::Head);
        let e2 = v.max_abs_error(&xs, Precision::HeadTail1);
        let e3 = v.max_abs_error(&xs, Precision::Full);
        assert!(e2 <= e1 && e3 <= e2, "{e1} {e2} {e3}");
        assert!(e3 < e1 || e1 == 0.0);
    }

    #[test]
    fn reused_table_encoding() {
        let train: Vec<f64> = (0..100).map(|i| (i as f64 + 1.0) * 0.25).collect();
        let t = GseTable::from_values(&train, 8);
        let test: Vec<f64> = vec![0.3, 1.7, 12.5];
        let v = SemVector::encode_with_table(&test, t);
        let back = v.decode(Precision::Full);
        for (&x, &y) in test.iter().zip(&back) {
            assert!(((x - y) / x).abs() < 1e-9, "x={x} y={y}");
        }
    }

    #[test]
    fn saturation_out_of_table() {
        // Table built on small data; encode a huge value -> clamps to the
        // largest shared binade instead of panicking.
        let t = GseTable::from_values(&[1.0, 2.0], 2);
        let v = SemVector::encode_with_table(&[1e100, -1e100], t);
        let back = v.decode(Precision::Full);
        assert!(back[0] > 0.0 && back[0].is_finite());
        assert_eq!(back[1], -back[0]);
        assert!(back[0] < 8.0); // clamped into the table's range
    }

    #[test]
    fn zeros_roundtrip() {
        let xs = [0.0, 1.0, 0.0, -2.0];
        let v = SemVector::encode(&xs, 2);
        for lvl in Precision::LADDER {
            let d = v.decode(lvl);
            assert_eq!(d[0], 0.0);
            assert_eq!(d[2], 0.0);
        }
    }
}
