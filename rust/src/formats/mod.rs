//! Floating-point formats: IEEE-754 bit tools, software minifloats
//! (FP16 / BF16 / FP8 / TF32), and the paper's GSE-SEM format.
//!
//! The GSE-SEM pieces:
//! * [`gse`] — group-shared exponent table extraction (§III-B1).
//! * [`sem`] — sign / exponent-index / mantissa encoding with
//!   denormalized significands (§III-B2, Alg. 1) and the three-level
//!   decode (head / head+tail1 / head+tail1+tail2, Alg. 2).
//! * [`segmented`] — the SoA segmented memory layout (§III-B3, Fig. 3).
//! * [`entropy`] — value/exponent/mantissa information-entropy analysis
//!   backing Fig. 1.

pub mod ieee;
pub mod minifloat;
pub mod fp16;
pub mod bf16;
pub mod gse;
pub mod sem;
pub mod segmented;
pub mod entropy;
pub mod msplit;

pub use bf16::Bf16;
pub use fp16::Fp16;
pub use gse::GseTable;
pub use segmented::SemVector;

/// Storage precision level of a GSE-SEM datum (§III-B3): which mantissa
/// segments are read from memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Precision {
    /// 16-bit head only (lowest precision, least traffic).
    Head,
    /// head + 16-bit tail1.
    HeadTail1,
    /// head + tail1 + tail2 (full stored mantissa).
    Full,
}

impl Precision {
    /// All levels in escalation order (the "stepped" ladder of §III-D).
    pub const LADDER: [Precision; 3] = [Precision::Head, Precision::HeadTail1, Precision::Full];

    /// The paper's integer tag (Alg. 3): 1, 2, 3.
    pub fn tag(self) -> u8 {
        match self {
            Precision::Head => 1,
            Precision::HeadTail1 => 2,
            Precision::Full => 3,
        }
    }

    /// Inverse of [`Precision::tag`], clamping out-of-range tags to the
    /// nearest rung (0 → Head, ≥3 → Full).
    pub fn from_tag(tag: u8) -> Precision {
        match tag {
            0 | 1 => Precision::Head,
            2 => Precision::HeadTail1,
            _ => Precision::Full,
        }
    }

    /// Next level up the ladder, saturating at `Full`.
    pub fn escalate(self) -> Precision {
        match self {
            Precision::Head => Precision::HeadTail1,
            Precision::HeadTail1 | Precision::Full => Precision::Full,
        }
    }

    /// Bytes of value data read per element at this level.
    pub fn bytes_per_value(self) -> usize {
        match self {
            Precision::Head => 2,
            Precision::HeadTail1 => 4,
            Precision::Full => 8,
        }
    }
}

/// Which storage format an SpMV / solver variant uses for matrix values.
/// This is the axis of every comparison figure in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ValueFormat {
    Fp64,
    Fp32,
    Fp16,
    Bf16,
    GseSem(Precision),
}

impl ValueFormat {
    pub fn label(self) -> &'static str {
        match self {
            ValueFormat::Fp64 => "FP64",
            ValueFormat::Fp32 => "FP32",
            ValueFormat::Fp16 => "FP16",
            ValueFormat::Bf16 => "BF16",
            ValueFormat::GseSem(Precision::Head) => "GSE-SEM(head)",
            ValueFormat::GseSem(Precision::HeadTail1) => "GSE-SEM(head+t1)",
            ValueFormat::GseSem(Precision::Full) => "GSE-SEM(full)",
        }
    }

    /// Bytes of value data read per non-zero.
    pub fn bytes_per_value(self) -> usize {
        match self {
            ValueFormat::Fp64 => 8,
            ValueFormat::Fp32 => 4,
            ValueFormat::Fp16 | ValueFormat::Bf16 => 2,
            ValueFormat::GseSem(p) => p.bytes_per_value(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_escalates_and_saturates() {
        assert_eq!(Precision::Head.escalate(), Precision::HeadTail1);
        assert_eq!(Precision::HeadTail1.escalate(), Precision::Full);
        assert_eq!(Precision::Full.escalate(), Precision::Full);
        assert_eq!(Precision::LADDER[0].tag(), 1);
        assert_eq!(Precision::LADDER[2].tag(), 3);
    }

    #[test]
    fn tag_roundtrip_and_clamping() {
        for p in Precision::LADDER {
            assert_eq!(Precision::from_tag(p.tag()), p);
        }
        assert_eq!(Precision::from_tag(0), Precision::Head);
        assert_eq!(Precision::from_tag(9), Precision::Full);
    }

    #[test]
    fn value_format_bytes() {
        assert_eq!(ValueFormat::Fp64.bytes_per_value(), 8);
        assert_eq!(ValueFormat::Fp16.bytes_per_value(), 2);
        assert_eq!(ValueFormat::GseSem(Precision::Head).bytes_per_value(), 2);
        assert_eq!(ValueFormat::GseSem(Precision::HeadTail1).bytes_per_value(), 4);
        assert_eq!(ValueFormat::GseSem(Precision::Full).bytes_per_value(), 8);
    }
}
