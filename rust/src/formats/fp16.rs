//! Concrete IEEE binary16 storage type used by the FP16-SpMV baseline.
//!
//! Arithmetic is *not* implemented on the type — matching the paper's
//! baselines, FP16 is a storage/transfer format only: values are loaded,
//! widened to f64, and all multiply/accumulate happens in f64.

use super::minifloat::FP16;
use std::sync::OnceLock;

/// A 16-bit IEEE half-precision value (storage only).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct Fp16(pub u16);

/// Widening LUT: real hardware converts FP16→FP64 in one instruction;
/// the software simulation matches that cost with a 512 KiB table
/// (hot-path requirement — the FP16-SpMV baseline is memory-bound, so
/// the conversion must not dominate like the generic decoder would).
fn widen_lut() -> &'static [f64; 1 << 16] {
    static LUT: OnceLock<Box<[f64; 1 << 16]>> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = vec![0f64; 1 << 16];
        for (bits, slot) in t.iter_mut().enumerate() {
            *slot = FP16.decode(bits as u32);
        }
        t.into_boxed_slice().try_into().unwrap()
    })
}

impl Fp16 {
    /// Round an f64 to the nearest representable half (ties to even).
    #[inline]
    pub fn from_f64(x: f64) -> Self {
        Fp16(FP16.encode(x) as u16)
    }

    /// Exact widening conversion (table-driven; see [`widen_lut`]).
    #[inline(always)]
    pub fn to_f64(self) -> f64 {
        widen_lut()[self.0 as usize]
    }

    /// Reference widening through the generic minifloat decoder (tests).
    pub fn to_f64_reference(self) -> f64 {
        FP16.decode(self.0 as u32)
    }

    pub fn is_nan(self) -> bool {
        self.to_f64().is_nan()
    }

    pub fn is_infinite(self) -> bool {
        self.to_f64().is_infinite()
    }

    /// Convert a whole slice (the baseline matrix-conversion path).
    pub fn encode_slice(xs: &[f64]) -> Vec<Fp16> {
        xs.iter().map(|&x| Fp16::from_f64(x)).collect()
    }

    /// Returns true if any value overflowed to ±Inf during encoding —
    /// the paper reports FP16 "arithmetic overflow" on 4 GMRES and 10 CG
    /// matrices; this is how the solver detects that condition up front.
    pub fn any_overflow(xs: &[f64]) -> bool {
        xs.iter().any(|&x| x.is_finite() && Fp16::from_f64(x).is_infinite())
    }
}

impl From<f64> for Fp16 {
    fn from(x: f64) -> Self {
        Fp16::from_f64(x)
    }
}

impl From<Fp16> for f64 {
    fn from(h: Fp16) -> f64 {
        h.to_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        for x in [0.0, 1.0, -1.0, 0.5, 1024.0, 0.333251953125] {
            assert_eq!(Fp16::from_f64(x).to_f64(), FP16.round(x));
        }
    }

    #[test]
    fn lut_matches_reference_exhaustively() {
        for bits in 0u16..=u16::MAX {
            let h = Fp16(bits);
            let (a, b) = (h.to_f64(), h.to_f64_reference());
            assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()), "bits={bits:#06x}");
        }
    }

    #[test]
    fn overflow_detection() {
        assert!(Fp16::any_overflow(&[1.0, 1e6]));
        assert!(!Fp16::any_overflow(&[1.0, 65504.0]));
        assert!(Fp16::from_f64(70000.0).is_infinite());
    }

    #[test]
    fn encode_slice_matches_scalar() {
        let xs = [1.5, -2.25, 3e-5];
        let enc = Fp16::encode_slice(&xs);
        for (e, &x) in enc.iter().zip(&xs) {
            assert_eq!(e.0, Fp16::from_f64(x).0);
        }
    }
}
