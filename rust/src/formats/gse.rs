//! Group-Shared Exponent (GSE) table extraction — §III-B1 of the paper.
//!
//! For a set of FP64 values we count the occurrences of each distinct
//! biased exponent, keep the `k` most frequent, and store each as
//! `biased_exp + 1`: the +1 implements the paper's explicit-leading-one
//! convention (§III-B2) — every encoded significand is shifted right by
//! at least `minDiff = 1`, so the hidden bit becomes an explicit stored
//! bit and values whose exponent is *not* in the table are represented
//! denormalized relative to the nearest larger shared exponent.
//!
//! The table also guarantees that `max_exponent + 1` is present
//! (replacing the least frequent entry if needed); otherwise the largest
//! values of the set would be unrepresentable (§III-B2).

use super::ieee;

/// Maximum supported table size: 6 index bits (the paper sweeps k ≤ 64).
pub const MAX_SHARED_EXPONENTS: usize = 64;

/// Histogram of biased FP64 exponents (2048 bins).
#[derive(Clone)]
pub struct ExpHistogram {
    pub counts: Vec<u64>,
    pub total: u64,
    /// values skipped because they are zero/subnormal/Inf/NaN
    pub skipped: u64,
}

impl Default for ExpHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl ExpHistogram {
    pub fn new() -> Self {
        Self { counts: vec![0u64; 2048], total: 0, skipped: 0 }
    }

    /// Accumulate one value.
    #[inline]
    pub fn push(&mut self, x: f64) {
        let p = ieee::split(x);
        if p.exp == 0 || p.exp == ieee::EXP_SPECIAL {
            self.skipped += 1;
        } else {
            self.counts[p.exp as usize] += 1;
            self.total += 1;
        }
    }

    /// Accumulate a slice.
    pub fn push_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    /// Number of distinct exponents observed.
    pub fn num_distinct(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    /// Fraction of values covered by the `k` most frequent exponents
    /// (the paper's Eq. 2 / Fig. 1(b–h) "top-k" metric).
    pub fn topk_coverage(&self, k: usize) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        let mut nonzero: Vec<u64> = self.counts.iter().copied().filter(|&c| c > 0).collect();
        nonzero.sort_unstable_by(|a, b| b.cmp(a));
        let covered: u64 = nonzero.iter().take(k).sum();
        covered as f64 / self.total as f64
    }

    /// Largest biased exponent present, if any value was counted.
    pub fn max_exp(&self) -> Option<u32> {
        self.counts.iter().rposition(|&c| c > 0).map(|i| i as u32)
    }
}

/// The extracted shared-exponent table plus the derived encode metadata.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GseTable {
    /// Shared exponents stored as `biased_exp + 1`, ordered by descending
    /// frequency (so index 0 is the most common — the fast path).
    pub entries: Vec<u32>,
    /// Bits needed to index the table (`EI_bit` in the paper).
    pub ei_bit: u32,
    /// Per-biased-exponent lookup: `lut[exp] = (index, minDiff)` of the
    /// best (smallest `minDiff >= 1`) table entry, or `NO_ENTRY` if no
    /// entry can represent that exponent. Precomputing this makes encode
    /// O(1) per value instead of O(k) (the GPU kernel does the O(k) scan
    /// in shared memory; see DESIGN.md §6).
    lut: Vec<(u16, u16)>,
}

/// LUT marker for "no representable entry".
pub const NO_ENTRY: (u16, u16) = (u16::MAX, u16::MAX);

impl GseTable {
    /// Build a table from an exponent histogram, keeping the `k` most
    /// frequent exponents and guaranteeing `max_exp + 1` is present.
    pub fn from_histogram(hist: &ExpHistogram, k: usize) -> Self {
        assert!(k >= 1 && k <= MAX_SHARED_EXPONENTS, "k must be in 1..=64");
        let mut freq: Vec<(u32, u64)> = hist
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(e, &c)| (e as u32, c))
            .collect();
        // descending count, ties by ascending exponent for determinism
        freq.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));

        let mut entries: Vec<u32> = freq.iter().take(k).map(|&(e, _)| e + 1).collect();
        if entries.is_empty() {
            // Degenerate input (all zeros): single entry representing 1.0
            entries.push(ieee::BIAS as u32 + 1);
        }
        // Guarantee the maximum exponent (+1) is representable.
        if let Some(maxe) = hist.max_exp() {
            let need = maxe + 1;
            if !entries.contains(&need) {
                let last = entries.len() - 1;
                entries[last] = need;
            }
        }
        Self::from_entries(entries)
    }

    /// Build directly from `biased_exp + 1` entries (frequency order).
    /// Duplicates are removed (first occurrence wins).
    pub fn from_entries(mut entries: Vec<u32>) -> Self {
        let mut seen = [false; 2049];
        entries.retain(|&e| {
            assert!(e <= 2047, "entry out of biased-exponent range");
            let fresh = !seen[e as usize];
            seen[e as usize] = true;
            fresh
        });
        assert!(!entries.is_empty() && entries.len() <= MAX_SHARED_EXPONENTS);
        let k = entries.len();
        let ei_bit = if k <= 1 { 1 } else { (usize::BITS - (k - 1).leading_zeros()).max(1) };

        // Precompute, for every biased exponent, the entry with the
        // smallest positive minDiff = entry - exp (Alg. 1 lines 6-21).
        let mut lut = vec![NO_ENTRY; 2048];
        for (exp, slot) in lut.iter_mut().enumerate() {
            let mut best: (u16, u16) = NO_ENTRY;
            for (i, &e) in entries.iter().enumerate() {
                let diff = e as i64 - exp as i64;
                if diff >= 1 && (diff as u16) < best.1 {
                    best = (i as u16, diff as u16);
                }
            }
            *slot = best;
        }
        Self { entries, ei_bit, lut }
    }

    /// Convenience: build from a value slice.
    pub fn from_values(xs: &[f64], k: usize) -> Self {
        let mut h = ExpHistogram::new();
        h.push_all(xs);
        Self::from_histogram(&h, k)
    }

    /// Sampled extraction (§III-B1): rows are grouped into `nblocks`
    /// blocks; one random row per block contributes its exponents. Used
    /// to bound preprocessing cost on very large matrices.
    pub fn from_sampled_rows<'a>(
        rows: impl Fn(usize) -> &'a [f64],
        nrows: usize,
        k: usize,
        nblocks: usize,
        rng: &mut crate::util::Prng,
    ) -> Self {
        let nblocks = nblocks.clamp(1, nrows.max(1));
        let mut h = ExpHistogram::new();
        if nrows == 0 {
            return Self::from_histogram(&h, k);
        }
        let block = nrows.div_ceil(nblocks);
        let mut r = 0usize;
        while r < nrows {
            let hi = (r + block).min(nrows);
            let pick = r + rng.below(hi - r);
            h.push_all(rows(pick));
            r = hi;
        }
        Self::from_histogram(&h, k)
    }

    /// Table size `k`.
    #[inline]
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// O(1) lookup: best (index, minDiff) for a biased exponent, or
    /// `None` if the exponent exceeds every table entry.
    #[inline(always)]
    pub fn lookup(&self, biased_exp: u32) -> Option<(u16, u16)> {
        let hit = self.lut[biased_exp as usize];
        if hit == NO_ENTRY {
            None
        } else {
            Some(hit)
        }
    }

    /// O(k) reference lookup replicating Alg. 1's scan exactly; used by
    /// tests to validate the LUT.
    pub fn lookup_scan(&self, biased_exp: u32) -> Option<(u16, u16)> {
        // lines 6-12: exact match (exp + 1 == SEM[k]) wins immediately
        for (i, &e) in self.entries.iter().enumerate() {
            if biased_exp + 1 == e {
                return Some((i as u16, 1));
            }
        }
        // lines 13-21: nearest greater entry
        let mut best: Option<(u16, u16)> = None;
        for (i, &e) in self.entries.iter().enumerate() {
            let diff = e as i64 - biased_exp as i64;
            if diff <= 0 {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, d)) => (diff as u16) < d,
            };
            if better {
                best = Some((i as u16, diff as u16));
            }
        }
        best
    }

    /// The stored exponent (`biased + 1`) at `idx`.
    #[inline(always)]
    pub fn stored_exp(&self, idx: usize) -> u32 {
        self.entries[idx]
    }

    /// Pick the smallest k from the paper's sweep {2,4,8,16,32,64} whose
    /// top-k coverage reaches `target` (e.g. 0.9) — automatic tuning of
    /// the §IV-B knob instead of the paper's fixed k=8.
    pub fn auto_k(hist: &ExpHistogram, target: f64) -> usize {
        for k in [2usize, 4, 8, 16, 32, 64] {
            if hist.topk_coverage(k) >= target {
                return k;
            }
        }
        MAX_SHARED_EXPONENTS
    }

    /// Fraction of histogram values whose exponent is an exact table hit
    /// (`minDiff == 1`) — the fast path of the decode kernel.
    pub fn exact_hit_ratio(&self, hist: &ExpHistogram) -> f64 {
        if hist.total == 0 {
            return 1.0;
        }
        let hits: u64 = self
            .entries
            .iter()
            .filter_map(|&e| e.checked_sub(1))
            .map(|e| hist.counts[e as usize])
            .sum();
        hits as f64 / hist.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn hist_of(xs: &[f64]) -> ExpHistogram {
        let mut h = ExpHistogram::new();
        h.push_all(xs);
        h
    }

    #[test]
    fn histogram_counts_and_skips() {
        let h = hist_of(&[1.0, 2.0, 2.5, 0.0, f64::NAN, 1e-310]);
        assert_eq!(h.total, 3);
        assert_eq!(h.skipped, 3);
        assert_eq!(h.counts[1023], 1); // 1.0
        assert_eq!(h.counts[1024], 2); // 2.0, 2.5
        assert_eq!(h.num_distinct(), 2);
        assert_eq!(h.max_exp(), Some(1024));
    }

    #[test]
    fn topk_coverage_matches_eq2() {
        // 6 values with exp 1023, 3 with 1024, 1 with 1020
        let xs = [1.0, 1.1, 1.2, 1.3, 1.4, 1.5, 2.0, 2.1, 2.2, 0.1];
        let h = hist_of(&xs);
        assert!((h.topk_coverage(1) - 0.6).abs() < 1e-12);
        assert!((h.topk_coverage(2) - 0.9).abs() < 1e-12);
        assert!((h.topk_coverage(3) - 1.0).abs() < 1e-12);
        assert!((h.topk_coverage(99) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table_orders_by_frequency_and_stores_plus_one() {
        let xs = [2.0, 2.5, 3.0, 1.0]; // exp 1024 x3, 1023 x1
        let t = GseTable::from_values(&xs, 4);
        assert_eq!(t.entries[0], 1025); // most frequent first, stored +1
        assert!(t.entries.contains(&1024));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn max_exponent_guaranteed() {
        // many small values, a single huge one; k=1 must still keep max+1
        let mut xs = vec![1.0; 100];
        xs.push(1e300);
        let t = GseTable::from_values(&xs, 1);
        let maxe = ieee::split(1e300).exp;
        assert_eq!(t.entries, vec![maxe + 1]);
        // k=2 keeps both
        let t = GseTable::from_values(&xs, 2);
        assert!(t.entries.contains(&(maxe + 1)));
        assert!(t.entries.contains(&1024));
    }

    #[test]
    fn ei_bit_widths() {
        let mk = |k: usize| {
            let entries: Vec<u32> = (0..k as u32).map(|i| 1000 + i).collect();
            GseTable::from_entries(entries).ei_bit
        };
        assert_eq!(mk(1), 1);
        assert_eq!(mk(2), 1);
        assert_eq!(mk(3), 2);
        assert_eq!(mk(4), 2);
        assert_eq!(mk(8), 3);
        assert_eq!(mk(16), 4);
        assert_eq!(mk(64), 6);
    }

    #[test]
    fn lut_matches_reference_scan() {
        let mut r = Prng::new(17);
        for _ in 0..50 {
            let k = 1 + r.below(16);
            let entries: Vec<u32> =
                (0..k).map(|_| 900 + r.below(300) as u32).collect();
            let t = GseTable::from_entries(entries);
            for exp in 850..1250u32 {
                assert_eq!(t.lookup(exp), t.lookup_scan(exp), "exp={exp} t={:?}", t.entries);
            }
        }
    }

    #[test]
    fn lookup_none_above_all_entries() {
        let t = GseTable::from_entries(vec![1024]);
        assert_eq!(t.lookup(1024), None); // needs entry >= 1025
        assert_eq!(t.lookup(1023), Some((0, 1)));
        assert_eq!(t.lookup(1000), Some((0, 24)));
    }

    #[test]
    fn exact_hit_ratio_computation() {
        let xs = [1.0, 1.5, 2.0, 4.0]; // exps 1023 x2, 1024, 1025
        let h = hist_of(&xs);
        let t = GseTable::from_entries(vec![1024, 1026]); // hits 1023(x2) and 1025
        assert!((t.exact_hit_ratio(&h) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn auto_k_picks_smallest_sufficient() {
        // two equally frequent exponents -> top-2 covers 100%
        let h = hist_of(&[1.0, 2.0, 1.5, 2.5]);
        assert_eq!(GseTable::auto_k(&h, 0.95), 2);
        // 8 exponents uniform -> need k=8 for full coverage
        let xs: Vec<f64> = (0..64).map(|i| 2f64.powi((i % 8) as i32)).collect();
        let h = hist_of(&xs);
        assert_eq!(GseTable::auto_k(&h, 0.99), 8);
        assert_eq!(GseTable::auto_k(&h, 0.5), 4);
    }

    #[test]
    fn sampled_extraction_covers_blocks() {
        let rows: Vec<Vec<f64>> = (0..100)
            .map(|i| vec![2f64.powi(i % 7), 1.0])
            .collect();
        let mut rng = Prng::new(5);
        let t = GseTable::from_sampled_rows(|i| &rows[i], 100, 8, 10, &mut rng);
        // exponent of 1.0 (1023+1) must be the most frequent entry
        assert_eq!(t.entries[0], 1024);
        assert!(t.len() <= 8);
    }

    #[test]
    fn duplicate_entries_removed() {
        let t = GseTable::from_entries(vec![1024, 1024, 1025]);
        assert_eq!(t.entries, vec![1024, 1025]);
    }
}
