//! SEM encoding — the Sign / Exponent-index / Mantissa half of GSE-SEM
//! (§III-B2, Algorithm 1) and the three-level decode (Algorithm 2).
//!
//! Two head layouts exist in the paper:
//!
//! * **Inline** (Alg. 1, used for vectors): the 16-bit head is
//!   `[sign:1][expIdx:EI_bit][mantissa:15-EI_bit]`.
//! * **External** (Alg. 2, used for sparse matrices): the 16-bit head is
//!   `[sign:1][mantissa:15]` and the exponent index travels out-of-band —
//!   packed into the top `EI_bit` bits of the CSR column index, or in a
//!   separate byte array when the column count is too large (§III-C1).
//!
//! Both layouts store the significand *denormalized*: the full 53-bit
//! significand (implicit 1 made explicit) is shifted right by
//! `minDiff = storedExp − exp ≥ 1` into a common 52-bit frame `D`, then
//! split into head / tail1 / tail2 segments (Fig. 3):
//!
//! ```text
//!  52-bit frame D:   [ head: M_h bits ][ tail1: 16 bits ][ tail2: rest ]
//!  M_h = 15 − EI_bit (inline)  or  15 (external)
//! ```
//!
//! Decoding at level L reconstructs the prefix of `D` available at that
//! level and rescales: `value = ±D_L · 2^(storedExp − 1075)`
//! (1075 = bias 1023 + mantissa width 52; the explicit-one shift is already folded into D).

use super::gse::GseTable;
use super::ieee;
use super::Precision;
use crate::util::bits::{mask64, shr64};

/// Head layout selector (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SemLayout {
    /// expIdx inside the head word (vectors; Alg. 1).
    Inline,
    /// expIdx carried out-of-band (sparse matrices; Alg. 2).
    External,
}

/// Derived bit geometry for one (layout, EI_bit) combination.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SemGeometry {
    pub layout: SemLayout,
    pub ei_bit: u32,
    /// mantissa bits held by the head
    pub m_head: u32,
    /// right-shift of the 52-bit frame that yields the head mantissa
    pub s_head: u32,
    /// right-shift that yields tail1
    pub s_tail1: u32,
    /// bit width of tail2
    pub w_tail2: u32,
}

impl SemGeometry {
    pub fn new(layout: SemLayout, ei_bit: u32) -> Self {
        assert!((1..=6).contains(&ei_bit), "EI_bit must be 1..=6");
        let m_head = match layout {
            SemLayout::Inline => 15 - ei_bit,
            SemLayout::External => 15,
        };
        let s_head = 52 - m_head; // 37 + EI_bit (inline) or 37 (external)
        let s_tail1 = s_head - 16;
        Self { layout, ei_bit, m_head, s_head, s_tail1, w_tail2: s_tail1 }
    }

    /// Mantissa bits available at a precision level (excluding the
    /// explicit leading 1, which is part of the stored bits).
    pub fn mantissa_bits(&self, level: Precision) -> u32 {
        match level {
            Precision::Head => self.m_head,
            Precision::HeadTail1 => self.m_head + 16,
            Precision::Full => 52,
        }
    }
}

/// One encoded value: 16-bit head, 16-bit tail1, up-to-27-bit tail2, and
/// the exponent index (stored in-head for Inline, returned separately for
/// External).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SemParts {
    pub head: u16,
    pub tail1: u16,
    pub tail2: u32,
    pub exp_idx: u16,
}

/// Why a value could not be encoded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EncodeError {
    /// The value's exponent exceeds every shared exponent; the table was
    /// built without seeing this magnitude (§III-B2 requires max_exp+1).
    ExponentTooLarge { biased_exp: u32 },
    /// Inf or NaN cannot be represented in GSE-SEM.
    NonFinite,
}

/// Encode one f64 (Algorithm 1). Zeros and f64-subnormals encode to an
/// all-zero mantissa (they decode to ±0).
pub fn encode(x: f64, table: &GseTable, geom: &SemGeometry) -> Result<SemParts, EncodeError> {
    debug_assert_eq!(geom.ei_bit, table.ei_bit);
    let p = ieee::split(x);
    if p.exp == ieee::EXP_SPECIAL {
        return Err(EncodeError::NonFinite);
    }
    if p.exp == 0 {
        // zero / subnormal -> canonical zero with index 0
        let head = (p.sign as u16) << 15;
        return Ok(SemParts { head, tail1: 0, tail2: 0, exp_idx: 0 });
    }
    let (idx, min_diff) = table
        .lookup(p.exp)
        .ok_or(EncodeError::ExponentTooLarge { biased_exp: p.exp })?;

    // D: explicit-one significand shifted into the common 52-bit frame.
    let d = shr64((1u64 << 52) | p.mant, min_diff as u32);

    let head_mant = (d >> geom.s_head) as u16;
    let tail1 = ((d >> geom.s_tail1) & 0xFFFF) as u16;
    let tail2 = (d & mask64(geom.w_tail2)) as u32;

    let head = match geom.layout {
        SemLayout::Inline => {
            ((p.sign as u16) << 15) | (idx << geom.m_head as u16) | head_mant
        }
        SemLayout::External => ((p.sign as u16) << 15) | head_mant,
    };
    Ok(SemParts { head, tail1, tail2, exp_idx: idx })
}

/// Reconstruct the frame prefix available at `level`.
#[inline(always)]
fn frame_at(parts: &SemParts, geom: &SemGeometry, level: Precision) -> u64 {
    let head_mant = (parts.head as u64) & mask64(geom.m_head);
    let mut d = head_mant << geom.s_head;
    if level >= Precision::HeadTail1 {
        d |= (parts.tail1 as u64) << geom.s_tail1;
    }
    if level == Precision::Full {
        d |= (parts.tail2 as u64) & mask64(geom.w_tail2);
    }
    d
}

/// Extract the exponent index from an Inline head.
#[inline(always)]
pub fn inline_exp_idx(head: u16, geom: &SemGeometry) -> u16 {
    debug_assert_eq!(geom.layout, SemLayout::Inline);
    (head >> geom.m_head) & mask64(geom.ei_bit) as u16
}

/// Sign bit of a head.
#[inline(always)]
pub fn head_sign(head: u16) -> bool {
    head & 0x8000 != 0
}

/// Fast decode: rescale the reconstructed frame with an exact `ldexp`.
/// Branch-free in the common case — this is the formulation the Pallas
/// kernel uses (TPUs have no per-lane bit scan; DESIGN.md §6).
#[inline]
pub fn decode_ldexp(
    parts: &SemParts,
    table: &GseTable,
    geom: &SemGeometry,
    level: Precision,
) -> f64 {
    let d = frame_at(parts, geom, level);
    if d == 0 {
        return 0.0;
    }
    let stored = table.stored_exp(parts.exp_idx as usize) as i32;
    let v = ieee::ldexp(d as f64, stored - 1075);
    if head_sign(parts.head) {
        -v
    } else {
        v
    }
}

/// Faithful decode replicating Algorithm 2's GPU bit-scan: find the
/// first set bit scanning down from the head's top mantissa bit,
/// renormalize, and assemble the IEEE-754 bit pattern directly.
/// Semantically identical to [`decode_ldexp`] (property-tested); kept as
/// the reference for the kernel-conversion-cost model.
pub fn decode_faithful(
    parts: &SemParts,
    table: &GseTable,
    geom: &SemGeometry,
    level: Precision,
) -> f64 {
    let d = frame_at(parts, geom, level);
    if d == 0 {
        return 0.0; // Alg. 2 line 16
    }
    // Position of the leading 1 in the 52-bit frame.
    let pos = 63 - d.leading_zeros(); // 0..=51
    let stored = table.stored_exp(parts.exp_idx as usize) as i64;
    // minDiff implied by the leading-one position:
    let min_diff = 52 - pos as i64;
    let new_exp = stored - min_diff; // == original biased exp when lossless
    let mant = (d << min_diff) & ieee::MANT_MASK; // renormalized mantissa
    if new_exp <= 0 {
        // Underflow into f64-subnormal territory: fall back to the exact
        // path (cannot assemble a normal bit pattern).
        return decode_ldexp(parts, table, geom, level);
    }
    debug_assert!(new_exp < ieee::EXP_SPECIAL as i64);
    let sign = (parts.head as u64 >> 15) << 63;
    f64::from_bits(sign | ((new_exp as u64) << 52) | mant)
}

/// Worst-case absolute representation error at a level for a value with
/// stored exponent `stored`: one unit in the last held frame bit.
pub fn ulp_at(stored_exp: u32, geom: &SemGeometry, level: Precision) -> f64 {
    let dropped_bits = match level {
        Precision::Head => geom.s_head,
        Precision::HeadTail1 => geom.s_tail1,
        Precision::Full => 0,
    };
    ieee::ldexp(1.0, stored_exp as i32 - 1075 + dropped_bits as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck;
    use crate::util::Prng;

    fn table_for(xs: &[f64], k: usize) -> GseTable {
        GseTable::from_values(xs, k)
    }

    #[test]
    fn golden_values_shared_with_python_oracle() {
        // Pinned in python/tests/test_ref.py::TestGolden — the spec the
        // three implementations meet at (DESIGN.md §8).
        let t = GseTable::from_entries(vec![1024]);
        let g = SemGeometry::new(SemLayout::External, t.ei_bit);
        let p = encode(1.5, &t, &g).unwrap();
        assert_eq!(p.head, 0x6000); // D = 3<<50, head mant = D>>37 = 3<<13
        assert_eq!((p.tail1, p.tail2, p.exp_idx), (0, 0, 0));
        assert_eq!(decode_ldexp(&p, &t, &g, Precision::Head), 1.5);
        let n = encode(-1.5, &t, &g).unwrap();
        assert_eq!(n.head, 0xE000);
    }

    #[test]
    fn geometry_inline_vs_external() {
        let gi = SemGeometry::new(SemLayout::Inline, 3);
        assert_eq!((gi.m_head, gi.s_head, gi.s_tail1, gi.w_tail2), (12, 40, 24, 24));
        let ge = SemGeometry::new(SemLayout::External, 3);
        assert_eq!((ge.m_head, ge.s_head, ge.s_tail1, ge.w_tail2), (15, 37, 21, 21));
        assert_eq!(gi.mantissa_bits(Precision::Head), 12);
        assert_eq!(gi.mantissa_bits(Precision::HeadTail1), 28);
        assert_eq!(gi.mantissa_bits(Precision::Full), 52);
    }

    #[test]
    fn exact_roundtrip_when_mantissa_fits_head() {
        // 1.5 = 1.1b: with an exact table hit (minDiff=1) the significand
        // 0b11 fits easily in any head.
        let xs = [1.5, -1.5];
        let t = table_for(&xs, 2);
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        for &x in &xs {
            let p = encode(x, &t, &g).unwrap();
            assert_eq!(decode_ldexp(&p, &t, &g, Precision::Head), x);
            assert_eq!(decode_faithful(&p, &t, &g, Precision::Head), x);
        }
    }

    #[test]
    fn zero_encodes_and_decodes_to_zero() {
        let t = table_for(&[1.0], 1);
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        for x in [0.0, -0.0, 1e-320] {
            let p = encode(x, &t, &g).unwrap();
            for lvl in Precision::LADDER {
                assert_eq!(decode_ldexp(&p, &t, &g, lvl), 0.0);
                assert_eq!(decode_faithful(&p, &t, &g, lvl), 0.0);
            }
        }
    }

    #[test]
    fn nonfinite_rejected() {
        let t = table_for(&[1.0], 1);
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        assert_eq!(encode(f64::NAN, &t, &g), Err(EncodeError::NonFinite));
        assert_eq!(encode(f64::INFINITY, &t, &g), Err(EncodeError::NonFinite));
    }

    #[test]
    fn exponent_too_large_rejected() {
        let t = GseTable::from_entries(vec![1024]); // covers exp <= 1023
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        assert!(matches!(
            encode(4.0, &t, &g), // exp 1025
            Err(EncodeError::ExponentTooLarge { biased_exp: 1025 })
        ));
        assert!(encode(1.9, &t, &g).is_ok());
    }

    #[test]
    fn full_level_error_bounded_by_one_dropped_bit() {
        // With minDiff=1 the only lost bit is mantissa bit 0: error
        // <= 2^(exp-52).
        let mut r = Prng::new(42);
        let xs: Vec<f64> = (0..1000).map(|_| r.range_f64(-8.0, 8.0)).collect();
        let t = table_for(&xs, 8);
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        for &x in &xs {
            if x == 0.0 {
                continue;
            }
            let p = encode(x, &t, &g).unwrap();
            let y = decode_ldexp(&p, &t, &g, Precision::Full);
            let stored = t.stored_exp(p.exp_idx as usize);
            let ulp = ulp_at(stored, &g, Precision::Full);
            assert!((x - y).abs() <= ulp, "x={x} y={y} ulp={ulp}");
        }
    }

    #[test]
    fn precision_levels_monotone() {
        // more tail segments -> error never grows
        let mut r = Prng::new(7);
        let xs: Vec<f64> = (0..2000).map(|_| r.lognormal(0.0, 4.0)).collect();
        let t = table_for(&xs, 8);
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        for &x in &xs {
            let p = encode(x, &t, &g).unwrap();
            let e_h = (decode_ldexp(&p, &t, &g, Precision::Head) - x).abs();
            let e_t1 = (decode_ldexp(&p, &t, &g, Precision::HeadTail1) - x).abs();
            let e_f = (decode_ldexp(&p, &t, &g, Precision::Full) - x).abs();
            assert!(e_t1 <= e_h && e_f <= e_t1, "x={x} {e_h} {e_t1} {e_f}");
        }
    }

    #[test]
    fn head_error_bound_matches_ulp_model() {
        let mut r = Prng::new(8);
        let xs: Vec<f64> = (0..2000).map(|_| r.range_f64(-100.0, 100.0)).collect();
        let t = table_for(&xs, 8);
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        for &x in &xs {
            let p = encode(x, &t, &g).unwrap();
            let y = decode_ldexp(&p, &t, &g, Precision::Head);
            let ulp = ulp_at(t.stored_exp(p.exp_idx as usize), &g, Precision::Head);
            assert!((x - y).abs() < ulp, "x={x} y={y}");
        }
    }

    #[test]
    fn faithful_equals_ldexp_property() {
        // The Alg.2 bit-scan decode and the ldexp decode are the same
        // function — over random magnitudes, layouts, k, and levels.
        quickcheck::check(
            2024,
            4000,
            |r| {
                let k = 1 + r.below(16);
                let n = 4 + r.below(60);
                let sigma = 0.5 + r.f64() * 6.0;
                let xs: Vec<f64> = (0..n)
                    .map(|_| r.lognormal(0.0, sigma) * if r.chance(0.5) { -1.0 } else { 1.0 })
                    .collect();
                let layout = if r.chance(0.5) { SemLayout::Inline } else { SemLayout::External };
                let lvl = Precision::LADDER[r.below(3)];
                (xs, k, layout, lvl)
            },
            |(xs, k, layout, lvl)| {
                let t = GseTable::from_values(xs, *k);
                let g = SemGeometry::new(*layout, t.ei_bit);
                for &x in xs {
                    let p = encode(x, &t, &g).map_err(|e| format!("{e:?}"))?;
                    let a = decode_faithful(&p, &t, &g, *lvl);
                    let b = decode_ldexp(&p, &t, &g, *lvl);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("x={x} faithful={a} ldexp={b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn denormalized_values_lose_min_diff_bits() {
        // value with exponent far below the only shared exponent: head
        // keeps fewer significant bits but magnitude survives.
        let t = GseTable::from_entries(vec![1024 + 6]); // stored for exp 1029
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        let x = 1.75; // exp 1023, minDiff = 7
        let p = encode(x, &t, &g).unwrap();
        let y = decode_ldexp(&p, &t, &g, Precision::Full);
        // lost 7 low mantissa bits; 1.75 has only 2 significant -> exact
        assert_eq!(y, x);
        // now a value needing all 52 bits is truncated but within 2^-45 rel
        let x2 = 1.0 + (1.0 - 2f64.powi(-52));
        let p2 = encode(x2, &t, &g).unwrap();
        let y2 = decode_ldexp(&p2, &t, &g, Precision::Full);
        assert!(((x2 - y2) / x2).abs() < 2f64.powi(-44));
    }

    #[test]
    fn inline_exp_idx_roundtrip() {
        // Spread entries so every lognormal(0,1) draw is representable
        // (max entry 1045 covers values up to ~2^22).
        let entries: Vec<u32> = (0..8).map(|i| 1045 - 3 * i).collect();
        let t = GseTable::from_entries(entries);
        let g = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        let mut r = Prng::new(3);
        for _ in 0..500 {
            let x = r.lognormal(0.0, 1.0);
            let p = encode(x, &t, &g).unwrap();
            assert_eq!(inline_exp_idx(p.head, &g), p.exp_idx);
        }
    }

    #[test]
    fn external_layout_has_three_more_head_bits() {
        // Same value, k=8: external head mantissa = 15 bits vs 12 inline;
        // head-level error must be <= inline's.
        let mut r = Prng::new(10);
        let xs: Vec<f64> = (0..500).map(|_| r.lognormal(0.0, 1.0)).collect();
        let t = GseTable::from_values(&xs, 8);
        let gi = SemGeometry::new(SemLayout::Inline, t.ei_bit);
        let ge = SemGeometry::new(SemLayout::External, t.ei_bit);
        let mut better = 0;
        for &x in &xs {
            let pi = encode(x, &t, &gi).unwrap();
            let pe = encode(x, &t, &ge).unwrap();
            let ei = (decode_ldexp(&pi, &t, &gi, Precision::Head) - x).abs();
            let ee = (decode_ldexp(&pe, &t, &ge, Precision::Head) - x).abs();
            assert!(ee <= ei + 1e-300, "x={x}");
            if ee < ei {
                better += 1;
            }
        }
        assert!(better > 100, "external should strictly win often: {better}");
    }
}
