//! Generic software minifloat: any (exponent bits, mantissa bits) IEEE-
//! style format with round-to-nearest-even conversion from/to f64,
//! including subnormals, infinities and NaN.
//!
//! FP16, BF16, TF32 and the two FP8 variants the paper's intro mentions
//! are instances; the FP16/BF16 instances back the baseline SpMV and
//! solver comparisons (Fig. 6/8/9, Tables III/IV).

use super::ieee;
use crate::util::bits::{mask64, round_ties_even};

/// Static description of a minifloat format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Format {
    pub name: &'static str,
    /// exponent field width in bits
    pub ebits: u32,
    /// mantissa field width in bits
    pub mbits: u32,
    /// true if the format reserves the all-ones exponent for Inf/NaN
    /// (IEEE-style). FP8-E4M3 famously does not reserve Inf.
    pub has_inf: bool,
}

/// IEEE binary16.
pub const FP16: Format = Format { name: "FP16", ebits: 5, mbits: 10, has_inf: true };
/// bfloat16.
pub const BF16: Format = Format { name: "BF16", ebits: 8, mbits: 7, has_inf: true };
/// NVIDIA TF32 (19 bits used of 32).
pub const TF32: Format = Format { name: "TF32", ebits: 8, mbits: 10, has_inf: true };
/// FP8 E4M3 (no infinities; max finite 448).
pub const FP8_E4M3: Format = Format { name: "FP8-E4M3", ebits: 4, mbits: 3, has_inf: false };
/// FP8 E5M2.
pub const FP8_E5M2: Format = Format { name: "FP8-E5M2", ebits: 5, mbits: 2, has_inf: true };

impl Format {
    /// Total storage bits (sign + exponent + mantissa).
    pub const fn bits(&self) -> u32 {
        1 + self.ebits + self.mbits
    }

    /// Exponent bias.
    pub const fn bias(&self) -> i32 {
        (1 << (self.ebits - 1)) - 1
    }

    /// Largest finite value.
    pub fn max_finite(&self) -> f64 {
        let max_exp = if self.has_inf {
            (1i32 << self.ebits) - 2 - self.bias()
        } else {
            // all-ones exponent is a normal binade; its top mantissa
            // pattern is NaN (E4M3 convention), so max mantissa is all
            // ones minus one step.
            (1i32 << self.ebits) - 1 - self.bias()
        };
        let frac_steps = if self.has_inf {
            mask64(self.mbits)
        } else {
            mask64(self.mbits) - 1
        };
        let frac = 1.0 + frac_steps as f64 / (1u64 << self.mbits) as f64;
        ieee::ldexp(frac, max_exp)
    }

    /// Smallest positive normal value.
    pub fn min_normal(&self) -> f64 {
        ieee::ldexp(1.0, 1 - self.bias())
    }

    /// Smallest positive subnormal value.
    pub fn min_subnormal(&self) -> f64 {
        ieee::ldexp(1.0, 1 - self.bias() - self.mbits as i32)
    }

    /// Encode an f64 into this format's bit pattern (round to nearest
    /// even, overflow to Inf — or to NaN for formats without Inf).
    pub fn encode(&self, x: f64) -> u32 {
        let p = ieee::split(x);
        let sign = (p.sign as u32) << (self.ebits + self.mbits);
        let exp_all1 = mask64(self.ebits) as u32;

        if x.is_nan() {
            // canonical quiet NaN: all-ones exponent, top mantissa bit
            // (for E4M3: all-ones everything)
            return if self.has_inf {
                sign | (exp_all1 << self.mbits) | (1 << (self.mbits - 1))
            } else {
                sign | (exp_all1 << self.mbits) | mask64(self.mbits) as u32
            };
        }
        if x.is_infinite() {
            return if self.has_inf {
                sign | (exp_all1 << self.mbits)
            } else {
                // saturate to NaN-adjacent max? E4M3 overflows to NaN.
                sign | (exp_all1 << self.mbits) | mask64(self.mbits) as u32
            };
        }
        if x == 0.0 {
            return sign;
        }

        // Effective unbiased exponent and 53-bit significand of |x|,
        // normalizing f64 subnormals.
        let (e, sig) = if p.exp == 0 {
            // f64 subnormal: normalize
            let shift = p.mant.leading_zeros() - 11; // bring MSB to bit 52
            (
                1 - ieee::BIAS - shift as i32,
                (p.mant << shift) & ieee::MANT_MASK | (1u64 << 52),
            )
        } else {
            (p.exp as i32 - ieee::BIAS, p.mant | (1u64 << 52))
        };

        let bias = self.bias();
        let mut target_exp = e + bias; // tentative biased exponent

        // Subnormal in the target format: shift the significand right so
        // the exponent field becomes 0. The subnormal ULP is
        // 2^(1 − bias − mbits), so frac = sig · 2^(e − 52) / ulp
        // = sig >> (52 + extra − mbits) with extra = 1 − target_exp.
        let (frac, carried) = if target_exp <= 0 {
            let extra = (1 - target_exp) as u32;
            let total_drop = 52 + extra - self.mbits;
            if total_drop >= 64 {
                return sign; // far below the smallest subnormal: 0
            }
            // Emulate the extra shift by treating sig as (52+extra)-wide.
            let (f, c) = round_ties_even(sig, 52 + extra, self.mbits);
            target_exp = 0;
            (f, c)
        } else {
            round_ties_even(sig, 53, self.mbits + 1)
        };

        if target_exp > 0 {
            // Normal path: frac has mbits+1 bits with the leading 1.
            let mut frac = frac;
            let mut texp = target_exp;
            if carried {
                texp += 1;
            }
            // Remove the implicit leading one.
            frac &= mask64(self.mbits);
            if texp >= exp_all1 as i32 {
                // overflow
                return if self.has_inf {
                    sign | (exp_all1 << self.mbits)
                } else if texp == exp_all1 as i32 && frac != mask64(self.mbits) as u32 as u64 {
                    sign | (exp_all1 << self.mbits) | frac as u32
                } else {
                    sign | (exp_all1 << self.mbits) | mask64(self.mbits) as u32 // NaN (E4M3)
                };
            }
            sign | ((texp as u32) << self.mbits) | frac as u32
        } else {
            // Subnormal result; a carry promotes it to the min normal
            // (round_ties_even reports the carry after folding the value
            // back down, so the promoted significand is exactly 1.0).
            let (frac, texp) = if carried {
                (0u64, 1u32)
            } else if frac >> self.mbits != 0 {
                (frac & mask64(self.mbits), 1u32)
            } else {
                (frac, 0u32)
            };
            sign | (texp << self.mbits) | frac as u32
        }
    }

    /// Decode this format's bit pattern to f64 (exact).
    pub fn decode(&self, bits: u32) -> f64 {
        let sign = if bits >> (self.ebits + self.mbits) & 1 == 1 { -1.0 } else { 1.0 };
        let exp = (bits >> self.mbits) & mask64(self.ebits) as u32;
        let frac = (bits & mask64(self.mbits) as u32) as u64;
        let exp_all1 = mask64(self.ebits) as u32;

        if exp == exp_all1 && self.has_inf {
            return if frac == 0 { sign * f64::INFINITY } else { f64::NAN };
        }
        if exp == exp_all1 && !self.has_inf && frac == mask64(self.mbits) {
            return f64::NAN; // E4M3 NaN
        }
        if exp == 0 {
            // subnormal (or zero)
            let v = frac as f64 / (1u64 << self.mbits) as f64;
            return sign * ieee::ldexp(v, 1 - self.bias());
        }
        let v = 1.0 + frac as f64 / (1u64 << self.mbits) as f64;
        sign * ieee::ldexp(v, exp as i32 - self.bias())
    }

    /// Round an f64 through this format (encode + decode).
    pub fn round(&self, x: f64) -> f64 {
        self.decode(self.encode(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn fp16_known_values() {
        assert_eq!(FP16.encode(1.0), 0x3C00);
        assert_eq!(FP16.encode(-2.0), 0xC000);
        assert_eq!(FP16.encode(0.5), 0x3800);
        assert_eq!(FP16.decode(0x3C00), 1.0);
        assert_eq!(FP16.decode(0x7C00), f64::INFINITY);
        assert!(FP16.decode(0x7E00).is_nan());
        assert_eq!(FP16.max_finite(), 65504.0);
        assert_eq!(FP16.min_normal(), 6.103515625e-05);
    }

    #[test]
    fn bf16_known_values() {
        // bf16 is the top 16 bits of f32 for exactly-representable values
        assert_eq!(BF16.encode(1.0), 0x3F80);
        assert_eq!(BF16.encode(-1.0), 0xBF80);
        assert_eq!(BF16.decode(0x3F80), 1.0);
        assert!(BF16.max_finite() > 3.3e38 && BF16.max_finite() < 3.4e38);
    }

    #[test]
    fn fp16_overflow_to_inf() {
        assert_eq!(FP16.decode(FP16.encode(1e6)), f64::INFINITY);
        assert_eq!(FP16.decode(FP16.encode(-1e6)), f64::NEG_INFINITY);
        // BF16 handles the same magnitude fine
        assert!((BF16.round(1e6) - 1e6).abs() / 1e6 < 0.01);
    }

    #[test]
    fn fp16_subnormals() {
        let tiny = FP16.min_subnormal();
        assert!(tiny > 0.0);
        assert_eq!(FP16.round(tiny), tiny);
        assert_eq!(FP16.round(tiny / 3.0), 0.0);
        // halfway between 0 and min_subnormal rounds to even (0)
        assert_eq!(FP16.round(tiny / 2.0), 0.0);
        assert_eq!(FP16.round(tiny * 1.5), tiny * 2.0); // tie to even
    }

    #[test]
    fn roundtrip_exhaustive_fp16() {
        // every finite FP16 pattern decodes then re-encodes to itself
        for bits in 0u32..=0xFFFF {
            let v = FP16.decode(bits);
            if v.is_nan() {
                continue;
            }
            let re = FP16.encode(v);
            // -0.0 and 0.0 both fine, compare decoded values
            assert_eq!(
                FP16.decode(re).to_bits(),
                v.to_bits(),
                "bits={bits:#06x} v={v}"
            );
        }
    }

    #[test]
    fn roundtrip_exhaustive_bf16() {
        for bits in 0u32..=0xFFFF {
            let v = BF16.decode(bits);
            if v.is_nan() {
                continue;
            }
            assert_eq!(BF16.decode(BF16.encode(v)).to_bits(), v.to_bits(), "bits={bits:#06x}");
        }
    }

    #[test]
    fn rounding_is_nearest_even_fp16() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 -> even (1.0)
        assert_eq!(FP16.round(1.0 + 2f64.powi(-11)), 1.0);
        // slightly above goes up
        assert_eq!(FP16.round(1.0 + 2f64.powi(-11) + 1e-10), 1.0 + 2f64.powi(-10));
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 -> even (1+2^-9)
        assert_eq!(FP16.round(1.0 + 3.0 * 2f64.powi(-11)), 1.0 + 2.0 * 2f64.powi(-10));
    }

    #[test]
    fn rounding_error_bounded_random() {
        let mut r = Prng::new(1234);
        for _ in 0..20_000 {
            let x = r.lognormal(0.0, 3.0) * if r.chance(0.5) { -1.0 } else { 1.0 };
            for f in [FP16, BF16, TF32, FP8_E5M2] {
                let y = f.round(x);
                // The relative-error bound only holds for normal results;
                // subnormals trade relative precision for gradual underflow.
                if y.is_finite() && y.abs() >= f.min_normal() {
                    let rel = ((y - x) / x).abs();
                    let ulp = 2f64.powi(-(f.mbits as i32));
                    assert!(rel <= ulp, "{} x={x} y={y} rel={rel}", f.name);
                }
            }
        }
    }

    #[test]
    fn fp8_e4m3_max_is_448() {
        assert_eq!(FP8_E4M3.max_finite(), 448.0);
        // E4M3 overflows to NaN, not Inf
        assert!(FP8_E4M3.round(1000.0).is_nan());
    }

    #[test]
    fn decode_encode_monotone_fp16() {
        // format rounding must be monotone non-decreasing
        let mut r = Prng::new(77);
        for _ in 0..5_000 {
            let a = r.range_f64(-100.0, 100.0);
            let b = a + r.f64().abs();
            assert!(FP16.round(a) <= FP16.round(b), "a={a} b={b}");
        }
    }
}
