//! IEEE-754 double-precision bit-level utilities.
//!
//! Everything in the GSE-SEM pipeline works on the `(sign, biased
//! exponent, 52-bit mantissa)` decomposition of `f64`; this module is the
//! single place those bit conventions live.

/// Number of mantissa bits in f64.
pub const MANT_BITS: u32 = 52;
/// f64 exponent bias.
pub const BIAS: i32 = 1023;
/// Mask of the 52 mantissa bits.
pub const MANT_MASK: u64 = (1u64 << MANT_BITS) - 1;
/// Biased exponent of Inf/NaN.
pub const EXP_SPECIAL: u32 = 0x7FF;

/// Decomposed f64: sign (0/1), biased exponent (0..=2047), mantissa.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct F64Parts {
    pub sign: u32,
    pub exp: u32,
    pub mant: u64,
}

/// Split an f64 into its bit fields.
#[inline(always)]
pub fn split(x: f64) -> F64Parts {
    let b = x.to_bits();
    F64Parts {
        sign: (b >> 63) as u32,
        exp: ((b >> MANT_BITS) & 0x7FF) as u32,
        mant: b & MANT_MASK,
    }
}

/// Reassemble an f64 from bit fields.
#[inline(always)]
pub fn join(p: F64Parts) -> f64 {
    debug_assert!(p.sign <= 1 && p.exp <= 0x7FF && p.mant <= MANT_MASK);
    f64::from_bits(((p.sign as u64) << 63) | ((p.exp as u64) << MANT_BITS) | p.mant)
}

/// Is the value zero, subnormal, infinite, or NaN? (The GSE-SEM encoder
/// treats these specially: zeros/subnormals truncate to 0; Inf/NaN are
/// rejected at table-build time.)
#[inline(always)]
pub fn is_normal_nonzero(x: f64) -> bool {
    let e = split(x).exp;
    e != 0 && e != EXP_SPECIAL
}

/// Exact `x * 2^e` handling the full double range including gradual
/// underflow (std has no `ldexp`).
#[inline]
pub fn ldexp(x: f64, e: i32) -> f64 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    // Fast path: result stays comfortably in the normal range.
    if (-1000..=1000).contains(&e) {
        let p = split(x);
        let new_e = p.exp as i32 + e;
        if p.exp != 0 && new_e > 0 && new_e < EXP_SPECIAL as i32 {
            return join(F64Parts { sign: p.sign, exp: new_e as u32, mant: p.mant });
        }
    }
    // Slow path: split the scale into two (or three) in-range factors.
    let mut r = x;
    let mut rem = e;
    while rem != 0 {
        let step = rem.clamp(-1000, 1000);
        r *= pow2(step);
        rem -= step;
        if r == 0.0 || r.is_infinite() {
            return r;
        }
    }
    r
}

/// 2^e as f64 for e in the normal range [-1022, 1023]; saturates outside.
#[inline]
fn pow2(e: i32) -> f64 {
    if e < -1074 {
        0.0
    } else if e < -1022 {
        // subnormal power of two
        f64::from_bits(1u64 << (e + 1074))
    } else if e <= 1023 {
        join(F64Parts { sign: 0, exp: (e + BIAS) as u32, mant: 0 })
    } else {
        f64::INFINITY
    }
}

/// Unbiased exponent of a normal f64 (floor(log2|x|)).
#[inline]
pub fn exponent_of(x: f64) -> i32 {
    split(x).exp as i32 - BIAS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn split_join_roundtrip_specials() {
        for x in [0.0, -0.0, 1.0, -1.0, 0.5, 3.5, f64::MAX, f64::MIN_POSITIVE, 1e-310] {
            assert_eq!(join(split(x)).to_bits(), x.to_bits(), "x={x}");
        }
    }

    #[test]
    fn split_known_values() {
        let p = split(1.0);
        assert_eq!((p.sign, p.exp, p.mant), (0, 1023, 0));
        let p = split(-2.0);
        assert_eq!((p.sign, p.exp, p.mant), (1, 1024, 0));
        let p = split(1.5);
        assert_eq!((p.sign, p.exp, p.mant), (0, 1023, 1u64 << 51));
    }

    #[test]
    fn normal_nonzero_classification() {
        assert!(is_normal_nonzero(1.0));
        assert!(is_normal_nonzero(-1e300));
        assert!(!is_normal_nonzero(0.0));
        assert!(!is_normal_nonzero(1e-310)); // subnormal
        assert!(!is_normal_nonzero(f64::INFINITY));
        assert!(!is_normal_nonzero(f64::NAN));
    }

    #[test]
    fn ldexp_matches_multiplication_in_range() {
        let mut r = Prng::new(99);
        for _ in 0..10_000 {
            let x = r.range_f64(-10.0, 10.0);
            let e = r.range_i64(-60, 60) as i32;
            let want = x * 2f64.powi(e);
            assert_eq!(ldexp(x, e).to_bits(), want.to_bits(), "x={x} e={e}");
        }
    }

    #[test]
    fn ldexp_underflow_and_overflow() {
        assert_eq!(ldexp(1.0, -1080), 0.0);
        assert!(ldexp(1.0, -1060) > 0.0); // subnormal, not zero
        assert!(ldexp(1.0, 2000).is_infinite());
        assert_eq!(ldexp(0.0, 500), 0.0);
        // gradual underflow exactness
        assert_eq!(ldexp(1.5, -1040), 1.5 * pow2(-1040));
    }

    #[test]
    fn exponent_of_known() {
        assert_eq!(exponent_of(1.0), 0);
        assert_eq!(exponent_of(0.75), -1);
        assert_eq!(exponent_of(1024.0), 10);
    }
}
