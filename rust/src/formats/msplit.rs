//! Mantissa-segmentation format of Grützmacher et al. [17] — the
//! related-work baseline the paper builds on (§V-A): an FP64 value is
//! split into two 32-bit segments; low-precision consumers read only the
//! head (top 32 bits — sign, full 11-bit exponent, 20 mantissa bits),
//! high-precision consumers concatenate head and tail.
//!
//! Contrast with GSE-SEM: the head here is twice as wide (32 vs 16 bits
//! of traffic) but needs no shared-exponent table and no denormalized
//! mantissa — the ablation bench quantifies that trade
//! (`ablation_msplit`).

/// A dense f64 vector stored as 32-bit head/tail segment planes.
#[derive(Clone, Debug)]
pub struct SplitF64Vector {
    pub head: Vec<u32>,
    pub tail: Vec<u32>,
}

/// Read precision for the split format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitLevel {
    /// top 32 bits only (sign + exponent + 20 mantissa bits)
    Head,
    /// full 64 bits
    Full,
}

impl SplitLevel {
    pub fn bytes_per_value(self) -> usize {
        match self {
            SplitLevel::Head => 4,
            SplitLevel::Full => 8,
        }
    }
}

/// Split one value.
#[inline(always)]
pub fn split(x: f64) -> (u32, u32) {
    let b = x.to_bits();
    ((b >> 32) as u32, b as u32)
}

/// Reassemble at a level (head-only truncates the low mantissa bits).
#[inline(always)]
pub fn join(head: u32, tail: u32, level: SplitLevel) -> f64 {
    let bits = match level {
        SplitLevel::Head => (head as u64) << 32,
        SplitLevel::Full => ((head as u64) << 32) | tail as u64,
    };
    f64::from_bits(bits)
}

impl SplitF64Vector {
    pub fn encode(xs: &[f64]) -> Self {
        let mut head = Vec::with_capacity(xs.len());
        let mut tail = Vec::with_capacity(xs.len());
        for &x in xs {
            let (h, t) = split(x);
            head.push(h);
            tail.push(t);
        }
        Self { head, tail }
    }

    pub fn len(&self) -> usize {
        self.head.len()
    }

    pub fn is_empty(&self) -> bool {
        self.head.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize, level: SplitLevel) -> f64 {
        join(self.head[i], self.tail[i], level)
    }

    pub fn decode(&self, level: SplitLevel) -> Vec<f64> {
        (0..self.len()).map(|i| self.get(i, level)).collect()
    }

    pub fn max_abs_error(&self, original: &[f64], level: SplitLevel) -> f64 {
        original
            .iter()
            .enumerate()
            .map(|(i, &x)| (x - self.get(i, level)).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn full_roundtrip_is_exact() {
        let mut r = Prng::new(3);
        let xs: Vec<f64> = (0..1000)
            .map(|_| r.lognormal(0.0, 10.0) * if r.chance(0.5) { -1.0 } else { 1.0 })
            .collect();
        let v = SplitF64Vector::encode(&xs);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(v.get(i, SplitLevel::Full).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn head_keeps_20_mantissa_bits() {
        let mut r = Prng::new(4);
        for _ in 0..2000 {
            let x = r.lognormal(0.0, 5.0);
            let (h, t) = split(x);
            let y = join(h, t, SplitLevel::Head);
            assert!(((x - y) / x).abs() < 2f64.powi(-20), "x={x} y={y}");
            // truncation: |y| <= |x|
            assert!(y.abs() <= x.abs());
        }
    }

    #[test]
    fn head_preserves_sign_and_exponent_exactly() {
        for x in [1e-300, -1e300, 0.5, -3.0, 0.0] {
            let (h, t) = split(x);
            let y = join(h, t, SplitLevel::Head);
            assert_eq!(y.signum().to_bits(), x.signum().to_bits());
            if x != 0.0 {
                assert_eq!(
                    crate::formats::ieee::split(x).exp,
                    crate::formats::ieee::split(y).exp
                );
            }
        }
    }

    #[test]
    fn error_comparison_vs_gse_head() {
        // GSE-SEM head (15 mantissa bits, 2 B) vs split head (20 bits,
        // 4 B): split is more precise per value, GSE cheaper per byte.
        let mut r = Prng::new(5);
        let xs: Vec<f64> = (0..3000).map(|_| 1.0 + r.f64()).collect(); // one binade
        let sp = SplitF64Vector::encode(&xs);
        let gse = crate::formats::SemVector::encode(&xs, 8);
        let e_split = sp.max_abs_error(&xs, SplitLevel::Head);
        let e_gse = gse.max_abs_error(&xs, crate::formats::Precision::Head);
        assert!(e_split < e_gse); // 20 vs ~12-14 effective bits
        // but per-byte, GSE reads half the value traffic
        assert!(gse.read_bytes(crate::formats::Precision::Head) < 4 * xs.len() + 1);
    }
}
