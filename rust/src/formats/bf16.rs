//! Concrete bfloat16 storage type used by the BF16-SpMV baseline.
//! Storage/transfer format only, like [`super::fp16::Fp16`].

use super::minifloat::BF16;

/// A 16-bit bfloat16 value (storage only).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Round an f64 to the nearest representable bfloat16 (ties to even).
    #[inline]
    pub fn from_f64(x: f64) -> Self {
        Bf16(BF16.encode(x) as u16)
    }

    /// Exact widening conversion. bfloat16 is the top half of an IEEE
    /// f32, so widening is a single shift — the hot-path formulation
    /// (hardware does exactly this).
    #[inline(always)]
    pub fn to_f64(self) -> f64 {
        f32::from_bits((self.0 as u32) << 16) as f64
    }

    /// Reference widening through the generic minifloat decoder (tests).
    pub fn to_f64_reference(self) -> f64 {
        BF16.decode(self.0 as u32)
    }

    pub fn is_nan(self) -> bool {
        self.to_f64().is_nan()
    }

    pub fn is_infinite(self) -> bool {
        self.to_f64().is_infinite()
    }

    /// Convert a whole slice (the baseline matrix-conversion path).
    pub fn encode_slice(xs: &[f64]) -> Vec<Bf16> {
        xs.iter().map(|&x| Bf16::from_f64(x)).collect()
    }
}

impl From<f64> for Bf16 {
    fn from(x: f64) -> Self {
        Bf16::from_f64(x)
    }
}

impl From<Bf16> for f64 {
    fn from(h: Bf16) -> f64 {
        h.to_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shift_widen_matches_reference_exhaustively() {
        for bits in 0u16..=u16::MAX {
            let b = Bf16(bits);
            let (x, y) = (b.to_f64(), b.to_f64_reference());
            assert!(x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()), "bits={bits:#06x}");
        }
    }

    #[test]
    fn bf16_equals_truncated_f32_semantics() {
        // For values exactly representable in bf16, conversion is exact.
        for x in [1.0, -2.0, 0.15625, 1.5 * 2f64.powi(127)] {
            assert_eq!(Bf16::from_f64(x).to_f64(), x);
        }
    }

    #[test]
    fn bf16_wide_range_no_overflow() {
        // The FP16-killer cases survive in bf16.
        for x in [1e6, 1e20, 1e-20, -1e30] {
            let y = Bf16::from_f64(x).to_f64();
            assert!(y.is_finite());
            assert!(((y - x) / x).abs() < 0.01, "x={x} y={y}");
        }
    }

    #[test]
    fn relative_error_bound() {
        // bf16 has 7 mantissa bits -> rel err <= 2^-8 for RNE
        let mut r = crate::util::Prng::new(31);
        for _ in 0..5_000 {
            let x = r.lognormal(0.0, 5.0);
            let y = Bf16::from_f64(x).to_f64();
            assert!(((y - x) / x).abs() <= 2f64.powi(-8), "x={x}");
        }
    }
}
