//! Information-entropy analysis of floating-point populations — the §II
//! motivation study (Fig. 1a): entropy of values, exponent fields, and
//! mantissa fields of a matrix's non-zeros.

use super::ieee;
use crate::util::stats::entropy_from_counts;
use std::collections::HashMap;

/// Entropies (bits) of the three bit-field populations of a value set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EntropyReport {
    pub value_bits: f64,
    pub exponent_bits: f64,
    pub mantissa_bits: f64,
    pub n: usize,
}

/// Compute the paper's Fig. 1(a) entropies for a set of values.
/// Only true zeros and non-finite values are excluded (sparse-matrix
/// non-zeros); subnormals count toward `n` and populate the exponent
/// field 0 bin they actually encode.
pub fn analyze(xs: &[f64]) -> EntropyReport {
    let mut value_counts: HashMap<u64, u64> = HashMap::new();
    let mut mant_counts: HashMap<u64, u64> = HashMap::new();
    let mut exp_counts = vec![0u64; 2048];
    let mut n = 0usize;
    for &x in xs {
        if x == 0.0 || !x.is_finite() {
            continue;
        }
        let p = ieee::split(x);
        *value_counts.entry(x.to_bits()).or_insert(0) += 1;
        *mant_counts.entry(p.mant).or_insert(0) += 1;
        exp_counts[p.exp as usize] += 1;
        n += 1;
    }
    let vals: Vec<u64> = value_counts.into_values().collect();
    let mants: Vec<u64> = mant_counts.into_values().collect();
    EntropyReport {
        value_bits: entropy_from_counts(&vals),
        exponent_bits: entropy_from_counts(&exp_counts),
        mantissa_bits: entropy_from_counts(&mants),
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn constant_vector_has_zero_entropy() {
        let r = analyze(&[2.5; 1000]);
        assert_eq!(r.value_bits, 0.0);
        assert_eq!(r.exponent_bits, 0.0);
        assert_eq!(r.mantissa_bits, 0.0);
        assert_eq!(r.n, 1000);
    }

    #[test]
    fn value_entropy_close_to_mantissa_entropy_for_clustered_exponents() {
        // The paper's key observation: random mantissas within one binade
        // -> value entropy == mantissa entropy, exponent entropy == 0.
        let mut rng = Prng::new(4);
        let xs: Vec<f64> = (0..5000).map(|_| 1.0 + rng.f64()).collect();
        let r = analyze(&xs);
        assert_eq!(r.exponent_bits, 0.0);
        assert!((r.value_bits - r.mantissa_bits).abs() < 1e-9);
        assert!(r.value_bits > 10.0); // ~log2(5000) distinct
    }

    #[test]
    fn wide_exponent_range_raises_exponent_entropy() {
        let mut rng = Prng::new(5);
        let xs: Vec<f64> = (0..4096).map(|_| rng.lognormal(0.0, 40.0)).collect();
        let r = analyze(&xs);
        assert!(r.exponent_bits > 4.0, "exp entropy {}", r.exponent_bits);
    }

    #[test]
    fn skips_zeros_and_nonfinite() {
        let r = analyze(&[0.0, f64::NAN, f64::INFINITY, 1.0, 2.0]);
        assert_eq!(r.n, 2);
        assert_eq!(r.exponent_bits, 1.0); // two equally likely exponents
    }

    #[test]
    fn counts_subnormals_in_the_zero_exponent_bin() {
        // Regression: subnormals were silently dropped, so an ill-scaled
        // population reported too-low n and skewed exponent entropy —
        // exactly the inputs where a format policy must see the full
        // dynamic range. Subnormals carry exponent field 0.
        let sub = f64::MIN_POSITIVE / 4.0; // 2^-1024, subnormal
        debug_assert!(sub.is_subnormal());
        let xs = [sub, 2.0 * sub, -sub, 1.0, 2.0, 0.0, f64::NAN];
        let r = analyze(&xs);
        assert_eq!(r.n, 5, "subnormal non-zeros must count");
        // exponent population {0: 3, 1023: 1, 1024: 1}
        assert!(
            (r.exponent_bits - entropy_from_counts(&[3, 1, 1])).abs() < 1e-12,
            "exp entropy {}",
            r.exponent_bits
        );
        // an all-subnormal population shares one exponent field
        let only = analyze(&[sub, 2.0 * sub, 3.0 * sub]);
        assert_eq!(only.n, 3);
        assert_eq!(only.exponent_bits, 0.0);
    }
}
