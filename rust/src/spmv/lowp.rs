//! Low-precision-stored SpMV baselines (FP16-SpMV / BF16-SpMV / FP32):
//! "all non-zero elements are stored and loaded in FP16 or BF16 format,
//! then converted to FP64 and multiplied by the double-precision vector.
//! All intermediate results are accumulated in double precision" (§IV-C).

use super::{SpmvOp, ThreadBudget};
use crate::formats::{Bf16, Fp16, ValueFormat};
use crate::sparse::csr::Csr;
use crate::util::parallel;
use std::ops::Range;

/// A value type that can stand in for the matrix values of an SpMV.
pub trait StoredValue: Copy + Send + Sync + 'static {
    const FORMAT: ValueFormat;
    const BYTES: usize;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
}

impl StoredValue for Fp16 {
    const FORMAT: ValueFormat = ValueFormat::Fp16;
    const BYTES: usize = 2;
    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        Fp16::from_f64(x)
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        Fp16::to_f64(self)
    }
}

impl StoredValue for Bf16 {
    const FORMAT: ValueFormat = ValueFormat::Bf16;
    const BYTES: usize = 2;
    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        Bf16::from_f64(x)
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        Bf16::to_f64(self)
    }
}

impl StoredValue for f32 {
    const FORMAT: ValueFormat = ValueFormat::Fp32;
    const BYTES: usize = 4;
    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

/// CSR matrix whose values are stored in a reduced-precision format.
pub struct LowpCsr<T: StoredValue> {
    pub nrows: usize,
    pub ncols: usize,
    pub rowptr: Vec<usize>,
    pub colidx: Vec<u32>,
    pub vals: Vec<T>,
    /// true if any finite value overflowed to ±Inf in conversion (the
    /// paper's "/" rows in Tables III/IV)
    pub overflowed: bool,
    /// Runtime-reconfigurable worker count (1 = serial; see
    /// [`crate::util::parallel`] and [`SpmvOp::set_threads`]).
    pub threads: ThreadBudget,
}

impl<T: StoredValue> LowpCsr<T> {
    pub fn from_csr(a: &Csr) -> Self {
        let vals: Vec<T> = a.vals.iter().map(|&v| T::from_f64(v)).collect();
        let overflowed = a
            .vals
            .iter()
            .zip(&vals)
            .any(|(&orig, lv)| orig.is_finite() && !lv.to_f64().is_finite());
        Self {
            nrows: a.nrows,
            ncols: a.ncols,
            rowptr: a.rowptr.clone(),
            colidx: a.colidx.clone(),
            vals,
            overflowed,
            threads: ThreadBudget::new(1),
        }
    }

    /// Set the SpMV worker count (1 = serial). Any count produces
    /// bit-for-bit the serial result — rows never split across threads.
    /// Installs a fresh [`ThreadBudget`] handle; use
    /// [`SpmvOp::set_threads`] to retune post-build.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = ThreadBudget::new(threads);
        self
    }

    /// SpMV with f64 accumulation; chunk-parallel over nnz-balanced row
    /// ranges when `threads` > 1 (the shared [`parallel`] hot path).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        let threads = self.threads.get();
        if threads <= 1 || self.nrows < super::par_min_rows() {
            return self.spmv_range(x, 0..self.nrows, y);
        }
        let chunks = parallel::balance_by_weight(self.nrows, threads, |r| {
            self.rowptr[r + 1] - self.rowptr[r]
        });
        parallel::for_each_disjoint(y, &chunks, |ch, ys| self.spmv_range(x, ch, ys));
    }

    /// One row-range of the SpMV; `y[i]` receives row `rows.start + i`.
    fn spmv_range(&self, x: &[f64], rows: Range<usize>, y: &mut [f64]) {
        for (i, r) in rows.enumerate() {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            let mut sum = 0.0;
            for k in a..b {
                sum += self.vals[k].to_f64() * x[self.colidx[k] as usize];
            }
            y[i] = sum;
        }
    }

    /// Fused multi-RHS SpMV over column-major packed vectors (layout in
    /// [`SpmvOp::apply_multi`]): each stored value is loaded and widened
    /// to f64 **once**, then broadcast through the [`super::tile`]
    /// register tiles across all RHS. Bit-for-bit identical to `nrhs`
    /// single [`LowpCsr::spmv`] calls.
    pub fn spmv_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        assert_eq!(x.len(), self.ncols * nrhs);
        assert_eq!(y.len(), self.nrows * nrhs);
        if nrhs == 0 {
            return;
        }
        let parts = super::multi_parts(self.threads.get(), self.nrows, nrhs);
        let chunks = parallel::balance_by_weight(self.nrows, parts, |r| {
            self.rowptr[r + 1] - self.rowptr[r]
        });
        parallel::for_each_disjoint_cols(y, self.nrows, &chunks, |ch, cols| {
            let mut acc = vec![0.0f64; nrhs];
            for (i, r) in ch.enumerate() {
                let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
                acc.fill(0.0);
                for k in a..b {
                    let v = self.vals[k].to_f64();
                    super::tile::fma_lanes(&mut acc, v, x, self.colidx[k] as usize, self.ncols);
                }
                for (j, aj) in acc.iter().enumerate() {
                    cols[j][i] = *aj;
                }
            }
        });
    }
}

impl<T: StoredValue> SpmvOp for LowpCsr<T> {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y);
    }

    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        self.spmv_multi(x, y, nrhs);
    }

    fn set_threads(&self, threads: usize) {
        self.threads.set(threads);
    }

    fn threads(&self) -> usize {
        self.threads.get()
    }

    fn nrows(&self) -> usize {
        self.nrows
    }

    fn ncols(&self) -> usize {
        self.ncols
    }

    fn format(&self) -> ValueFormat {
        T::FORMAT
    }

    fn matrix_bytes(&self) -> usize {
        self.vals.len() * (T::BYTES + 4) + (self.nrows + 1) * 8
    }

    fn encoded_bytes(&self) -> usize {
        // single-plane CSR: resident storage equals per-apply traffic
        self.matrix_bytes()
    }

    fn spill_bytes(&self) -> Option<Vec<u8>> {
        let tag = match T::FORMAT {
            ValueFormat::Fp32 => super::spill_tag::FP32,
            ValueFormat::Fp16 => super::spill_tag::FP16,
            ValueFormat::Bf16 => super::spill_tag::BF16,
            _ => return None,
        };
        // values round-trip through f64 losslessly (each stored format
        // is a strict subset of f64), so one layout covers all three
        let mut w = crate::util::codec::ByteWriter::new();
        w.put_u8(tag);
        w.put_u64(self.nrows as u64);
        w.put_u64(self.ncols as u64);
        w.put_usizes(&self.rowptr);
        w.put_u32s(&self.colidx);
        let vals: Vec<f64> = self.vals.iter().map(|v| v.to_f64()).collect();
        w.put_f64s(&vals);
        w.put_u8(self.overflowed as u8);
        Some(w.into_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::sparse::gen::randmat::{exp_controlled, ExpLaw};
    use crate::spmv::fp64;
    use crate::util::Prng;

    #[test]
    fn exact_on_representable_values() {
        let a = poisson2d(10, 10);
        let mut rng = Prng::new(9);
        let x: Vec<f64> = (0..a.ncols).map(|_| (rng.below(64) as f64) - 32.0).collect();
        let mut y64 = vec![0.0; a.nrows];
        fp64::spmv(&a, &x, &mut y64);
        let h = LowpCsr::<Fp16>::from_csr(&a);
        let b = LowpCsr::<Bf16>::from_csr(&a);
        let s = LowpCsr::<f32>::from_csr(&a);
        for op in [&h as &dyn SpmvOp, &b, &s] {
            let mut y = vec![0.0; a.nrows];
            op.apply(&x, &mut y);
            assert_eq!(y, y64, "{:?}", op.format());
        }
    }

    #[test]
    fn error_ordering_fp16_worst() {
        // wide-magnitude values: fp16 error >= bf16 storage has fewer
        // mantissa bits but fp16 saturates range; use in-range values so
        // pure mantissa precision shows: bf16 (8 bits) < fp16 (11 bits).
        let a = exp_controlled(100, 100, 6, ExpLaw::Gaussian { e0: 0, sigma: 2.0 }, 10);
        let x = vec![1.0; 100];
        let mut y64 = vec![0.0; 100];
        fp64::spmv(&a, &x, &mut y64);
        let mut yh = vec![0.0; 100];
        LowpCsr::<Fp16>::from_csr(&a).spmv(&x, &mut yh);
        let mut yb = vec![0.0; 100];
        LowpCsr::<Bf16>::from_csr(&a).spmv(&x, &mut yb);
        let eh = crate::spmv::max_abs_diff(&y64, &yh);
        let eb = crate::spmv::max_abs_diff(&y64, &yb);
        // fp16 has 11-bit mantissa vs bf16's 8: fp16 closer in-range
        assert!(eh < eb, "fp16 err {eh} vs bf16 err {eb}");
        assert!(eh > 0.0);
    }

    #[test]
    fn parallel_spmv_bit_exact_vs_serial() {
        let a = exp_controlled(1400, 1400, 5, ExpLaw::Gaussian { e0: 0, sigma: 2.0 }, 3);
        let mut rng = Prng::new(4);
        let x: Vec<f64> = (0..a.ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let serial = LowpCsr::<Bf16>::from_csr(&a);
        let mut y1 = vec![0.0; a.nrows];
        serial.spmv(&x, &mut y1);
        for threads in [1usize, 3, 6] {
            let par = LowpCsr::<Bf16>::from_csr(&a).with_threads(threads);
            let mut y2 = vec![0.0; a.nrows];
            par.spmv(&x, &mut y2);
            assert_eq!(y1, y2, "threads={threads}");
        }
    }

    #[test]
    fn fused_multi_rhs_equals_looped_single() {
        // above the PAR_MIN_ROWS threshold so the parallel path runs too
        let a = exp_controlled(1100, 1100, 5, ExpLaw::Gaussian { e0: 0, sigma: 2.0 }, 8);
        let mut rng = Prng::new(3);
        for nrhs in [1usize, 3, 8] {
            let x: Vec<f64> = (0..a.ncols * nrhs).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            for threads in [1usize, 4] {
                let m = LowpCsr::<Bf16>::from_csr(&a).with_threads(threads);
                let mut y_loop = vec![0.0; a.nrows * nrhs];
                for j in 0..nrhs {
                    let (lo, hi) = (j * a.nrows, (j + 1) * a.nrows);
                    m.spmv(&x[j * a.ncols..(j + 1) * a.ncols], &mut y_loop[lo..hi]);
                }
                let mut y = vec![0.0; a.nrows * nrhs];
                m.spmv_multi(&x, &mut y, nrhs);
                assert_eq!(y, y_loop, "nrhs={nrhs} threads={threads}");
            }
        }
    }

    #[test]
    fn overflow_flag_set() {
        let mut a = poisson2d(3, 3);
        a.vals[0] = 1e10; // overflows fp16, fine in bf16
        assert!(LowpCsr::<Fp16>::from_csr(&a).overflowed);
        assert!(!LowpCsr::<Bf16>::from_csr(&a).overflowed);
        assert!(!LowpCsr::<f32>::from_csr(&a).overflowed);
    }
}
