//! FP64 CSR SpMV — the baseline every figure normalizes against.
//!
//! The serial kernel mirrors CUSP's CSR-vector algorithm collapsed onto
//! one lane; the parallel variant partitions rows into contiguous chunks
//! of roughly equal nnz (the CPU analog of the threads-per-row decision
//! tree the paper cites [19]).

use super::SpmvOp;
use crate::formats::ValueFormat;
use crate::sparse::csr::Csr;

/// FP64-stored CSR operator.
pub struct Fp64Csr {
    pub a: Csr,
    pub threads: usize,
}

impl Fp64Csr {
    pub fn new(a: Csr) -> Self {
        Self { a, threads: 1 }
    }

    pub fn with_threads(a: Csr, threads: usize) -> Self {
        Self { a, threads: threads.max(1) }
    }
}

/// Serial FP64 SpMV: `y = A x`.
pub fn spmv(a: &Csr, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.ncols);
    debug_assert_eq!(y.len(), a.nrows);
    for r in 0..a.nrows {
        let (cols, vals) = a.row(r);
        let mut sum = 0.0;
        for (&c, &v) in cols.iter().zip(vals) {
            sum += v * x[c as usize];
        }
        y[r] = sum;
    }
}

/// Partition rows into `parts` contiguous chunks balancing nnz.
pub fn balance_rows(a: &Csr, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1).min(a.nrows.max(1));
    let target = a.nnz().div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    for r in 0..a.nrows {
        acc += a.rowptr[r + 1] - a.rowptr[r];
        if acc >= target && out.len() + 1 < parts {
            out.push(start..r + 1);
            start = r + 1;
            acc = 0;
        }
    }
    out.push(start..a.nrows);
    out
}

/// Chunk-parallel FP64 SpMV using scoped threads.
pub fn spmv_par(a: &Csr, x: &[f64], y: &mut [f64], threads: usize) {
    if threads <= 1 || a.nrows < 1024 {
        return spmv(a, x, y);
    }
    let chunks = balance_rows(a, threads);
    // Split y into per-chunk mutable slices.
    let mut slices: Vec<&mut [f64]> = Vec::with_capacity(chunks.len());
    let mut rest = y;
    let mut cursor = 0usize;
    for ch in &chunks {
        let (head, tail) = rest.split_at_mut(ch.end - cursor);
        cursor = ch.end;
        slices.push(head);
        rest = tail;
    }
    std::thread::scope(|s| {
        for (ch, ys) in chunks.iter().zip(slices) {
            let ch = ch.clone();
            s.spawn(move || {
                for (i, r) in ch.clone().enumerate() {
                    let (cols, vals) = a.row(r);
                    let mut sum = 0.0;
                    for (&c, &v) in cols.iter().zip(vals) {
                        sum += v * x[c as usize];
                    }
                    ys[i] = sum;
                }
            });
        }
    });
}

impl SpmvOp for Fp64Csr {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        spmv_par(&self.a, x, y, self.threads);
    }

    fn nrows(&self) -> usize {
        self.a.nrows
    }

    fn ncols(&self) -> usize {
        self.a.ncols
    }

    fn format(&self) -> ValueFormat {
        ValueFormat::Fp64
    }

    fn matrix_bytes(&self) -> usize {
        self.a.nnz() * (8 + 4) + (self.a.nrows + 1) * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::util::Prng;

    #[test]
    fn spmv_matches_dense() {
        let mut c = Coo::new(3, 3);
        for (r, cc, v) in [(0, 0, 2.0), (0, 1, 1.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)] {
            c.push(r, cc, v);
        }
        let a = c.to_csr();
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![0.0; 3];
        spmv(&a, &x, &mut y);
        assert_eq!(y, vec![4.0, 6.0, 19.0]);
    }

    #[test]
    fn identity_is_noop() {
        let a = Csr::identity(10);
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut y = vec![0.0; 10];
        spmv(&a, &x, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn balance_rows_covers_everything() {
        let a = poisson2d(20, 20);
        for parts in [1, 2, 3, 7] {
            let ch = balance_rows(&a, parts);
            assert_eq!(ch.len(), parts);
            assert_eq!(ch[0].start, 0);
            assert_eq!(ch.last().unwrap().end, a.nrows);
            for w in ch.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let a = poisson2d(40, 40);
        let mut rng = Prng::new(6);
        let x: Vec<f64> = (0..a.ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut y1 = vec![0.0; a.nrows];
        let mut y2 = vec![0.0; a.nrows];
        spmv(&a, &x, &mut y1);
        spmv_par(&a, &x, &mut y2, 4);
        assert_eq!(y1, y2);
    }

    #[test]
    fn op_trait_surface() {
        let op = Fp64Csr::new(poisson2d(5, 5));
        assert_eq!(op.nrows(), 25);
        assert_eq!(op.format(), ValueFormat::Fp64);
        assert!(op.matrix_bytes() > 25 * 12);
    }
}
