//! FP64 CSR SpMV — the baseline every figure normalizes against.
//!
//! The serial kernel mirrors CUSP's CSR-vector algorithm collapsed onto
//! one lane; the parallel variant partitions rows into contiguous chunks
//! of roughly equal nnz (the CPU analog of the threads-per-row decision
//! tree the paper cites [19]).

use super::{SpmvOp, ThreadBudget};
use crate::formats::ValueFormat;
use crate::sparse::csr::Csr;
use crate::util::parallel;

/// Default row count below which the parallel paths fall back to serial
/// — the spawn cost dwarfs the work on tiny systems. The live value is
/// [`super::par_min_rows`] (env-overridable); this constant is only its
/// default.
pub(crate) const PAR_MIN_ROWS: usize = 1024;

/// FP64-stored CSR operator.
pub struct Fp64Csr {
    pub a: Csr,
    /// Runtime-reconfigurable worker count ([`SpmvOp::set_threads`]).
    pub threads: ThreadBudget,
}

impl Fp64Csr {
    pub fn new(a: Csr) -> Self {
        Self { a, threads: ThreadBudget::new(1) }
    }

    pub fn with_threads(a: Csr, threads: usize) -> Self {
        Self { a, threads: ThreadBudget::new(threads) }
    }
}

/// Serial FP64 SpMV: `y = A x`.
pub fn spmv(a: &Csr, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.ncols);
    debug_assert_eq!(y.len(), a.nrows);
    for r in 0..a.nrows {
        let (cols, vals) = a.row(r);
        let mut sum = 0.0;
        for (&c, &v) in cols.iter().zip(vals) {
            sum += v * x[c as usize];
        }
        y[r] = sum;
    }
}

/// Partition rows into `parts` contiguous chunks balancing nnz — thin
/// wrapper over [`parallel::balance_by_weight`] keyed on row lengths.
pub fn balance_rows(a: &Csr, parts: usize) -> Vec<std::ops::Range<usize>> {
    parallel::balance_by_weight(a.nrows, parts, |r| a.rowptr[r + 1] - a.rowptr[r])
}

/// Chunk-parallel FP64 SpMV over the shared [`parallel`] machinery.
/// Bit-for-bit identical to [`spmv`] for every thread count (each row is
/// accumulated by one thread in serial order).
pub fn spmv_par(a: &Csr, x: &[f64], y: &mut [f64], threads: usize) {
    if threads <= 1 || a.nrows < super::par_min_rows() {
        return spmv(a, x, y);
    }
    let chunks = balance_rows(a, threads);
    parallel::for_each_disjoint(y, &chunks, |ch, ys| {
        for (i, r) in ch.enumerate() {
            let (cols, vals) = a.row(r);
            let mut sum = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                sum += v * x[c as usize];
            }
            ys[i] = sum;
        }
    });
}

/// Fused multi-RHS SpMV: each row's non-zeros are read **once** and
/// broadcast through the [`super::tile`] register tiles across all
/// `nrhs` column-major packed vectors (see [`SpmvOp::apply_multi`] for
/// the layout). Bit-for-bit identical to `nrhs` single [`spmv`] calls
/// for every thread count.
pub fn spmv_multi(a: &Csr, x: &[f64], y: &mut [f64], nrhs: usize, threads: usize) {
    assert_eq!(x.len(), a.ncols * nrhs);
    assert_eq!(y.len(), a.nrows * nrhs);
    if nrhs == 0 {
        return;
    }
    let parts = super::multi_parts(threads, a.nrows, nrhs);
    let chunks = balance_rows(a, parts);
    let ncols = a.ncols;
    parallel::for_each_disjoint_cols(y, a.nrows, &chunks, |ch, cols| {
        let mut acc = vec![0.0f64; nrhs];
        for (i, r) in ch.enumerate() {
            let (rc, rv) = a.row(r);
            acc.fill(0.0);
            for (&c, &v) in rc.iter().zip(rv) {
                super::tile::fma_lanes(&mut acc, v, x, c as usize, ncols);
            }
            for (j, aj) in acc.iter().enumerate() {
                cols[j][i] = *aj;
            }
        }
    });
}

impl SpmvOp for Fp64Csr {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        spmv_par(&self.a, x, y, self.threads.get());
    }

    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        spmv_multi(&self.a, x, y, nrhs, self.threads.get());
    }

    fn set_threads(&self, threads: usize) {
        self.threads.set(threads);
    }

    fn threads(&self) -> usize {
        self.threads.get()
    }

    fn nrows(&self) -> usize {
        self.a.nrows
    }

    fn ncols(&self) -> usize {
        self.a.ncols
    }

    fn format(&self) -> ValueFormat {
        ValueFormat::Fp64
    }

    fn matrix_bytes(&self) -> usize {
        self.a.nnz() * (8 + 4) + (self.a.nrows + 1) * 8
    }

    fn encoded_bytes(&self) -> usize {
        // single-plane CSR: resident storage equals per-apply traffic
        self.matrix_bytes()
    }

    fn spill_bytes(&self) -> Option<Vec<u8>> {
        let mut w = crate::util::codec::ByteWriter::new();
        w.put_u8(super::spill_tag::FP64);
        w.put_u64(self.a.nrows as u64);
        w.put_u64(self.a.ncols as u64);
        w.put_usizes(&self.a.rowptr);
        w.put_u32s(&self.a.colidx);
        w.put_f64s(&self.a.vals);
        Some(w.into_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::util::Prng;

    #[test]
    fn spmv_matches_dense() {
        let mut c = Coo::new(3, 3);
        for (r, cc, v) in [(0, 0, 2.0), (0, 1, 1.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)] {
            c.push(r, cc, v);
        }
        let a = c.to_csr();
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![0.0; 3];
        spmv(&a, &x, &mut y);
        assert_eq!(y, vec![4.0, 6.0, 19.0]);
    }

    #[test]
    fn identity_is_noop() {
        let a = Csr::identity(10);
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut y = vec![0.0; 10];
        spmv(&a, &x, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn balance_rows_covers_everything() {
        let a = poisson2d(20, 20);
        for parts in [1, 2, 3, 7] {
            let ch = balance_rows(&a, parts);
            assert_eq!(ch.len(), parts);
            assert_eq!(ch[0].start, 0);
            assert_eq!(ch.last().unwrap().end, a.nrows);
            for w in ch.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let a = poisson2d(40, 40);
        let mut rng = Prng::new(6);
        let x: Vec<f64> = (0..a.ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut y1 = vec![0.0; a.nrows];
        let mut y2 = vec![0.0; a.nrows];
        spmv(&a, &x, &mut y1);
        spmv_par(&a, &x, &mut y2, 4);
        assert_eq!(y1, y2);
    }

    #[test]
    fn fused_multi_rhs_equals_looped_single() {
        // below and above the PAR_MIN_ROWS fallback, all thread counts
        for (w, h) in [(8usize, 8usize), (40, 40)] {
            let a = poisson2d(w, h);
            let mut rng = Prng::new(11);
            for nrhs in [1usize, 3, 8] {
                let x: Vec<f64> = (0..a.ncols * nrhs).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                let mut y_loop = vec![0.0; a.nrows * nrhs];
                for j in 0..nrhs {
                    let xj = &x[j * a.ncols..(j + 1) * a.ncols];
                    spmv(&a, xj, &mut y_loop[j * a.nrows..(j + 1) * a.nrows]);
                }
                for threads in [1usize, 3, 5] {
                    let mut y = vec![0.0; a.nrows * nrhs];
                    spmv_multi(&a, &x, &mut y, nrhs, threads);
                    assert_eq!(y, y_loop, "nrhs={nrhs} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn op_trait_surface() {
        let op = Fp64Csr::new(poisson2d(5, 5));
        assert_eq!(op.nrows(), 25);
        assert_eq!(op.format(), ValueFormat::Fp64);
        assert!(op.matrix_bytes() > 25 * 12);
    }
}
