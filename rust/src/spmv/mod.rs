//! SpMV operators over every storage format the paper compares
//! (§III-C, §IV-C):
//!
//! * [`fp64`] — the FP64 baseline (CUSP CSR-vector analog), serial and
//!   chunk-parallel.
//! * [`lowp`] — FP32 / FP16 / BF16-stored SpMV: values live in the low
//!   precision format, are widened to f64 on load, and all arithmetic is
//!   f64 (exactly the paper's baseline kernels).
//! * [`gse`] — the GSE-SEM CSR matrix and its three-precision SpMV
//!   (Algorithm 2), with the exponent index packed into column-index
//!   high bits or an out-of-band array (§III-C1).
//! * [`ell`] — padded-ELL blocks, the static-shape view consumed by the
//!   Pallas kernel (L1) and its parity tests, with a fused multi-RHS
//!   kernel and an [`ell::EllSpmv`] operator adapter.
//! * [`tile`] — the register-tiled lane primitive every fused multi-RHS
//!   kernel broadcasts decoded values through ([`LANES`]-wide
//!   `[f64; LANES]` accumulator tiles the stable compiler vectorizes).
//! * [`traffic`] — the memory-traffic/roofline model that translates
//!   bytes-moved into modeled V100 kernel time (DESIGN.md §5).

pub mod fp64;
pub mod lowp;
pub mod gse;
pub mod ell;
pub mod msplit;
pub mod tile;
pub mod traffic;

pub use ell::EllSpmv;
pub use gse::{DecodeStrategy, GseCsr};
pub use lowp::LowpCsr;
pub use tile::LANES;

use crate::formats::{Precision, ValueFormat};
use crate::sparse::csr::Csr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// Runtime-reconfigurable worker-count handle shared between an encoded
/// operator and whoever schedules it (the intake flusher's core
/// allocator, the CLI, a bench). The count lives behind an
/// `Arc<AtomicUsize>`, so reconfiguring it is a store — **zero
/// re-encode**, no change to the operator's digest key or
/// [`SpmvOp::encoded_bytes`] — and every view of one encode (the three
/// GSE levels, a ladder's rungs) sees the new budget at its next apply.
///
/// Thread count never changes results (rows are never split across
/// workers — the bit-exactness invariant of [`crate::util::parallel`]),
/// which is what makes a mid-solve `set` safe.
///
/// `clone()` shares the handle; constructor-time `with_threads`
/// builders install a **fresh** handle so a cloned-and-retuned operator
/// detaches from its source.
#[derive(Debug)]
pub struct ThreadBudget(Arc<AtomicUsize>);

impl ThreadBudget {
    pub fn new(threads: usize) -> Self {
        Self(Arc::new(AtomicUsize::new(threads.max(1))))
    }

    /// Current worker count (always >= 1).
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed).max(1)
    }

    /// Reconfigure the worker count; values are clamped to >= 1.
    pub fn set(&self, threads: usize) {
        self.0.store(threads.max(1), Ordering::Relaxed);
    }
}

impl Clone for ThreadBudget {
    /// Shares the underlying handle: a `set` on either clone is seen by
    /// both. Use [`ThreadBudget::new`] for a detached handle.
    fn clone(&self) -> Self {
        Self(Arc::clone(&self.0))
    }
}

impl Default for ThreadBudget {
    fn default() -> Self {
        Self::new(1)
    }
}

/// A type-erased "y = A·x" operator — what the solvers are generic over.
pub trait SpmvOp: Send + Sync {
    /// `y` must have length `nrows`; `x` length `ncols`.
    fn apply(&self, x: &[f64], y: &mut [f64]);

    /// Block apply over `nrhs` column-major packed vectors:
    /// `x[j*ncols..(j+1)*ncols]` is RHS `j` and
    /// `y[j*nrows..(j+1)*nrows]` receives its product.
    ///
    /// The default implementation loops over single [`SpmvOp::apply`]
    /// calls. Fused overrides decode each matrix row **once** and stream
    /// it across all RHS — the amortization lever of the paper's
    /// memory-bound analysis (§III-C) — and must stay **bit-for-bit**
    /// identical to the looped default (each column's dot products
    /// accumulate in the same order as a single apply).
    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        let (nc, nr) = (self.ncols(), self.nrows());
        assert_eq!(x.len(), nc * nrhs);
        assert_eq!(y.len(), nr * nrhs);
        for j in 0..nrhs {
            self.apply(&x[j * nc..(j + 1) * nc], &mut y[j * nr..(j + 1) * nr]);
        }
    }

    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    /// Storage format (for traffic accounting / labels).
    fn format(&self) -> ValueFormat;
    /// Bytes read from matrix storage per apply (traffic model input).
    fn matrix_bytes(&self) -> usize;
    /// Resident bytes of the encoded operator — the matrix storage the
    /// operator actually holds in memory, as opposed to
    /// [`SpmvOp::matrix_bytes`]' per-apply traffic. This is what the
    /// coordinator registry's eviction budget and its `cache.bytes`
    /// gauge account. The default (storage = per-apply traffic) is
    /// right for single-plane formats; multi-plane operators (GSE-SEM
    /// levels, copy ladders, mantissa splits) override it with the sum
    /// of every plane they keep resident.
    fn encoded_bytes(&self) -> usize {
        self.matrix_bytes()
    }

    /// Serialize the operator's resident storage for the coordinator
    /// registry's disk spill (see `coordinator::spill`). `None` — the
    /// default — opts the operator type out: on eviction it is simply
    /// dropped and rebuilt on the next hit. Implementations emit a
    /// `spill_tag` byte followed by a layout private to themselves and
    /// the spill decoder; the restored operator must be bitwise
    /// indistinguishable from the original encode.
    fn spill_bytes(&self) -> Option<Vec<u8>> {
        None
    }

    /// Reconfigure the operator's worker count **post-build** (see
    /// [`ThreadBudget`]). Safe to call concurrently with applies and
    /// even mid-solve: any count is bit-for-bit identical to serial, so
    /// the only observable effect is wall time. The default is a no-op
    /// for operators without a parallel path.
    fn set_threads(&self, _threads: usize) {}

    /// The operator's current worker count (>= 1). Defaults to 1 for
    /// operators without a parallel path.
    fn threads(&self) -> usize {
        1
    }
}

/// Leading payload byte of each operator spill layout, so the decoder
/// can cross-check the registry key's format against what is actually
/// in the file.
pub(crate) mod spill_tag {
    pub const FP64: u8 = 0;
    pub const FP32: u8 = 1;
    pub const FP16: u8 = 2;
    pub const BF16: u8 = 3;
    pub const GSE: u8 = 4;
    pub const SAINV: u8 = 5;
    pub const POLICY: u8 = 6;
}

/// The serial-fallback work threshold every parallel split gates on —
/// the one tunable the intake core allocator and all SpMV kernels
/// agree on for "when does a parallel split pay". Defaults to
/// [`fp64::PAR_MIN_ROWS`] (1024); override with the
/// `GSEM_PAR_MIN_ROWS` env var (read once, cached) so benches can
/// force the parallel path on small smoke matrices.
pub fn par_min_rows() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        std::env::var("GSEM_PAR_MIN_ROWS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(fp64::PAR_MIN_ROWS)
    })
}

/// Serial-vs-parallel split decision shared by the fused multi-RHS
/// kernels. Work scales with rows × nrhs, so a short-but-wide block
/// (say 1k rows × 64 RHS) still clears the [`par_min_rows`] spawn
/// threshold that a single skinny apply would not. Thread count never
/// changes results (rows are never split across workers), so the gate
/// is free to consider shape only.
pub(crate) fn multi_parts(threads: usize, nrows: usize, nrhs: usize) -> usize {
    if threads <= 1 || nrows.saturating_mul(nrhs) < par_min_rows() {
        1
    } else {
        threads
    }
}

/// The looped multi-RHS baseline: `nrhs` single applies, regardless of
/// any fused [`SpmvOp::apply_multi`] override. The ablation bench and
/// the batched-parity tests compare fused kernels against this.
pub fn apply_multi_looped(op: &dyn SpmvOp, x: &[f64], y: &mut [f64], nrhs: usize) {
    let (nc, nr) = (op.ncols(), op.nrows());
    assert_eq!(x.len(), nc * nrhs);
    assert_eq!(y.len(), nr * nrhs);
    for j in 0..nrhs {
        op.apply(&x[j * nc..(j + 1) * nc], &mut y[j * nr..(j + 1) * nr]);
    }
}

/// Build the paper's full comparison set of operators for one matrix.
/// `k` is the shared-exponent count for the GSE-SEM entries.
pub fn build_operators(a: &Csr, k: usize) -> Vec<Box<dyn SpmvOp>> {
    build_operators_par(a, k, 1)
}

/// Same comparison set with every operator — FP64 baseline, the FP32 /
/// 16-bit baselines, and all three GSE-SEM levels — sharing the
/// chunk-parallel hot path ([`crate::util::parallel`]) at the given
/// worker count. The three GSE levels share one encoded matrix.
pub fn build_operators_par(a: &Csr, k: usize, threads: usize) -> Vec<Box<dyn SpmvOp>> {
    let gse = std::sync::Arc::new(GseCsr::from_csr(a, k).with_threads(threads));
    vec![
        Box::new(fp64::Fp64Csr::with_threads(a.clone(), threads)),
        Box::new(LowpCsr::<f32>::from_csr(a).with_threads(threads)),
        Box::new(LowpCsr::<crate::formats::Fp16>::from_csr(a).with_threads(threads)),
        Box::new(LowpCsr::<crate::formats::Bf16>::from_csr(a).with_threads(threads)),
        Box::new(gse::GseSpmv::new(std::sync::Arc::clone(&gse), Precision::Head)),
        Box::new(gse::GseSpmv::new(std::sync::Arc::clone(&gse), Precision::HeadTail1)),
        Box::new(gse::GseSpmv::new(gse, Precision::Full)),
    ]
}

/// Maximum absolute difference between two result vectors — the error
/// metric of Fig. 4(b)/6(b).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn operator_set_is_consistent() {
        let a = poisson2d(8, 8);
        let ops = build_operators(&a, 8);
        assert_eq!(ops.len(), 7);
        let x = vec![1.0; a.ncols];
        let mut y0 = vec![0.0; a.nrows];
        ops[0].apply(&x, &mut y0);
        for op in &ops[1..] {
            let mut y = vec![0.0; a.nrows];
            op.apply(&x, &mut y);
            // Poisson values are exactly representable in every format.
            assert_eq!(max_abs_diff(&y0, &y), 0.0, "{}", op.format().label());
        }
    }

    #[test]
    fn operator_set_covers_comparison_formats() {
        let a = poisson2d(6, 6);
        let got: Vec<ValueFormat> = build_operators(&a, 8).iter().map(|op| op.format()).collect();
        let want = vec![
            ValueFormat::Fp64,
            ValueFormat::Fp32,
            ValueFormat::Fp16,
            ValueFormat::Bf16,
            ValueFormat::GseSem(Precision::Head),
            ValueFormat::GseSem(Precision::HeadTail1),
            ValueFormat::GseSem(Precision::Full),
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn default_apply_multi_loops_single_applies() {
        let a = poisson2d(8, 8);
        let ops = build_operators(&a, 8);
        let nrhs = 3usize;
        let n = a.ncols;
        let mut x = vec![0.0; n * nrhs];
        for (i, xi) in x.iter_mut().enumerate() {
            *xi = (i % 7) as f64 - 3.0;
        }
        for op in &ops {
            let mut y_multi = vec![0.0; a.nrows * nrhs];
            op.apply_multi(&x, &mut y_multi, nrhs);
            let mut y_loop = vec![0.0; a.nrows * nrhs];
            apply_multi_looped(op.as_ref(), &x, &mut y_loop, nrhs);
            assert_eq!(y_multi, y_loop, "{}", op.format().label());
        }
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    fn encoded_bytes_cover_resident_storage() {
        let a = poisson2d(8, 8);
        for op in build_operators(&a, 8) {
            // every operator holds at least its per-apply traffic
            assert!(
                op.encoded_bytes() >= op.matrix_bytes(),
                "{}: encoded {} < traffic {}",
                op.format().label(),
                op.encoded_bytes(),
                op.matrix_bytes()
            );
        }
        // the three GSE levels view one encode: same resident size,
        // even though head-only reads far less per apply
        let ops = build_operators(&a, 8);
        assert_eq!(ops[4].encoded_bytes(), ops[6].encoded_bytes());
        assert!(ops[4].matrix_bytes() < ops[6].matrix_bytes());
    }
}
