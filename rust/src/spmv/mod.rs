//! SpMV operators over every storage format the paper compares
//! (§III-C, §IV-C):
//!
//! * [`fp64`] — the FP64 baseline (CUSP CSR-vector analog), serial and
//!   chunk-parallel.
//! * [`lowp`] — FP32 / FP16 / BF16-stored SpMV: values live in the low
//!   precision format, are widened to f64 on load, and all arithmetic is
//!   f64 (exactly the paper's baseline kernels).
//! * [`gse`] — the GSE-SEM CSR matrix and its three-precision SpMV
//!   (Algorithm 2), with the exponent index packed into column-index
//!   high bits or an out-of-band array (§III-C1).
//! * [`ell`] — padded-ELL blocks, the static-shape view consumed by the
//!   Pallas kernel (L1) and its parity tests.
//! * [`traffic`] — the memory-traffic/roofline model that translates
//!   bytes-moved into modeled V100 kernel time (DESIGN.md §5).

pub mod fp64;
pub mod lowp;
pub mod gse;
pub mod ell;
pub mod msplit;
pub mod traffic;

pub use gse::{DecodeStrategy, GseCsr};
pub use lowp::LowpCsr;

use crate::formats::{Precision, ValueFormat};
use crate::sparse::csr::Csr;

/// A type-erased "y = A·x" operator — what the solvers are generic over.
pub trait SpmvOp: Sync {
    /// `y` must have length `nrows`; `x` length `ncols`.
    fn apply(&self, x: &[f64], y: &mut [f64]);
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    /// Storage format (for traffic accounting / labels).
    fn format(&self) -> ValueFormat;
    /// Bytes read from matrix storage per apply (traffic model input).
    fn matrix_bytes(&self) -> usize;
}

/// Build the paper's full comparison set of operators for one matrix.
/// `k` is the shared-exponent count for the GSE-SEM entries.
pub fn build_operators(a: &Csr, k: usize) -> Vec<Box<dyn SpmvOp>> {
    build_operators_par(a, k, 1)
}

/// Same comparison set with every operator — FP64 baseline, the 16-bit
/// baselines, and all three GSE-SEM levels — sharing the chunk-parallel
/// hot path ([`crate::util::parallel`]) at the given worker count.
pub fn build_operators_par(a: &Csr, k: usize, threads: usize) -> Vec<Box<dyn SpmvOp>> {
    let gse = GseCsr::from_csr(a, k).with_threads(threads);
    vec![
        Box::new(fp64::Fp64Csr::with_threads(a.clone(), threads)),
        Box::new(LowpCsr::<crate::formats::Fp16>::from_csr(a).with_threads(threads)),
        Box::new(LowpCsr::<crate::formats::Bf16>::from_csr(a).with_threads(threads)),
        Box::new(gse.clone().at_level(Precision::Head)),
        Box::new(gse.clone().at_level(Precision::HeadTail1)),
        Box::new(gse.at_level(Precision::Full)),
    ]
}

/// Maximum absolute difference between two result vectors — the error
/// metric of Fig. 4(b)/6(b).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn operator_set_is_consistent() {
        let a = poisson2d(8, 8);
        let ops = build_operators(&a, 8);
        assert_eq!(ops.len(), 6);
        let x = vec![1.0; a.ncols];
        let mut y0 = vec![0.0; a.nrows];
        ops[0].apply(&x, &mut y0);
        for op in &ops[1..] {
            let mut y = vec![0.0; a.nrows];
            op.apply(&x, &mut y);
            // Poisson values are exactly representable in every format.
            assert_eq!(max_abs_diff(&y0, &y), 0.0, "{}", op.format().label());
        }
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
