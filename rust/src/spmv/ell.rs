//! Padded-ELL blocks: the static-shape matrix view consumed by the
//! Pallas SpMV kernel (L1). Pallas/XLA require fixed shapes, so the CSR
//! matrix is re-laid-out as `nrows × width` index/value planes padded
//! with zero-entries; rows longer than `width` spill into additional
//! *slabs* (row splitting), whose partial sums the caller adds — the
//! TPU-side analog of CSR-vector's multiple-threads-per-row
//! (DESIGN.md §6 Hardware-Adaptation).

use crate::formats::{Precision, ValueFormat};
use crate::sparse::csr::Csr;
use crate::spmv::gse::GseCsr;
use crate::spmv::{SpmvOp, ThreadBudget};
use crate::util::parallel;
use std::sync::Arc;

/// One fixed-shape slab of an ELL-converted matrix.
#[derive(Clone, Debug)]
pub struct EllSlab {
    pub nrows: usize,
    pub width: usize,
    /// row-major `nrows × width` column indexes (padding points at 0)
    pub cols: Vec<u32>,
    /// row-major `nrows × width` values (padding is exactly 0.0)
    pub vals: Vec<f64>,
    /// packed GSE-SEM planes mirroring `vals` (heads plane etc.)
    pub heads: Vec<u16>,
    pub tail1: Vec<u16>,
    pub tail2: Vec<u32>,
    /// exponent index plane (u32 for the kernel's convenience)
    pub exp_idx: Vec<u32>,
}

/// ELL view of a matrix: one or more slabs; `y = Σ_s slab_s · x`.
#[derive(Clone, Debug)]
pub struct EllBlocks {
    pub nrows: usize,
    pub ncols: usize,
    pub width: usize,
    pub slabs: Vec<EllSlab>,
}

/// Convert a GSE-SEM CSR to padded ELL slabs of the given width.
pub fn to_ell(g: &GseCsr, original: &Csr, width: usize) -> EllBlocks {
    assert!(width >= 1);
    let nslabs = g
        .rowptr
        .windows(2)
        .map(|w| (w[1] - w[0]).div_ceil(width))
        .max()
        .unwrap_or(0)
        .max(1);
    let mut slabs = Vec::with_capacity(nslabs);
    for s in 0..nslabs {
        let mut slab = EllSlab {
            nrows: g.nrows,
            width,
            cols: vec![0; g.nrows * width],
            vals: vec![0.0; g.nrows * width],
            heads: vec![0; g.nrows * width],
            tail1: vec![0; g.nrows * width],
            tail2: vec![0; g.nrows * width],
            exp_idx: vec![0; g.nrows * width],
        };
        for r in 0..g.nrows {
            let (a, b) = (g.rowptr[r], g.rowptr[r + 1]);
            let lo = a + s * width;
            let hi = (lo + width).min(b);
            if lo >= hi {
                continue;
            }
            for (slot, j) in (lo..hi).enumerate() {
                let (col, idx) = g.col_and_idx(j);
                let o = r * width + slot;
                slab.cols[o] = col as u32;
                slab.vals[o] = original.vals[j];
                slab.heads[o] = g.heads[j];
                slab.tail1[o] = g.tail1[j];
                slab.tail2[o] = g.tail2[j];
                slab.exp_idx[o] = idx as u32;
            }
        }
        slabs.push(slab);
    }
    EllBlocks { nrows: g.nrows, ncols: g.ncols, width, slabs }
}

impl EllBlocks {
    /// Reference SpMV over the ELL planes, decoding GSE-SEM at `level`
    /// with the given table — mirrors what the Pallas kernel computes,
    /// used by the runtime parity tests.
    pub fn spmv_decoded(&self, g: &GseCsr, x: &[f64], level: Precision) -> Vec<f64> {
        self.spmv_decoded_par(g, x, level, 1)
    }

    /// Chunk-parallel variant over nnz-balanced row ranges (the shared
    /// [`parallel`] hot path). Per row, slab partial sums are added in
    /// slab order, so the result is bit-for-bit identical to the serial
    /// path for every thread count.
    pub fn spmv_decoded_par(
        &self,
        g: &GseCsr,
        x: &[f64],
        level: Precision,
        threads: usize,
    ) -> Vec<f64> {
        let mut y = vec![0.0; self.nrows];
        let chunks = if threads <= 1 || self.nrows < crate::spmv::par_min_rows() {
            vec![0..self.nrows]
        } else {
            self.balanced_chunks(g, threads)
        };
        parallel::for_each_disjoint(&mut y, &chunks, |rows, ys| {
            for (i, r) in rows.enumerate() {
                let mut total = 0.0;
                for slab in &self.slabs {
                    let mut sum = 0.0;
                    for c in 0..self.width {
                        let o = r * self.width + c;
                        let parts = crate::formats::sem::SemParts {
                            head: slab.heads[o],
                            tail1: if level >= Precision::HeadTail1 { slab.tail1[o] } else { 0 },
                            tail2: if level == Precision::Full { slab.tail2[o] } else { 0 },
                            exp_idx: slab.exp_idx[o] as u16,
                        };
                        let v =
                            crate::formats::sem::decode_ldexp(&parts, &g.table, &g.geom, level);
                        sum += v * x[slab.cols[o] as usize];
                    }
                    total += sum;
                }
                ys[i] = total;
            }
        });
        y
    }

    /// Row partition for the parallel paths, weighted by real non-zeros
    /// from the CSR rowptr rather than row count: padded slots decode
    /// against a cached `x[0]`, so the cache-missing gathers — the cost
    /// that actually skews — follow nnz. `max(1)` keeps empty rows from
    /// collapsing to zero weight (their padding still decodes).
    fn balanced_chunks(&self, g: &GseCsr, parts: usize) -> Vec<std::ops::Range<usize>> {
        parallel::balance_by_weight(self.nrows, parts, |r| {
            (g.rowptr[r + 1] - g.rowptr[r]).max(1)
        })
    }

    /// Fused multi-RHS SpMV over the ELL planes: column-major packed `x`
    /// and `y` (layout as [`SpmvOp::apply_multi`]), each slot's SEM word
    /// decoded **once** and broadcast through the [`crate::spmv::tile`]
    /// register tiles. Padded slots contribute exactly as in
    /// [`EllBlocks::spmv_decoded`] (skipping them could flip a +0.0 sum
    /// to -0.0), and per row the slab partial sums are added in slab
    /// order per column — so every column is bit-for-bit identical to a
    /// single [`EllBlocks::spmv_decoded`] over that column's `x` slice.
    pub fn spmv_multi_decoded(
        &self,
        g: &GseCsr,
        x: &[f64],
        nrhs: usize,
        level: Precision,
    ) -> Vec<f64> {
        self.spmv_multi_decoded_par(g, x, nrhs, level, 1)
    }

    /// Chunk-parallel variant of [`EllBlocks::spmv_multi_decoded`] —
    /// bit-for-bit identical to it for every thread count.
    pub fn spmv_multi_decoded_par(
        &self,
        g: &GseCsr,
        x: &[f64],
        nrhs: usize,
        level: Precision,
        threads: usize,
    ) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols * nrhs);
        let mut y = vec![0.0; self.nrows * nrhs];
        if nrhs == 0 {
            return y;
        }
        let nparts = crate::spmv::multi_parts(threads, self.nrows, nrhs);
        let chunks =
            if nparts <= 1 { vec![0..self.nrows] } else { self.balanced_chunks(g, nparts) };
        let ncols = self.ncols;
        parallel::for_each_disjoint_cols(&mut y, self.nrows, &chunks, |rows, cols_out| {
            let mut total = vec![0.0f64; cols_out.len()];
            let mut sum = vec![0.0f64; cols_out.len()];
            for (i, r) in rows.enumerate() {
                total.fill(0.0);
                for slab in &self.slabs {
                    sum.fill(0.0);
                    for c in 0..self.width {
                        let o = r * self.width + c;
                        let parts = crate::formats::sem::SemParts {
                            head: slab.heads[o],
                            tail1: if level >= Precision::HeadTail1 { slab.tail1[o] } else { 0 },
                            tail2: if level == Precision::Full { slab.tail2[o] } else { 0 },
                            exp_idx: slab.exp_idx[o] as u16,
                        };
                        let v =
                            crate::formats::sem::decode_ldexp(&parts, &g.table, &g.geom, level);
                        crate::spmv::tile::fma_lanes(
                            &mut sum,
                            v,
                            x,
                            slab.cols[o] as usize,
                            ncols,
                        );
                    }
                    for (q, tq) in total.iter_mut().enumerate() {
                        *tq += sum[q];
                    }
                }
                for (q, tq) in total.iter().enumerate() {
                    cols_out[q][i] = *tq;
                }
            }
        });
        y
    }

    pub fn total_slots(&self) -> usize {
        self.slabs.len() * self.nrows * self.width
    }

    /// Padding overhead ratio: slots / nnz.
    pub fn padding_ratio(&self, nnz: usize) -> f64 {
        if nnz == 0 {
            0.0
        } else {
            self.total_slots() as f64 / nnz as f64
        }
    }
}

/// [`SpmvOp`] adapter over the ELL planes at a fixed precision level —
/// the static-shape (L1/Pallas) view of a GSE encode participating in
/// the same solver / block-solve machinery as the CSR operators. Holds
/// the encode behind an `Arc` (the decode table and geometry live
/// there) next to the padded slabs.
pub struct EllSpmv {
    pub g: Arc<GseCsr>,
    pub blocks: EllBlocks,
    pub level: Precision,
    /// Runtime-reconfigurable worker count (1 = serial); any count is
    /// bit-for-bit identical (see [`SpmvOp::set_threads`]).
    pub threads: ThreadBudget,
}

impl EllSpmv {
    /// Lay out `original` (already encoded as `g`) into width-`width`
    /// ELL slabs and wrap them as an operator at `level`.
    pub fn new(g: Arc<GseCsr>, original: &Csr, width: usize, level: Precision) -> Self {
        let blocks = to_ell(&g, original, width);
        Self { g, blocks, level, threads: ThreadBudget::new(1) }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = ThreadBudget::new(threads);
        self
    }
}

impl SpmvOp for EllSpmv {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let out = self.blocks.spmv_decoded_par(&self.g, x, self.level, self.threads.get());
        y.copy_from_slice(&out);
    }

    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        assert_eq!(y.len(), self.blocks.nrows * nrhs);
        let out =
            self.blocks.spmv_multi_decoded_par(&self.g, x, nrhs, self.level, self.threads.get());
        y.copy_from_slice(&out);
    }

    fn set_threads(&self, threads: usize) {
        self.threads.set(threads);
    }

    fn threads(&self) -> usize {
        self.threads.get()
    }

    fn nrows(&self) -> usize {
        self.blocks.nrows
    }

    fn ncols(&self) -> usize {
        self.blocks.ncols
    }

    fn format(&self) -> ValueFormat {
        ValueFormat::GseSem(self.level)
    }

    fn matrix_bytes(&self) -> usize {
        // every slot streams its column word, out-of-band exponent
        // index, and the level's value planes; padding included
        self.blocks.total_slots() * (4 + 4 + self.level.bytes_per_value())
            + self.g.table.len() * 4
    }

    fn encoded_bytes(&self) -> usize {
        // all planes stay resident regardless of level (cols + vals +
        // heads + tail1 + tail2 + exp_idx), plus the shared CSR encode
        self.blocks.total_slots() * (4 + 8 + 2 + 2 + 4 + 4) + self.g.encoded_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::sparse::gen::randmat::{exp_controlled, ExpLaw};
    use crate::spmv::fp64;
    use crate::spmv::max_abs_diff;
    use crate::util::Prng;

    #[test]
    fn single_slab_when_width_covers_rows() {
        let a = poisson2d(6, 6);
        let g = GseCsr::from_csr(&a, 8);
        let e = to_ell(&g, &a, 5);
        assert_eq!(e.slabs.len(), 1);
        assert_eq!(e.total_slots(), 36 * 5);
    }

    #[test]
    fn row_splitting_spills_to_slabs() {
        let a = poisson2d(6, 6); // max 5 nnz/row
        let g = GseCsr::from_csr(&a, 8);
        let e = to_ell(&g, &a, 2);
        assert_eq!(e.slabs.len(), 3); // ceil(5/2)
    }

    #[test]
    fn ell_spmv_matches_csr_spmv() {
        let a = exp_controlled(40, 40, 7, ExpLaw::Gaussian { e0: 0, sigma: 3.0 }, 8);
        let g = GseCsr::from_csr(&a, 8);
        let mut r = Prng::new(2);
        let x: Vec<f64> = (0..a.ncols).map(|_| r.range_f64(-1.0, 1.0)).collect();
        for width in [3, 8, 16] {
            let e = to_ell(&g, &a, width);
            for lvl in Precision::LADDER {
                let mut y_csr = vec![0.0; a.nrows];
                g.spmv(&x, &mut y_csr, lvl);
                let y_ell = e.spmv_decoded(&g, &x, lvl);
                // identical decode + different summation order: allow tiny fp drift
                let scale = y_csr.iter().fold(1.0f64, |m, &v| m.max(v.abs()));
                assert!(
                    max_abs_diff(&y_csr, &y_ell) <= 1e-12 * scale,
                    "width={width} {lvl:?}"
                );
            }
        }
    }

    #[test]
    fn parallel_ell_spmv_bit_exact_vs_serial() {
        let a = exp_controlled(1200, 1200, 5, ExpLaw::Zipf { e0: -4, count: 8, s: 1.2 }, 6);
        let g = GseCsr::from_csr(&a, 8);
        let e = to_ell(&g, &a, 3);
        let mut r = Prng::new(11);
        let x: Vec<f64> = (0..a.ncols).map(|_| r.range_f64(-1.0, 1.0)).collect();
        for lvl in Precision::LADDER {
            let serial = e.spmv_decoded(&g, &x, lvl);
            for threads in [1usize, 2, 5] {
                let par = e.spmv_decoded_par(&g, &x, lvl, threads);
                assert_eq!(serial, par, "threads={threads} {lvl:?}");
            }
        }
    }

    #[test]
    fn fused_multi_rhs_matches_per_column_single() {
        let a = exp_controlled(150, 150, 6, ExpLaw::Gaussian { e0: -1, sigma: 3.0 }, 14);
        let g = GseCsr::from_csr(&a, 8);
        let e = to_ell(&g, &a, 4);
        let mut r = Prng::new(21);
        for nrhs in [1usize, 3, 5] {
            let x: Vec<f64> = (0..a.ncols * nrhs).map(|_| r.range_f64(-1.0, 1.0)).collect();
            for lvl in Precision::LADDER {
                let y = e.spmv_multi_decoded(&g, &x, nrhs, lvl);
                for j in 0..nrhs {
                    let yj = e.spmv_decoded(&g, &x[j * a.ncols..(j + 1) * a.ncols], lvl);
                    assert_eq!(&y[j * a.nrows..(j + 1) * a.nrows], &yj[..], "col {j} {lvl:?}");
                }
            }
        }
    }

    #[test]
    fn fused_multi_rhs_parallel_bit_exact() {
        // nrows * nrhs crosses the rows×nrhs gate even though a single
        // apply would stay serial
        let a = exp_controlled(700, 700, 5, ExpLaw::Zipf { e0: -3, count: 8, s: 1.1 }, 4);
        let g = GseCsr::from_csr(&a, 8);
        let e = to_ell(&g, &a, 3);
        let mut r = Prng::new(8);
        let nrhs = 4usize;
        let x: Vec<f64> = (0..a.ncols * nrhs).map(|_| r.range_f64(-1.0, 1.0)).collect();
        for lvl in Precision::LADDER {
            let serial = e.spmv_multi_decoded(&g, &x, nrhs, lvl);
            for threads in [2usize, 5] {
                let par = e.spmv_multi_decoded_par(&g, &x, nrhs, lvl, threads);
                assert_eq!(serial, par, "threads={threads} {lvl:?}");
            }
        }
    }

    #[test]
    fn ell_operator_adapter_surface() {
        let a = exp_controlled(60, 60, 5, ExpLaw::Gaussian { e0: 0, sigma: 2.0 }, 7);
        let g = Arc::new(GseCsr::from_csr(&a, 8));
        let op = EllSpmv::new(Arc::clone(&g), &a, 4, Precision::Full).with_threads(3);
        assert_eq!(op.nrows(), 60);
        assert_eq!(op.format(), ValueFormat::GseSem(Precision::Full));
        assert!(op.encoded_bytes() > op.matrix_bytes());
        let mut r = Prng::new(3);
        let nrhs = 3usize;
        let x: Vec<f64> = (0..a.ncols * nrhs).map(|_| r.range_f64(-1.0, 1.0)).collect();
        let mut y_fused = vec![0.0; a.nrows * nrhs];
        op.apply_multi(&x, &mut y_fused, nrhs);
        let mut y_loop = vec![0.0; a.nrows * nrhs];
        crate::spmv::apply_multi_looped(&op, &x, &mut y_loop, nrhs);
        assert_eq!(y_fused, y_loop);
    }

    #[test]
    fn padding_vals_are_zero_and_fp64_parity() {
        let a = poisson2d(5, 5);
        let g = GseCsr::from_csr(&a, 8);
        let e = to_ell(&g, &a, a.max_row_nnz());
        // fp64 plane parity: dense ELL spmv with vals plane == csr fp64 spmv
        let x = vec![1.0; a.ncols];
        let mut y = vec![0.0; a.nrows];
        fp64::spmv(&a, &x, &mut y);
        let mut y_ell = vec![0.0; a.nrows];
        for slab in &e.slabs {
            for r in 0..a.nrows {
                for c in 0..e.width {
                    let o = r * e.width + c;
                    y_ell[r] += slab.vals[o] * x[slab.cols[o] as usize];
                }
            }
        }
        assert_eq!(y, y_ell);
    }
}
