//! SpMV over the mantissa-segmentation format of Grützmacher et al.
//! [17] (DESIGN.md / paper §V-A) — head = top 32 bits of each FP64
//! non-zero, tail = low 32 bits. The related-work baseline the
//! `ablation_msplit` bench compares against GSE-SEM: no shared-exponent
//! table and 20 head mantissa bits, but twice the head traffic.

use super::SpmvOp;
use crate::formats::msplit::{join, split, SplitLevel};
use crate::formats::{Precision, ValueFormat};
use crate::sparse::csr::Csr;

/// CSR matrix stored as 32-bit head/tail planes.
#[derive(Clone, Debug)]
pub struct SplitCsr {
    pub nrows: usize,
    pub ncols: usize,
    pub rowptr: Vec<usize>,
    pub colidx: Vec<u32>,
    pub head: Vec<u32>,
    pub tail: Vec<u32>,
}

impl SplitCsr {
    pub fn from_csr(a: &Csr) -> Self {
        let mut head = Vec::with_capacity(a.nnz());
        let mut tail = Vec::with_capacity(a.nnz());
        for &v in &a.vals {
            let (h, t) = split(v);
            head.push(h);
            tail.push(t);
        }
        Self {
            nrows: a.nrows,
            ncols: a.ncols,
            rowptr: a.rowptr.clone(),
            colidx: a.colidx.clone(),
            head,
            tail,
        }
    }

    pub fn nnz(&self) -> usize {
        self.head.len()
    }

    /// Two-precision SpMV: head-only reads 4 B/nnz, full reads 8 B/nnz.
    pub fn spmv(&self, x: &[f64], y: &mut [f64], level: SplitLevel) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        match level {
            SplitLevel::Head => {
                for r in 0..self.nrows {
                    let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
                    let mut sum = 0.0;
                    for j in a..b {
                        let v = f64::from_bits((self.head[j] as u64) << 32);
                        sum += v * x[self.colidx[j] as usize];
                    }
                    y[r] = sum;
                }
            }
            SplitLevel::Full => {
                for r in 0..self.nrows {
                    let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
                    let mut sum = 0.0;
                    for j in a..b {
                        let v = join(self.head[j], self.tail[j], SplitLevel::Full);
                        sum += v * x[self.colidx[j] as usize];
                    }
                    y[r] = sum;
                }
            }
        }
    }

    pub fn bytes_at(&self, level: SplitLevel) -> usize {
        self.nnz() * (4 + level.bytes_per_value()) + (self.nrows + 1) * 8
    }

    /// Wrap as an [`SpmvOp`] at a fixed level.
    pub fn at_level(self, level: SplitLevel) -> SplitSpmv {
        SplitSpmv { m: self, level }
    }
}

/// [`SpmvOp`] adapter. `format()` reports the closest `ValueFormat`
/// analog for labeling (FP32-sized head reads / FP64 full reads).
pub struct SplitSpmv {
    pub m: SplitCsr,
    pub level: SplitLevel,
}

impl SpmvOp for SplitSpmv {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.m.spmv(x, y, self.level);
    }

    fn nrows(&self) -> usize {
        self.m.nrows
    }

    fn ncols(&self) -> usize {
        self.m.ncols
    }

    fn format(&self) -> ValueFormat {
        match self.level {
            SplitLevel::Head => ValueFormat::Fp32,
            SplitLevel::Full => ValueFormat::Fp64,
        }
    }

    fn matrix_bytes(&self) -> usize {
        self.m.bytes_at(self.level)
    }

    fn encoded_bytes(&self) -> usize {
        // head + tail planes both stay resident whatever the level
        self.m.nnz() * (4 + 4 + 4) + (self.m.nrows + 1) * 8
    }
}

/// Equivalent GSE-SEM precision by traffic (for apples-to-apples rows in
/// the ablation): split head (4 B) ≈ GSE head+tail1 (4 B).
pub fn traffic_equivalent_gse_level(level: SplitLevel) -> Precision {
    match level {
        SplitLevel::Head => Precision::HeadTail1,
        SplitLevel::Full => Precision::Full,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::sparse::gen::randmat::{exp_controlled, ExpLaw};
    use crate::spmv::{fp64, max_abs_diff};

    #[test]
    fn full_level_is_bit_exact() {
        let a = exp_controlled(50, 50, 5, ExpLaw::Gaussian { e0: 0, sigma: 6.0 }, 7);
        let s = SplitCsr::from_csr(&a);
        let x = vec![1.0; 50];
        let mut y64 = vec![0.0; 50];
        fp64::spmv(&a, &x, &mut y64);
        let mut y = vec![0.0; 50];
        s.spmv(&x, &mut y, SplitLevel::Full);
        assert_eq!(y, y64);
    }

    #[test]
    fn head_error_bounded_by_20_bits() {
        let a = exp_controlled(80, 80, 6, ExpLaw::Zipf { e0: -4, count: 12, s: 1.0 }, 9);
        let s = SplitCsr::from_csr(&a);
        let x = vec![1.0; 80];
        let mut y64 = vec![0.0; 80];
        fp64::spmv(&a, &x, &mut y64);
        let mut y = vec![0.0; 80];
        s.spmv(&x, &mut y, SplitLevel::Head);
        let err = max_abs_diff(&y64, &y);
        let scale: f64 = y64.iter().fold(0.0, |m, v| m.max(v.abs()));
        // each term truncated at 2^-20 relative; row sums accumulate
        assert!(err <= scale.max(1.0) * 6.0 * 2f64.powi(-20) * 10.0, "err={err}");
        assert!(err > 0.0);
    }

    #[test]
    fn exact_on_poisson_head() {
        // {4, -1} need 3 mantissa bits: head-exact
        let a = poisson2d(8, 8);
        let s = SplitCsr::from_csr(&a);
        let x = vec![1.0; 64];
        let mut y64 = vec![0.0; 64];
        fp64::spmv(&a, &x, &mut y64);
        let mut y = vec![0.0; 64];
        s.spmv(&x, &mut y, SplitLevel::Head);
        assert_eq!(y, y64);
    }

    #[test]
    fn op_adapter_and_traffic() {
        let a = poisson2d(6, 6);
        let s = SplitCsr::from_csr(&a);
        assert_eq!(s.bytes_at(SplitLevel::Full) - s.bytes_at(SplitLevel::Head), a.nnz() * 4);
        let op = s.at_level(SplitLevel::Head);
        assert_eq!(op.nrows(), 36);
    }
}
