//! GSE-SEM sparse matrices and the paper's three-precision SpMV
//! (§III-C, Algorithm 2).
//!
//! Storage layout (Fig. 3 applied to CSR):
//! * `heads`, `tail1`, `tail2` — contiguous segmented value storage; the
//!   head is `[sign:1][mantissa:15]` (External layout — the exponent
//!   index does NOT live in the head for matrices).
//! * `cols` — u32 column indexes. When the column count allows, the
//!   exponent index is packed into the top `EI_bit` bits (`col >> 29`
//!   for k=8, exactly Alg. 2 lines 3-5); otherwise a separate byte array
//!   `ext_idx` carries it (§III-C1's fallback, which the paper puts in
//!   the value array — out-of-band bytes are the CPU equivalent).
//!
//! The SpMV reads only the segments its precision level needs; the
//! decode-to-FP64 conversion is the kernel-overhead the paper measures
//! (GSE-SEM vs GSE-SEM*), so three decode strategies are provided and
//! ablated in `benches/ablation_decode.rs`.

use super::{SpmvOp, ThreadBudget};
use crate::formats::gse::GseTable;
use crate::formats::sem::{self, SemGeometry, SemLayout};
use crate::formats::{ieee, Precision, ValueFormat};
use crate::sparse::csr::Csr;
use crate::util::parallel;
use std::ops::Range;
use std::sync::Arc;

/// How the SpMV inner loop converts SEM words to f64.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeStrategy {
    /// Faithful Algorithm 2: per-element bit scan for the leading one,
    /// renormalization, IEEE bit assembly (the GPU `__fns` path).
    BitScan,
    /// Branch-free: reconstruct the frame integer and rescale with an
    /// exact ldexp.
    Ldexp,
    /// Fastest: per-exponent-index precomputed power-of-two scale;
    /// decode = frame × scale[idx] (one int->fp convert + one multiply).
    ScaleLut,
}

/// A CSR matrix stored in GSE-SEM format.
#[derive(Clone, Debug)]
pub struct GseCsr {
    pub nrows: usize,
    pub ncols: usize,
    pub rowptr: Vec<usize>,
    /// Column indexes (exponent index packed in the top bits iff
    /// `packed`).
    pub cols: Vec<u32>,
    pub heads: Vec<u16>,
    pub tail1: Vec<u16>,
    pub tail2: Vec<u32>,
    /// Out-of-band exponent indexes when not packed.
    pub ext_idx: Option<Vec<u8>>,
    pub table: GseTable,
    pub geom: SemGeometry,
    pub packed: bool,
    pub strategy: DecodeStrategy,
    /// Runtime-reconfigurable worker count (1 = serial; see
    /// [`crate::util::parallel`] and [`SpmvOp::set_threads`]). Shared by
    /// every view of this encode — all three [`GseSpmv`] levels and any
    /// `SwitchableOp` ladder over it retune together.
    pub threads: ThreadBudget,
    /// 2^(storedExp − 1075) per table entry (ScaleLut path).
    scales: Vec<f64>,
    /// scale multiply is exact (scale normal & results in range)
    scale_exact: Vec<bool>,
    /// every entry's scale is exact — gates the packed-LUT kernels
    all_exact: bool,
    /// signed scales `[idx*2 + sign] = ±2^(stored − 1075)`, padded to
    /// the 64-entry table maximum (tails kernel)
    sscale: Vec<f64>,
    /// signed, `s_head`-folded scales for the head-only kernel
    sscale_head: Vec<f64>,
}

impl GseCsr {
    /// Encode a CSR matrix with a k-entry shared exponent table
    /// extracted from its non-zeros.
    pub fn from_csr(a: &Csr, k: usize) -> Self {
        let table = GseTable::from_values(&a.vals, k);
        Self::from_csr_with_table(a, table)
    }

    /// Encode with a caller-provided table (reuse across matrices /
    /// sampled extraction).
    ///
    /// Panics on structurally invalid input (unsorted rowptr or
    /// out-of-range columns) — the hot SpMV kernels elide bounds checks
    /// and rely on this validation.
    pub fn from_csr_with_table(a: &Csr, table: GseTable) -> Self {
        assert_eq!(a.rowptr.len(), a.nrows + 1);
        assert_eq!(*a.rowptr.last().unwrap(), a.nnz());
        assert!(a.rowptr.windows(2).all(|w| w[0] <= w[1]), "rowptr not monotone");
        assert!(
            a.colidx.iter().all(|&c| (c as usize) < a.ncols),
            "column index out of range"
        );
        let geom = SemGeometry::new(SemLayout::External, table.ei_bit);
        let shift = 32 - table.ei_bit;
        let packed = (a.ncols as u64) <= (1u64 << shift);
        let nnz = a.nnz();
        let mut heads = Vec::with_capacity(nnz);
        let mut tail1 = Vec::with_capacity(nnz);
        let mut tail2 = Vec::with_capacity(nnz);
        let mut cols = Vec::with_capacity(nnz);
        let mut ext = if packed { None } else { Some(Vec::with_capacity(nnz)) };
        for (&c, &v) in a.colidx.iter().zip(&a.vals) {
            let p = sem::encode(v, &table, &geom)
                .unwrap_or_else(|_| saturate(v, &table, &geom));
            heads.push(p.head);
            tail1.push(p.tail1);
            tail2.push(p.tail2);
            if packed {
                cols.push(c | ((p.exp_idx as u32) << shift));
            } else {
                cols.push(c);
                ext.as_mut().unwrap().push(p.exp_idx as u8);
            }
        }
        let scales: Vec<f64> =
            table.entries.iter().map(|&e| ieee::ldexp(1.0, e as i32 - 1075)).collect();
        let scale_exact: Vec<bool> = scales
            .iter()
            .map(|&s| s.is_normal() && s > 0.0)
            .collect();
        // Signed per-index scale tables, built once here instead of per
        // SpMV chunk (the packed-LUT kernels index them unchecked, so
        // they are padded to the MAX_SHARED_EXPONENTS=64 table bound).
        let all_exact = scale_exact.iter().all(|&e| e);
        let mut sscale = vec![0f64; 2 * 64];
        let mut sscale_head = vec![0f64; 2 * 64];
        for (i, &e) in table.entries.iter().enumerate() {
            let s = ieee::ldexp(1.0, e as i32 - 1075);
            sscale[2 * i] = s;
            sscale[2 * i + 1] = -s;
            let sh = ieee::ldexp(1.0, e as i32 - 1075 + geom.s_head as i32);
            sscale_head[2 * i] = sh;
            sscale_head[2 * i + 1] = -sh;
        }
        Self {
            nrows: a.nrows,
            ncols: a.ncols,
            rowptr: a.rowptr.clone(),
            cols,
            heads,
            tail1,
            tail2,
            ext_idx: ext,
            table,
            geom,
            packed,
            strategy: DecodeStrategy::ScaleLut,
            threads: ThreadBudget::new(1),
            scales,
            scale_exact,
            all_exact,
            sscale,
            sscale_head,
        }
    }

    /// Reassemble an encoded matrix from its stored planes — the
    /// registry's spill-restore path (`coordinator::spill`). Only the
    /// fields a spill file persists are taken; every derived decode
    /// table (geometry, scale LUTs) is recomputed deterministically
    /// from `table`, so a restored matrix is indistinguishable from the
    /// original encode (same planes, same decode arithmetic, hence
    /// bitwise-identical SpMV).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        nrows: usize,
        ncols: usize,
        rowptr: Vec<usize>,
        cols: Vec<u32>,
        heads: Vec<u16>,
        tail1: Vec<u16>,
        tail2: Vec<u32>,
        ext_idx: Option<Vec<u8>>,
        table: GseTable,
        packed: bool,
    ) -> Self {
        let geom = SemGeometry::new(SemLayout::External, table.ei_bit);
        let scales: Vec<f64> =
            table.entries.iter().map(|&e| ieee::ldexp(1.0, e as i32 - 1075)).collect();
        let scale_exact: Vec<bool> = scales
            .iter()
            .map(|&s| s.is_normal() && s > 0.0)
            .collect();
        let all_exact = scale_exact.iter().all(|&e| e);
        let mut sscale = vec![0f64; 2 * 64];
        let mut sscale_head = vec![0f64; 2 * 64];
        for (i, &e) in table.entries.iter().enumerate() {
            let s = ieee::ldexp(1.0, e as i32 - 1075);
            sscale[2 * i] = s;
            sscale[2 * i + 1] = -s;
            let sh = ieee::ldexp(1.0, e as i32 - 1075 + geom.s_head as i32);
            sscale_head[2 * i] = sh;
            sscale_head[2 * i + 1] = -sh;
        }
        Self {
            nrows,
            ncols,
            rowptr,
            cols,
            heads,
            tail1,
            tail2,
            ext_idx,
            table,
            geom,
            packed,
            strategy: DecodeStrategy::ScaleLut,
            threads: ThreadBudget::new(1),
            scales,
            scale_exact,
            all_exact,
            sscale,
            sscale_head,
        }
    }

    pub fn nnz(&self) -> usize {
        self.heads.len()
    }

    pub fn with_strategy(mut self, s: DecodeStrategy) -> Self {
        self.strategy = s;
        self
    }

    /// Set the SpMV worker count (1 = serial). Any count produces
    /// bit-for-bit the serial result — rows never split across threads.
    /// Installs a fresh [`ThreadBudget`] handle (detaching a clone from
    /// its source); use [`SpmvOp::set_threads`] on any view of this
    /// encode to retune the shared handle post-build.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = ThreadBudget::new(threads);
        self
    }

    /// Wrap as an [`SpmvOp`] at a fixed precision level.
    pub fn at_level(self, level: Precision) -> GseSpmv {
        GseSpmv { m: Arc::new(self), level }
    }

    /// Column index and exponent index of non-zero `j`.
    #[inline(always)]
    pub fn col_and_idx(&self, j: usize) -> (usize, usize) {
        if self.packed {
            let shift = 32 - self.table.ei_bit;
            let cw = self.cols[j];
            (((cw << self.table.ei_bit) >> self.table.ei_bit) as usize, (cw >> shift) as usize)
        } else {
            (self.cols[j] as usize, self.ext_idx.as_ref().unwrap()[j] as usize)
        }
    }

    /// Frame integer (52-bit denormalized significand prefix) of
    /// non-zero `j` at `level`.
    #[inline(always)]
    fn frame(&self, j: usize, level: Precision) -> u64 {
        let mut d = ((self.heads[j] & 0x7FFF) as u64) << self.geom.s_head;
        if level >= Precision::HeadTail1 {
            d |= (self.tail1[j] as u64) << self.geom.s_tail1;
        }
        if level == Precision::Full {
            d |= self.tail2[j] as u64;
        }
        d
    }

    /// Decode non-zero `j` to f64 at `level` using `strategy`.
    #[inline(always)]
    pub fn decode(&self, j: usize, level: Precision) -> f64 {
        let (_, idx) = self.col_and_idx(j);
        self.decode_with_idx(j, idx, level)
    }

    #[inline(always)]
    fn decode_with_idx(&self, j: usize, idx: usize, level: Precision) -> f64 {
        match self.strategy {
            DecodeStrategy::ScaleLut => {
                let d = self.frame(j, level);
                if self.scale_exact[idx] {
                    let v = d as f64 * self.scales[idx];
                    if self.heads[j] & 0x8000 != 0 {
                        -v
                    } else {
                        v
                    }
                } else {
                    self.decode_ldexp_path(j, idx, level)
                }
            }
            DecodeStrategy::Ldexp => self.decode_ldexp_path(j, idx, level),
            DecodeStrategy::BitScan => {
                let parts = sem::SemParts {
                    head: self.heads[j],
                    tail1: if level >= Precision::HeadTail1 { self.tail1[j] } else { 0 },
                    tail2: if level == Precision::Full { self.tail2[j] } else { 0 },
                    exp_idx: idx as u16,
                };
                sem::decode_faithful(&parts, &self.table, &self.geom, level)
            }
        }
    }

    #[inline(always)]
    fn decode_ldexp_path(&self, j: usize, idx: usize, level: Precision) -> f64 {
        let d = self.frame(j, level);
        if d == 0 {
            return 0.0;
        }
        let stored = self.table.stored_exp(idx) as i32;
        let v = ieee::ldexp(d as f64, stored - 1075);
        if self.heads[j] & 0x8000 != 0 {
            -v
        } else {
            v
        }
    }

    /// Three-precision SpMV (Algorithm 2 generalized to all levels).
    /// Runs chunk-parallel over nnz-balanced row ranges when `threads`
    /// > 1 (the same shared hot path as the FP64 baseline).
    pub fn spmv(&self, x: &[f64], y: &mut [f64], level: Precision) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        let threads = self.threads.get();
        if threads <= 1 || self.nrows < super::par_min_rows() {
            return self.spmv_range(x, 0..self.nrows, y, level);
        }
        let chunks = parallel::balance_by_weight(self.nrows, threads, |r| {
            self.rowptr[r + 1] - self.rowptr[r]
        });
        parallel::for_each_disjoint(y, &chunks, |ch, ys| self.spmv_range(x, ch, ys, level));
    }

    /// One row-range of the SpMV; `y[i]` receives row `rows.start + i`.
    fn spmv_range(&self, x: &[f64], rows: Range<usize>, y: &mut [f64], level: Precision) {
        match (self.strategy, self.packed, level) {
            // Hot paths: fully inlined packed ScaleLut kernels.
            (DecodeStrategy::ScaleLut, true, Precision::Head) => {
                self.spmv_head_packed_lut(x, rows, y)
            }
            (DecodeStrategy::ScaleLut, true, lvl) => self.spmv_tails_packed_lut(x, rows, y, lvl),
            _ => self.spmv_generic(x, rows, y, level),
        }
    }

    /// Packed ScaleLut kernel for the head+tail1 / full levels: the
    /// 52-bit frame is assembled from the segments and scaled by the
    /// signed per-index power of two (same structure as the head kernel,
    /// one u64→f64 convert instead of a u16 widen).
    fn spmv_tails_packed_lut(
        &self,
        x: &[f64],
        rows: Range<usize>,
        y: &mut [f64],
        level: Precision,
    ) {
        let shift = 32 - self.table.ei_bit;
        let col_mask = (1u32 << shift) - 1;
        if !self.all_exact {
            return self.spmv_generic(x, rows, y, level);
        }
        let sscale = &self.sscale[..];
        let full = level == Precision::Full;
        let (s_head, s_tail1) = (self.geom.s_head, self.geom.s_tail1);
        let heads = &self.heads[..];
        let tail1 = &self.tail1[..];
        let tail2 = &self.tail2[..];
        let cols = &self.cols[..];
        for (i, r) in rows.enumerate() {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            let mut sum = 0.0;
            for j in a..b {
                // SAFETY: validated at construction (see from_csr_with_table)
                let (cw, h, t1) = unsafe {
                    (*cols.get_unchecked(j), *heads.get_unchecked(j), *tail1.get_unchecked(j))
                };
                let mut d = (((h & 0x7FFF) as u64) << s_head) | ((t1 as u64) << s_tail1);
                if full {
                    d |= unsafe { *tail2.get_unchecked(j) } as u64;
                }
                let scale = unsafe {
                    *sscale.get_unchecked(2 * (cw >> shift) as usize + (h >> 15) as usize)
                };
                let xv = unsafe { *x.get_unchecked((cw & col_mask) as usize) };
                sum += d as f64 * scale * xv;
            }
            y[i] = sum;
        }
    }

    fn spmv_generic(&self, x: &[f64], rows: Range<usize>, y: &mut [f64], level: Precision) {
        for (i, r) in rows.enumerate() {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            let mut sum = 0.0;
            for j in a..b {
                let (col, idx) = self.col_and_idx(j);
                sum += self.decode_with_idx(j, idx, level) * x[col];
            }
            y[i] = sum;
        }
    }

    /// Specialized kernel: packed indexes + ScaleLut + head segment only
    /// — the configuration every headline number uses (k=8, head SpMV).
    ///
    /// Optimizations over the generic path (EXPERIMENTS.md §Perf):
    /// * the frame shift `<< s_head` is folded into the per-index scale
    ///   (`2^(stored − 1075 + s_head)`), so the int→fp convert is a
    ///   cheap u16 widen instead of a u64;
    /// * the sign is applied branchlessly through a (idx, sign)-indexed
    ///   signed-scale table (±scale), removing the unpredictable branch;
    /// * gathers are bounds-check-free (`cols`/rowptr validated at
    ///   construction).
    fn spmv_head_packed_lut(&self, x: &[f64], rows: Range<usize>, y: &mut [f64]) {
        let shift = 32 - self.table.ei_bit;
        let col_mask = (1u32 << shift) - 1;
        if !self.all_exact {
            return self.spmv_generic(x, rows, y, Precision::Head);
        }
        // signed, shift-folded scale table: [idx*2 + sign]
        let sscale = &self.sscale_head[..];
        let heads = &self.heads[..];
        let cols = &self.cols[..];
        for (i, r) in rows.enumerate() {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            let mut sum = 0.0;
            for j in a..b {
                // SAFETY: rowptr/cols validated against heads len and
                // ncols at construction (from_csr over a validated Csr).
                let (cw, h) = unsafe { (*cols.get_unchecked(j), *heads.get_unchecked(j)) };
                let mant = (h & 0x7FFF) as f64;
                let scale = unsafe {
                    *sscale.get_unchecked(2 * (cw >> shift) as usize + (h >> 15) as usize)
                };
                let xv = unsafe { *x.get_unchecked((cw & col_mask) as usize) };
                sum += mant * scale * xv;
            }
            y[i] = sum;
        }
    }

    /// Fused multi-RHS three-precision SpMV over column-major packed
    /// vectors (layout in [`SpmvOp::apply_multi`]): every SEM word is
    /// decoded **once** per apply and streamed across all RHS, so the
    /// segment traffic and decode overhead amortize over the batch.
    /// Bit-for-bit identical to `nrhs` single [`GseCsr::spmv`] calls for
    /// every strategy / packing / thread count.
    pub fn spmv_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize, level: Precision) {
        assert_eq!(x.len(), self.ncols * nrhs);
        assert_eq!(y.len(), self.nrows * nrhs);
        if nrhs == 0 {
            return;
        }
        let parts = super::multi_parts(self.threads.get(), self.nrows, nrhs);
        let chunks = parallel::balance_by_weight(self.nrows, parts, |r| {
            self.rowptr[r + 1] - self.rowptr[r]
        });
        parallel::for_each_disjoint_cols(y, self.nrows, &chunks, |ch, cols| {
            self.spmv_multi_range(x, ch, cols, level)
        });
    }

    /// One row-range of the multi-RHS SpMV; `cols_out[j][i]` receives
    /// (row `rows.start + i`, RHS `j`). Kernel dispatch mirrors
    /// [`GseCsr::spmv_range`].
    fn spmv_multi_range(
        &self,
        x: &[f64],
        rows: Range<usize>,
        cols_out: &mut [&mut [f64]],
        level: Precision,
    ) {
        if self.strategy == DecodeStrategy::ScaleLut && self.packed && self.all_exact {
            if level == Precision::Head {
                self.spmv_multi_head_packed_lut(x, rows, cols_out)
            } else {
                self.spmv_multi_tails_packed_lut(x, rows, cols_out, level)
            }
        } else {
            self.spmv_multi_generic(x, rows, cols_out, level)
        }
    }

    /// Multi-RHS sibling of [`GseCsr::spmv_head_packed_lut`]: one decode
    /// (`mant × signed scale`) per non-zero, broadcast through the
    /// [`super::tile`] register tiles. The product order per RHS matches
    /// the single-RHS kernel exactly.
    fn spmv_multi_head_packed_lut(
        &self,
        x: &[f64],
        rows: Range<usize>,
        cols_out: &mut [&mut [f64]],
    ) {
        let shift = 32 - self.table.ei_bit;
        let col_mask = (1u32 << shift) - 1;
        let sscale = &self.sscale_head[..];
        let heads = &self.heads[..];
        let cols = &self.cols[..];
        let ncols = self.ncols;
        let mut acc = vec![0.0f64; cols_out.len()];
        for (i, r) in rows.enumerate() {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            acc.fill(0.0);
            for j in a..b {
                // SAFETY: validated at construction (from_csr_with_table);
                // x length asserted against ncols*nrhs in spmv_multi.
                let (cw, h) = unsafe { (*cols.get_unchecked(j), *heads.get_unchecked(j)) };
                let scale = unsafe {
                    *sscale.get_unchecked(2 * (cw >> shift) as usize + (h >> 15) as usize)
                };
                let val = (h & 0x7FFF) as f64 * scale;
                let c = (cw & col_mask) as usize;
                // SAFETY: c < ncols (construction) and x.len() ==
                // ncols * nrhs (kernel mouth), so with stride == ncols
                // and acc.len() == nrhs the lane walk stays in range.
                unsafe { super::tile::fma_lanes_unchecked(&mut acc, val, x, c, ncols) };
            }
            for (q, aq) in acc.iter().enumerate() {
                cols_out[q][i] = *aq;
            }
        }
    }

    /// Multi-RHS sibling of [`GseCsr::spmv_tails_packed_lut`].
    fn spmv_multi_tails_packed_lut(
        &self,
        x: &[f64],
        rows: Range<usize>,
        cols_out: &mut [&mut [f64]],
        level: Precision,
    ) {
        let shift = 32 - self.table.ei_bit;
        let col_mask = (1u32 << shift) - 1;
        let sscale = &self.sscale[..];
        let full = level == Precision::Full;
        let (s_head, s_tail1) = (self.geom.s_head, self.geom.s_tail1);
        let heads = &self.heads[..];
        let tail1 = &self.tail1[..];
        let tail2 = &self.tail2[..];
        let cols = &self.cols[..];
        let ncols = self.ncols;
        let mut acc = vec![0.0f64; cols_out.len()];
        for (i, r) in rows.enumerate() {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            acc.fill(0.0);
            for j in a..b {
                // SAFETY: validated at construction (see from_csr_with_table)
                let (cw, h, t1) = unsafe {
                    (*cols.get_unchecked(j), *heads.get_unchecked(j), *tail1.get_unchecked(j))
                };
                let mut d = (((h & 0x7FFF) as u64) << s_head) | ((t1 as u64) << s_tail1);
                if full {
                    d |= unsafe { *tail2.get_unchecked(j) } as u64;
                }
                let scale = unsafe {
                    *sscale.get_unchecked(2 * (cw >> shift) as usize + (h >> 15) as usize)
                };
                let val = d as f64 * scale;
                let c = (cw & col_mask) as usize;
                // SAFETY: same range argument as the head kernel above.
                unsafe { super::tile::fma_lanes_unchecked(&mut acc, val, x, c, ncols) };
            }
            for (q, aq) in acc.iter().enumerate() {
                cols_out[q][i] = *aq;
            }
        }
    }

    /// Multi-RHS sibling of [`GseCsr::spmv_generic`] (any strategy /
    /// packing): still decodes each non-zero once per apply.
    fn spmv_multi_generic(
        &self,
        x: &[f64],
        rows: Range<usize>,
        cols_out: &mut [&mut [f64]],
        level: Precision,
    ) {
        let ncols = self.ncols;
        let mut acc = vec![0.0f64; cols_out.len()];
        for (i, r) in rows.enumerate() {
            let (a, b) = (self.rowptr[r], self.rowptr[r + 1]);
            acc.fill(0.0);
            for j in a..b {
                let (col, idx) = self.col_and_idx(j);
                let val = self.decode_with_idx(j, idx, level);
                super::tile::fma_lanes(&mut acc, val, x, col, ncols);
            }
            for (q, aq) in acc.iter().enumerate() {
                cols_out[q][i] = *aq;
            }
        }
    }

    /// Materialize the decoded matrix at a level (tests / analyses).
    pub fn decode_csr(&self, level: Precision) -> Csr {
        let vals: Vec<f64> = (0..self.nnz()).map(|j| self.decode(j, level)).collect();
        let cols: Vec<u32> = (0..self.nnz()).map(|j| self.col_and_idx(j).0 as u32).collect();
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            rowptr: self.rowptr.clone(),
            colidx: cols,
            vals,
        }
    }

    /// Max |A_orig − A_level| over stored entries.
    pub fn max_abs_error(&self, original: &Csr, level: Precision) -> f64 {
        debug_assert_eq!(original.nnz(), self.nnz());
        original
            .vals
            .iter()
            .enumerate()
            .map(|(j, &v)| (v - self.decode(j, level)).abs())
            .fold(0.0, f64::max)
    }

    /// Matrix bytes read per SpMV at `level` (the traffic model input).
    pub fn bytes_at(&self, level: Precision) -> usize {
        let idx_bytes = if self.packed { 0 } else { self.nnz() };
        self.nnz() * (4 + level.bytes_per_value())
            + idx_bytes
            + (self.nrows + 1) * 8
            + self.table.len() * 4
    }

    /// Total resident bytes of the encode — all three segment planes,
    /// column words, out-of-band exponent indexes, row pointers, and
    /// the shared-exponent table. This is what a registry cache entry
    /// actually holds (every precision level views the same storage),
    /// as opposed to [`GseCsr::bytes_at`], the per-apply traffic of one
    /// level.
    pub fn encoded_bytes(&self) -> usize {
        let ext = self.ext_idx.as_ref().map_or(0, Vec::len);
        self.heads.len() * 2
            + self.tail1.len() * 2
            + self.tail2.len() * 4
            + self.cols.len() * 4
            + ext
            + (self.nrows + 1) * 8
            + self.table.len() * 4
    }
}

/// Clamp out-of-table values to the largest shared binade (same policy
/// as `SemVector`).
fn saturate(x: f64, table: &GseTable, geom: &SemGeometry) -> sem::SemParts {
    let (bi, _) = table
        .entries
        .iter()
        .enumerate()
        .map(|(i, &e)| (i, e))
        .max_by_key(|&(_, e)| e)
        .unwrap();
    let stored = table.stored_exp(bi);
    let max_val = ieee::ldexp(((1u64 << 52) - 1) as f64, stored as i32 - 1075);
    let v = if x.is_nan() { 0.0 } else { max_val.copysign(x) };
    sem::encode(v, table, geom).expect("saturated value must encode")
}

/// [`SpmvOp`] adapter fixing the precision level. Holds the encoded
/// matrix behind an `Arc` so one encode can serve several levels (the
/// operator comparison set) or cache entries without deep copies.
#[derive(Clone)]
pub struct GseSpmv {
    pub m: Arc<GseCsr>,
    pub level: Precision,
}

impl GseSpmv {
    /// View an already-shared encoded matrix at `level`.
    pub fn new(m: Arc<GseCsr>, level: Precision) -> Self {
        Self { m, level }
    }
}

impl SpmvOp for GseSpmv {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.m.spmv(x, y, self.level);
    }

    fn apply_multi(&self, x: &[f64], y: &mut [f64], nrhs: usize) {
        self.m.spmv_multi(x, y, nrhs, self.level);
    }

    fn set_threads(&self, threads: usize) {
        // the budget lives on the shared encode: all sibling level
        // views (and any ladder over the same encode) retune together
        self.m.threads.set(threads);
    }

    fn threads(&self) -> usize {
        self.m.threads.get()
    }

    fn nrows(&self) -> usize {
        self.m.nrows
    }

    fn ncols(&self) -> usize {
        self.m.ncols
    }

    fn format(&self) -> ValueFormat {
        ValueFormat::GseSem(self.level)
    }

    fn matrix_bytes(&self) -> usize {
        self.m.bytes_at(self.level)
    }

    fn encoded_bytes(&self) -> usize {
        // the level is a view: the whole encode stays resident
        self.m.encoded_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;
    use crate::sparse::gen::randmat::{exp_controlled, ExpLaw};
    use crate::spmv::{fp64, max_abs_diff};
    use crate::util::quickcheck;
    use crate::util::Prng;

    fn rand_x(n: usize, seed: u64) -> Vec<f64> {
        let mut r = Prng::new(seed);
        (0..n).map(|_| r.range_f64(-2.0, 2.0)).collect()
    }

    #[test]
    fn exact_on_poisson_all_levels_all_strategies() {
        let a = poisson2d(12, 12);
        let x = rand_x(a.ncols, 1);
        let mut y64 = vec![0.0; a.nrows];
        fp64::spmv(&a, &x, &mut y64);
        for strat in [DecodeStrategy::BitScan, DecodeStrategy::Ldexp, DecodeStrategy::ScaleLut] {
            let g = GseCsr::from_csr(&a, 8).with_strategy(strat);
            for lvl in Precision::LADDER {
                let mut y = vec![0.0; a.nrows];
                g.spmv(&x, &mut y, lvl);
                assert_eq!(max_abs_diff(&y64, &y), 0.0, "{strat:?} {lvl:?}");
            }
        }
    }

    #[test]
    fn packed_bit_layout_matches_paper_alg2() {
        // k=8 -> EI_bit=3 -> expIdx = col >> 29, col &= MAX_29
        let a = exp_controlled(50, 50, 5, ExpLaw::Zipf { e0: 0, count: 10, s: 1.0 }, 2);
        let g = GseCsr::from_csr(&a, 8);
        assert!(g.packed);
        assert_eq!(g.table.ei_bit, 3);
        for j in 0..g.nnz() {
            let cw = g.cols[j];
            let (col, idx) = g.col_and_idx(j);
            assert_eq!(idx, (cw >> 29) as usize);
            assert_eq!(col, (cw & ((1 << 29) - 1)) as usize);
            assert_eq!(col as u32, a.colidx[j]);
            assert!(idx < g.table.len());
        }
    }

    #[test]
    fn unpacked_fallback_when_columns_huge() {
        // Force the fallback by constructing a matrix with huge ncols.
        let mut a = poisson2d(4, 4);
        a.ncols = (1 << 31) + 1; // exceeds 2^(32-ei_bit) for every ei_bit
        let g = GseCsr::from_csr(&a, 8);
        assert!(!g.packed);
        assert!(g.ext_idx.is_some());
        // decode parity instead of spmv (an x of 2^30 doubles would be absurd)
        let d = g.decode_csr(Precision::Full);
        for (j, &v) in a.vals.iter().enumerate() {
            assert_eq!(d.vals[j], v);
        }
    }

    #[test]
    fn strategies_agree_bitwise() {
        quickcheck::check(
            77,
            60,
            |r| {
                let n = 10 + r.below(40);
                let law = match r.below(3) {
                    0 => ExpLaw::Zipf { e0: -6, count: 16, s: 1.2 },
                    1 => ExpLaw::Gaussian { e0: 2, sigma: 5.0 },
                    _ => ExpLaw::Bimodal { e0: -3, gap: 9, p: 0.8 },
                };
                let k = [2usize, 4, 8, 16, 32, 64][r.below(6)];
                (exp_controlled(n, n, 4, law, r.next_u64()), k)
            },
            |(a, k)| {
                let x = rand_x(a.ncols, 5);
                let base = GseCsr::from_csr(a, *k);
                for lvl in Precision::LADDER {
                    let mut ys: Vec<Vec<f64>> = Vec::new();
                    for strat in
                        [DecodeStrategy::BitScan, DecodeStrategy::Ldexp, DecodeStrategy::ScaleLut]
                    {
                        let g = base.clone().with_strategy(strat);
                        let mut y = vec![0.0; a.nrows];
                        g.spmv(&x, &mut y, lvl);
                        ys.push(y);
                    }
                    for y in &ys[1..] {
                        if ys[0] != *y {
                            return Err(format!("strategy mismatch at {lvl:?}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn error_decreases_with_level_and_k() {
        let a = exp_controlled(200, 200, 6, ExpLaw::Gaussian { e0: 0, sigma: 4.0 }, 3);
        let x = vec![1.0; a.ncols]; // paper sets x = 1 to observe errors
        let mut y64 = vec![0.0; a.nrows];
        fp64::spmv(&a, &x, &mut y64);
        let errs_k: Vec<f64> = [2usize, 8, 64]
            .iter()
            .map(|&k| {
                let g = GseCsr::from_csr(&a, k);
                let mut y = vec![0.0; a.nrows];
                g.spmv(&x, &mut y, Precision::Head);
                max_abs_diff(&y64, &y)
            })
            .collect();
        assert!(errs_k[0] >= errs_k[1] && errs_k[1] >= errs_k[2], "{errs_k:?}");

        let g = GseCsr::from_csr(&a, 8);
        let levels: Vec<f64> = Precision::LADDER
            .iter()
            .map(|&lvl| {
                let mut y = vec![0.0; a.nrows];
                g.spmv(&x, &mut y, lvl);
                max_abs_diff(&y64, &y)
            })
            .collect();
        assert!(levels[0] >= levels[1] && levels[1] >= levels[2], "{levels:?}");
        assert!(levels[2] < levels[0]);
    }

    #[test]
    fn parallel_spmv_bit_exact_vs_serial() {
        // large enough to clear the PAR_MIN_ROWS fallback
        let a = exp_controlled(1500, 1500, 6, ExpLaw::Gaussian { e0: 0, sigma: 3.0 }, 12);
        let x = rand_x(a.ncols, 9);
        let serial = GseCsr::from_csr(&a, 8);
        for lvl in Precision::LADDER {
            let mut y1 = vec![0.0; a.nrows];
            serial.spmv(&x, &mut y1, lvl);
            for threads in [1usize, 2, 4, 7] {
                let par = serial.clone().with_threads(threads);
                let mut y2 = vec![0.0; a.nrows];
                par.spmv(&x, &mut y2, lvl);
                assert_eq!(y1, y2, "threads={threads} {lvl:?}");
            }
        }
    }

    #[test]
    fn fused_multi_rhs_equals_looped_single_all_strategies() {
        let a = exp_controlled(120, 120, 5, ExpLaw::Gaussian { e0: 0, sigma: 3.0 }, 21);
        for strat in [DecodeStrategy::BitScan, DecodeStrategy::Ldexp, DecodeStrategy::ScaleLut] {
            let g = GseCsr::from_csr(&a, 8).with_strategy(strat);
            for lvl in Precision::LADDER {
                for nrhs in [1usize, 3, 8] {
                    let x = rand_x(a.ncols * nrhs, 40 + nrhs as u64);
                    let mut y_loop = vec![0.0; a.nrows * nrhs];
                    for j in 0..nrhs {
                        let (lo, hi) = (j * a.nrows, (j + 1) * a.nrows);
                        g.spmv(&x[j * a.ncols..(j + 1) * a.ncols], &mut y_loop[lo..hi], lvl);
                    }
                    let mut y = vec![0.0; a.nrows * nrhs];
                    g.spmv_multi(&x, &mut y, nrhs, lvl);
                    assert_eq!(y, y_loop, "{strat:?} {lvl:?} nrhs={nrhs}");
                }
            }
        }
    }

    #[test]
    fn fused_multi_rhs_parallel_bit_exact() {
        let a = exp_controlled(1300, 1300, 5, ExpLaw::Gaussian { e0: 0, sigma: 3.0 }, 6);
        let g = GseCsr::from_csr(&a, 8);
        let nrhs = 4usize;
        let x = rand_x(a.ncols * nrhs, 17);
        for lvl in Precision::LADDER {
            let mut y1 = vec![0.0; a.nrows * nrhs];
            g.spmv_multi(&x, &mut y1, nrhs, lvl);
            for threads in [2usize, 5] {
                let par = g.clone().with_threads(threads);
                let mut y2 = vec![0.0; a.nrows * nrhs];
                par.spmv_multi(&x, &mut y2, nrhs, lvl);
                assert_eq!(y1, y2, "threads={threads} {lvl:?}");
            }
        }
    }

    #[test]
    fn bytes_at_accounts_segments() {
        let a = poisson2d(10, 10);
        let g = GseCsr::from_csr(&a, 8);
        let h = g.bytes_at(Precision::Head);
        let t1 = g.bytes_at(Precision::HeadTail1);
        let f = g.bytes_at(Precision::Full);
        assert_eq!(t1 - h, g.nnz() * 2);
        assert_eq!(f - t1, g.nnz() * 4);
    }

    #[test]
    fn decode_csr_error_bounds() {
        let a = exp_controlled(100, 100, 5, ExpLaw::Zipf { e0: -2, count: 8, s: 1.5 }, 9);
        let g = GseCsr::from_csr(&a, 8);
        // head: 15 mantissa bits minus denormalization loss; full: near-lossless
        let e_full = g.max_abs_error(&a, Precision::Full);
        let e_head = g.max_abs_error(&a, Precision::Head);
        let amax = a.vals.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(e_full <= amax * 2f64.powi(-44), "full err {e_full}");
        assert!(e_head <= amax * 2f64.powi(-4), "head err {e_head}");
        assert!(e_head > e_full);
    }

    #[test]
    fn spmv_op_adapter() {
        let a = poisson2d(6, 6);
        let op = GseCsr::from_csr(&a, 8).at_level(Precision::Head);
        assert_eq!(op.format(), ValueFormat::GseSem(Precision::Head));
        assert_eq!(op.nrows(), 36);
        let x = vec![1.0; 36];
        let mut y = vec![0.0; 36];
        op.apply(&x, &mut y);
        assert!(y.iter().any(|&v| v != 0.0));
    }
}
