//! Memory-traffic / roofline model (DESIGN.md §5 substitution).
//!
//! The paper's SpMV numbers come from a V100 (898 GB/s HBM2). SpMV is
//! memory-bound on both the V100 and this CPU, so the *shape* of every
//! speedup figure is a traffic ratio modulated by decode overhead. This
//! model converts bytes-moved into modeled kernel time so benches can
//! report the paper's setting alongside measured CPU time:
//!
//! `t_model = bytes / BW + nnz · decode_ns(format)`
//!
//! with the decode cost per non-zero calibrated from the GPU ratios the
//! paper reports (GSE-SEM slower than FP16/BF16 "because they have
//! almost the same memory access overhead [but] higher kernel execution
//! overhead", §IV-C).

use crate::formats::{Precision, ValueFormat};
use crate::sparse::csr::Csr;

/// Device model parameters.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    /// main-memory bandwidth in bytes/s
    pub bw: f64,
    /// extra per-nonzero decode cost (seconds) for GSE-SEM conversion
    pub gse_decode_ns: f64,
    /// per-nonzero cost of the trivial FP16/BF16->FP64 widening
    pub widen_ns: f64,
}

/// The paper's evaluation device (Table I). The decode/widen costs are
/// calibrated so the modeled format ordering and speedup magnitudes
/// match §IV-C: GSE-SEM(head) ≈ 1.2–1.4× over FP64 (Fig. 5 peak at k=8),
/// FP16/BF16 strictly faster kernels than GSE-SEM (Fig. 6a) because the
/// widening conversion is much cheaper than the SEM renormalization.
pub const V100: Device =
    Device { name: "V100-SXM2", bw: 898e9, gse_decode_ns: 0.0022, widen_ns: 0.0005 };

/// Table size charged by the k-agnostic entry points below — the
/// paper's maximum group count ([`crate::formats::gse::MAX_SHARED_EXPONENTS`]).
/// Callers that know the actual k should use the `*_at_k` variants.
pub const DEFAULT_MODEL_K: usize = 64;

impl Device {
    /// Bytes moved by one SpMV for a matrix stored in `fmt`.
    /// Counts matrix values + column indexes + rowptr + input gather +
    /// output write (input gather modeled as one 8-byte load per nnz,
    /// the worst case the CSR-vector kernel approaches for scattered
    /// columns; caches only improve both sides equally).
    pub fn spmv_bytes(&self, nnz: usize, nrows: usize, fmt: ValueFormat) -> f64 {
        self.spmv_multi_bytes(nnz, nrows, fmt, 1)
    }

    /// Matrix-plane bytes of one SpMV — the part a fused multi-RHS
    /// kernel streams **once** regardless of batch width: values,
    /// column indexes, rowptr, and the shared-exponent table. Charges a
    /// [`DEFAULT_MODEL_K`]-entry table for `GseSem`; see
    /// [`Device::spmv_matrix_bytes_at_k`] for the k-exact model.
    pub fn spmv_matrix_bytes(&self, nnz: usize, nrows: usize, fmt: ValueFormat) -> f64 {
        self.spmv_matrix_bytes_at_k(nnz, nrows, fmt, DEFAULT_MODEL_K)
    }

    /// Matrix-plane bytes with the shared-exponent table charged at its
    /// actual size: `k` 4-byte entries for `GseSem`, nothing otherwise.
    pub fn spmv_matrix_bytes_at_k(
        &self,
        nnz: usize,
        nrows: usize,
        fmt: ValueFormat,
        k: usize,
    ) -> f64 {
        let value_bytes = fmt.bytes_per_value();
        let gse_table = match fmt {
            ValueFormat::GseSem(_) => k * 4,
            _ => 0,
        };
        (nnz * (value_bytes + 4) + (nrows + 1) * 8 + gse_table) as f64
    }

    /// Per-RHS vector traffic of one SpMV: the input gather (one 8-byte
    /// load per nnz, the scattered-column worst case) plus the output
    /// write.
    pub fn spmv_rhs_bytes(&self, nnz: usize, nrows: usize) -> f64 {
        (nnz * 8 + nrows * 8) as f64
    }

    /// Bytes moved by one fused multi-RHS SpMV: matrix planes once,
    /// vector traffic per RHS. [`Device::spmv_bytes`] is the `nrhs = 1`
    /// case; the looped baseline instead pays
    /// `nrhs × spmv_bytes`. This is the byte model behind the
    /// achieved-GB/s / roofline-fraction columns in `ablation_batch`.
    pub fn spmv_multi_bytes(&self, nnz: usize, nrows: usize, fmt: ValueFormat, nrhs: usize) -> f64 {
        self.spmv_matrix_bytes(nnz, nrows, fmt) + nrhs as f64 * self.spmv_rhs_bytes(nnz, nrows)
    }

    /// [`Device::spmv_multi_bytes`] with the table charged at its actual
    /// k ([`Device::spmv_matrix_bytes_at_k`]).
    pub fn spmv_multi_bytes_at_k(
        &self,
        nnz: usize,
        nrows: usize,
        fmt: ValueFormat,
        nrhs: usize,
        k: usize,
    ) -> f64 {
        self.spmv_matrix_bytes_at_k(nnz, nrows, fmt, k)
            + nrhs as f64 * self.spmv_rhs_bytes(nnz, nrows)
    }

    /// Per-nonzero decode cost (seconds) of widening `fmt` to fp64.
    fn decode_time(&self, nnz: usize, fmt: ValueFormat) -> f64 {
        match fmt {
            ValueFormat::GseSem(_) => nnz as f64 * self.gse_decode_ns * 1e-9,
            ValueFormat::Fp16 | ValueFormat::Bf16 | ValueFormat::Fp32 => {
                nnz as f64 * self.widen_ns * 1e-9
            }
            ValueFormat::Fp64 => 0.0,
        }
    }

    /// Modeled kernel time for one SpMV.
    pub fn spmv_time(&self, nnz: usize, nrows: usize, fmt: ValueFormat) -> f64 {
        self.spmv_bytes(nnz, nrows, fmt) / self.bw + self.decode_time(nnz, fmt)
    }

    /// Modeled kernel time for one fused multi-RHS SpMV with the
    /// shared-exponent table charged at its actual k. The decode cost is
    /// paid once per non-zero — fused kernels decode each value once and
    /// broadcast it across the RHS block.
    pub fn spmv_multi_time_at_k(
        &self,
        nnz: usize,
        nrows: usize,
        fmt: ValueFormat,
        nrhs: usize,
        k: usize,
    ) -> f64 {
        self.spmv_multi_bytes_at_k(nnz, nrows, fmt, nrhs, k) / self.bw
            + self.decode_time(nnz, fmt)
    }

    /// Modeled speedup of `fmt` over FP64 storage.
    pub fn speedup_vs_fp64(&self, a: &Csr, fmt: ValueFormat) -> f64 {
        self.spmv_time(a.nnz(), a.nrows, ValueFormat::Fp64)
            / self.spmv_time(a.nnz(), a.nrows, fmt)
    }

    /// Modeled GFLOPS (2 flops per nnz, the paper's Fig. 6(a) metric).
    pub fn spmv_gflops(&self, a: &Csr, fmt: ValueFormat) -> f64 {
        2.0 * a.nnz() as f64 / self.spmv_time(a.nnz(), a.nrows, fmt) / 1e9
    }
}

/// Extra shared-exponent traffic for a k-entry table per SpMV — used by
/// the Fig. 4/5 "speedup first rises then falls with k" explanation
/// (shared-memory staging + register loads on the GPU).
pub fn k_overhead_time(dev: &Device, k: usize, nnz: usize) -> f64 {
    // staging cost ~ k, per-nnz register pressure cost grows mildly with k
    let staging = k as f64 * 16.0 / dev.bw;
    let per_nnz = (k as f64).log2().max(0.0) * 2e-13;
    staging + nnz as f64 * per_nnz
}

/// Modeled GSE-SEM(head) time at a given k, including the k-dependent
/// cost and the miss-ratio-dependent bit-scan cost: values whose
/// exponent is NOT an exact table hit pay a longer renormalization path
/// (Alg. 2's "finding cost is relatively low" fast path discussion).
pub fn gse_head_time_at_k(dev: &Device, a: &Csr, k: usize, exact_hit_ratio: f64) -> f64 {
    let base = dev.spmv_time(a.nnz(), a.nrows, ValueFormat::GseSem(Precision::Head));
    let miss = (1.0 - exact_hit_ratio).max(0.0);
    base + k_overhead_time(dev, k, a.nnz()) + a.nnz() as f64 * miss * 0.004e-9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson::poisson2d;

    #[test]
    fn fp64_moves_most_bytes() {
        let d = V100;
        let b64 = d.spmv_bytes(1000, 100, ValueFormat::Fp64);
        let bh = d.spmv_bytes(1000, 100, ValueFormat::GseSem(Precision::Head));
        let bf = d.spmv_bytes(1000, 100, ValueFormat::Fp16);
        assert!(b64 > bh && bh > bf - 300.0);
        assert!(b64 - bh >= 1000.0 * 6.0 - 300.0);
    }

    #[test]
    fn fused_multi_bytes_amortize_matrix_planes() {
        let d = V100;
        for fmt in [ValueFormat::Fp64, ValueFormat::Fp16, ValueFormat::GseSem(Precision::Head)] {
            let single = d.spmv_bytes(1000, 100, fmt);
            // nrhs = 1 decomposes exactly into matrix + one RHS share
            assert_eq!(d.spmv_multi_bytes(1000, 100, fmt, 1), single);
            assert_eq!(
                single,
                d.spmv_matrix_bytes(1000, 100, fmt) + d.spmv_rhs_bytes(1000, 100)
            );
            // the fused batch streams the matrix once, the loop 8 times
            let fused8 = d.spmv_multi_bytes(1000, 100, fmt, 8);
            assert!(fused8 < 8.0 * single, "{fmt:?}");
            assert!(fused8 > d.spmv_rhs_bytes(1000, 100) * 8.0);
        }
    }

    #[test]
    fn modeled_ordering_matches_paper() {
        // Fig. 6: FP16/BF16 fastest, GSE-SEM(head) next, FP64 slowest.
        let a = poisson2d(64, 64);
        let d = V100;
        let t16 = d.spmv_time(a.nnz(), a.nrows, ValueFormat::Fp16);
        let tg = d.spmv_time(a.nnz(), a.nrows, ValueFormat::GseSem(Precision::Head));
        let t64 = d.spmv_time(a.nnz(), a.nrows, ValueFormat::Fp64);
        assert!(t16 < tg && tg < t64, "{t16} {tg} {t64}");
        // and the speedup over fp64 is > 1 (paper: avg 1.1-1.4x)
        let s = d.speedup_vs_fp64(&a, ValueFormat::GseSem(Precision::Head));
        assert!(s > 1.0 && s < 2.0, "s={s}");
    }

    #[test]
    fn k_sweep_has_interior_optimum() {
        // Fig. 5: speedup rises then falls with k. With a fixed hit-ratio
        // improvement schedule the model must produce an interior max.
        let a = poisson2d(96, 96);
        let d = V100;
        // mimic a matrix where hit ratio saturates by k=8
        let hit = |k: usize| (1.0 - 0.5 / k as f64).min(1.0);
        let times: Vec<f64> = [2usize, 4, 8, 16, 32, 64]
            .iter()
            .map(|&k| gse_head_time_at_k(&d, &a, k, hit(k)))
            .collect();
        let best = times
            .iter()
            .enumerate()
            .min_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        assert!(best > 0 && best < 5, "best index {best}, times {times:?}");
    }

    #[test]
    fn table_bytes_follow_k_with_k64_default_unchanged() {
        // Regression: the GSE table was hard-coded at 64 × 4 bytes for
        // every k. The k-agnostic entry points must stay byte-for-byte
        // at k = 64 (roofline columns, ablation_batch asserts), while
        // the *_at_k variants charge the real table.
        let d = V100;
        let head = ValueFormat::GseSem(Precision::Head);
        let full = ValueFormat::GseSem(Precision::Full);
        for fmt in [ValueFormat::Fp64, ValueFormat::Fp16, head, full] {
            assert_eq!(
                d.spmv_matrix_bytes(1000, 100, fmt),
                d.spmv_matrix_bytes_at_k(1000, 100, fmt, DEFAULT_MODEL_K)
            );
            assert_eq!(
                d.spmv_multi_bytes(1000, 100, fmt, 4),
                d.spmv_multi_bytes_at_k(1000, 100, fmt, 4, DEFAULT_MODEL_K)
            );
            assert_eq!(
                d.spmv_time(1000, 100, fmt),
                d.spmv_multi_time_at_k(1000, 100, fmt, 1, DEFAULT_MODEL_K)
            );
        }
        // a k=8 table is exactly 56 entries (224 bytes) lighter
        let b64 = d.spmv_matrix_bytes_at_k(1000, 100, head, 64);
        let b8 = d.spmv_matrix_bytes_at_k(1000, 100, head, 8);
        assert_eq!(b64 - b8, 56.0 * 4.0);
        // non-GSE formats carry no table regardless of k
        assert_eq!(
            d.spmv_matrix_bytes_at_k(1000, 100, ValueFormat::Fp64, 2),
            d.spmv_matrix_bytes_at_k(1000, 100, ValueFormat::Fp64, 64)
        );
        // smaller tables shrink modeled time, consistent with k_overhead_time
        assert!(
            d.spmv_multi_time_at_k(1000, 100, head, 4, 8)
                < d.spmv_multi_time_at_k(1000, 100, head, 4, 64)
        );
    }

    #[test]
    fn gflops_metric_consistent() {
        let a = poisson2d(32, 32);
        let g64 = V100.spmv_gflops(&a, ValueFormat::Fp64);
        let gh = V100.spmv_gflops(&a, ValueFormat::GseSem(Precision::Head));
        assert!(gh > g64);
        assert!(g64 > 1.0); // V100-scale numbers, not CPU-scale
    }
}
