//! Register-tiled multi-RHS inner-loop primitive.
//!
//! Every fused `apply_multi` kernel shares one inner operation: a matrix
//! value `v` decoded once at column `c` must accumulate into every RHS
//! column's accumulator, `acc[j] += v * x[c + j*stride]` over the
//! column-major RHS block (`stride` = ncols). Written as a plain indexed
//! loop the stable compiler keeps a scalar FMA chain with a bounds check
//! per lane; rewritten over fixed-width `[f64; LANES]` tiles via
//! `chunks_exact_mut` it unrolls and autovectorizes on stable (no
//! nightly `std::simd`), with one up-front range proof covering the
//! whole lane walk and a scalar remainder path for `nrhs % LANES`.
//!
//! Bitwise contract: lane `j`'s update is exactly the scalar
//! `acc[j] += v * x[c + j*stride]` it replaces — same operation, same
//! per-column order, and lanes never mix. Every fused kernel built on
//! this primitive therefore stays bit-for-bit identical per column to
//! single-RHS dispatch, which is the invariant `block_parity` /
//! `service_parity` pin.

/// Lane width of the accumulator tiles: 4 × f64 fills one AVX2 register
/// (two NEON registers). Batch widths that are not a multiple of LANES
/// fall through `chunks_exact_mut` into the scalar remainder path.
pub const LANES: usize = 4;

/// `acc[j] += v * x[col + j * stride]` for every lane `j`, register-tiled,
/// without per-lane bounds checks.
///
/// # Safety
///
/// Caller guarantees `col + (acc.len() - 1) * stride < x.len()` when
/// `acc` is non-empty. The packed-LUT GSE kernels uphold this the same
/// way their single-RHS unchecked gathers do: column indices are
/// validated `< ncols` at construction and `x.len() == ncols * nrhs` is
/// asserted at the kernel mouth, which with `stride == ncols` and
/// `acc.len() <= nrhs` implies the bound.
#[inline(always)]
pub(crate) unsafe fn fma_lanes_unchecked(
    acc: &mut [f64],
    v: f64,
    x: &[f64],
    col: usize,
    stride: usize,
) {
    debug_assert!(acc.is_empty() || col + (acc.len() - 1) * stride < x.len());
    let mut base = col;
    let mut tiles = acc.chunks_exact_mut(LANES);
    for tile in tiles.by_ref() {
        // fixed-width view: the compiler unrolls and packs these FMAs
        let tile: &mut [f64; LANES] = tile.try_into().unwrap();
        for (l, lane) in tile.iter_mut().enumerate() {
            *lane += v * *x.get_unchecked(base + l * stride);
        }
        base += LANES * stride;
    }
    for lane in tiles.into_remainder() {
        *lane += v * *x.get_unchecked(base);
        base += stride;
    }
}

/// Checked front door used by the kernels over plainly-indexed storage:
/// one range proof for the whole lane walk, then the tiled loop.
#[inline(always)]
pub(crate) fn fma_lanes(acc: &mut [f64], v: f64, x: &[f64], col: usize, stride: usize) {
    if acc.is_empty() {
        return;
    }
    assert!(
        col + (acc.len() - 1) * stride < x.len(),
        "lane walk out of range: col {col} stride {stride} lanes {} x.len {}",
        acc.len(),
        x.len()
    );
    // SAFETY: the assert above is exactly the unchecked contract.
    unsafe { fma_lanes_unchecked(acc, v, x, col, stride) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference(acc: &mut [f64], v: f64, x: &[f64], col: usize, stride: usize) {
        for (j, aj) in acc.iter_mut().enumerate() {
            *aj += v * x[col + j * stride];
        }
    }

    #[test]
    fn matches_scalar_loop_for_every_lane_count() {
        let stride = 7usize;
        for n in 0..=(2 * LANES + 3) {
            let x: Vec<f64> = (0..stride * n.max(1)).map(|i| (i as f64) * 0.5 - 3.0).collect();
            for col in [0usize, 3, stride - 1] {
                let mut tiled = vec![1.0; n];
                let mut plain = vec![1.0; n];
                fma_lanes(&mut tiled, 1.25, &x, col, stride);
                reference(&mut plain, 1.25, &x, col, stride);
                assert_eq!(tiled, plain, "n={n} col={col}");
            }
        }
    }

    #[test]
    fn signed_zero_contributions_are_preserved() {
        // v * x can be -0.0; the tiled walk must add it like the scalar
        // loop does (skipping would flip +0.0 sums to -0.0 and back)
        let x = [-1.0, 0.0, -0.0];
        let mut tiled = vec![0.0; 3];
        let mut plain = vec![0.0; 3];
        fma_lanes(&mut tiled, 0.0, &x, 0, 1);
        reference(&mut plain, 0.0, &x, 0, 1);
        assert_eq!(
            tiled.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            plain.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    #[should_panic(expected = "lane walk out of range")]
    fn rejects_short_rhs_block() {
        let x = vec![0.0; 4];
        let mut acc = vec![0.0; 2];
        fma_lanes(&mut acc, 1.0, &x, 3, 4);
    }
}
