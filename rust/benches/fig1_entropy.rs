//! Fig. 1 — motivation study over the matrix corpus:
//! (a) information entropy of non-zero values / exponents / mantissas;
//! (b–h) ratio of non-zeros covered by the top-{1,2,4,8,16,32,64}
//! exponents.
//!
//! Paper reference points: >52% of matrices have value entropy > 4 bits;
//! 97% have exponent entropy < 4 bits; average top-k coverages are
//! 64.7 / 73.1 / 82.4 / 90.9 / 96.5 / 98.9 / 99.8 %.

#[path = "common.rs"]
mod common;

use gsem::sparse::gen::corpus::spmv_corpus;
use gsem::sparse::stats::{matrix_stats, TOPK_LEVELS};
use gsem::util::csv::write_csv;
use gsem::util::stats::mean;
use gsem::util::table::TextTable;

fn main() {
    let corpus = spmv_corpus(common::bench_corpus_size());
    eprintln!("fig1: analyzing {} matrices", corpus.len());

    let mut rows = Vec::new();
    let mut val_entropy = Vec::new();
    let mut exp_entropy = Vec::new();
    let mut mant_entropy = Vec::new();
    let mut topk: Vec<Vec<f64>> = vec![Vec::new(); 7];
    for m in &corpus {
        let s = matrix_stats(&m.a);
        val_entropy.push(s.entropy.value_bits);
        exp_entropy.push(s.entropy.exponent_bits);
        mant_entropy.push(s.entropy.mantissa_bits);
        for i in 0..7 {
            topk[i].push(s.topk[i]);
        }
        rows.push(vec![
            m.name.clone(),
            m.class.to_string(),
            s.nnz.to_string(),
            format!("{:.4}", s.entropy.value_bits),
            format!("{:.4}", s.entropy.exponent_bits),
            format!("{:.4}", s.entropy.mantissa_bits),
            format!("{:.4}", s.topk[0]),
            format!("{:.4}", s.topk[3]),
            format!("{:.4}", s.topk[6]),
        ]);
    }
    let _ = write_csv(
        "fig1_entropy",
        &["matrix", "class", "nnz", "H_value", "H_exp", "H_mant", "top1", "top8", "top64"],
        &rows,
    );

    let n = corpus.len() as f64;
    let frac = |pred: &dyn Fn(f64) -> bool, xs: &[f64]| {
        xs.iter().filter(|&&x| pred(x)).count() as f64 / n
    };
    println!("Fig. 1(a) — entropy of non-zero populations ({} matrices)", corpus.len());
    let mut t = TextTable::new(&["population", "mean bits", "share > 4 bits", "share < 4 bits"]);
    for (name, xs) in [
        ("values", &val_entropy),
        ("exponents", &exp_entropy),
        ("mantissas", &mant_entropy),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.3}", mean(xs)),
            format!("{:.1}%", 100.0 * frac(&|x| x > 4.0, xs)),
            format!("{:.1}%", 100.0 * frac(&|x| x < 4.0, xs)),
        ]);
    }
    t.print();
    println!(
        "paper: value entropy > 4 bits for >52% of matrices; exponent entropy < 4 bits for 97%"
    );

    println!("\nFig. 1(b-h) — average top-k exponent coverage");
    let paper = [64.7, 73.1, 82.4, 90.9, 96.5, 98.9, 99.8];
    let mut t = TextTable::new(&["k", "measured avg", "paper avg"]);
    for (i, &k) in TOPK_LEVELS.iter().enumerate() {
        t.row(&[
            format!("top-{k}"),
            format!("{:.1}%", 100.0 * mean(&topk[i])),
            format!("{:.1}%", paper[i]),
        ]);
    }
    t.print();
}
