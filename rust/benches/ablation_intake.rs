//! Ablation — windowed intake vs. no-window dispatch on a staggered
//! arrival trace. A serving workload rarely hands the coordinator a
//! ready-made batch: same-matrix CG requests arrive a few hundred
//! microseconds apart. With a zero window the service flushes as soon
//! as anything is pending, so requests (almost always) solve alone —
//! no multi-RHS merge, one decode pass per request; the windowed
//! [`gsem::coordinator::SolverService`] holds the batch open for a
//! short window so staggered arrivals still merge into
//! `cg_solve_multi` block solves. Both modes replay the **same**
//! submission trace (identical stagger, non-blocking submits), so the
//! comparison isolates the window policy.

#[path = "common.rs"]
mod common;

use gsem::coordinator::{
    FormatChoice, RhsSpec, ServiceConfig, SolveSpec, SolverKind, SolverService,
};
use gsem::formats::ValueFormat;
use gsem::sparse::gen::corpus::cg_set;
use gsem::sparse::Csr;
use gsem::util::csv::write_csv;
use gsem::util::table::TextTable;
use gsem::util::Timer;
use std::sync::Arc;
use std::time::Duration;

struct TraceStats {
    wall_s: f64,
    flushes: u64,
    merged: u64,
    batched_rhs: u64,
}

/// Replay the staggered trace through a windowed service and collect
/// the intake counters. `window == 0` + `width == 1` is the no-window
/// baseline: every wakeup of the flusher drains immediately.
fn run_trace(
    name: &str,
    mats: &[(String, Arc<Csr>)],
    requests: usize,
    stagger: Duration,
    window: Duration,
    width: usize,
) -> TraceStats {
    let svc = SolverService::new(
        ServiceConfig::new().workers(4).window(window).batch_width(width),
    );
    // register each trace matrix once; the submit loop reuses handles
    let handles: Vec<_> =
        mats.iter().map(|(name, a)| (name.clone(), svc.register(a))).collect();
    let timer = Timer::start();
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            let (mname, handle) = &handles[i % handles.len()];
            let mut spec = SolveSpec::new(
                &format!("{mname}#{i}"),
                handle.clone(),
                SolverKind::Cg,
                FormatChoice::fixed(ValueFormat::Fp64),
            );
            spec.rhs = RhsSpec::Random(i as u64);
            let ticket = svc.submit(spec).expect("unbounded intake admits the whole trace");
            std::thread::sleep(stagger);
            ticket
        })
        .collect();
    let solved = tickets
        .into_iter()
        .map(|t| t.wait().expect("trace solves cleanly"))
        .filter(|r| r.outcome.converged)
        .count();
    let wall_s = timer.elapsed_s();
    assert_eq!(solved, requests, "{name}: every request must converge");
    let m = svc.metrics();
    TraceStats {
        wall_s,
        flushes: m.counter("intake.flushes"),
        merged: m.counter("intake.merged"),
        batched_rhs: m.counter("pool.batched_rhs"),
    }
}

fn main() {
    let mut set = cg_set(common::bench_corpus_size());
    set.sort_by_key(|m| m.a.nnz());
    // two small matrices: merges happen per matrix, arrivals alternate
    let mats: Vec<(String, Arc<Csr>)> =
        set.into_iter().take(2).map(|m| (m.name, Arc::new(m.a))).collect();
    let requests = if common::fast() { 16 } else { 48 };
    let stagger = Duration::from_micros(if common::fast() { 120 } else { 400 });
    let window = Duration::from_millis(if common::fast() { 3 } else { 8 });
    eprintln!(
        "ablation_intake: {} requests over {} matrices, stagger {:?}, window {:?}",
        requests,
        mats.len(),
        stagger,
        window
    );

    let no_window = run_trace("no-window", &mats, requests, stagger, Duration::ZERO, 1);
    let windowed = run_trace("windowed", &mats, requests, stagger, window, 16);

    let header = ["mode", "wall(s)", "ms/req", "flushes", "merged", "batched_rhs"];
    let mut t = TextTable::new(&header);
    let mut rows = Vec::new();
    for (mode, s) in [("no-window", &no_window), ("windowed", &windowed)] {
        t.row(&[
            mode.to_string(),
            format!("{:.3}", s.wall_s),
            format!("{:.3}", s.wall_s * 1e3 / requests as f64),
            s.flushes.to_string(),
            s.merged.to_string(),
            s.batched_rhs.to_string(),
        ]);
        rows.push(vec![
            mode.to_string(),
            requests.to_string(),
            format!("{:.5}", s.wall_s),
            s.flushes.to_string(),
            s.merged.to_string(),
            s.batched_rhs.to_string(),
        ]);
    }
    println!("Ablation — windowed intake vs. no-window dispatch, staggered arrivals");
    t.print();
    let _ = write_csv(
        "ablation_intake",
        &["mode", "requests", "wall_s", "flushes", "merged", "batched_rhs"],
        &rows,
    );
    println!(
        "\nwindowed intake merged {}/{} requests across {} flushes \
         (no-window merged {} across {} flushes); wall {:.3}s vs {:.3}s",
        windowed.merged,
        requests,
        windowed.flushes,
        no_window.merged,
        no_window.flushes,
        windowed.wall_s,
        no_window.wall_s
    );
    // the window must create merges the no-window policy only gets by
    // accident (solver backlog); both replay the identical trace
    assert!(
        windowed.merged > 0,
        "a {window:?} window over {stagger:?} staggering must merge some requests"
    );
    assert!(
        windowed.flushes <= no_window.flushes,
        "windowing must not fragment flushes ({} vs {})",
        windowed.flushes,
        no_window.flushes
    );
}
