//! Ablation — intra-group parallelism (the PR-8 core allocator's
//! premise, measured): given the same core budget, is one **merged**
//! nrhs-wide CG block over a thread-retuned registry operator faster
//! than the pre-allocator baseline of **scattered** single solves, one
//! worker-queue slot each with serial operators? The merged block
//! streams the matrix once per iteration across all right-hand sides
//! (the §III-C traffic argument) *and* concentrates the whole budget
//! on that one stream via [`SpmvOp::set_threads`]; the scattered
//! baseline re-reads the matrix per solve but overlaps solves across
//! the budget's worker slots. Column-for-column the arithmetic is
//! bitwise identical either way (pinned by `tests/group_threads.rs`),
//! so the wall-time ratio is pure scheduling.
//!
//! Reported per (matrix, format, nrhs, budget): both wall times, the
//! speedup, and the merged run's achieved GB/s from the
//! `spmv::traffic` byte model against this machine's measured
//! STREAM-triad roofline. The largest (smoke) matrix doubles as the
//! CI regression guard: at a 4-core budget and nrhs >= 4, merged must
//! beat scattered (geomean), or the allocator's policy of granting a
//! dominant merged group the full budget has stopped paying.

#[path = "common.rs"]
mod common;

use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::{cg_solve, cg_solve_multi, CgOpts, MonitorCmd};
use gsem::sparse::gen::corpus::{spmv_corpus, NamedMatrix};
use gsem::spmv::traffic::V100;
use gsem::spmv::SpmvOp;
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;
use gsem::util::{parallel, Prng, Timer};
use std::sync::Arc;

/// Wall time of `body`, best of `reps` runs (solves are too long for
/// the adaptive per-cell budget; the min discards scheduler noise).
fn best_of(reps: usize, mut body: impl FnMut()) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..reps {
        let t = Timer::start();
        std::hint::black_box(&mut body)();
        best = best.min(t.elapsed_s());
    }
    best
}

fn main() {
    let mut corpus = spmv_corpus(common::bench_corpus_size());
    corpus.sort_by_key(|m| m.a.nnz());
    let picks: Vec<&NamedMatrix> = corpus.iter().rev().take(3).collect();
    let bw = common::stream_triad_bw();
    eprintln!(
        "ablation_group_par: {} matrices, STREAM triad roofline {:.2} GB/s",
        picks.len(),
        bw / 1e9
    );
    let reps = if common::fast() { 2 } else { 3 };
    let opts = CgOpts {
        tol: 1e-6,
        max_iters: if common::fast() { 150 } else { 500 },
        inv_diag: None,
    };
    let budgets = [1usize, 2, 4];
    let widths = [4usize, 8];
    let formats = [ValueFormat::Fp64, ValueFormat::GseSem(Precision::Head)];

    let header =
        ["matrix", "format", "nrhs", "budget", "scattered", "merged", "speedup", "GB/s", "roof%"];
    let mut t = TextTable::new(&header);
    let mut rows = Vec::new();
    // merged-vs-scattered speedups on the smoke matrix, budget 4
    let mut guard: Vec<f64> = Vec::new();
    let reg = gsem::coordinator::MatrixRegistry::new();
    for (mi, m) in picks.iter().enumerate() {
        let a = Arc::new(m.a.clone());
        let h = reg.register(&a);
        for &format in &formats {
            let op = reg.operator(&h, format, 8, None);
            for &nrhs in &widths {
                let n = a.nrows;
                let mut rng = Prng::new(41);
                let bs: Vec<f64> =
                    (0..n * nrhs).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                for &budget in &budgets {
                    // scattered baseline: nrhs singleton "groups" on a
                    // budget-wide worker queue, serial operators — the
                    // flusher's behavior before the core allocator
                    op.set_threads(1);
                    let t_scat = best_of(reps, || {
                        let jobs: Vec<usize> = (0..nrhs).collect();
                        parallel::run_queue(budget, jobs, |j| {
                            cg_solve(op.as_ref(), &bs[j * n..(j + 1) * n], &opts, |_, _| {
                                MonitorCmd::Continue
                            })
                        });
                    });
                    // merged block: one fused multi-RHS solve holding
                    // the entire budget (what the allocator grants a
                    // lone dominant group)
                    op.set_threads(budget);
                    let mut iters_max = 0usize;
                    let t_merge = best_of(reps, || {
                        let outs = cg_solve_multi(op.as_ref(), &bs, nrhs, &opts);
                        iters_max = outs.iter().map(|o| o.iters).max().unwrap_or(0);
                    });
                    // achieved bandwidth of the merged run: one fused
                    // matrix stream per iteration (deflation and vector
                    // traffic ignored, so this under-counts)
                    let bytes = iters_max as f64
                        * V100.spmv_multi_bytes(a.nnz(), a.nrows, op.format(), nrhs);
                    let gbs = bytes / t_merge / 1e9;
                    let roof = gbs * 1e9 / bw * 100.0;
                    let speedup = t_scat / t_merge;
                    if mi == 0 && budget == 4 {
                        guard.push(speedup);
                    }
                    t.row(&[
                        m.name.clone(),
                        format.label().to_string(),
                        nrhs.to_string(),
                        budget.to_string(),
                        format!("{:.3}ms", t_scat * 1e3),
                        format!("{:.3}ms", t_merge * 1e3),
                        format!("{speedup:.2}x"),
                        format!("{gbs:.2}"),
                        format!("{roof:.1}"),
                    ]);
                    rows.push(vec![
                        m.name.clone(),
                        format.label().to_string(),
                        nrhs.to_string(),
                        budget.to_string(),
                        format!("{t_scat:.4e}"),
                        format!("{t_merge:.4e}"),
                        format!("{speedup:.4}"),
                        format!("{gbs:.4e}"),
                        format!("{roof:.2}"),
                    ]);
                }
            }
        }
    }
    println!("Ablation — merged multi-RHS block vs scattered single solves at equal core budget");
    println!("(GB/s = modeled merged-stream bytes / measured time; roof% vs STREAM triad)");
    t.print();
    let _ = write_csv(
        "ablation_group_par",
        &[
            "matrix",
            "format",
            "nrhs",
            "budget",
            "t_scattered",
            "t_merged",
            "speedup",
            "merged_gbs",
            "roof_pct",
        ],
        &rows,
    );

    // Regression guard: on the smoke matrix with the acceptance
    // budget of 4 cores, the merged block must beat the scattered
    // baseline at nrhs >= 4 — geomean across formats and widths, so a
    // single noisy cell cannot flip the verdict.
    let g = geomean(&guard);
    println!(
        "\nmerged-vs-scattered geomean on {} at budget=4, nrhs>=4: {:.2}x ({} cells)",
        picks[0].name,
        g,
        guard.len()
    );
    assert!(
        g >= 1.0,
        "merged block solves regressed below scattered singles: {g:.3}x on {}",
        picks[0].name
    );
}
