//! Ablation — GSE-SEM vs the mantissa-segmentation baseline [17]
//! (paper §V-A): same-traffic comparisons of error and CPU time.
//!
//! * split head (4 B/value, 20 mantissa bits, full exponent) vs
//!   GSE head+tail1 (4 B/value, 31 mantissa bits, shared exponents);
//! * split head vs GSE head (2 B/value) — half the traffic;
//! * solver impact: CG iterations to 1e-6 with each reduced operator.

#[path = "common.rs"]
mod common;

use gsem::formats::msplit::SplitLevel;
use gsem::formats::Precision;
use gsem::sparse::gen::corpus::spmv_corpus;
use gsem::spmv::msplit::SplitCsr;
use gsem::spmv::{fp64, max_abs_diff, GseCsr};
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;

fn main() {
    let corpus = spmv_corpus(common::bench_corpus_size());
    let picks: Vec<_> = corpus
        .iter()
        .filter(|m| m.a.nnz() > 500)
        .take(if common::fast() { 8 } else { 24 })
        .collect();
    eprintln!("ablation_msplit: {} matrices", picks.len());
    let budget = common::cell_budget();

    let mut t = TextTable::new(&[
        "matrix",
        "err split-head(4B)",
        "err GSE h+t1(4B)",
        "err GSE head(2B)",
        "t split-head",
        "t GSE h+t1",
        "t GSE head",
    ]);
    let mut rows = Vec::new();
    let mut speed_ratio = Vec::new();
    let mut err_wins = 0usize;
    for m in &picks {
        let a = &m.a;
        let x = vec![1.0; a.ncols];
        let mut y64 = vec![0.0; a.nrows];
        fp64::spmv(a, &x, &mut y64);
        let sp = SplitCsr::from_csr(a);
        let g = GseCsr::from_csr(a, 8);

        let mut ys = vec![0.0; a.nrows];
        sp.spmv(&x, &mut ys, SplitLevel::Head);
        let mut yt = vec![0.0; a.nrows];
        g.spmv(&x, &mut yt, Precision::HeadTail1);
        let mut yh = vec![0.0; a.nrows];
        g.spmv(&x, &mut yh, Precision::Head);
        let (es, et, eh) =
            (max_abs_diff(&y64, &ys), max_abs_diff(&y64, &yt), max_abs_diff(&y64, &yh));
        if et <= es {
            err_wins += 1;
        }

        let ts = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            sp.spmv(&x, &mut y, SplitLevel::Head);
            y
        });
        let tt = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            g.spmv(&x, &mut y, Precision::HeadTail1);
            y
        });
        let th = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            g.spmv(&x, &mut y, Precision::Head);
            y
        });
        speed_ratio.push(ts / th);
        t.row(&[
            m.name.clone(),
            format!("{es:.2e}"),
            format!("{et:.2e}"),
            format!("{eh:.2e}"),
            format!("{:.1}us", ts * 1e6),
            format!("{:.1}us", tt * 1e6),
            format!("{:.1}us", th * 1e6),
        ]);
        rows.push(vec![
            m.name.clone(),
            format!("{es:.4e}"),
            format!("{et:.4e}"),
            format!("{eh:.4e}"),
            format!("{ts:.4e}"),
            format!("{tt:.4e}"),
            format!("{th:.4e}"),
        ]);
    }
    println!("Ablation — GSE-SEM vs mantissa segmentation [17]");
    t.print();
    let _ = write_csv(
        "ablation_msplit",
        &["matrix", "err_split", "err_gse_t1", "err_gse_head", "t_split", "t_gse_t1", "t_gse_head"],
        &rows,
    );
    println!(
        "\nsame-traffic (4 B/value) error: GSE h+t1 <= split-head on {err_wins}/{} matrices \
         (shared exponents buy 31 vs 20 mantissa bits when exponents cluster);\n\
         half-traffic GSE head runs {:.2}x the speed of split-head on CPU.",
        picks.len(),
        geomean(&speed_ratio)
    );
}
