//! Shared helpers for the paper-figure bench binaries (harness = false).
#![allow(dead_code)]

use gsem::coordinator::{FormatChoice, RhsSpec, SolveRequest, SolverKind};
use gsem::formats::ValueFormat;
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::csr::Csr;
use gsem::sparse::gen::corpus::CorpusSize;
use gsem::util::Timer;
use std::sync::Arc;

/// Corpus scale for benches: GSEM_CORPUS, with GSEM_BENCH_FAST forcing
/// Small.
pub fn bench_corpus_size() -> CorpusSize {
    if std::env::var("GSEM_BENCH_FAST").is_ok() {
        CorpusSize::Small
    } else {
        CorpusSize::from_env()
    }
}

/// Are we in the abbreviated CI mode?
pub fn fast() -> bool {
    std::env::var("GSEM_BENCH_FAST").is_ok()
}

/// Time `body` adaptively: enough iterations to fill ~`budget_s`,
/// reporting seconds per call. Cheap replacement for the full harness
/// when a figure needs hundreds of (matrix, format) cells.
pub fn quick_time<T>(budget_s: f64, mut body: impl FnMut() -> T) -> f64 {
    // calibrate with one call
    let t0 = Timer::start();
    std::hint::black_box(body());
    let one = t0.elapsed_s().max(1e-9);
    let iters = ((budget_s / one).ceil() as usize).clamp(1, 1_000_000);
    let t = Timer::start();
    for _ in 0..iters {
        std::hint::black_box(body());
    }
    t.elapsed_s() / iters as f64
}

/// Per-cell measurement budget.
pub fn cell_budget() -> f64 {
    if fast() {
        0.004
    } else {
        0.05
    }
}

/// Measured STREAM-triad bandwidth of this machine in bytes/s:
/// `a[i] = b[i] + s·c[i]` over arrays well past the LLC, traffic
/// counted as three 8-byte streams per element (write-allocate traffic
/// on `a` is not separately charged, STREAM's own convention). This is
/// the machine roofline the achieved-GB/s bench columns report
/// against. Memoized — the arrays are allocated and swept once per
/// process; `GSEM_BENCH_FAST` shrinks them so CI stays cheap (the
/// fast-mode number reads as cache bandwidth, which only makes the
/// roofline fraction conservative).
pub fn stream_triad_bw() -> f64 {
    use std::sync::OnceLock;
    static BW: OnceLock<f64> = OnceLock::new();
    *BW.get_or_init(|| {
        let bytes_per_array: usize = if fast() { 4 << 20 } else { 64 << 20 };
        let n = bytes_per_array / 8;
        let s = 3.0f64;
        let b: Vec<f64> = (0..n).map(|i| (i % 17) as f64).collect();
        let c: Vec<f64> = (0..n).map(|i| (i % 13) as f64 * 0.5).collect();
        let mut a = vec![0.0f64; n];
        let mut best = f64::MAX;
        // pass 0 faults the pages in and is discarded
        for pass in 0..(if fast() { 4 } else { 6 }) {
            let t = Timer::start();
            for ((ai, bi), ci) in a.iter_mut().zip(&b).zip(&c) {
                *ai = bi + s * ci;
            }
            std::hint::black_box(&mut a);
            let dt = t.elapsed_s().max(1e-9);
            if pass > 0 {
                best = best.min(dt);
            }
        }
        (3 * 8 * n) as f64 / best
    })
}

/// The format set of the solver comparisons (Tables III/IV, Figs. 8/9).
pub fn solver_formats(solver: SolverKind) -> Vec<(&'static str, FormatChoice)> {
    let stepped = match solver {
        SolverKind::Gmres => SteppedParams::gmres_paper(),
        _ => SteppedParams::cg_paper(),
    }
    .scaled(if fast() { 0.005 } else { 0.02 });
    vec![
        ("FP64", FormatChoice::fixed(ValueFormat::Fp64)),
        ("FP16", FormatChoice::fixed(ValueFormat::Fp16)),
        ("BF16", FormatChoice::fixed(ValueFormat::Bf16)),
        ("GSE-SEM", FormatChoice::Stepped { k: 8, params: stepped }),
    ]
}

/// Run one (matrix, solver, format) cell with the paper's caps.
pub fn run_solver_cell(
    name: &str,
    a: &Arc<Csr>,
    solver: SolverKind,
    fmt: FormatChoice,
) -> gsem::coordinator::jobs::SolveResult {
    let mut req = SolveRequest::new(name, Arc::clone(a), solver, fmt);
    req.rhs = RhsSpec::AxOnes;
    req.tol = 1e-6;
    req.max_iters = match solver {
        SolverKind::Cg | SolverKind::Bicgstab => {
            if fast() {
                1000
            } else {
                5000
            }
        }
        SolverKind::Gmres => {
            if fast() {
                3000
            } else {
                15000
            }
        }
    };
    // bench cells chart breakdowns as data points (the paper's "/"
    // rows), so redeem them from the typed error surface
    match gsem::coordinator::jobs::dispatch(&req) {
        Ok(r) => r,
        Err(gsem::coordinator::ServiceError::Breakdown(b)) => *b,
        Err(e) => panic!("{name}: unexpected dispatch error: {e}"),
    }
}

/// Geometric-mean speedup helper skipping non-positive entries.
pub fn avg_speedup(speedups: &[f64]) -> f64 {
    gsem::util::stats::geomean(speedups)
}

/// Run the paper's full (test set × format) grid for one solver.
/// Returns per-matrix results in format order of [`solver_formats`].
pub fn run_suite(
    solver: SolverKind,
    set: &[gsem::sparse::gen::corpus::NamedMatrix],
) -> Vec<(String, Vec<gsem::coordinator::jobs::SolveResult>)> {
    let mut out = Vec::new();
    for m in set {
        let a = Arc::new(m.a.clone());
        let mut results = Vec::new();
        for (_, fmt) in solver_formats(solver) {
            results.push(run_solver_cell(&m.name, &a, solver, fmt));
        }
        eprintln!(
            "  {}: {}",
            m.name,
            results
                .iter()
                .map(|r| format!("{}={}it", r.format_label, r.outcome.iters))
                .collect::<Vec<_>>()
                .join(" ")
        );
        out.push((m.name.clone(), results));
    }
    out
}
