//! Shared helpers for the paper-figure bench binaries (harness = false).
#![allow(dead_code)]

use gsem::coordinator::{FormatChoice, RhsSpec, SolveRequest, SolverKind};
use gsem::formats::ValueFormat;
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::csr::Csr;
use gsem::sparse::gen::corpus::CorpusSize;
use gsem::util::Timer;
use std::sync::Arc;

/// Corpus scale for benches: GSEM_CORPUS, with GSEM_BENCH_FAST forcing
/// Small.
pub fn bench_corpus_size() -> CorpusSize {
    if std::env::var("GSEM_BENCH_FAST").is_ok() {
        CorpusSize::Small
    } else {
        CorpusSize::from_env()
    }
}

/// Are we in the abbreviated CI mode?
pub fn fast() -> bool {
    std::env::var("GSEM_BENCH_FAST").is_ok()
}

/// Time `body` adaptively: enough iterations to fill ~`budget_s`,
/// reporting seconds per call. Cheap replacement for the full harness
/// when a figure needs hundreds of (matrix, format) cells.
pub fn quick_time<T>(budget_s: f64, mut body: impl FnMut() -> T) -> f64 {
    // calibrate with one call
    let t0 = Timer::start();
    std::hint::black_box(body());
    let one = t0.elapsed_s().max(1e-9);
    let iters = ((budget_s / one).ceil() as usize).clamp(1, 1_000_000);
    let t = Timer::start();
    for _ in 0..iters {
        std::hint::black_box(body());
    }
    t.elapsed_s() / iters as f64
}

/// Per-cell measurement budget.
pub fn cell_budget() -> f64 {
    if fast() {
        0.004
    } else {
        0.05
    }
}

/// The format set of the solver comparisons (Tables III/IV, Figs. 8/9).
pub fn solver_formats(solver: SolverKind) -> Vec<(&'static str, FormatChoice)> {
    let stepped = match solver {
        SolverKind::Gmres => SteppedParams::gmres_paper(),
        _ => SteppedParams::cg_paper(),
    }
    .scaled(if fast() { 0.005 } else { 0.02 });
    vec![
        ("FP64", FormatChoice::fixed(ValueFormat::Fp64)),
        ("FP16", FormatChoice::fixed(ValueFormat::Fp16)),
        ("BF16", FormatChoice::fixed(ValueFormat::Bf16)),
        ("GSE-SEM", FormatChoice::Stepped { k: 8, params: stepped }),
    ]
}

/// Run one (matrix, solver, format) cell with the paper's caps.
pub fn run_solver_cell(
    name: &str,
    a: &Arc<Csr>,
    solver: SolverKind,
    fmt: FormatChoice,
) -> gsem::coordinator::jobs::SolveResult {
    let mut req = SolveRequest::new(name, Arc::clone(a), solver, fmt);
    req.rhs = RhsSpec::AxOnes;
    req.tol = 1e-6;
    req.max_iters = match solver {
        SolverKind::Cg | SolverKind::Bicgstab => {
            if fast() {
                1000
            } else {
                5000
            }
        }
        SolverKind::Gmres => {
            if fast() {
                3000
            } else {
                15000
            }
        }
    };
    // bench cells chart breakdowns as data points (the paper's "/"
    // rows), so redeem them from the typed error surface
    match gsem::coordinator::jobs::dispatch(&req) {
        Ok(r) => r,
        Err(gsem::coordinator::ServiceError::Breakdown(b)) => *b,
        Err(e) => panic!("{name}: unexpected dispatch error: {e}"),
    }
}

/// Geometric-mean speedup helper skipping non-positive entries.
pub fn avg_speedup(speedups: &[f64]) -> f64 {
    gsem::util::stats::geomean(speedups)
}

/// Run the paper's full (test set × format) grid for one solver.
/// Returns per-matrix results in format order of [`solver_formats`].
pub fn run_suite(
    solver: SolverKind,
    set: &[gsem::sparse::gen::corpus::NamedMatrix],
) -> Vec<(String, Vec<gsem::coordinator::jobs::SolveResult>)> {
    let mut out = Vec::new();
    for m in set {
        let a = Arc::new(m.a.clone());
        let mut results = Vec::new();
        for (_, fmt) in solver_formats(solver) {
            results.push(run_solver_cell(&m.name, &a, solver, fmt));
        }
        eprintln!(
            "  {}: {}",
            m.name,
            results
                .iter()
                .map(|r| format!("{}={}it", r.format_label, r.outcome.iters))
                .collect::<Vec<_>>()
                .join(" ")
        );
        out.push((m.name.clone(), results));
    }
    out
}
