//! Fig. 4 + Fig. 5 — the shared-exponent-count sweep (k ∈ {2..64}):
//! (4a) per-matrix speedup of GSE-SEM(head) SpMV over FP64 SpMV,
//! (4b) per-matrix max absolute error vs the FP64 result (x = 1),
//! (5)  average speedups per k.
//!
//! Reports both measured CPU speedups and the modeled-V100 speedups
//! (DESIGN.md §5: the GPU numbers are traffic ratios; the CPU validates
//! ordering and decode overhead). Paper: the average speedup peaks at
//! k=8; error decreases monotonically with k.

#[path = "common.rs"]
mod common;

use gsem::formats::gse::ExpHistogram;
use gsem::formats::Precision;
use gsem::sparse::gen::corpus::spmv_corpus;
use gsem::spmv::traffic::{gse_head_time_at_k, V100};
use gsem::spmv::{fp64, max_abs_diff, GseCsr};
use gsem::util::csv::write_csv;
use gsem::util::stats::{geomean, mean};
use gsem::util::table::TextTable;

const KS: [usize; 6] = [2, 4, 8, 16, 32, 64];

fn main() {
    let corpus = spmv_corpus(common::bench_corpus_size());
    eprintln!("fig4/5: {} matrices x {} k values", corpus.len(), KS.len());
    let budget = common::cell_budget();

    let mut rows = Vec::new();
    // speedups[ki] / errors[ki] across matrices
    let mut cpu_speedups: Vec<Vec<f64>> = vec![Vec::new(); KS.len()];
    let mut v100_speedups: Vec<Vec<f64>> = vec![Vec::new(); KS.len()];
    let mut errors: Vec<Vec<f64>> = vec![Vec::new(); KS.len()];

    for m in &corpus {
        let a = &m.a;
        let x = vec![1.0; a.ncols]; // paper: multiplication vector = 1
        let mut y64 = vec![0.0; a.nrows];
        fp64::spmv(a, &x, &mut y64);
        let t64 = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            fp64::spmv(a, &x, &mut y);
            y
        });
        let mut hist = ExpHistogram::new();
        hist.push_all(&a.vals);

        for (ki, &k) in KS.iter().enumerate() {
            let g = GseCsr::from_csr(a, k);
            let mut y = vec![0.0; a.nrows];
            g.spmv(&x, &mut y, Precision::Head);
            let err = max_abs_diff(&y64, &y);
            let tg = common::quick_time(budget, || {
                let mut y = vec![0.0; a.nrows];
                g.spmv(&x, &mut y, Precision::Head);
                y
            });
            let hit = g.table.exact_hit_ratio(&hist);
            let t64_model = V100.spmv_time(a.nnz(), a.nrows, gsem::formats::ValueFormat::Fp64);
            let tg_model = gse_head_time_at_k(&V100, a, k, hit);
            cpu_speedups[ki].push(t64 / tg);
            v100_speedups[ki].push(t64_model / tg_model);
            errors[ki].push(err);
            rows.push(vec![
                m.name.clone(),
                k.to_string(),
                format!("{:.4}", t64 / tg),
                format!("{:.4}", t64_model / tg_model),
                format!("{err:.6e}"),
                format!("{hit:.4}"),
            ]);
        }
    }
    let _ = write_csv(
        "fig4_k_sweep",
        &["matrix", "k", "cpu_speedup", "v100_model_speedup", "maxAbsErr", "exact_hit"],
        &rows,
    );

    println!("Fig. 5 — average GSE-SEM(head) SpMV speedup vs FP64 per k");
    let mut t = TextTable::new(&[
        "k",
        "cpu geomean speedup",
        "V100-model geomean",
        "mean maxAbsErr",
        "median maxAbsErr",
    ]);
    for (ki, &k) in KS.iter().enumerate() {
        t.row(&[
            k.to_string(),
            format!("{:.3}x", geomean(&cpu_speedups[ki])),
            format!("{:.3}x", geomean(&v100_speedups[ki])),
            format!("{:.3e}", mean(&errors[ki])),
            format!("{:.3e}", gsem::util::stats::median(&errors[ki])),
        ]);
    }
    t.print();

    // the two headline shapes of the figure:
    let v100_avgs: Vec<f64> = (0..KS.len()).map(|ki| geomean(&v100_speedups[ki])).collect();
    let best = v100_avgs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!(
        "\nshape checks: modeled speedup peaks at k={} (paper: k=8); \
         error decreases with k: {}",
        KS[best],
        (0..KS.len() - 1).all(|i| mean(&errors[i]) >= mean(&errors[i + 1]) * 0.99)
    );
}
