//! Fig. 7 — traces of the three switching metrics (RSD, nDec, relDec)
//! during FP64 CG and GMRES runs, on analogs of the paper's four example
//! systems (CG: consph, cvxbqp1; GMRES: dw2048, adder_dcop_01).
//!
//! The traces calibrate the thresholds of §IV-D1; the bench prints the
//! per-window metric series and writes them to results/ as CSV.

#[path = "common.rs"]
mod common;

use gsem::coordinator::SolverKind;
use gsem::solvers::stepped::window_metrics;
use gsem::solvers::{cg_solve, gmres_solve, CgOpts, GmresOpts};
use gsem::sparse::gen::corpus::{cg_set, gmres_set};
use gsem::spmv::fp64::Fp64Csr;
use gsem::util::csv::CsvWriter;
use gsem::util::table::TextTable;

fn trace(name: &str, solver: SolverKind, a: &gsem::sparse::Csr, t_window: usize, m_step: usize) {
    let op = Fp64Csr::new(a.clone());
    let ones = vec![1.0; a.ncols];
    let mut b = vec![0.0; a.nrows];
    gsem::spmv::fp64::spmv(a, &ones, &mut b);
    let out = match solver {
        SolverKind::Cg => cg_solve(
            &op,
            &b,
            &CgOpts {
                tol: 1e-10,
                max_iters: if common::fast() { 400 } else { 3000 },
                inv_diag: None,
            },
            |_, _| gsem::solvers::MonitorCmd::Continue,
        ),
        _ => gmres_solve(
            &op,
            &b,
            &GmresOpts {
                tol: 1e-10,
                restart: 30,
                max_outer: if common::fast() { 20 } else { 200 },
            },
            |_, _| gsem::solvers::MonitorCmd::Continue,
        ),
    };
    let hist = &out.history;
    println!(
        "\n{name} ({:?}): {} iterations recorded, final rel {:.2e}",
        solver,
        hist.len(),
        hist.last().copied().unwrap_or(f64::NAN)
    );
    let mut table = TextTable::new(&["iter", "RSD", "nDec", "relDec"]);
    let mut csv = CsvWriter::create(
        &format!("fig7_{}", name.replace(|c: char| !c.is_alphanumeric(), "_")),
        &["iter", "rsd", "ndec", "reldec"],
    )
    .unwrap();
    let mut j = t_window;
    while j <= hist.len() {
        let w = &hist[j - t_window..j];
        let m = window_metrics(w);
        table.row(&[
            j.to_string(),
            format!("{:.4}", m.rsd),
            m.ndec.to_string(),
            format!("{:.4}", m.reldec),
        ]);
        csv.row(&[
            j.to_string(),
            format!("{:.6}", m.rsd),
            m.ndec.to_string(),
            format!("{:.6}", m.reldec),
        ]);
        j += m_step;
    }
    let _ = csv.finish();
    table.print();
}

fn main() {
    let size = common::bench_corpus_size();
    let cg = cg_set(size);
    let gm = gmres_set(size);
    let (t_cg, m_cg) = if common::fast() { (25, 50) } else { (50, 100) };
    let (t_gm, m_gm) = if common::fast() { (30, 60) } else { (60, 150) };

    // paper: CG on consph (cg06 analog) and cvxbqp1 (cg05 analog)
    trace(&cg[5].name.clone(), SolverKind::Cg, &cg[5].a, t_cg, m_cg);
    trace(&cg[4].name.clone(), SolverKind::Cg, &cg[4].a, t_cg, m_cg);
    // paper: GMRES on dw2048 (gm03 analog) and adder_dcop_01 (gm04 analog)
    trace(&gm[2].name.clone(), SolverKind::Gmres, &gm[2].a, t_gm, m_gm);
    trace(&gm[3].name.clone(), SolverKind::Gmres, &gm[3].a, t_gm, m_gm);

    println!(
        "\nshape checks (paper §IV-D1): CG — RSD starts high and decays, nDec declines with \
         fluctuations; GMRES — nDec pinned at the window size while steadily converging."
    );
}
