//! Ablation — block vs. looped multi-RHS solves for the asymmetric
//! suites. `cg_solve_multi` (PR 2) showed the win for SPD systems;
//! this bench measures the same lever for GMRES, BiCGSTAB and the
//! stepped mixed-precision mode on one suite matrix: `nrhs`
//! right-hand sides solved as one lockstep block (every round trip is
//! a single fused `apply_multi` across the live columns — for stepped,
//! one per precision rung still in play) against the looped baseline
//! (`nrhs` independent single-RHS solves). Per-column results are
//! asserted bitwise identical, so the comparison isolates the batching.

#[path = "common.rs"]
mod common;

use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::bicgstab::{bicgstab_solve, bicgstab_solve_multi, BicgstabOpts};
use gsem::solvers::gmres::{gmres_solve, gmres_solve_multi, GmresOpts};
use gsem::solvers::stepped::{run_stepped_multi, run_stepped_with, BlockSolver, SteppedParams};
use gsem::solvers::{MonitorCmd, SolveOutcome, SwitchableOp};
use gsem::sparse::gen::corpus::gmres_set;
use gsem::spmv::fp64::Fp64Csr;
use gsem::spmv::traffic::V100;
use gsem::spmv::GseCsr;
use gsem::util::csv::write_csv;
use gsem::util::table::TextTable;
use gsem::util::Prng;
use gsem::util::Timer;
use std::sync::Arc;

struct Cell {
    solver: &'static str,
    looped_s: f64,
    block_s: f64,
    iters: usize,
    /// fused block rounds ~= the max per-column iteration count (the
    /// block runs one `apply_multi` per round across live columns)
    rounds: usize,
    /// storage format of the operator the block streams, for the
    /// modeled-traffic estimate (stepped uses the full-level GSE bound)
    fmt: ValueFormat,
}

fn check_parity(looped: &[SolveOutcome], block: &[SolveOutcome], solver: &str) {
    for (j, (l, b)) in looped.iter().zip(block).enumerate() {
        assert_eq!(l.iters, b.iters, "{solver} col {j}: iteration drift");
        assert_eq!(l.x, b.x, "{solver} col {j}: blockwise result drift");
    }
}

fn main() {
    let mut set = gmres_set(common::bench_corpus_size());
    set.sort_by_key(|m| m.a.nnz());
    let m = set.into_iter().next().expect("gmres set is non-empty");
    let a = m.a;
    let nrhs = if common::fast() { 4 } else { 8 };
    let n = a.nrows;
    let mut rng = Prng::new(17);
    let mut bs = vec![0.0; n * nrhs];
    for v in bs.iter_mut() {
        *v = rng.range_f64(-1.0, 1.0);
    }
    eprintln!(
        "ablation_block_asym: {} ({}x{}, nnz {}), nrhs {}",
        m.name,
        n,
        a.ncols,
        a.nnz(),
        nrhs
    );

    let op = Fp64Csr::new(a.clone());
    let gse = Arc::new(GseCsr::from_csr(&a, 8));
    let gmres_opts =
        GmresOpts { tol: 1e-6, restart: 30, max_outer: if common::fast() { 40 } else { 200 } };
    let bicg_opts = BicgstabOpts { tol: 1e-6, max_iters: if common::fast() { 600 } else { 3000 } };
    let params = SteppedParams::gmres_paper().scaled(if common::fast() { 0.005 } else { 0.02 });
    let mut cells: Vec<Cell> = Vec::new();

    // GMRES: looped singles vs one block
    let t = Timer::start();
    let looped: Vec<SolveOutcome> = (0..nrhs)
        .map(|j| {
            gmres_solve(&op, &bs[j * n..(j + 1) * n], &gmres_opts, |_, _| MonitorCmd::Continue)
        })
        .collect();
    let looped_s = t.elapsed_s();
    let t = Timer::start();
    let block = gmres_solve_multi(&op, &bs, nrhs, &gmres_opts);
    let block_s = t.elapsed_s();
    check_parity(&looped, &block, "gmres");
    cells.push(Cell {
        solver: "gmres",
        looped_s,
        block_s,
        iters: block.iter().map(|o| o.iters).sum(),
        rounds: block.iter().map(|o| o.iters).max().unwrap_or(0),
        fmt: ValueFormat::Fp64,
    });

    // BiCGSTAB
    let t = Timer::start();
    let looped: Vec<SolveOutcome> = (0..nrhs)
        .map(|j| {
            bicgstab_solve(&op, &bs[j * n..(j + 1) * n], &bicg_opts, |_, _| MonitorCmd::Continue)
        })
        .collect();
    let looped_s = t.elapsed_s();
    let t = Timer::start();
    let block = bicgstab_solve_multi(&op, &bs, nrhs, &bicg_opts);
    let block_s = t.elapsed_s();
    check_parity(&looped, &block, "bicgstab");
    cells.push(Cell {
        solver: "bicgstab",
        looped_s,
        block_s,
        iters: block.iter().map(|o| o.iters).sum(),
        rounds: block.iter().map(|o| o.iters).max().unwrap_or(0),
        fmt: ValueFormat::Fp64,
    });

    // stepped GMRES over the shared GSE tag ladder
    let t = Timer::start();
    let looped: Vec<SolveOutcome> = (0..nrhs)
        .map(|j| {
            let ladder = SwitchableOp::new(Arc::clone(&gse));
            let b = &bs[j * n..(j + 1) * n];
            let (out, _, _) =
                run_stepped_with(&ladder, params, |op, mon| gmres_solve(op, b, &gmres_opts, mon));
            out
        })
        .collect();
    let looped_s = t.elapsed_s();
    let t = Timer::start();
    let ladder = SwitchableOp::new(Arc::clone(&gse));
    let block = run_stepped_multi(&ladder, &bs, nrhs, params, &BlockSolver::Gmres(gmres_opts));
    let block_s = t.elapsed_s();
    check_parity(&looped, &block, "stepped-gmres");
    cells.push(Cell {
        solver: "stepped-gmres",
        looped_s,
        block_s,
        iters: block.iter().map(|o| o.iters).sum(),
        rounds: block.iter().map(|o| o.iters).max().unwrap_or(0),
        // coarse upper bound: charge every rung at the full GSE level
        fmt: ValueFormat::GseSem(Precision::Full),
    });

    let bw = common::stream_triad_bw();
    eprintln!("STREAM triad roofline {:.2} GB/s", bw / 1e9);
    let mut t = TextTable::new(&[
        "solver",
        "looped(s)",
        "block(s)",
        "speedup",
        "total iters",
        "est GB/s",
        "roof%",
    ]);
    let mut rows = Vec::new();
    for c in &cells {
        // modeled block-solve traffic: matrix planes once per fused
        // round (the block's whole point), per-RHS vector traffic per
        // column iteration. An estimate — solver-side vector ops
        // (orthogonalization, axpys) are not charged — so read it as a
        // lower bound on the block's achieved bandwidth.
        let est_bytes = V100.spmv_matrix_bytes(a.nnz(), n, c.fmt) * c.rounds as f64
            + V100.spmv_rhs_bytes(a.nnz(), n) * c.iters as f64;
        let gbs = est_bytes / c.block_s.max(1e-12) / 1e9;
        let roof = gbs * 1e9 / bw * 100.0;
        t.row(&[
            c.solver.to_string(),
            format!("{:.3}", c.looped_s),
            format!("{:.3}", c.block_s),
            format!("{:.2}x", c.looped_s / c.block_s.max(1e-12)),
            c.iters.to_string(),
            format!("{gbs:.2}"),
            format!("{roof:.1}"),
        ]);
        rows.push(vec![
            c.solver.to_string(),
            nrhs.to_string(),
            format!("{:.6}", c.looped_s),
            format!("{:.6}", c.block_s),
            c.iters.to_string(),
            format!("{gbs:.4e}"),
            format!("{roof:.2}"),
        ]);
    }
    println!("Ablation — block vs. looped multi-RHS, asymmetric + stepped solvers");
    println!("(est GB/s = modeled SpMV traffic of the block solve / measured block time)");
    t.print();
    let _ = write_csv(
        "ablation_block_asym",
        &["solver", "nrhs", "looped_s", "block_s", "total_iters", "est_gbs", "roof_pct"],
        &rows,
    );
}
