//! Fig. 8 — GMRES wall-time speedup over FP64 for FP16 / BF16 /
//! GSE-SEM(stepped) / GSE-SEM* on the GMRES set.
//!
//! GSE-SEM* removes the format-conversion overhead via the paper's
//! Eq. 7: `TIME_fp16 / ITERS_fp16 * ITERS_gse` (FP16 shares the head's
//! memory traffic but widens for free) — the "if hardware supported
//! GSE-SEM" estimate. Paper averages: FP16 0.61x, BF16 0.67x,
//! GSE-SEM 1.24x, GSE-SEM* 1.29x.

#[path = "common.rs"]
mod common;

use gsem::coordinator::SolverKind;
use gsem::sparse::gen::corpus::gmres_set;
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;

fn main() {
    let set = gmres_set(common::bench_corpus_size());
    eprintln!("fig8: GMRES timing over {} matrices x 4 formats", set.len());
    let grid = common::run_suite(SolverKind::Gmres, &set);

    let mut t = TextTable::new(&["ID", "matrix", "FP16", "BF16", "GSE-SEM", "GSE-SEM*"]);
    let mut sp = vec![Vec::new(), Vec::new(), Vec::new(), Vec::new()];
    let mut rows = Vec::new();
    for (i, (name, rs)) in grid.iter().enumerate() {
        let t64 = rs[0].outcome.seconds;
        // a broken-down (overflowed) run has no meaningful time — the
        // paper prints "/" and excludes it from the averages
        let sp_of = |i: usize| {
            if rs[i].outcome.broke_down {
                f64::NAN
            } else {
                t64 / rs[i].outcome.seconds
            }
        };
        let s16 = sp_of(1);
        let sb = sp_of(2);
        let sg = sp_of(3);
        // Eq. 7: conversion-free GSE-SEM estimate from the FP16 (or BF16
        // when FP16 overflowed) per-iteration cost
        let proxy = if rs[1].outcome.broke_down { &rs[2] } else { &rs[1] };
        let per_iter = proxy.outcome.seconds / proxy.outcome.iters.max(1) as f64;
        let t_star = per_iter * rs[3].outcome.iters as f64;
        let sstar = t64 / t_star;
        for (v, s) in sp.iter_mut().zip([s16, sb, sg, sstar]) {
            if s.is_finite() {
                v.push(s);
            }
        }
        t.row(&[
            (i + 1).to_string(),
            name.clone(),
            fmt_sp(s16),
            fmt_sp(sb),
            fmt_sp(sg),
            fmt_sp(sstar),
        ]);
        rows.push(vec![
            name.clone(),
            format!("{s16:.4}"),
            format!("{sb:.4}"),
            format!("{sg:.4}"),
            format!("{sstar:.4}"),
        ]);
    }
    println!("Fig. 8 — GMRES speedup over FP64 (measured wall time)");
    t.print();
    let _ = write_csv(
        "fig8_gmres_speedup",
        &["matrix", "fp16", "bf16", "gse", "gse_star"],
        &rows,
    );
    println!(
        "\naverages (geomean): FP16 {:.2}x  BF16 {:.2}x  GSE-SEM {:.2}x  GSE-SEM* {:.2}x",
        geomean(&sp[0]),
        geomean(&sp[1]),
        geomean(&sp[2]),
        geomean(&sp[3])
    );
    println!("paper averages:     FP16 0.61x  BF16 0.67x  GSE-SEM 1.24x  GSE-SEM* 1.29x");
    println!(
        "shape: GSE-SEM > max(FP16, BF16) on average and GSE-SEM* >= GSE-SEM: {} / {}",
        geomean(&sp[2]) > geomean(&sp[0]).max(geomean(&sp[1])),
        geomean(&sp[3]) >= geomean(&sp[2]) * 0.95
    );
}

fn fmt_sp(s: f64) -> String {
    if s.is_finite() {
        format!("{s:.2}x")
    } else {
        "/".to_string()
    }
}
