//! Ablation — decode-strategy cost in the GSE-SEM SpMV hot loop:
//! the faithful Algorithm-2 bit-scan vs the branch-free ldexp decode vs
//! the per-index scale LUT (the optimized kernel), at each precision
//! level. This quantifies the "format conversion overhead" the paper's
//! GSE-SEM* analysis removes (§IV-D3).

#[path = "common.rs"]
mod common;

use gsem::formats::Precision;
use gsem::sparse::gen::corpus::spmv_corpus;
use gsem::spmv::{fp64, DecodeStrategy, GseCsr};
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;

fn main() {
    let corpus = spmv_corpus(common::bench_corpus_size());
    // take the largest few matrices of each class for stable timing
    let mut picks: Vec<&gsem::sparse::gen::corpus::NamedMatrix> = Vec::new();
    for class in ["pde", "cfd", "fem", "circuit", "random"] {
        let mut of_class: Vec<_> = corpus.iter().filter(|m| m.class == class).collect();
        of_class.sort_by_key(|m| m.a.nnz());
        picks.extend(of_class.into_iter().rev().take(2));
    }
    eprintln!("ablation_decode: {} matrices", picks.len());
    let budget = common::cell_budget();

    let strategies = [
        ("bitscan", DecodeStrategy::BitScan),
        ("ldexp", DecodeStrategy::Ldexp),
        ("scale-lut", DecodeStrategy::ScaleLut),
    ];
    let mut per_strategy: Vec<Vec<f64>> = vec![Vec::new(); 3]; // speedup vs fp64, head level
    let mut rows = Vec::new();
    let mut t = TextTable::new(&["matrix", "level", "fp64", "bitscan", "ldexp", "scale-lut"]);
    for m in &picks {
        let a = &m.a;
        let x = vec![1.0; a.ncols];
        let t64 = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            fp64::spmv(a, &x, &mut y);
            y
        });
        for level in Precision::LADDER {
            let mut times = Vec::new();
            for (_, s) in strategies {
                let g = GseCsr::from_csr(a, 8).with_strategy(s);
                times.push(common::quick_time(budget, || {
                    let mut y = vec![0.0; a.nrows];
                    g.spmv(&x, &mut y, level);
                    y
                }));
            }
            if level == Precision::Head {
                for (i, &tt) in times.iter().enumerate() {
                    per_strategy[i].push(t64 / tt);
                }
            }
            t.row(&[
                m.name.clone(),
                format!("{level:?}"),
                format!("{:.2}us", t64 * 1e6),
                format!("{:.2}us", times[0] * 1e6),
                format!("{:.2}us", times[1] * 1e6),
                format!("{:.2}us", times[2] * 1e6),
            ]);
            rows.push(vec![
                m.name.clone(),
                format!("{level:?}"),
                format!("{:.4e}", t64),
                format!("{:.4e}", times[0]),
                format!("{:.4e}", times[1]),
                format!("{:.4e}", times[2]),
            ]);
        }
    }
    println!("Ablation — SpMV time per decode strategy");
    t.print();
    let _ = write_csv(
        "ablation_decode",
        &["matrix", "level", "t_fp64", "t_bitscan", "t_ldexp", "t_scalelut"],
        &rows,
    );
    println!(
        "\nhead-level speedup vs FP64 (geomean): bitscan {:.2}x  ldexp {:.2}x  scale-lut {:.2}x",
        geomean(&per_strategy[0]),
        geomean(&per_strategy[1]),
        geomean(&per_strategy[2])
    );
}
